"""Adaptive per-transfer KV wire compression: burstiness x bandwidth.

PR 4's wire compression is a static per-fabric mode: an idle fabric pays
quantization error and (de)quant compute for nothing, and a saturated one
cannot reach past its configured mode.  This study sweeps the
:class:`~repro.serving.resources.AdaptiveCompressionPolicy` — the fabric
picks raw / int8 / int4 per transfer from live channel backlog with
hysteresis — against every static mode on the same cells:

1. **Burstiness** — gamma-burst (CV=4) vs Poisson arrivals at the same
   rate; bursts are where a static mode is wrong twice (raw during the
   burst, quantized during the lull).
2. **Bandwidth** — 2 GB/s (transfer-bound, the acceptance regime) and, in
   the full sweep, 8 GB/s (the wire is roomy and raw is fine).

Acceptance on the 2 GB/s bursty cells (asserted in
tests/test_adaptive.py): the adaptive policy's p95 TTFT <= every static
mode's (strictly below raw), while its quantized wire volume stays
strictly below always-int4's — the ramp and lulls ship raw.

Two grounding cells ride along:

* ``parity_rawlock`` — the same cell with the adaptive ladder locked at
  ``("raw",)``: must reproduce the static ``compression=None`` fabric
  (and PR 4's ``kvcomp_*_raw`` baseline cell) bit-exactly, proving the
  policy is inert until it acts.
* ``joint_axis`` — a jointly autoscaled budget-6 cell where the fabric
  starts ceiling-locked at raw and the
  :class:`~repro.serving.autoscaler.JointAutoscaler` raises the mode
  ceiling (compression axis) before trading replicas away from a cold
  tier; vs the same cell raw-locked.

CSV columns: name,us_per_call,derived.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time
from typing import List, Optional

from repro.configs import get_config
from repro.serving.autoscaler import JointAutoscalerConfig, SLOConfig
from repro.serving.prefill import PrefillConfig
from repro.serving.request import Request
from repro.serving.resources import (AdaptiveCompressionConfig, BudgetConfig,
                                     FabricConfig, KVCompressionConfig)
from repro.serving.router import FleetConfig
from repro.serving.simulator import run_elastic_study
from repro.serving.workload import WorkloadSpec, make_workload

try:
    from .common import csv_row
    from .joint_budget import static_split_cell
    from .kv_compression import CHUNK
except ImportError:                      # run as a script, not a module
    from common import csv_row
    from joint_budget import static_split_cell
    from kv_compression import CHUNK

N_ADAPTERS = 256

STATIC_MODES = [
    ("raw", None),
    ("int8", KVCompressionConfig(mode="int8")),
    ("int4", KVCompressionConfig(mode="int4")),
]


def adaptive_workload(burst_cv: float, alpha: float = 1.0, seed: int = 0,
                      n_requests: int = 300) -> List[Request]:
    """Prompt-heavy 256-token stream at 150 req/s; ``burst_cv > 1`` makes
    it gamma-bursty (the CV=4 case is PR 4's transfer-bound workload
    bit-for-bit, keeping the raw cells comparable with BENCH_kvcomp)."""
    return make_workload(WorkloadSpec(
        n_requests=n_requests, n_adapters=N_ADAPTERS,
        popularity="uniform" if alpha == 0 else "zipf", zipf_alpha=alpha,
        arrival="poisson" if burst_cv <= 1 else "gamma",
        arrival_rate=150.0, burst_cv=burst_cv,
        prompt_len_mean=256, prompt_len_std=32, new_tokens=32, seed=seed))


def adaptive_cell(cfg, requests: List[Request], bandwidth: float,
                  adaptive: Optional[AdaptiveCompressionConfig] = None,
                  compression: Optional[KVCompressionConfig] = None,
                  n_prefill: int = 3, n_decode: int = 3):
    """One fixed-split disaggregated cell (same shape as the PR-4 study)."""
    fabric = FabricConfig(bandwidth=bandwidth, chunk_bytes=CHUNK,
                          compression=compression, adaptive=adaptive)
    return static_split_cell(cfg, requests, n_prefill, n_decode,
                             fabric=fabric)


def joint_axis_cell(cfg, requests: List[Request], bandwidth: float,
                    raw_locked: bool = False, total_accels: int = 6,
                    slo_ttft: float = 0.4):
    """Jointly autoscaled cell whose fabric starts ceiling-locked at raw;
    the autoscaler's compression axis must open the ladder under wire
    pressure before any replica trade (``raw_locked=True`` removes the
    ladder entirely, leaving only trades)."""
    adaptive = (AdaptiveCompressionConfig(modes=("raw",)) if raw_locked
                else AdaptiveCompressionConfig(initial_ceiling=0))
    fab = FabricConfig(bandwidth=bandwidth, chunk_bytes=CHUNK,
                       adaptive=adaptive)
    return run_elastic_study(
        cfg, "jd", N_ADAPTERS, [dataclasses.replace(r) for r in requests],
        FleetConfig(n_replicas=2, policy="cluster_affinity"),
        prefill_cfg=PrefillConfig(n_workers=2, fabric=fab),
        slo=SLOConfig(ttft_p95=slo_ttft),
        budget_cfg=BudgetConfig(total_accelerators=total_accels),
        joint_cfg=JointAutoscalerConfig(decision_interval=0.05,
                                        cooldown_intervals=0))


def quantized_wire_bytes(stats_dict) -> int:
    """Wire bytes shipped under any quantized mode (raw excluded)."""
    by_mode = stats_dict.get("kv_wire_bytes_by_mode", {})
    return sum(v for k, v in by_mode.items() if k != "raw")


def main(quick: bool = True, json_path: Optional[str] = None):
    cfg = get_config("mistral-7b")
    bursts = [("bursty", 4.0)] if quick else [("steady", 1.0),
                                              ("bursty", 4.0)]
    bandwidths = [("bw2g", 2e9)] if quick else [("bw2g", 2e9),
                                                ("bw8g", 8e9)]
    rows = []
    metrics = {}

    def record(name, stats, dt, extra=""):
        d = stats.to_dict()
        by_mode = d.get("kv_wire_bytes_by_mode", {})
        mix = ",".join(f"{k}:{v / 1e6:.0f}MB"
                       for k, v in sorted(by_mode.items()))
        derived = (f"rps={d['throughput_rps']:.2f};"
                   f"ttft_p95={d['ttft_p95_s'] * 1e3:.1f}ms;"
                   f"qwire={quantized_wire_bytes(d) / 1e6:.0f}MB;"
                   f"mix={mix};switches={d.get('kv_mode_switches', 0)}")
        if extra:
            derived += ";" + extra
        rows.append(csv_row(name, dt, derived))
        metrics[name] = {"rps": d["throughput_rps"]}
        return d

    for burst_name, burst_cv in bursts:
        reqs = adaptive_workload(burst_cv)
        for bw_name, bw in bandwidths:
            static = {}
            for mode_name, comp in STATIC_MODES:
                t0 = time.perf_counter()
                stats = adaptive_cell(cfg, reqs, bw, compression=comp)
                static[mode_name] = record(
                    f"adaptive_{burst_name}_{bw_name}_{mode_name}", stats,
                    (time.perf_counter() - t0) * 1e6)
            t0 = time.perf_counter()
            stats = adaptive_cell(cfg, reqs, bw,
                                  adaptive=AdaptiveCompressionConfig())
            best_static = min(s["ttft_p95_s"] for s in static.values())
            lt_int4 = (quantized_wire_bytes(stats.to_dict())
                       < quantized_wire_bytes(static["int4"]))
            record(
                f"adaptive_{burst_name}_{bw_name}_adaptive", stats,
                (time.perf_counter() - t0) * 1e6,
                extra=(f"beats_statics="
                       f"{stats.total.ttft_pct(95) <= best_static};"
                       f"lt_int4_qwire={lt_int4}"))

    # parity: the raw-locked ladder must reproduce compression=None bit-
    # exactly — compared against the sweep's own raw static cell (same
    # deterministic workload), which also pins PR 4's kvcomp raw baseline
    reqs = adaptive_workload(4.0)
    t0 = time.perf_counter()
    locked = adaptive_cell(cfg, reqs, 2e9,
                           adaptive=AdaptiveCompressionConfig(
                               modes=("raw",)))
    none_rps = metrics["adaptive_bursty_bw2g_raw"]["rps"]
    record("adaptive_parity_rawlock_bw2g", locked,
           (time.perf_counter() - t0) * 1e6,
           extra=f"bit_exact_vs_none={locked.total.throughput_rps == none_rps}")

    # the joint autoscaler's compression axis vs the same cell raw-locked
    t0 = time.perf_counter()
    axis = joint_axis_cell(cfg, reqs, 2e9)
    n_esc = sum(1 for h in axis.autoscaler if h.d_comp > 0)
    record("adaptive_joint_axis_b6_bw2g", axis,
           (time.perf_counter() - t0) * 1e6, extra=f"ceiling_raises={n_esc}")
    t0 = time.perf_counter()
    record("adaptive_joint_rawlock_b6_bw2g",
           joint_axis_cell(cfg, reqs, 2e9, raw_locked=True),
           (time.perf_counter() - t0) * 1e6)

    if json_path:
        with open(json_path, "w") as f:
            json.dump(metrics, f, indent=2, sort_keys=True)
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="small sweep for CI smoke")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write deterministic metrics as JSON")
    args = ap.parse_args()
    print("\n".join(main(quick=args.quick, json_path=args.json)))
