# One function per paper table. Print ``name,us_per_call,derived`` CSV.
import sys
import time


def main() -> None:
    from . import (cluster_selection, compression_quality, kernel_bench,
                   microbench_lora_fwd, recon_random_vs_trained,
                   roofline_report, serving_throughput)
    mods = [
        ("compression_quality", compression_quality),   # Fig 2/3, Tbl 7-14
        ("serving_throughput", serving_throughput),     # Fig 1/4
        ("microbench_lora_fwd", microbench_lora_fwd),   # Fig 5
        ("cluster_selection", cluster_selection),       # Fig 6 / App G
        ("recon_random_vs_trained", recon_random_vs_trained),  # Tbl 15
        ("kernel_bench", kernel_bench),
        ("roofline_report", roofline_report),           # deliverable (g)
    ]
    print("name,us_per_call,derived")
    for name, mod in mods:
        t0 = time.time()
        try:
            for row in mod.main(quick=True):
                print(row)
        except Exception as e:  # pragma: no cover
            print(f"{name},0,ERROR:{type(e).__name__}:{e}")
        print(f"# {name} done in {time.time()-t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
