"""Heterogeneous placement study: typed slices + rank-aware routing.

The acceptance question of the typed-budget refactor: on a FIXED-COST
pool of mixed slice classes serving a mixed-rank Zipf adapter population,
does typed placement (the right adapters on the right hardware) beat the
best *homogeneous* configuration of the same cost — and how much of the
win is the router's rank-awareness vs just owning a mixed fleet?

Four equal-cost fixed fleets (8 cost units of decode each), every
replica running a paged pool sized from its OWN slice's HBM
(``pool_bytes="slice"``):

* ``homo_small``  — 8 narrow-tile unit slices: the best aggregate
  bandwidth per cost unit, but each replica's pool is tight, so the
  fat-rank working set churns through adapter-page reclaim + DMA;
* ``homo_big``    — 2 wide-tile 4-unit slices (3x speed for 4x cost —
  sublinear, collectives are not free — but 4x the HBM): everything
  stays resident, yet two queues eat the burst tail;
* ``typed_blind`` — the mixed fleet (1 big + 4 small) with rank-blind
  routing: fat adapters land on small slices anyway and churn;
* ``typed``       — the same mixed fleet, rank-aware: the router's
  tile/speed score parks fat ranks on the big slice (whose pool holds
  them resident and whose padding is free at rank 64) and keeps skinny
  ranks on narrow-tile unit slices, where they are cheap.

The study asserts ``typed`` beats the best homogeneous cell AND the
blind mixed cell on p95 TTFT; the committed gate metric is
``ttft_p95_advantage_ratio`` (best-homo p95 / typed p95, >1 = win).

Two companion cells:

* ``joint_typed`` — the jointly autoscaled typed pool: the autoscaler
  picks *which* slice class each scale-up adds (big for prefill
  pressure, small for decode pressure) under one cost-unit budget.
* ``sgmv_microbench`` — wall-clock validation of the pure tile cost
  model (:func:`repro.kernels.sgmv.sgmv_tile_cost`) the router scores
  with: kernel time over rank must fit an affine model (the padding
  story) and grow monotonically.

CSV columns: name,us_per_call,derived.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.configs import get_config
from repro.serving.autoscaler import JointAutoscalerConfig, SLOConfig
from repro.serving.prefill import PrefillConfig
from repro.serving.request import Request
from repro.serving.resources import BudgetConfig, SliceType
from repro.serving.router import FleetConfig
from repro.serving.simulator import run_elastic_study
from repro.serving.workload import WorkloadSpec, make_workload

try:
    from .common import csv_row
except ImportError:                      # run as a script, not a module
    from common import csv_row

N_ADAPTERS = 256
RANKS = (4, 8, 16, 48, 64)               # heterogeneous LoRA collection

# The two slice classes.  BIG is a 4-unit slice: sublinear speed (3x for
# 4x the cost — collectives are not free) but 4x the HBM, so its paged
# pool holds the whole fat working set resident.  SMALL is the unit
# slice: best bandwidth per cost unit, but its pool is small enough that
# a fat-rank working set churns through adapter-page reclaim + DMA.
BIG = SliceType("big", cost_units=4, prefill_speed=3.0, decode_speed=3.0,
                sgmv_tile_rank=32)
SMALL = SliceType("small", cost_units=1, hbm_bytes=38e9, sgmv_tile_rank=8)


def mixed_rank_of(seed: int = 0) -> Dict[int, int]:
    """Adapter id -> LoRA rank, drawn over `RANKS` with a seeded rng."""
    rng = np.random.default_rng(seed)
    return {a: int(rng.choice(RANKS)) for a in range(N_ADAPTERS)}


def mixed_workload(alpha: float = 1.0, seed: int = 0,
                   n_requests: int = 900,
                   rate: float = 800.0) -> List[Request]:
    """Zipf-skewed gamma-burst arrivals over the mixed-rank collection."""
    return make_workload(WorkloadSpec(
        n_adapters=N_ADAPTERS, n_requests=n_requests,
        popularity="zipf", zipf_alpha=alpha,
        arrival="gamma", burst_cv=4.0, arrival_rate=rate,
        prompt_len_mean=64, prompt_len_std=16, new_tokens=24, seed=seed))


def fleet_cost_units(slice_types: Sequence[SliceType]) -> int:
    return sum(st.cost("decode") for st in slice_types)


def placement_cell(cfg, requests: List[Request],
                   slice_types: Sequence[SliceType],
                   rank_of: Optional[Dict[int, int]],
                   rank_aware: bool, max_batch: int = 32):
    """One fixed colocated fleet over the given slice mix."""
    return run_elastic_study(
        cfg, "lora", N_ADAPTERS, [dataclasses.replace(r) for r in requests],
        FleetConfig(n_replicas=len(slice_types), policy="adapter_affinity",
                    rank_aware=rank_aware),
        max_batch=max_batch, pool_bytes="slice",
        decode_slice_types=list(slice_types), rank_of=rank_of,
        report=True)


def joint_typed_cell(cfg, requests: List[Request],
                     rank_of: Optional[Dict[int, int]],
                     total_units: int = 12, slo_ttft: float = 0.4):
    """The jointly autoscaled typed pool: both tiers start small; every
    scale-up names a slice class via the autoscaler's ``pick_slice``."""
    return run_elastic_study(
        cfg, "jd", N_ADAPTERS, [dataclasses.replace(r) for r in requests],
        FleetConfig(n_replicas=2, policy="cluster_affinity"),
        prefill_cfg=PrefillConfig(n_workers=2),
        slo=SLOConfig(ttft_p95=slo_ttft),
        budget_cfg=BudgetConfig(slice_types=(BIG, SMALL),
                                total_cost_units=total_units),
        joint_cfg=JointAutoscalerConfig(decision_interval=0.05,
                                        cooldown_intervals=0),
        decode_slice_types=[SMALL, SMALL], prefill_slice_type=SMALL,
        rank_of=rank_of, report=True)


def sgmv_rank_microbench(ranks: Sequence[int] = (8, 16, 32, 64),
                         T: int = 128, d: int = 256,
                         iters: int = 5) -> Dict[str, float]:
    """Wall-clock check of the affine rank backbone behind
    ``sgmv_tile_cost``: shrink+expand time over rank must fit
    ``t = a + b*r`` and grow with rank.  (CPU interpret mode cannot see
    real tile padding — that part of the model is hardware-documented —
    but the linear-in-rank term it scales is measurable anywhere.)"""
    import jax.numpy as jnp

    from repro.kernels.sgmv import sgmv_expand, sgmv_shrink

    times = []
    for r in ranks:
        x = jnp.ones((T, d), jnp.float32)
        A = jnp.ones((2, r, d), jnp.float32)
        B = jnp.ones((2, d, r), jnp.float32)
        ids = jnp.zeros((T // 128 or 1,), jnp.int32)

        def step():
            t = sgmv_shrink(x, A, ids)
            return sgmv_expand(t, B, ids).block_until_ready()

        step()                           # compile/trace warmup
        samples = []
        for _ in range(iters):
            t0 = time.perf_counter()
            step()
            samples.append(time.perf_counter() - t0)
        times.append(sorted(samples)[len(samples) // 2])   # median

    r_arr = np.asarray(ranks, dtype=float)
    t_arr = np.asarray(times)
    (slope, intercept), res, *_ = np.polyfit(r_arr, t_arr, 1, full=True)
    ss_tot = float(((t_arr - t_arr.mean()) ** 2).sum())
    r2 = 1.0 - (float(res[0]) / ss_tot if ss_tot > 0 and len(res) else 0.0)
    grows = t_arr[-1] > t_arr[0]
    return {"r2": r2, "slope_us_per_rank": slope * 1e6,
            "grows_with_rank": float(grows)}


def main(quick: bool = True, json_path: Optional[str] = None):
    cfg = get_config("mistral-7b")
    rank_of = mixed_rank_of()
    reqs = mixed_workload()
    if quick:
        reqs = reqs[:700]
    rows = []
    metrics = {}

    def record(name, report, dt, extra=""):
        derived = report.derived()
        if extra:
            derived += ";" + extra
        rows.append(csv_row(name, dt, derived))
        metrics[name] = report.metrics()
        return report

    fleets = {
        "homo_small": [SMALL] * 8,
        "homo_big": [BIG] * 2,
        "typed_blind": [BIG] + [SMALL] * 4,
        "typed": [BIG] + [SMALL] * 4,
    }
    costs = {name: fleet_cost_units(mix) for name, mix in fleets.items()}
    assert len(set(costs.values())) == 1, f"unequal cost cells: {costs}"

    p95 = {}
    for name, mix in fleets.items():
        t0 = time.perf_counter()
        rep = placement_cell(cfg, reqs, mix, rank_of,
                             rank_aware=(name == "typed"))
        p95[name] = rep.stats.total.ttft_pct(95)
        record(f"hetero_{name}", rep, (time.perf_counter() - t0) * 1e6,
               extra=f"cost_units={costs[name]};replicas={len(mix)}")

    best_homo = min(p95["homo_small"], p95["homo_big"])
    # the refactor's acceptance cell: typed placement beats the best
    # homogeneous configuration of the same cost, and the rank-aware
    # router beats the same mixed fleet routed blind
    assert p95["typed"] < best_homo, (
        f"typed p95 {p95['typed']:.3f}s not better than best homogeneous "
        f"{best_homo:.3f}s at equal cost")
    assert p95["typed"] < p95["typed_blind"], (
        f"typed p95 {p95['typed']:.3f}s not better than rank-blind mixed "
        f"fleet {p95['typed_blind']:.3f}s")
    advantage = best_homo / p95["typed"]
    blind_gap = p95["typed_blind"] / p95["typed"]
    rows.append(csv_row(
        "hetero_typed_vs_best_homo", 0.0,
        f"advantage={advantage:.3f}x;vs_blind={blind_gap:.3f}x;"
        f"best_homo={'homo_small' if best_homo == p95['homo_small'] else 'homo_big'}"))
    metrics["hetero_typed_vs_best_homo"] = {
        "ttft_p95_advantage_ratio": advantage,
        "rank_aware_vs_blind_ratio": blind_gap,
    }

    # jointly autoscaled typed pool: which classes did the scaler buy?
    t0 = time.perf_counter()
    rep = joint_typed_cell(cfg, reqs, rank_of)
    added = [h.prefill_slice for h in rep.decisions if h.d_prefill > 0] + \
            [h.decode_slice for h in rep.decisions if h.d_decode > 0]
    record("hetero_joint_typed_b12", rep, (time.perf_counter() - t0) * 1e6,
           extra=f"slices_added={','.join(s or '?' for s in added) or 'none'}")

    # wall-clock validation of the tile cost model's rank backbone
    t0 = time.perf_counter()
    mb = sgmv_rank_microbench()
    assert mb["grows_with_rank"], "SGMV time does not grow with rank"
    assert mb["r2"] >= 0.5, f"affine rank fit r2={mb['r2']:.2f} < 0.5"
    rows.append(csv_row("hetero_sgmv_microbench",
                        (time.perf_counter() - t0) * 1e6,
                        f"r2={mb['r2']:.3f};"
                        f"slope={mb['slope_us_per_rank']:.1f}us/rank;"
                        f"grows={bool(mb['grows_with_rank'])}"))
    # wall-clock: informational only (no gated suffix)
    metrics["hetero_sgmv_microbench"] = {"r2": mb["r2"]}

    if json_path:
        with open(json_path, "w") as f:
            json.dump(metrics, f, indent=2, sort_keys=True)
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="small sweep for CI smoke")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write deterministic metrics as JSON")
    args = ap.parse_args()
    print("\n".join(main(quick=args.quick, json_path=args.json)))
