"""Compressed-KV wire-transfer study: bandwidth x compression mode x skew.

PR 3's joint-budget study showed the prefill->decode KV handoff is the
bottleneck on slow interconnects: on a 2 GB/s shared fabric a 256-token
prompt ships ~34 MB of bf16 KV, and the fabric saturates long before the
decode tier does.  This study applies the paper's compress-then-serve
thesis to the wire itself (see ``repro.serving.resources.KVCompressionConfig``
and the grounding Pallas kernels in ``repro.kernels.kv_quant``):

1. **Bandwidth** — the shared fabric's aggregate bytes/s; at 2 GB/s the
   handoff is transfer-bound (where compression should win), at 50 GB/s
   it is not (where compression only pays its quant/dequant cost).
2. **Compression mode** — raw | int8 | int4 | lowrank, all streamed in
   16 MB chunks (see ``CHUNK``), vs. a serial raw reference.
3. **Skew** — adapter popularity, as in the fleet/joint studies.

A parity cell reruns PR 3's ``joint_zipf1.0_b6_fab50g_static3x3`` cell
with the compression field left at None: its throughput must stay
bit-exact with ``benchmarks/baselines/BENCH_joint.json`` (asserted in
tests/test_kvcomp.py), proving the compression path is inert when off.

CSV columns: name,us_per_call,derived.
"""
from __future__ import annotations

import argparse
import json
import time
from typing import List, Optional

from repro.configs import get_config
from repro.serving.request import Request
from repro.serving.resources import FabricConfig, KVCompressionConfig
from repro.serving.workload import WorkloadSpec, make_workload

try:
    from .common import csv_row
    from .joint_budget import phase_shift_workload, static_split_cell
except ImportError:                      # run as a script, not a module
    from common import csv_row
    from joint_budget import phase_shift_workload, static_split_cell

N_ADAPTERS = 256
# 16 MB streamed chunks (layer-group granularity on a ~34 MB KV).  This is
# the transfer-bound sweet spot the study targets: on a 2 GB/s fabric a raw
# 16 MB first chunk serializes at 8 ms — EXCEEDING the 150 req/s arrival
# rate's 6.7 ms inter-arrival budget, so the first-chunk queue grows and
# TTFT becomes transfer-bound; int8 halves the chunk's wire size (~4 ms)
# and keeps the queue drained.  (With tiny chunks the fair interleave's
# first-chunk priority hides any wire-size effect behind prefill queueing.)
CHUNK = 1 << 24

MODES = [
    ("raw", None),
    ("int8", KVCompressionConfig(mode="int8")),
    ("int4", KVCompressionConfig(mode="int4")),
    ("lowrank", KVCompressionConfig(mode="lowrank", lowrank_ratio=0.25)),
]


def transfer_bound_workload(alpha: float = 1.0, seed: int = 0,
                            n_requests: int = 300) -> List[Request]:
    """Prompt-heavy gamma-burst stream (256-token prompts) whose KV volume
    saturates a 2 GB/s fabric — the regime the ROADMAP item targets."""
    return make_workload(WorkloadSpec(
        n_requests=n_requests, n_adapters=N_ADAPTERS,
        popularity="uniform" if alpha == 0 else "zipf", zipf_alpha=alpha,
        arrival="gamma", arrival_rate=150.0, burst_cv=4.0,
        prompt_len_mean=256, prompt_len_std=32, new_tokens=32, seed=seed))


def compression_cell(cfg, requests: List[Request], bandwidth: float,
                     compression: Optional[KVCompressionConfig],
                     chunk_bytes: int = CHUNK, n_prefill: int = 3,
                     n_decode: int = 3):
    """One fixed-split disaggregated cell on a compressing fabric."""
    fabric = FabricConfig(bandwidth=bandwidth, chunk_bytes=chunk_bytes,
                          compression=compression)
    return static_split_cell(cfg, requests, n_prefill, n_decode,
                             fabric=fabric)


def parity_cell(cfg):
    """PR 3's quick static3x3 joint-budget cell, compression off — must
    reproduce BENCH_joint.json's rps bit-exactly."""
    reqs = phase_shift_workload(alpha=1.0)[:1000]
    return static_split_cell(cfg, reqs, 3, 3, fabric=None)


def main(quick: bool = True, json_path: Optional[str] = None):
    cfg = get_config("mistral-7b")
    bandwidths = [("bw2g", 2e9)] if quick else [("bw2g", 2e9),
                                                ("bw8g", 8e9),
                                                ("bw50g", 50e9)]
    skews = [("zipf1.0", 1.0)] if quick else [("uniform", 0.0),
                                              ("zipf1.0", 1.0)]
    rows = []
    metrics = {}

    def record(name, stats, dt, p95_raw=None):
        d = stats.to_dict()
        wire = d.get("kv_bytes_moved", 0)
        raw = d.get("kv_raw_bytes", 0) or wire
        derived = (f"rps={d['throughput_rps']:.2f};"
                   f"ttft_p95={d['ttft_p95_s'] * 1e3:.1f}ms;"
                   f"wire_ratio={wire / max(raw, 1):.3f}")
        if p95_raw is not None:
            derived += f";beats_raw_chunked={d['ttft_p95_s'] < p95_raw}"
        rows.append(csv_row(name, dt, derived))
        metrics[name] = {"rps": d["throughput_rps"]}
        return d["ttft_p95_s"]

    for skew_name, alpha in skews:
        reqs = transfer_bound_workload(alpha=alpha)
        for bw_name, bw in bandwidths:
            # serial raw handoff: the PR-2-shaped worst case on this fabric
            t0 = time.perf_counter()
            stats = compression_cell(cfg, reqs, bw, None, chunk_bytes=0)
            record(f"kvcomp_{skew_name}_{bw_name}_raw_serial", stats,
                   (time.perf_counter() - t0) * 1e6)
            p95_raw = None
            for mode_name, comp in MODES:
                t0 = time.perf_counter()
                stats = compression_cell(cfg, reqs, bw, comp)
                p95 = record(f"kvcomp_{skew_name}_{bw_name}_{mode_name}",
                             stats, (time.perf_counter() - t0) * 1e6,
                             p95_raw=p95_raw)
                if mode_name == "raw":
                    p95_raw = p95

    t0 = time.perf_counter()
    stats = parity_cell(cfg)
    record("kvcomp_parity_joint_static3x3", stats,
           (time.perf_counter() - t0) * 1e6)
    if json_path:
        with open(json_path, "w") as f:
            json.dump(metrics, f, indent=2, sort_keys=True)
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="small sweep for CI smoke")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write deterministic metrics as JSON")
    args = ap.parse_args()
    print("\n".join(main(quick=args.quick, json_path=args.json)))
