"""Real-executor decode throughput: unfused vs fused vs fused+int8 (PR 8).

The first wall-clock (non-simulated) number the perf gate protects.  On the
cheap `tests/test_system.py` fixture config (d64 / 2-head / 2-layer) this
sweeps decode batch x mode over `RealModelExecutor`'s three decode paths:

* ``unfused``  — generic `transformer.decode_step` (functional KV cache,
  attention and adapter delta as separate passes).  Baseline-bit-exact.
* ``fused``    — the purpose-built step: unrolled layers, donated in-place
  KV cache, attention + o-projection adapter delta in one pass
  (`kernels/fused_decode.py` via `kernels/ops.py`).
* ``fused_q8`` — ``fused`` with int8 per-channel adapter residency
  (`kernels/adapter_quant.py`).

Gated metrics (see benchmarks/check_regression.py): per-batch
``fused_speedup`` / ``fused_q8_speedup`` (dimensionless, stable across CI
hosts — absolute ``*_steps_per_s`` are reported but not gated) and the
residency cell's ``bytes_ratio`` / ``page_ratio``.  Hard in-benchmark
asserts (the ISSUE's acceptance criteria, independent of the baseline
file): fused >= 1.3x unfused at the largest batch, int8 page usage cut
>= 3x at reconstruction rel-err within the lifecycle refresh gate (0.5),
and unfused/fused argmax-token parity.

``--json`` also embeds ``derived``: the simulator cost-model constants
re-fit from the FUSED measurements via
:func:`repro.serving.real_executor.derive_cost_constants` — t(B) ~=
step_overhead_s + per_slot_s * B, the same affine shape as
`ServingHardware.step_overhead` + the roofline per-token term, so
simulator drift vs the real executor is a number in the report.

CSV columns: name,us_per_call,derived.
"""
from __future__ import annotations

import argparse
import dataclasses as dc
import json
import math
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import smoke_config
from repro.kernels.adapter_quant import adapter_quantize, quantized_nbytes
from repro.kernels.ref import adapter_dequant_ref
from repro.models import transformer as tf
from repro.models.param import init_params
from repro.serving.real_executor import (RealModelExecutor,
                                         derive_cost_constants)
from repro.serving.request import Request
from repro.serving.resources import PAGE_TOKENS

try:
    from .common import csv_row
except ImportError:                      # run as a script, not a module
    from common import csv_row

N_ADAPTERS = 8
RANK = 8
# slots provision for long generations; the fused path only touches the
# occupied 128-token bucket of this, the unfused path masks all of it
S_MAX = 1024
MODES = ("unfused", "fused", "fused_q8")


def fixture_config():
    """The `tests/test_system.py` cheap fixture config — keeps the lane
    budget small and ties the wall-clock gate to a config CI already
    exercises."""
    return dc.replace(smoke_config("mistral-7b"), num_layers=2, d_model=64,
                      num_heads=2, num_kv_heads=1, d_ff=128, vocab_size=64)


def _target_dims(cfg):
    d, hd = cfg.d_model, cfg.resolved_head_dim
    return {"q": (d, cfg.num_heads * hd), "k": (d, cfg.num_kv_heads * hd),
            "v": (d, cfg.num_kv_heads * hd), "o": (cfg.num_heads * hd, d)}


def make_bundles(cfg, n: int, rank: int, seed: int = 7):
    """float32 LoRA banks over q/k/v/o (f32 = training-output dtype; the
    int8 residency ratio is measured against what trainers emit)."""
    dims = _target_dims(cfg)
    ks = jax.random.split(jax.random.PRNGKey(seed), 2 * len(dims))
    out = {"layers": {}}
    for i, (t, (di, do)) in enumerate(dims.items()):
        out["layers"][t] = {
            "A": 0.05 * jax.random.normal(ks[2 * i],
                                          (cfg.num_layers, n, rank, di),
                                          jnp.float32),
            "B": 0.05 * jax.random.normal(ks[2 * i + 1],
                                          (cfg.num_layers, n, do, rank),
                                          jnp.float32)}
    return out


def _prompts(batch: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    return {rid: rng.integers(0, 36, size=int(rng.integers(6, 14)))
            .astype(np.int32) for rid in range(batch)}


def _prefilled(cfg, params, bundles, mode: str, batch: int):
    ex = RealModelExecutor(cfg, params, bundles, "lora", max_batch=batch,
                           s_max=S_MAX, decode_path=mode)
    for rid, prompt in _prompts(batch).items():
        ex.prefill_request(Request(rid=rid, adapter_id=rid % N_ADAPTERS,
                                   prompt_len=len(prompt),
                                   max_new_tokens=64), prompt)
    return ex


def decode_cell(cfg, params, bundles, mode: str, batch: int,
                steps: int, reps: int = 3) -> float:
    """Seconds per decode step (all slots advance one token).

    Best-of-``reps`` chunks of ``steps``: the per-step cost is a few ms,
    so a single chunk is one scheduler hiccup away from a 30% swing; the
    minimum chunk is the de-noised estimate CI can gate."""
    ex = _prefilled(cfg, params, bundles, mode, batch)
    for _ in range(2):                   # compile + warm
        ex.decode_step_real()
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        for _ in range(steps):
            ex.decode_step_real()
        best = min(best, (time.perf_counter() - t0) / steps)
    return best


def parity_cell(cfg, params, bundles, batch: int = 8, steps: int = 4):
    """Unfused vs fused must emit the SAME argmax token stream (the fused
    path is opt-in precisely because the unfused one is the bit-exactness
    anchor for every committed baseline)."""
    e_u = _prefilled(cfg, params, bundles, "unfused", batch)
    e_f = _prefilled(cfg, params, bundles, "fused", batch)
    match = all(e_u.decode_step_real() == e_f.decode_step_real()
                for _ in range(steps))
    assert match, "fused decode diverged from the unfused token stream"
    return {"token_match": float(match)}


def residency_cell(cfg, rank: int = 128, n: int = 8):
    """int8 adapter residency vs the float32 training-output banks.

    Rank is fat (128) on purpose: at the fixture's 32 KiB page the decode
    ranks round to a page or two and the ratio is granularity, not
    compression; serving-scale ranks make the page arithmetic meaningful.
    """
    dims = _target_dims(cfg)
    L = cfg.num_layers
    page_bytes = (2 * 2 * L * cfg.num_kv_heads * cfg.resolved_head_dim
                  * PAGE_TOKENS)
    fp_bytes = q8_bytes = 0
    rel_errs = []
    key = jax.random.PRNGKey(11)
    for t, (di, do) in dims.items():
        key, ka, kb = jax.random.split(key, 3)
        A = 0.05 * jax.random.normal(ka, (L, n, rank, di), jnp.float32)
        B = 0.05 * jax.random.normal(kb, (L, n, do, rank), jnp.float32)
        fp_bytes += 4 * L * rank * (di + do)          # per adapter
        q8_bytes += (quantized_nbytes((L, rank, di))
                     + quantized_nbytes((L, do, rank)))
        aq, a_s = adapter_quantize(A)
        bq, b_s = adapter_quantize(B)
        w = jnp.einsum("lnor,lnri->lnoi", B, A)
        wq = jnp.einsum("lnor,lnri->lnoi", adapter_dequant_ref(bq, b_s),
                        adapter_dequant_ref(aq, a_s))
        num = jnp.linalg.norm((wq - w).reshape(L, n, -1), axis=-1)
        den = jnp.linalg.norm(w.reshape(L, n, -1), axis=-1)
        rel_errs.append(float(jnp.max(num / den)))
    fp_pages = math.ceil(fp_bytes / page_bytes)
    q8_pages = math.ceil(q8_bytes / page_bytes)
    out = {"bytes_ratio": fp_bytes / q8_bytes,
           "page_ratio": fp_pages / q8_pages,
           "rel_err": max(rel_errs)}
    assert out["page_ratio"] >= 3.0, out      # acceptance: >= 3x page cut
    assert out["rel_err"] <= 0.5, out         # lifecycle gate_max_rel_err
    return out


def main(quick: bool = True, json_path: Optional[str] = None):
    cfg = fixture_config()
    params = init_params(tf.model_defs(cfg), jax.random.PRNGKey(0))
    bundles = make_bundles(cfg, N_ADAPTERS, RANK)
    batches = (2, 8) if quick else (2, 4, 8, 16)
    steps = 12 if quick else 24
    rows = []
    metrics = {}
    fused_samples = []
    for batch in batches:
        cell = {}
        for mode in MODES:
            sec = decode_cell(cfg, params, bundles, mode, batch, steps)
            cell[f"{mode}_steps_per_s"] = 1.0 / sec
            cell[f"{mode}_sec"] = sec
            if mode == "fused":
                fused_samples.append((batch, sec))
        for mode in ("fused", "fused_q8"):
            cell[f"{mode}_speedup"] = cell["unfused_sec"] / cell[f"{mode}_sec"]
        rows.append(csv_row(
            f"real_decode_b{batch}", cell["fused_sec"] * 1e6,
            f"unfused_us={cell['unfused_sec'] * 1e6:.0f};"
            f"fused_speedup={cell['fused_speedup']:.2f};"
            f"fused_q8_speedup={cell['fused_q8_speedup']:.2f}"))
        # speedup is gated (check_regression HIGHER_IS_BETTER) — only emit
        # it where the acceptance criterion applies (batch >= 8); small
        # batches are overhead-dominated and too noisy to gate
        metrics[f"decode_b{batch}"] = {
            k: v for k, v in cell.items()
            if not k.endswith("_sec")
            and (batch >= 8 or not k.endswith("_speedup"))}
    big = max(batches)
    headline = metrics[f"decode_b{big}"]["fused_speedup"]
    assert headline >= 1.3, (                 # acceptance criterion
        f"fused speedup {headline:.2f} < 1.3x at batch {big}")
    metrics["parity"] = parity_cell(cfg, params, bundles)
    metrics["residency"] = residency_cell(cfg)
    rows.append(csv_row(
        "real_decode_residency", 0.0,
        f"bytes_ratio={metrics['residency']['bytes_ratio']:.2f};"
        f"page_ratio={metrics['residency']['page_ratio']:.2f};"
        f"rel_err={metrics['residency']['rel_err']:.4f}"))
    derived = derive_cost_constants(fused_samples)
    derived["derivation"] = ("least-squares fit of fused decode wall clock "
                             "t(B) = step_overhead_s + per_slot_s * B over "
                             f"batches {list(b for b, _ in fused_samples)}; "
                             "compare ServingHardware.step_overhead and the "
                             "cost-model per-token term")
    metrics["derived"] = {k: v for k, v in derived.items()
                          if isinstance(v, float)}
    metrics["derived"]["n_samples"] = derived["n_samples"]
    rows.append(csv_row(
        "real_decode_cost_model", 0.0,
        f"step_overhead_s={derived['step_overhead_s']:.2e};"
        f"per_slot_s={derived['per_slot_s']:.2e};r2={derived['r2']:.3f}"))
    if json_path:
        out = dict(metrics)
        out["derived"] = derived              # keep the prose derivation
        with open(json_path, "w") as f:
            json.dump(out, f, indent=2, sort_keys=True)
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="small sweep for CI smoke")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write metrics as JSON "
                         "(CI perf gate; see benchmarks/check_regression.py)")
    args = ap.parse_args()
    print("\n".join(main(quick=args.quick, json_path=args.json)))
