"""Mid-stream live-migration study: instant scale-down vs drain.

PR 9's acceptance cell.  A fleet of six decode replicas (the full
6-accelerator budget) serves a hot Zipf(1.0) workload; mid-run one
replica must give its budget slice back.  Two retirement disciplines
compete:

* **drain** — the replica stops taking new work and runs its queue to
  completion; the slice is free only when the last straggler finishes,
  and the replacement capacity (the re-invested slice) comes online at
  that drain-end instant;
* **migrate** — every request still on the replica, running mid-decode
  or queued, is checkpointed (KV pages freed at the source immediately),
  shipped int8-quantized over the migration fabric, and re-admitted on a
  surviving replica token-exactly; the slice is free AT the retire
  instant and the replacement comes online immediately.

Both disciplines spend the same budget — the comparison is purely WHEN
the slice is released and re-invested.  Acceptance (asserted below and
gated by the committed baseline):

* migrate releases the slice strictly sooner than drain
  (``release_speedup`` > 1);
* instant scale-down beats the drain on p95 TTFT over the post-retire
  window (requests arriving after the retire event) —
  ``post_ttft_ratio`` > 1;
* every request in the migrate cell finishes with exactly the control
  cell's generated-token count (the cost-model face of invariant M1;
  tests/test_migration.py pins content-level token exactness on the
  real executor), and at least one retire-triggered migration actually
  happened.

CSV columns: name,us_per_call,derived.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time
from typing import List, Optional

import numpy as np

from repro.configs import get_config
from repro.serving.engine import ServingHardware
from repro.serving.migration import MigrationConfig, MigrationPolicy
from repro.serving.request import Request
from repro.serving.resources import FabricConfig, KVCompressionConfig
from repro.serving.router import FleetConfig
from repro.serving.simulator import (StudyEvent, build_engine, build_fleet,
                                     memory_matched_setup, run_study)
from repro.serving.workload import WorkloadSpec, make_workload

try:
    from .common import csv_row
except ImportError:                      # run as a script, not a module
    from common import csv_row

N_BASE = 128
MODE = "jd"
N_REPLICAS = 6                           # the whole accelerator budget
RETIRE_IDX = N_REPLICAS - 1
WINDOW = 0.02


def hot_workload(n_requests: int, seed: int = 0) -> List[Request]:
    """Zipf(1.0)-skewed Poisson stream with generations long enough that
    the retire event lands mid-decode for a full batch."""
    return make_workload(WorkloadSpec(
        n_requests=n_requests, n_adapters=N_BASE,
        popularity="zipf", zipf_alpha=1.0,
        arrival="poisson", arrival_rate=520.0,
        prompt_len_mean=128, prompt_len_std=16,
        new_tokens=48, seed=seed))


def _setup(cfg):
    setting, cluster_of, budget = memory_matched_setup(cfg, N_BASE)
    fabric = FabricConfig(bandwidth=50e9, chunk_bytes=1 << 20,
                          compression=KVCompressionConfig(mode="int8"))
    # least_outstanding: routing by live queue depth lets the re-invested
    # replica fill at the natural service rate (the affinity policies'
    # cumulative routed-load estimate would dump a full-history backlog
    # on any replica that joins mid-run)
    fleet_cfg = FleetConfig(n_replicas=N_REPLICAS, policy="least_outstanding",
                            migration_fabric=fabric)
    return setting, cluster_of, budget, fleet_cfg


def migration_cell(cfg, requests: List[Request], retire_t: Optional[float],
                   migrate: bool, reinvest_t: Optional[float] = None):
    """One retirement discipline over a fresh fleet.

    ``retire_t=None`` is the no-event control.  With ``migrate`` the
    retire is instant scale-down through an attached
    :class:`MigrationPolicy` (priority preemption and defrag disabled so
    the pre-retire trajectory is identical across cells).  ``reinvest_t``
    attaches the replacement replica — the re-invested budget slice — at
    that instant (the retire time for migrate, the discovered drain end
    for the drain cell)."""
    setting, cluster_of, budget, fleet_cfg = _setup(cfg)
    hw = ServingHardware()
    fleet = build_fleet(cfg, MODE, N_BASE, budget, fleet_cfg, hw,
                        cluster_of, setting)
    reqs = requests                      # caller owns the copy
    if retire_t is None:
        return run_study(fleet, reqs)
    policy = (MigrationPolicy(MigrationConfig(
        preempt_priority=False, defrag=False)) if migrate else None)
    events = [StudyEvent(retire_t,
                         lambda st: st.retire_decode(RETIRE_IDX,
                                                     migrate=migrate),
                         label="retire")]
    if reinvest_t is not None:
        events.append(StudyEvent(
            reinvest_t,
            lambda st: st.attach_engine(build_engine(
                cfg, MODE, N_BASE, budget, hw, cluster_of, setting)),
            label="reinvest"))
    return run_study(fleet, reqs, events=events, migration=policy,
                     window=WINDOW)


def release_time(reqs: List[Request], retire_t: float) -> float:
    """When the retired replica's hardware is actually free: the last
    finish on it after the retire event (the drain tail), or the retire
    instant itself when it was emptied by migration."""
    tail = [r.finish_time for r in reqs
            if r.replica == RETIRE_IDX and r.finish_time is not None
            and r.finish_time > retire_t]
    return max(tail) if tail else retire_t


def post_ttft_p95(reqs: List[Request], retire_t: float) -> float:
    xs = [r.ttft for r in reqs
          if r.arrival_time >= retire_t and r.ttft is not None]
    return float(np.percentile(xs, 95)) if xs else 0.0


def main(quick: bool = True, json_path: Optional[str] = None):
    cfg = get_config("mistral-7b")
    n_requests = 600 if quick else 1500
    base = hot_workload(n_requests)
    retire_t = 0.4 * base[-1].arrival_time
    rows, metrics = [], {}
    cells = {}

    def run(name, **kw):
        reqs = [dataclasses.replace(r) for r in base]
        t0 = time.perf_counter()
        report = migration_cell(cfg, reqs, **kw)
        dt = (time.perf_counter() - t0) * 1e6
        cells[name] = (reqs, report)
        rows.append(csv_row(f"migrate_{name}", dt, report.derived()))
        metrics[f"migrate_{name}"] = report.metrics()
        return reqs, report

    run("control", retire_t=None, migrate=False)
    # pass 1 discovers the drain tail: how long the slice stays occupied
    drain_reqs, _ = run("drain", retire_t=retire_t, migrate=False)
    rel_drain = release_time(drain_reqs, retire_t)
    # pass 2 re-invests the slice the instant the drain actually frees it
    run("drain_reinvest", retire_t=retire_t, migrate=False,
        reinvest_t=rel_drain)
    mig_reqs, mig_report = run("migrate", retire_t=retire_t, migrate=True,
                               reinvest_t=retire_t)
    rel_mig = release_time(mig_reqs, retire_t)

    # -- acceptance --------------------------------------------------------
    mig = mig_report.migration
    assert mig is not None and mig["n_retire_migrations"] > 0, mig
    assert rel_mig < rel_drain, (rel_mig, rel_drain)
    p95_drain = post_ttft_p95(cells["drain_reinvest"][0], retire_t)
    p95_mig = post_ttft_p95(mig_reqs, retire_t)
    assert p95_mig < p95_drain, (p95_mig, p95_drain)
    # token parity with the unmigrated control, request by request
    ctrl_gen = {r.rid: r.generated for r in cells["control"][0]}
    mig_gen = {r.rid: r.generated for r in mig_reqs}
    assert mig_gen == ctrl_gen, "migrated cell diverged from control"
    assert all(r.finish_time is not None for r in mig_reqs)

    release_speedup = rel_drain / rel_mig
    post_ratio = p95_drain / p95_mig
    rows.append(csv_row(
        "migrate_headline", 0.0,
        f"retire_t={retire_t:.3f}s;release_drain={rel_drain:.3f}s;"
        f"release_migrate={rel_mig:.3f}s;release_speedup={release_speedup:.2f}x;"
        f"post_ttft_ratio={post_ratio:.2f};"
        f"migrations={mig['n_migrations']};"
        f"wire_mb={mig['kv_wire_bytes'] / 1e6:.1f}"))
    metrics["migrate_headline"] = {"release_speedup": release_speedup,
                                   "post_ttft_ratio": post_ratio}
    if json_path:
        with open(json_path, "w") as f:
            json.dump(metrics, f, indent=2, sort_keys=True)
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="small sweep for CI smoke")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write deterministic metrics as JSON "
                         "(CI perf gate; see benchmarks/check_regression.py)")
    args = ap.parse_args()
    print("\n".join(main(quick=args.quick, json_path=args.json)))
