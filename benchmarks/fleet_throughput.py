"""Fleet-scale serving study: replicas x popularity skew x routing policy.

Extends the paper's Fig. 1/4 single-replica setup to the production regime
S-LoRA measures: many replicas, Zipf-skewed adapter popularity, asynchronous
(Poisson) arrivals.  Compares routing policies for both the uncompressed
("lora") and compressed ("jd") serving modes; JD-cluster-affinity routing
co-locates adapters sharing a compressed basis, maximizing pinned-base reuse
per replica and minimizing swap traffic.

CSV columns: name,us_per_call,derived  (matches benchmarks/run.py contract).
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time
from typing import Optional

from repro.configs import get_config
from repro.serving.router import FleetConfig
from repro.serving.simulator import build_fleet, memory_matched_setup
from repro.serving.workload import WorkloadSpec, make_workload

try:
    from .common import csv_row
except ImportError:                      # run as a script, not a module
    from common import csv_row


def run_cell(model_cfg, n_adapters: int, n_replicas: int, policy: str,
             mode: str, wl: WorkloadSpec, cluster_seed: int = 0,
             prefetch: bool = False):
    from repro.serving.engine import ServingHardware
    setting, cluster_of, budget = memory_matched_setup(
        model_cfg, n_adapters, cluster_seed)
    fleet = build_fleet(model_cfg, mode, n_adapters, budget,
                        FleetConfig(n_replicas=n_replicas, policy=policy),
                        ServingHardware(), cluster_of, setting,
                        prefetch=prefetch)
    fleet.submit(make_workload(
        dataclasses.replace(wl, n_adapters=n_adapters)))
    return fleet.run()


def main(quick: bool = True, json_path: Optional[str] = None):
    cfg = get_config("mistral-7b")
    n_adapters = 256
    replicas = [4] if quick else [1, 2, 4, 8]
    skews = [("uniform", 0.0), ("zipf1.0", 1.0)]
    policies = ["round_robin", "least_outstanding", "adapter_affinity",
                "cluster_affinity"]
    n_requests = 600 if quick else 2000
    rows = []
    metrics = {}
    for n_rep in replicas:
        for skew_name, alpha in skews:
            wl = WorkloadSpec(
                n_requests=n_requests, new_tokens=10,
                popularity="uniform" if alpha == 0 else "zipf",
                zipf_alpha=alpha,
                arrival="poisson",
                # saturating per-replica offered load (single-replica capacity
                # is ~145 rps): throughput differences reflect steady state,
                # not arrival gaps
                arrival_rate=500.0 * n_rep)
            for mode in ("lora", "jd"):
                for policy in policies:
                    t0 = time.perf_counter()
                    stats = run_cell(cfg, n_adapters, n_rep, policy, mode, wl)
                    dt = (time.perf_counter() - t0) * 1e6
                    d = stats.to_dict()
                    name = f"fleet_{mode}_{skew_name}_r{n_rep}_{policy}"
                    rows.append(csv_row(
                        name, dt,
                        f"rps={d['throughput_rps']:.2f};"
                        f"p50={d['latency_p50_s'] * 1e3:.1f}ms;"
                        f"p99={d['latency_p99_s'] * 1e3:.1f}ms;"
                        f"ttft_p95={d['ttft_p95_s'] * 1e3:.1f}ms;"
                        f"swaps={d['n_swaps']};"
                        "per_rep=" + "/".join(
                            str(n) for n in d["per_replica_n_requests"])))
                    # simulated-clock metrics: deterministic, gateable
                    metrics[name] = {"rps": d["throughput_rps"]}
    if json_path:
        with open(json_path, "w") as f:
            json.dump(metrics, f, indent=2, sort_keys=True)
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="small sweep for CI smoke")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write deterministic metrics as JSON "
                         "(CI perf gate; see benchmarks/check_regression.py)")
    args = ap.parse_args()
    print("\n".join(main(quick=args.quick, json_path=args.json)))
