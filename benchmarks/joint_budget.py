"""Joint-budget serving study: budget x skew x fabric bandwidth.

The question PR 2's disaggregated study could not ask: given a FIXED pool
of N accelerators, how should it be split between prefill workers and
decode replicas — and can a joint autoscaler that re-splits on the fly beat
every static split when the workload's prefill:decode mix shifts?

Three axes:

1. **Budget** — total accelerators in the pool; every configuration
   (static splits and the joint autoscaler) draws from the same pool.
2. **Skew** — adapter popularity (uniform vs Zipf), as in the fleet study.
3. **Fabric bandwidth** — the shared KV fabric all prefill workers contend
   on; at low bandwidth the handoff is transfer-bound and chunked
   streaming (first chunk unblocks decode) starts to matter.

The driving workload is *phase-shifted*: a prompt-heavy phase (long
prompts, few generated tokens — the prefill tier is the bottleneck)
followed by a decode-heavy phase (short prompts, long generations — the
decode tier is).  No static split is right for both phases, which is
exactly the regime where joint autoscaling pays.

CSV columns: name,us_per_call,derived.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time
from typing import List, Optional

from repro.configs import get_config
from repro.serving.autoscaler import JointAutoscalerConfig, SLOConfig
from repro.serving.prefill import PrefillConfig
from repro.serving.request import Request
from repro.serving.resources import BudgetConfig, FabricConfig
from repro.serving.router import FleetConfig
from repro.serving.simulator import run_elastic_study
from repro.serving.workload import WorkloadSpec, make_workload

try:
    from .common import csv_row
except ImportError:                      # run as a script, not a module
    from common import csv_row

N_ADAPTERS = 256


def phase_shift_workload(alpha: float = 1.0, seed: int = 0,
                         n_prompt_heavy: int = 600,
                         n_decode_heavy: int = 900,
                         prompt_rate: float = 220.0,
                         decode_rate: float = 320.0) -> List[Request]:
    """Prompt-heavy phase (512-token prompts, 4 generated tokens) followed
    by a decode-heavy phase (64-token prompts, 48 generated tokens), both
    gamma-bursty (CV=4) over the same Zipf-skewed adapter set."""
    base = WorkloadSpec(
        n_adapters=N_ADAPTERS,
        popularity="uniform" if alpha == 0 else "zipf", zipf_alpha=alpha,
        arrival="gamma", burst_cv=4.0, seed=seed)
    phase_a = make_workload(dataclasses.replace(
        base, n_requests=n_prompt_heavy, arrival_rate=prompt_rate,
        prompt_len_mean=512, prompt_len_std=64, new_tokens=4))
    phase_b = make_workload(dataclasses.replace(
        base, n_requests=n_decode_heavy, arrival_rate=decode_rate,
        prompt_len_mean=64, prompt_len_std=16, new_tokens=48,
        seed=seed + 1))
    t0 = phase_a[-1].arrival_time if phase_a else 0.0
    for r in phase_b:
        r.rid += len(phase_a)
        r.arrival_time += t0
    return phase_a + phase_b


def static_split_cell(cfg, requests: List[Request], n_prefill: int,
                      n_decode: int, mode: str = "jd",
                      fabric: Optional[FabricConfig] = None,
                      report: bool = False):
    """A fixed prefill:decode split of the budget (no autoscaling)."""
    return run_elastic_study(
        cfg, mode, N_ADAPTERS, [dataclasses.replace(r) for r in requests],
        FleetConfig(n_replicas=n_decode, policy="cluster_affinity"),
        prefill_cfg=PrefillConfig(n_workers=n_prefill, fabric=fabric),
        report=report)


def joint_cell(cfg, requests: List[Request], total_accels: int,
               slo_ttft: float, mode: str = "jd",
               n_prefill0: int = 2, n_decode0: int = 2,
               fabric: Optional[FabricConfig] = None,
               cooldown: int = 0, interval: float = 0.05,
               report: bool = False):
    """The jointly autoscaled cell over the same fixed budget."""
    return run_elastic_study(
        cfg, mode, N_ADAPTERS, [dataclasses.replace(r) for r in requests],
        FleetConfig(n_replicas=n_decode0, policy="cluster_affinity"),
        prefill_cfg=PrefillConfig(n_workers=n_prefill0, fabric=fabric),
        slo=SLOConfig(ttft_p95=slo_ttft),
        budget_cfg=BudgetConfig(total_accelerators=total_accels),
        joint_cfg=JointAutoscalerConfig(
            decision_interval=interval, cooldown_intervals=cooldown),
        report=report)


def main(quick: bool = True, json_path: Optional[str] = None):
    cfg = get_config("mistral-7b")
    budgets = [6] if quick else [4, 6, 8]
    skews = [("zipf1.0", 1.0)] if quick else [("uniform", 0.0),
                                              ("zipf1.0", 1.0)]
    fabrics = [("fab50g", None)] if quick else [
        ("fab50g", None),
        ("fab2g", FabricConfig(bandwidth=2e9, chunk_bytes=1 << 20)),
    ]
    slo = 0.4
    rows = []
    metrics = {}

    def record(name, report, dt):
        rows.append(csv_row(name, dt, report.derived(slo_ttft=slo)))
        metrics[name] = report.metrics()

    for skew_name, alpha in skews:
        reqs = phase_shift_workload(alpha=alpha)
        if quick:
            reqs = reqs[:1000]
        for total in budgets:
            for fab_name, fabric in fabrics:
                # static splits of the same budget
                splits = ([(total // 2, total - total // 2)] if quick
                          else [(p, total - p) for p in range(1, total)])
                for n_pf, n_dec in splits:
                    t0 = time.perf_counter()
                    report = static_split_cell(cfg, reqs, n_pf, n_dec,
                                               fabric=fabric, report=True)
                    record(f"joint_{skew_name}_b{total}_{fab_name}"
                           f"_static{n_pf}x{n_dec}",
                           report, (time.perf_counter() - t0) * 1e6)
                # the joint autoscaler over the same pool
                t0 = time.perf_counter()
                report = joint_cell(cfg, reqs, total, slo_ttft=slo,
                                    fabric=fabric, report=True)
                record(f"joint_{skew_name}_b{total}_{fab_name}_auto",
                       report, (time.perf_counter() - t0) * 1e6)
    if json_path:
        with open(json_path, "w") as f:
            json.dump(metrics, f, indent=2, sort_keys=True)
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="small sweep for CI smoke")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write deterministic metrics as JSON")
    args = ap.parse_args()
    print("\n".join(main(quick=args.quick, json_path=args.json)))
