"""Fig. 5 analogue: adapter memory footprint, host->device transfer model,
and forward-pass latency of uncompressed vs JD-compressed application."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ref as R
from repro.serving.adapter_cache import DMAModel
from .common import csv_row, timed


def main(quick: bool = True):
    rows = []
    T, d, n, r = (256, 1024, 64, 16) if quick else (1024, 4096, 256, 16)
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 6)
    x = jax.random.normal(ks[0], (T, d), jnp.float32)
    A = jax.random.normal(ks[1], (n, r, d)) * 0.02
    B = jax.random.normal(ks[2], (n, d, r)) * 0.02
    U = jax.random.normal(ks[3], (1, d, r)) * 0.02
    V = jax.random.normal(ks[4], (1, d, r)) * 0.02
    sig = jax.random.normal(ks[5], (n, r, r)) * 0.1
    ids = jax.random.randint(ks[0], (T,), 0, n)
    cluster_of = jnp.zeros((n,), jnp.int32)

    lora_apply = jax.jit(R.lora_apply_ref)
    jd_apply = jax.jit(R.jd_apply_ref)
    _, t_lora = timed(lora_apply, x, A, B, ids, reps=5)
    _, t_jd = timed(jd_apply, x, U, V, sig, cluster_of, ids, reps=5)

    bytes_lora = n * r * 2 * d * 4
    bytes_jd = 2 * d * r * 4 + n * r * r * 4
    dma = DMAModel()
    t_xfer_lora = bytes_lora / dma.bandwidth
    t_xfer_jd = bytes_jd / dma.bandwidth
    rows.append(csv_row("lora_fwd", t_lora * 1e6,
                        f"mem_MB={bytes_lora/1e6:.2f};xfer_ms={t_xfer_lora*1e3:.3f}"))
    rows.append(csv_row("jd_fwd", t_jd * 1e6,
                        f"mem_MB={bytes_jd/1e6:.2f};xfer_ms={t_xfer_jd*1e3:.3f}"))
    rows.append(csv_row("jd_vs_lora", 0.0,
                        f"mem_ratio={bytes_lora/bytes_jd:.1f};"
                        f"fwd_latency_ratio={t_lora/max(t_jd,1e-9):.2f}"))
    return rows


if __name__ == "__main__":
    print("\n".join(main(quick=True)))
