"""Deliverable (g): per-(arch x shape x mesh) roofline table from the
dry-run artifacts (results/dryrun/*.json)."""
from __future__ import annotations

import json
from pathlib import Path

from .common import csv_row

RESULTS = Path(__file__).resolve().parents[1] / "results" / "dryrun"


def main(quick: bool = True):
    rows = []
    if not RESULTS.exists():
        return [csv_row("roofline_missing", 0.0, "run repro.launch.dryrun")]
    for p in sorted(RESULTS.glob("*.json")):
        d = json.loads(p.read_text())
        if d.get("skipped"):
            rows.append(csv_row(p.stem, 0.0, f"SKIP:{d['reason'][:40]}"))
            continue
        if not d.get("ok"):
            rows.append(csv_row(p.stem, 0.0, f"FAIL:{d.get('error','')[:40]}"))
            continue
        r = d["roofline"]
        rows.append(csv_row(
            p.stem, d.get("compile_s", 0) * 1e6,
            f"bneck={r['bottleneck']};tc={r['t_compute_s']:.3f};"
            f"tm={r['t_memory_s']:.3f};tx={r['t_collective_s']:.3f};"
            f"useful={r['useful_flops_ratio']:.3f};"
            f"frac={r['roofline_fraction']:.4f}"))
    return rows


if __name__ == "__main__":
    print("\n".join(main(quick=True)))
