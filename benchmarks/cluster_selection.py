"""Fig. 6 / App. G: reconstruction error vs parameter-saved ratio across
(rank, clusters) on a single module -> the §6.5 selection procedure."""
from __future__ import annotations

import jax

from repro.core import (cluster_jd, clustered_reconstruction_errors,
                        jd_full_eig, normalize_bank, parameter_counts,
                        reconstruction_errors)
from repro.core.recommend import recommend
from repro.core.collection import LoRABank
import jax.numpy as jnp

from .common import csv_row, structured_bank, timed


def main(quick: bool = True):
    rows = []
    n, r_l, d = (128, 8, 256) if quick else (512, 16, 1024)
    A, B = structured_bank(jax.random.PRNGKey(1), n, r_l, d, n_families=8)
    A, B, _ = normalize_bank(A, B)
    for k, rank in [(1, 16), (2, 16), (4, 16), (8, 16), (1, 64)]:
        if k == 1:
            res, dt = timed(jd_full_eig, A, B, rank, iters=12)
            loss = float(reconstruction_errors(A, B, res)["loss"])
        else:
            res, dt = timed(cluster_jd, A, B, rank, k, jd_iters=8,
                            outer_iters=3)
            loss = float(clustered_reconstruction_errors(A, B, res)["loss"])
        pc = parameter_counts(d, d, n, rank, k, lora_rank=r_l)
        rows.append(csv_row(f"select_k{k}_r{rank}", dt * 1e6,
                            f"loss={loss:.4f};saved={pc['saved_ratio']:.3f}"))
    # §6.5 procedure end-to-end
    bank = LoRABank(A=A, B=B, ranks=jnp.full((n,), r_l, jnp.int32))
    rec, dt = timed(lambda: recommend({"mid.q": bank}, rank=16,
                                      max_clusters=16, iters=8))
    rows.append(csv_row("recommend_6_5", dt * 1e6,
                        f"k={rec.n_clusters};losses={rec.probe_losses}"))
    return rows


if __name__ == "__main__":
    print("\n".join(main(quick=True)))
