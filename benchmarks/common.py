"""Shared benchmark utilities: synthetic structured LoRA collections + CSV."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp


def structured_bank(key, n: int, r_l: int, d: int, n_families: int = 4,
                    noise: float = 0.3):
    """Synthetic collection with shared per-family structure (App. H.11:
    trained LoRAs share components; random ones don't)."""
    keys = jax.random.split(key, 2 * n_families + 2)
    fam_A = [jax.random.normal(keys[2 * i], (r_l, d)) for i in range(n_families)]
    fam_B = [jax.random.normal(keys[2 * i + 1], (d, r_l))
             for i in range(n_families)]
    ka, kb = keys[-2:]
    As, Bs = [], []
    for i in range(n):
        f = i % n_families
        As.append(fam_A[f] + noise * jax.random.normal(
            jax.random.fold_in(ka, i), (r_l, d)))
        Bs.append(fam_B[f] + noise * jax.random.normal(
            jax.random.fold_in(kb, i), (d, r_l)))
    return jnp.stack(As), jnp.stack(Bs)


def random_bank(key, n: int, r_l: int, d: int):
    ka, kb = jax.random.split(key)
    return (jax.random.normal(ka, (n, r_l, d)),
            jax.random.normal(kb, (n, d, r_l)))


def timed(fn, *args, reps: int = 1, **kw):
    fn(*args, **kw)  # warmup/compile
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args, **kw)
    jax.block_until_ready(jax.tree.leaves(out)[0]) if jax.tree.leaves(out) \
        else None
    return out, (time.perf_counter() - t0) / reps


def csv_row(name: str, us_per_call: float, derived: str) -> str:
    return f"{name},{us_per_call:.1f},{derived}"
