"""Fig. 2 / Fig. 3 / Tables 7-14 analogue: reconstruction error vs
compression setting across methods, on structured collections."""
from __future__ import annotations

import jax

from repro.core import (cluster_jd, clustered_reconstruction_errors, jd_diag,
                        jd_full_eig, normalize_bank, parameter_counts,
                        reconstruction_errors, svd_per_lora,
                        svd_reconstruction_errors)
from .common import csv_row, structured_bank, timed


def main(quick: bool = True):
    rows = []
    n, r_l, d = (64, 8, 256) if quick else (256, 16, 1024)
    A, B = structured_bank(jax.random.PRNGKey(0), n, r_l, d)
    A, B, _ = normalize_bank(A, B)

    for rank in (8, 16, 32, 64):
        res, dt = timed(jd_full_eig, A, B, rank, iters=15)
        loss = float(reconstruction_errors(A, B, res)["loss"])
        pc = parameter_counts(d, d, n, rank, 1, lora_rank=r_l)
        rows.append(csv_row(f"jd_full_r{rank}", dt * 1e6,
                            f"loss={loss:.4f};saved={pc['saved_ratio']:.3f}"))

    res, dt = timed(jd_diag, A, B, 32, iters=25)
    loss = float(reconstruction_errors(A, B, res)["loss"])
    rows.append(csv_row("jd_diag_r32", dt * 1e6, f"loss={loss:.4f}"))

    res, dt = timed(svd_per_lora, A, B, 4)
    loss = float(svd_reconstruction_errors(A, B, res)["loss"])
    rows.append(csv_row("svd_r4_per_lora", dt * 1e6, f"loss={loss:.4f}"))

    for k in (2, 4, 8):
        res, dt = timed(cluster_jd, A, B, 16, k, jd_iters=10, outer_iters=3)
        loss = float(clustered_reconstruction_errors(A, B, res)["loss"])
        pc = parameter_counts(d, d, n, 16, k, lora_rank=r_l)
        rows.append(csv_row(f"jd_cluster_k{k}_r16", dt * 1e6,
                            f"loss={loss:.4f};saved={pc['saved_ratio']:.3f}"))
    return rows


if __name__ == "__main__":
    print("\n".join(main(quick=True)))
