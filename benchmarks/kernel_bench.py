"""Kernel-level microbench: SGMV / JD-apply arithmetic-intensity model +
interpret-mode sanity timing (CPU has no MXU; see EXPERIMENTS.md §Perf for
the dry-run-derived roofline placement of these ops).

PR 8 adds the fused decode rows: attention + adapter delta as one pass
(`kernels/fused_decode.py`) vs the composed unfused pipeline
(`flash_decode` then the adapter delta as a second pass over the same
activations), emitted as a fused-vs-unfused speedup table so a regression
in EITHER path is visible — the unfused path stays the bit-exactness
anchor, so it getting slower must not hide behind the fused win."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ref as R
from .common import csv_row, timed


def main(quick: bool = True):
    rows = []
    T, d, n, r = 256, 1024, 32, 16
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 4)
    x = jax.random.normal(ks[0], (T, d), jnp.bfloat16)
    A = (jax.random.normal(ks[1], (n, r, d)) * 0.02).astype(jnp.bfloat16)
    Bm = (jax.random.normal(ks[2], (n, d, r)) * 0.02).astype(jnp.bfloat16)
    ids = jax.random.randint(ks[3], (T,), 0, n)

    _, t = timed(jax.jit(R.lora_apply_ref), x, A, Bm, ids, reps=3)
    flops = 2 * T * r * 2 * d
    # uncompressed: every token streams its own adapter block
    bytes_lora = T * r * 2 * d * 2
    rows.append(csv_row("sgmv_pair", t * 1e6,
                        f"flops={flops:.2e};ai={flops/bytes_lora:.2f}"))
    U = (jax.random.normal(ks[1], (1, d, r)) * 0.02).astype(jnp.bfloat16)
    V = (jax.random.normal(ks[2], (1, d, r)) * 0.02).astype(jnp.bfloat16)
    sig = (jax.random.normal(ks[3], (n, r, r)) * 0.1).astype(jnp.bfloat16)
    cl = jnp.zeros((n,), jnp.int32)
    _, t = timed(jax.jit(R.jd_apply_ref), x, U, V, sig, cl, ids, reps=3)
    bytes_jd = 2 * d * r * 2 + T * r * r * 2
    rows.append(csv_row("jd_apply", t * 1e6,
                        f"flops={flops:.2e};ai={flops/bytes_jd:.2f}"))
    rows.extend(fused_rows(quick))
    return rows


def fused_rows(quick: bool = True):
    """Fused decode (one pass) vs composed flash_decode + delta (two
    passes), ref impls on identical inputs — the speedup table."""
    rows = []
    B, H, Kv, hd, S, n, r, d_out = 8, 8, 4, 64, 512, 16, 16, 512
    ks = jax.random.split(jax.random.PRNGKey(1), 7)
    q = jax.random.normal(ks[0], (B, H, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, Kv, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, Kv, hd), jnp.float32)
    kv_len = jnp.full((B,), S, jnp.int32)
    ids = jax.random.randint(ks[3], (B,), 0, n)
    A = jax.random.normal(ks[4], (n, r, H * hd), jnp.float32) / 8
    Bm = jax.random.normal(ks[5], (n, d_out, r), jnp.float32) / 4

    def unfused(q, k, v, kv_len, ids, A, Bm):
        of = R.flash_decode_ref(q, k, v, kv_len)
        of2 = of.reshape(B, -1)                 # second pass re-reads attn out
        t = jnp.einsum("bd,brd->br", of2, A[ids])
        return of, jnp.einsum("br,bor->bo", t, Bm[ids])

    _, t_un = timed(jax.jit(unfused), q, k, v, kv_len, ids, A, Bm, reps=5)
    _, t_fu = timed(jax.jit(R.fused_decode_lora_ref),
                    q, k, v, kv_len, ids, A, Bm, reps=5)
    rows.append(csv_row("fused_decode_lora", t_fu * 1e6,
                        f"unfused_us={t_un * 1e6:.1f};"
                        f"speedup={t_un / t_fu:.2f}"))
    U = jax.random.normal(ks[4], (4, d_out, r), jnp.float32) / 4
    V = jax.random.normal(ks[5], (4, H * hd, r), jnp.float32) / 8
    sig = jax.random.normal(ks[6], (n, r, r), jnp.float32) / 4
    cl = (jnp.arange(n, dtype=jnp.int32) % 4)

    def unfused_jd(q, k, v, kv_len, ids, U, V, sig, cl):
        of = R.flash_decode_ref(q, k, v, kv_len)
        of2 = of.reshape(B, -1)
        t = jnp.einsum("bd,bdr->br", of2, V[cl[ids]])
        t = jnp.einsum("br,brq->bq", t, sig[ids])
        return of, jnp.einsum("br,bor->bo", t, U[cl[ids]])

    _, t_un = timed(jax.jit(unfused_jd), q, k, v, kv_len, ids, U, V, sig,
                    cl, reps=5)
    _, t_fu = timed(jax.jit(R.fused_decode_jd_ref), q, k, v, kv_len, ids,
                    U, V, sig, cl, reps=5)
    rows.append(csv_row("fused_decode_jd", t_fu * 1e6,
                        f"unfused_us={t_un * 1e6:.1f};"
                        f"speedup={t_un / t_fu:.2f}"))
    return rows


if __name__ == "__main__":
    print("\n".join(main(quick=True)))
