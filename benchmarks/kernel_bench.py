"""Kernel-level microbench: SGMV / JD-apply arithmetic-intensity model +
interpret-mode sanity timing (CPU has no MXU; see EXPERIMENTS.md §Perf for
the dry-run-derived roofline placement of these ops)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ref as R
from .common import csv_row, timed


def main(quick: bool = True):
    rows = []
    T, d, n, r = 256, 1024, 32, 16
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 4)
    x = jax.random.normal(ks[0], (T, d), jnp.bfloat16)
    A = (jax.random.normal(ks[1], (n, r, d)) * 0.02).astype(jnp.bfloat16)
    Bm = (jax.random.normal(ks[2], (n, d, r)) * 0.02).astype(jnp.bfloat16)
    ids = jax.random.randint(ks[3], (T,), 0, n)

    _, t = timed(jax.jit(R.lora_apply_ref), x, A, Bm, ids, reps=3)
    flops = 2 * T * r * 2 * d
    # uncompressed: every token streams its own adapter block
    bytes_lora = T * r * 2 * d * 2
    rows.append(csv_row("sgmv_pair", t * 1e6,
                        f"flops={flops:.2e};ai={flops/bytes_lora:.2f}"))
    U = (jax.random.normal(ks[1], (1, d, r)) * 0.02).astype(jnp.bfloat16)
    V = (jax.random.normal(ks[2], (1, d, r)) * 0.02).astype(jnp.bfloat16)
    sig = (jax.random.normal(ks[3], (n, r, r)) * 0.1).astype(jnp.bfloat16)
    cl = jnp.zeros((n,), jnp.int32)
    _, t = timed(jax.jit(R.jd_apply_ref), x, U, V, sig, cl, ids, reps=3)
    bytes_jd = 2 * d * r * 2 + T * r * r * 2
    rows.append(csv_row("jd_apply", t * 1e6,
                        f"flops={flops:.2e};ai={flops/bytes_jd:.2f}"))
    return rows


if __name__ == "__main__":
    print("\n".join(main(quick=True)))
