"""Table 15 / App. H.11: structured (trained-like) collections compress far
better than random ones at the same rank."""
from __future__ import annotations

import jax

from repro.core import jd_full_eig, normalize_bank, reconstruction_errors
from .common import csv_row, random_bank, structured_bank, timed


def main(quick: bool = True):
    rows = []
    n, r_l, d = (64, 8, 256) if quick else (256, 16, 1024)
    for name, maker in (("structured", structured_bank),
                        ("random", random_bank)):
        A, B = maker(jax.random.PRNGKey(2), n, r_l, d)
        A, B, _ = normalize_bank(A, B)
        res, dt = timed(jd_full_eig, A, B, 16, iters=12)
        loss = float(reconstruction_errors(A, B, res)["loss"])
        rows.append(csv_row(f"recon_{name}_r16", dt * 1e6,
                            f"loss={loss:.4f}"))
    return rows


if __name__ == "__main__":
    print("\n".join(main(quick=True)))
