"""Adapter churn study: hot register / update / retire under load.

PR 7 adds the online lifecycle control plane
(:mod:`repro.serving.lifecycle`): new adapters hot-register mid-run and
serve RAW through the uncompressed SGMV path immediately, a background
basis refresh walks the fleet one replica at a time behind a quality
gate, and retirements drain in place.  This study drives the same
cost-model fleet through a Zipf(1.0) base load with a Poisson adapter
arrival/retirement stream layered on top, sweeping churn rate x refresh
cadence against a no-churn control cell.

Acceptance (asserted below, at generous margins so the CI smoke stays
robust; the 10% steady-state band is enforced by the perf gate against
the committed baseline):

* no cold-start TTFT cliff — a hot-registered adapter's FIRST request
  pays ordinary queueing+prefill, never an offline compression solve:
  p95 of first-request TTFTs stays within the cell's own steady TTFT
  envelope;
* the background refresh never fails its gate into production
  (rollbacks == 0 with the shipped gate);
* steady-state p95 TTFT of the BASE load under churn stays within a
  small band of the no-churn cell (the control plane is off the data
  path).

CSV columns: name,us_per_call,derived.
"""
from __future__ import annotations

import argparse
import json
import time
from typing import Optional

import numpy as np

from repro.configs import get_config
from repro.serving.engine import ServingHardware
from repro.serving.lifecycle import (AdapterLifecycle, ChurnSpec,
                                     LifecycleConfig, make_churn_workload)
from repro.serving.router import FleetConfig
from repro.serving.simulator import (build_fleet, memory_matched_setup,
                                     run_study, serving_footprint)
from repro.serving.workload import WorkloadSpec

try:
    from .common import csv_row
except ImportError:                      # run as a script, not a module
    from common import csv_row

N_BASE = 128                             # offline-compressed collection
                                         # (paper setting: rank 16, 7
                                         # clusters -> affinity spreads)
MODE = "jd"


def churn_cell(cfg, n_requests: int, churn_rate: float,
               refresh_interval: float, seed: int = 0):
    """One fleet under a churned workload; returns (reqs, report, lc)."""
    setting, cluster_of, budget = memory_matched_setup(cfg, N_BASE)
    # Appendix-F matching covers shared bases + Sigmas only; hot-registered
    # adapters serve RAW until a refresh lands, so the cell carries
    # explicit LoRA headroom on top (the price of serving churn).
    fp_lora = serving_footprint(cfg, "lora", N_BASE, setting)
    budget += 6 * fp_lora.lora_bytes_per_adapter
    fleet = build_fleet(cfg, MODE, N_BASE, budget,
                        FleetConfig(n_replicas=3, policy="cluster_affinity",
                                    spill_requests=1e9),
                        ServingHardware(), cluster_of, setting)
    lc = AdapterLifecycle(
        fleet, LifecycleConfig(refresh_interval=refresh_interval),
        assign_fn=lambda aid: aid % setting["clusters"])
    spec = ChurnSpec(
        base=WorkloadSpec(n_requests=n_requests, n_adapters=N_BASE,
                          popularity="zipf", zipf_alpha=1.0,
                          arrival="poisson", arrival_rate=90.0,
                          prompt_len_mean=256, prompt_len_std=32,
                          new_tokens=10, seed=seed),
        churn_rate=churn_rate, lifetime=1.5, request_rate=6.0,
        update_prob=0.25, seed=seed + 1)
    reqs, events = make_churn_workload(spec)
    report = run_study(fleet, reqs, lifecycle=lc, events=events, window=0.25)
    return reqs, report, lc


def _p95(xs) -> float:
    return float(np.percentile(xs, 95)) if xs else 0.0


def cell_metrics(reqs, report, lc) -> dict:
    base_ttfts = [r.ttft for r in reqs
                  if r.adapter_id < N_BASE and r.ttft is not None]
    churn = {}
    for r in reqs:
        if r.adapter_id >= N_BASE and r.ttft is not None:
            prev = churn.get(r.adapter_id)
            if prev is None or r.arrival_time < prev.arrival_time:
                churn[r.adapter_id] = r
    first_ttfts = [r.ttft for r in churn.values()]
    return dict(rps=report.rps,
                base_p95_ttft=_p95(base_ttfts),
                first_p95_ttft=_p95(first_ttfts),
                all_p95_ttft=report.stats.total.ttft_pct(95),
                lc=lc.stats.to_dict())


def main(quick: bool = True, json_path: Optional[str] = None):
    cfg = get_config("mistral-7b")
    n_requests = 300 if quick else 900
    cells = [("nochurn", 0.0, 2.0), ("churn", 1.0, 2.0)]
    if not quick:
        cells += [("churn_hi", 2.0, 2.0), ("churn_fastref", 1.0, 0.5)]
    rows, metrics, out = [], {}, {}
    for name, rate, cadence in cells:
        t0 = time.perf_counter()
        reqs, report, lc = churn_cell(cfg, n_requests, rate, cadence)
        dt = (time.perf_counter() - t0) * 1e6
        m = cell_metrics(reqs, report, lc)
        out[name] = m
        d = m["lc"]
        derived = (f"rps={m['rps']:.2f};base_p95_ttft={m['base_p95_ttft']:.4f};"
                   f"first_p95_ttft={m['first_p95_ttft']:.4f};"
                   f"registered={d['n_registered']};retired={d['n_retired']};"
                   f"updated={d['n_updated']};refreshes={d['n_refreshes']};"
                   f"rollbacks={d['n_rollbacks']};raw={d['raw_requests']};"
                   f"assigned={d['assigned_requests']}")
        rows.append(csv_row(f"churn_{name}_r{rate:g}_c{cadence:g}", dt,
                            derived))
        metrics[f"churn_{name}"] = {"rps": m["rps"]}
    nc, ch = out["nochurn"], out["churn"]
    # -- acceptance: the control plane stays off the data path ------------
    assert ch["lc"]["n_rollbacks"] == 0, "refresh failed gate into prod"
    assert ch["lc"]["n_refreshes"] > 0, "no refresh ever completed"
    # hot registration has no cold-start cliff: first requests live inside
    # the cell's own steady TTFT envelope (an offline re-solve in the
    # serving path would blow this by orders of magnitude)
    no_cliff = ch["first_p95_ttft"] <= 1.5 * ch["all_p95_ttft"] + 1e-9
    assert no_cliff, (ch["first_p95_ttft"], ch["all_p95_ttft"])
    # base-load p95 TTFT under churn stays near the no-churn control
    band = ch["base_p95_ttft"] <= 1.10 * nc["base_p95_ttft"] + 1e-9
    rows.append(csv_row(
        "churn_headline", 0.0,
        f"no_cliff={no_cliff};ttft_within_band={band};"
        f"base_p95_ratio={ch['base_p95_ttft'] / max(nc['base_p95_ttft'], 1e-12):.3f}"))
    assert band, (ch["base_p95_ttft"], nc["base_p95_ttft"])
    if json_path:
        with open(json_path, "w") as f:
            json.dump(metrics, f, indent=2, sort_keys=True)
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="small sweep for CI smoke")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write deterministic metrics as JSON "
                         "(CI perf gate; see benchmarks/check_regression.py)")
    args = ap.parse_args()
    print("\n".join(main(quick=args.quick, json_path=args.json)))
