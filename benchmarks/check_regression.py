"""CI perf gate: compare a benchmark JSON against its committed baseline.

The serving benchmarks emit deterministic simulated-clock metrics (request
throughput from the cost model, not host wall time), so they are stable
across CI machines and can be gated tightly.  A cell regressing more than
``--tolerance`` (default 10%) below baseline fails the job; improvements
are reported so baselines can be ratcheted.

Usage (see .github/workflows/ci.yml):

    python -m benchmarks.serving_throughput --quick --json BENCH_serving.json
    python -m benchmarks.check_regression BENCH_serving.json \
        benchmarks/baselines/BENCH_serving.json

Baselines are regenerated with the same commands and committed whenever a
deliberate perf change lands.
"""
from __future__ import annotations

import argparse
import json
import sys

# metrics where larger is better (throughputs, fused-vs-unfused speedups,
# residency compression ratios); a latency metric would be gated in the
# opposite direction if one is ever added here
HIGHER_IS_BETTER = ("rps", "speedup", "ratio")


def compare(current: dict, baseline: dict, tolerance: float):
    """Yields (kind, message); kind in {"fail", "warn", "info"}."""
    for name in sorted(baseline):
        if name not in current:
            yield "fail", f"{name}: missing from current run"
            continue
        for metric, base_val in sorted(baseline[name].items()):
            if not any(metric.endswith(h) for h in HIGHER_IS_BETTER):
                continue
            cur_val = current[name].get(metric)
            if cur_val is None:
                yield "fail", f"{name}.{metric}: missing from current run"
                continue
            if base_val <= 0:
                continue
            ratio = cur_val / base_val
            if ratio < 1.0 - tolerance:
                yield "fail", (f"{name}.{metric}: {cur_val:.2f} vs baseline "
                               f"{base_val:.2f} ({(1 - ratio) * 100:.1f}% "
                               f"regression > {tolerance * 100:.0f}%)")
            elif ratio > 1.0 + tolerance:
                yield "info", (f"{name}.{metric}: {cur_val:.2f} vs baseline "
                               f"{base_val:.2f} (+{(ratio - 1) * 100:.1f}% — "
                               "consider ratcheting the baseline)")
    for name in sorted(set(current) - set(baseline)):
        yield "info", f"{name}: new cell (not in baseline)"


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("current", help="JSON written by a benchmark's --json")
    ap.add_argument("baseline", help="committed baseline JSON")
    ap.add_argument("--tolerance", type=float, default=0.10,
                    help="allowed fractional regression (default 0.10)")
    args = ap.parse_args(argv)
    with open(args.current) as f:
        current = json.load(f)
    with open(args.baseline) as f:
        baseline = json.load(f)
    failures = 0
    for kind, msg in compare(current, baseline, args.tolerance):
        print(f"[{kind}] {msg}")
        failures += kind == "fail"
    if failures:
        print(f"FAIL: {failures} metric(s) regressed beyond "
              f"{args.tolerance * 100:.0f}% (baseline {args.baseline})")
        return 1
    print(f"OK: no regression beyond {args.tolerance * 100:.0f}% "
          f"({args.baseline})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
