"""Fig. 1 / Fig. 4: throughput of compressed vs uncompressed multi-LoRA
serving vs collection size (memory-matched, v5e cost model)."""
from __future__ import annotations

import json
import time
from typing import Optional

from repro.configs import get_config
from repro.serving.simulator import WorkloadConfig, run_throughput_study
from .common import csv_row


def main(quick: bool = True, json_path: Optional[str] = None):
    cfg = get_config("mistral-7b")
    ns = [4, 16, 64, 256, 1024] if quick else [4, 8, 16, 32, 64, 128, 256,
                                               512, 1024]
    t0 = time.perf_counter()
    rows_raw = run_throughput_study(
        cfg, ns, WorkloadConfig(n_requests=400 if quick else 1000,
                                new_tokens=10))
    dt = (time.perf_counter() - t0) / len(ns)
    rows = []
    metrics = {}
    for r in rows_raw:
        rows.append(csv_row(
            f"serve_n{r['n_adapters']}", dt * 1e6,
            f"jd_rps={r['jd']['throughput_rps']:.2f};"
            f"lora_rps={r['lora']['throughput_rps']:.2f};"
            f"ratio={r['throughput_ratio_jd_vs_lora']:.2f};"
            f"frac_single={r['jd_frac_of_single']:.3f};"
            f"lora_swaps={r['lora']['n_swaps']}"))
        # simulated-clock metrics only: deterministic, safe to regression-gate
        metrics[f"serve_n{r['n_adapters']}"] = {
            "jd_rps": r["jd"]["throughput_rps"],
            "lora_rps": r["lora"]["throughput_rps"],
            "single_rps": r["single"]["throughput_rps"],
        }
    if json_path:
        with open(json_path, "w") as f:
            json.dump(metrics, f, indent=2, sort_keys=True)
    return rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="small sweep for CI smoke")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write deterministic metrics as JSON "
                         "(CI perf gate; see benchmarks/check_regression.py)")
    args = ap.parse_args()
    print("\n".join(main(quick=args.quick, json_path=args.json)))
