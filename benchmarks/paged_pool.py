"""Unified paging study: one paged HBM pool for adapter weights + KV blocks.

PR 6 replaces the two statically sized per-replica pools (adapter cache
bytes, KV slots) with one :class:`~repro.serving.resources.PagedPool` —
S-LoRA's unified paging, at the 128-token page granularity the
quantization kernels already use.  This study runs the same Zipf(1.0)
skew-shift workload (popularity ranks permuted mid-stream) through the
SAME allocator in two configurations at a fixed HBM budget:

* ``unified`` — ``adapter_share=None``: every page is fungible, a skew
  shift trades cache-resident adapters for decode KV pages and back.
* ``split_XX`` — ``adapter_share=0.25/0.50``: the pre-PR-6 static carve-
  out expressed as a degenerate configuration of the same pool.

Acceptance (asserted in tests/test_paged.py): at equal budget the unified
pool keeps strictly more adapters cache-resident at an equal-or-better
decode batch, and never pays more adapter reloads.  The memory-
architecture spec is docs/architecture.md.

CSV columns: name,us_per_call,derived.
"""
from __future__ import annotations

import argparse
import json
import math
import time
from typing import List, Optional

import numpy as np

from repro.configs import get_config
from repro.serving.engine import ServingHardware
from repro.serving.request import Request
from repro.serving.resources import PAGE_TOKENS
from repro.serving.simulator import (build_engine, memory_matched_setup,
                                     serving_footprint)
from repro.serving.workload import WorkloadSpec, make_workload

try:
    from .common import csv_row
except ImportError:                      # run as a script, not a module
    from common import csv_row

N_ADAPTERS = 64
MODE = "lora"                            # uncompressed: adapter pages are big


def skew_shift_workload(n_per_phase: int, seed: int = 0) -> List[Request]:
    """Two Zipf(1.0) phases at 150 req/s; phase 2 permutes the popularity
    ranks (a tenant-mix shift), which is exactly the event a static
    adapter/KV split cannot follow."""
    spec = WorkloadSpec(
        n_requests=n_per_phase, n_adapters=N_ADAPTERS, popularity="zipf",
        zipf_alpha=1.0, arrival="poisson", arrival_rate=150.0,
        prompt_len_mean=256, prompt_len_std=32, new_tokens=32, seed=seed)
    phase1 = make_workload(spec)
    phase2 = make_workload(
        WorkloadSpec(**{**spec.__dict__, "seed": seed + 1}))
    perm = np.random.default_rng(seed + 2).permutation(N_ADAPTERS)
    t0 = phase1[-1].arrival_time + 1e-3
    for i, r in enumerate(phase2):
        r.rid = n_per_phase + i
        r.adapter_id = int(perm[r.adapter_id])
        r.arrival_time += t0
    return phase1 + phase2


def paged_cell(cfg, requests: List[Request], pool_pages: int,
               adapter_share: Optional[float], max_batch: int = 8):
    """One single-replica decode cell on a `pool_pages`-page pool."""
    setting, cluster_of, budget = memory_matched_setup(cfg, N_ADAPTERS)
    fp = serving_footprint(cfg, MODE, N_ADAPTERS, setting)
    page_bytes = fp.kv_bytes_per_token * PAGE_TOKENS
    eng = build_engine(cfg, MODE, N_ADAPTERS, budget, ServingHardware(),
                      cluster_of, setting, max_batch=max_batch,
                      pool_bytes=float(pool_pages * page_bytes),
                      pool_adapter_share=adapter_share)
    eng.submit(requests)
    return eng.run()


def pool_sizes(cfg) -> dict:
    """Pool sizes in pages, derived from the model footprint so the cells
    stay meaningful if the config changes: the pool fits ~12 resident
    adapters' pages plus a full batch of worst-case KV."""
    setting, _, _ = memory_matched_setup(cfg, N_ADAPTERS)
    fp = serving_footprint(cfg, MODE, N_ADAPTERS, setting)
    page_bytes = fp.kv_bytes_per_token * PAGE_TOKENS
    adapter_pages = max(1, math.ceil(fp.lora_bytes_per_adapter / page_bytes))
    kv_pages_per_req = math.ceil((256 + 32 + 2 * 32) / PAGE_TOKENS)
    return {"p12a": 12 * adapter_pages + 8 * kv_pages_per_req,
            "adapter_pages": adapter_pages}


def main(quick: bool = True, json_path: Optional[str] = None):
    cfg = get_config("mistral-7b")
    n_per_phase = 150 if quick else 400
    shares = [("unified", None), ("split_25", 0.25)]
    if not quick:
        shares.append(("split_50", 0.50))
    sizes = pool_sizes(cfg)
    pool_pages = sizes["p12a"]
    rows = []
    metrics = {}
    cells = {}
    for name, share in shares:
        reqs = skew_shift_workload(n_per_phase)
        t0 = time.perf_counter()
        stats = paged_cell(cfg, reqs, pool_pages, share)
        dt = (time.perf_counter() - t0) * 1e6
        d = stats.to_dict()
        cells[name] = d
        derived = (f"rps={d['throughput_rps']:.2f};"
                   f"resident_peak={d['peak_resident_adapters']};"
                   f"batch_peak={d['peak_batch']};"
                   f"kv_pages_peak={d['peak_kv_pages']};"
                   f"adapter_pages_peak={d['peak_adapter_pages']};"
                   f"reclaims={d['n_page_reclaims']};"
                   f"swaps={d['n_swaps']};blocked={d['n_page_blocked']}")
        rows.append(csv_row(f"paged_{name}_p{pool_pages}", dt, derived))
        metrics[f"paged_{name}"] = {"rps": d["throughput_rps"]}
    u, s = cells["unified"], cells["split_25"]
    rows.append(csv_row(
        "paged_skew_shift_headline", 0.0,
        f"unified_more_resident="
        f"{u['peak_resident_adapters'] > s['peak_resident_adapters']};"
        f"equal_or_better_batch={u['peak_batch'] >= s['peak_batch']};"
        f"no_extra_swaps={u['n_swaps'] <= s['n_swaps']}"))
    if json_path:
        with open(json_path, "w") as f:
            json.dump(metrics, f, indent=2, sort_keys=True)
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="small sweep for CI smoke")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write deterministic metrics as JSON "
                         "(CI perf gate; see benchmarks/check_regression.py)")
    args = ap.parse_args()
    print("\n".join(main(quick=args.quick, json_path=args.json)))
