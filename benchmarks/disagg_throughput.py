"""Disaggregated serving study: prefill:decode ratio x skew x SLO target.

Three questions the colocated fleet study cannot answer:

1. **Tiering** — how does throughput/TTFT move as prefill capacity is traded
   against decode capacity (prefill:decode ratio) once prefill leaves the
   decode replicas' admission loop (no head-of-line blocking)?
2. **Skew** — does JD cluster-affinity decode placement keep its win when
   decode replicas no longer run prefill?
3. **Elasticity** — given a TTFT SLO, how many decode replicas does the
   autoscaler actually provision under bursty (Gamma, CV=4) arrivals, and
   does it meet SLOs a fixed fleet misses?

Workload is decode-bound (32 generated tokens) so the decode tier is the
scaled resource.  CSV columns: name,us_per_call,derived.
"""
from __future__ import annotations

import argparse
import json
import time
from typing import Optional

from repro.configs import get_config
from repro.serving.autoscaler import AutoscalerConfig, SLOConfig
from repro.serving.prefill import PrefillConfig
from repro.serving.router import FleetConfig
from repro.serving.simulator import run_elastic_study
from repro.serving.workload import WorkloadSpec, make_workload

try:
    from .common import csv_row
except ImportError:                      # run as a script, not a module
    from common import csv_row

N_ADAPTERS = 256


def bursty_workload(n_requests: int, alpha: float,
                    seed: int = 0) -> WorkloadSpec:
    return WorkloadSpec(
        n_requests=n_requests, n_adapters=N_ADAPTERS, new_tokens=32,
        popularity="uniform" if alpha == 0 else "zipf", zipf_alpha=alpha,
        arrival="gamma", arrival_rate=400.0, burst_cv=4.0, seed=seed)


def fixed_cell(cfg, wl: WorkloadSpec, n_prefill: int, n_decode: int,
               mode: str = "jd"):
    prefill = (PrefillConfig(n_workers=n_prefill) if n_prefill else None)
    return run_elastic_study(
        cfg, mode, N_ADAPTERS, make_workload(wl),
        FleetConfig(n_replicas=n_decode, policy="cluster_affinity"),
        prefill_cfg=prefill)


def autoscaled_cell(cfg, wl: WorkloadSpec, n_prefill: int, slo_ttft: float,
                    mode: str = "jd", max_replicas: int = 12):
    return run_elastic_study(
        cfg, mode, N_ADAPTERS, make_workload(wl),
        FleetConfig(n_replicas=2, policy="cluster_affinity"),
        prefill_cfg=PrefillConfig(n_workers=n_prefill),
        autoscaler_cfg=AutoscalerConfig(
            min_replicas=2, max_replicas=max_replicas,
            decision_interval=0.05, cooldown_intervals=1, max_step=2),
        slo=SLOConfig(ttft_p95=slo_ttft))


def main(quick: bool = True, json_path: Optional[str] = None):
    cfg = get_config("mistral-7b")
    n_requests = 600 if quick else 1600
    ratios = [(0, 4), (2, 4), (4, 4)] if quick else \
        [(0, 4), (1, 4), (2, 4), (4, 4), (2, 8), (4, 8)]
    skews = [("zipf1.0", 1.0)] if quick else [("uniform", 0.0),
                                              ("zipf1.0", 1.0)]
    slos = [0.35] if quick else [0.15, 0.35, 0.75]
    rows = []
    metrics = {}

    for skew_name, alpha in skews:
        wl = bursty_workload(n_requests, alpha)
        # -- fixed fleets across prefill:decode ratios (0 = colocated) ------
        for n_pf, n_dec in ratios:
            t0 = time.perf_counter()
            stats = fixed_cell(cfg, wl, n_pf, n_dec)
            dt = (time.perf_counter() - t0) * 1e6
            d = stats.to_dict()
            name = f"disagg_jd_{skew_name}_p{n_pf}d{n_dec}"
            derived = (f"rps={d['throughput_rps']:.2f};"
                       f"ttft_p95={d['ttft_p95_s'] * 1e3:.1f}ms;"
                       f"tpot_p95={d['tpot_p95_s'] * 1e3:.2f}ms;"
                       f"swaps={d['n_swaps']}")
            if n_pf:
                derived += (f";kv_xfer={d['kv_transfer_s'] * 1e3:.1f}ms;"
                            f"n_prefills={d['n_prefills']}")
            rows.append(csv_row(name, dt, derived))
            metrics[name] = {"rps": d["throughput_rps"]}
        # -- autoscaled fleets across TTFT SLO targets ----------------------
        for slo in slos:
            t0 = time.perf_counter()
            stats = autoscaled_cell(cfg, wl, n_prefill=4, slo_ttft=slo)
            dt = (time.perf_counter() - t0) * 1e6
            d = stats.to_dict()
            name = f"disagg_jd_{skew_name}_auto_slo{int(slo * 1e3)}ms"
            rows.append(csv_row(
                name, dt,
                f"rps={d['throughput_rps']:.2f};"
                f"ttft_p95={d['ttft_p95_s'] * 1e3:.1f}ms;"
                f"met_slo={d['ttft_p95_s'] <= slo};"
                f"n_final={d['n_replicas_final']};"
                f"scale_events={d['scale_events']}"))
            metrics[name] = {"rps": d["throughput_rps"]}
    if json_path:
        with open(json_path, "w") as f:
            json.dump(metrics, f, indent=2, sort_keys=True)
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="small sweep for CI smoke")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write deterministic metrics as JSON")
    args = ap.parse_args()
    print("\n".join(main(quick=args.quick, json_path=args.json)))
