"""Synthetic natural-instruction-style task generator.

Stands in for the paper's 1000 natural-instruction tasks (no corpora
offline).  Each task is a deterministic seeded transformation family over a
small byte-level vocabulary — structurally like classification / extraction
/ transduction instruction tasks: the model sees  [instr tokens] [input]
[SEP] and must produce [output].  Tasks differ enough that per-task LoRAs
learn genuinely different adapters (verified by cross-task eval in tests).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import numpy as np

PAD, BOS, SEP, EOS = 0, 1, 2, 3
RESERVED = 4


@dataclasses.dataclass(frozen=True)
class TaskSpec:
    task_id: int
    kind: str          # copy | reverse | map | sort | filter | rotate | pair
    seed: int
    vocab: int         # usable vocab (offset by RESERVED)
    in_len: int = 12
    instr_len: int = 4


KINDS = ("copy", "reverse", "map", "sort", "filter", "rotate", "pair")


def make_task(task_id: int, vocab: int = 256, seed: int = 1234) -> TaskSpec:
    kind = KINDS[task_id % len(KINDS)]
    return TaskSpec(task_id=task_id, kind=kind, seed=seed * 7919 + task_id,
                    vocab=vocab)


def _apply(spec: TaskSpec, rng: np.random.Generator,
           x: np.ndarray) -> np.ndarray:
    v = spec.vocab
    task_rng = np.random.default_rng(spec.seed)
    if spec.kind == "copy":
        return x
    if spec.kind == "reverse":
        return x[::-1]
    if spec.kind == "map":
        perm = task_rng.permutation(v)
        return perm[x]
    if spec.kind == "sort":
        return np.sort(x)
    if spec.kind == "filter":
        thr = int(task_rng.integers(v // 4, 3 * v // 4))
        kept = x[x < thr]
        out = np.full_like(x, 0)
        out[:kept.size] = kept
        return out
    if spec.kind == "rotate":
        k = int(task_rng.integers(1, spec.in_len - 1))
        return np.roll(x, k)
    if spec.kind == "pair":
        off = int(task_rng.integers(1, v - 1))
        return (x + off) % v
    raise ValueError(spec.kind)


def sample_example(spec: TaskSpec, rng: np.random.Generator
                   ) -> Tuple[np.ndarray, np.ndarray]:
    """Returns (tokens, targets) of equal length; targets = -1 on non-output
    positions (loss-masked)."""
    task_rng = np.random.default_rng(spec.seed)
    instr = task_rng.integers(0, spec.vocab, size=spec.instr_len)
    x = rng.integers(0, spec.vocab, size=spec.in_len)
    y = _apply(spec, rng, x)
    seq = np.concatenate([[BOS], instr + RESERVED, x + RESERVED, [SEP],
                          y + RESERVED, [EOS]])
    tokens = seq[:-1]
    targets = seq[1:].copy()
    out_start = 1 + spec.instr_len + spec.in_len  # index of SEP in tokens
    targets[:out_start] = -1                       # only predict the output
    return tokens.astype(np.int32), targets.astype(np.int32)


def batch_of(spec: TaskSpec, batch: int, seq_len: int, seed: int
             ) -> Dict[str, np.ndarray]:
    rng = np.random.default_rng(seed)
    toks = np.zeros((batch, seq_len), np.int32)
    tgts = np.full((batch, seq_len), -1, np.int32)
    for i in range(batch):
        t, g = sample_example(spec, rng)
        n = min(len(t), seq_len)
        toks[i, :n] = t[:n]
        tgts[i, :n] = g[:n]
    return {"tokens": toks, "targets": tgts}


def eval_exact_match(spec: TaskSpec, predict_fn, n: int = 32,
                     seq_len: int = 64, seed: int = 999) -> float:
    """predict_fn(tokens (B,S)) -> predicted next-token ids (B,S).
    Exact-match on the output segment (the paper's EM metric analogue)."""
    b = batch_of(spec, n, seq_len, seed)
    pred = np.asarray(predict_fn(b["tokens"]))
    mask = b["targets"] >= 0
    correct = ((pred == b["targets"]) | ~mask).all(axis=1)
    return float(correct.mean())


def eval_token_accuracy(spec: TaskSpec, predict_fn, n: int = 32,
                        seq_len: int = 64, seed: int = 999) -> float:
    b = batch_of(spec, n, seq_len, seed)
    pred = np.asarray(predict_fn(b["tokens"]))
    mask = b["targets"] >= 0
    return float((pred == b["targets"])[mask].mean())
