"""Sharded deterministic data pipeline.

Host-side batching with deterministic per-step seeds: every (task, step)
yields identical batches across restarts, which makes checkpoint/restart
bitwise reproducible — the fault-tolerance tests rely on this.  Prefetching
runs on a background thread (double-buffering the host->device transfer).
"""
from __future__ import annotations

import queue
import threading
from typing import Dict, Iterator

import numpy as np

from .tasks import TaskSpec, batch_of


class TaskDataLoader:
    def __init__(self, spec: TaskSpec, batch: int, seq_len: int,
                 base_seed: int = 0, prefetch: int = 2):
        self.spec = spec
        self.batch = batch
        self.seq_len = seq_len
        self.base_seed = base_seed
        self.prefetch = prefetch

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        seed = (self.base_seed * 1_000_003 + self.spec.task_id * 7919
                + step) % (2 ** 31)
        return batch_of(self.spec, self.batch, self.seq_len, seed)

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        return self.iterate(0)

    def iterate(self, start_step: int) -> Iterator[Dict[str, np.ndarray]]:
        """Resumable iterator (start_step from a restored checkpoint)."""
        q: "queue.Queue" = queue.Queue(maxsize=self.prefetch)
        stop = threading.Event()

        def worker():
            step = start_step
            while not stop.is_set():
                try:
                    q.put(self.batch_at(step), timeout=0.2)
                    step += 1
                except queue.Full:
                    continue

        t = threading.Thread(target=worker, daemon=True)
        t.start()
        try:
            while True:
                yield q.get()
        finally:
            stop.set()


def mixture_loader(specs, batch: int, seq_len: int, base_seed: int = 0):
    """Round-robin over tasks (multi-task training batches)."""
    loaders = [TaskDataLoader(s, batch, seq_len, base_seed) for s in specs]

    def gen(start_step: int = 0):
        step = start_step
        while True:
            yield loaders[step % len(loaders)].batch_at(step // len(loaders))
            step += 1

    return gen
