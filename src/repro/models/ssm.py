"""Mamba2 / SSD (state-space duality) block, TPU-adapted.

The GPU reference implementation is a fused CUDA scan; on TPU we use the
*chunked* SSD formulation (arXiv:2405.21060 §6): intra-chunk terms are plain
matmuls (MXU-friendly), inter-chunk recurrence is a short ``lax.scan`` over
chunk states.  Decode is an O(1) recurrent state update — the "KV cache of
seq_len" for SSM shapes is this fixed-size state.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import constrain
from repro.models.param import ParamDef
from repro.models import lora as lora_mod

Array = jax.Array


@dataclasses.dataclass
class SSMCache:
    """conv_state: (B, d_conv-1, di+2GN); state: (B, H, N, P); index: ()."""
    conv: Array
    state: Array
    index: Array

    @staticmethod
    def zeros(batch, cfg: ModelConfig, dtype=jnp.bfloat16) -> "SSMCache":
        s = cfg.ssm
        di = s.d_inner(cfg.d_model)
        H = s.n_heads(cfg.d_model)
        width = di + 2 * s.n_groups * s.d_state
        return SSMCache(
            conv=jnp.zeros((batch, s.d_conv - 1, width), dtype),
            state=jnp.zeros((batch, H, s.d_state, s.head_dim), jnp.float32),
            index=jnp.zeros((), jnp.int32))

    @staticmethod
    def abstract(batch, cfg: ModelConfig, dtype=jnp.bfloat16) -> "SSMCache":
        s = cfg.ssm
        di = s.d_inner(cfg.d_model)
        H = s.n_heads(cfg.d_model)
        width = di + 2 * s.n_groups * s.d_state
        return SSMCache(
            conv=jax.ShapeDtypeStruct((batch, s.d_conv - 1, width), dtype),
            state=jax.ShapeDtypeStruct((batch, H, s.d_state, s.head_dim),
                                       jnp.float32),
            index=jax.ShapeDtypeStruct((), jnp.int32))


jax.tree_util.register_dataclass(SSMCache, ["conv", "state", "index"], [])


def ssm_defs(cfg: ModelConfig) -> Dict:
    d = cfg.d_model
    s = cfg.ssm
    di = s.d_inner(d)
    GN = s.n_groups * s.d_state
    H = s.n_heads(d)
    return {
        "wz": ParamDef((d, di), ("d_model", "d_ff")),
        "wx": ParamDef((d, di), ("d_model", "d_ff")),
        "wB": ParamDef((d, GN), ("d_model", "ssm_state")),
        "wC": ParamDef((d, GN), ("d_model", "ssm_state")),
        "wdt": ParamDef((d, H), ("d_model", "ssm_heads")),
        "dt_bias": ParamDef((H,), ("ssm_heads",), init="zeros"),
        "A_log": ParamDef((H,), ("ssm_heads",), init="zeros"),
        "D": ParamDef((H,), ("ssm_heads",), init="ones"),
        "conv_w": ParamDef((s.d_conv, di + 2 * GN), ("conv_k", "d_ff"),
                           scale=0.5),
        "norm": ParamDef((di,), ("d_ff",), init="ones"),
        "out_proj": ParamDef((di, d), ("d_ff", "d_model")),
    }


def _causal_conv(xbc: Array, w: Array, conv_state: Optional[Array] = None
                 ) -> Tuple[Array, Array]:
    """Depthwise causal conv1d.  xbc: (B, S, W); w: (k, W).

    Returns (out (B,S,W), new_conv_state (B, k-1, W))."""
    k = w.shape[0]
    if conv_state is None:
        pad = jnp.zeros((xbc.shape[0], k - 1, xbc.shape[2]), xbc.dtype)
    else:
        pad = conv_state.astype(xbc.dtype)
    xp = jnp.concatenate([pad, xbc], axis=1)           # (B, S+k-1, W)
    out = sum(xp[:, i:i + xbc.shape[1], :] * w[i][None, None, :]
              for i in range(k))
    new_state = xp[:, -(k - 1):, :] if k > 1 else xp[:, :0, :]
    return jax.nn.silu(out.astype(jnp.float32)).astype(xbc.dtype), new_state


def _project(p: Dict, x: Array, cfg: ModelConfig, lora_ctx):
    """x: (B,S,d) -> z (B,S,di), xbc (B,S,di+2GN), dt (B,S,H)."""
    z = jnp.einsum("bsd,de->bse", x, p["wz"])
    xs = jnp.einsum("bsd,de->bse", x, p["wx"])
    if lora_ctx is not None:
        xs = lora_mod.apply(lora_ctx, "ssm_in", x, xs)
    bb = jnp.einsum("bsd,de->bse", x, p["wB"])
    cc = jnp.einsum("bsd,de->bse", x, p["wC"])
    dt = jnp.einsum("bsd,dh->bsh", x, p["wdt"]).astype(jnp.float32)
    dt = jax.nn.softplus(dt + p["dt_bias"].astype(jnp.float32))
    xbc = jnp.concatenate([xs, bb, cc], axis=-1)
    return z, xbc, dt


def _split_xbc(xbc: Array, cfg: ModelConfig):
    s = cfg.ssm
    di = s.d_inner(cfg.d_model)
    GN = s.n_groups * s.d_state
    xs = xbc[..., :di]
    bb = xbc[..., di:di + GN]
    cc = xbc[..., di + GN:]
    B_, S_ = xbc.shape[:2]
    H = s.n_heads(cfg.d_model)
    xh = xs.reshape(B_, S_, H, s.head_dim)
    bg = bb.reshape(B_, S_, s.n_groups, s.d_state)
    cg = cc.reshape(B_, S_, s.n_groups, s.d_state)
    return xh, bg, cg


def ssd_scan(xh: Array, bg: Array, cg: Array, dt: Array, A: Array,
             chunk: int, init_state: Optional[Array] = None
             ) -> Tuple[Array, Array]:
    """Chunked SSD.  xh: (B,S,H,P); bg/cg: (B,S,G,N); dt: (B,S,H); A: (H,) < 0.

    Returns (y (B,S,H,P) fp32, final_state (B,H,N,P) fp32)."""
    B, S, H, P = xh.shape
    G, N = bg.shape[2], bg.shape[3]
    hpg = H // G
    Q = min(chunk, S)
    if S % Q:
        # pad with dt = 0 steps: decay factor exp(0) = 1 and zero state
        # contribution, so padding is exact; slice y back afterwards.
        pad = Q - S % Q
        y, final = ssd_scan(
            jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0))),
            jnp.pad(bg, ((0, 0), (0, pad), (0, 0), (0, 0))),
            jnp.pad(cg, ((0, 0), (0, pad), (0, 0), (0, 0))),
            jnp.pad(dt, ((0, 0), (0, pad), (0, 0))),
            A, chunk, init_state)
        return y[:, :S], final
    nc = S // Q
    # heads laid out as (G, hpg): head h belongs to group h // hpg
    xf = xh.astype(jnp.float32).reshape(B, nc, Q, G, hpg, P)
    bf = bg.astype(jnp.float32).reshape(B, nc, Q, G, N)
    cf = cg.astype(jnp.float32).reshape(B, nc, Q, G, N)
    dtc = dt.reshape(B, nc, Q, G, hpg)
    dA = dtc * A.reshape(G, hpg)[None, None, None]       # (B,nc,Q,G,hpg) <= 0
    cum = jnp.cumsum(dA, axis=2)                         # inclusive
    # intra-chunk: M[...,i,j] = C_i.B_j * exp(cum_i - cum_j) * dt_j  (i>=j)
    cb = jnp.einsum("bcign,bcjgn->bcgij", cf, bf)        # (B,nc,G,Q,Q)
    ii = jnp.arange(Q)
    # decay[b,c,g,h,i,j] = exp(cum_i - cum_j), lower-triangular
    cum_h = cum.transpose(0, 1, 3, 4, 2)                 # (B,nc,G,hpg,Q)
    decay = jnp.exp(jnp.clip(cum_h[..., :, None] - cum_h[..., None, :],
                             -60.0, 0.0))
    mask = (ii[:, None] >= ii[None, :])[None, None, None, None]
    M = cb[:, :, :, None] * jnp.where(mask, decay, 0.0) \
        * dtc.transpose(0, 1, 3, 4, 2)[..., None, :]     # (B,nc,G,hpg,Q,Q)
    y_intra = jnp.einsum("bcghij,bcjghp->bcighp", M, xf)
    # chunk state: sum_j exp(cum_last - cum_j) dt_j B_j (x) x_j
    seg = jnp.exp(jnp.clip(cum[:, :, -1:] - cum, -60.0, 0.0)) * dtc  # (B,nc,Q,G,hpg)
    bx = jnp.einsum("bcjgn,bcjgh,bcjghp->bcghnp", bf, seg, xf)
    total_decay = jnp.exp(jnp.clip(cum[:, :, -1], -60.0, 0.0))       # (B,nc,G,hpg)

    def chunk_step(state, inp):
        bx_c, td_c = inp                                 # (B,G,hpg,N,P), (B,G,hpg)
        new = state * td_c[..., None, None] + bx_c
        return new, state                                # emit state BEFORE chunk

    s0 = (jnp.zeros((B, G, hpg, N, P), jnp.float32) if init_state is None
          else init_state.astype(jnp.float32).reshape(B, G, hpg, N, P))
    final, prev_states = jax.lax.scan(
        chunk_step, s0,
        (bx.transpose(1, 0, 2, 3, 4, 5), total_decay.transpose(1, 0, 2, 3)))
    prev_states = jnp.moveaxis(prev_states, 0, 1)        # (B,nc,G,hpg,N,P)
    y_inter = jnp.einsum("bcign,bcghnp,bcigh->bcighp",
                         cf, prev_states,
                         jnp.exp(jnp.clip(cum, -60.0, 0.0)))
    y = (y_intra + y_inter).reshape(B, S, H, P)
    return y, final.reshape(B, H, N, P)


def ssd_decode_step(xh, bg, cg, dt, A, state):
    """Single-token recurrence.  xh: (B,1,H,P) etc.  state: (B,H,N,P)."""
    B, _, H, P = xh.shape
    G = bg.shape[2]
    hpg = H // G
    xf = xh[:, 0].astype(jnp.float32)                    # (B,H,P)
    bf = jnp.repeat(bg[:, 0].astype(jnp.float32), hpg, axis=1)  # (B,H,N)
    cf = jnp.repeat(cg[:, 0].astype(jnp.float32), hpg, axis=1)
    dtf = dt[:, 0]                                       # (B,H)
    decay = jnp.exp(jnp.clip(dtf * A[None, :], -60.0, 0.0))
    new_state = state * decay[:, :, None, None] + \
        jnp.einsum("bhn,bh,bhp->bhnp", bf, dtf, xf)
    y = jnp.einsum("bhn,bhnp->bhp", cf, new_state)
    return y[:, None], new_state                         # (B,1,H,P)


def ssm_block_fwd(p: Dict, x: Array, cfg: ModelConfig, *,
                  mode: str = "train",
                  cache: Optional[SSMCache] = None,
                  lora_ctx=None) -> Tuple[Array, Optional[SSMCache]]:
    """Full Mamba2 block: proj -> causal conv -> SSD -> gated norm -> out."""
    B, S, _ = x.shape
    s = cfg.ssm
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    z, xbc, dt = _project(p, x, cfg, lora_ctx)

    new_cache = None
    if mode == "decode":
        assert cache is not None
        full = jnp.concatenate([cache.conv.astype(xbc.dtype), xbc], axis=1)
        conv_out = jnp.einsum("bkw,kw->bw", full[:, -s.d_conv:, :], p["conv_w"])
        conv_out = jax.nn.silu(conv_out.astype(jnp.float32)).astype(xbc.dtype)[:, None]
        xh, bg, cg = _split_xbc(conv_out, cfg)
        y, new_state = ssd_decode_step(xh, bg, cg, dt, A, cache.state)
        new_cache = SSMCache(conv=full[:, -(s.d_conv - 1):, :].astype(cache.conv.dtype),
                             state=new_state, index=cache.index + 1)
    else:
        conv_out, conv_state = _causal_conv(xbc, p["conv_w"])
        xh, bg, cg = _split_xbc(conv_out, cfg)
        xh = constrain(xh, "batch", "seq", "ssm_heads", None)
        y, final_state = ssd_scan(xh, bg, cg, dt, A, s.chunk)
        if mode == "prefill":
            assert cache is not None
            new_cache = SSMCache(conv=conv_state.astype(cache.conv.dtype),
                                 state=final_state,
                                 index=jnp.asarray(S, jnp.int32))
    y = y + p["D"].astype(jnp.float32)[None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(B, S, -1).astype(x.dtype)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    from repro.models.layers import rms_norm
    y = rms_norm(y, p["norm"], cfg.norm_eps)
    y = constrain(y, "batch", "seq", "d_ff")
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"])
    if lora_ctx is not None:
        out = lora_mod.apply(lora_ctx, "ssm_out", y, out)
    return constrain(out, "batch", "seq", "d_model"), new_cache
