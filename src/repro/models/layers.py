"""Dense transformer building blocks: norms, RoPE, GQA attention (qk-norm /
qkv-bias / sliding-window / chunked-flash), SwiGLU MLP, embeddings, and
memory-safe cross-entropy.

All functions are pure; parameters are nested dicts produced by the
``*_defs`` companions (see :mod:`repro.models.param`).  Activations carry
logical sharding constraints so the same code lowers on any mesh.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import constrain, current_mesh
from repro.models.param import ParamDef
from repro.models import lora as lora_mod

Array = jax.Array
NEG_INF = -1e30


# ---------------------------------------------------------------------------
# norms & rope
# ---------------------------------------------------------------------------


def rms_norm(x: Array, scale: Array, eps: float = 1e-5) -> Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(dt)


def rope_tables(positions: Array, head_dim: int, theta: float) -> Tuple[Array, Array]:
    """cos/sin tables for given integer positions. positions: (...,S)."""
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    angles = positions.astype(jnp.float32)[..., None] * freqs  # (...,S,half)
    return jnp.cos(angles), jnp.sin(angles)


def apply_rope(x: Array, cos: Array, sin: Array) -> Array:
    """x: (B, S, H, hd); cos/sin: (B, S, half) or (S, half)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    if cos.ndim == 2:
        cos = cos[None]
        sin = sin[None]
    cos = cos[:, :, None, :]
    sin = sin[:, :, None, :]
    dt = x.dtype
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate(
        [x1f * cos - x2f * sin, x2f * cos + x1f * sin], axis=-1).astype(dt)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------


def attention_defs(cfg: ModelConfig, kv_heads: Optional[int] = None) -> Dict:
    d, H = cfg.d_model, cfg.num_heads
    Kv = kv_heads if kv_heads is not None else cfg.num_kv_heads
    hd = cfg.resolved_head_dim
    defs = {
        "wq": ParamDef((d, H, hd), ("d_model", "heads", "head_dim")),
        "wk": ParamDef((d, Kv, hd), ("d_model", "kv_heads", "head_dim")),
        "wv": ParamDef((d, Kv, hd), ("d_model", "kv_heads", "head_dim")),
        "wo": ParamDef((H, hd, d), ("heads", "head_dim", "d_model")),
    }
    if cfg.qkv_bias:
        defs["bq"] = ParamDef((H, hd), ("heads", "head_dim"), init="zeros")
        defs["bk"] = ParamDef((Kv, hd), ("kv_heads", "head_dim"), init="zeros")
        defs["bv"] = ParamDef((Kv, hd), ("kv_heads", "head_dim"), init="zeros")
    if cfg.qk_norm:
        defs["q_norm"] = ParamDef((hd,), ("head_dim",), init="ones")
        defs["k_norm"] = ParamDef((hd,), ("head_dim",), init="ones")
    return defs


def _qkv(p: Dict, x: Array, cfg: ModelConfig, lora_ctx) -> Tuple[Array, Array, Array]:
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if lora_ctx is not None:
        q = lora_mod.apply(lora_ctx, "q", x, q)
        k = lora_mod.apply(lora_ctx, "k", x, k)
        v = lora_mod.apply(lora_ctx, "v", x, v)
    if cfg.qkv_bias:
        q = q + p["bq"].astype(q.dtype)
        k = k + p["bk"].astype(k.dtype)
        v = v + p["bv"].astype(v.dtype)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    return q, k, v


def _gqa_logits(q: Array, k: Array) -> Array:
    """q: (B,Sq,Kv,G,hd), k: (B,Skv,Kv,hd) -> (B,Kv,G,Sq,Skv) fp32."""
    return jnp.einsum("bqkgh,bskh->bkgqs", q.astype(jnp.float32),
                      k.astype(jnp.float32))


def naive_attention(q: Array, k: Array, v: Array, *, causal: bool,
                    q_offset: Array | int = 0,
                    kv_len: Optional[Array] = None,
                    sliding_window: int = 0) -> Array:
    """Reference attention. q: (B,Sq,H,hd); k,v: (B,Skv,Kv,hd)."""
    B, Sq, H, hd = q.shape
    Skv, Kv = k.shape[1], k.shape[2]
    G = H // Kv
    qg = q.reshape(B, Sq, Kv, G, hd) * (hd ** -0.5)
    logits = _gqa_logits(qg, k)  # (B,Kv,G,Sq,Skv)
    q_off = jnp.asarray(q_offset)
    kpos = jnp.arange(Skv)
    if q_off.ndim == 0:
        qpos = jnp.arange(Sq) + q_off
        mask = jnp.ones((1, Sq, Skv), dtype=bool)
        qp = qpos[None]
    else:  # per-batch offsets (continuous batching, ragged slots)
        qp = q_off[:, None] + jnp.arange(Sq)[None]       # (B, Sq)
        mask = jnp.ones((B, Sq, Skv), dtype=bool)
    if causal:
        mask &= kpos[None, None, :] <= qp[:, :, None]
    if sliding_window:
        mask &= kpos[None, None, :] > qp[:, :, None] - sliding_window
    if kv_len is not None:
        kl = jnp.asarray(kv_len)
        kl = kl[:, None, None] if kl.ndim == 1 else kl
        mask &= kpos[None, None, :] < kl
    logits = jnp.where(mask[:, None, None], logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgqs,bskh->bqkgh", w, v.astype(jnp.float32))
    return out.reshape(B, Sq, H, hd).astype(q.dtype)


def chunked_attention(q: Array, k: Array, v: Array, *, causal: bool,
                      chunk_q: int, chunk_kv: int,
                      q_offset: Array | int = 0,
                      kv_len: Optional[Array] = None,
                      sliding_window: int = 0) -> Array:
    """Flash-style online-softmax attention in pure jnp (scan over chunks).

    Memory is O(chunk_q * chunk_kv) per (batch, head) instead of O(Sq * Skv).
    """
    B, Sq, H, hd = q.shape
    Skv, Kv = k.shape[1], k.shape[2]
    G = H // Kv
    cq = min(chunk_q, Sq)
    ckv = min(chunk_kv, Skv)
    if Sq % cq or Skv % ckv:
        return naive_attention(q, k, v, causal=causal, q_offset=q_offset,
                               kv_len=kv_len, sliding_window=sliding_window)
    nq, nkv = Sq // cq, Skv // ckv
    qg = (q.reshape(B, nq, cq, Kv, G, hd) * (hd ** -0.5)).astype(jnp.float32)
    ks = k.reshape(B, nkv, ckv, Kv, hd).astype(jnp.float32)
    vs = v.reshape(B, nkv, ckv, Kv, hd).astype(jnp.float32)

    def q_block(iq, q_i):
        # q_i: (B, cq, Kv, G, hd)
        qpos = iq * cq + jnp.arange(cq) + q_offset

        def kv_block(carry, ikv):
            m, l, acc = carry
            k_j = jax.lax.dynamic_index_in_dim(ks, ikv, 1, keepdims=False)
            v_j = jax.lax.dynamic_index_in_dim(vs, ikv, 1, keepdims=False)
            logits = jnp.einsum("bqkgh,bskh->bkgqs", q_i, k_j)
            kpos = ikv * ckv + jnp.arange(ckv)
            mask = jnp.ones((cq, ckv), dtype=bool)
            if causal:
                mask &= kpos[None, :] <= qpos[:, None]
            if sliding_window:
                mask &= kpos[None, :] > qpos[:, None] - sliding_window
            if kv_len is not None:
                mask &= kpos[None, :] < kv_len
            logits = jnp.where(mask[None, None, None], logits, NEG_INF)
            m_new = jnp.maximum(m, logits.max(axis=-1))
            p = jnp.exp(logits - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l_new = l * alpha + p.sum(axis=-1)
            pv = jnp.einsum("bkgqs,bskh->bkgqh", p, v_j)
            acc_new = acc * alpha[..., None] + pv
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, Kv, G, cq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, Kv, G, cq), jnp.float32)
        a0 = jnp.zeros((B, Kv, G, cq, hd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_block, (m0, l0, a0), jnp.arange(nkv))
        out = acc / jnp.maximum(l, 1e-30)[..., None]     # (B,Kv,G,cq,hd)
        return jnp.transpose(out, (0, 3, 1, 2, 4))       # (B,cq,Kv,G,hd)

    outs = jax.vmap(q_block, in_axes=(0, 1), out_axes=1)(jnp.arange(nq), qg)
    return outs.reshape(B, Sq, H, hd).astype(q.dtype)


def _two_part_decode_attention(q, cache_k, cache_v, k_new, v_new, idx):
    """Decode attention over (old cache) + (current token) without writing
    the cache first.  q/k_new/v_new: (B,1,H|Kv,hd); cache: (B,S,Kv,hd)."""
    B, _, H, hd = q.shape
    S, Kv = cache_k.shape[1], cache_k.shape[2]
    G = H // Kv
    qg = (q[:, 0].reshape(B, Kv, G, hd) * (hd ** -0.5)).astype(jnp.float32)
    logits_c = jnp.einsum("bkgh,bskh->bkgs", qg,
                          cache_k.astype(jnp.float32))        # (B,Kv,G,S)
    kl = idx if jnp.ndim(idx) == 1 else jnp.full((B,), idx, jnp.int32)
    valid = jnp.arange(S)[None, :] < kl[:, None]
    logits_c = jnp.where(valid[:, None, None, :], logits_c, NEG_INF)
    logit_s = jnp.einsum("bkgh,bkh->bkg", qg,
                         k_new[:, 0].astype(jnp.float32))[..., None]
    m = jnp.maximum(logits_c.max(-1, keepdims=True), logit_s)
    w_c = jnp.exp(logits_c - m)
    w_c = jnp.where(valid[:, None, None, :], w_c, 0.0)
    w_s = jnp.exp(logit_s - m)
    denom = w_c.sum(-1, keepdims=True) + w_s
    out = jnp.einsum("bkgs,bskh->bkgh", w_c, cache_v.astype(jnp.float32))
    out = out + w_s * v_new[:, 0].astype(jnp.float32).reshape(B, Kv, 1, hd)
    out = out / jnp.maximum(denom, 1e-30)
    return out.reshape(B, 1, H, hd).astype(q.dtype)


@dataclasses.dataclass
class KVCache:
    """Static-size KV cache. k/v: (B, S_max, Kv, hd); index: scalar int32."""
    k: Array
    v: Array
    index: Array

    @staticmethod
    def zeros(batch: int, s_max: int, kv_heads: int, head_dim: int,
              dtype=jnp.bfloat16) -> "KVCache":
        return KVCache(
            k=jnp.zeros((batch, s_max, kv_heads, head_dim), dtype),
            v=jnp.zeros((batch, s_max, kv_heads, head_dim), dtype),
            index=jnp.zeros((), jnp.int32))

    @staticmethod
    def abstract(batch: int, s_max: int, kv_heads: int, head_dim: int,
                 dtype=jnp.bfloat16) -> "KVCache":
        return KVCache(
            k=jax.ShapeDtypeStruct((batch, s_max, kv_heads, head_dim), dtype),
            v=jax.ShapeDtypeStruct((batch, s_max, kv_heads, head_dim), dtype),
            index=jax.ShapeDtypeStruct((), jnp.int32))


jax.tree_util.register_dataclass(KVCache, ["k", "v", "index"], [])


def attention_fwd(p: Dict, x: Array, cfg: ModelConfig, *,
                  positions: Array,
                  mode: str = "train",            # train | prefill | decode
                  cache: Optional[KVCache] = None,
                  lora_ctx=None,
                  causal: bool = True) -> Tuple[Array, Optional[KVCache]]:
    """Self-attention over x; updates cache in prefill/decode modes."""
    B, S, _ = x.shape
    hd = cfg.resolved_head_dim
    q, k, v = _qkv(p, x, cfg, lora_ctx)
    cos, sin = rope_tables(positions, hd, cfg.rope_theta)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    mesh = current_mesh()
    tp = mesh.shape.get("model", 1) if mesh is not None else 1
    # context-parallel fallback (§Perf hillclimb): when heads don't divide
    # the TP degree (granite 24H / whisper 12H at TP16), shard the attention
    # compute over SEQUENCE instead of replicating it on every model rank.
    use_cp = (cfg.attn_cp_fallback and tp > 1 and cfg.num_heads % tp != 0
              and mode != "decode" and S % tp == 0)
    if use_cp:
        q = constrain(q, "batch", "seq_sp", "heads", "head_dim")
        v = constrain(v, "batch", "seq", "kv_heads", "head_dim")
    else:
        q = constrain(q, "batch", "seq", "heads", "head_dim")
    k = constrain(k, "batch", "seq", "kv_heads", "head_dim")

    new_cache = None
    if mode == "train":
        keys, vals = k, v
        kv_len = None
        q_offset = 0
    elif mode == "prefill":
        assert cache is not None
        keys = jax.lax.dynamic_update_slice_in_dim(cache.k, k.astype(cache.k.dtype), 0, axis=1)
        vals = jax.lax.dynamic_update_slice_in_dim(cache.v, v.astype(cache.v.dtype), 0, axis=1)
        new_cache = KVCache(k=keys, v=vals, index=jnp.asarray(S, jnp.int32))
        keys, vals, kv_len, q_offset = k, v, None, 0   # attend within prompt only
    elif mode == "decode":
        assert cache is not None
        idx = cache.index
        S_max0 = cache.k.shape[1]
        use_seq_decode0 = (cfg.decode_attn == "seq_shard" and S == 1
                           and mesh is not None and tp > 1
                           and cfg.num_kv_heads % tp != 0
                           and S_max0 % tp == 0)
        if use_seq_decode0:
            # fused update+attention: the S-sharded cache never leaves its
            # shards (avoids per-layer full-cache reshard copies; §Perf)
            from repro.distributed.collectives import seq_sharded_decode_step
            out, keys, vals = seq_sharded_decode_step(
                q, cache.k, cache.v, k, v, idx, mesh)
            new_cache = KVCache(k=keys, v=vals, index=idx + S)
            out = constrain(out, "batch", "seq", "heads", "head_dim")
            y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
            if lora_ctx is not None:
                y = lora_mod.apply(lora_ctx, "o", out.reshape(B, S, -1), y)
            return constrain(y, "batch", "seq", "d_model"), new_cache
        if cfg.decode_attn == "lazy" and S == 1:
            # lazy cache write (§Perf): attend to the OLD cache + the new
            # token as a two-part softmax; emit only the new (k, v) token.
            # The caller splices all layers' new tokens into the stacked
            # cache with ONE tiny dynamic-update-slice per step, instead of
            # rewriting every layer's full cache slice through scan ys.
            out = _two_part_decode_attention(q, cache.k, cache.v, k, v, idx)
            new_cache = KVCache(k=k.astype(cache.k.dtype),
                                v=v.astype(cache.v.dtype), index=idx + S)
            out = constrain(out, "batch", "seq", "heads", "head_dim")
            y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
            if lora_ctx is not None:
                y = lora_mod.apply(lora_ctx, "o", out.reshape(B, S, -1), y)
            return constrain(y, "batch", "seq", "d_model"), new_cache
        if jnp.ndim(idx) == 0:
            keys = jax.lax.dynamic_update_slice_in_dim(
                cache.k, k.astype(cache.k.dtype), idx, axis=1)
            vals = jax.lax.dynamic_update_slice_in_dim(
                cache.v, v.astype(cache.v.dtype), idx, axis=1)
        else:  # per-row positions (S == 1)
            rows = jnp.arange(B)
            keys = cache.k.at[rows, idx].set(k[:, 0].astype(cache.k.dtype))
            vals = cache.v.at[rows, idx].set(v[:, 0].astype(cache.v.dtype))
        new_cache = KVCache(k=keys, v=vals, index=idx + S)
        kv_len = idx + S
        q_offset = idx
    else:
        raise ValueError(mode)

    keys = constrain(keys, "batch", "kv_seq", "kv_heads", "head_dim") \
        if mode == "decode" else keys
    vals = constrain(vals, "batch", "kv_seq", "kv_heads", "head_dim") \
        if mode == "decode" else vals

    use_chunks = cfg.attn_chunk_q > 0 and mode != "decode" and S > cfg.attn_chunk_q
    if use_chunks:
        out = chunked_attention(q, keys, vals, causal=causal,
                                chunk_q=cfg.attn_chunk_q,
                                chunk_kv=cfg.attn_chunk_kv,
                                q_offset=q_offset, kv_len=kv_len,
                                sliding_window=cfg.sliding_window)
    else:
        out = naive_attention(q, keys, vals, causal=causal, q_offset=q_offset,
                              kv_len=kv_len, sliding_window=cfg.sliding_window)
    out = constrain(out, "batch", "seq", "heads", "head_dim")
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    if lora_ctx is not None:
        y = lora_mod.apply(lora_ctx, "o", out.reshape(B, S, -1), y)
    return constrain(y, "batch", "seq", "d_model"), new_cache


def cross_attention_fwd(p: Dict, x: Array, memory: Array, cfg: ModelConfig,
                        lora_ctx=None) -> Array:
    """Encoder-decoder cross attention (no rope, no causal mask)."""
    B, S, _ = x.shape
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", memory, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", memory, p["wv"])
    if lora_ctx is not None:
        q = lora_mod.apply(lora_ctx, "xq", x, q)
        k = lora_mod.apply(lora_ctx, "xk", memory, k)
        v = lora_mod.apply(lora_ctx, "xv", memory, v)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    out = naive_attention(q, k, v, causal=False)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"])


# ---------------------------------------------------------------------------
# mlp
# ---------------------------------------------------------------------------


def mlp_defs(d_model: int, d_ff: int) -> Dict:
    return {
        "w_gate": ParamDef((d_model, d_ff), ("d_model", "d_ff")),
        "w_up": ParamDef((d_model, d_ff), ("d_model", "d_ff")),
        "w_down": ParamDef((d_ff, d_model), ("d_ff", "d_model")),
    }


def mlp_fwd(p: Dict, x: Array) -> Array:
    g = jnp.einsum("bsd,df->bsf", x, p["w_gate"])
    u = jnp.einsum("bsd,df->bsf", x, p["w_up"])
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    h = constrain(h, "batch", "seq", "d_ff")
    return jnp.einsum("bsf,fd->bsd", h, p["w_down"])


# ---------------------------------------------------------------------------
# embeddings & losses
# ---------------------------------------------------------------------------


def embedding_defs(cfg: ModelConfig) -> Dict:
    Vp, d = cfg.padded_vocab, cfg.d_model
    defs = {
        "embed": ParamDef((Vp, d), ("vocab", "d_model"), scale=0.02),
        "final_norm": ParamDef((d,), ("d_model",), init="ones"),
    }
    if not cfg.tie_embeddings:
        defs["unembed"] = ParamDef((d, Vp), ("d_model", "vocab"), scale=0.02)
    return defs


def embed_tokens(p: Dict, tokens: Array) -> Array:
    return constrain(p["embed"][tokens], "batch", "seq", "d_model")


def _unembed_matrix(p: Dict, cfg: ModelConfig) -> Array:
    return p["embed"].T if cfg.tie_embeddings else p["unembed"]


def logits_fwd(p: Dict, h: Array, cfg: ModelConfig) -> Array:
    h = rms_norm(h, p["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", h, _unembed_matrix(p, cfg))
    return constrain(logits, "batch", "seq", "vocab")


def cross_entropy(p: Dict, h: Array, targets: Array, cfg: ModelConfig,
                  mask: Optional[Array] = None) -> Array:
    """Token-mean CE.  With cfg.logits_chunk_vocab > 0, never materializes the
    full (B, S, V) logits: scans vocab chunks with an online logsumexp."""
    h = rms_norm(h, p["final_norm"], cfg.norm_eps)
    W = _unembed_matrix(p, cfg)                   # (d, Vp)
    Vp = W.shape[1]
    tgt = jnp.clip(targets, 0, Vp - 1)
    if mask is None:
        mask = (targets >= 0).astype(jnp.float32)
    chunk = cfg.logits_chunk_vocab
    if chunk and Vp > chunk:
        # pick the smallest chunk count >= Vp/target that divides Vp
        n = -(-Vp // chunk)
        while Vp % n and n < min(Vp, 4096):
            n += 1
        chunk = Vp // n if Vp % n == 0 else 0
    if chunk and Vp % chunk == 0 and Vp > chunk:
        n = Vp // chunk
        Wc = W.reshape(W.shape[0], n, chunk)

        def body(carry, i):
            m, l = carry
            lg = jnp.einsum("bsd,dv->bsv", h, jax.lax.dynamic_index_in_dim(
                Wc, i, 1, keepdims=False)).astype(jnp.float32)
            m_new = jnp.maximum(m, lg.max(axis=-1))
            l = l * jnp.exp(m - m_new) + jnp.exp(lg - m_new[..., None]).sum(-1)
            return (m_new, l), None

        m0 = jnp.full(h.shape[:2], NEG_INF, jnp.float32)
        l0 = jnp.zeros(h.shape[:2], jnp.float32)
        (m, l), _ = jax.lax.scan(body, (m0, l0), jnp.arange(n))
        lse = m + jnp.log(jnp.maximum(l, 1e-30))
        tgt_logit = jnp.einsum("bsd,bsd->bs", h.astype(jnp.float32),
                               W.T[tgt].astype(jnp.float32))
    else:
        logits = jnp.einsum("bsd,dv->bsv", h, W).astype(jnp.float32)
        logits = constrain(logits, "batch", "seq", "vocab")
        lse = jax.nn.logsumexp(logits, axis=-1)
        tgt_logit = jnp.take_along_axis(logits, tgt[..., None], axis=-1)[..., 0]
    nll = (lse - tgt_logit) * mask
    return jnp.sum(nll) / jnp.maximum(jnp.sum(mask), 1.0)
