"""Minimal parameter-definition system.

Models are defined as nested dicts of :class:`ParamDef`; the same tree yields
(1) materialized parameters, (2) PartitionSpecs via the logical-axis rules,
(3) ShapeDtypeStructs for allocation-free dry-runs, and (4) param counts.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding

from repro.distributed.sharding import spec_for

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class ParamDef:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]           # logical axis per dim
    init: str = "normal"                      # normal | zeros | ones | small
    scale: Optional[float] = None             # stddev override
    dtype: Any = jnp.bfloat16

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def is_def(x) -> bool:
    return isinstance(x, ParamDef)


def _tree_map(fn, tree):
    return jax.tree.map(fn, tree, is_leaf=is_def)


def init_params(defs, key: Array, dtype_override=None):
    """Materialize a ParamDef tree into arrays (deterministic per-leaf keys)."""
    leaves, treedef = jax.tree.flatten(defs, is_leaf=is_def)
    keys = jax.random.split(key, max(len(leaves), 1))
    out = []
    for k, d in zip(keys, leaves):
        dt = dtype_override or d.dtype
        if d.init == "zeros":
            out.append(jnp.zeros(d.shape, dt))
        elif d.init == "ones":
            out.append(jnp.ones(d.shape, dt))
        else:
            fan_in = d.shape[0] if len(d.shape) >= 2 else max(d.shape[-1], 1)
            std = d.scale if d.scale is not None else 1.0 / math.sqrt(fan_in)
            if d.init == "small":
                std = (d.scale or 1.0) * 0.02
            out.append((jax.random.normal(k, d.shape, jnp.float32) * std).astype(dt))
    return jax.tree.unflatten(treedef, out)


def abstract_params(defs, dtype_override=None):
    """ShapeDtypeStruct tree (for .lower() without allocation)."""
    return _tree_map(
        lambda d: jax.ShapeDtypeStruct(d.shape, dtype_override or d.dtype), defs)


def param_specs(defs, mesh: Optional[Mesh] = None):
    """PartitionSpec tree resolved against a mesh."""
    return _tree_map(lambda d: spec_for(d.shape, d.axes, mesh), defs)


def param_shardings(defs, mesh: Mesh):
    return _tree_map(lambda d: NamedSharding(mesh, spec_for(d.shape, d.axes, mesh)),
                     defs)


def count_params(defs) -> int:
    leaves = jax.tree.leaves(defs, is_leaf=is_def)
    return sum(int(jnp.prod(jnp.asarray(l.shape))) if not hasattr(l, "size")
               else l.size for l in leaves) if leaves and is_def(leaves[0]) else \
        sum(l.size for l in leaves)


def count_defs(defs) -> int:
    leaves = jax.tree.flatten(defs, is_leaf=is_def)[0]
    total = 0
    for d in leaves:
        sz = 1
        for s in d.shape:
            sz *= s
        total += sz
    return total


def stacked(defs: Dict, n: int, axis_name: str = "layers"):
    """Add a leading stacking dim (for scan-over-layers) to every leaf."""
    return _tree_map(
        lambda d: dataclasses.replace(d, shape=(n,) + d.shape,
                                      axes=(axis_name,) + d.axes), defs)
