"""Model assembly for all assigned architecture families.

``model_defs(cfg)`` builds the ParamDef tree; ``forward(...)`` runs it in
train / prefill / decode mode with optional LoRA context.  Layers are scanned
(``jax.lax.scan``) with optional remat so the HLO stays compact for 80–90
layer models; hybrid (zamba2) scans groups of SSM layers with a weight-shared
attention block between groups; audio (whisper) runs an encoder stack and a
decoder stack with cross-attention.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import constrain
from repro.models import lora as lora_mod
from repro.models.layers import (KVCache, ParamDef, attention_defs,
                                 attention_fwd, cross_attention_fwd,
                                 cross_entropy, embed_tokens, embedding_defs,
                                 logits_fwd, mlp_defs, mlp_fwd, rms_norm)
from repro.models.moe import moe_defs, moe_fwd
from repro.models.param import stacked
from repro.models.ssm import SSMCache, ssm_block_fwd, ssm_defs

Array = jax.Array


# ---------------------------------------------------------------------------
# parameter trees
# ---------------------------------------------------------------------------


def _norm_def(d: int) -> ParamDef:
    return ParamDef((d,), ("d_model",), init="ones")


def _attn_block_defs(cfg: ModelConfig) -> Dict:
    return {"ln1": _norm_def(cfg.d_model), "attn": attention_defs(cfg),
            "ln2": _norm_def(cfg.d_model), "mlp": mlp_defs(cfg.d_model, cfg.d_ff)}


def _moe_block_defs(cfg: ModelConfig) -> Dict:
    return {"ln1": _norm_def(cfg.d_model), "attn": attention_defs(cfg),
            "ln2": _norm_def(cfg.d_model), "moe": moe_defs(cfg)}


def _ssm_block_defs(cfg: ModelConfig) -> Dict:
    return {"ln1": _norm_def(cfg.d_model), "ssm": ssm_defs(cfg)}


def _decoder_block_defs(cfg: ModelConfig) -> Dict:
    return {"ln1": _norm_def(cfg.d_model), "attn": attention_defs(cfg),
            "lnx": _norm_def(cfg.d_model), "xattn": attention_defs(cfg),
            "ln2": _norm_def(cfg.d_model), "mlp": mlp_defs(cfg.d_model, cfg.d_ff)}


def model_defs(cfg: ModelConfig) -> Dict:
    defs: Dict[str, Any] = {"embed": embedding_defs(cfg)}
    L = cfg.num_layers
    if cfg.family == "moe":
        fk = cfg.moe.first_k_dense
        if fk:
            import dataclasses as _dc
            dense_cfg = _dc.replace(cfg, d_ff=cfg.moe.d_ff_dense)
            defs["dense_layers"] = stacked(_attn_block_defs(dense_cfg), fk)
        defs["layers"] = stacked(_moe_block_defs(cfg), L - fk)
    elif cfg.family == "ssm":
        defs["layers"] = stacked(_ssm_block_defs(cfg), L)
    elif cfg.family == "hybrid":
        period = cfg.hybrid.period
        groups = L // period
        defs["layers"] = stacked(stacked(_ssm_block_defs(cfg), period, None),
                                 groups)
        defs["shared"] = _attn_block_defs(cfg)
    elif cfg.family == "audio":
        enc_l = cfg.encdec.encoder_layers
        defs["enc_layers"] = stacked(_attn_block_defs(cfg), enc_l)
        defs["enc_norm"] = _norm_def(cfg.d_model)
        defs["layers"] = stacked(_decoder_block_defs(cfg), L)
    else:  # dense / vlm
        defs["layers"] = stacked(_attn_block_defs(cfg), L)
    return defs


def lora_defs_tree(cfg: ModelConfig) -> Dict:
    """LoRA adapter ParamDefs mirroring the layer structure."""
    targets = cfg.lora.targets
    if cfg.family == "ssm":
        per = lora_mod.lora_layer_defs(cfg, targets)
        return {"layers": stacked(per, cfg.num_layers)}
    if cfg.family == "hybrid":
        ssm_targets = tuple(t for t in targets if t.startswith("ssm"))
        attn_targets = tuple(t for t in targets if not t.startswith("ssm"))
        out = {}
        if ssm_targets:
            period = cfg.hybrid.period
            groups = cfg.num_layers // period
            out["layers"] = stacked(
                stacked(lora_mod.lora_layer_defs(cfg, ssm_targets), period, None),
                groups)
        if attn_targets:
            out["shared"] = lora_mod.lora_layer_defs(cfg, attn_targets)
        return out
    if cfg.family == "audio":
        per = lora_mod.lora_layer_defs(cfg, targets)
        return {"enc_layers": stacked(per, cfg.encdec.encoder_layers),
                "layers": stacked(per, cfg.num_layers)}
    if cfg.family == "moe":
        fk = cfg.moe.first_k_dense
        per = lora_mod.lora_layer_defs(cfg, targets)
        out = {"layers": stacked(per, cfg.num_layers - fk)}
        if fk:
            out["dense_layers"] = stacked(per, fk)
        return out
    per = lora_mod.lora_layer_defs(cfg, targets)
    return {"layers": stacked(per, cfg.num_layers)}


# ---------------------------------------------------------------------------
# caches
# ---------------------------------------------------------------------------


def init_cache(cfg: ModelConfig, batch: int, s_max: int, *,
               enc_len: int = 0, abstract: bool = False,
               dtype=jnp.bfloat16) -> Dict:
    """Family-appropriate decode cache (stacked over layers for scanning)."""
    mk = jax.ShapeDtypeStruct if abstract else jnp.zeros

    def zeros(shape, dt=dtype):
        return mk(shape, dt)

    hd = cfg.resolved_head_dim
    Kv = cfg.num_kv_heads
    L = cfg.num_layers
    cache: Dict[str, Any] = {"index": zeros((), jnp.int32)}
    if cfg.family in ("dense", "vlm", "moe"):
        cache["k"] = zeros((L, batch, s_max, Kv, hd))
        cache["v"] = zeros((L, batch, s_max, Kv, hd))
    elif cfg.family == "ssm":
        s = cfg.ssm
        W = s.d_inner(cfg.d_model) + 2 * s.n_groups * s.d_state
        H = s.n_heads(cfg.d_model)
        cache["conv"] = zeros((L, batch, s.d_conv - 1, W))
        cache["state"] = zeros((L, batch, H, s.d_state, s.head_dim), jnp.float32)
    elif cfg.family == "hybrid":
        s = cfg.ssm
        period = cfg.hybrid.period
        groups = L // period
        W = s.d_inner(cfg.d_model) + 2 * s.n_groups * s.d_state
        H = s.n_heads(cfg.d_model)
        cache["conv"] = zeros((groups, period, batch, s.d_conv - 1, W))
        cache["state"] = zeros((groups, period, batch, H, s.d_state, s.head_dim),
                               jnp.float32)
        cache["k"] = zeros((groups, batch, s_max, Kv, hd))
        cache["v"] = zeros((groups, batch, s_max, Kv, hd))
    elif cfg.family == "audio":
        cache["k"] = zeros((L, batch, s_max, Kv, hd))
        cache["v"] = zeros((L, batch, s_max, Kv, hd))
        cache["cross_k"] = zeros((L, batch, enc_len, Kv, hd))
        cache["cross_v"] = zeros((L, batch, enc_len, Kv, hd))
    else:
        raise ValueError(cfg.family)
    return cache


# ---------------------------------------------------------------------------
# blocks (single layer, used inside scans)
# ---------------------------------------------------------------------------


def _dense_block(p, x, cfg, *, positions, mode, kv, lora_ctx, causal=True):
    h, new_kv = attention_fwd(p["attn"], rms_norm(x, p["ln1"], cfg.norm_eps),
                              cfg, positions=positions, mode=mode, cache=kv,
                              lora_ctx=lora_ctx, causal=causal)
    x = x + h
    x = x + mlp_fwd(p["mlp"], rms_norm(x, p["ln2"], cfg.norm_eps))
    return x, new_kv


def _moe_block(p, x, cfg, *, positions, mode, kv, lora_ctx):
    h, new_kv = attention_fwd(p["attn"], rms_norm(x, p["ln1"], cfg.norm_eps),
                              cfg, positions=positions, mode=mode, cache=kv,
                              lora_ctx=lora_ctx)
    x = x + h
    y, aux = moe_fwd(p["moe"], rms_norm(x, p["ln2"], cfg.norm_eps), cfg)
    return x + y, new_kv, aux


def _ssm_block(p, x, cfg, *, mode, cache, lora_ctx):
    h, new_cache = ssm_block_fwd(p["ssm"], rms_norm(x, p["ln1"], cfg.norm_eps),
                                 cfg, mode=mode, cache=cache, lora_ctx=lora_ctx)
    return x + h, new_cache


# ---------------------------------------------------------------------------
# layer-stack scanners
# ---------------------------------------------------------------------------


def _maybe_remat(fn, cfg: ModelConfig, mode: str):
    if cfg.remat and mode == "train":
        return jax.checkpoint(fn, prevent_cse=False)
    return fn


@jax.custom_vjp
def _bf16_grad_boundary(x):
    return x


def _bf16_fwd(x):
    return x, None


def _bf16_bwd(_, g):
    # cast the cotangent to bf16 (halves backward activation collective
    # traffic through the FSDP/SP gathers; §Perf hillclimb)
    return (g.astype(jnp.bfloat16).astype(g.dtype)
            if g.dtype == jnp.float32 else g,)


_bf16_grad_boundary.defvjp(_bf16_fwd, _bf16_bwd)


def _constrain_carry(c, cfg=None):
    """Layer-boundary activation sharding (Megatron-SP), applied OUTSIDE the
    remat boundary so the saved residuals are the sequence-sharded copies."""
    def one(a):
        if hasattr(a, "ndim") and a.ndim == 3:
            a = constrain(a, "batch", "seq_sp", "d_model")
            if cfg is not None and cfg.grad_cast_bf16:
                a = _bf16_grad_boundary(a)
        return a
    return jax.tree.map(one, c)


def _scan_stack(fn, x, xs, cfg: ModelConfig, mode: str):
    """scan fn over stacked layer inputs; fn(x, xs_l) -> (x, ys_l)."""
    inner = _maybe_remat(fn, cfg, mode)

    def wrapped(c, xs_l):
        return inner(_constrain_carry(c, cfg), xs_l)

    if cfg.scan_layers:
        return jax.lax.scan(wrapped, x, xs)
    n = jax.tree.leaves(xs)[0].shape[0]
    ys = []
    for i in range(n):
        x, y = wrapped(x, jax.tree.map(lambda a: a[i], xs))
        ys.append(y)
    ys = jax.tree.map(lambda *a: jnp.stack(a), *ys) if ys and ys[0] is not None \
        else None
    return x, ys


def _kv_of(cache, mode, layer_kv=None, index=None):
    if mode == "train":
        return None
    k, v = layer_kv
    return KVCache(k=k, v=v, index=index)


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------


def forward(params: Dict, cfg: ModelConfig, *,
            tokens: Optional[Array] = None,
            patches: Optional[Array] = None,
            frames: Optional[Array] = None,
            mode: str = "train",
            cache: Optional[Dict] = None,
            lora_params: Optional[Dict] = None,
            lora_ctx_proto: Optional[lora_mod.LoRAContext] = None,
            ) -> Tuple[Array, Optional[Dict], Array]:
    """Run the model.  Returns (hidden (B,S,d), new_cache, aux_loss).

    ``lora_params`` mirrors the layer structure (see lora_defs_tree);
    ``lora_ctx_proto`` carries mode/ids/scaling (its .params is ignored).
    """
    assert mode in ("train", "prefill", "decode")
    aux = jnp.zeros((), jnp.float32)

    def ctx(layer_lora):
        if layer_lora is None or lora_ctx_proto is None:
            return None
        return lora_mod.layer_slice(lora_ctx_proto, layer_lora)

    lp = lora_params or {}
    if cfg.family == "audio":
        return _forward_audio(params, cfg, tokens=tokens, frames=frames,
                              mode=mode, cache=cache, lp=lp, ctx=ctx, aux=aux)

    # ---- embed ----------------------------------------------------------
    x = embed_tokens(params["embed"], tokens)
    if cfg.family == "vlm" and patches is not None:
        x = jnp.concatenate([patches.astype(x.dtype), x], axis=1)
        x = constrain(x, "batch", "seq", "d_model")
    B, S, _ = x.shape

    index = cache["index"] if cache is not None else jnp.zeros((), jnp.int32)
    if jnp.ndim(index) == 1:   # per-slot positions (continuous batching)
        positions = index[:, None] + jnp.arange(S, dtype=jnp.int32)[None]
    else:
        positions = index + jnp.arange(S, dtype=jnp.int32)
    new_cache = dict(cache) if cache is not None else None

    # ---- layer stacks ---------------------------------------------------
    if cfg.family in ("dense", "vlm"):
        def fn(x, xs):
            p_l, kv_l, lora_l = xs
            kv = _kv_of(cache, mode, kv_l, index)
            x, new_kv = _dense_block(p_l, x, cfg, positions=positions,
                                     mode=mode, kv=kv, lora_ctx=ctx(lora_l))
            ys = (new_kv.k, new_kv.v) if new_kv is not None else None
            return x, ys

        kv_stack = (cache["k"], cache["v"]) if cache is not None else None
        xs = (params["layers"], kv_stack, lp.get("layers"))
        x, ys = _scan_stack(fn, x, xs, cfg, mode)
        if ys is not None and cache is not None:
            if cfg.decode_attn == "lazy" and mode == "decode":
                # ys hold only each layer's new (k, v) token: one tiny
                # dynamic-update-slice on the stacked cache per step
                new_cache["k"] = jax.lax.dynamic_update_slice_in_dim(
                    cache["k"], ys[0], index, axis=2)
                new_cache["v"] = jax.lax.dynamic_update_slice_in_dim(
                    cache["v"], ys[1], index, axis=2)
            else:
                new_cache["k"], new_cache["v"] = ys

    elif cfg.family == "moe":
        fk = cfg.moe.first_k_dense
        if fk:
            import dataclasses as _dc
            dense_cfg = _dc.replace(cfg, d_ff=cfg.moe.d_ff_dense)

            def fn_d(x, xs):
                p_l, kv_l, lora_l = xs
                kv = _kv_of(cache, mode, kv_l, index)
                x, new_kv = _dense_block(p_l, x, dense_cfg,
                                         positions=positions, mode=mode,
                                         kv=kv, lora_ctx=ctx(lora_l))
                ys = (new_kv.k, new_kv.v) if new_kv is not None else None
                return x, ys

            kv_stack = ((cache["k"][:fk], cache["v"][:fk])
                        if cache is not None else None)
            xs = (params["dense_layers"], kv_stack, lp.get("dense_layers"))
            x, ys_d = _scan_stack(fn_d, x, xs, cfg, mode)
        aux_acc = jnp.zeros((), jnp.float32)

        def fn_m(carry, xs):
            x, aux_acc = carry
            p_l, kv_l, lora_l = xs
            kv = _kv_of(cache, mode, kv_l, index)
            x, new_kv, aux_l = _moe_block(p_l, x, cfg, positions=positions,
                                          mode=mode, kv=kv, lora_ctx=ctx(lora_l))
            ys = (new_kv.k, new_kv.v) if new_kv is not None else None
            return (x, aux_acc + aux_l), ys

        kv_stack = ((cache["k"][fk:], cache["v"][fk:])
                    if cache is not None else None)
        xs = (params["layers"], kv_stack, lp.get("layers"))
        (x, aux_acc), ys_m = _scan_stack(fn_m, (x, aux_acc), xs, cfg, mode)
        aux = aux + aux_acc / max(cfg.num_layers - fk, 1)
        if cache is not None:
            ks, vs = [], []
            if fk:
                ks.append(ys_d[0])
                vs.append(ys_d[1])
            if ys_m is not None:
                ks.append(ys_m[0])
                vs.append(ys_m[1])
            new_cache["k"] = jnp.concatenate(ks, axis=0)
            new_cache["v"] = jnp.concatenate(vs, axis=0)

    elif cfg.family == "ssm":
        def fn(x, xs):
            p_l, c_l, lora_l = xs
            c = _ssm_cache_of(c_l, index) if cache is not None else None
            x, new_c = _ssm_block(p_l, x, cfg, mode=mode, cache=c,
                                  lora_ctx=ctx(lora_l))
            ys = (new_c.conv, new_c.state) if new_c is not None else None
            return x, ys

        c_stack = ((cache["conv"], cache["state"]) if cache is not None else None)
        xs = (params["layers"], c_stack, lp.get("layers"))
        x, ys = _scan_stack(fn, x, xs, cfg, mode)
        if ys is not None and cache is not None:
            new_cache["conv"], new_cache["state"] = ys

    elif cfg.family == "hybrid":
        period = cfg.hybrid.period
        shared_p = params["shared"]
        shared_lora = lp.get("shared")

        def group_fn(x, xs):
            p_g, ssm_c_g, kv_g, lora_g = xs

            def inner_fn(x, xs_i):
                p_l, c_l, lora_l = xs_i
                c = _ssm_cache_of(c_l, index) if cache is not None else None
                x, new_c = _ssm_block(p_l, x, cfg, mode=mode, cache=c,
                                      lora_ctx=ctx(lora_l))
                ys = (new_c.conv, new_c.state) if new_c is not None else None
                return x, ys

            x, ssm_ys = jax.lax.scan(inner_fn, x, (p_g, ssm_c_g, lora_g))
            kv = _kv_of(cache, mode, kv_g, index)
            x, new_kv = _dense_block(shared_p, x, cfg, positions=positions,
                                     mode=mode, kv=kv, lora_ctx=ctx(shared_lora))
            kv_ys = (new_kv.k, new_kv.v) if new_kv is not None else None
            return x, (ssm_ys, kv_ys)

        ssm_stack = ((cache["conv"], cache["state"]) if cache is not None
                     else None)
        kv_stack = ((cache["k"], cache["v"]) if cache is not None else None)
        lora_stack = lp.get("layers")
        xs = (params["layers"], ssm_stack, kv_stack, lora_stack)
        x, ys = _scan_stack(group_fn, x, xs, cfg, mode)
        if cache is not None and ys is not None:
            (conv_s, state_s), kv_ys = ys
            new_cache["conv"], new_cache["state"] = conv_s, state_s
            new_cache["k"], new_cache["v"] = kv_ys
    else:
        raise ValueError(cfg.family)

    if new_cache is not None:
        new_cache["index"] = index + S
    return x, new_cache, aux


def _forward_audio(params, cfg, *, tokens, frames, mode, cache, lp, ctx, aux):
    """whisper-style: encoder over frames, decoder over tokens w/ cross-attn."""
    index = cache["index"] if cache is not None else jnp.zeros((), jnp.int32)
    new_cache = dict(cache) if cache is not None else None
    enc_lp = lp.get("enc_layers")

    memory = None
    if frames is not None:
        h = frames
        pos_e = jnp.arange(h.shape[1], dtype=jnp.int32)

        def enc_fn(x, xs):
            p_l, lora_l = xs
            x, _ = _dense_block(p_l, x, cfg, positions=pos_e, mode="train",
                                kv=None, lora_ctx=ctx(lora_l), causal=False)
            return x, None

        xs = (params["enc_layers"], enc_lp)
        h, _ = _scan_stack(enc_fn, h, xs, cfg, mode)
        memory = rms_norm(h, params["enc_norm"], cfg.norm_eps)

    x = embed_tokens(params["embed"], tokens)
    B, S, _ = x.shape
    positions = index + jnp.arange(S, dtype=jnp.int32)

    def dec_fn(x, xs):
        p_l, kv_l, xkv_l, lora_l = xs
        kv = _kv_of(cache, mode, kv_l, index)
        h, new_kv = attention_fwd(p_l["attn"],
                                  rms_norm(x, p_l["ln1"], cfg.norm_eps), cfg,
                                  positions=positions, mode=mode, cache=kv,
                                  lora_ctx=ctx(lora_l))
        x = x + h
        xin = rms_norm(x, p_l["lnx"], cfg.norm_eps)
        if mode == "decode":
            xk, xv = xkv_l
            q = jnp.einsum("bsd,dhk->bshk", xin, p_l["xattn"]["wq"])
            from repro.models.layers import naive_attention
            o = naive_attention(q, xk, xv, causal=False)
            h2 = jnp.einsum("bshk,hkd->bsd", o, p_l["xattn"]["wo"])
            new_xkv = None
        else:
            h2 = cross_attention_fwd(p_l["xattn"], xin, memory, cfg,
                                     lora_ctx=ctx(lora_l))
            new_xkv = (jnp.einsum("bsd,dhk->bshk", memory, p_l["xattn"]["wk"]),
                       jnp.einsum("bsd,dhk->bshk", memory, p_l["xattn"]["wv"])) \
                if mode == "prefill" else None
        x = x + h2
        x = x + mlp_fwd(p_l["mlp"], rms_norm(x, p_l["ln2"], cfg.norm_eps))
        ys = ((new_kv.k, new_kv.v) if new_kv is not None else None, new_xkv)
        return x, ys

    kv_stack = (cache["k"], cache["v"]) if cache is not None else None
    xkv_stack = ((cache["cross_k"], cache["cross_v"])
                 if (cache is not None and mode == "decode") else None)
    xs = (params["layers"], kv_stack, xkv_stack, lp.get("layers"))
    x, ys = _scan_stack(dec_fn, x, xs, cfg, mode)
    if cache is not None and ys is not None:
        kv_ys, xkv_ys = ys
        if kv_ys is not None:
            new_cache["k"], new_cache["v"] = kv_ys
        if xkv_ys is not None and mode == "prefill":
            new_cache["cross_k"], new_cache["cross_v"] = xkv_ys
        new_cache["index"] = index + S
    return x, new_cache, aux


# ---------------------------------------------------------------------------
# scan plumbing helpers
# ---------------------------------------------------------------------------


def _ssm_cache_of(c_l, index):
    conv, state = c_l
    return SSMCache(conv=conv, state=state, index=index)


# ---------------------------------------------------------------------------
# public steps
# ---------------------------------------------------------------------------


def lm_loss(params: Dict, batch: Dict, cfg: ModelConfig,
            lora_params: Optional[Dict] = None,
            lora_ctx_proto=None,
            aux_weight: float = 0.01) -> Array:
    h, _, aux = forward(params, cfg, tokens=batch.get("tokens"),
                        patches=batch.get("patches"),
                        frames=batch.get("frames"), mode="train",
                        lora_params=lora_params, lora_ctx_proto=lora_ctx_proto)
    targets = batch["targets"]
    if cfg.family == "vlm" and batch.get("patches") is not None:
        h = h[:, batch["patches"].shape[1]:]
    loss = cross_entropy(params["embed"], h, targets, cfg,
                         mask=batch.get("loss_mask"))
    return loss + aux_weight * aux


def prefill(params: Dict, batch: Dict, cfg: ModelConfig, cache: Dict,
            lora_params=None, lora_ctx_proto=None) -> Tuple[Array, Dict]:
    h, new_cache, _ = forward(params, cfg, tokens=batch.get("tokens"),
                              patches=batch.get("patches"),
                              frames=batch.get("frames"), mode="prefill",
                              cache=cache, lora_params=lora_params,
                              lora_ctx_proto=lora_ctx_proto)
    logits = logits_fwd(params["embed"], h[:, -1:], cfg)
    return logits, new_cache


def decode_step(params: Dict, tokens: Array, cfg: ModelConfig, cache: Dict,
                lora_params=None, lora_ctx_proto=None) -> Tuple[Array, Dict]:
    h, new_cache, _ = forward(params, cfg, tokens=tokens, mode="decode",
                              cache=cache, lora_params=lora_params,
                              lora_ctx_proto=lora_ctx_proto)
    logits = logits_fwd(params["embed"], h, cfg)
    return logits, new_cache
