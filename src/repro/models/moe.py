"""Mixture-of-Experts block: shared experts + routed top-k experts.

Implementations:

- ``dense``:  every expert processes every token, masked combine.  Exact
  oracle; used for CPU smoke tests and as the correctness reference for the
  distributed paths (tiny configs only — compute is O(E) per token).
- ``ep``:     shard_map expert-parallel production path.  Router runs in
  plain SPMD; dispatch/compute/combine run per-device with static capacity
  buffers; partial outputs are summed with a ``psum`` over the model axis.
  Works with expert-sharded weights when ``E % model == 0`` (deepseek) and
  falls back to ff-sharded weights otherwise (granite's 40 experts on a
  16-way axis).  An all-to-all variant is a recorded §Perf hillclimb.

Token dropping follows the standard static-capacity discipline
(capacity_factor in the config); dropped tokens fall through on the residual.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.compat import shard_map
from repro.configs.base import ModelConfig
from repro.distributed.sharding import constrain, current_mesh
from repro.models.param import ParamDef
from repro.models.layers import mlp_defs, mlp_fwd

Array = jax.Array


def moe_defs(cfg: ModelConfig) -> Dict:
    d = cfg.d_model
    m = cfg.moe
    E, f = m.num_experts, m.d_ff_expert
    defs = {
        "router": ParamDef((d, E), ("d_model", "experts"), scale=0.02),
        "w_gate": ParamDef((E, d, f), ("experts", "d_model", "expert_ff")),
        "w_up": ParamDef((E, d, f), ("experts", "d_model", "expert_ff")),
        "w_down": ParamDef((E, f, d), ("experts", "expert_ff", "d_model")),
    }
    if m.num_shared:
        defs["shared"] = mlp_defs(d, m.num_shared * f)
    return defs


def _route(p: Dict, x: Array, cfg: ModelConfig) -> Tuple[Array, Array, Array]:
    """Router in fp32: returns (topw (T,k), topi (T,k), aux_loss scalar)."""
    m = cfg.moe
    logits = jnp.einsum("td,de->te", x.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    topw, topi = jax.lax.top_k(probs, m.top_k)
    topw = topw / jnp.maximum(topw.sum(-1, keepdims=True), 1e-9)
    # switch-style load-balancing aux loss
    E = m.num_experts
    dispatch = jax.nn.one_hot(topi[:, 0], E, dtype=jnp.float32)
    f_e = dispatch.mean(0)
    p_e = probs.mean(0)
    aux = E * jnp.sum(f_e * p_e)
    return topw, topi.astype(jnp.int32), aux


# ---------------------------------------------------------------------------
# dense oracle
# ---------------------------------------------------------------------------


def _moe_dense(p: Dict, x: Array, topw: Array, topi: Array, cfg: ModelConfig
               ) -> Array:
    """(T, d) tokens; computes every expert then combines.  Oracle only."""
    m = cfg.moe
    E = m.num_experts
    g = jnp.einsum("td,edf->tef", x, p["w_gate"])
    u = jnp.einsum("td,edf->tef", x, p["w_up"])
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    y_all = jnp.einsum("tef,efd->ted", h, p["w_down"])   # (T, E, d)
    w_full = jnp.zeros((x.shape[0], E), x.dtype)
    w_full = w_full.at[jnp.arange(x.shape[0])[:, None], topi].set(
        topw.astype(x.dtype))
    return jnp.einsum("ted,te->td", y_all, w_full)


# ---------------------------------------------------------------------------
# static-capacity dispatch/combine (per-device, local shapes)
# ---------------------------------------------------------------------------


def _dispatch(x: Array, topi: Array, capacity: int, n_buckets: int,
              bucket_offset: int = 0) -> Tuple[Array, Array, Array, Array]:
    """Scatter tokens into (n_buckets, capacity, d) by expert choice.

    Only choices with bucket id in [bucket_offset, bucket_offset+n_buckets)
    participate; everything else lands in trash rows/slots that get sliced
    off.  Returns (buf, eid, slot, valid) where eid/slot/valid are per-choice
    (T*k,) in the ORIGINAL choice order (for combine).
    """
    T, k = topi.shape
    d = x.shape[-1]
    flat = topi.reshape(-1) - bucket_offset
    inside = (flat >= 0) & (flat < n_buckets)
    eid = jnp.where(inside, flat, n_buckets)             # trash bucket id
    order = jnp.argsort(eid, stable=True)
    sorted_e = eid[order]
    counts = jnp.bincount(eid, length=n_buckets + 1)
    starts = jnp.cumsum(counts) - counts
    pos = jnp.arange(T * k) - starts[sorted_e]
    slot_sorted = jnp.where((pos < capacity) & (sorted_e < n_buckets),
                            pos, capacity)               # trash slot
    buf = jnp.zeros((n_buckets + 1, capacity + 1, d), x.dtype)
    buf = buf.at[sorted_e, slot_sorted].set(x[order // k])
    # per-choice mapping back in original order
    inv = jnp.zeros_like(order).at[order].set(jnp.arange(T * k))
    slot = slot_sorted[inv]
    valid = (slot < capacity) & inside
    return buf[:n_buckets, :capacity], eid, slot, valid


def _combine(y_buf: Array, eid: Array, slot: Array, valid: Array,
             topw: Array) -> Array:
    """Gather per-choice outputs and sum weighted over k."""
    T, k = topw.shape
    n_buckets, capacity, d = y_buf.shape
    e = jnp.minimum(eid, n_buckets - 1)
    s = jnp.minimum(slot, capacity - 1)
    y = y_buf[e, s] * valid[:, None].astype(y_buf.dtype)
    y = y.reshape(T, k, d) * topw[..., None].astype(y_buf.dtype)
    return y.sum(axis=1)


def _expert_ffn(buf: Array, w_gate: Array, w_up: Array, w_down: Array) -> Array:
    """(E_loc, C, d) x per-expert weights -> (E_loc, C, d)."""
    g = jnp.einsum("ecd,edf->ecf", buf, w_gate)
    u = jnp.einsum("ecd,edf->ecf", buf, w_up)
    h = jax.nn.silu(g.astype(jnp.float32)).astype(buf.dtype) * u
    return jnp.einsum("ecf,efd->ecd", h, w_down)


# ---------------------------------------------------------------------------
# shard_map expert-parallel path
# ---------------------------------------------------------------------------


def _moe_ep(p: Dict, x: Array, topw: Array, topi: Array, cfg: ModelConfig,
            mesh) -> Array:
    """Expert-parallel MoE via shard_map + psum over the model axis."""
    from jax.sharding import PartitionSpec as P

    m = cfg.moe
    E = m.num_experts
    M = mesh.shape.get("model", 1)
    batch_axes = tuple(n for n in mesh.axis_names if n != "model")
    expert_sharded = (E % M == 0) and M > 1
    E_loc = E // M if expert_sharded else E
    T = x.shape[0]
    n_batch_shards = 1
    for n in batch_axes:
        n_batch_shards *= mesh.shape[n]
    T_loc = max(T // max(n_batch_shards, 1), 1)
    capacity = max(int(T_loc * m.top_k / E * m.capacity_factor) + 1, 4)

    bd = P(batch_axes if len(batch_axes) > 1 else batch_axes[0])
    w_spec = P("model") if expert_sharded else P(None, None, "model")
    w_down_spec = P("model") if expert_sharded else P(None, "model", None)

    def body(x_l, topw_l, topi_l, wg, wu, wd):
        if expert_sharded:
            ridx = jax.lax.axis_index("model")
            offset = ridx * E_loc
        else:
            offset = 0
        buf, eid, slot, valid = _dispatch(x_l, topi_l, capacity, E_loc, offset)
        y_buf = _expert_ffn(buf, wg, wu, wd)
        y = _combine(y_buf, eid, slot, valid, topw_l)
        return jax.lax.psum(y, "model")

    return shard_map(
        body, mesh=mesh,
        in_specs=(P(bd[0], None), P(bd[0], None), P(bd[0], None),
                  w_spec, w_spec, w_down_spec),
        out_specs=P(bd[0], None),
    )(x, topw, topi, p["w_gate"], p["w_up"], p["w_down"])


def moe_fwd(p: Dict, x: Array, cfg: ModelConfig) -> Tuple[Array, Array]:
    """Full MoE layer on (B, S, d).  Returns (y, aux_loss)."""
    B, S, d = x.shape
    m = cfg.moe
    xt = x.reshape(B * S, d)
    topw, topi, aux = _route(p, xt, cfg)
    mesh = current_mesh()
    if mesh is not None and "model" in mesh.axis_names:
        y = _moe_ep(p, xt, topw, topi, cfg, mesh)
    else:
        y = _moe_dense(p, xt, topw, topi, cfg)
    y = y.reshape(B, S, d)
    if m.num_shared:
        y = y + mlp_fwd(p["shared"], x)
    return constrain(y, "batch", "seq", "d_model"), aux
