"""LoRA application inside model layers — three modes:

- ``single``:  one adapter, training / single-tenant serving.
- ``batched``: uncompressed multi-LoRA serving; per-sequence adapter ids
  select (A_i, B_i) from stacked banks (the vLLM-multi-LoRA baseline).
- ``jd``:      compressed serving; shared (possibly clustered) bases U, V +
  per-adapter Sigma (the paper's method).

The jnp paths here gather per-*sequence* weights (ids are (B,)), which is
cheap.  The serving engine's flattened token path uses the Pallas kernels in
:mod:`repro.kernels` instead (per-token ids, tile-grouped).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.models.param import ParamDef

Array = jax.Array


@dataclasses.dataclass
class LoRAContext:
    mode: str                       # single | batched | jd
    params: Dict[str, Any]          # target -> arrays
    ids: Optional[Array] = None     # (B,) adapter id per sequence
    scaling: float = 1.0


jax.tree_util.register_dataclass(
    LoRAContext, data_fields=["params", "ids"], meta_fields=["mode", "scaling"])


def single_lora_defs(d_in: int, d_out: int, rank: int) -> Dict:
    return {
        "a": ParamDef((rank, d_in), ("rank", "d_model"), scale=0.02),
        "b": ParamDef((d_out, rank), (None, "rank"), init="zeros"),
    }


def target_dims(cfg, target: str) -> tuple:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    H, Kv = cfg.num_heads, cfg.num_kv_heads
    if target in ("q", "xq"):
        return d, H * hd
    if target in ("k", "v", "xk", "xv"):
        return d, Kv * hd
    if target == "o":
        return H * hd, d
    if target == "ssm_in":
        s = cfg.ssm
        di = s.d_inner(d)
        return d, 2 * di + 2 * s.n_groups * s.d_state + s.n_heads(d)
    if target == "ssm_out":
        return cfg.ssm.d_inner(d), d
    raise ValueError(target)


def lora_layer_defs(cfg, targets=None) -> Dict:
    targets = targets or cfg.lora.targets
    return {t: single_lora_defs(*target_dims(cfg, t), cfg.lora.rank)
            for t in targets}


def apply(ctx: Optional[LoRAContext], target: str, x: Array, y: Array) -> Array:
    """y + scaled LoRA delta for `target`; no-op when absent."""
    if ctx is None or ctx.params is None or target not in ctx.params:
        return y
    p = ctx.params[target]
    if ctx.mode == "single":
        t = jnp.einsum("bsd,rd->bsr", x, p["a"].astype(x.dtype))
        delta = jnp.einsum("bsr,or->bso", t, p["b"].astype(x.dtype))
    elif ctx.mode == "batched":
        A = p["A"][ctx.ids].astype(x.dtype)        # (B, r, d_in)
        Bm = p["B"][ctx.ids].astype(x.dtype)       # (B, d_out, r)
        t = jnp.einsum("bsd,brd->bsr", x, A)
        delta = jnp.einsum("bsr,bor->bso", t, Bm)
    elif ctx.mode == "jd":
        cid = p["cluster_of"][ctx.ids]             # (B,)
        V = p["V"][cid].astype(x.dtype)            # (B, d_in, r)
        U = p["U"][cid].astype(x.dtype)            # (B, d_out, r)
        sig = p["sigma"][ctx.ids].astype(x.dtype)  # (B, r, r) or (B, r)
        t = jnp.einsum("bsd,bdr->bsr", x, V)
        if sig.ndim == 2:                          # JD-Diag
            t = t * sig[:, None, :]
        else:                                      # JD-Full
            t = jnp.einsum("bsr,brq->bsq", t, sig)
        delta = jnp.einsum("bsr,bor->bso", t, U)
    else:
        raise ValueError(ctx.mode)
    delta = (ctx.scaling * delta.astype(jnp.float32)).astype(y.dtype)
    return y + delta.reshape(y.shape)


def layer_slice(ctx: Optional[LoRAContext], layer_params) -> Optional[LoRAContext]:
    """Rebind a context to one layer's (scanned) adapter params."""
    if ctx is None or layer_params is None:
        return None
    return LoRAContext(mode=ctx.mode, params=layer_params, ids=ctx.ids,
                       scaling=ctx.scaling)
