"""Public model API: batch specs per (arch x shape) cell and step functions.

``input_specs(cfg, shape)`` returns ShapeDtypeStruct stand-ins for every
model input of that cell (weak-type-correct, shardable, no allocation) —
used by the multi-pod dry-run and the smoke tests alike.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import transformer as tf

Array = jax.Array


def batch_struct(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """ShapeDtypeStructs for the step input batch of this cell."""
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    bf16 = jnp.bfloat16

    def tok(b, s):
        return jax.ShapeDtypeStruct((b, s), i32)

    if shape.kind == "train":
        if cfg.family == "audio":
            return {"frames": jax.ShapeDtypeStruct((B, S, cfg.d_model), bf16),
                    "tokens": tok(B, S), "targets": tok(B, S)}
        if cfg.family == "vlm":
            npch = cfg.vlm.num_patches
            return {"tokens": tok(B, S - npch),
                    "patches": jax.ShapeDtypeStruct((B, npch, cfg.d_model), bf16),
                    "targets": tok(B, S - npch)}
        return {"tokens": tok(B, S), "targets": tok(B, S)}
    if shape.kind == "prefill":
        if cfg.family == "audio":
            return {"frames": jax.ShapeDtypeStruct((B, S, cfg.d_model), bf16),
                    "tokens": tok(B, S)}
        if cfg.family == "vlm":
            npch = cfg.vlm.num_patches
            return {"tokens": tok(B, S - npch),
                    "patches": jax.ShapeDtypeStruct((B, npch, cfg.d_model), bf16)}
        return {"tokens": tok(B, S)}
    # decode: one new token against a cache of seq_len
    return {"tokens": tok(B, 1)}


def cache_struct(cfg: ModelConfig, shape: ShapeConfig,
                 abstract: bool = True) -> Optional[Dict]:
    if shape.kind == "train":
        return None
    B, S = shape.global_batch, shape.seq_len
    enc_len = S if cfg.family == "audio" else 0
    return tf.init_cache(cfg, B, S, enc_len=enc_len, abstract=abstract)


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """All step inputs (batch + cache when applicable) as structs."""
    out = {"batch": batch_struct(cfg, shape)}
    c = cache_struct(cfg, shape)
    if c is not None:
        out["cache"] = c
    return out


# ---------------------------------------------------------------------------
# step functions (model-only; training step w/ optimizer lives in training/)
# ---------------------------------------------------------------------------


def make_loss_fn(cfg: ModelConfig):
    def loss_fn(params, batch, lora_params=None, lora_ctx_proto=None):
        return tf.lm_loss(params, batch, cfg, lora_params=lora_params,
                          lora_ctx_proto=lora_ctx_proto)
    return loss_fn


def make_prefill_fn(cfg: ModelConfig):
    def prefill_fn(params, batch, cache, lora_params=None, lora_ctx_proto=None):
        return tf.prefill(params, batch, cfg, cache, lora_params=lora_params,
                          lora_ctx_proto=lora_ctx_proto)
    return prefill_fn


def make_decode_fn(cfg: ModelConfig):
    def decode_fn(params, batch, cache, lora_params=None, lora_ctx_proto=None):
        return tf.decode_step(params, batch["tokens"], cfg, cache,
                              lora_params=lora_params,
                              lora_ctx_proto=lora_ctx_proto)
    return decode_fn


def step_fn_for(cfg: ModelConfig, shape: ShapeConfig, with_opt: bool = True):
    """The function a dry-run lowers for this cell.

    train cells lower a full train_step (fwd+bwd+AdamW update) built by
    repro.training; prefill/decode cells lower the serve step."""
    if shape.kind == "train":
        from repro.training.step import make_train_step
        return make_train_step(cfg, with_opt=with_opt)
    if shape.kind == "prefill":
        return make_prefill_fn(cfg)
    return make_decode_fn(cfg)
