"""Distributed attention collectives.

``seq_sharded_decode_attention``: FlashDecoding-style decode over a KV cache
whose *sequence* dimension is sharded across the model axis (the layout the
framework falls back to when KV heads don't divide the TP degree — most GQA
archs at TP16).  Each shard computes partial attention over its KV slice with
online-softmax stats (m, l, o); shards merge with pmax/psum instead of
all-gathering the cache.  Beyond-paper optimization recorded in §Perf.

On TPU the per-shard inner loop is `kernels/flash_decode.py`; the jnp path
below is used on CPU and in the dry-run.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map

Array = jax.Array
NEG_INF = -1e30


def _partial_decode(q, k, v, start, kv_len):
    """Partial attention over a KV slice.  q: (B,1,H,hd); k/v: (B,S_loc,Kv,hd);
    global positions are start + arange(S_loc); valid when < kv_len.
    Returns (o (B,Kv,G,hd), l (B,Kv,G), m (B,Kv,G)) in fp32."""
    B, _, H, hd = q.shape
    S, Kv = k.shape[1], k.shape[2]
    G = H // Kv
    qg = q[:, 0].reshape(B, Kv, G, hd).astype(jnp.float32) * (hd ** -0.5)
    logits = jnp.einsum("bkgh,bskh->bkgs", qg, k.astype(jnp.float32))
    pos = start + jnp.arange(S)
    valid = pos[None, :] < jnp.reshape(kv_len, (-1, 1))
    logits = jnp.where(valid[:, None, None, :], logits, NEG_INF)
    m = logits.max(axis=-1)
    p = jnp.exp(logits - m[..., None])
    p = jnp.where(valid[:, None, None, :], p, 0.0)
    l = p.sum(axis=-1)
    o = jnp.einsum("bkgs,bskh->bkgh", p, v.astype(jnp.float32))
    return o, l, m


def seq_sharded_decode_attention(q: Array, keys: Array, vals: Array,
                                 kv_len: Array, mesh,
                                 axis: str = "model") -> Array:
    """q: (B,1,H,hd) replicated over `axis`; keys/vals: (B,S,Kv,hd) sharded on
    S over `axis`; kv_len: (B,).  Returns (B,1,H,hd)."""
    B, _, H, hd = q.shape
    Kv = keys.shape[2]
    batch_axes = tuple(n for n in mesh.axis_names if n != axis)
    bspec = batch_axes if len(batch_axes) > 1 else (
        batch_axes[0] if batch_axes else None)
    n_b = 1
    for a in batch_axes:
        n_b *= mesh.shape[a]
    if n_b <= 1 or B % n_b:
        bspec = None

    def body(q_l, k_l, v_l, kvl_l):
        r = jax.lax.axis_index(axis)
        S_loc = k_l.shape[1]
        o, l, m = _partial_decode(q_l, k_l, v_l, r * S_loc, kvl_l)
        m_g = jax.lax.pmax(m, axis)
        w = jnp.exp(m - m_g) * l                      # (B,Kv,G)
        o_sum = jax.lax.psum(o * w[..., None], axis)
        l_sum = jax.lax.psum(w, axis)
        out = o_sum / jnp.maximum(l_sum, 1e-30)[..., None]
        return out.reshape(q_l.shape[0], 1, H, hd).astype(q_l.dtype)

    return shard_map(
        body, mesh=mesh,
        in_specs=(P(bspec, None, None, None), P(bspec, axis, None, None),
                  P(bspec, axis, None, None), P(bspec)),
        out_specs=P(bspec, None, None, None))(q, keys, vals, kv_len)


def seq_sharded_decode_step(q: Array, cache_k: Array, cache_v: Array,
                            k_new: Array, v_new: Array, idx: Array,
                            mesh, axis: str = "model"):
    """Fused cache-update + partial attention + softmax merge, all inside one
    shard_map so the S-sharded cache never gets resharded (the baseline's
    'involuntary full rematerialization' f32 copies — §Perf cell 3).

    q/k_new/v_new: (B,1,H|Kv,hd) replicated over `axis`; cache_k/v:
    (B,S,Kv,hd) sharded on S; idx: (B,) or scalar current lengths.
    Returns (out (B,1,H,hd), new_cache_k, new_cache_v)."""
    B, _, H, hd = q.shape
    batch_axes = tuple(n for n in mesh.axis_names if n != axis)
    bspec = batch_axes if len(batch_axes) > 1 else (
        batch_axes[0] if batch_axes else None)
    n_b = 1
    for a in batch_axes:
        n_b *= mesh.shape[a]
    if n_b <= 1 or B % n_b:
        bspec = None
    idx_vec = idx if jnp.ndim(idx) == 1 else jnp.full((B,), idx, jnp.int32)

    def body(q_l, ck, cv, kn, vn, idx_l):
        r = jax.lax.axis_index(axis)
        Bl, S_loc = ck.shape[0], ck.shape[1]
        start = r * S_loc
        pos = idx_l - start                              # (B,) local write pos
        ok = (pos >= 0) & (pos < S_loc)
        safe = jnp.clip(pos, 0, S_loc - 1)
        rows = jnp.arange(Bl)
        old_k = ck[rows, safe]
        old_v = cv[rows, safe]
        k_w = jnp.where(ok[:, None, None], kn[:, 0].astype(ck.dtype), old_k)
        v_w = jnp.where(ok[:, None, None], vn[:, 0].astype(cv.dtype), old_v)
        ck = ck.at[rows, safe].set(k_w)
        cv = cv.at[rows, safe].set(v_w)
        o, l, m = _partial_decode(q_l, ck, cv, start, idx_l + 1)
        m_g = jax.lax.pmax(m, axis)
        w = jnp.exp(m - m_g) * l
        o_sum = jax.lax.psum(o * w[..., None], axis)
        l_sum = jax.lax.psum(w, axis)
        out = o_sum / jnp.maximum(l_sum, 1e-30)[..., None]
        return out.reshape(Bl, 1, H, hd).astype(q_l.dtype), ck, cv

    return shard_map(
        body, mesh=mesh,
        in_specs=(P(bspec, None, None, None), P(bspec, axis, None, None),
                  P(bspec, axis, None, None), P(bspec, None, None, None),
                  P(bspec, None, None, None), P(bspec)),
        out_specs=(P(bspec, None, None, None), P(bspec, axis, None, None),
                   P(bspec, axis, None, None)))(
        q, cache_k, cache_v, k_new, v_new, idx_vec)
