"""Logical-axis sharding rules (MaxText-style) with automatic divisibility
fallback.

Every parameter / activation dimension carries a *logical* axis name; rules
map logical names to mesh axis names.  If a dimension is not divisible by the
product of its mapped mesh axes, the mapping silently falls back to
replication for that dimension (e.g. granite's 24 heads or 8 KV heads on a
16-way model axis).  This keeps one rule table valid across all 10 archs.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

AxisName = Union[str, Tuple[str, ...], None]

# logical axis -> mesh axes (tuple = sharded over multiple mesh axes)
DEFAULT_RULES: Dict[str, AxisName] = {
    "batch": ("pod", "data"),
    "seq": None,
    "seq_sp": "model",        # Megatron-SP: layer-boundary activations seq-sharded
    "kv_seq": "model",        # KV-cache sequence dim (used when kv heads don't divide)
    "d_model": None,
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "d_ff": "model",
    "experts": "model",
    "expert_ff": "model",     # claims model ONLY if experts could not (spec_for order)
    "vocab": "model",
    "ssm_heads": "model",
    "ssm_state": None,
    "ssm_groups": None,
    "conv_k": None,
    "layers": None,           # scan axis
    "rank": None,             # LoRA / JD rank
    "adapters": None,
    "clusters": None,
    "stats": None,
}


class _Ctx(threading.local):
    def __init__(self):
        self.mesh: Optional[Mesh] = None
        self.rules: Dict[str, AxisName] = dict(DEFAULT_RULES)


_CTX = _Ctx()


@contextlib.contextmanager
def use_mesh(mesh: Optional[Mesh], rules: Optional[Dict[str, AxisName]] = None):
    """Activate a mesh + rules for spec resolution and constraints."""
    prev = (_CTX.mesh, _CTX.rules)
    _CTX.mesh = mesh
    if rules is not None:
        _CTX.rules = {**DEFAULT_RULES, **rules}
    try:
        if mesh is not None:
            with mesh:
                yield
        else:
            yield
    finally:
        _CTX.mesh, _CTX.rules = prev


def current_mesh() -> Optional[Mesh]:
    return _CTX.mesh


def _mesh_axis_size(mesh: Mesh, name: AxisName) -> int:
    if name is None:
        return 1
    if isinstance(name, tuple):
        out = 1
        for n in name:
            out *= _mesh_axis_size(mesh, n)
        return out
    return mesh.shape.get(name, 1)


def _resolve_axis(mesh: Mesh, rules, logical: Optional[str], dim: int) -> AxisName:
    if logical is None:
        return None
    mapped = rules.get(logical)
    if mapped is None:
        return None
    # drop mesh axes absent from this mesh (e.g. "pod" on single-pod)
    if isinstance(mapped, tuple):
        mapped = tuple(m for m in mapped if m in mesh.shape)
        if not mapped:
            return None
        if len(mapped) == 1:
            mapped = mapped[0]
    elif mapped not in mesh.shape:
        return None
    size = _mesh_axis_size(mesh, mapped)
    if size <= 1 or dim % size != 0:
        return None  # divisibility fallback -> replicate
    return mapped


def spec_for(shape: Sequence[int], axes: Sequence[Optional[str]],
             mesh: Optional[Mesh] = None,
             rules: Optional[Dict[str, AxisName]] = None) -> P:
    """PartitionSpec for an array with given logical axes under a mesh."""
    mesh = mesh or _CTX.mesh
    rules = rules or _CTX.rules
    if mesh is None:
        return P()
    assert len(shape) == len(axes), (shape, axes)
    used = set()
    parts = []
    for dim, ax in zip(shape, axes):
        resolved = _resolve_axis(mesh, rules, ax, dim)
        # a mesh axis may appear at most once in a spec
        flat = (resolved,) if isinstance(resolved, str) else (resolved or ())
        if any(f in used for f in flat):
            resolved = None
        else:
            used.update(flat)
        parts.append(resolved)
    return P(*parts)


def sharding_for(shape, axes, mesh=None, rules=None) -> Optional[NamedSharding]:
    mesh = mesh or _CTX.mesh
    if mesh is None:
        return None
    return NamedSharding(mesh, spec_for(shape, axes, mesh, rules))


def constrain(x: jax.Array, *axes: Optional[str]) -> jax.Array:
    """with_sharding_constraint by logical axes; no-op without a mesh."""
    mesh = _CTX.mesh
    if mesh is None:
        return x
    spec = spec_for(x.shape, axes, mesh, _CTX.rules)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def batch_spec(mesh: Optional[Mesh] = None) -> P:
    """Spec for a (B, ...) input batch dim."""
    return spec_for((1 << 30,), ("batch",), mesh)  # huge dim => always divisible
