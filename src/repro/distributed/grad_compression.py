"""Compressed cross-replica gradient reduction (int8 ring emulation).

Used for the data-parallel all-reduce of LoRA-adapter gradients (the
training mode this paper cares about): adapters are small, but at 1000+
concurrent fine-tunes the aggregate DP traffic matters, and int8 is
standard practice (1-bit Adam / PowerSGD lineage — we implement the simple
deterministic int8 variant).

``compressed_psum`` must run inside shard_map with `axis_name` bound.  The
wire format is int8 chunks moved with all_to_all (reduce-scatter phase) and
all_gather (broadcast phase): 4x less traffic than fp32 psum, ~1e-2 relative
error (bounded by 2/127 per hop).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.compat import axis_size, shard_map

Array = jax.Array


def _quant(x: Array, scale: Array) -> Array:
    return jnp.clip(jnp.round(x / jnp.maximum(scale, 1e-30) * 127.0),
                    -127, 127).astype(jnp.int8)


def _dequant(q: Array, scale: Array) -> Array:
    return q.astype(jnp.float32) * scale / 127.0


def compressed_psum(x: Array, axis_name: str) -> Array:
    """int8 reduce-scatter + all-gather emulation of psum over axis_name."""
    g = axis_size(axis_name)
    if g == 1:
        return x
    shape = x.shape
    flat = x.reshape(-1).astype(jnp.float32)
    pad = (-flat.size) % g
    flat = jnp.pad(flat, (0, pad))
    chunks = flat.reshape(g, -1)
    # phase 1: shared scale (pmax keeps quantization consistent across peers)
    scale = jax.lax.pmax(jnp.max(jnp.abs(flat)), axis_name)
    q = _quant(chunks, scale)                              # (g, n/g) int8
    # reduce-scatter: everyone sends chunk j to peer j
    recv = jax.lax.all_to_all(q, axis_name, split_axis=0, concat_axis=0,
                              tiled=False)                 # (g, n/g) int8
    part = jnp.sum(_dequant(recv, scale), axis=0)          # my reduced chunk
    # broadcast phase: requantize the reduced chunk and all-gather
    scale2 = jax.lax.pmax(jnp.max(jnp.abs(part)), axis_name)
    q2 = _quant(part, scale2)
    full = jax.lax.all_gather(q2, axis_name, axis=0, tiled=False)  # (g, n/g)
    out = _dequant(full.reshape(-1), scale2)
    if pad:
        out = out[:-pad]
    return out.reshape(shape).astype(x.dtype)


def compressed_psum_tree(tree: Any, axis_name: str) -> Any:
    return jax.tree.map(lambda x: compressed_psum(x, axis_name), tree)


def make_compressed_dp_allreduce(mesh, axes=("pod", "data")):
    """shard_map wrapper reducing a (replicated-over-dp) gradient tree with
    int8 traffic.  Grads enter sharded over their natural spec; we reduce
    over the dp axes only."""
    from jax.sharding import PartitionSpec as P
    names = tuple(a for a in axes if a in mesh.shape)
    if not names:
        return lambda tree: tree

    def reducer(tree):
        def body(t):
            out = t
            for a in names:
                out = jax.tree.map(
                    lambda x: compressed_psum(x, a) / axis_size(a),
                    out)
            return out

        return shard_map(body, mesh=mesh,
                             in_specs=P(*names), out_specs=P(*names))(tree)

    return reducer
