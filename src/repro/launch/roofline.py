"""Roofline term extraction from compiled dry-run artifacts.

Hardware model (TPU v5e): 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
``cost_analysis()`` on an SPMD-partitioned module reports **per-device**
FLOPs / bytes (verified against a known matmul); collective bytes are parsed
from the post-SPMD HLO text with per-op ring-cost factors.
"""
from __future__ import annotations

import dataclasses
import re
from collections import Counter
from typing import Dict

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes / s / chip
ICI_BW = 50e9                # bytes / s / link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# ring-model bytes moved per device, as a multiple of the RESULT bytes
# (g = replica-group size)
def _ring_factor(op: str, g: int) -> float:
    if g <= 1:
        return 0.0
    if op == "all-gather":
        return (g - 1) / g            # result is the gathered tensor
    if op == "reduce-scatter":
        return float(g - 1)           # result is the scattered piece
    if op == "all-reduce":
        return 2.0 * (g - 1) / g
    if op == "all-to-all":
        return (g - 1) / g
    if op == "collective-permute":
        return 1.0
    return 1.0


_SHAPE_RE = re.compile(r"\b([a-z]+[0-9]+|pred)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")
_GROUPS_V2_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _result_bytes(line: str) -> int:
    """Sum bytes of all result shapes on an HLO instruction line (handles
    tuple results; only looks left of the op name occurrence)."""
    # restrict to result type: text between '=' and the op name
    m = re.search(r"=\s*(.*?)\s+(" + "|".join(_COLLECTIVES) + r")", line)
    if not m:
        return 0
    out = 0
    for dt, dims in _SHAPE_RE.findall(m.group(1)):
        b = _DTYPE_BYTES.get(dt, 4)
        n = 1
        for d in dims.split(","):
            if d.strip():
                n *= int(d)
        out += n * b
    return out


def _group_size(line: str) -> int:
    m = _GROUPS_V2_RE.search(line)
    if m:                              # replica_groups=[ngroups,gsize]
        return int(m.group(2))
    m = _GROUPS_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return 2


def collective_stats(hlo_text: str) -> Dict:
    """Per-device collective traffic from post-SPMD HLO."""
    counts: Counter = Counter()
    bytes_moved = 0.0
    bytes_result = 0
    for line in hlo_text.splitlines():
        stripped = line.strip()
        op = None
        for c in _COLLECTIVES:
            if re.search(r"\b" + c + r"(-start|-done)?\(", stripped):
                op = c
                break
        if op is None or stripped.startswith("ROOT tuple") or \
                re.search(r"\b" + op + r"-done\(", stripped):
            continue
        rb = _result_bytes(stripped)
        if rb == 0:
            continue
        g = _group_size(stripped)
        counts[op] += 1
        bytes_result += rb
        bytes_moved += rb * _ring_factor(op, g)
    return {"counts": dict(counts), "bytes_result": int(bytes_result),
            "bytes_moved": float(bytes_moved)}


@dataclasses.dataclass
class Roofline:
    flops_per_dev: float
    hbm_bytes_per_dev: float
    coll_bytes_per_dev: float
    n_devices: int
    model_flops: float           # analytic useful FLOPs (global)

    @property
    def t_compute(self) -> float:
        return self.flops_per_dev / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes_per_dev / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes_per_dev / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        tot = self.flops_per_dev * self.n_devices
        return self.model_flops / tot if tot else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the peak-compute roofline achieved if the step ran at
        the max of the three terms: t_ideal_compute / t_bound."""
        t_ideal = self.model_flops / (self.n_devices * PEAK_FLOPS)
        t_bound = max(self.t_compute, self.t_memory, self.t_collective)
        return t_ideal / t_bound if t_bound else 0.0

    def to_dict(self) -> Dict:
        return {
            "flops_per_dev": self.flops_per_dev,
            "hbm_bytes_per_dev": self.hbm_bytes_per_dev,
            "coll_bytes_per_dev": self.coll_bytes_per_dev,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "model_flops": self.model_flops,
            "useful_flops_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
        }


def model_flops_for(cfg, shape) -> float:
    """Analytic MODEL_FLOPS: 6ND train, 2ND prefill, 2·N_active·B decode
    (+ KV attention read FLOPs for decode)."""
    n_active = cfg.active_param_count()
    tokens = shape.global_batch * shape.seq_len
    if shape.kind == "train":
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        return 2.0 * n_active * tokens
    # decode: one token per sequence + attention over the cache
    flops = 2.0 * n_active * shape.global_batch
    if cfg.family in ("dense", "vlm", "moe", "audio"):
        hd = cfg.resolved_head_dim
        layers = cfg.num_layers
        flops += (4.0 * cfg.num_heads * hd * shape.seq_len
                  * shape.global_batch * layers)
    return flops
