"""Serving launcher: multi-LoRA continuous-batching server (real model or
cost-model simulation).

  # paper-style throughput study (simulated clock, v5e cost model)
  PYTHONPATH=src python -m repro.launch.serve --arch mistral-7b \\
      --study 4,64,256,1024 --requests 500

  # real reduced-model serving on CPU
  PYTHONPATH=src python -m repro.launch.serve --arch mistral-7b --smoke \\
      --real --adapters 8 --requests 24
"""
from __future__ import annotations

import argparse
import json

import jax
import jax.numpy as jnp

from repro.configs import get_config, smoke_config
from repro.serving.engine import EngineConfig, ServingEngine
from repro.serving.scheduler import SchedulerConfig
from repro.serving.simulator import WorkloadConfig, make_workload, \
    run_throughput_study


def run_real(cfg, n_adapters: int, n_requests: int, mode: str = "jd",
             max_batch: int = 8, seed: int = 0,
             decode_path: str = "unfused") -> dict:
    """Real execution path: random adapters (paper §6.4 simulates random
    LoRAs for throughput), real prefill/decode with batched adapter math.
    ``decode_path`` selects the executor's decode step ("unfused" keeps the
    baseline-bit-exact generic path; "fused"/"fused_q8" run the one-pass
    kernel of `kernels/fused_decode.py`); the fused paths add an "o" target
    so the fused epilogue has an output delta to apply."""
    from repro.models import transformer as tf
    from repro.models.param import init_params
    from repro.serving.real_executor import RealModelExecutor

    defs = tf.model_defs(cfg)
    params = init_params(defs, jax.random.PRNGKey(seed))
    key = jax.random.PRNGKey(seed + 1)
    r = cfg.lora.rank
    d = cfg.d_model
    hd = cfg.resolved_head_dim
    L = cfg.num_layers

    bundles = {"layers": {}}
    dims = {"q": (d, cfg.num_heads * hd), "k": (d, cfg.num_kv_heads * hd),
            "v": (d, cfg.num_kv_heads * hd)}
    if decode_path != "unfused":
        dims["o"] = (cfg.num_heads * hd, d)
    for tname, (di, do) in dims.items():
        ka, kb = jax.random.split(jax.random.fold_in(key, hash(tname) % 97))
        if mode == "lora":
            bundles["layers"][tname] = {
                "A": jax.random.normal(ka, (L, n_adapters, r, di),
                                       jnp.bfloat16) * 0.02,
                "B": jax.random.normal(kb, (L, n_adapters, do, r),
                                       jnp.bfloat16) * 0.02}
        else:
            k_cl = 1
            bundles["layers"][tname] = {
                "U": jax.random.normal(ka, (L, k_cl, do, r), jnp.bfloat16) * 0.02,
                "V": jax.random.normal(kb, (L, k_cl, di, r), jnp.bfloat16) * 0.02,
                "sigma": jax.random.normal(ka, (L, n_adapters, r, r),
                                           jnp.bfloat16) * 0.1,
                "cluster_of": jnp.zeros((L, n_adapters), jnp.int32)}

    s_max = 160
    ex = RealModelExecutor(cfg, params, bundles, mode, max_batch, s_max,
                           decode_path=decode_path)
    eng = ServingEngine(EngineConfig(
        scheduler=SchedulerConfig(max_batch=max_batch),
        adapter_budget_bytes=1e12, mode="lora",
        decode_path=decode_path), ex)
    wl = WorkloadConfig(n_requests=n_requests, n_adapters=n_adapters,
                        prompt_len_mean=24, prompt_len_std=4, new_tokens=8)
    def _release(req):
        ex.release(req.rid)

    eng.on_finish = _release
    eng.submit(make_workload(wl))
    stats = eng.run()
    return stats.to_dict()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--study", default=None,
                    help="comma list of adapter counts for the Fig-1 study")
    ap.add_argument("--requests", type=int, default=500)
    ap.add_argument("--real", action="store_true")
    ap.add_argument("--adapters", type=int, default=8)
    ap.add_argument("--mode", default="jd", choices=["jd", "lora"])
    ap.add_argument("--decode-path", default="unfused",
                    choices=["unfused", "fused", "fused_q8"])
    args = ap.parse_args()

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if args.study:
        ns = [int(x) for x in args.study.split(",")]
        rows = run_throughput_study(
            cfg, ns, WorkloadConfig(n_requests=args.requests))
        for r in rows:
            print(json.dumps(r, indent=None, default=str))
    elif args.real:
        out = run_real(cfg, args.adapters, args.requests, args.mode,
                       decode_path=args.decode_path)
        print(json.dumps(out, indent=2))


if __name__ == "__main__":
    main()
