"""Production meshes.

Functions (not module-level constants) so importing never touches jax device
state.  The dry-run forces 512 host devices via XLA_FLAGS before any import
(see dryrun.py); real deployments get the same shapes from the TPU topology.
"""
from __future__ import annotations

import jax


def make_mesh_compat(shape, axis_names):
    """jax.make_mesh across jax versions: ``axis_types`` (and
    ``jax.sharding.AxisType``) only exist in newer releases; older jax
    treats every axis as Auto by default, so omitting it is equivalent."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(shape, axis_names)
    return jax.make_mesh(shape, axis_names,
                         axis_types=(axis_type.Auto,) * len(axis_names))


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh_compat(shape, axes)


def make_mesh_for(n_devices: int, model_parallel: int = 1, pods: int = 1):
    """Elastic-scaling entry point: build the best mesh for the devices that
    are actually alive (used by repro.ft on restart after failures)."""
    assert n_devices % (model_parallel * pods) == 0, (n_devices, model_parallel, pods)
    data = n_devices // (model_parallel * pods)
    if pods > 1:
        return make_mesh_compat((pods, data, model_parallel),
                                ("pod", "data", "model"))
    return make_mesh_compat((data, model_parallel), ("data", "model"))


def mesh_description(mesh) -> dict:
    return {"axis_names": list(mesh.axis_names),
            "shape": [int(mesh.shape[a]) for a in mesh.axis_names],
            "n_devices": int(mesh.size)}
