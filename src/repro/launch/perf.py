import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=" + \
    os.environ.get("REPRO_DRYRUN_DEVICES", "512")
# (must precede any jax import — see dryrun.py)

import argparse          # noqa: E402
import json              # noqa: E402
import time              # noqa: E402
import traceback         # noqa: E402
from pathlib import Path  # noqa: E402

from repro.launch.dryrun import run_cell  # noqa: E402

RESULTS_DIR = Path(__file__).resolve().parents[3] / "results" / "perf"

# hillclimb variants: cell -> [(variant_name, cfg_overrides, n_micro)]
VARIANTS = {
    # most collective-bound cell: FSDP/SP gather traffic scales with the
    # grad-accumulation factor
    ("mistral-large-123b", "train_4k"): [
        ("v1_micro1", {}, 1),
        ("v2_micro2", {}, 2),
        ("v3_micro1_chunk4k", {"attn_chunk_q": 2048, "attn_chunk_kv": 4096}, 1),
        ("v4_micro4_chunk4k", {"attn_chunk_q": 2048, "attn_chunk_kv": 4096}, 4),
    ],
    # worst useful-flops cell: 24 heads don't shard at TP16 -> replicated
    # attention; context-parallel fallback shards it over sequence
    ("granite-moe-3b-a800m", "prefill_32k"): [
        ("v1_cp_attn", {"attn_cp_fallback": True}, None),
        ("v2_cp_attn_chunk4k", {"attn_cp_fallback": True,
                                "attn_chunk_q": 2048,
                                "attn_chunk_kv": 4096}, None),
    ],
    # paper-representative serving cell: seq-sharded KV decode without
    # gathering the cache (flash-decode partial-softmax merge)
    ("qwen3-32b", "decode_32k"): [
        ("v1_seqshard_decode", {"decode_attn": "seq_shard"}, None),
        ("v2_fused_seqshard", {"decode_attn": "seq_shard"}, None),
        ("v3_lazy_cache_write", {"decode_attn": "lazy"}, None),
    ],
    # lazy cache write applied to the other big decode cells
    ("qwen1.5-110b", "decode_32k"): [
        ("v3_lazy_cache_write", {"decode_attn": "lazy"}, None),
    ],
    ("mistral-large-123b", "decode_32k"): [
        ("v3_lazy_cache_write", {"decode_attn": "lazy"}, None),
    ],
}


def main():
    ap = argparse.ArgumentParser(description="perf hillclimb runner")
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--variant", default=None)
    ap.add_argument("--out", default=str(RESULTS_DIR))
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    out = Path(args.out)
    out.mkdir(parents=True, exist_ok=True)

    for (arch, shape), variants in VARIANTS.items():
        if args.arch and arch != args.arch:
            continue
        if args.shape and shape != args.shape:
            continue
        for name, overrides, n_micro in variants:
            if args.variant and name != args.variant:
                continue
            path = out / f"{arch}__{shape}__{name}.json"
            if path.exists() and not args.force:
                print(f"[skip-cached] {path.name}")
                continue
            print(f"[run] {arch} {shape} {name} ...", flush=True)
            t0 = time.time()
            try:
                rec = run_cell(arch, shape, multi_pod=False,
                               cfg_overrides=overrides,
                               n_micro_override=n_micro)
                rec["variant"] = name
                rec["overrides"] = {**overrides,
                                    **({"n_micro": n_micro} if n_micro else {})}
            except Exception as e:
                rec = {"arch": arch, "shape": shape, "variant": name,
                       "ok": False, "error": f"{type(e).__name__}: {e}",
                       "traceback": traceback.format_exc()[-3000:]}
            rec["wall_s"] = round(time.time() - t0, 2)
            path.write_text(json.dumps(rec, indent=2, default=str))
            status = "OK" if rec.get("ok") else "FAIL"
            extra = ""
            if rec.get("ok"):
                r = rec["roofline"]
                extra = (f" tc={r['t_compute_s']:.3f} tm={r['t_memory_s']:.3f}"
                         f" tx={r['t_collective_s']:.3f}"
                         f" frac={r['roofline_fraction']:.4f}"
                         f" temp={rec['memory']['temp_bytes']/1e9:.1f}GB")
            print(f"[{status}] {path.name}{extra}"
                  + ("" if rec.get("ok") else f" :: {rec.get('error')}"),
                  flush=True)


if __name__ == "__main__":
    main()
