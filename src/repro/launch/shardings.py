"""Per-cell sharding assembly: params (TP / TP+FSDP), batch, cache, opt."""
from __future__ import annotations

from typing import Dict

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.distributed.sharding import DEFAULT_RULES, spec_for

# FSDP: weight d_model dims additionally sharded over the batch axes (train)
TRAIN_PARAM_RULES = {**DEFAULT_RULES, "d_model": ("pod", "data")}
SERVE_PARAM_RULES = dict(DEFAULT_RULES)


def _bd(mesh: Mesh):
    names = tuple(n for n in ("pod", "data") if n in mesh.shape)
    return names if len(names) > 1 else (names[0] if names else None)


def _div(dim: int, mesh: Mesh, axis) -> bool:
    if axis is None:
        return False
    size = 1
    for a in (axis if isinstance(axis, tuple) else (axis,)):
        size *= mesh.shape.get(a, 1)
    return size > 1 and dim % size == 0


def param_rules_for(kind: str) -> Dict:
    return TRAIN_PARAM_RULES if kind == "train" else SERVE_PARAM_RULES


def params_shardings(defs, mesh: Mesh, kind: str):
    from repro.models.param import is_def
    rules = param_rules_for(kind)
    return jax.tree.map(
        lambda d: NamedSharding(mesh, spec_for(d.shape, d.axes, mesh, rules)),
        defs, is_leaf=is_def)


def batch_shardings(batch_struct: Dict, mesh: Mesh):
    bd = _bd(mesh)

    def one(s):
        b = s.shape[0]
        first = bd if _div(b, mesh, bd) else None
        return NamedSharding(mesh, P(first, *([None] * (len(s.shape) - 1))))

    return jax.tree.map(one, batch_struct)


def cache_shardings(cache_struct: Dict, cfg: ModelConfig, mesh: Mesh):
    bd = _bd(mesh)
    m = mesh.shape.get("model", 1)

    def kv_spec(s):
        *lead, B, S, Kv, hd = s.shape
        bspec = bd if _div(B, mesh, bd) else None
        if Kv % m == 0 and m > 1:
            return P(*([None] * len(lead)), bspec, None, "model", None)
        if S % m == 0 and m > 1:
            return P(*([None] * len(lead)), bspec, "model", None, None)
        return P(*([None] * len(lead)), bspec, None, None, None)

    def conv_spec(s):
        *lead, B, K, W = s.shape
        bspec = bd if _div(B, mesh, bd) else None
        wspec = "model" if (W % m == 0 and m > 1) else None
        return P(*([None] * len(lead)), bspec, None, wspec)

    def state_spec(s):
        *lead, B, H, N, Pdim = s.shape
        bspec = bd if _div(B, mesh, bd) else None
        hspec = "model" if (H % m == 0 and m > 1) else None
        return P(*([None] * len(lead)), bspec, hspec, None, None)

    out = {}
    for key, s in cache_struct.items():
        if key == "index":
            out[key] = NamedSharding(mesh, P())
        elif key in ("k", "v", "cross_k", "cross_v"):
            out[key] = NamedSharding(mesh, kv_spec(s))
        elif key == "conv":
            out[key] = NamedSharding(mesh, conv_spec(s))
        elif key == "state":
            out[key] = NamedSharding(mesh, state_spec(s))
        else:
            raise KeyError(key)
    return out


def opt_shardings(param_sh):
    return {
        "master": param_sh,
        "mu": param_sh,
        "nu": param_sh,
        "count": _replicated_like(param_sh),
    }


def _replicated_like(param_sh):
    leaf = jax.tree.leaves(param_sh)[0]
    return NamedSharding(leaf.mesh, P())
