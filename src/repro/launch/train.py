"""Training launcher: full fine-tuning or per-task LoRA-collection training,
with fault-tolerant checkpoint/restart.

Examples
--------
  # smoke-scale full training on CPU
  PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b --smoke \\
      --steps 20 --batch 4 --seq 64

  # train a collection of per-task LoRAs (the paper's §5.1 at small scale)
  PYTHONPATH=src python -m repro.launch.train --arch mistral-7b --smoke \\
      --lora-collection 8 --steps 60 --out /tmp/loras
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, smoke_config
from repro.checkpoint.checkpoint import (latest_step, restore_checkpoint,
                                         save_checkpoint,
                                         wait_for_async_saves)
from repro.data.pipeline import TaskDataLoader
from repro.data.tasks import make_task
from repro.ft.failures import FTConfig, FaultTolerantRunner
from repro.models import transformer as tf
from repro.models.param import init_params
from repro.training.optimizer import AdamWConfig, init_opt_state
from repro.training.step import make_lora_train_step, make_train_step


def train_full(cfg, steps: int, batch: int, seq: int, ckpt_dir: str,
               seed: int = 0, ckpt_every: int = 10, log_every: int = 5):
    defs = tf.model_defs(cfg)
    params = init_params(defs, jax.random.PRNGKey(seed))
    opt = init_opt_state(params)
    step_fn = jax.jit(make_train_step(cfg, AdamWConfig(warmup_steps=10,
                                                       total_steps=steps)))
    loader = TaskDataLoader(make_task(0, vocab=cfg.vocab_size - 8), batch, seq,
                            base_seed=seed)

    state = {"params": params, "opt": opt}

    def one_step(state, i):
        b = loader.batch_at(i)
        p, o, metrics = step_fn(state["params"], state["opt"],
                                {k: jnp.asarray(v) for k, v in b.items()})
        if i % log_every == 0:
            print(f"step {i:5d} loss {float(metrics['loss']):.4f} "
                  f"lr {float(metrics['lr']):.2e}", flush=True)
        return {"params": p, "opt": o}

    def save(step, state):
        save_checkpoint(ckpt_dir, step, state, blocking=False)

    def restore():
        # a save issued just before the failure may still be in flight;
        # land it so restart resumes from the newest checkpoint
        wait_for_async_saves()
        ls = latest_step(ckpt_dir)
        if ls is None:
            return None
        return ls, restore_checkpoint(ckpt_dir, ls, state)

    runner = FaultTolerantRunner(FTConfig(ckpt_every=ckpt_every), one_step,
                                 save, restore)
    final = runner.run(state, steps)
    save_checkpoint(ckpt_dir, steps, final, blocking=True)
    return final


def train_lora_collection(cfg, n_tasks: int, steps: int, batch: int, seq: int,
                          out_dir: str, seed: int = 0, log_every: int = 20,
                          base_params=None, specs=None, lr: float = 3e-3):
    """Paper §5.1 at reproducible scale: one LoRA per task on a shared base."""
    out = Path(out_dir)
    out.mkdir(parents=True, exist_ok=True)
    defs = tf.model_defs(cfg)
    if base_params is None:
        base_params = init_params(defs, jax.random.PRNGKey(seed))
    lora_defs = tf.lora_defs_tree(cfg)
    step_fn = jax.jit(make_lora_train_step(
        cfg, AdamWConfig(lr=lr, weight_decay=0.0, warmup_steps=10,
                         total_steps=steps)))

    results = {}
    for t in range(n_tasks):
        spec = specs[t] if specs is not None else \
            make_task(t, vocab=cfg.vocab_size - 8)
        loader = TaskDataLoader(spec, batch, seq, base_seed=seed + 17 * t)
        lp = init_params(lora_defs, jax.random.PRNGKey(seed + 1000 + t),
                         dtype_override=jnp.float32)
        opt = init_opt_state(lp)
        t0 = time.time()
        loss = None
        for i in range(steps):
            b = loader.batch_at(i)
            lp, opt, m = step_fn(base_params, lp, opt,
                                 {k: jnp.asarray(v) for k, v in b.items()})
            loss = float(m["loss"])
            if i % log_every == 0:
                print(f"task {t:3d} step {i:4d} loss {loss:.4f}", flush=True)
        np.savez(out / f"lora_task{t}.npz",
                 **{k: np.asarray(v) for k, v in _flatten_lora(lp).items()})
        results[t] = {"final_loss": loss, "train_s": time.time() - t0,
                      "kind": spec.kind}
    (out / "summary.json").write_text(json.dumps(results, indent=2))
    return results


def _flatten_lora(tree):
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(getattr(p, "key", str(getattr(p, "idx", p)))
                       for p in path)
        flat[key] = leaf
    return flat


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lora-collection", type=int, default=0)
    ap.add_argument("--out", default="/tmp/repro_train")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if args.lora_collection:
        train_lora_collection(cfg, args.lora_collection, args.steps,
                              args.batch, args.seq, args.out, args.seed)
    else:
        train_full(cfg, args.steps, args.batch, args.seq, args.out, args.seed)


if __name__ == "__main__":
    main()
