import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=" + \
    os.environ.get("REPRO_DRYRUN_DEVICES", "512")
# NOTE: the two lines above MUST run before any jax import (device count is
# locked at first backend init).  Everything below is ordinary.

import argparse          # noqa: E402
import dataclasses       # noqa: E402
import json              # noqa: E402
import time              # noqa: E402
import traceback         # noqa: E402
from pathlib import Path  # noqa: E402

import jax               # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import ASSIGNED, SHAPES, get_config                 # noqa: E402
from repro.configs.base import ModelConfig, ShapeConfig                # noqa: E402
from repro.distributed.sharding import use_mesh                        # noqa: E402
from repro.launch.mesh import make_production_mesh, mesh_description   # noqa: E402
from repro.launch.roofline import (Roofline, collective_stats,         # noqa: E402
                                   model_flops_for)
from repro.launch import shardings as sh                               # noqa: E402
from repro.models import api, transformer as tf                       # noqa: E402
from repro.models.param import abstract_params                         # noqa: E402
from repro.training.optimizer import abstract_opt_state                # noqa: E402
from repro.training.step import auto_microbatches, make_train_step     # noqa: E402

RESULTS_DIR = Path(__file__).resolve().parents[3] / "results" / "dryrun"


def skip_reason(cfg: ModelConfig, shape: ShapeConfig) -> str | None:
    if shape.name == "long_500k" and cfg.family not in ("ssm", "hybrid"):
        return ("long_500k needs sub-quadratic attention; skipped for pure "
                "full-attention archs (DESIGN.md §4)")
    return None


def cell_name(arch: str, shape: str, multi_pod: bool) -> str:
    return f"{arch}__{shape}__{'pod2x16x16' if multi_pod else 'pod16x16'}"


def run_cell(arch: str, shape_name: str, multi_pod: bool = False,
             collect_hlo: bool = True, cfg_overrides: dict | None = None,
             n_micro_override: int | None = None) -> dict:
    cfg = get_config(arch)
    if cfg_overrides:
        cfg = dataclasses.replace(cfg, **cfg_overrides)
    shape = SHAPES[shape_name]
    rec: dict = {"arch": arch, "shape": shape_name,
                 "mesh": "2x16x16" if multi_pod else "16x16",
                 "kind": shape.kind, "ok": False}
    reason = skip_reason(cfg, shape)
    if reason:
        rec.update(skipped=True, reason=reason, ok=True)
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    rec["mesh_info"] = mesh_description(mesh)
    n_dev = mesh.size
    t0 = time.time()
    with use_mesh(mesh):
        defs = tf.model_defs(cfg)
        params_s = abstract_params(defs)
        params_sh = sh.params_shardings(defs, mesh, shape.kind)
        batch_s = api.batch_struct(cfg, shape)
        batch_sh = sh.batch_shardings(batch_s, mesh)

        if shape.kind == "train":
            n_batch_shards = 1
            for a in ("pod", "data"):
                n_batch_shards *= mesh.shape.get(a, 1)
            n_micro = auto_microbatches(cfg, shape, n_batch_shards,
                                        seq_shard=mesh.shape.get("model", 1))
            if n_micro_override is not None:
                n_micro = n_micro_override
            rec["n_micro"] = n_micro
            step = make_train_step(cfg, n_micro=n_micro)
            opt_s = abstract_opt_state(params_s)
            opt_sh = sh.opt_shardings(params_sh)
            jitted = jax.jit(step,
                             in_shardings=(params_sh, opt_sh, batch_sh),
                             out_shardings=(params_sh, opt_sh, None),
                             donate_argnums=(0, 1))
            lowered = jitted.lower(params_s, opt_s, batch_s)
        else:
            cache_s = api.cache_struct(cfg, shape)
            cache_sh = sh.cache_shardings(cache_s, cfg, mesh)
            if shape.kind == "prefill":
                fn = api.make_prefill_fn(cfg)
            else:
                fn = api.make_decode_fn(cfg)
            jitted = jax.jit(fn,
                             in_shardings=(params_sh, batch_sh, cache_sh),
                             out_shardings=(None, cache_sh),
                             donate_argnums=(2,))
            lowered = jitted.lower(params_s, batch_s, cache_s)

        rec["lower_s"] = round(time.time() - t0, 2)
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 2)

        ca = compiled.cost_analysis() or {}
        if isinstance(ca, (list, tuple)):     # jax<0.5 returns [dict]
            ca = ca[0] if ca else {}
        flops_dev = float(ca.get("flops", 0.0))
        bytes_dev = float(ca.get("bytes accessed", 0.0))
        try:
            ma = compiled.memory_analysis()
            rec["memory"] = {
                "argument_bytes": int(ma.argument_size_in_bytes),
                "output_bytes": int(ma.output_size_in_bytes),
                "temp_bytes": int(ma.temp_size_in_bytes),
                "alias_bytes": int(ma.alias_size_in_bytes),
            }
        except Exception as e:  # pragma: no cover
            rec["memory"] = {"error": str(e)}

        # loop-aware HLO parse: XLA's cost_analysis counts while bodies once,
        # undercounting scanned layer stacks by ~num_layers (see hlo_cost.py)
        from repro.launch.hlo_cost import analyze_hlo
        txt = compiled.as_text()
        hc = analyze_hlo(txt)
        rec["hlo_chars"] = len(txt)
        rec["collectives"] = {"counts": hc["coll_counts"],
                              "bytes_moved": hc["coll_bytes_per_dev"]}
        rec["xla_cost_analysis"] = {"flops_per_dev_unscaled": flops_dev,
                                    "bytes_per_dev_unscaled": bytes_dev}

        roof = Roofline(flops_per_dev=hc["dot_flops_per_dev"],
                        hbm_bytes_per_dev=hc["hbm_bytes_per_dev"],
                        coll_bytes_per_dev=hc["coll_bytes_per_dev"],
                        n_devices=n_dev,
                        model_flops=model_flops_for(cfg, shape))
        rec["roofline"] = roof.to_dict()
        rec["params"] = cfg.param_count()
        rec["active_params"] = cfg.active_param_count()
        rec["ok"] = True
    return rec


def main():
    ap = argparse.ArgumentParser(description="multi-pod dry-run")
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--out", default=str(RESULTS_DIR))
    args = ap.parse_args()

    out_dir = Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)

    cells = []
    archs = ASSIGNED if args.all or args.arch is None else [args.arch]
    shapes = list(SHAPES) if args.all or args.shape is None else [args.shape]
    meshes = [False, True] if (args.both_meshes or args.all) else [args.multi_pod]
    for a in archs:
        for s in shapes:
            for mp in meshes:
                cells.append((a, s, mp))

    n_ok = n_fail = 0
    for a, s, mp in cells:
        name = cell_name(a, s, mp)
        path = out_dir / (name + ".json")
        if path.exists() and not args.force:
            print(f"[skip-cached] {name}")
            continue
        print(f"[run] {name} ...", flush=True)
        t0 = time.time()
        try:
            rec = run_cell(a, s, mp)
        except Exception as e:
            rec = {"arch": a, "shape": s,
                   "mesh": "2x16x16" if mp else "16x16", "ok": False,
                   "error": f"{type(e).__name__}: {e}",
                   "traceback": traceback.format_exc()[-4000:]}
        rec["wall_s"] = round(time.time() - t0, 2)
        path.write_text(json.dumps(rec, indent=2, default=str))
        status = "OK" if rec.get("ok") else "FAIL"
        if rec.get("skipped"):
            status = "SKIP"
        print(f"[{status}] {name} ({rec['wall_s']}s)"
              + ("" if rec.get("ok") else f" :: {rec.get('error')}"), flush=True)
        n_ok += int(bool(rec.get("ok")))
        n_fail += int(not rec.get("ok"))
    print(f"done: {n_ok} ok, {n_fail} failed")
    return 0 if n_fail == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
