"""Loop-aware cost extraction from post-SPMD HLO text.

XLA's ``compiled.cost_analysis()`` counts each ``while`` body ONCE, which
undercounts scanned layer stacks by ~num_layers.  This module re-derives the
three roofline inputs directly from the HLO text, recursively multiplying
loop bodies by their trip counts:

- dot FLOPs      : 2 * prod(out_shape) * prod(contracting dims)
- HBM traffic    : sum of result bytes of top-level (fused) instructions
                   (proxy: every fusion result is written once to HBM)
- collective traffic per device : ring-model factors on result bytes

Verified against analytic 6ND within a few percent on scanned transformers
(see EXPERIMENTS.md §Dry-run methodology).
"""
from __future__ import annotations

import dataclasses
import re
from collections import Counter
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s4": 1, "u4": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "token": 0, "s2": 1, "u2": 1,
    "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"\b([a-z][a-z0-9]*)\[([0-9,]*)\]")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*(?:\([^)]*\))?\s*->.*\{\s*$")
_WHILE_RE = re.compile(r"while\(.*?\).*?condition=%?([\w\.\-]+),\s*body=%?([\w\.\-]+)")
_CALL_RE = re.compile(r"(?:fusion|call)\(.*?\).*?(?:calls|to_apply)=%?([\w\.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_DOT_RE = re.compile(r"=\s*([a-z][a-z0-9]*\[[0-9,]*\])[^=]*\bdot\(")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")
_GROUPS_V2_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(dt: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d.strip():
            n *= int(d)
    return n * _DTYPE_BYTES.get(dt, 4)


def _shape_elems(dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d.strip():
            n *= int(d)
    return n


def _ring_factor(op: str, g: int) -> float:
    if g <= 1:
        return 0.0
    return {"all-gather": (g - 1) / g,
            "reduce-scatter": float(g - 1),
            "all-reduce": 2.0 * (g - 1) / g,
            "all-to-all": (g - 1) / g,
            "collective-permute": 1.0}.get(op, 1.0)


def _group_size(line: str) -> int:
    m = _GROUPS_V2_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return 2


_SKIP_OPS = ("parameter(", "constant(", "get-tuple-element(", "tuple(",
             "bitcast(", "after-all(", "partition-id(", "replica-id(")


@dataclasses.dataclass
class CompCost:
    dot_flops: float = 0.0
    hbm_bytes: float = 0.0
    coll_moved: float = 0.0
    coll_counts: Counter = dataclasses.field(default_factory=Counter)
    calls: List[Tuple[str, str]] = dataclasses.field(default_factory=list)
    # fusion call sites: (callee, result_bytes) — accounted in total() so a
    # callee whose root is an in-place dynamic-update-slice can be discounted
    fusion_results: List[Tuple[str, int]] = dataclasses.field(
        default_factory=list)
    whiles: List[Tuple[str, str]] = dataclasses.field(default_factory=list)
    max_const: int = 1
    root_dus_update: Optional[int] = None   # update bytes if root is a DUS


_INSTR_RE = re.compile(r"^(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.*)$")


def _parse_computations(text: str) -> Dict[str, CompCost]:
    comps: Dict[str, CompCost] = {}
    cur: Optional[CompCost] = None
    symtab: Dict[str, str] = {}           # instr name -> dims string of result
    dus_updates: Dict[str, int] = {}      # DUS instr name -> update bytes
    for raw in text.splitlines():
        line = raw.strip()
        if not line:
            continue
        if line.endswith("{") and ("->" in line or line.startswith("ENTRY")):
            m2 = re.match(r"^(?:ENTRY\s+)?%?([\w\.\-]+)", line)
            name = m2.group(1) if m2 else f"comp{len(comps)}"
            cur = CompCost()
            comps[name] = cur
            symtab = {}
            dus_updates = {}
            continue
        if cur is None or line.startswith("}"):
            continue
        # record result (dtype, dims) for every instruction (operand lookup)
        mi = _INSTR_RE.match(line)
        if mi:
            shapes = _SHAPE_RE.findall(mi.group(2).split("(")[0])
            if len(shapes) == 1:
                symtab[mi.group(1)] = shapes[0]
        for m in _CONST_RE.finditer(line):
            cur.max_const = max(cur.max_const, int(m.group(1)))
        if any(op in line for op in _SKIP_OPS) and " dot(" not in line:
            continue
        mw = _WHILE_RE.search(line)
        if mw:
            cur.whiles.append((mw.group(1), mw.group(2)))
            continue
        mc = _CALL_RE.search(line)
        if mc:
            cur.calls.append(("call", mc.group(1)))
            rb = sum(_shape_bytes(dt, dims) for dt, dims in
                     _SHAPE_RE.findall(line.split("=", 1)[-1].split("(")[0]))
            cur.fusion_results.append((mc.group(1), rb))
            continue
        # dot flops: 2 * out_elems * prod(lhs contracting dims)
        md = _DOT_RE.search(line)
        if md:
            dt, dims = _SHAPE_RE.findall(md.group(1))[0]
            out_elems = _shape_elems(dims)
            k = 1
            mctr = _CONTRACT_RE.search(line)
            args = line.split("dot(", 1)[1].split(")")[0]
            opnames = [a.strip().lstrip("%") for a in args.split(",")]
            lhs_dims = None
            if opnames:
                inline = _SHAPE_RE.findall(args)
                if inline:                      # operands printed with types
                    lhs_dims = inline[0][1].split(",")
                elif opnames[0] in symtab:
                    lhs_dims = symtab[opnames[0]][1].split(",")
            if mctr and lhs_dims:
                for ci in mctr.group(1).split(","):
                    if ci.strip() and int(ci) < len(lhs_dims):
                        k *= int(lhs_dims[int(ci)] or 1)
            cur.dot_flops += 2.0 * out_elems * k
        # collectives
        op_found = None
        for c in _COLLECTIVES:
            if re.search(r"\b" + c + r"(-start)?\(", line):
                op_found = c
                break
        if op_found and not re.search(r"\b" + op_found + r"-done\(", line):
            m2 = re.search(r"=\s*(.*?)\s+" + op_found, line)
            if m2:
                rb = sum(_shape_bytes(dt, dims)
                         for dt, dims in _SHAPE_RE.findall(m2.group(1)))
                g = _group_size(line)
                cur.coll_moved += rb * _ring_factor(op_found, g)
                cur.coll_counts[op_found] += 1
        # hbm proxy: result bytes of this instruction.  In-place updates
        # (dynamic-update-slice; scan carry/ys writes) only touch the update
        # slice, not the whole buffer — count operand[1] instead.
        eq = line.split("=", 1)
        if len(eq) == 2:
            rhs = eq[1].strip()
            if "dynamic-update-slice(" in rhs:
                args = rhs.split("dynamic-update-slice(", 1)[1].split(")")[0]
                ops = [a.strip().lstrip("%") for a in args.split(",")]
                upd = symtab.get(ops[1]) if len(ops) > 1 else None
                if upd is not None:
                    # update slice read + write only (in-place aliasing)
                    ub = 2 * _shape_bytes(upd[0], upd[1])
                    cur.hbm_bytes += ub
                    if mi:
                        dus_updates[mi.group(1)] = ub
                    if line.startswith("ROOT"):
                        cur.root_dus_update = ub
                    continue
            # ROOT convert(DUS): XLA:CPU round-trips scan-carry buffers
            # through f32 converts; on TPU the DUS writes in place — count
            # only the slice (judgement call, documented in EXPERIMENTS.md)
            if line.startswith("ROOT") and "convert(" in rhs:
                op0 = rhs.split("convert(", 1)[1].split(")")[0].strip().lstrip("%")
                if op0 in dus_updates:
                    cur.root_dus_update = dus_updates[op0]
                    continue
            shapes = _SHAPE_RE.findall(rhs.split("(")[0])
            cur.hbm_bytes += sum(_shape_bytes(dt, dims) for dt, dims in shapes)
    return comps


def analyze_hlo(text: str, entry: Optional[str] = None) -> Dict:
    """Trip-count-aware totals.  Returns dict with flops/hbm/collective."""
    comps = _parse_computations(text)
    # entry: computation named like 'main...' or marked ENTRY (first with
    # whiles as fallback)
    if entry is None:
        cands = [n for n in comps if n.startswith("main")]
        entry = cands[0] if cands else next(iter(comps))

    memo: Dict[str, Tuple[float, float, float, Counter]] = {}

    def total(name: str, depth=0) -> Tuple[float, float, float, Counter]:
        if name in memo:
            return memo[name]
        c = comps.get(name)
        if c is None or depth > 50:
            return (0.0, 0.0, 0.0, Counter())
        fl, hb, cm, cnt = c.dot_flops, c.hbm_bytes, c.coll_moved, Counter(c.coll_counts)
        for _, callee in c.calls:
            f2, h2, c2, n2 = total(callee, depth + 1)
            fl += f2
            # fused computations' internal results are NOT separate HBM
            # traffic; only add collectives/flops from callees.
            cm += c2
            cnt += n2
        for callee, rb in c.fusion_results:
            cc = comps.get(callee)
            if cc is not None and cc.root_dus_update is not None:
                hb += cc.root_dus_update     # in-place update, not full buffer
            else:
                hb += rb
        for cond, body in c.whiles:
            trips = comps.get(cond, CompCost()).max_const
            f2, h2, c2, n2 = total(body, depth + 1)
            fl += trips * f2
            hb += trips * h2
            cm += trips * c2
            cnt += Counter({k: v * trips for k, v in n2.items()})
        memo[name] = (fl, hb, cm, cnt)
        return memo[name]

    fl, hb, cm, cnt = total(entry)
    return {"dot_flops_per_dev": fl, "hbm_bytes_per_dev": hb,
            "coll_bytes_per_dev": cm, "coll_counts": dict(cnt),
            "entry": entry, "n_computations": len(comps)}
