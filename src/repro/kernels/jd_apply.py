"""Fused compressed-LoRA (JD) forward kernel.

The paper's serving insight (App. D) in MXU terms: `U Sigma_i V^T x` needs
per-adapter state only in the tiny Sigma stage; `V^T x` and `U(.)` are dense
matmuls shared by all tokens of a cluster.  This kernel fuses the shrink
matmul with the per-token diagonal-Sigma scale (JD-Diag) so the (T, r)
intermediate never round-trips HBM; JD-Full uses `sgmv.sigma_bmm` between the
two dense stages instead.

Tokens are grouped by *cluster* (k clusters, each with its own V/U), with
per-token sigma rows pre-gathered into (T, r) — that gather is tiny and
stays outside the kernel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .sgmv import _pick_block

Array = jax.Array


def _shrink_scale_kernel(cids_ref, x_ref, v_ref, sig_ref, o_ref):
    """o[tile, r] = (x[tile, :] @ V[cluster]) * sigma_tok[tile, r].

    Accumulates over d blocks; applies the per-token scale on the last one.
    """
    j = pl.program_id(1)
    nd = pl.num_programs(1)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(x_ref[...], v_ref[0],
                          preferred_element_type=jnp.float32)

    @pl.when(j == nd - 1)
    def _scale():
        o_ref[...] = o_ref[...] * sig_ref[...].astype(jnp.float32)


@functools.partial(jax.jit,
                   static_argnames=("block_t", "block_d", "interpret"))
def jd_shrink_scale(x: Array, V: Array, sigma_tok: Array, tile_cids: Array, *,
                    block_t: int = 128, block_d: int = 512,
                    interpret: bool = True) -> Array:
    """x: (T_pad, d_in); V: (k, d_in, r); sigma_tok: (T_pad, r) pre-gathered
    diag sigmas; tile_cids: (T_pad/block_t,) cluster per tile -> (T_pad, r)."""
    T, d_in = x.shape
    k, _, r = V.shape
    bt = _pick_block(T, block_t)
    bd = _pick_block(d_in, block_d)
    grid = (T // bt, d_in // bd)
    return pl.pallas_call(
        _shrink_scale_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((bt, bd), lambda i, j, ids: (i, j)),
                pl.BlockSpec((1, bd, r), lambda i, j, ids: (ids[i], j, 0)),
                pl.BlockSpec((bt, r), lambda i, j, ids: (i, 0)),
            ],
            out_specs=pl.BlockSpec((bt, r), lambda i, j, ids: (i, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct((T, r), jnp.float32),
        interpret=interpret,
    )(tile_cids, x, V, sigma_tok)


def jd_apply(x: Array, U: Array, V: Array, sigma: Array, cluster_of: Array,
             ids: Array, tile_cids: Array, tile_ids: Array, *,
             block_t: int = 128, block_d: int = 512,
             interpret: bool = True) -> Array:
    """Full compressed delta for grouped tokens.

    JD-Diag: fused shrink+scale, then expand with cluster U.
    JD-Full: shrink (scale=1), sigma_bmm by adapter tiles, then expand.
    Tokens must be grouped so each tile has one adapter (and hence one
    cluster — adapters of a tile share their cluster by construction).
    """
    from .sgmv import sgmv_expand, sigma_bmm

    T = x.shape[0]
    r = V.shape[-1]
    assert T % tile_cids.shape[0] == 0
    bt = T // tile_cids.shape[0]          # tile size fixed by the grouping
    assert block_t % bt == 0 or bt <= block_t
    if sigma.ndim == 2:  # diagonal
        sig_tok = sigma[ids].astype(x.dtype)            # (T, r) tiny gather
        t = jd_shrink_scale(x, V, sig_tok, tile_cids, block_t=bt,
                            block_d=block_d, interpret=interpret)
    else:
        ones = jnp.ones((T, r), x.dtype)
        t = jd_shrink_scale(x, V, ones, tile_cids, block_t=bt,
                            block_d=block_d, interpret=interpret)
        t = sigma_bmm(t.astype(x.dtype), sigma, tile_ids, block_t=bt,
                      interpret=interpret)
    # expand with per-cluster U: same SGMV pattern with cluster ids
    return sgmv_expand(t.astype(x.dtype), U, tile_cids, block_t=bt,
                       block_d=block_d, interpret=interpret)
