"""Jit'd wrappers dispatching between Pallas kernels and jnp oracles.

``use_pallas='auto'`` picks the Pallas path on TPU backends and interpret
mode in tests; the jnp refs serve CPU execution and the SPMD dry-run (Pallas
TPU kernels do not lower on the forced-host-device CPU backend)."""
from __future__ import annotations


import jax
import jax.numpy as jnp

from . import ref as ref_mod
from .flash_decode import flash_decode as _flash_decode_pallas
from .fused_decode import fused_decode_jd as _fused_jd_pallas
from .fused_decode import fused_decode_lora as _fused_lora_pallas
from .jd_apply import jd_apply as _jd_apply_pallas
from .sgmv import sgmv_expand, sgmv_shrink

Array = jax.Array


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def resolve_impl(use_pallas) -> str:
    """'pallas' | 'interpret' | 'ref'."""
    if use_pallas in ("pallas", "interpret", "ref"):
        return use_pallas
    return "pallas" if _on_tpu() else "ref"


def lora_apply(x: Array, A: Array, B: Array, ids: Array, *,
               tile: int = 128, scaling: float = 1.0,
               use_pallas="auto") -> Array:
    """Uncompressed multi-LoRA delta on flattened tokens (the baseline path).

    x: (T, d_in); A: (n, r, d_in); B: (n, d_out, r); ids: (T,)."""
    impl = resolve_impl(use_pallas)
    if impl == "ref":
        return ref_mod.lora_apply_ref(x, A, B, ids, scaling)
    perm, tile_ids, valid = ref_mod.group_tokens_by_adapter(
        ids, A.shape[0], tile)
    xg = x[perm]
    t = sgmv_shrink(xg, A, tile_ids, block_t=tile,
                    interpret=(impl == "interpret"))
    y = sgmv_expand(t.astype(x.dtype), B, tile_ids, block_t=tile,
                    interpret=(impl == "interpret"))
    out = jnp.zeros((x.shape[0], B.shape[1]), x.dtype)
    out = out.at[perm].add(y * valid[:, None].astype(y.dtype))
    return out * scaling


def jd_apply(x: Array, U: Array, V: Array, sigma: Array, cluster_of: Array,
             ids: Array, *, tile: int = 128, use_pallas="auto") -> Array:
    """Compressed (JD) multi-LoRA delta on flattened tokens."""
    impl = resolve_impl(use_pallas)
    if impl == "ref":
        return ref_mod.jd_apply_ref(x, U, V, sigma, cluster_of, ids)
    perm, tile_ids, valid = ref_mod.group_tokens_by_adapter(
        ids, sigma.shape[0], tile)
    xg = x[perm]
    idg = ids[perm]
    tile_cids = cluster_of[tile_ids]
    y = _jd_apply_pallas(xg, U, V, sigma, cluster_of, idg, tile_cids,
                         tile_ids, block_t=tile,
                         interpret=(impl == "interpret"))
    out = jnp.zeros((x.shape[0], U.shape[1]), x.dtype)
    out = out.at[perm].add(y * valid[:, None].astype(y.dtype))
    return out


def decode_attention(q: Array, k: Array, v: Array, kv_len: Array, *,
                     use_pallas="auto") -> Array:
    """Decode attention (one token per sequence)."""
    impl = resolve_impl(use_pallas)
    if impl == "ref":
        return ref_mod.flash_decode_ref(q, k, v, kv_len)
    out, _, _ = _flash_decode_pallas(q, k, v, kv_len,
                                     interpret=(impl == "interpret"))
    return out


def fused_lora_decode(q: Array, k: Array, v: Array, kv_len: Array,
                      ids: Array, A: Array, B: Array,
                      a_scale=None, b_scale=None, *, use_pallas="auto"):
    """Fused decode attention + per-slot raw-LoRA output delta
    (`fused_decode.fused_decode_lora`): attention and the adapter shrink/
    expand in ONE kernel pass.  Optional per-channel scales serve int8
    banks from `adapter_quant.py`.  Returns (out (B,H,hd), delta (B,d_out))."""
    impl = resolve_impl(use_pallas)
    if impl == "ref":
        return ref_mod.fused_decode_lora_ref(q, k, v, kv_len, ids, A, B,
                                             a_scale, b_scale)
    return _fused_lora_pallas(q, k, v, kv_len, ids, A, B, a_scale, b_scale,
                              interpret=(impl == "interpret"))


def fused_jd_decode(q: Array, k: Array, v: Array, kv_len: Array, ids: Array,
                    U: Array, V: Array, sigma: Array, cluster_of: Array,
                    u_scale=None, v_scale=None, *, use_pallas="auto"):
    """Fused decode attention + compressed shared-basis output delta
    (`fused_decode.fused_decode_jd`)."""
    impl = resolve_impl(use_pallas)
    if impl == "ref":
        return ref_mod.fused_decode_jd_ref(q, k, v, kv_len, ids, U, V,
                                           sigma, cluster_of, u_scale,
                                           v_scale)
    return _fused_jd_pallas(q, k, v, kv_len, ids, U, V, sigma, cluster_of,
                            u_scale, v_scale,
                            interpret=(impl == "interpret"))
