"""Per-channel symmetric KV-cache quantization Pallas kernels.

The wire-compression path of the disaggregated KV handoff (see
``repro.serving.resources.KVCompressionConfig``) ships quantized KV blocks
over the prefill->decode fabric and dequantizes on the decode replica.
These kernels are the measured artifact that grounds the simulator's
compression parameters:

  - **wire ratio** — the packed artifact's bytes per raw bf16 byte is read
    off the actual kernel outputs (:func:`measured_wire_ratio`), not
    guessed: int8 values + one f32 scale per channel per 128-token block
    give ``33/64``; int4 packs two values per byte for ``17/64``.
  - **error bound** — per-channel symmetric round-to-nearest bounds the
    absolute error by ``scale/2 = absmax / (2 * qmax)`` per channel, i.e.
    ``1/254`` (int8) / ``1/14`` (int4) of the channel absmax; asserted
    against the pure-JAX oracle in tests/test_kvcomp.py.

Layout: a KV block is (T, C) — T tokens (the fabric's canonical block is
``BLOCK_T = 128``) by C channels (layers x kv-heads x head_dim flattened).
Scales are per *channel* (axis 0 reduction): decode-time dequantization
streams the block once and rescales columns, which is HBM-bandwidth bound —
exactly the cost model ``KVCompressionConfig`` charges.

The grid runs over channel blocks; each kernel instance sees all T tokens
of its channels so the absmax reduction stays in-kernel (no cross-block
pass).  int4 packs adjacent token pairs into one byte (lo nibble = even
token), so T must be even.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import kv_dequant_ref, kv_quant_ref

Array = jax.Array

BLOCK_T = 128                        # canonical KV wire block, in tokens
QMAX = {8: 127, 4: 7}
# wire bytes per raw bf16 byte at the canonical block, as measured from the
# packed kernel artifacts (values + f32 scales; see measured_wire_ratio)
WIRE_RATIO = {8: (BLOCK_T + 4) / (2 * BLOCK_T),
              4: (BLOCK_T // 2 + 4) / (2 * BLOCK_T)}
# worst-case |dequant - x| per channel, as a fraction of the channel absmax
ERROR_BOUND = {8: 1 / 254, 4: 1 / 14}


def _pick_block(dim: int, target: int) -> int:
    """Largest divisor of `dim` that is <= target (keeps BlockSpecs exact)."""
    b = min(dim, target)
    while dim % b:
        b -= 1
    return b


def _quant_body(x_ref, qmax: float):
    x = x_ref[...].astype(jnp.float32)
    absmax = jnp.max(jnp.abs(x), axis=0, keepdims=True)
    scale = jnp.where(absmax > 0, absmax / qmax, 1.0)
    q = jnp.clip(jnp.round(x / scale), -qmax, qmax)
    return q, scale


def _quant8_kernel(x_ref, q_ref, s_ref):
    q, scale = _quant_body(x_ref, 127.0)
    q_ref[...] = q.astype(jnp.int8)
    s_ref[...] = scale


def _quant4_kernel(x_ref, q_ref, s_ref):
    q, scale = _quant_body(x_ref, 7.0)
    qi = q.astype(jnp.int32) & 0xF               # two's-complement nibble
    q_ref[...] = (qi[0::2] | (qi[1::2] << 4)).astype(jnp.uint8)
    s_ref[...] = scale


@functools.partial(jax.jit, static_argnames=("bits", "block_c", "interpret"))
def kv_quantize(x: Array, *, bits: int = 8, block_c: int = 128,
                interpret: bool = True):
    """Quantize a (T, C) KV block per channel.

    Returns ``(packed, scales)``: packed is (T, C) int8 for 8 bits or
    (T//2, C) uint8 for 4 bits (token pairs share a byte); scales is
    (1, C) f32.  The packed + scale bytes ARE the wire bytes the serving
    fabric accounts for.
    """
    T, C = x.shape
    if bits not in QMAX:
        raise ValueError(f"bits must be one of {sorted(QMAX)}, got {bits}")
    if bits == 4 and T % 2:
        raise ValueError("int4 packing needs an even token count")
    bc = _pick_block(C, block_c)
    rows = T if bits == 8 else T // 2
    kernel = _quant8_kernel if bits == 8 else _quant4_kernel
    vdtype = jnp.int8 if bits == 8 else jnp.uint8
    return pl.pallas_call(
        kernel,
        grid=(C // bc,),
        in_specs=[pl.BlockSpec((T, bc), lambda j: (0, j))],
        out_specs=[pl.BlockSpec((rows, bc), lambda j: (0, j)),
                   pl.BlockSpec((1, bc), lambda j: (0, j))],
        out_shape=[jax.ShapeDtypeStruct((rows, C), vdtype),
                   jax.ShapeDtypeStruct((1, C), jnp.float32)],
        interpret=interpret,
    )(x)


def _dequant8_kernel(q_ref, s_ref, o_ref):
    o_ref[...] = (q_ref[...].astype(jnp.float32)
                  * s_ref[...]).astype(o_ref.dtype)


def _dequant4_kernel(q_ref, s_ref, o_ref):
    v = q_ref[...].astype(jnp.int32)
    lo = ((v & 0xF) ^ 8) - 8                     # sign-extend low nibble
    hi = ((v >> 4) ^ 8) - 8
    rows, bc = v.shape
    q = jnp.stack([lo, hi], axis=1).reshape(2 * rows, bc)
    o_ref[...] = (q.astype(jnp.float32) * s_ref[...]).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("bits", "out_dtype", "block_c",
                                    "interpret"))
def kv_dequantize(packed: Array, scales: Array, *, bits: int = 8,
                  out_dtype=jnp.float32, block_c: int = 128,
                  interpret: bool = True) -> Array:
    """Invert :func:`kv_quantize`; returns the (T, C) dequantized block."""
    rows, C = packed.shape
    if bits not in QMAX:
        raise ValueError(f"bits must be one of {sorted(QMAX)}, got {bits}")
    T = rows if bits == 8 else 2 * rows
    bc = _pick_block(C, block_c)
    kernel = _dequant8_kernel if bits == 8 else _dequant4_kernel
    return pl.pallas_call(
        kernel,
        grid=(C // bc,),
        in_specs=[pl.BlockSpec((rows, bc), lambda j: (0, j)),
                  pl.BlockSpec((1, bc), lambda j: (0, j))],
        out_specs=pl.BlockSpec((T, bc), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((T, C), out_dtype),
        interpret=interpret,
    )(packed, scales)


def kv_roundtrip_ref(x: Array, bits: int = 8) -> Array:
    """Pure-JAX reference round trip (oracle for the Pallas pair)."""
    q, s = kv_quant_ref(x, bits)
    return kv_dequant_ref(q, s)


def measured_wire_ratio(bits: int, n_tokens: int = BLOCK_T,
                        n_channels: int = 256) -> float:
    """Wire bytes per raw bf16 byte, read off the packed kernel artifacts
    (this is where the serving simulator's ratios come from)."""
    x = jax.random.normal(jax.random.PRNGKey(0), (n_tokens, n_channels),
                          jnp.bfloat16)
    packed, scales = kv_quantize(x.astype(jnp.float32), bits=bits)
    return (packed.nbytes + scales.nbytes) / x.nbytes
