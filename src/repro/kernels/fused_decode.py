"""Fused flash-decode + per-slot adapter delta: one kernel, one HBM pass.

The serving hot path used to be three kernel launches per decode step —
`flash_decode.py` attention, then `sgmv.py` shrink/expand (raw LoRA) or
`jd_apply.py` (compressed shared basis) re-reading the attention output
from HBM.  Punica's observation (PAPERS.md) is that the per-slot adapter
matmul is tiny next to the attention read and belongs in the attention
kernel's epilogue.  These kernels do exactly that:

* The grid, BlockSpecs, and online-softmax body are `flash_decode`'s —
  the attention math is the *same function* (`_decode_kernel`), so fused
  attention output is bit-exact with the unfused kernel.
* Per-slot adapter ids (and cluster ids for the jd path) ride in as
  scalar-prefetch operands, the `sgmv.py` pattern: the adapter-bank
  BlockSpec index maps read ``ids[b]`` so each sequence fetches only its
  own adapter's rows.
* When the attention accumulator for one (b, kv-head) finalizes (last S
  block), its (G, hd) tile is immediately contracted against that head's
  slice of the LoRA ``A`` (or basis ``V``) factor into a rank-r scratch
  accumulator — the "shrink" happens while the activation is still in
  VMEM.  The last head's iteration runs the expand (``Sigma``/``B``/``U``)
  and writes the (1, d_out) delta output block.
* Int8 banks from `adapter_quant.py` are dequantized *inside* the kernel:
  per-output-channel scales are always passed (ones for fp banks — a
  bit-exact multiply), so one body serves both precisions.

Delta outputs revisit one (1, d_out) block across the (h, s) grid axes;
Pallas guarantees revisited output blocks stay resident across contiguous
grid iterations, so only the final visit's write lands — the same
contract `flash_decode` relies on for its own epilogue.

Paged variants mirror `flash_decode_paged`: the page table is one more
scalar-prefetch operand and the bodies delegate, so paged and contiguous
fused results are bit-exact on equal logical content (asserted in
tests/test_kernels.py over permuted page tables).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .flash_decode import _decode_kernel
from .sgmv import _pick_block

Array = jax.Array


def _finalized_attn(acc_ref, l_sc):
    """(1, G*hd) f32 attention output for this (b, kv-head), flattened to
    its slice of the (H*hd,) activation vector (head-major layout — the
    same flattening `out.reshape(B, -1)` produces on the unfused path)."""
    o = acc_ref[...] / jnp.maximum(l_sc[...], 1e-30)     # (G, hd)
    return o.reshape(1, -1)


def _shrink_into(t_sc, of, w_ref, s_ref):
    """t += (of @ W[head_slice]^T) * scale — W rows are rank channels, so
    per-row scales rescale the rank axis after the contraction."""
    w = w_ref[0].astype(jnp.float32)                     # (r, G*hd)
    t = jax.lax.dot_general(
        of, w, dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)              # (1, r)
    t_sc[...] += t * s_ref[0].reshape(1, -1).astype(jnp.float32)


def _expand_out(d_ref, t, w_ref, s_ref):
    """delta = (t @ W^T) * scale — W rows are output channels (d_out)."""
    w = w_ref[0].astype(jnp.float32)                     # (d_out, r)
    d = jax.lax.dot_general(
        t, w, dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)              # (1, d_out)
    d_ref[...] = d * s_ref[0].reshape(1, -1).astype(jnp.float32)


def _fused_lora_kernel(ids_ref, kvlen_ref, q_ref, k_ref, v_ref,
                       a_ref, as_ref, b_ref, bs_ref,
                       o_ref, l_ref, m_ref, d_ref,
                       acc_ref, m_sc, l_sc, t_sc):
    # ids_ref is consumed by the A/B/scale BlockSpec index maps
    del ids_ref
    h, s = pl.program_id(1), pl.program_id(2)
    nh, ns = pl.num_programs(1), pl.num_programs(2)

    @pl.when((h == 0) & (s == 0))
    def _init_t():
        t_sc[...] = jnp.zeros_like(t_sc)

    _decode_kernel(kvlen_ref, q_ref, k_ref, v_ref, o_ref, l_ref, m_ref,
                   acc_ref, m_sc, l_sc)

    @pl.when(s == ns - 1)
    def _shrink():
        _shrink_into(t_sc, _finalized_attn(acc_ref, l_sc), a_ref, as_ref)

    @pl.when((h == nh - 1) & (s == ns - 1))
    def _expand():
        _expand_out(d_ref, t_sc[...], b_ref, bs_ref)


def _fused_jd_kernel(ids_ref, cids_ref, kvlen_ref, q_ref, k_ref, v_ref,
                     vb_ref, vs_ref, sig_ref, u_ref, us_ref,
                     o_ref, l_ref, m_ref, d_ref,
                     acc_ref, m_sc, l_sc, t_sc):
    # ids_ref indexes the per-slot Sigma; cids_ref the shared U/V bases
    del ids_ref, cids_ref
    h, s = pl.program_id(1), pl.program_id(2)
    nh, ns = pl.num_programs(1), pl.num_programs(2)

    @pl.when((h == 0) & (s == 0))
    def _init_t():
        t_sc[...] = jnp.zeros_like(t_sc)

    _decode_kernel(kvlen_ref, q_ref, k_ref, v_ref, o_ref, l_ref, m_ref,
                   acc_ref, m_sc, l_sc)

    @pl.when(s == ns - 1)
    def _shrink():
        of = _finalized_attn(acc_ref, l_sc)
        vb = vb_ref[0].astype(jnp.float32)               # (G*hd, r)
        t = jnp.dot(of, vb, preferred_element_type=jnp.float32)
        t_sc[...] += t * vs_ref[0].astype(jnp.float32)   # vs: (1, r)

    @pl.when((h == nh - 1) & (s == ns - 1))
    def _expand():
        t = t_sc[...]
        if sig_ref.ndim == 3:                            # JD-Full (1, r, r)
            t = jnp.dot(t, sig_ref[0].astype(jnp.float32),
                        preferred_element_type=jnp.float32)
        else:                                            # JD-Diag (1, r)
            t = t * sig_ref[...].astype(jnp.float32)
        _expand_out(d_ref, t, u_ref, us_ref)


def _fused_lora_paged_kernel(pt_ref, ids_ref, kvlen_ref, *refs):
    # pt_ref feeds the k/v index maps; body shared with the contiguous
    # kernel, so paged/contiguous fused results are bit-exact
    del pt_ref
    _fused_lora_kernel(ids_ref, kvlen_ref, *refs)


def _fused_jd_paged_kernel(pt_ref, ids_ref, cids_ref, kvlen_ref, *refs):
    del pt_ref
    _fused_jd_kernel(ids_ref, cids_ref, kvlen_ref, *refs)


def _attn_outs(B, Kv, G, hd, d_out, dtype):
    out_specs = [
        pl.BlockSpec((1, 1, G, hd), lambda b, h, s, *sc: (b, h, 0, 0)),
        pl.BlockSpec((1, 1, G, 1), lambda b, h, s, *sc: (b, h, 0, 0)),
        pl.BlockSpec((1, 1, G, 1), lambda b, h, s, *sc: (b, h, 0, 0)),
        pl.BlockSpec((1, d_out), lambda b, h, s, *sc: (b, 0)),
    ]
    out_shape = [
        jax.ShapeDtypeStruct((B, Kv, G, hd), dtype),
        jax.ShapeDtypeStruct((B, Kv, G, 1), jnp.float32),
        jax.ShapeDtypeStruct((B, Kv, G, 1), jnp.float32),
        jax.ShapeDtypeStruct((B, d_out), jnp.float32),
    ]
    return out_specs, out_shape


def _scratch(G, hd, r):
    return [pltpu.VMEM((G, hd), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((1, r), jnp.float32)]


def _ones(shape):
    return jnp.ones(shape, jnp.float32)


@functools.partial(jax.jit, static_argnames=("block_s", "interpret"))
def fused_decode_lora(q: Array, k: Array, v: Array, kv_len: Array,
                      ids: Array, A: Array, B: Array,
                      a_scale: Array | None = None,
                      b_scale: Array | None = None, *,
                      block_s: int = 512, interpret: bool = True):
    """Fused decode attention + raw-LoRA output delta.

    q: (B, H, hd); k/v: (B, S, Kv, hd); kv_len/ids: (B,) int32;
    A: (n, r, H*hd) fp or int8 with a_scale (n, r, 1);
    B: (n, d_out, r) fp or int8 with b_scale (n, d_out, 1).

    Returns (out (B, H, hd), delta (B, d_out) f32) where out is bit-exact
    with `flash_decode` and delta is the un-scaled per-slot LoRA delta
    (caller applies `LoRAContext.scaling`).
    """
    Bt, H, hd = q.shape
    S, Kv = k.shape[1], k.shape[2]
    G = H // Kv
    n, r, d_attn = A.shape
    d_out = B.shape[1]
    if d_attn != H * hd:
        raise ValueError(f"A maps {d_attn} dims, attention makes {H * hd}")
    a_scale = _ones((n, r, 1)) if a_scale is None else a_scale
    b_scale = _ones((n, d_out, 1)) if b_scale is None else b_scale
    bs = _pick_block(S, block_s)
    grid = (Bt, Kv, S // bs)
    qg = q.reshape(Bt, Kv, G, hd)
    out_specs, out_shape = _attn_outs(Bt, Kv, G, hd, d_out, q.dtype)
    out, l, m, delta = pl.pallas_call(
        _fused_lora_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, G, hd),
                             lambda b, h, s, ids, kl: (b, h, 0, 0)),
                pl.BlockSpec((1, bs, 1, hd),
                             lambda b, h, s, ids, kl: (b, s, h, 0)),
                pl.BlockSpec((1, bs, 1, hd),
                             lambda b, h, s, ids, kl: (b, s, h, 0)),
                pl.BlockSpec((1, r, G * hd),
                             lambda b, h, s, ids, kl: (ids[b], 0, h)),
                pl.BlockSpec((1, r, 1),
                             lambda b, h, s, ids, kl: (ids[b], 0, 0)),
                pl.BlockSpec((1, d_out, r),
                             lambda b, h, s, ids, kl: (ids[b], 0, 0)),
                pl.BlockSpec((1, d_out, 1),
                             lambda b, h, s, ids, kl: (ids[b], 0, 0)),
            ],
            out_specs=out_specs,
            scratch_shapes=_scratch(G, hd, r),
        ),
        out_shape=out_shape,
        interpret=interpret,
    )(ids, kv_len, qg, k, v, A, a_scale, B, b_scale)
    del l, m
    return out.reshape(Bt, H, hd), delta


@functools.partial(jax.jit, static_argnames=("interpret",))
def fused_decode_lora_paged(q: Array, k_pages: Array, v_pages: Array,
                            page_table: Array, kv_len: Array, ids: Array,
                            A: Array, B: Array,
                            a_scale: Array | None = None,
                            b_scale: Array | None = None, *,
                            interpret: bool = True):
    """Paged-KV variant of :func:`fused_decode_lora` (layout contract of
    `flash_decode_paged`: k/v_pages (P, page_t, Kv, hd) + page_table
    (B, n_blocks))."""
    Bt, H, hd = q.shape
    page_t, Kv = k_pages.shape[1], k_pages.shape[2]
    n_blocks = page_table.shape[1]
    G = H // Kv
    n, r, _ = A.shape
    d_out = B.shape[1]
    a_scale = _ones((n, r, 1)) if a_scale is None else a_scale
    b_scale = _ones((n, d_out, 1)) if b_scale is None else b_scale
    grid = (Bt, Kv, n_blocks)
    qg = q.reshape(Bt, Kv, G, hd)
    out_specs, out_shape = _attn_outs(Bt, Kv, G, hd, d_out, q.dtype)
    out, l, m, delta = pl.pallas_call(
        _fused_lora_paged_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=3,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, G, hd),
                             lambda b, h, s, pt, ids, kl: (b, h, 0, 0)),
                pl.BlockSpec((1, page_t, 1, hd),
                             lambda b, h, s, pt, ids, kl: (pt[b, s], 0, h, 0)),
                pl.BlockSpec((1, page_t, 1, hd),
                             lambda b, h, s, pt, ids, kl: (pt[b, s], 0, h, 0)),
                pl.BlockSpec((1, r, G * hd),
                             lambda b, h, s, pt, ids, kl: (ids[b], 0, h)),
                pl.BlockSpec((1, r, 1),
                             lambda b, h, s, pt, ids, kl: (ids[b], 0, 0)),
                pl.BlockSpec((1, d_out, r),
                             lambda b, h, s, pt, ids, kl: (ids[b], 0, 0)),
                pl.BlockSpec((1, d_out, 1),
                             lambda b, h, s, pt, ids, kl: (ids[b], 0, 0)),
            ],
            out_specs=out_specs,
            scratch_shapes=_scratch(G, hd, r),
        ),
        out_shape=out_shape,
        interpret=interpret,
    )(page_table, ids, kv_len, qg, k_pages, v_pages, A, a_scale, B, b_scale)
    del l, m
    return out.reshape(Bt, H, hd), delta


def _jd_sigma_spec(sigma, r, pos):
    """BlockSpec for the per-slot Sigma: (n, r) diag or (n, r, r) full.
    ``pos`` is the index of `ids` among the scalar-prefetch refs."""
    if sigma.ndim == 2:
        return pl.BlockSpec((1, r), lambda b, h, s, *sc: (sc[pos][b], 0))
    return pl.BlockSpec((1, r, r), lambda b, h, s, *sc: (sc[pos][b], 0, 0))


@functools.partial(jax.jit, static_argnames=("block_s", "interpret"))
def fused_decode_jd(q: Array, k: Array, v: Array, kv_len: Array, ids: Array,
                    U: Array, V: Array, sigma: Array, cluster_of: Array,
                    u_scale: Array | None = None,
                    v_scale: Array | None = None, *,
                    block_s: int = 512, interpret: bool = True):
    """Fused decode attention + compressed shared-basis (jd) output delta.

    U: (k_clusters, d_out, r) / V: (k_clusters, H*hd, r) fp or int8 with
    u_scale (k, d_out, 1) / v_scale (k, 1, r); sigma: per-slot (n, r)
    diag or (n, r, r) full; cluster_of: (n,) int32.  Cluster ids are
    gathered host-side (``cluster_of[ids]``) and prefetched alongside the
    adapter ids.  Returns (out (B, H, hd), delta (B, d_out) f32).
    """
    Bt, H, hd = q.shape
    S, Kv = k.shape[1], k.shape[2]
    G = H // Kv
    kcl, d_attn, r = V.shape
    d_out = U.shape[1]
    if d_attn != H * hd:
        raise ValueError(f"V maps {d_attn} dims, attention makes {H * hd}")
    cids = cluster_of[ids].astype(jnp.int32)
    u_scale = _ones((kcl, d_out, 1)) if u_scale is None else u_scale
    v_scale = _ones((kcl, 1, r)) if v_scale is None else v_scale
    bs = _pick_block(S, block_s)
    grid = (Bt, Kv, S // bs)
    qg = q.reshape(Bt, Kv, G, hd)
    out_specs, out_shape = _attn_outs(Bt, Kv, G, hd, d_out, q.dtype)
    out, l, m, delta = pl.pallas_call(
        _fused_jd_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=3,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, G, hd),
                             lambda b, h, s, ids, ci, kl: (b, h, 0, 0)),
                pl.BlockSpec((1, bs, 1, hd),
                             lambda b, h, s, ids, ci, kl: (b, s, h, 0)),
                pl.BlockSpec((1, bs, 1, hd),
                             lambda b, h, s, ids, ci, kl: (b, s, h, 0)),
                pl.BlockSpec((1, G * hd, r),
                             lambda b, h, s, ids, ci, kl: (ci[b], h, 0)),
                pl.BlockSpec((1, 1, r),
                             lambda b, h, s, ids, ci, kl: (ci[b], 0, 0)),
                _jd_sigma_spec(sigma, r, 0),
                pl.BlockSpec((1, d_out, r),
                             lambda b, h, s, ids, ci, kl: (ci[b], 0, 0)),
                pl.BlockSpec((1, d_out, 1),
                             lambda b, h, s, ids, ci, kl: (ci[b], 0, 0)),
            ],
            out_specs=out_specs,
            scratch_shapes=_scratch(G, hd, r),
        ),
        out_shape=out_shape,
        interpret=interpret,
    )(ids, cids, kv_len, qg, k, v, V, v_scale, sigma, U, u_scale)
    del l, m
    return out.reshape(Bt, H, hd), delta


@functools.partial(jax.jit, static_argnames=("interpret",))
def fused_decode_jd_paged(q: Array, k_pages: Array, v_pages: Array,
                          page_table: Array, kv_len: Array, ids: Array,
                          U: Array, V: Array, sigma: Array,
                          cluster_of: Array,
                          u_scale: Array | None = None,
                          v_scale: Array | None = None, *,
                          interpret: bool = True):
    """Paged-KV variant of :func:`fused_decode_jd`."""
    Bt, H, hd = q.shape
    page_t, Kv = k_pages.shape[1], k_pages.shape[2]
    n_blocks = page_table.shape[1]
    G = H // Kv
    kcl, _, r = V.shape
    d_out = U.shape[1]
    cids = cluster_of[ids].astype(jnp.int32)
    u_scale = _ones((kcl, d_out, 1)) if u_scale is None else u_scale
    v_scale = _ones((kcl, 1, r)) if v_scale is None else v_scale
    grid = (Bt, Kv, n_blocks)
    qg = q.reshape(Bt, Kv, G, hd)
    out_specs, out_shape = _attn_outs(Bt, Kv, G, hd, d_out, q.dtype)
    out, l, m, delta = pl.pallas_call(
        _fused_jd_paged_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=4,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, G, hd),
                             lambda b, h, s, pt, ids, ci, kl: (b, h, 0, 0)),
                pl.BlockSpec((1, page_t, 1, hd),
                             lambda b, h, s, pt, ids, ci, kl:
                             (pt[b, s], 0, h, 0)),
                pl.BlockSpec((1, page_t, 1, hd),
                             lambda b, h, s, pt, ids, ci, kl:
                             (pt[b, s], 0, h, 0)),
                pl.BlockSpec((1, G * hd, r),
                             lambda b, h, s, pt, ids, ci, kl: (ci[b], h, 0)),
                pl.BlockSpec((1, 1, r),
                             lambda b, h, s, pt, ids, ci, kl: (ci[b], 0, 0)),
                _jd_sigma_spec(sigma, r, 1),
                pl.BlockSpec((1, d_out, r),
                             lambda b, h, s, pt, ids, ci, kl: (ci[b], 0, 0)),
                pl.BlockSpec((1, d_out, 1),
                             lambda b, h, s, pt, ids, ci, kl: (ci[b], 0, 0)),
            ],
            out_specs=out_specs,
            scratch_shapes=_scratch(G, hd, r),
        ),
        out_shape=out_shape,
        interpret=interpret,
    )(page_table, ids, cids, kv_len, qg, k_pages, v_pages, V, v_scale,
      sigma, U, u_scale)
    del l, m
    return out.reshape(Bt, H, hd), delta
