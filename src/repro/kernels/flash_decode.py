"""Flash-decode attention kernel: one query token per sequence against a long
KV cache, online-softmax over KV blocks (FlashDecoding-style, TPU tiling).

Grid is (B, Kv, S_blocks); the S dimension is the minor (sequential on TPU)
axis so fp32 scratch accumulators persist across KV blocks of one (b, head).
Used by the serving engine's decode step and by the sequence-sharded
long-context path (each shard runs this kernel over its KV slice, partial
(m, l, o) stats are merged across shards — see distributed/collectives.py).

Two layouts share one kernel body:

- :func:`flash_decode` — contiguous KV, ``k/v: (B, S, Kv, hd)``.
- :func:`flash_decode_paged` — unified-paging KV (S-LoRA/Punica): each
  sequence's cache lives in non-contiguous :data:`PAGE_TOKENS`-token pages
  of a shared pool, ``k/v: (P, page_t, Kv, hd)``, addressed through a per-
  sequence page table.  The page table rides in as a SECOND scalar-prefetch
  operand (the adapter-id pattern of ``sgmv.py``): the k/v BlockSpec index
  maps read ``pt[b, s]`` to fetch logical block ``s``'s physical page, so
  the gather costs nothing extra — it is just block addressing.  The body
  is the *same function* as the contiguous kernel, so the two are bit-exact
  given equal logical content (asserted in tests/test_paged.py against the
  ``kernels/ref.py`` oracle over permuted page tables).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .sgmv import _pick_block

Array = jax.Array
NEG_INF = -1e30


def _decode_kernel(kvlen_ref, q_ref, k_ref, v_ref, o_ref, l_ref, m_ref,
                   acc_ref, m_sc, l_sc):
    b = pl.program_id(0)
    s = pl.program_id(2)
    ns = pl.num_programs(2)

    @pl.when(s == 0)
    def _init():
        m_sc[...] = jnp.full_like(m_sc, NEG_INF)
        l_sc[...] = jnp.zeros_like(l_sc)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)                  # (G, hd)
    q = q * (q.shape[-1] ** -0.5)
    k = k_ref[0, :, 0].astype(jnp.float32)               # (bs, hd)
    v = v_ref[0, :, 0].astype(jnp.float32)               # (bs, hd)
    bs = k.shape[0]
    logits = jax.lax.dot_general(
        q, k, dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)              # (G, bs)
    pos = s * bs + jax.lax.broadcasted_iota(jnp.int32, (1, bs), 1)
    valid = pos < kvlen_ref[b]
    logits = jnp.where(valid, logits, NEG_INF)
    m_prev = m_sc[...]                                   # (G, 1)
    m_new = jnp.maximum(m_prev, logits.max(axis=-1, keepdims=True))
    p = jnp.exp(logits - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_sc[...] = l_sc[...] * alpha + p.sum(-1, keepdims=True)
    m_sc[...] = m_new
    acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
        p, v, preferred_element_type=jnp.float32)

    @pl.when(s == ns - 1)
    def _done():
        o_ref[0, 0] = (acc_ref[...] / jnp.maximum(l_sc[...], 1e-30)
                       ).astype(o_ref.dtype)
        l_ref[0, 0] = l_sc[...]
        m_ref[0, 0] = m_sc[...]


@functools.partial(jax.jit, static_argnames=("block_s", "interpret"))
def flash_decode(q: Array, k: Array, v: Array, kv_len: Array, *,
                 block_s: int = 512, interpret: bool = True):
    """q: (B, H, hd); k/v: (B, S, Kv, hd); kv_len: (B,) int32.

    Returns (out (B, H, hd), l (B, Kv, G, 1), m (B, Kv, G, 1)) — the (l, m)
    stats allow cross-shard softmax merging for sequence-sharded KV.
    """
    B, H, hd = q.shape
    S, Kv = k.shape[1], k.shape[2]
    G = H // Kv
    bs = _pick_block(S, block_s)
    grid = (B, Kv, S // bs)
    qg = q.reshape(B, Kv, G, hd)
    out, l, m = pl.pallas_call(
        _decode_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, G, hd), lambda b, h, s, kl: (b, h, 0, 0)),
                pl.BlockSpec((1, bs, 1, hd), lambda b, h, s, kl: (b, s, h, 0)),
                pl.BlockSpec((1, bs, 1, hd), lambda b, h, s, kl: (b, s, h, 0)),
            ],
            out_specs=[
                pl.BlockSpec((1, 1, G, hd), lambda b, h, s, kl: (b, h, 0, 0)),
                pl.BlockSpec((1, 1, G, 1), lambda b, h, s, kl: (b, h, 0, 0)),
                pl.BlockSpec((1, 1, G, 1), lambda b, h, s, kl: (b, h, 0, 0)),
            ],
            scratch_shapes=[
                pltpu.VMEM((G, hd), jnp.float32),
                pltpu.VMEM((G, 1), jnp.float32),
                pltpu.VMEM((G, 1), jnp.float32),
            ],
        ),
        out_shape=[
            jax.ShapeDtypeStruct((B, Kv, G, hd), q.dtype),
            jax.ShapeDtypeStruct((B, Kv, G, 1), jnp.float32),
            jax.ShapeDtypeStruct((B, Kv, G, 1), jnp.float32),
        ],
        interpret=interpret,
    )(kv_len, qg, k, v)
    return out.reshape(B, H, hd), l, m


def _decode_paged_kernel(pt_ref, kvlen_ref, q_ref, k_ref, v_ref, o_ref,
                         l_ref, m_ref, acc_ref, m_sc, l_sc):
    # pt_ref is consumed by the k/v BlockSpec index maps (physical page
    # lookup); the softmax body is the contiguous kernel, unchanged — that
    # sharing is what makes paged vs contiguous bit-exact.
    del pt_ref
    _decode_kernel(kvlen_ref, q_ref, k_ref, v_ref, o_ref, l_ref, m_ref,
                   acc_ref, m_sc, l_sc)


@functools.partial(jax.jit, static_argnames=("interpret",))
def flash_decode_paged(q: Array, k_pages: Array, v_pages: Array,
                       page_table: Array, kv_len: Array, *,
                       interpret: bool = True):
    """Gathered-page flash decode over a unified paged KV pool.

    q: (B, H, hd); k_pages/v_pages: (P, page_t, Kv, hd) — the pool's
    physical pages; page_table: (B, n_blocks) int32 — sequence b's logical
    KV block s lives in page ``page_table[b, s]``; kv_len: (B,) int32.

    Entries of `page_table` beyond ``ceil(kv_len[b] / page_t)`` must be
    valid page indices (e.g. 0) — their tokens are masked by `kv_len` but
    the blocks are still fetched.  Returns (out (B, H, hd),
    l (B, Kv, G, 1), m (B, Kv, G, 1)) exactly like :func:`flash_decode`.
    """
    B, H, hd = q.shape
    page_t, Kv = k_pages.shape[1], k_pages.shape[2]
    n_blocks = page_table.shape[1]
    G = H // Kv
    grid = (B, Kv, n_blocks)
    qg = q.reshape(B, Kv, G, hd)
    out, l, m = pl.pallas_call(
        _decode_paged_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, G, hd),
                             lambda b, h, s, pt, kl: (b, h, 0, 0)),
                pl.BlockSpec((1, page_t, 1, hd),
                             lambda b, h, s, pt, kl: (pt[b, s], 0, h, 0)),
                pl.BlockSpec((1, page_t, 1, hd),
                             lambda b, h, s, pt, kl: (pt[b, s], 0, h, 0)),
            ],
            out_specs=[
                pl.BlockSpec((1, 1, G, hd),
                             lambda b, h, s, pt, kl: (b, h, 0, 0)),
                pl.BlockSpec((1, 1, G, 1),
                             lambda b, h, s, pt, kl: (b, h, 0, 0)),
                pl.BlockSpec((1, 1, G, 1),
                             lambda b, h, s, pt, kl: (b, h, 0, 0)),
            ],
            scratch_shapes=[
                pltpu.VMEM((G, hd), jnp.float32),
                pltpu.VMEM((G, 1), jnp.float32),
                pltpu.VMEM((G, 1), jnp.float32),
            ],
        ),
        out_shape=[
            jax.ShapeDtypeStruct((B, Kv, G, hd), q.dtype),
            jax.ShapeDtypeStruct((B, Kv, G, 1), jnp.float32),
            jax.ShapeDtypeStruct((B, Kv, G, 1), jnp.float32),
        ],
        interpret=interpret,
    )(page_table, kv_len, qg, k_pages, v_pages)
    return out.reshape(B, H, hd), l, m
