"""Int8 per-output-channel quantization for adapter / basis weights.

Same symmetric absmax scheme as `kv_quant.py`, applied per weight matrix
instead of per 128-token KV block: every *output channel* (a row of a LoRA
``A``/``B`` factor or a column of a shared basis ``V``) gets one float32
scale computed over its *input* axis, so the matmul against a quantized
bank is exact up to a single per-channel rescale that the fused decode
kernel (`fused_decode.py`) folds into its epilogue.

Layouts (``axis`` = the input/reduction axis of the matrix):

* LoRA ``A`` bank ``(..., r, d_in)``     -> ``axis=-1``, scales ``(..., r, 1)``
* LoRA ``B`` / basis ``U`` ``(..., d, r)`` -> ``axis=-1``, scales ``(..., d, 1)``
* basis ``V`` ``(..., d_in, r)``          -> ``axis=-2``, scales ``(..., 1, r)``

Residency math: a quantized bank costs ``values * 1 byte + channels * 4
bytes`` against ``values * 4`` for float32 training-output banks — a
~3.2-3.9x cut in `PagedPool` adapter pages for the ranks we serve.
Validated against the `ref.py` oracles `adapter_quant_ref` /
`adapter_dequant_ref`; the roundtrip error is bounded by the same
per-channel `ERROR_BOUND` as the KV kernels (absmax / 254 for int8).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .kv_quant import ERROR_BOUND, QMAX
from .sgmv import _pick_block

Array = jax.Array

INT8_SCALE_BYTES = 4                     # one f32 scale per output channel


def _quant_matrix(x, axis: int):
    """Symmetric per-channel int8 over one reduction axis (kv_quant's
    `_quant_body` scheme, matrix-shaped)."""
    xf = x.astype(jnp.float32)
    absmax = jnp.max(jnp.abs(xf), axis=axis, keepdims=True)
    scale = jnp.where(absmax > 0, absmax / QMAX[8], 1.0)
    q = jnp.clip(jnp.round(xf / scale), -QMAX[8], QMAX[8])
    return q.astype(jnp.int8), scale


def _quant_rows_kernel(x_ref, q_ref, s_ref):
    q, s = _quant_matrix(x_ref[0], axis=1)   # (br, C): scale per row
    q_ref[0], s_ref[0] = q, s


def _quant_cols_kernel(x_ref, q_ref, s_ref):
    q, s = _quant_matrix(x_ref[0], axis=0)   # (R, bc): scale per column
    q_ref[0], s_ref[0] = q, s


def _dequant_kernel(q_ref, s_ref, o_ref):
    o_ref[0] = (q_ref[0].astype(jnp.float32) * s_ref[0]).astype(o_ref.dtype)


def _norm_axis(ndim: int, axis: int) -> int:
    axis = axis % ndim
    if axis not in (ndim - 1, ndim - 2):
        raise ValueError("axis must be one of the trailing two (matrix) dims")
    return axis


@functools.partial(jax.jit, static_argnames=("axis", "block", "interpret"))
def adapter_quantize(w: Array, *, axis: int = -1, block: int = 256,
                     interpret: bool = True):
    """Quantize a bank of weight matrices ``w (..., R, C)`` to int8 plus
    float32 per-output-channel scales (keepdims along ``axis``)."""
    if w.ndim < 2:
        raise ValueError("adapter_quantize expects a bank of matrices")
    axis = _norm_axis(w.ndim, axis)
    lead = w.shape[:-2]
    R, C = w.shape[-2:]
    n = 1
    for d in lead:
        n *= d
    x = w.reshape(n, R, C)
    rows = axis == w.ndim - 1                # reduce over columns
    if rows:
        br = _pick_block(R, block)
        grid = (n, R // br)
        blk = (1, br, C)
        idx = lambda i, j: (i, j, 0)
        s_blk, s_shape = (1, br, 1), (n, R, 1)
    else:
        bc = _pick_block(C, block)
        grid = (n, C // bc)
        blk = (1, R, bc)
        idx = lambda i, j: (i, 0, j)
        s_blk, s_shape = (1, 1, bc), (n, 1, C)
    q, s = pl.pallas_call(
        _quant_rows_kernel if rows else _quant_cols_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec(blk, idx)],
        out_specs=[pl.BlockSpec(blk, idx), pl.BlockSpec(s_blk, idx)],
        out_shape=[jax.ShapeDtypeStruct((n, R, C), jnp.int8),
                   jax.ShapeDtypeStruct(s_shape, jnp.float32)],
        interpret=interpret,
    )(x)
    s_out = lead + ((R, 1) if rows else (1, C))
    return q.reshape(w.shape), s.reshape(s_out)


@functools.partial(jax.jit, static_argnames=("out_dtype", "block",
                                             "interpret"))
def adapter_dequantize(q: Array, scales: Array, *,
                       out_dtype=jnp.float32, block: int = 256,
                       interpret: bool = True) -> Array:
    """Inverse of `adapter_quantize`; the reduction axis is recovered from
    the keepdims position in ``scales``."""
    rows = scales.shape[-1] == 1
    lead = q.shape[:-2]
    R, C = q.shape[-2:]
    n = 1
    for d in lead:
        n *= d
    if rows:
        br = _pick_block(R, block)
        grid, blk = (n, R // br), (1, br, C)
        idx = lambda i, j: (i, j, 0)
        s_blk, s_shape = (1, br, 1), (n, R, 1)
    else:
        bc = _pick_block(C, block)
        grid, blk = (n, C // bc), (1, R, bc)
        idx = lambda i, j: (i, 0, j)
        s_blk, s_shape = (1, 1, bc), (n, 1, C)
    out = pl.pallas_call(
        _dequant_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec(blk, idx), pl.BlockSpec(s_blk, idx)],
        out_specs=pl.BlockSpec(blk, idx),
        out_shape=jax.ShapeDtypeStruct((n, R, C), out_dtype),
        interpret=interpret,
    )(q.reshape(n, R, C), scales.reshape(s_shape))
    return out.reshape(q.shape)


def quantized_nbytes(shape, *, axis: int = -1) -> int:
    """Bytes of the packed representation: int8 values + one f32 scale per
    output channel (what the quantized bank actually occupies in the pool)."""
    axis = _norm_axis(len(shape), axis)
    values = 1
    for d in shape:
        values *= d
    channels = values // shape[axis]
    return values + INT8_SCALE_BYTES * channels


def int8_error_bound(w: Array, *, axis: int = -1) -> Array:
    """Worst-case absolute roundtrip error per channel (same bound family
    as kv_quant's `ERROR_BOUND`)."""
    absmax = jnp.max(jnp.abs(w.astype(jnp.float32)), axis=axis,
                     keepdims=True)
    return absmax * ERROR_BOUND[8]
