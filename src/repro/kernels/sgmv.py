"""SGMV (segmented-gather matrix multiply) Pallas kernels — the TPU
adaptation of Punica's multi-LoRA CUDA kernels (DESIGN.md §2).

Tokens arrive *pre-grouped by adapter* and padded so every token tile maps to
exactly one adapter (``ref.group_tokens_by_adapter``).  The per-tile adapter
id is a scalar-prefetch operand: the BlockSpec index_map reads it to stream
the right adapter block HBM->VMEM, turning per-token weight gathers into a
block-diagonal grouped GEMM that the MXU actually likes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jax.Array


def _pick_block(dim: int, target: int) -> int:
    """Largest divisor of `dim` that is <= target (keeps BlockSpecs exact)."""
    b = min(dim, target)
    while dim % b:
        b -= 1
    return b


# -- rank-tile cost model (pure; no jax) -------------------------------------
#
# The SGMV kernels contract over the rank axis in hardware tiles: the f32
# minimum TPU tile is 8 sublanes x 128 lanes, so a shrink/expand pass moves
# the rank dimension through the MXU in multiples of the slice's native
# tile width.  A rank-r adapter therefore pays for ceil(r / tile) * tile
# rank lanes — rank 4 on a tile-8 pipeline streams and multiplies 8 lanes,
# half of them zeros.  These two functions surface that padding as a pure
# cost model the router scores replicas with (mirrored jax-free in
# serving/router.py; tests/test_hetero.py asserts the mirror agrees) and
# benchmarks/hetero_placement.py validates against a wall-clock microbench
# of the kernels themselves.  Note the kernels above run interpret=True on
# CPU where padding is invisible — the microbench validates the affine
# rank backbone (time linear in r), and tile_rank=1 reduces both functions
# to the unpadded identity.


def sgmv_tile_cost(rank: int, tile_rank: int = 8) -> int:
    """Rank lanes one SGMV contraction actually occupies: `rank` padded
    up to the next multiple of the hardware's native `tile_rank`."""
    if rank < 1:
        raise ValueError("rank must be >= 1")
    if tile_rank < 1:
        raise ValueError("tile_rank must be >= 1")
    return tile_rank * -(-rank // tile_rank)


def sgmv_rank_efficiency(rank: int, tile_rank: int = 8) -> float:
    """Useful fraction of the occupied rank lanes, in (0, 1]: 1.0 when
    `rank` is a tile multiple, 1/tile_rank at its worst (rank 1 on a wide
    pipeline).  The Fleet's rank-aware routing divides a replica's
    effective throughput by this."""
    return rank / sgmv_tile_cost(rank, tile_rank)


def _shrink_kernel(ids_ref, x_ref, a_ref, o_ref):
    """o[tile, r] += x[tile, d_blk] @ A[id, :, d_blk]^T."""
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jax.lax.dot_general(
        x_ref[...], a_ref[0],
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)


@functools.partial(jax.jit,
                   static_argnames=("block_t", "block_d", "interpret"))
def sgmv_shrink(x: Array, A: Array, tile_ids: Array, *,
                block_t: int = 128, block_d: int = 512,
                interpret: bool = True) -> Array:
    """x: (T_pad, d_in) grouped tokens; A: (n, r, d_in); tile_ids:
    (T_pad/block_t,) adapter id per tile.  Returns (T_pad, r) fp32."""
    T, d_in = x.shape
    n, r, _ = A.shape
    bt = _pick_block(T, block_t)
    bd = _pick_block(d_in, block_d)
    assert tile_ids.shape[0] == T // bt, (tile_ids.shape, T, bt)
    grid = (T // bt, d_in // bd)
    return pl.pallas_call(
        _shrink_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((bt, bd), lambda i, j, ids: (i, j)),
                pl.BlockSpec((1, r, bd), lambda i, j, ids: (ids[i], 0, j)),
            ],
            out_specs=pl.BlockSpec((bt, r), lambda i, j, ids: (i, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct((T, r), jnp.float32),
        interpret=interpret,
    )(tile_ids, x, A)


def _expand_kernel(ids_ref, t_ref, b_ref, o_ref):
    """o[tile, d_blk] = t[tile, r] @ B[id, d_blk, :]^T."""
    o_ref[...] = jax.lax.dot_general(
        t_ref[...], b_ref[0],
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("block_t", "block_d", "interpret"))
def sgmv_expand(t: Array, B: Array, tile_ids: Array, *,
                block_t: int = 128, block_d: int = 512,
                interpret: bool = True) -> Array:
    """t: (T_pad, r); B: (n, d_out, r); returns (T_pad, d_out) in t.dtype."""
    T, r = t.shape
    n, d_out, _ = B.shape
    bt = _pick_block(T, block_t)
    bd = _pick_block(d_out, block_d)
    assert tile_ids.shape[0] == T // bt, (tile_ids.shape, T, bt)
    grid = (T // bt, d_out // bd)
    return pl.pallas_call(
        _expand_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((bt, r), lambda i, j, ids: (i, 0)),
                pl.BlockSpec((1, bd, r), lambda i, j, ids: (ids[i], j, 0)),
            ],
            out_specs=pl.BlockSpec((bt, bd), lambda i, j, ids: (i, j)),
        ),
        out_shape=jax.ShapeDtypeStruct((T, d_out), t.dtype),
        interpret=interpret,
    )(tile_ids, t, B)


def _sigma_bmm_kernel(ids_ref, t_ref, s_ref, o_ref):
    """o[tile, r] = t[tile, r] @ Sigma[id]  (JD-Full middle stage)."""
    o_ref[...] = jnp.dot(t_ref[...], s_ref[0],
                         preferred_element_type=jnp.float32).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_t", "interpret"))
def sigma_bmm(t: Array, sigma: Array, tile_ids: Array, *,
              block_t: int = 128, interpret: bool = True) -> Array:
    """t: (T_pad, r); sigma: (n, r, r); per-tile adapter ids."""
    T, r = t.shape
    bt = _pick_block(T, block_t)
    assert tile_ids.shape[0] == T // bt, (tile_ids.shape, T, bt)
    return pl.pallas_call(
        _sigma_bmm_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(T // bt,),
            in_specs=[
                pl.BlockSpec((bt, r), lambda i, ids: (i, 0)),
                pl.BlockSpec((1, r, r), lambda i, ids: (ids[i], 0, 0)),
            ],
            out_specs=pl.BlockSpec((bt, r), lambda i, ids: (i, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct((T, r), t.dtype),
        interpret=interpret,
    )(tile_ids, t, sigma)
