"""Pure-jnp oracles for every Pallas kernel (the `ref.py` layer).

Token-level interfaces: the serving engine flattens a continuous batch into
(T, d) tokens with per-token adapter/group metadata.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


def sgmv_shrink_ref(x: Array, A: Array, ids: Array) -> Array:
    """y[t] = A[ids[t]] @ x[t].   x: (T, d_in), A: (n, r, d_in) -> (T, r)."""
    return jnp.einsum("trd,td->tr", A[ids].astype(jnp.float32),
                      x.astype(jnp.float32)).astype(x.dtype)


def sgmv_expand_ref(t: Array, B: Array, ids: Array) -> Array:
    """y[i] = B[ids[i]] @ t[i].   t: (T, r), B: (n, d_out, r) -> (T, d_out)."""
    return jnp.einsum("tor,tr->to", B[ids].astype(jnp.float32),
                      t.astype(jnp.float32)).astype(t.dtype)


def lora_apply_ref(x: Array, A: Array, B: Array, ids: Array,
                   scaling: float = 1.0) -> Array:
    """Uncompressed multi-LoRA delta: B[id] @ (A[id] @ x) per token."""
    t = sgmv_shrink_ref(x, A, ids)
    return sgmv_expand_ref(t, B, ids) * scaling


def jd_apply_ref(x: Array, U: Array, V: Array, sigma: Array,
                 cluster_of: Array, ids: Array) -> Array:
    """Compressed (JD) multi-LoRA delta per token.

    x: (T, d_in); U: (k, d_out, r); V: (k, d_in, r);
    sigma: (n, r, r) full or (n, r) diag; cluster_of: (n,); ids: (T,).
    """
    cid = cluster_of[ids]
    Vt = V[cid].astype(jnp.float32)                  # (T, d_in, r)
    Ut = U[cid].astype(jnp.float32)                  # (T, d_out, r)
    t = jnp.einsum("td,tdr->tr", x.astype(jnp.float32), Vt)
    sig = sigma[ids].astype(jnp.float32)
    if sig.ndim == 2:
        t = t * sig
    else:
        t = jnp.einsum("tr,trq->tq", t, sig)
    return jnp.einsum("tq,toq->to", t, Ut).astype(x.dtype)


def sigma_bmm_ref(t: Array, sigma: Array, ids: Array) -> Array:
    """t: (T, r) x sigma[ids]: per-token (r, r) matmul (JD-Full mid stage)."""
    sig = sigma[ids].astype(jnp.float32)
    return jnp.einsum("tr,trq->tq", t.astype(jnp.float32), sig).astype(t.dtype)


def kv_quant_ref(x: Array, bits: int = 8) -> Tuple[Array, Array]:
    """Per-channel symmetric KV quantization oracle.

    x: (T, C) — a KV block, T tokens by C channels.  One f32 scale per
    channel (absmax / qmax); values are round-to-nearest int8 in
    [-qmax, qmax] (int4 values live in int8 storage here — the Pallas
    kernel packs two per byte; see kv_quant.py).
    """
    qmax = {8: 127, 4: 7}[bits]
    absmax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=0, keepdims=True)
    scale = jnp.where(absmax > 0, absmax / qmax, 1.0)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -qmax, qmax)
    return q.astype(jnp.int8), scale.astype(jnp.float32)


def kv_dequant_ref(q: Array, scale: Array,
                   out_dtype=jnp.float32) -> Array:
    """Dequantization oracle: values (T, C) int8 x per-channel scales."""
    return (q.astype(jnp.float32) * scale.astype(jnp.float32)).astype(out_dtype)


def flash_decode_ref(q: Array, k: Array, v: Array,
                     kv_len: Optional[Array] = None) -> Array:
    """Decode attention oracle.  q: (B, H, hd); k/v: (B, S, Kv, hd)."""
    B, H, hd = q.shape
    S, Kv = k.shape[1], k.shape[2]
    G = H // Kv
    qf = q.reshape(B, Kv, G, hd).astype(jnp.float32) * (hd ** -0.5)
    logits = jnp.einsum("bkgh,bskh->bkgs", qf, k.astype(jnp.float32))
    if kv_len is not None:
        mask = jnp.arange(S)[None, :] < kv_len.reshape(-1, 1)
        logits = jnp.where(mask[:, None, None, :], logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgs,bskh->bkgh", w, v.astype(jnp.float32))
    return out.reshape(B, H, hd).astype(q.dtype)


def gather_pages_ref(pages: Array, page_table: Array) -> Array:
    """Materialize a contiguous KV layout from a paged pool.

    pages: (P, page_t, Kv, hd) physical pages; page_table: (B, n_blocks)
    int32 — sequence b's logical block s lives in page ``page_table[b, s]``.
    Returns (B, n_blocks * page_t, Kv, hd): exactly the layout the
    contiguous :func:`flash_decode_ref` / Pallas kernel consume, so the
    gathered-page kernel can be checked against the contiguous oracle.
    """
    B, n_blocks = page_table.shape
    g = pages[page_table]                    # (B, n_blocks, page_t, Kv, hd)
    return g.reshape(B, n_blocks * pages.shape[1], *pages.shape[2:])


def flash_decode_paged_ref(q: Array, k_pages: Array, v_pages: Array,
                           page_table: Array,
                           kv_len: Optional[Array] = None) -> Array:
    """Paged decode-attention oracle: gather to contiguous, then the
    contiguous oracle — the reference the Pallas gathered-page path must
    match bit-for-bit on equal logical content."""
    return flash_decode_ref(q, gather_pages_ref(k_pages, page_table),
                            gather_pages_ref(v_pages, page_table), kv_len)


def group_tokens_by_adapter(ids: Array, n_adapters: int, tile: int
                            ) -> Tuple[Array, Array, Array]:
    """Host-side grouping: sort tokens by adapter and pad each group to a
    multiple of `tile` (the TPU adaptation of Punica's SGMV — see DESIGN.md).

    Returns (perm (T_pad,), tile_ids (T_pad//tile,), valid (T_pad,)):
      - perm: indices into the original token array (arbitrary for padding)
      - tile_ids: adapter id per tile (constant within a tile by construction)
      - valid: 0/1 mask for padding slots.
    Pure numpy-style; runs on host at batch-assembly time (not jitted).
    """
    import numpy as np
    ids_np = np.asarray(ids)
    order = np.argsort(ids_np, kind="stable")
    sorted_ids = ids_np[order]
    perm, valid, tile_ids = [], [], []
    for a in range(n_adapters):
        sel = order[sorted_ids == a]
        if sel.size == 0:
            continue
        pad = (-sel.size) % tile
        perm.extend(sel.tolist() + [int(sel[0])] * pad)
        valid.extend([1] * sel.size + [0] * pad)
        tile_ids.extend([a] * ((sel.size + pad) // tile))
    return (jnp.asarray(perm, jnp.int32), jnp.asarray(tile_ids, jnp.int32),
            jnp.asarray(valid, jnp.int32))


def adapter_quant_ref(w: Array, axis: int = -1) -> Tuple[Array, Array]:
    """Per-output-channel symmetric int8 oracle for adapter/basis banks
    (`adapter_quant.py`): one f32 scale per channel, reduced over the
    matrix's input `axis` (keepdims)."""
    xf = w.astype(jnp.float32)
    absmax = jnp.max(jnp.abs(xf), axis=axis, keepdims=True)
    scale = jnp.where(absmax > 0, absmax / 127.0, 1.0)
    q = jnp.clip(jnp.round(xf / scale), -127, 127)
    return q.astype(jnp.int8), scale.astype(jnp.float32)


def adapter_dequant_ref(q: Array, scale: Array,
                        out_dtype=jnp.float32) -> Array:
    return (q.astype(jnp.float32) * scale.astype(jnp.float32)).astype(
        out_dtype)


def _deq(w: Array, scale: Optional[Array]) -> Array:
    wf = w.astype(jnp.float32)
    return wf if scale is None else wf * scale.astype(jnp.float32)


def fused_decode_lora_ref(q: Array, k: Array, v: Array, kv_len, ids: Array,
                          A: Array, B: Array, a_scale=None, b_scale=None
                          ) -> Tuple[Array, Array]:
    """Composed oracle for `fused_decode.fused_decode_lora`: decode
    attention, then the per-slot LoRA delta on the flattened (H*hd)
    attention output.  Optional per-channel scales dequantize int8 banks
    (`adapter_quant_ref`); returns (out (B,H,hd), delta (B,d_out) f32)."""
    out = flash_decode_ref(q, k, v, kv_len)
    of = out.reshape(out.shape[0], -1).astype(jnp.float32)
    t = jnp.einsum("bd,brd->br", of, _deq(A, a_scale)[ids])
    delta = jnp.einsum("br,bor->bo", t, _deq(B, b_scale)[ids])
    return out, delta


def fused_decode_jd_ref(q: Array, k: Array, v: Array, kv_len, ids: Array,
                        U: Array, V: Array, sigma: Array, cluster_of: Array,
                        u_scale=None, v_scale=None) -> Tuple[Array, Array]:
    """Composed oracle for `fused_decode.fused_decode_jd`: attention, then
    the compressed shared-basis delta (V^T -> Sigma -> U) with per-slot
    sigma and per-cluster bases."""
    out = flash_decode_ref(q, k, v, kv_len)
    of = out.reshape(out.shape[0], -1).astype(jnp.float32)
    cid = cluster_of[ids]
    t = jnp.einsum("bd,bdr->br", of, _deq(V, v_scale)[cid])
    sig = sigma[ids].astype(jnp.float32)
    if sig.ndim == 2:                        # JD-Diag: (B, r)
        t = t * sig
    else:                                    # JD-Full: (B, r, r)
        t = jnp.einsum("br,brq->bq", t, sig)
    delta = jnp.einsum("br,bor->bo", t, _deq(U, u_scale)[cid])
    return out, delta


def fused_decode_lora_paged_ref(q, k_pages, v_pages, page_table, kv_len,
                                ids, A, B, a_scale=None, b_scale=None):
    """Paged fused oracle: gather pages to contiguous, then the contiguous
    fused oracle (same contract as `flash_decode_paged_ref`)."""
    return fused_decode_lora_ref(
        q, gather_pages_ref(k_pages, page_table),
        gather_pages_ref(v_pages, page_table), kv_len, ids, A, B,
        a_scale, b_scale)


def fused_decode_jd_paged_ref(q, k_pages, v_pages, page_table, kv_len, ids,
                              U, V, sigma, cluster_of,
                              u_scale=None, v_scale=None):
    return fused_decode_jd_ref(
        q, gather_pages_ref(k_pages, page_table),
        gather_pages_ref(v_pages, page_table), kv_len, ids, U, V, sigma,
        cluster_of, u_scale, v_scale)
