"""Train-step builders: full fine-tuning and LoRA-only fine-tuning, with
microbatched gradient accumulation (scan) for the 100B+ cells."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import transformer as tf
from repro.models.lora import LoRAContext
from repro.training.optimizer import AdamWConfig, adamw_update

Array = jax.Array


def _microbatch_grads(loss_fn, params, batch, n_micro: int):
    """Gradient accumulation over n_micro microbatches via lax.scan."""
    if n_micro <= 1:
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        return loss, grads

    def reshape(x):
        b = x.shape[0]
        return x.reshape((n_micro, b // n_micro) + x.shape[1:])

    mb = jax.tree.map(reshape, batch)

    def body(carry, mbatch):
        loss_acc, grads_acc = carry
        loss, grads = jax.value_and_grad(loss_fn)(params, mbatch)
        grads_acc = jax.tree.map(
            lambda a, g: a + g.astype(jnp.float32) / n_micro, grads_acc, grads)
        return (loss_acc + loss / n_micro, grads_acc), None

    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    (loss, grads), _ = jax.lax.scan(body, (jnp.zeros((), jnp.float32), zeros), mb)
    return loss, grads


def make_train_step(cfg: ModelConfig, opt_cfg: Optional[AdamWConfig] = None,
                    n_micro: int = 1, with_opt: bool = True):
    """Full-model train step: loss -> grads -> AdamW.

    signature: step(params, opt_state, batch) -> (params, opt_state, metrics)
    (with_opt=False: step(params, batch) -> (loss, grads), for tests)."""
    opt_cfg = opt_cfg or AdamWConfig()

    def loss_fn(params, batch):
        return tf.lm_loss(params, batch, cfg)

    if not with_opt:
        def grad_step(params, batch):
            return _microbatch_grads(loss_fn, params, batch, n_micro)
        return grad_step

    def step(params, opt_state, batch):
        loss, grads = _microbatch_grads(loss_fn, params, batch, n_micro)
        params, opt_state, metrics = adamw_update(opt_cfg, grads, opt_state)
        metrics["loss"] = loss
        return params, opt_state, metrics

    return step


def make_lora_train_step(cfg: ModelConfig,
                         opt_cfg: Optional[AdamWConfig] = None,
                         n_micro: int = 1):
    """LoRA fine-tuning: base params frozen, gradients over adapters only.

    signature: step(base_params, lora_params, opt_state, batch)
               -> (lora_params, opt_state, metrics)"""
    opt_cfg = opt_cfg or AdamWConfig(lr=1e-3, weight_decay=0.0)
    scaling = cfg.lora.alpha / cfg.lora.rank
    proto = LoRAContext(mode="single", params=None, scaling=scaling)

    def step(base_params, lora_params, opt_state, batch):
        def loss_fn(lp, b):
            return tf.lm_loss(base_params, b, cfg, lora_params=lp,
                              lora_ctx_proto=proto)

        loss, grads = _microbatch_grads(loss_fn, lora_params, batch, n_micro)
        lora_params, opt_state, metrics = adamw_update(
            opt_cfg, grads, opt_state, param_dtype=jnp.float32)
        metrics["loss"] = loss
        return lora_params, opt_state, metrics

    return step


def auto_microbatches(cfg: ModelConfig, shape: ShapeConfig, n_batch_shards: int,
                      budget_bytes: float = 2.5e9,
                      seq_shard: int = 1) -> int:
    """Pick a grad-accumulation factor so rematted layer inputs fit HBM.

    saved-per-layer ~= B_local/n x S x d_model x 2 bytes / seq_shard."""
    B_local = max(shape.global_batch // max(n_batch_shards, 1), 1)
    layers = cfg.num_layers * (2 if cfg.family == "audio" else 1)
    per_full = B_local * shape.seq_len * cfg.d_model * 2 * layers / seq_shard
    n = 1
    while per_full / n > budget_bytes and n < B_local:
        n *= 2
    return n
