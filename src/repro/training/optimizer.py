"""AdamW with fp32 master weights + moments (mixed-precision training).

Optimizer state mirrors the parameter tree; under the training sharding
rules (FSDP: weight ``d_model`` dims sharded over (pod, data) on top of TP)
the state is fully sharded — ZeRO-equivalent memory scaling without a
separate partitioner.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def lr_at(cfg: AdamWConfig, step: Array) -> Array:
    """Linear warmup + cosine decay."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps)
                 / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return cfg.lr * warm * cos


def init_opt_state(params) -> Dict[str, Any]:
    def f32(p):
        return p.astype(jnp.float32)

    def zeros(p):
        return jnp.zeros(p.shape, jnp.float32)

    return {
        "master": jax.tree.map(f32, params),
        "mu": jax.tree.map(zeros, params),
        "nu": jax.tree.map(zeros, params),
        "count": jnp.zeros((), jnp.int32),
    }


def abstract_opt_state(params_struct) -> Dict[str, Any]:
    def f32(p):
        return jax.ShapeDtypeStruct(p.shape, jnp.float32)

    return {
        "master": jax.tree.map(f32, params_struct),
        "mu": jax.tree.map(f32, params_struct),
        "nu": jax.tree.map(f32, params_struct),
        "count": jax.ShapeDtypeStruct((), jnp.int32),
    }


def opt_state_specs(param_spec_tree) -> Dict[str, Any]:
    """Optimizer-state PartitionSpecs mirror parameter specs."""
    from jax.sharding import PartitionSpec as P
    return {
        "master": param_spec_tree,
        "mu": param_spec_tree,
        "nu": param_spec_tree,
        "count": P(),
    }


def global_norm(tree) -> Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(cfg: AdamWConfig, grads, opt_state, param_dtype=jnp.bfloat16):
    """One AdamW step.  Returns (new_params, new_opt_state, metrics)."""
    count = opt_state["count"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12)) \
        if cfg.grad_clip > 0 else jnp.float32(1.0)
    lr = lr_at(cfg, count)
    b1c = 1 - cfg.b1 ** count.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** count.astype(jnp.float32)

    def upd(g, m, mu, nu):
        g = g.astype(jnp.float32) * scale
        mu = cfg.b1 * mu + (1 - cfg.b1) * g
        nu = cfg.b2 * nu + (1 - cfg.b2) * g * g
        step = (mu / b1c) / (jnp.sqrt(nu / b2c) + cfg.eps)
        m = m - lr * (step + cfg.weight_decay * m)
        return m, mu, nu

    flat_g, treedef = jax.tree.flatten(grads)
    flat_m = treedef.flatten_up_to(opt_state["master"])
    flat_mu = treedef.flatten_up_to(opt_state["mu"])
    flat_nu = treedef.flatten_up_to(opt_state["nu"])
    out = [upd(g, m, mu, nu)
           for g, m, mu, nu in zip(flat_g, flat_m, flat_mu, flat_nu)]
    master = treedef.unflatten([o[0] for o in out])
    mu = treedef.unflatten([o[1] for o in out])
    nu = treedef.unflatten([o[2] for o in out])
    params = jax.tree.map(lambda m: m.astype(param_dtype), master)
    new_state = {"master": master, "mu": mu, "nu": nu, "count": count}
    return params, new_state, {"grad_norm": gnorm, "lr": lr}
