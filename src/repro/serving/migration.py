"""Live-migration policies over the :meth:`Fleet.migrate` primitive (PR 9).

The primitive (router.py / engine.py / resources.py) checkpoints a running
request's KV, ships it wire-quantized over the contended fabric, and
re-admits it on the target replica token-exactly.  This module decides
WHEN to use it:

* **Preempt-and-migrate for priority tenants** — a ready high-priority
  request stuck behind a full batch evicts the lowest-priority running
  victim (:meth:`Scheduler.pick_victim
  <repro.serving.scheduler.Scheduler.pick_victim>`), which is rehomed on
  the least-loaded surviving replica instead of being parked.
* **Instant scale-down** — ``retire_decode`` events and autoscaler
  shrink decisions pass ``migrate=True`` to :meth:`Fleet.retire_replica
  <repro.serving.router.Fleet.retire_replica>`: the retired replica is
  emptied at retire time, so its budget slice frees immediately instead
  of after the drain tail (the `benchmarks/migration.py` acceptance
  cell).
* **Affinity defragmentation** — after membership or lifecycle churn
  re-homes an adapter/cluster, queued stragglers sitting on the wrong
  replica are migrated back to their sticky home, restoring pinned-base
  locality.  The move respects the router's bounded-spill guard, so
  defrag never re-creates the hot spot spill existed to break.
* **Page-pressure relief** — engines running ``kv_reserve="on_demand"``
  call ``on_preempt`` when mid-decode growth exhausts the pool;
  :meth:`MigrationPolicy.wire` routes the victim to another replica
  instead of the engine's local host-swap fallback.

All policies cap a single request's total moves
(``max_moves_per_request``): a bounced request eventually becomes
un-evictable and runs to completion — preemption never starves the
victim (invariant M5, ``tests/test_migration.py``).
"""
from __future__ import annotations

import dataclasses

from .request import Request
from .router import Fleet


@dataclasses.dataclass
class MigrationConfig:
    preempt_priority: bool = True    # priority tenants preempt-and-migrate
    migrate_on_retire: bool = True   # instant scale-down on retire events
    defrag: bool = True              # post-churn affinity defragmentation
    # starvation guard (M5): a request moved this many times (migrations +
    # preemptions) is no longer an eligible victim anywhere
    max_moves_per_request: int = 3
    # defrag churn bound: stragglers moved home per decision window
    defrag_max_per_window: int = 8


class MigrationPolicy:
    """Window-driven migration decisions; plugs into ``run_study`` as the
    ``migration`` hook and wires every engine's ``on_preempt``."""

    def __init__(self, cfg: MigrationConfig = None):
        self.cfg = cfg or MigrationConfig()
        self.fleet: Fleet = None

    # -- wiring -------------------------------------------------------------
    def attach(self, fleet: Fleet) -> None:
        """Bind to a fleet: page-pressure preemptions on every current
        replica rehome their victim through :meth:`Fleet.migrate` (the
        driver calls :meth:`wire` again for replicas added later)."""
        self.fleet = fleet
        for eng in fleet.engines:
            self.wire(eng)

    def wire(self, eng) -> None:
        eng.on_preempt = self._rehome

    def _rehome(self, victim: Request) -> bool:
        """``on_preempt`` handler: migrate `victim` off its replica.
        Declines (False -> engine falls back to a local host swap) when
        the fleet has nowhere else active or the victim hit its move cap."""
        fleet = self.fleet
        src = fleet.assignments.get(victim.rid, victim.replica)
        others = [i for i in fleet._active_idxs() if i != src]
        if not others:
            return False
        if victim.migrations + victim.preemptions \
                > self.cfg.max_moves_per_request:
            return False
        target = fleet._least_outstanding(others)
        fleet.migrate(victim, target, fleet.engines[src].clock)
        fleet.migration.n_preempt_migrations += 1
        return True

    # -- per-window hook ----------------------------------------------------
    def on_window(self, fleet: Fleet, t: float) -> None:
        if self.fleet is None:
            self.attach(fleet)
        if self.cfg.preempt_priority:
            self._preempt_for_priority(t)
        if self.cfg.defrag:
            self._defrag(t)

    def _preempt_for_priority(self, t: float) -> None:
        """On each replica whose batch is full while a strictly
        higher-priority request is ready, evict the lowest-priority
        victim (move-capped, M5) and rehome it on the least-loaded OTHER
        replica — the slot frees for the priority tenant at the next
        admission, the victim resumes elsewhere instead of queueing."""
        fleet = self.fleet
        idxs = fleet._active_idxs()
        if len(idxs) < 2:
            return
        for i in idxs:
            eng = fleet.engines[i]
            while len(eng.running) >= eng.cfg.scheduler.max_batch:
                ready = [r for r in eng.waiting if r.ready_time <= t]
                if not ready:
                    break
                top = max(r.priority for r in ready)
                victim = eng.scheduler.pick_victim(
                    eng.running, below_priority=top,
                    max_moves=self.cfg.max_moves_per_request)
                if victim is None:
                    break
                victim.preemptions += 1
                eng.stats.n_preempted += 1
                fleet.migrate(victim, fleet._least_outstanding(
                    [k for k in idxs if k != i]), t)
                fleet.migration.n_preempt_migrations += 1

    def _defrag(self, t: float) -> None:
        """Migrate queued stragglers back to their sticky affinity home.

        After churn (a retire re-homed a cluster, spill scattered a
        burst, an adapter retired and re-registered), an adapter's queued
        requests can sit on a replica that no longer matches
        ``Fleet._home`` — decoding there cold-starts a cache the home
        replica already has warm.  Only WAITING requests move (running
        ones finish where their KV is); the spill bound is re-checked so
        defrag never pushes load back onto an overloaded home."""
        fleet = self.fleet
        if fleet.cfg.policy not in ("adapter_affinity", "cluster_affinity"):
            return
        moved = 0
        idxs = fleet._active_idxs()
        slack = fleet.cfg.spill_requests * fleet._avg_request_work()
        for i in idxs:
            for req in list(fleet.engines[i].waiting):
                if moved >= self.cfg.defrag_max_per_window:
                    return
                home = fleet._home.get(fleet._affinity_key(req))
                if home is None or home == i or not fleet.active[home]:
                    continue
                if req.migrations + req.preemptions \
                        >= self.cfg.max_moves_per_request:
                    continue
                lightest = min(idxs,
                               key=lambda k: (fleet._routed_load[k], k))
                if fleet._routed_load[home] \
                        - fleet._routed_load[lightest] > slack:
                    continue         # home is hot again: spill stands
                fleet.migrate(req, home, t)
                fleet.migration.n_defrag_migrations += 1
                moved += 1
