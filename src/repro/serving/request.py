"""Serving request/response types + per-request latency accounting."""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np

from .resources import merge_mode_dict


def weight_key(req: "Request"):
    """Cache key for the adapter weights `req` decodes against.

    Epoch 0 (every request outside the lifecycle) keys by the bare adapter
    id — bit-exact with pre-lifecycle behavior.  Updated adapters key by
    ``(adapter_id, epoch)`` so two weight versions can be resident at once
    while the old epoch's in-flight requests drain (invariant L4)."""
    if req.adapter_epoch == 0:
        return req.adapter_id
    return (req.adapter_id, req.adapter_epoch)


@dataclasses.dataclass
class Request:
    rid: int
    adapter_id: int
    prompt_len: int
    max_new_tokens: int
    arrival_time: float = 0.0
    # online lifecycle (serving/lifecycle.py): which weight epoch of the
    # adapter this request was routed against.  An update is retire+register
    # with a bumped epoch; in-flight requests keep decoding against the
    # epoch they were stamped with (invariant L4, docs/lifecycle.md).
    # Epoch 0 is the default and keys caches by the bare adapter id, so
    # request streams that never touch the lifecycle are unchanged.
    adapter_epoch: int = 0
    # runtime state
    generated: int = 0
    start_time: Optional[float] = None
    first_token_time: Optional[float] = None
    finish_time: Optional[float] = None
    prefilled: bool = False
    replica: Optional[int] = None    # set by fleet routing
    # disaggregated serving: set by the prefill tier when prefill runs on a
    # separate replica and the KV cache is shipped to decode over the shared
    # fabric.  With chunked/streamed handoff `decode_ready_time` is the
    # FIRST chunk's landing (enough KV to start decoding) and
    # `kv_landed_time` the last chunk's; they coincide on the serial path.
    prefill_replica: Optional[int] = None
    prefill_done_time: Optional[float] = None
    transfer_time: float = 0.0       # KV handoff span (prefill -> all landed)
    decode_ready_time: Optional[float] = None
    kv_landed_time: Optional[float] = None
    # KV wire compression (stamped by the fabric when the handoff is
    # recorded): raw bytes prefill produced, bytes actually shipped, the
    # mode, and the decode-side dequantization cost the decode replica pays
    # at admission (decompress_done_time is set when it does).  With an
    # adaptive fabric policy the mode is a PER-TRANSFER pick from live
    # channel backlog, so it varies request to request; None means the
    # transfer shipped raw (see `wire_mode`).
    kv_raw_bytes: int = 0
    kv_wire_bytes: int = 0
    kv_compression: Optional[str] = None
    kv_decompress_cost: float = 0.0
    decompress_done_time: Optional[float] = None
    # scheduler-visible tenant priority: higher admits first, and with a
    # MigrationPolicy attached a ready high-priority request may
    # preempt-and-migrate a lower-priority running one (serving/migration.py)
    priority: int = 0
    # live migration / preemption (Fleet.migrate, ServingEngine.preempt).
    # Wire accounting is CUMULATIVE across hops and kept separate from the
    # prefill-handoff fields above, so the original handoff's bytes are
    # never overwritten and no byte is charged twice (invariant M2).
    migrations: int = 0              # completed live moves between replicas
    preemptions: int = 0             # times evicted from a decode slot
    migrated_from: Optional[int] = None  # source replica of the last move
    migration_time: float = 0.0      # total checkpoint -> KV-landed span
    mig_raw_bytes: int = 0           # KV bytes checkpointed across moves
    mig_wire_bytes: int = 0          # bytes actually shipped (post-quant)
    # pending target-side restore charge (wire dequant for a migrated
    # checkpoint, host swap round-trip for a local preemption); the
    # admitting engine pays it once and zeroes it (M1: the request then
    # resumes decode at the same `generated` position it was stopped at)
    kv_restore_cost: float = 0.0

    @property
    def wire_mode(self) -> str:
        """The handoff's wire mode with raw spelled out — the key the
        per-mode fabric/prefill/decode stats aggregate under."""
        return self.kv_compression or "raw"

    @property
    def ready_time(self) -> float:
        """Earliest time a decode engine may admit this request: the arrival
        for colocated serving, the first KV chunk's landing when prefill ran
        on a disaggregated prefill tier."""
        if self.decode_ready_time is not None:
            return self.decode_ready_time
        return self.arrival_time

    @property
    def prefill_lag(self) -> Optional[float]:
        """The prefill tier's contribution to this request's TTFT: arrival ->
        decode-ready (queueing + prefill compute + first-chunk transfer).
        None for colocated serving."""
        if self.decode_ready_time is None:
            return None
        return self.decode_ready_time - self.arrival_time

    @property
    def decode_wait(self) -> Optional[float]:
        """The decode tier's contribution to TTFT: decode-ready (or arrival,
        when colocated) -> first token."""
        if self.first_token_time is None:
            return None
        return self.first_token_time - self.ready_time

    @property
    def done(self) -> bool:
        return self.generated >= self.max_new_tokens

    @property
    def latency(self) -> Optional[float]:
        if self.finish_time is None:
            return None
        return self.finish_time - self.arrival_time

    @property
    def ttft(self) -> Optional[float]:
        """Time to first token (arrival -> first decoded token)."""
        if self.first_token_time is None:
            return None
        return self.first_token_time - self.arrival_time

    @property
    def tpot(self) -> Optional[float]:
        """Time per output token after the first (0 for 1-token requests)."""
        if self.finish_time is None or self.first_token_time is None:
            return None
        if self.generated <= 1:
            return 0.0
        return (self.finish_time - self.first_token_time) / (self.generated - 1)


@dataclasses.dataclass
class ServeStats:
    n_requests: int = 0
    n_tokens: int = 0
    wall_time: float = 0.0
    swap_time: float = 0.0
    compute_time: float = 0.0
    decompress_time: float = 0.0     # decode-side KV dequantization
    # dequant cost split by the wire mode the fabric picked per transfer
    decompress_by_mode: dict = dataclasses.field(default_factory=dict)
    n_swaps: int = 0
    sum_latency: float = 0.0
    latencies: List[float] = dataclasses.field(default_factory=list)
    ttfts: List[float] = dataclasses.field(default_factory=list)
    tpots: List[float] = dataclasses.field(default_factory=list)
    # unified paging (engines with a PagedPool; all zero otherwise).
    # Counters are in PAGES except the two peaks marked otherwise.
    peak_kv_pages: int = 0           # high-water decode KV reservation
    peak_adapter_pages: int = 0      # high-water adapter-weight footprint
    peak_resident_adapters: int = 0  # count of cache-resident adapters
    peak_batch: int = 0              # count of concurrent decode slots used
    n_page_reclaims: int = 0         # KV-pressure adapter-eviction rounds
    pages_reclaimed: int = 0         # adapter pages evicted to fund KV
    n_page_blocked: int = 0          # admissions deferred for lack of pages
    # live migration / preemption (all zero when no request ever moves)
    n_migrated_in: int = 0           # checkpoints re-admitted here
    n_migrated_out: int = 0          # requests checkpointed away
    n_preempted: int = 0             # decode-slot evictions (pages/priority)
    restore_time: float = 0.0        # checkpoint restore paid at admission

    def record_finish(self, req: Request) -> None:
        self.n_requests += 1
        self.sum_latency += req.latency
        self.latencies.append(req.latency)
        if req.ttft is not None:
            self.ttfts.append(req.ttft)
        if req.tpot is not None:
            self.tpots.append(req.tpot)

    @property
    def throughput_rps(self) -> float:
        return self.n_requests / self.wall_time if self.wall_time else 0.0

    @property
    def throughput_tps(self) -> float:
        return self.n_tokens / self.wall_time if self.wall_time else 0.0

    @property
    def mean_latency(self) -> float:
        return self.sum_latency / self.n_requests if self.n_requests else 0.0

    @staticmethod
    def _pct(xs: List[float], q: float) -> float:
        return float(np.percentile(xs, q)) if xs else 0.0

    def latency_pct(self, q: float) -> float:
        return self._pct(self.latencies, q)

    def ttft_pct(self, q: float) -> float:
        return self._pct(self.ttfts, q)

    def tpot_pct(self, q: float) -> float:
        return self._pct(self.tpots, q)

    @classmethod
    def merged(cls, parts: List["ServeStats"]) -> "ServeStats":
        """Fleet-level aggregate: additive counters, wall = slowest replica."""
        out = cls()
        for s in parts:
            out.n_requests += s.n_requests
            out.n_tokens += s.n_tokens
            out.wall_time = max(out.wall_time, s.wall_time)
            out.swap_time += s.swap_time
            out.compute_time += s.compute_time
            out.decompress_time += s.decompress_time
            merge_mode_dict(out.decompress_by_mode, s.decompress_by_mode)
            out.n_swaps += s.n_swaps
            out.sum_latency += s.sum_latency
            out.latencies.extend(s.latencies)
            out.ttfts.extend(s.ttfts)
            out.tpots.extend(s.tpots)
            # peaks keep the worst single replica; counters are additive
            out.peak_kv_pages = max(out.peak_kv_pages, s.peak_kv_pages)
            out.peak_adapter_pages = max(out.peak_adapter_pages,
                                         s.peak_adapter_pages)
            out.peak_resident_adapters = max(out.peak_resident_adapters,
                                             s.peak_resident_adapters)
            out.peak_batch = max(out.peak_batch, s.peak_batch)
            out.n_page_reclaims += s.n_page_reclaims
            out.pages_reclaimed += s.pages_reclaimed
            out.n_page_blocked += s.n_page_blocked
            out.n_migrated_in += s.n_migrated_in
            out.n_migrated_out += s.n_migrated_out
            out.n_preempted += s.n_preempted
            out.restore_time += s.restore_time
        return out

    def to_dict(self):
        return {
            "n_requests": self.n_requests, "n_tokens": self.n_tokens,
            "wall_time_s": self.wall_time, "swap_time_s": self.swap_time,
            "compute_time_s": self.compute_time, "n_swaps": self.n_swaps,
            "decompress_time_s": self.decompress_time,
            "decompress_by_mode_s": dict(self.decompress_by_mode),
            "throughput_rps": self.throughput_rps,
            "throughput_tps": self.throughput_tps,
            "mean_latency_s": self.mean_latency,
            "latency_p50_s": self.latency_pct(50),
            "latency_p95_s": self.latency_pct(95),
            "latency_p99_s": self.latency_pct(99),
            "ttft_p50_s": self.ttft_pct(50),
            "ttft_p95_s": self.ttft_pct(95),
            "ttft_p99_s": self.ttft_pct(99),
            "tpot_p50_s": self.tpot_pct(50),
            "tpot_p95_s": self.tpot_pct(95),
            "tpot_p99_s": self.tpot_pct(99),
            "peak_kv_pages": self.peak_kv_pages,
            "peak_adapter_pages": self.peak_adapter_pages,
            "peak_resident_adapters": self.peak_resident_adapters,
            "peak_batch": self.peak_batch,
            "n_page_reclaims": self.n_page_reclaims,
            "pages_reclaimed": self.pages_reclaimed,
            "n_page_blocked": self.n_page_blocked,
            "n_migrated_in": self.n_migrated_in,
            "n_migrated_out": self.n_migrated_out,
            "n_preempted": self.n_preempted,
            "restore_time_s": self.restore_time,
        }
