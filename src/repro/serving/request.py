"""Serving request/response types."""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass
class Request:
    rid: int
    adapter_id: int
    prompt_len: int
    max_new_tokens: int
    arrival_time: float = 0.0
    # runtime state
    generated: int = 0
    start_time: Optional[float] = None
    finish_time: Optional[float] = None
    prefilled: bool = False

    @property
    def done(self) -> bool:
        return self.generated >= self.max_new_tokens

    @property
    def latency(self) -> Optional[float]:
        if self.finish_time is None:
            return None
        return self.finish_time - self.arrival_time


@dataclasses.dataclass
class ServeStats:
    n_requests: int = 0
    n_tokens: int = 0
    wall_time: float = 0.0
    swap_time: float = 0.0
    compute_time: float = 0.0
    n_swaps: int = 0
    sum_latency: float = 0.0

    @property
    def throughput_rps(self) -> float:
        return self.n_requests / self.wall_time if self.wall_time else 0.0

    @property
    def throughput_tps(self) -> float:
        return self.n_tokens / self.wall_time if self.wall_time else 0.0

    @property
    def mean_latency(self) -> float:
        return self.sum_latency / self.n_requests if self.n_requests else 0.0

    def to_dict(self):
        return {
            "n_requests": self.n_requests, "n_tokens": self.n_tokens,
            "wall_time_s": self.wall_time, "swap_time_s": self.swap_time,
            "compute_time_s": self.compute_time, "n_swaps": self.n_swaps,
            "throughput_rps": self.throughput_rps,
            "throughput_tps": self.throughput_tps,
            "mean_latency_s": self.mean_latency,
        }
