"""Disaggregated prefill tier: prefill as schedulable work + KV handoff.

The colocated engine serializes prefill inside ``ServingEngine._admit``,
so a long prompt blocks every decode slot on the replica (head-of-line
blocking).  Disaggregated serving (InfiniLoRA, arXiv:2604.07173; Splitwise)
moves prefill to a dedicated tier:

  - :class:`PrefillWorker` — one prefill replica with its own simulated
    clock, batch queue, and :class:`~repro.serving.adapter_cache.AdapterCache`
    (adapters must be resident on the *prefill* device too; compressed "jd"
    collections pin their shared bases here exactly as on decode).
    Admission reuses the decode scheduler's adapter/cluster-aware ordering;
    prefill compute within an admitted batch is serialized (compute-bound).
  - :class:`~repro.serving.resources.KVFabric` — the shared, contended
    prefill->decode interconnect.  Workers *record* each produced KV cache
    on the fabric as its prefill completes (handoff never blocks the
    worker's next prefill); the fabric schedules chunks across all workers'
    transfers and stamps ``decode_ready_time`` (first chunk) /
    ``kv_landed_time`` (last chunk).  A standalone worker owns a private
    single-link-equivalent fabric, which reproduces the PR-2
    :class:`TransferLink` times bit-exactly.
  - :class:`PrefillTier` — routes requests across *active* workers
    (least-outstanding, deterministic) and supports elastic membership
    symmetric with the decode fleet: :meth:`add_worker` joins a worker
    mid-stream, :meth:`retire_worker` stops routing to one while it drains
    its remaining queue — so the joint autoscaler can shrink this tier to
    fund the other under a fixed :class:`~repro.serving.resources.HardwareBudget`.

The tier is feed-forward: decode never blocks prefill, so the whole tier
can be simulated eagerly as requests are submitted (window-by-window under
the autoscaler) without a global event queue; the fabric resolves at each
drain, carrying channel backlog across windows.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

from .adapter_cache import AdapterCache, CacheConfig
from .request import Request, weight_key
from .resources import (FabricConfig, FabricStats, KVFabric, PagedPool,
                        PagedPoolConfig, kv_bytes_per_token, merge_mode_dict)
from .scheduler import Scheduler, SchedulerConfig


@dataclasses.dataclass
class TransferLink:
    """PR-2 compatibility: one private prefill->decode link.

    Kept as the configuration surface for the degenerate fabric (a
    single-worker fabric with serial chunks is bit-exact with this model:
    ``latency + nbytes / bandwidth``, serialized per link).  New code should
    configure :class:`~repro.serving.resources.FabricConfig` instead.
    """
    bandwidth: float = 50e9          # bytes/s prefill -> decode
    latency: float = 200e-6          # per-handoff fixed cost

    def time_for(self, nbytes: int) -> float:
        return self.latency + nbytes / self.bandwidth


@dataclasses.dataclass
class PrefillConfig:
    n_workers: int = 1
    max_batch: int = 8               # admission group (adapter reuse window)
    adapter_budget_bytes: float = 2e9
    mode: str = "lora"               # lora | jd (pins shared bases)
    link: TransferLink = dataclasses.field(default_factory=TransferLink)
    # shared-fabric override: when set, the tier builds one KVFabric from
    # this config and all workers contend on it (chunked/streamed handoff);
    # when None, the tier's fabric is derived from `link` (aggregate
    # bandwidth = one link's worth, serial chunks)
    fabric: Optional[FabricConfig] = None
    # unified paging: when set, each worker's adapter cache allocates whole
    # pages from its own PagedPool (same allocator as decode replicas —
    # prefill holds no decode KV, so only adapter/pinned pages are used);
    # None keeps the legacy byte-budget cache
    pool: Optional[PagedPoolConfig] = None

    def fabric_config(self) -> FabricConfig:
        return self.fabric or FabricConfig(bandwidth=self.link.bandwidth,
                                           latency=self.link.latency,
                                           chunk_bytes=0)


@dataclasses.dataclass
class PrefillStats:
    n_prefills: int = 0
    compute_time: float = 0.0        # prefill FLOP time
    swap_time: float = 0.0           # adapter-residency stalls
    compress_time: float = 0.0       # KV wire-compression (quantize) time
    transfer_time: float = 0.0       # sum of per-request KV handoff times
    kv_bytes_moved: int = 0          # bytes on the wire (post-compression)
    kv_raw_bytes: int = 0            # bytes produced by prefill
    n_swaps: int = 0
    n_chunks: int = 0                # fabric chunks shipped (disagg)
    # per-wire-mode fabric accounting (adaptive compression picks a mode
    # per transfer; "raw" keys the uncompressed ones)
    kv_wire_bytes_by_mode: Dict[str, int] = dataclasses.field(
        default_factory=dict)
    kv_raw_bytes_by_mode: Dict[str, int] = dataclasses.field(
        default_factory=dict)
    n_mode_switches: int = 0         # adaptive-policy level changes

    @classmethod
    def merged(cls, parts: Sequence["PrefillStats"]) -> "PrefillStats":
        out = cls()
        for s in parts:
            out.n_prefills += s.n_prefills
            out.compute_time += s.compute_time
            out.swap_time += s.swap_time
            out.compress_time += s.compress_time
            out.transfer_time += s.transfer_time
            out.kv_bytes_moved += s.kv_bytes_moved
            out.kv_raw_bytes += s.kv_raw_bytes
            out.n_swaps += s.n_swaps
            out.n_chunks += s.n_chunks
            merge_mode_dict(out.kv_wire_bytes_by_mode,
                            s.kv_wire_bytes_by_mode)
            merge_mode_dict(out.kv_raw_bytes_by_mode, s.kv_raw_bytes_by_mode)
            out.n_mode_switches += s.n_mode_switches
        return out

    def add_fabric(self, fs: FabricStats) -> "PrefillStats":
        self.transfer_time += fs.transfer_time
        self.kv_bytes_moved += fs.kv_bytes_moved
        self.kv_raw_bytes += fs.kv_raw_bytes
        self.n_chunks += fs.n_chunks
        merge_mode_dict(self.kv_wire_bytes_by_mode, fs.wire_bytes_by_mode)
        merge_mode_dict(self.kv_raw_bytes_by_mode, fs.raw_bytes_by_mode)
        self.n_mode_switches += fs.n_mode_switches
        return self

    def to_dict(self) -> Dict:
        return {
            "n_prefills": self.n_prefills,
            "prefill_compute_s": self.compute_time,
            "prefill_swap_s": self.swap_time,
            "kv_compress_s": self.compress_time,
            "kv_transfer_s": self.transfer_time,
            "kv_bytes_moved": self.kv_bytes_moved,
            "kv_raw_bytes": self.kv_raw_bytes,
            "kv_chunks": self.n_chunks,
            "kv_wire_bytes_by_mode": dict(self.kv_wire_bytes_by_mode),
            "kv_raw_bytes_by_mode": dict(self.kv_raw_bytes_by_mode),
            "kv_mode_switches": self.n_mode_switches,
            "prefill_n_swaps": self.n_swaps,
        }


class PrefillWorker:
    """One prefill replica: batch queue + adapter cache + serialized compute.

    The executor provides ``prefill_time(req)``, ``adapter_bytes(aid)``,
    ``shared_bytes()`` and ``kv_bytes(req)`` (see
    :class:`~repro.serving.engine.CostModelExecutor`).

    KV handoff goes through ``self.fabric``.  A worker constructed without
    one owns a private fabric derived from ``cfg`` (PR-2 single-link
    semantics) and resolves it on :meth:`drain`; a worker inside a
    :class:`PrefillTier` is re-bound to the tier's shared fabric, which the
    tier resolves after all workers drain.
    """

    def __init__(self, cfg: PrefillConfig, executor,
                 cluster_of: Optional[Dict[int, int]] = None,
                 fabric: Optional[KVFabric] = None,
                 slice_type=None):
        if cfg.max_batch < 1:
            raise ValueError("PrefillConfig.max_batch must be >= 1")
        self.cfg = cfg
        self.executor = executor
        # the hardware slice class this worker occupies (None: the legacy
        # interchangeable accelerator); run_study releases the matching
        # budget allocation when the worker retires
        self.slice_type = slice_type
        self.scheduler = Scheduler(SchedulerConfig(max_batch=cfg.max_batch),
                                   cluster_of)
        self.pool = None if cfg.pool is None else PagedPool(cfg.pool)
        self.cache = AdapterCache(CacheConfig(cfg.adapter_budget_bytes),
                                  pool=self.pool)
        if cfg.mode == "jd":
            self.cache.pin_shared(executor.shared_bytes())
        self.fabric = fabric or KVFabric(cfg.fabric_config())
        self._owns_fabric = fabric is None
        self.clock = 0.0
        self.waiting: List[Request] = []
        self.stats = PrefillStats()

    @property
    def outstanding(self) -> int:
        return len(self.waiting)

    def submit(self, reqs: Sequence[Request]) -> None:
        self.waiting.extend(reqs)
        self.waiting.sort(key=lambda r: r.arrival_time)

    def refresh_shared(self, nbytes: int, now: float) -> float:
        """Swap this worker's pinned shared bases (basis-refresh rollout
        step / rollback) — symmetric with
        :meth:`repro.serving.engine.ServingEngine.refresh_shared`: the DMA
        stalls this worker's clock while the rest of the tier serves."""
        self.clock = max(self.clock, now)
        t_done = self.cache.repin_shared(nbytes, self.clock)
        self.stats.swap_time += t_done - self.clock
        self.clock = t_done
        return t_done

    def _handoff(self, req: Request) -> None:
        """Record the produced KV cache on the fabric (never blocks this
        worker's next prefill); the fabric stamps readiness at resolve.

        The fabric plans the transfer's wire mode first (the static
        per-fabric mode, or the adaptive policy's live-backlog pick).
        When it compresses, the quantize / projection kernel runs on THIS
        worker between prefills — the compression cost is serialized on
        the worker's clock before the handoff is recorded, so a
        compressed transfer starts later but ships fewer bytes.  A raw
        pick (and a raw-locked adaptive policy) charges nothing, exactly
        like a ``compression=None`` fabric."""
        nbytes = self.executor.kv_bytes(req)
        comp = self.fabric.plan(req, self.clock, nbytes)
        if comp is not None:
            t_comp = comp.compress_time(
                nbytes, kv_bytes_per_token(nbytes, req.prompt_len))
            self.clock += t_comp
            self.stats.compress_time += t_comp
        req.prefill_done_time = self.clock
        req.prefilled = True
        self.fabric.request(req, self.clock, nbytes, comp=comp)

    def step(self) -> bool:
        """Prefill one admitted batch; returns False when drained."""
        if not self.waiting:
            return False
        self.clock = max(self.clock, self.waiting[0].arrival_time)
        batch = self.scheduler.admit([], self.waiting,
                                     self.cache.resident_ids, self.clock)
        if not batch:
            # unreachable by construction (clock was advanced to the head
            # arrival and max_batch >= 1); fail loudly rather than letting
            # drain() spin forever if a scheduler change breaks that
            raise RuntimeError("prefill scheduler admitted nothing while "
                               f"{len(self.waiting)} requests wait")
        # overlapped DMA for the batch's adapters; stall on the max
        t_ready = self.clock
        for r in batch:
            t_ready = max(t_ready, self.cache.ensure(
                weight_key(r), self.executor.adapter_bytes(r.adapter_id),
                self.clock))
        stall = max(0.0, t_ready - self.clock)
        self.clock += stall
        self.stats.swap_time += stall
        # prefill is compute-bound: serialize within the batch; each request
        # hands its KV to the fabric as soon as its own prefill finishes
        for r in batch:
            self.waiting.remove(r)
            r.start_time = self.clock
            t_pre = self.executor.prefill_time(r)
            self.clock += t_pre
            self.stats.compute_time += t_pre
            self.stats.n_prefills += 1
            self._handoff(r)
        return True

    def drain(self) -> None:
        while self.step():
            pass
        self.stats.n_swaps = self.cache.n_swaps
        if self._owns_fabric:
            self.fabric.resolve()
            fs = self.fabric.stats
            self.stats.transfer_time = fs.transfer_time
            self.stats.kv_bytes_moved = fs.kv_bytes_moved
            self.stats.kv_raw_bytes = fs.kv_raw_bytes
            self.stats.n_chunks = fs.n_chunks
            self.stats.kv_wire_bytes_by_mode = dict(fs.wire_bytes_by_mode)
            self.stats.kv_raw_bytes_by_mode = dict(fs.raw_bytes_by_mode)
            self.stats.n_mode_switches = fs.n_mode_switches


class PrefillTier:
    """Routes requests across active prefill workers, runs them eagerly,
    and resolves the shared KV fabric.

    Routing is least-outstanding with a deterministic index tiebreak (the
    tier has no adapter-affinity pressure of its own at jd mode — shared
    bases are pinned on every worker — and lora-mode affinity is dominated
    by keeping the tier's queues short).

    Membership is elastic and symmetric with the decode fleet:
    :meth:`add_worker` joins a worker at a simulated time,
    :meth:`retire_worker` stops routing to one (it drains what it has), so
    an autoscaler can shrink this tier to fund decode replicas under a
    fixed hardware budget — and vice versa.
    """

    def __init__(self, cfg: PrefillConfig, workers: Sequence[PrefillWorker],
                 fabric: Optional[KVFabric] = None):
        if len(workers) != cfg.n_workers:
            raise ValueError(f"expected {cfg.n_workers} workers, "
                             f"got {len(workers)}")
        self.cfg = cfg
        self.workers = list(workers)
        self.fabric = fabric or KVFabric(cfg.fabric_config())
        for w in self.workers:
            self._bind(w)
        self.active: List[bool] = [True] * len(self.workers)
        self.scale_events = 0

    def _bind(self, worker: PrefillWorker) -> None:
        worker.fabric = self.fabric
        worker._owns_fabric = False

    # -- elastic membership -------------------------------------------------
    def _active_idxs(self) -> List[int]:
        return [i for i, a in enumerate(self.active) if a]

    @property
    def n_active(self) -> int:
        return len(self._active_idxs())

    def add_worker(self, worker: PrefillWorker, now: float = 0.0) -> int:
        """Join a fresh prefill worker at simulated time `now`."""
        worker.clock = max(worker.clock, now)
        self._bind(worker)
        self.workers.append(worker)
        self.active.append(True)
        self.scale_events += 1
        return len(self.workers) - 1

    def retire_worker(self, i: int) -> None:
        """Stop routing to worker `i`; it drains its remaining queue."""
        if not self.active[i]:
            return
        if self.n_active == 1:
            raise ValueError("cannot retire the last active prefill worker")
        self.active[i] = False
        self.scale_events += 1

    # -- request flow -------------------------------------------------------
    def submit(self, reqs: Sequence[Request]) -> None:
        idxs = self._active_idxs()
        for r in sorted(reqs, key=lambda r: r.arrival_time):
            i = min(idxs, key=lambda j: (self.workers[j].outstanding,
                                         self.workers[j].clock, j))
            r.prefill_replica = i
            self.workers[i].submit([r])

    def drain(self) -> None:
        for w in self.workers:
            w.drain()
        self.fabric.resolve()

    def process(self, reqs: Sequence[Request]) -> List[Request]:
        """Submit + drain; returns the same requests, now KV-ready-stamped.
        Incremental: worker clocks/queues and fabric backlog persist across
        calls, so the autoscaler can feed arrival windows one at a time."""
        self.submit(reqs)
        self.drain()
        return list(reqs)

    @property
    def stats(self) -> PrefillStats:
        merged = PrefillStats.merged([w.stats for w in self.workers])
        return merged.add_fabric(self.fabric.stats)
