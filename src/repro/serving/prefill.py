"""Disaggregated prefill tier: prefill as schedulable work + KV handoff.

The colocated engine serializes prefill inside ``ServingEngine._admit``,
so a long prompt blocks every decode slot on the replica (head-of-line
blocking).  Disaggregated serving (InfiniLoRA, arXiv:2604.07173; Splitwise)
moves prefill to a dedicated tier:

  - :class:`PrefillWorker` — one prefill replica with its own simulated
    clock, batch queue, and :class:`~repro.serving.adapter_cache.AdapterCache`
    (adapters must be resident on the *prefill* device too; compressed "jd"
    collections pin their shared bases here exactly as on decode).
    Admission reuses the decode scheduler's adapter/cluster-aware ordering;
    prefill compute within an admitted batch is serialized (compute-bound).
  - :class:`TransferLink` — cost model for shipping the produced KV cache
    to the decode tier: fixed latency + size/bandwidth, serialized per link
    (one link per prefill worker), overlapping the worker's next prefill.
  - :class:`PrefillTier` — routes requests across workers (least-loaded,
    deterministic) and stamps each request with ``prefill_done_time`` /
    ``decode_ready_time`` so decode engines admit it only once its KV has
    landed.

The tier is feed-forward: decode never blocks prefill, so the whole tier
can be simulated eagerly as requests are submitted (window-by-window under
the autoscaler) without a global event queue.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

from .adapter_cache import AdapterCache, CacheConfig
from .request import Request
from .scheduler import Scheduler, SchedulerConfig


@dataclasses.dataclass
class TransferLink:
    """KV handoff cost between the prefill and decode tiers.

    Defaults model an intra-pod interconnect (ICI/NVLink-class): shipping a
    512-token bf16 KV cache for an 8B-class model costs ~1 ms — small vs.
    prefill, but not free under bursts when the link serializes.
    """
    bandwidth: float = 50e9          # bytes/s prefill -> decode
    latency: float = 200e-6          # per-handoff fixed cost

    def time_for(self, nbytes: int) -> float:
        return self.latency + nbytes / self.bandwidth


@dataclasses.dataclass
class PrefillConfig:
    n_workers: int = 1
    max_batch: int = 8               # admission group (adapter reuse window)
    adapter_budget_bytes: float = 2e9
    mode: str = "lora"               # lora | jd (pins shared bases)
    link: TransferLink = dataclasses.field(default_factory=TransferLink)


@dataclasses.dataclass
class PrefillStats:
    n_prefills: int = 0
    compute_time: float = 0.0        # prefill FLOP time
    swap_time: float = 0.0           # adapter-residency stalls
    transfer_time: float = 0.0       # sum of per-request KV handoff times
    kv_bytes_moved: int = 0
    n_swaps: int = 0

    @classmethod
    def merged(cls, parts: Sequence["PrefillStats"]) -> "PrefillStats":
        out = cls()
        for s in parts:
            out.n_prefills += s.n_prefills
            out.compute_time += s.compute_time
            out.swap_time += s.swap_time
            out.transfer_time += s.transfer_time
            out.kv_bytes_moved += s.kv_bytes_moved
            out.n_swaps += s.n_swaps
        return out

    def to_dict(self) -> Dict:
        return {
            "n_prefills": self.n_prefills,
            "prefill_compute_s": self.compute_time,
            "prefill_swap_s": self.swap_time,
            "kv_transfer_s": self.transfer_time,
            "kv_bytes_moved": self.kv_bytes_moved,
            "prefill_n_swaps": self.n_swaps,
        }


class PrefillWorker:
    """One prefill replica: batch queue + adapter cache + serialized compute.

    The executor provides ``prefill_time(req)``, ``adapter_bytes(aid)``,
    ``shared_bytes()`` and ``kv_bytes(req)`` (see
    :class:`~repro.serving.engine.CostModelExecutor`).
    """

    def __init__(self, cfg: PrefillConfig, executor,
                 cluster_of: Optional[Dict[int, int]] = None):
        if cfg.max_batch < 1:
            raise ValueError("PrefillConfig.max_batch must be >= 1")
        self.cfg = cfg
        self.executor = executor
        self.scheduler = Scheduler(SchedulerConfig(max_batch=cfg.max_batch),
                                   cluster_of)
        self.cache = AdapterCache(CacheConfig(cfg.adapter_budget_bytes))
        if cfg.mode == "jd":
            self.cache.pin_shared(executor.shared_bytes())
        self.clock = 0.0
        self.link_free_at = 0.0
        self.waiting: List[Request] = []
        self.stats = PrefillStats()

    @property
    def outstanding(self) -> int:
        return len(self.waiting)

    def submit(self, reqs: Sequence[Request]) -> None:
        self.waiting.extend(reqs)
        self.waiting.sort(key=lambda r: r.arrival_time)

    def _handoff(self, req: Request) -> None:
        """Ship the KV cache over this worker's link (serialized) and stamp
        the decode-readiness time."""
        nbytes = self.executor.kv_bytes(req)
        start = max(self.clock, self.link_free_at)
        t_done = start + self.cfg.link.time_for(nbytes)
        self.link_free_at = t_done
        req.prefill_done_time = self.clock
        req.transfer_time = t_done - self.clock
        req.decode_ready_time = t_done
        req.prefilled = True
        self.stats.transfer_time += req.transfer_time
        self.stats.kv_bytes_moved += nbytes

    def step(self) -> bool:
        """Prefill one admitted batch; returns False when drained."""
        if not self.waiting:
            return False
        self.clock = max(self.clock, self.waiting[0].arrival_time)
        batch = self.scheduler.admit([], self.waiting,
                                     self.cache.resident_ids, self.clock)
        if not batch:
            # unreachable by construction (clock was advanced to the head
            # arrival and max_batch >= 1); fail loudly rather than letting
            # drain() spin forever if a scheduler change breaks that
            raise RuntimeError("prefill scheduler admitted nothing while "
                               f"{len(self.waiting)} requests wait")
        # overlapped DMA for the batch's adapters; stall on the max
        t_ready = self.clock
        for r in batch:
            t_ready = max(t_ready, self.cache.ensure(
                r.adapter_id, self.executor.adapter_bytes(r.adapter_id),
                self.clock))
        stall = max(0.0, t_ready - self.clock)
        self.clock += stall
        self.stats.swap_time += stall
        # prefill is compute-bound: serialize within the batch; each request
        # hands its KV off as soon as its own prefill finishes
        for r in batch:
            self.waiting.remove(r)
            r.start_time = self.clock
            t_pre = self.executor.prefill_time(r)
            self.clock += t_pre
            self.stats.compute_time += t_pre
            self.stats.n_prefills += 1
            self._handoff(r)
        return True

    def drain(self) -> None:
        while self.step():
            pass
        self.stats.n_swaps = self.cache.n_swaps


class PrefillTier:
    """Routes requests across prefill workers and runs them to completion.

    Routing is least-outstanding with a deterministic index tiebreak (the
    tier has no adapter-affinity pressure of its own at jd mode — shared
    bases are pinned on every worker — and lora-mode affinity is dominated
    by keeping the tier's queues short)."""

    def __init__(self, cfg: PrefillConfig, workers: Sequence[PrefillWorker]):
        if len(workers) != cfg.n_workers:
            raise ValueError(f"expected {cfg.n_workers} workers, "
                             f"got {len(workers)}")
        self.cfg = cfg
        self.workers = list(workers)

    def submit(self, reqs: Sequence[Request]) -> None:
        for r in sorted(reqs, key=lambda r: r.arrival_time):
            i = min(range(len(self.workers)),
                    key=lambda j: (self.workers[j].outstanding,
                                   self.workers[j].clock, j))
            r.prefill_replica = i
            self.workers[i].submit([r])

    def drain(self) -> None:
        for w in self.workers:
            w.drain()

    def process(self, reqs: Sequence[Request]) -> List[Request]:
        """Submit + drain; returns the same requests, now KV-ready-stamped.
        Incremental: worker clocks/queues persist across calls, so the
        autoscaler can feed arrival windows one at a time."""
        self.submit(reqs)
        self.drain()
        return list(reqs)

    @property
    def stats(self) -> PrefillStats:
        return PrefillStats.merged([w.stats for w in self.workers])
