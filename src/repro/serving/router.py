"""Fleet-level serving: N engine replicas behind a pluggable router.

The paper serves one replica; production serves fleets, and under skewed
adapter popularity the *routing policy* decides how much pinned-base reuse
each replica gets (S-LoRA §6; arXiv:2511.22880).  Policies:

  "round_robin"        — classic stateless spread.
  "least_outstanding"  — route to the replica with the fewest queued+running
                         requests at arrival time (live state: the fleet
                         advances each replica's simulated clock to the
                         arrival before deciding).
  "adapter_affinity"   — sticky adapter -> replica map; repeat requests for
                         an adapter land where it is already warm.
  "cluster_affinity"   — sticky JD-cluster -> replica map; co-locates
                         adapters sharing a compressed basis so each replica
                         streams few shared bases and maximizes pinned-base
                         reuse.  Bounded work-balance spill (route to the
                         least-loaded replica once the home replica is more
                         than `spill_requests` requests' worth of work ahead
                         of the lightest) prevents hot clusters from
                         hot-spotting the fleet under Zipf skew.

All policies are deterministic given the request stream.

Two orthogonal production extensions on top of the policies:

  * **Disaggregated prefill** — pass a
    :class:`~repro.serving.prefill.PrefillTier`: requests are routed
    prefill-tier-first (the tier stamps ``decode_ready_time`` via the
    shared :class:`~repro.serving.resources.KVFabric` — first chunk landed),
    then placed on decode replicas with the configured policy; decode
    engines admit a request only once enough of its KV has landed.
  * **Cross-tier adapter prefetch** — with
    ``FleetConfig.cross_tier_prefetch`` a request entering prefill hints
    its routed decode replica's :meth:`AdapterCache.prefetch` at prefill
    ADMISSION time: the adapter's background load overlaps the prefill
    compute and KV transfer, so it is warm when decode admits the request
    (hints are low priority — they never evict and never delay a demand
    load).
  * **Elastic membership** — :meth:`add_replica` / :meth:`retire_replica`
    let an autoscaler grow/shrink the decode tier mid-stream.  Retired
    replicas drain their queue but receive no new work; membership changes
    re-home JD clusters (sticky affinity maps are rebuilt against the new
    active set on next sighting).  The prefill tier has the symmetric
    operations (``PrefillTier.add_worker`` / ``retire_worker``), so a joint
    autoscaler can trade capacity between the tiers under one fixed
    :class:`~repro.serving.resources.HardwareBudget`.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence

from .engine import CostModelExecutor, ServingEngine
from .prefill import PrefillTier
from .request import Request, ServeStats, weight_key
from .resources import (FabricConfig, KVFabric, MigrationTicket,
                        kv_bytes_per_token, merge_mode_dict)

POLICIES = ("round_robin", "least_outstanding", "adapter_affinity",
            "cluster_affinity")


def rank_efficiency(rank: int, tile_rank: int = 8) -> float:
    """Useful fraction of the SGMV rank lanes a rank-`rank` adapter
    occupies on a slice whose native contraction tile is `tile_rank` wide:
    ``rank / (tile_rank * ceil(rank / tile_rank))``, in (0, 1].

    Jax-free mirror of :func:`repro.kernels.sgmv.sgmv_rank_efficiency`
    (the router must stay importable without jax, the same reason
    PAGE_TOKENS is duplicated); ``tests/test_hetero.py`` asserts the two
    agree (invariant H4)."""
    if rank < 1:
        raise ValueError("rank must be >= 1")
    if tile_rank < 1:
        raise ValueError("tile_rank must be >= 1")
    return rank / (tile_rank * -(-rank // tile_rank))


@dataclasses.dataclass
class FleetConfig:
    n_replicas: int = 1
    policy: str = "round_robin"
    # affinity policies: allowed routed-work imbalance (home vs lightest
    # replica) before a request spills, in units of average request work
    spill_requests: float = 1.0
    # disaggregated serving: route requests through a prefill tier before
    # decode placement (the tier itself is passed to Fleet — it owns
    # executors/caches that FleetConfig cannot describe)
    disaggregated: bool = False
    # cross-tier adapter prefetch: a request entering prefill is a perfect
    # predictor of the adapter its decode replica needs a few hundred ms
    # later, so hint that replica's AdapterCache.prefetch at prefill
    # admission time (low priority: never evicts, never delays demand)
    cross_tier_prefetch: bool = False
    # live migration (PR 9): the decode→decode interconnect checkpointed
    # KV ships over in a COLOCATED fleet.  Disaggregated fleets ignore
    # this and reuse the prefill tier's contended fabric — migration
    # traffic competes with prefill handoffs for the same wire.  None
    # builds a default FabricConfig lazily on first migration.
    migration_fabric: Optional[FabricConfig] = None
    # rank-aware placement (PR 10): bias the affinity policies by each
    # replica's rank-efficiency score — decode speed times the SGMV tile
    # efficiency of the request's adapter rank on that replica's slice
    # (rank_efficiency; the jax mirror is kernels/sgmv.py) — so high-rank
    # adapters land on wide-tile slices and skinny ranks on narrow ones.
    # Needs a Fleet built with `rank_of`; off (the default) is bit-exact
    # with the rank-blind router.
    rank_aware: bool = False
    # what a mid-run-attached replica's routed-load estimate starts at:
    # "zero" (legacy — the cold replica compares a full-history backlog
    # against warm peers and hot-spots until it catches up) or
    # "peer_mean" (the mean of its active peers' estimates, so it joins
    # the spill comparison as an average citizen and picks up work as
    # peers pull ahead)
    routed_load_seed: str = "zero"


@dataclasses.dataclass
class MigrationStats:
    """Fleet-level live-migration accounting (every :meth:`Fleet.migrate`),
    including the per-mode wire split so compressed checkpoint traffic is
    auditable against the handoff traffic sharing the same fabric."""

    n_migrations: int = 0            # completed live moves
    n_retire_migrations: int = 0     # moved by instant scale-down
    n_preempt_migrations: int = 0    # moved to make room (pages/priority)
    n_defrag_migrations: int = 0     # moved home by affinity defrag
    migration_time: float = 0.0      # sum of checkpoint -> KV-landed spans
    compress_time: float = 0.0       # wire quantize cost before shipping
    kv_raw_bytes: int = 0            # checkpointed KV across all moves
    kv_wire_bytes: int = 0           # bytes actually shipped
    n_by_mode: Dict[str, int] = dataclasses.field(default_factory=dict)
    wire_bytes_by_mode: Dict[str, int] = dataclasses.field(
        default_factory=dict)
    raw_bytes_by_mode: Dict[str, int] = dataclasses.field(
        default_factory=dict)

    @property
    def empty(self) -> bool:
        return self.n_migrations == 0

    def _bump(self, mode: str, wire: int, raw: int) -> None:
        merge_mode_dict(self.n_by_mode, {mode: 1})
        merge_mode_dict(self.wire_bytes_by_mode, {mode: wire})
        merge_mode_dict(self.raw_bytes_by_mode, {mode: raw})

    def to_dict(self) -> Dict:
        return {
            "n_migrations": self.n_migrations,
            "n_retire_migrations": self.n_retire_migrations,
            "n_preempt_migrations": self.n_preempt_migrations,
            "n_defrag_migrations": self.n_defrag_migrations,
            "migration_time_s": self.migration_time,
            "compress_time_s": self.compress_time,
            "kv_raw_bytes": self.kv_raw_bytes,
            "kv_wire_bytes": self.kv_wire_bytes,
            "n_by_mode": dict(self.n_by_mode),
            "wire_bytes_by_mode": dict(self.wire_bytes_by_mode),
            "raw_bytes_by_mode": dict(self.raw_bytes_by_mode),
        }


@dataclasses.dataclass
class FleetStats:
    total: ServeStats
    per_replica: List[ServeStats]
    prefill: Optional[Dict] = None       # PrefillStats.to_dict() if disagg
    n_replicas_final: Optional[int] = None   # active replicas at drain time
    scale_events: int = 0                # autoscaler membership changes
    autoscaler: Optional[List] = None    # ScaleDecision history if autoscaled
    n_prefill_final: Optional[int] = None    # active prefill workers (joint)
    budget: Optional[Dict] = None        # HardwareBudget.to_dict() (joint)
    lifecycle: Optional[Dict] = None     # LifecycleStats.to_dict() (churn)
    migration: Optional[Dict] = None     # MigrationStats.to_dict() (PR 9)

    def to_dict(self) -> Dict:
        d = self.total.to_dict()
        d["n_replicas"] = len(self.per_replica)
        d["per_replica_rps"] = [s.throughput_rps for s in self.per_replica]
        d["per_replica_n_requests"] = [s.n_requests for s in self.per_replica]
        if self.prefill is not None:
            d.update(self.prefill)
        if self.n_replicas_final is not None:
            d["n_replicas_final"] = self.n_replicas_final
            d["scale_events"] = self.scale_events
        if self.n_prefill_final is not None:
            d["n_prefill_final"] = self.n_prefill_final
        if self.budget is not None:
            d["budget"] = self.budget
        if self.lifecycle is not None:
            d["lifecycle"] = self.lifecycle
        if self.migration is not None:
            d["migration"] = self.migration
        return d


class Fleet:
    """Routes a request stream across replicas and runs them to completion.

    Each replica is an independent :class:`ServingEngine` with its own
    simulated clock; fleet wall time is the slowest replica's clock.
    :meth:`submit` may be called repeatedly with successive arrival
    windows (routing state persists), :meth:`advance_to` steps every
    replica causally to a window boundary, and :meth:`run` drains the
    fleet and merges per-replica stats.  Membership is elastic
    (:meth:`add_replica` / :meth:`retire_replica`); sticky affinity state
    lives in a key -> replica home map that membership changes prune
    *scoped* (:meth:`rehome`) and the adapter lifecycle drains per key
    (:meth:`drop_home`).  `cluster_of` — shared with every replica's
    executor — maps adapter ids to JD clusters for the cluster-affinity
    policy; the lifecycle control plane mutates it in place when adapters
    register or retire, and every reader sees the update.
    """

    def __init__(self, cfg: FleetConfig, engines: Sequence[ServingEngine],
                 cluster_of: Optional[Dict[int, int]] = None,
                 prefill_tier: Optional[PrefillTier] = None,
                 rank_of: Optional[Dict[int, int]] = None):
        if len(engines) != cfg.n_replicas:
            raise ValueError(f"expected {cfg.n_replicas} engines, "
                             f"got {len(engines)}")
        if cfg.policy not in POLICIES:
            raise ValueError(f"unknown policy {cfg.policy!r}; "
                             f"one of {POLICIES}")
        if cfg.routed_load_seed not in ("zero", "peer_mean"):
            raise ValueError(f"routed_load_seed must be 'zero' or "
                             f"'peer_mean', got {cfg.routed_load_seed!r}")
        if cfg.rank_aware and rank_of is None:
            raise ValueError("rank_aware routing needs a rank_of map "
                             "(adapter id -> LoRA rank)")
        if cfg.disaggregated != (prefill_tier is not None):
            raise ValueError("disaggregated fleets need a prefill_tier and "
                             "colocated fleets must not pass one: got "
                             f"disaggregated={cfg.disaggregated}, "
                             f"prefill_tier={prefill_tier!r}")
        self.cfg = cfg
        self.engines = list(engines)
        self.cluster_of = cluster_of or {}
        self.rank_of = rank_of or {}
        self.prefill_tier = prefill_tier
        self.active: List[bool] = [True] * len(engines)
        self._rr = 0
        self._home: Dict[int, int] = {}          # affinity key -> replica
        self._routed_load: List[float] = [0.0] * len(engines)  # est. seconds
        self.assignments: Dict[int, int] = {}    # rid -> replica
        self.scale_events = 0
        self.migration = MigrationStats()
        self._mig_fabric: Optional[KVFabric] = None  # colocated, lazy

    # -- elastic membership -------------------------------------------------
    def _active_idxs(self) -> List[int]:
        return [i for i, a in enumerate(self.active) if a]

    def add_replica(self, engine: ServingEngine, now: float = 0.0) -> int:
        """Join a fresh decode replica at simulated time `now`.

        Existing affinity homes stay valid (the new replica holds none), so
        warm adapters keep their cache locality; the new replica fills up
        through first sightings and bounded spill.

        Its routed-load estimate starts per ``FleetConfig.routed_load_seed``:
        at zero (legacy — against peers carrying a full run's cumulative
        estimate the newcomer looks infinitely light, so every spill and
        first sighting dumps there until it catches up), or at the mean of
        its active peers' estimates (``"peer_mean"`` — it enters the spill
        comparison as an average citizen and starts receiving work within
        a window as peers pull ahead, without the hot-spot)."""
        seed = 0.0
        if self.cfg.routed_load_seed == "peer_mean":
            peers = [self._routed_load[i] for i in self._active_idxs()]
            if peers:
                seed = sum(peers) / len(peers)
        engine.clock = max(engine.clock, now)
        self.engines.append(engine)
        self.active.append(True)
        self._routed_load.append(seed)
        self.scale_events += 1
        return len(self.engines) - 1

    def retire_replica(self, i: int, migrate: bool = False,
                       now: float = 0.0) -> None:
        """Stop routing to replica `i`.

        Drain-based (the default, bit-exact with the pre-migration
        fleet): the replica accepts no new work but runs its queue to
        completion, so its hardware is genuinely free only when the last
        request finishes.  Instant scale-down (``migrate=True``): every
        request still on the replica — running mid-decode or queued — is
        live-migrated to the least-loaded surviving replica at `now`, so
        the replica is EMPTY at retire time and its budget slice can be
        re-allocated immediately instead of after the drain tail."""
        if not self.active[i]:
            return
        if len(self._active_idxs()) == 1:
            raise ValueError("cannot retire the last active replica")
        self.active[i] = False
        self.scale_events += 1
        self.rehome(i)
        if migrate:
            eng = self.engines[i]
            for req in list(eng.running) + list(eng.waiting):
                self.migrate(req, self._least_outstanding(), now)
                self.migration.n_retire_migrations += 1

    def rehome(self, replica: Optional[int] = None) -> None:
        """Drop sticky affinity placements so affected adapters/JD-clusters
        re-place against the current active set on next sighting.

        Scoped to `replica` when given: only keys homed THERE are dropped —
        a membership change must not cold-start the cache locality of
        adapters homed on unrelated replicas (they keep their warm caches).
        With ``replica=None`` every home is dropped (a full re-shuffle,
        e.g. after an offline basis rebuild changes cluster_of wholesale)."""
        if replica is None:
            self._home.clear()
            return
        for key in [k for k, h in self._home.items() if h == replica]:
            del self._home[key]

    def drop_home(self, key: int) -> None:
        """Forget the sticky home for one affinity key (an adapter id, or a
        JD cluster id under ``cluster_affinity``) — the lifecycle's
        retirement drain uses this so a retired adapter stops pinning
        placement state (invariant L5)."""
        self._home.pop(key, None)

    # -- live migration (PR 9) ----------------------------------------------
    def migration_fabric(self) -> KVFabric:
        """The channel checkpointed KV ships over: the prefill tier's
        contended fabric when disaggregated (migrations compete with
        prefill handoffs for the same wire), else a lazily built
        decode→decode fabric from ``FleetConfig.migration_fabric``."""
        if self.prefill_tier is not None:
            return self.prefill_tier.fabric
        if self._mig_fabric is None:
            self._mig_fabric = KVFabric(self.cfg.migration_fabric
                                        or FabricConfig())
        return self._mig_fabric

    def migrate(self, req: Request, target: int, now: float) -> float:
        """Live-migrate `req` to replica `target` at simulated time `now`.

        The source engine checkpoints the request — decode slot vacated,
        KV pages freed immediately (invariant M3) — and the full decoded
        prefix (prompt + every generated token) ships over
        :meth:`migration_fabric` as ONE transfer, wire-quantized by the
        fabric's compression plan exactly like a prefill handoff.  The
        transfer is recorded against a :class:`MigrationTicket
        <repro.serving.resources.MigrationTicket>` rather than the
        request, so the original handoff accounting survives and every
        wire byte is charged exactly once (M2); the stamped values fold
        into the request's cumulative ``mig_*`` counters.  The target
        pays the checkpoint's dequant at re-admission
        (`Request.kv_restore_cost`) and resumes decode at the same
        `generated` position (M1).  The quantize cost is charged to the
        transfer's start, not the source's decode clock — the source is
        shedding this request, its remaining batch must not stall.  The
        target's adapter cache is hinted through
        :meth:`AdapterCache.prefetch
        <repro.serving.adapter_cache.AdapterCache.prefetch>`, which
        dedupes against residency and in-flight hints, so a stale hint
        for the source (or a repeat migration) never double-loads (M4).
        Returns the time decode may resume on the target (the first wire
        chunk's landing; `now` for zero-KV moves)."""
        source = self.assignments.get(req.rid, req.replica)
        if source is None:
            raise ValueError(f"request {req.rid} was never routed")
        if source == target:
            raise ValueError(f"request {req.rid} is already on {target}")
        if not self.active[target]:
            raise ValueError(f"cannot migrate to retired replica {target}")
        src_eng, dst_eng = self.engines[source], self.engines[target]
        nbytes = src_eng.checkpoint(req)
        src_eng.stats.n_migrated_out += 1
        dst_eng.cache.prefetch(
            weight_key(req), dst_eng.executor.adapter_bytes(req.adapter_id),
            now)
        if nbytes > 0:
            fabric = self.migration_fabric()
            tokens = req.prompt_len + req.generated
            ticket = MigrationTicket(rid=req.rid, prompt_len=tokens)
            comp = fabric.plan(ticket, now, nbytes)
            ready = now
            if comp is not None:
                ready += comp.compress_time(
                    nbytes, kv_bytes_per_token(nbytes, tokens))
            fabric.request(ticket, ready, nbytes, comp=comp)
            fabric.resolve()
            req.mig_raw_bytes += ticket.kv_raw_bytes
            req.mig_wire_bytes += ticket.kv_wire_bytes
            req.kv_restore_cost += ticket.kv_decompress_cost
            # not admissible on the target before its first chunk lands
            req.decode_ready_time = ticket.decode_ready_time
            resume, landed = ticket.decode_ready_time, ticket.kv_landed_time
            self.migration.compress_time += ready - now
            self.migration.kv_raw_bytes += ticket.kv_raw_bytes
            self.migration.kv_wire_bytes += ticket.kv_wire_bytes
            self.migration._bump(ticket.wire_mode, ticket.kv_wire_bytes,
                                 ticket.kv_raw_bytes)
        else:
            resume = landed = now
        req.replica = target
        req.migrated_from = source
        req.migrations += 1
        req.migration_time += landed - now
        self.assignments[req.rid] = target
        if self.cfg.policy in ("adapter_affinity", "cluster_affinity"):
            w = self._remaining_work(req)
            self._routed_load[source] = max(0.0,
                                            self._routed_load[source] - w)
            self._routed_load[target] += w
        dst_eng.stats.n_migrated_in += 1
        dst_eng.submit([req])
        self.migration.n_migrations += 1
        self.migration.migration_time += landed - now
        return resume

    def _remaining_work(self, req: Request) -> float:
        """`_work_estimate` restricted to the tokens `req` has left — the
        share of routed load that moves replicas with a migration."""
        ex = self.engines[0].executor
        if isinstance(ex, CostModelExecutor):
            bs = self.engines[0].cfg.scheduler.max_batch
            step = ex.decode_step_time([req] * bs)
            pre = 0.0 if req.prefilled else ex.prefill_time(req)
            return pre + (req.max_new_tokens - req.generated) * step / bs
        return float(req.max_new_tokens - req.generated)

    # -- live state helpers -------------------------------------------------
    def _advance_to(self, t: float) -> None:
        """Step every replica's simulation up to (at least) time t so that
        queue-depth observations at an arrival are causal."""
        for eng in self.engines:
            while (eng.running or
                   (eng.waiting and eng.waiting[0].ready_time <= t)) \
                    and eng.clock < t:
                if not eng.step():
                    break

    def advance_to(self, t: float) -> None:
        """Public window driver for elastic serving (see autoscaler)."""
        self._advance_to(t)

    def _outstanding(self, i: int) -> int:
        eng = self.engines[i]
        return len(eng.running) + len(eng.waiting)

    def _least_outstanding(self, among: Optional[Sequence[int]] = None) -> int:
        idxs = self._active_idxs() if among is None else among
        return min(idxs, key=lambda i: (self._outstanding(i), i))

    # -- policies -----------------------------------------------------------
    def _route_round_robin(self, req: Request) -> int:
        idxs = self._active_idxs()
        i = idxs[self._rr % len(idxs)]
        self._rr += 1
        return i

    def _route_least_outstanding(self, req: Request) -> int:
        self._advance_to(req.ready_time)
        return self._least_outstanding()

    def _affinity_key(self, req: Request) -> int:
        if self.cfg.policy == "cluster_affinity":
            return self.cluster_of.get(req.adapter_id, req.adapter_id)
        return req.adapter_id

    def _rank_score(self, i: int, rank: int) -> float:
        """Replica `i`'s effective decode throughput for a rank-`rank`
        adapter: the slice's decode-speed factor discounted by the SGMV
        tile efficiency of that rank on the slice's native tile width.
        Replicas without a slice type score as the legacy accelerator
        (speed 1.0, tile 8)."""
        st = getattr(self.engines[i], "slice_type", None)
        speed = st.decode_speed if st is not None else 1.0
        tile = st.sgmv_tile_rank if st is not None else 8
        return speed * rank_efficiency(rank, tile)

    def _route_affinity(self, req: Request) -> int:
        key = self._affinity_key(req)
        home = self._home.get(key)
        idxs = self._active_idxs()
        rank = (self.rank_of.get(req.adapter_id)
                if self.cfg.rank_aware else None)
        if rank is None:
            lightest = min(idxs, key=lambda i: (self._routed_load[i], i))
        else:
            # rank-aware: the best replica minimizes this request's
            # effective finish estimate — queued work plus one average
            # request, deflated by the replica's rank score — so a fast
            # wide-tile slice absorbs high-rank adapters (its padding is
            # free there) while skinny ranks prefer narrow-tile replicas
            # even when the wide slice has spare capacity.  Ties (notably
            # an idle fleet, where every estimate is zero) break toward
            # the higher rank score, then the lower index.
            w = self._avg_request_work()
            lightest = min(idxs, key=lambda i: (
                (self._routed_load[i] + w) / self._rank_score(i, rank),
                -self._rank_score(i, rank), i))
        if home is None or not self.active[home]:
            # first sighting (or home retired): place on the least-loaded
            # active replica
            self._home[key] = lightest
            return lightest
        # bounded spill: sticky only while the home replica's routed work
        # stays within `spill_requests` average requests of the lightest
        slack = self.cfg.spill_requests * self._avg_request_work()
        if self._routed_load[home] - self._routed_load[lightest] > slack:
            return lightest
        return home

    def _avg_request_work(self) -> float:
        n = len(self.assignments)
        return (sum(self._routed_load) / n) if n else 0.0

    def _work_estimate(self, req: Request) -> float:
        """Estimated replica-seconds this request costs (prefill + its share
        of full decode batches).  Falls back to a token count for executors
        without a cost model."""
        ex = self.engines[0].executor
        # only the analytic executor is side-effect free to probe; a real
        # executor's cost hooks actually run model steps
        if isinstance(ex, CostModelExecutor):
            bs = self.engines[0].cfg.scheduler.max_batch
            step = ex.decode_step_time([req] * bs)
            pre = 0.0 if req.prefilled else ex.prefill_time(req)
            return pre + req.max_new_tokens * step / bs
        return float(req.prompt_len + req.max_new_tokens)

    def _router(self) -> Callable[[Request], int]:
        return {
            "round_robin": self._route_round_robin,
            "least_outstanding": self._route_least_outstanding,
            "adapter_affinity": self._route_affinity,
            "cluster_affinity": self._route_affinity,
        }[self.cfg.policy]

    # -- public API ---------------------------------------------------------
    def submit(self, requests: Sequence[Request]) -> None:
        """Route `requests` to decode replicas (prefill-tier-first when
        disaggregated).  May be called repeatedly with successive arrival
        windows; routing state persists across calls."""
        if self.prefill_tier is not None:
            # prefill tier runs first and stamps decode_ready_time; decode
            # placement happens in KV-arrival order
            self.prefill_tier.process(requests)
        route = self._router()
        # routed-load accounting feeds the affinity policies' spill logic
        # only; skip the per-request cost probe for the stateless policies
        track_load = self.cfg.policy in ("adapter_affinity",
                                         "cluster_affinity")
        for r in sorted(requests, key=lambda r: r.ready_time):
            i = route(r)
            r.replica = i
            self.assignments[r.rid] = i
            if track_load:
                self._routed_load[i] += self._work_estimate(r)
            if self.prefill_tier is not None and self.cfg.cross_tier_prefetch:
                # hint the decode cache as of prefill ADMISSION — the KV
                # will not land for another prefill + transfer, which is
                # exactly the head start the background copy engine needs
                eng = self.engines[i]
                hint_at = (r.start_time if r.start_time is not None
                           else r.ready_time)
                eng.cache.prefetch(
                    weight_key(r), eng.executor.adapter_bytes(r.adapter_id),
                    hint_at)
            self.engines[i].submit([r])

    def run(self, max_steps: int = 10_000_000) -> FleetStats:
        per = [eng.run(max_steps) for eng in self.engines]
        # live migration can rehome work onto a replica drained earlier in
        # the pass — sweep again until a full pass leaves every queue
        # empty.  Bounded: each request's moves are capped (the M5
        # starvation guard declines over-cap rehomes, falling back to a
        # local host swap), so migration-free fleets exit after one pass,
        # bit-exact with the sequential drain.
        while any(eng.running or eng.waiting for eng in self.engines):
            per = [eng.run(max_steps) for eng in self.engines]
        return FleetStats(
            total=ServeStats.merged(per), per_replica=per,
            prefill=(self.prefill_tier.stats.to_dict()
                     if self.prefill_tier is not None else None),
            n_replicas_final=len(self._active_idxs()),
            scale_events=self.scale_events,
            migration=(None if self.migration.empty
                       else self.migration.to_dict()))

    def replicas_of_adapter(self, requests: Sequence[Request]) -> Dict[int, set]:
        """adapter_id -> set of replicas its requests were routed to."""
        out: Dict[int, set] = {}
        for r in requests:
            if r.rid in self.assignments:
                out.setdefault(r.adapter_id, set()).add(self.assignments[r.rid])
        return out
