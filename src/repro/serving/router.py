"""Fleet-level serving: N engine replicas behind a pluggable router.

The paper serves one replica; production serves fleets, and under skewed
adapter popularity the *routing policy* decides how much pinned-base reuse
each replica gets (S-LoRA §6; arXiv:2511.22880).  Policies:

  "round_robin"        — classic stateless spread.
  "least_outstanding"  — route to the replica with the fewest queued+running
                         requests at arrival time (live state: the fleet
                         advances each replica's simulated clock to the
                         arrival before deciding).
  "adapter_affinity"   — sticky adapter -> replica map; repeat requests for
                         an adapter land where it is already warm.
  "cluster_affinity"   — sticky JD-cluster -> replica map; co-locates
                         adapters sharing a compressed basis so each replica
                         streams few shared bases and maximizes pinned-base
                         reuse.  Bounded work-balance spill (route to the
                         least-loaded replica once the home replica is more
                         than `spill_requests` requests' worth of work ahead
                         of the lightest) prevents hot clusters from
                         hot-spotting the fleet under Zipf skew.

All policies are deterministic given the request stream.

Two orthogonal production extensions on top of the policies:

  * **Disaggregated prefill** — pass a
    :class:`~repro.serving.prefill.PrefillTier`: requests are routed
    prefill-tier-first (the tier stamps ``decode_ready_time`` via the
    shared :class:`~repro.serving.resources.KVFabric` — first chunk landed),
    then placed on decode replicas with the configured policy; decode
    engines admit a request only once enough of its KV has landed.
  * **Cross-tier adapter prefetch** — with
    ``FleetConfig.cross_tier_prefetch`` a request entering prefill hints
    its routed decode replica's :meth:`AdapterCache.prefetch` at prefill
    ADMISSION time: the adapter's background load overlaps the prefill
    compute and KV transfer, so it is warm when decode admits the request
    (hints are low priority — they never evict and never delay a demand
    load).
  * **Elastic membership** — :meth:`add_replica` / :meth:`retire_replica`
    let an autoscaler grow/shrink the decode tier mid-stream.  Retired
    replicas drain their queue but receive no new work; membership changes
    re-home JD clusters (sticky affinity maps are rebuilt against the new
    active set on next sighting).  The prefill tier has the symmetric
    operations (``PrefillTier.add_worker`` / ``retire_worker``), so a joint
    autoscaler can trade capacity between the tiers under one fixed
    :class:`~repro.serving.resources.HardwareBudget`.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence

from .engine import CostModelExecutor, ServingEngine
from .prefill import PrefillTier
from .request import Request, ServeStats, weight_key

POLICIES = ("round_robin", "least_outstanding", "adapter_affinity",
            "cluster_affinity")


@dataclasses.dataclass
class FleetConfig:
    n_replicas: int = 1
    policy: str = "round_robin"
    # affinity policies: allowed routed-work imbalance (home vs lightest
    # replica) before a request spills, in units of average request work
    spill_requests: float = 1.0
    # disaggregated serving: route requests through a prefill tier before
    # decode placement (the tier itself is passed to Fleet — it owns
    # executors/caches that FleetConfig cannot describe)
    disaggregated: bool = False
    # cross-tier adapter prefetch: a request entering prefill is a perfect
    # predictor of the adapter its decode replica needs a few hundred ms
    # later, so hint that replica's AdapterCache.prefetch at prefill
    # admission time (low priority: never evicts, never delays demand)
    cross_tier_prefetch: bool = False


@dataclasses.dataclass
class FleetStats:
    total: ServeStats
    per_replica: List[ServeStats]
    prefill: Optional[Dict] = None       # PrefillStats.to_dict() if disagg
    n_replicas_final: Optional[int] = None   # active replicas at drain time
    scale_events: int = 0                # autoscaler membership changes
    autoscaler: Optional[List] = None    # ScaleDecision history if autoscaled
    n_prefill_final: Optional[int] = None    # active prefill workers (joint)
    budget: Optional[Dict] = None        # HardwareBudget.to_dict() (joint)
    lifecycle: Optional[Dict] = None     # LifecycleStats.to_dict() (churn)

    def to_dict(self) -> Dict:
        d = self.total.to_dict()
        d["n_replicas"] = len(self.per_replica)
        d["per_replica_rps"] = [s.throughput_rps for s in self.per_replica]
        d["per_replica_n_requests"] = [s.n_requests for s in self.per_replica]
        if self.prefill is not None:
            d.update(self.prefill)
        if self.n_replicas_final is not None:
            d["n_replicas_final"] = self.n_replicas_final
            d["scale_events"] = self.scale_events
        if self.n_prefill_final is not None:
            d["n_prefill_final"] = self.n_prefill_final
        if self.budget is not None:
            d["budget"] = self.budget
        if self.lifecycle is not None:
            d["lifecycle"] = self.lifecycle
        return d


class Fleet:
    """Routes a request stream across replicas and runs them to completion.

    Each replica is an independent :class:`ServingEngine` with its own
    simulated clock; fleet wall time is the slowest replica's clock.
    :meth:`submit` may be called repeatedly with successive arrival
    windows (routing state persists), :meth:`advance_to` steps every
    replica causally to a window boundary, and :meth:`run` drains the
    fleet and merges per-replica stats.  Membership is elastic
    (:meth:`add_replica` / :meth:`retire_replica`); sticky affinity state
    lives in a key -> replica home map that membership changes prune
    *scoped* (:meth:`rehome`) and the adapter lifecycle drains per key
    (:meth:`drop_home`).  `cluster_of` — shared with every replica's
    executor — maps adapter ids to JD clusters for the cluster-affinity
    policy; the lifecycle control plane mutates it in place when adapters
    register or retire, and every reader sees the update.
    """

    def __init__(self, cfg: FleetConfig, engines: Sequence[ServingEngine],
                 cluster_of: Optional[Dict[int, int]] = None,
                 prefill_tier: Optional[PrefillTier] = None):
        if len(engines) != cfg.n_replicas:
            raise ValueError(f"expected {cfg.n_replicas} engines, "
                             f"got {len(engines)}")
        if cfg.policy not in POLICIES:
            raise ValueError(f"unknown policy {cfg.policy!r}; "
                             f"one of {POLICIES}")
        if cfg.disaggregated != (prefill_tier is not None):
            raise ValueError("disaggregated fleets need a prefill_tier and "
                             "colocated fleets must not pass one: got "
                             f"disaggregated={cfg.disaggregated}, "
                             f"prefill_tier={prefill_tier!r}")
        self.cfg = cfg
        self.engines = list(engines)
        self.cluster_of = cluster_of or {}
        self.prefill_tier = prefill_tier
        self.active: List[bool] = [True] * len(engines)
        self._rr = 0
        self._home: Dict[int, int] = {}          # affinity key -> replica
        self._routed_load: List[float] = [0.0] * len(engines)  # est. seconds
        self.assignments: Dict[int, int] = {}    # rid -> replica
        self.scale_events = 0

    # -- elastic membership -------------------------------------------------
    def _active_idxs(self) -> List[int]:
        return [i for i, a in enumerate(self.active) if a]

    def add_replica(self, engine: ServingEngine, now: float = 0.0) -> int:
        """Join a fresh decode replica at simulated time `now`.

        Existing affinity homes stay valid (the new replica holds none), so
        warm adapters keep their cache locality; the new replica fills up
        through first sightings and bounded spill."""
        engine.clock = max(engine.clock, now)
        self.engines.append(engine)
        self.active.append(True)
        self._routed_load.append(0.0)
        self.scale_events += 1
        return len(self.engines) - 1

    def retire_replica(self, i: int) -> None:
        """Stop routing to replica `i`; it drains its remaining queue."""
        if not self.active[i]:
            return
        if len(self._active_idxs()) == 1:
            raise ValueError("cannot retire the last active replica")
        self.active[i] = False
        self.scale_events += 1
        self.rehome(i)

    def rehome(self, replica: Optional[int] = None) -> None:
        """Drop sticky affinity placements so affected adapters/JD-clusters
        re-place against the current active set on next sighting.

        Scoped to `replica` when given: only keys homed THERE are dropped —
        a membership change must not cold-start the cache locality of
        adapters homed on unrelated replicas (they keep their warm caches).
        With ``replica=None`` every home is dropped (a full re-shuffle,
        e.g. after an offline basis rebuild changes cluster_of wholesale)."""
        if replica is None:
            self._home.clear()
            return
        for key in [k for k, h in self._home.items() if h == replica]:
            del self._home[key]

    def drop_home(self, key: int) -> None:
        """Forget the sticky home for one affinity key (an adapter id, or a
        JD cluster id under ``cluster_affinity``) — the lifecycle's
        retirement drain uses this so a retired adapter stops pinning
        placement state (invariant L5)."""
        self._home.pop(key, None)

    # -- live state helpers -------------------------------------------------
    def _advance_to(self, t: float) -> None:
        """Step every replica's simulation up to (at least) time t so that
        queue-depth observations at an arrival are causal."""
        for eng in self.engines:
            while (eng.running or
                   (eng.waiting and eng.waiting[0].ready_time <= t)) \
                    and eng.clock < t:
                if not eng.step():
                    break

    def advance_to(self, t: float) -> None:
        """Public window driver for elastic serving (see autoscaler)."""
        self._advance_to(t)

    def _outstanding(self, i: int) -> int:
        eng = self.engines[i]
        return len(eng.running) + len(eng.waiting)

    def _least_outstanding(self, among: Optional[Sequence[int]] = None) -> int:
        idxs = self._active_idxs() if among is None else among
        return min(idxs, key=lambda i: (self._outstanding(i), i))

    # -- policies -----------------------------------------------------------
    def _route_round_robin(self, req: Request) -> int:
        idxs = self._active_idxs()
        i = idxs[self._rr % len(idxs)]
        self._rr += 1
        return i

    def _route_least_outstanding(self, req: Request) -> int:
        self._advance_to(req.ready_time)
        return self._least_outstanding()

    def _affinity_key(self, req: Request) -> int:
        if self.cfg.policy == "cluster_affinity":
            return self.cluster_of.get(req.adapter_id, req.adapter_id)
        return req.adapter_id

    def _route_affinity(self, req: Request) -> int:
        key = self._affinity_key(req)
        home = self._home.get(key)
        idxs = self._active_idxs()
        lightest = min(idxs, key=lambda i: (self._routed_load[i], i))
        if home is None or not self.active[home]:
            # first sighting (or home retired): place on the least-loaded
            # active replica
            self._home[key] = lightest
            return lightest
        # bounded spill: sticky only while the home replica's routed work
        # stays within `spill_requests` average requests of the lightest
        slack = self.cfg.spill_requests * self._avg_request_work()
        if self._routed_load[home] - self._routed_load[lightest] > slack:
            return lightest
        return home

    def _avg_request_work(self) -> float:
        n = len(self.assignments)
        return (sum(self._routed_load) / n) if n else 0.0

    def _work_estimate(self, req: Request) -> float:
        """Estimated replica-seconds this request costs (prefill + its share
        of full decode batches).  Falls back to a token count for executors
        without a cost model."""
        ex = self.engines[0].executor
        # only the analytic executor is side-effect free to probe; a real
        # executor's cost hooks actually run model steps
        if isinstance(ex, CostModelExecutor):
            bs = self.engines[0].cfg.scheduler.max_batch
            step = ex.decode_step_time([req] * bs)
            pre = 0.0 if req.prefilled else ex.prefill_time(req)
            return pre + req.max_new_tokens * step / bs
        return float(req.prompt_len + req.max_new_tokens)

    def _router(self) -> Callable[[Request], int]:
        return {
            "round_robin": self._route_round_robin,
            "least_outstanding": self._route_least_outstanding,
            "adapter_affinity": self._route_affinity,
            "cluster_affinity": self._route_affinity,
        }[self.cfg.policy]

    # -- public API ---------------------------------------------------------
    def submit(self, requests: Sequence[Request]) -> None:
        """Route `requests` to decode replicas (prefill-tier-first when
        disaggregated).  May be called repeatedly with successive arrival
        windows; routing state persists across calls."""
        if self.prefill_tier is not None:
            # prefill tier runs first and stamps decode_ready_time; decode
            # placement happens in KV-arrival order
            self.prefill_tier.process(requests)
        route = self._router()
        # routed-load accounting feeds the affinity policies' spill logic
        # only; skip the per-request cost probe for the stateless policies
        track_load = self.cfg.policy in ("adapter_affinity",
                                         "cluster_affinity")
        for r in sorted(requests, key=lambda r: r.ready_time):
            i = route(r)
            r.replica = i
            self.assignments[r.rid] = i
            if track_load:
                self._routed_load[i] += self._work_estimate(r)
            if self.prefill_tier is not None and self.cfg.cross_tier_prefetch:
                # hint the decode cache as of prefill ADMISSION — the KV
                # will not land for another prefill + transfer, which is
                # exactly the head start the background copy engine needs
                eng = self.engines[i]
                hint_at = (r.start_time if r.start_time is not None
                           else r.ready_time)
                eng.cache.prefetch(
                    weight_key(r), eng.executor.adapter_bytes(r.adapter_id),
                    hint_at)
            self.engines[i].submit([r])

    def run(self, max_steps: int = 10_000_000) -> FleetStats:
        per = [eng.run(max_steps) for eng in self.engines]
        return FleetStats(
            total=ServeStats.merged(per), per_replica=per,
            prefill=(self.prefill_tier.stats.to_dict()
                     if self.prefill_tier is not None else None),
            n_replicas_final=len(self._active_idxs()),
            scale_events=self.scale_events)

    def replicas_of_adapter(self, requests: Sequence[Request]) -> Dict[int, set]:
        """adapter_id -> set of replicas its requests were routed to."""
        out: Dict[int, set] = {}
        for r in requests:
            if r.rid in self.assignments:
                out.setdefault(r.adapter_id, set()).add(self.assignments[r.rid])
        return out
