"""Fleet-level serving: N engine replicas behind a pluggable router.

The paper serves one replica; production serves fleets, and under skewed
adapter popularity the *routing policy* decides how much pinned-base reuse
each replica gets (S-LoRA §6; arXiv:2511.22880).  Policies:

  "round_robin"        — classic stateless spread.
  "least_outstanding"  — route to the replica with the fewest queued+running
                         requests at arrival time (live state: the fleet
                         advances each replica's simulated clock to the
                         arrival before deciding).
  "adapter_affinity"   — sticky adapter -> replica map; repeat requests for
                         an adapter land where it is already warm.
  "cluster_affinity"   — sticky JD-cluster -> replica map; co-locates
                         adapters sharing a compressed basis so each replica
                         streams few shared bases and maximizes pinned-base
                         reuse.  Bounded work-balance spill (route to the
                         least-loaded replica once the home replica is more
                         than `spill_requests` requests' worth of work ahead
                         of the lightest) prevents hot clusters from
                         hot-spotting the fleet under Zipf skew.

All policies are deterministic given the request stream.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence

from .engine import CostModelExecutor, ServingEngine
from .request import Request, ServeStats

POLICIES = ("round_robin", "least_outstanding", "adapter_affinity",
            "cluster_affinity")


@dataclasses.dataclass
class FleetConfig:
    n_replicas: int = 1
    policy: str = "round_robin"
    # affinity policies: allowed routed-work imbalance (home vs lightest
    # replica) before a request spills, in units of average request work
    spill_requests: float = 1.0


@dataclasses.dataclass
class FleetStats:
    total: ServeStats
    per_replica: List[ServeStats]

    def to_dict(self) -> Dict:
        d = self.total.to_dict()
        d["n_replicas"] = len(self.per_replica)
        d["per_replica_rps"] = [s.throughput_rps for s in self.per_replica]
        d["per_replica_n_requests"] = [s.n_requests for s in self.per_replica]
        return d


class Fleet:
    """Routes a request stream across replicas and runs them to completion.

    Each replica is an independent :class:`ServingEngine` with its own
    simulated clock; fleet wall time is the slowest replica's clock.
    """

    def __init__(self, cfg: FleetConfig, engines: Sequence[ServingEngine],
                 cluster_of: Optional[Dict[int, int]] = None):
        if len(engines) != cfg.n_replicas:
            raise ValueError(f"expected {cfg.n_replicas} engines, "
                             f"got {len(engines)}")
        if cfg.policy not in POLICIES:
            raise ValueError(f"unknown policy {cfg.policy!r}; "
                             f"one of {POLICIES}")
        self.cfg = cfg
        self.engines = list(engines)
        self.cluster_of = cluster_of or {}
        self._rr = 0
        self._home: Dict[int, int] = {}          # affinity key -> replica
        self._routed_load: List[float] = [0.0] * len(engines)  # est. seconds
        self.assignments: Dict[int, int] = {}    # rid -> replica

    # -- live state helpers -------------------------------------------------
    def _advance_to(self, t: float) -> None:
        """Step every replica's simulation up to (at least) time t so that
        queue-depth observations at an arrival are causal."""
        for eng in self.engines:
            while (eng.running or
                   (eng.waiting and eng.waiting[0].arrival_time <= t)) \
                    and eng.clock < t:
                if not eng.step():
                    break

    def _outstanding(self, i: int) -> int:
        eng = self.engines[i]
        return len(eng.running) + len(eng.waiting)

    def _least_outstanding(self, among: Optional[Sequence[int]] = None) -> int:
        idxs = range(len(self.engines)) if among is None else among
        return min(idxs, key=lambda i: (self._outstanding(i), i))

    # -- policies -----------------------------------------------------------
    def _route_round_robin(self, req: Request) -> int:
        i = self._rr % len(self.engines)
        self._rr += 1
        return i

    def _route_least_outstanding(self, req: Request) -> int:
        self._advance_to(req.arrival_time)
        return self._least_outstanding()

    def _affinity_key(self, req: Request) -> int:
        if self.cfg.policy == "cluster_affinity":
            return self.cluster_of.get(req.adapter_id, req.adapter_id)
        return req.adapter_id

    def _route_affinity(self, req: Request) -> int:
        key = self._affinity_key(req)
        home = self._home.get(key)
        lightest = min(range(len(self.engines)),
                       key=lambda i: (self._routed_load[i], i))
        if home is None:
            # first sighting: place on the least-loaded replica so far
            self._home[key] = lightest
            return lightest
        # bounded spill: sticky only while the home replica's routed work
        # stays within `spill_requests` average requests of the lightest
        slack = self.cfg.spill_requests * self._avg_request_work()
        if self._routed_load[home] - self._routed_load[lightest] > slack:
            return lightest
        return home

    def _avg_request_work(self) -> float:
        n = len(self.assignments)
        return (sum(self._routed_load) / n) if n else 0.0

    def _work_estimate(self, req: Request) -> float:
        """Estimated replica-seconds this request costs (prefill + its share
        of full decode batches).  Falls back to a token count for executors
        without a cost model."""
        ex = self.engines[0].executor
        # only the analytic executor is side-effect free to probe; a real
        # executor's cost hooks actually run model steps
        if isinstance(ex, CostModelExecutor):
            bs = self.engines[0].cfg.scheduler.max_batch
            step = ex.decode_step_time([req] * bs)
            return ex.prefill_time(req) + req.max_new_tokens * step / bs
        return float(req.prompt_len + req.max_new_tokens)

    def _router(self) -> Callable[[Request], int]:
        return {
            "round_robin": self._route_round_robin,
            "least_outstanding": self._route_least_outstanding,
            "adapter_affinity": self._route_affinity,
            "cluster_affinity": self._route_affinity,
        }[self.cfg.policy]

    # -- public API ---------------------------------------------------------
    def submit(self, requests: Sequence[Request]) -> None:
        route = self._router()
        for r in sorted(requests, key=lambda r: r.arrival_time):
            i = route(r)
            r.replica = i
            self.assignments[r.rid] = i
            self._routed_load[i] += self._work_estimate(r)
            self.engines[i].submit([r])

    def run(self, max_steps: int = 10_000_000) -> FleetStats:
        per = [eng.run(max_steps) for eng in self.engines]
        return FleetStats(total=ServeStats.merged(per), per_replica=per)

    def replicas_of_adapter(self, requests: Sequence[Request]) -> Dict[int, set]:
        """adapter_id -> set of replicas its requests were routed to."""
        out: Dict[int, set] = {}
        for r in requests:
            if r.rid in self.assignments:
                out.setdefault(r.adapter_id, set()).add(self.assignments[r.rid])
        return out
