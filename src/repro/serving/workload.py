"""Workload generation for serving studies: adapter popularity models
(uniform / Zipf), arrival processes (batch / Poisson / bursty Gamma), and
CSV trace replay.

The paper's §6.4 setup (uniform popularity, asynchronous arrivals) is the
default — ``WorkloadSpec()`` with no overrides draws the *identical* request
stream the original single-replica study used, so seed numbers reproduce
bit-exactly.  Skewed popularity and bursty arrivals model what S-LoRA-style
production traces actually look like: a few hot adapters dominate and
traffic arrives in bursts, which is where fleet routing policy matters.
"""
from __future__ import annotations

import csv
import dataclasses
import warnings
from typing import List, Sequence

import numpy as np

from .request import Request


@dataclasses.dataclass
class WorkloadSpec:
    """Describes a synthetic request stream.

    popularity:
      "uniform" — every adapter equally likely (paper §6.4);
      "zipf"    — P(rank k) ∝ 1/k**zipf_alpha over the adapter set.
    arrival:
      "batch"   — everything at t=0 (arrival_rate ignored);
      "poisson" — exponential inter-arrivals at `arrival_rate` req/s;
      "gamma"   — Gamma inter-arrivals, same mean, `burst_cv` coefficient
                  of variation (>1 = bursty clumps, 1 = Poisson).
      With arrival_rate == 0 every process degenerates to "batch".
    """
    n_requests: int = 1000
    n_adapters: int = 64
    popularity: str = "uniform"      # uniform | zipf
    zipf_alpha: float = 1.0
    shuffle_ranks: bool = True       # decouple adapter id from popularity rank
    arrival: str = "poisson"         # batch | poisson | gamma
    arrival_rate: float = 0.0        # mean req/s; 0 = all at t=0
    burst_cv: float = 4.0            # gamma only
    prompt_len_mean: int = 128       # sonnet-ish prompts
    prompt_len_std: int = 32
    new_tokens: int = 10             # paper: ten tokens per request
    seed: int = 0


def zipf_pmf(n: int, alpha: float) -> np.ndarray:
    ranks = np.arange(1, n + 1, dtype=np.float64)
    w = ranks ** (-alpha)
    return w / w.sum()


def make_workload(spec: WorkloadSpec) -> List[Request]:
    """Generate the request stream described by `spec`.

    RNG call order (inter-arrival, prompt length, adapter id — per request)
    matches the original uniform generator so default configs reproduce the
    seed study exactly.
    """
    rng = np.random.default_rng(spec.seed)
    pmf = None
    rank_of = None
    if spec.popularity == "zipf":
        pmf = zipf_pmf(spec.n_adapters, spec.zipf_alpha)
        rank_of = np.arange(spec.n_adapters)
        if spec.shuffle_ranks:
            # separate stream: must not perturb the per-request draws
            rank_of = np.random.default_rng(
                spec.seed + 0x5EED).permutation(spec.n_adapters)
    elif spec.popularity != "uniform":
        raise ValueError(f"unknown popularity model: {spec.popularity!r}")
    if spec.arrival not in ("batch", "poisson", "gamma"):
        raise ValueError(f"unknown arrival process: {spec.arrival!r}")

    mean_gap = 1.0 / spec.arrival_rate if spec.arrival_rate > 0 else 0.0
    if spec.arrival == "gamma":
        k = 1.0 / (spec.burst_cv ** 2)      # CV = 1/sqrt(k)
        theta = mean_gap / k if k else 0.0

    t = 0.0
    out: List[Request] = []
    for i in range(spec.n_requests):
        if mean_gap and spec.arrival == "poisson":
            t += rng.exponential(mean_gap)
        elif mean_gap and spec.arrival == "gamma":
            t += rng.gamma(k, theta)
        plen = int(np.clip(rng.normal(spec.prompt_len_mean,
                                      spec.prompt_len_std),
                           16, 4 * spec.prompt_len_mean))
        if pmf is None:
            aid = int(rng.integers(spec.n_adapters))
        else:
            aid = int(rank_of[rng.choice(spec.n_adapters, p=pmf)])
        out.append(Request(rid=i, adapter_id=aid, prompt_len=plen,
                           max_new_tokens=spec.new_tokens, arrival_time=t))
    return out


# ---------------------------------------------------------------------------
# trace replay
# ---------------------------------------------------------------------------

TRACE_COLUMNS = ("arrival_time", "adapter_id", "prompt_len", "max_new_tokens")


def load_trace(path: str) -> List[Request]:
    """Replay a CSV trace with columns arrival_time,adapter_id,prompt_len,
    max_new_tokens (header required; extra columns ignored).

    Real traces are frequently written by concurrent frontends and arrive
    with out-of-order timestamps; replaying them unsorted would produce
    negative inter-arrival gaps (and non-causal queue dynamics), so the
    loader sorts by arrival time — warning when it had to — and renumbers
    ``rid`` to the replay order."""
    out: List[Request] = []
    with open(path, newline="") as f:
        reader = csv.DictReader(f)
        missing = [c for c in TRACE_COLUMNS if c not in (reader.fieldnames or [])]
        if missing:
            raise ValueError(f"trace {path} missing columns: {missing}")
        for i, row in enumerate(reader):
            out.append(Request(
                rid=i, adapter_id=int(row["adapter_id"]),
                prompt_len=int(row["prompt_len"]),
                max_new_tokens=int(row["max_new_tokens"]),
                arrival_time=float(row["arrival_time"])))
    if any(a.arrival_time > b.arrival_time for a, b in zip(out, out[1:])):
        warnings.warn(f"trace {path} has out-of-order timestamps; "
                      "sorting by arrival_time for replay", stacklevel=2)
        out.sort(key=lambda r: r.arrival_time)
        for i, r in enumerate(out):
            r.rid = i
    return out


def save_trace(path: str, requests: Sequence[Request]) -> None:
    with open(path, "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(TRACE_COLUMNS)
        for r in requests:
            w.writerow([r.arrival_time, r.adapter_id, r.prompt_len,
                        r.max_new_tokens])
