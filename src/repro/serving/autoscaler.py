"""SLO-driven decode-tier autoscaling for elastic fleets.

The ROADMAP's autoscaling item: `Fleet` exposes per-replica load and latency
percentiles; this module closes the loop.  An :class:`Autoscaler` watches
windowed TTFT/TPOT percentiles against a :class:`SLOConfig` and decides to
add or retire decode replicas; :func:`run_autoscaled` drives a fleet through
the request stream in decision windows, applying those decisions and
re-homing JD clusters on every membership change (``Fleet.rehome``).

The policy is deliberately simple and deterministic (simulations must be
reproducible): threshold + hysteresis + cooldown, the shape production
autoscalers (KEDA/HPA-style) reduce to once jitter is removed.

  - scale UP when the window's p95 TTFT (or p95 TPOT) exceeds its SLO, or
    when the window starved (backlog but no finishes — the fleet is so far
    behind that latency samples stopped arriving);
  - scale DOWN when p95 TTFT sits below ``down_fraction`` of the SLO and
    the backlog is small — hysteresis so the fleet doesn't flap;
  - at most ``max_step`` replicas change per decision, with
    ``cooldown_intervals`` quiet windows after any change.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Sequence

import numpy as np

from .request import Request
from .router import Fleet, FleetStats
from .engine import ServingEngine


@dataclasses.dataclass
class SLOConfig:
    """Latency objectives, evaluated at p95 over each decision window."""
    ttft_p95: float = 0.25           # seconds arrival -> first token
    tpot_p95: float = float("inf")   # seconds/token after the first

    def violated(self, ttft_p95: float, tpot_p95: float) -> bool:
        return ttft_p95 > self.ttft_p95 or tpot_p95 > self.tpot_p95


@dataclasses.dataclass
class AutoscalerConfig:
    min_replicas: int = 1
    max_replicas: int = 8
    decision_interval: float = 0.25  # simulated seconds per window
    down_fraction: float = 0.4       # scale down only below this SLO fraction
    backlog_per_replica: float = 4.0  # "small backlog" bound for scale-down
    cooldown_intervals: int = 2      # quiet windows after a change
    max_step: int = 1                # replicas changed per decision


@dataclasses.dataclass
class ScaleDecision:
    t: float
    n_active: int
    ttft_p95: float
    tpot_p95: float
    backlog: int
    delta: int


class Autoscaler:
    """Threshold/hysteresis policy over windowed latency percentiles."""

    def __init__(self, cfg: AutoscalerConfig, slo: SLOConfig):
        self.cfg = cfg
        self.slo = slo
        self.history: List[ScaleDecision] = []
        self._cooldown = 0

    def decide(self, now: float, ttfts: Sequence[float],
               tpots: Sequence[float], n_active: int, backlog: int) -> int:
        """Replica-count delta for this window (>0 add, <0 retire)."""
        ttft_p95 = float(np.percentile(ttfts, 95)) if len(ttfts) else 0.0
        tpot_p95 = float(np.percentile(tpots, 95)) if len(tpots) else 0.0
        starved = not ttfts and backlog > 0
        delta = 0
        if self._cooldown > 0:
            self._cooldown -= 1
        elif (starved or self.slo.violated(ttft_p95, tpot_p95)) \
                and n_active < self.cfg.max_replicas:
            delta = min(self.cfg.max_step, self.cfg.max_replicas - n_active)
        elif (ttfts and not self.slo.violated(ttft_p95, tpot_p95)
              and ttft_p95 < self.cfg.down_fraction * self.slo.ttft_p95
              and backlog <= self.cfg.backlog_per_replica * n_active
              and n_active > self.cfg.min_replicas):
            delta = -min(self.cfg.max_step, n_active - self.cfg.min_replicas)
        if delta:
            self._cooldown = self.cfg.cooldown_intervals
        self.history.append(ScaleDecision(
            t=now, n_active=n_active, ttft_p95=ttft_p95, tpot_p95=tpot_p95,
            backlog=backlog, delta=delta))
        return delta


def run_autoscaled(fleet: Fleet, requests: Sequence[Request],
                   autoscaler: Autoscaler,
                   engine_factory: Callable[[], ServingEngine],
                   max_steps: int = 10_000_000) -> FleetStats:
    """Drive `fleet` through `requests` in decision windows.

    Per window: route the window's arrivals (prefill-tier-first when the
    fleet is disaggregated), advance every replica to the window end,
    observe TTFT/TPOT of requests that finished inside the window, then
    apply the autoscaler's decision — ``engine_factory()`` builds a decode
    replica that joins at the window boundary; scale-down retires the most
    recently added active replica (drains, no new work).  Membership
    changes re-home JD clusters.  After the last arrival the fleet runs to
    completion and merged stats are returned.
    """
    reqs = sorted(requests, key=lambda r: r.arrival_time)
    finished: List[Request] = []

    def on_finish(r: Request) -> None:
        finished.append(r)

    for eng in fleet.engines:
        eng.on_finish = on_finish

    dt = autoscaler.cfg.decision_interval
    t = dt
    i = 0
    while True:
        j = i
        while j < len(reqs) and reqs[j].arrival_time < t:
            j += 1
        if j > i:
            fleet.submit(reqs[i:j])
            i = j
        fleet.advance_to(t)
        ttfts = [r.ttft for r in finished if r.ttft is not None]
        tpots = [r.tpot for r in finished if r.tpot is not None]
        finished.clear()
        outstanding = sum(len(eng.running) + len(eng.waiting)
                          for eng in fleet.engines)
        if i >= len(reqs) and outstanding == 0:
            break
        # decisions see only decode-actionable work: requests whose KV is
        # still in prefill/transfer (ready_time > t) cannot be helped by
        # another decode replica, and counting them would drive useless
        # scale-up against a prefill-tier bottleneck
        if i >= len(reqs):
            # drain phase: routing is over, so a new replica could never
            # receive work — taking further decisions would only inflate
            # scale_events / n_replicas_final with idle replicas
            t += dt
            continue
        backlog = sum(
            len(eng.running)
            + sum(1 for r in eng.waiting if r.ready_time <= t)
            for eng in fleet.engines)
        active = fleet._active_idxs()
        delta = autoscaler.decide(t, ttfts, tpots, len(active), backlog)
        if delta > 0:
            for _ in range(delta):
                eng = engine_factory()
                eng.on_finish = on_finish
                fleet.add_replica(eng, now=t)
        elif delta < 0:
            for _ in range(-delta):
                fleet.retire_replica(fleet._active_idxs()[-1])
        t += dt
    return fleet.run(max_steps)
