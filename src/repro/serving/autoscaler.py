"""SLO-driven autoscaling for elastic fleets: decode-only and joint.

The ROADMAP's autoscaling item: `Fleet` exposes per-replica load and latency
percentiles; this module closes the loop.  An :class:`Autoscaler` watches
windowed TTFT/TPOT percentiles against a :class:`SLOConfig` and decides to
add or retire decode replicas; :func:`run_autoscaled` drives a fleet through
the request stream in decision windows, applying those decisions and
re-homing JD clusters on every membership change (``Fleet.rehome``).

The policy is deliberately simple and deterministic (simulations must be
reproducible): threshold + hysteresis + cooldown, the shape production
autoscalers (KEDA/HPA-style) reduce to once jitter is removed.

  - scale UP when the window's p95 TTFT (or p95 TPOT) exceeds its SLO, or
    when the window starved (backlog but no finishes — the fleet is so far
    behind that latency samples stopped arriving);
  - scale DOWN when p95 TTFT sits below ``down_fraction`` of the SLO and
    the backlog is small — hysteresis so the fleet doesn't flap;
  - at most ``max_step`` replicas change per decision, with
    ``cooldown_intervals`` quiet windows after any change.

:class:`JointAutoscaler` generalizes this to *both* tiers of a
disaggregated fleet under a fixed
:class:`~repro.serving.resources.HardwareBudget`: the decode tier is scaled
from TPOT and the decode-side TTFT component exactly as above, the prefill
tier from its queue depth and its TTFT contribution (arrival ->
decode-ready), and when the budget pool is exhausted the policy *trades* —
it retires a worker/replica from a comfortable tier to fund the pressured
one.  :func:`run_joint_autoscaled` is the matching window driver.

With compressed KV handoffs
(:class:`~repro.serving.resources.KVCompressionConfig`) the decode tier
also pays a per-request dequantization cost at admission; the driver
reports that load as a window utilization fraction and the policy refuses
to classify a decode tier cold while it exceeds
``decompress_cold_util`` — wire compression must not trick the trader
into robbing the tier that is paying for it.

With unified paging (decode engines built over a
:class:`~repro.serving.resources.PagedPool`) the joint autoscaler's budget
accounting sees *pages*, not just whole-replica footprints: the driver
reports the worst replica's pool utilization (``kv_page_util``, fraction of
pages in use) and the policy classifies a page-saturated decode tier hot —
admissions there are blocking on memory, which latency percentiles can
miss entirely when the running batch is small but its KV reservations are
large — and never cold, so a trade cannot retire the replica that is the
fleet's page headroom.

With an *adaptive* fabric policy
(:class:`~repro.serving.resources.AdaptiveCompressionPolicy`) the joint
autoscaler gains a third axis: the policy's mode ceiling.  When the
prefill tier is hot, the pool is exhausted, and the fabric horizon
(``fabric_lag_s``) shows the wire is actually the pressure, the policy's
ceiling is raised — trading quantization error for bytes — *before* the
trader robs a cold decode tier of a replica; in quiet windows the ceiling
relaxes back so an idle fabric ships raw.  Both moves are recorded in
:class:`JointScaleDecision` (``d_comp`` / ``comp_ceiling``).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Sequence, Tuple

import numpy as np

from .prefill import PrefillWorker
from .request import Request
from .resources import AdaptiveCompressionPolicy, HardwareBudget
from .router import Fleet, FleetStats
from .engine import ServingEngine


@dataclasses.dataclass
class SLOConfig:
    """Latency objectives, evaluated at p95 over each decision window."""
    ttft_p95: float = 0.25           # seconds arrival -> first token
    tpot_p95: float = float("inf")   # seconds/token after the first

    def violated(self, ttft_p95: float, tpot_p95: float) -> bool:
        return ttft_p95 > self.ttft_p95 or tpot_p95 > self.tpot_p95


@dataclasses.dataclass
class AutoscalerConfig:
    min_replicas: int = 1
    max_replicas: int = 8
    decision_interval: float = 0.25  # simulated seconds per window
    down_fraction: float = 0.4       # scale down only below this SLO fraction
    backlog_per_replica: float = 4.0  # "small backlog" bound for scale-down
    cooldown_intervals: int = 2      # quiet windows after a change
    max_step: int = 1                # replicas changed per decision


@dataclasses.dataclass
class ScaleDecision:
    t: float
    n_active: int
    ttft_p95: float
    tpot_p95: float
    backlog: int
    delta: int


class Autoscaler:
    """Threshold/hysteresis policy over windowed latency percentiles."""

    def __init__(self, cfg: AutoscalerConfig, slo: SLOConfig):
        self.cfg = cfg
        self.slo = slo
        self.history: List[ScaleDecision] = []
        self._cooldown = 0

    def decide(self, now: float, ttfts: Sequence[float],
               tpots: Sequence[float], n_active: int, backlog: int) -> int:
        """Replica-count delta for this window (>0 add, <0 retire)."""
        ttft_p95 = float(np.percentile(ttfts, 95)) if len(ttfts) else 0.0
        tpot_p95 = float(np.percentile(tpots, 95)) if len(tpots) else 0.0
        starved = not ttfts and backlog > 0
        delta = 0
        if self._cooldown > 0:
            self._cooldown -= 1
        elif (starved or self.slo.violated(ttft_p95, tpot_p95)) \
                and n_active < self.cfg.max_replicas:
            delta = min(self.cfg.max_step, self.cfg.max_replicas - n_active)
        elif (ttfts and not self.slo.violated(ttft_p95, tpot_p95)
              and ttft_p95 < self.cfg.down_fraction * self.slo.ttft_p95
              and backlog <= self.cfg.backlog_per_replica * n_active
              and n_active > self.cfg.min_replicas):
            delta = -min(self.cfg.max_step, n_active - self.cfg.min_replicas)
        if delta:
            self._cooldown = self.cfg.cooldown_intervals
        self.history.append(ScaleDecision(
            t=now, n_active=n_active, ttft_p95=ttft_p95, tpot_p95=tpot_p95,
            backlog=backlog, delta=delta))
        return delta


# ---------------------------------------------------------------------------
# joint prefill/decode autoscaling under a fixed hardware budget
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class JointAutoscalerConfig:
    """Policy knobs for two-tier scaling under a fixed budget.

    ``prefill_share`` splits the TTFT SLO between the tiers: the prefill
    tier (queueing + prefill compute + first-chunk transfer) is considered
    pressured when its p95 contribution exceeds ``prefill_share *
    slo.ttft_p95``; the decode tier when its p95 wait (decode-ready ->
    first token) exceeds the remaining share, when p95 TPOT violates, or
    when it starves.  Hysteresis and cooldown mirror the decode-only
    policy.
    """
    min_prefill: int = 1
    min_decode: int = 1
    decision_interval: float = 0.25  # simulated seconds per window
    prefill_share: float = 0.5       # TTFT-SLO fraction budgeted to prefill
    down_fraction: float = 0.4       # scale down only below this share frac
    backlog_per_replica: float = 4.0  # per-tier "small backlog" bound
    cooldown_intervals: int = 2      # quiet windows after any change
    # compressed-KV handoff: a decode tier spending more than this fraction
    # of its window capacity on KV decompression is never classified cold —
    # retiring a replica would re-concentrate that dequantization load on
    # the survivors even when per-request decode waits look comfortable
    decompress_cold_util: float = 0.25
    # unified paging (engines with a PagedPool): a decode tier whose
    # worst replica has page utilization above page_hot_util (fraction of
    # pool pages in use, 0..1) is classified hot even when latency looks
    # fine — admissions are blocking on MEMORY, and more replicas is the
    # only lever that adds pages; the same bound vetoes the cold
    # classification, so the trader never retires a replica whose pool is
    # nearly full
    page_hot_util: float = 0.92
    # adaptive-compression axis (needs a bound AdaptiveCompressionPolicy):
    # raise the fabric's mode ceiling when prefill is hot, the pool is
    # exhausted, and the fabric's resolved horizon extends this far past
    # the window end (the wire, not prefill compute, is the pressure);
    # relax the ceiling in windows where the horizon is below the relax
    # bound and nothing is hot — but never below the ceiling the policy
    # was bound with (the autoscaler only takes back headroom it granted)
    comp_escalate_lag_s: float = 0.05
    comp_relax_lag_s: float = 0.01


@dataclasses.dataclass
class JointScaleDecision:
    t: float
    n_prefill: int
    n_decode: int
    free_accels: int
    ttft_p95: float
    tpot_p95: float
    prefill_lag_p95: float
    decode_wait_p95: float
    prefill_backlog: int
    decode_backlog: int
    d_prefill: int
    d_decode: int
    decompress_util: float = 0.0     # decode-tier KV-dequant utilization
    d_comp: int = 0                  # mode-ceiling delta (+1 raise, -1 relax)
    comp_ceiling: Optional[str] = None   # ceiling mode after this decision
    fabric_lag_s: float = 0.0        # fabric horizon past the window end
    kv_page_util: float = 0.0        # worst decode replica's page pressure
    refresh_active: bool = False     # basis-refresh rollout in flight
    # typed pools only: which slice class a +1 delta should land on
    prefill_slice: Optional[str] = None
    decode_slice: Optional[str] = None


class JointAutoscaler:
    """Trades prefill vs decode capacity under a fixed hardware budget.

    Per window each tier is classified hot / cold / ok from its own SLO
    share and backlog; a hot tier grows from the free pool when possible,
    and otherwise *takes* capacity from the other tier if that tier is
    cold (retire + drain there, add here).  Both-hot spends any free
    budget on the tier that is proportionally worse.  At most one
    worker/replica moves per tier per decision.

    Two extra signals refine the classification: ``kv_page_util`` (worst
    replica's unified-pool occupancy) marks decode hot on page pressure
    before eviction churn reaches the percentiles, and ``refresh_active``
    (a basis rollout is walking the fleet) vetoes treating decode as cold
    — comfortable mid-rollout percentiles are the rollout hiding load.
    """

    def __init__(self, cfg: JointAutoscalerConfig, slo: SLOConfig,
                 budget: HardwareBudget,
                 comp_policy: Optional[AdaptiveCompressionPolicy] = None):
        need = (cfg.min_prefill * budget.cfg.cost("prefill")
                + cfg.min_decode * budget.cfg.cost("decode"))
        if need > budget.cfg.total_units:
            raise ValueError(
                f"budget too small for the tier floors: min_prefill="
                f"{cfg.min_prefill} x {budget.cfg.cost('prefill')} accels + "
                f"min_decode={cfg.min_decode} x "
                f"{budget.cfg.cost('decode')} accels needs {need}, pool has "
                f"{budget.cfg.total_units}")
        self.cfg = cfg
        self.slo = slo
        self.budget = budget
        self.comp_policy = None
        self._comp_floor = 0
        if comp_policy is not None:
            self.bind_compression(comp_policy)
        self.history: List[JointScaleDecision] = []
        self._cooldown = 0
        # previous window's decompress_util: "sustained" decode-side
        # dequant pressure = above the cold threshold two windows running
        self._prev_decompress_util = 0.0

    def bind_compression(self, policy: AdaptiveCompressionPolicy) -> None:
        """Attach the fabric's adaptive policy as the compression axis.

        The ceiling at bind time becomes this autoscaler's relax floor: it
        only lowers a ceiling it previously raised, so a fabric configured
        to own its full ladder (``initial_ceiling=None``) is never quietly
        ratcheted down to raw by warm-up windows."""
        self.comp_policy = policy
        self._comp_floor = policy.ceiling

    def _escalate(self, fabric_lag_s: float) -> bool:
        """Raise the bound policy's mode ceiling when the wire (not
        compute) is the pressure — the free compute-for-bytes lever tried
        before any replica trade."""
        return (self.comp_policy is not None
                and fabric_lag_s > self.cfg.comp_escalate_lag_s
                and self.comp_policy.raise_ceiling())

    @staticmethod
    def _p95(xs: Sequence[float]) -> float:
        return float(np.percentile(xs, 95)) if len(xs) else 0.0

    def pick_slice(self, role: str, extra_units: int = 0):
        """Which slice class a +1 `role` delta should land on (None for an
        untyped pool — the legacy accelerator).

        Preference order encodes the tiers' rooflines: **prefill** wants
        the fastest compute per worker (big slices first — prefill is
        compute-bound and one fast worker beats two slow ones on p95 lag),
        **decode** wants the best bandwidth *per cost unit* (small slices
        first at equal efficiency — decode scales out and more replicas
        mean more aggregate HBM streams and more pool pages).  The first
        affordable type in preference order wins, where "affordable"
        includes `extra_units` a same-decision trade is about to free;
        with nothing affordable the cheapest type is returned so the
        caller's exhaustion handling (escalate / trade) sees the floor
        price."""
        cfg = self.budget.cfg
        if not cfg.typed:
            return None
        if role == "prefill":
            def key(st):
                return (-st.prefill_speed, st.cost(role), st.name)
        else:
            def key(st):
                return (-(st.decode_speed / st.cost(role)),
                        st.cost(role), st.name)
        ranked = sorted(cfg.types(), key=key)
        affordable = self.budget.available + extra_units
        for st in ranked:
            if st.cost(role) <= affordable:
                return st
        return min(ranked, key=lambda st: st.cost(role))

    def _trade_frees_enough(self, donor: str, receiver: str,
                            donor_units: Optional[int] = None) -> bool:
        """Retiring one `donor` unit must free enough cost units for one
        `receiver` unit.  Footprints differ per role AND per slice type:
        `donor_units` is the actual cost of the unit that would retire (a
        typed fleet's donor tier can hold mixed slice classes — the
        driver reports what its scale-down victim occupies); left None,
        the legacy per-role footprint / cheapest-type floor is assumed.
        The receiver side prices the slice :meth:`pick_slice` would
        choose given the freed units."""
        du = (donor_units if donor_units is not None
              else self.budget.cfg.cost(donor))
        ru = self.budget.cfg.cost(
            receiver, self.pick_slice(receiver, extra_units=du))
        return self.budget.available + du >= ru

    def decide(self, now: float, ttfts: Sequence[float],
               tpots: Sequence[float], decode_waits: Sequence[float],
               prefill_lags: Sequence[float], n_prefill: int, n_decode: int,
               prefill_backlog: int, decode_backlog: int,
               decompress_util: float = 0.0,
               fabric_lag_s: float = 0.0,
               kv_page_util: float = 0.0,
               refresh_active: bool = False,
               retire_prefill_units: Optional[int] = None,
               retire_decode_units: Optional[int] = None) -> Tuple[int, int]:
        """(prefill delta, decode delta) for this window, each in -1/0/+1.

        Units: latency sequences are per-request **seconds** observed in
        the window; backlogs are request **counts**; ``decompress_util``,
        ``kv_page_util`` are dimensionless fractions in [0, 1];
        ``fabric_lag_s`` is **seconds**.

        ``decompress_util`` is the decode tier's window-fraction spent
        dequantizing compressed KV handoffs (0 when the fabric ships raw
        KV); it vetoes the cold classification — see
        :attr:`JointAutoscalerConfig.decompress_cold_util`.

        ``fabric_lag_s`` is how far the KV fabric's resolved horizon
        extends past the window end — the wire-saturation signal that
        gates the compression axis: a bound adaptive policy's ceiling is
        raised (instead of a trade) only when the wire is actually the
        pressure, and relaxed only in windows where it is quiet.

        ``kv_page_util`` is the worst decode replica's unified-pool page
        utilization (0 for non-paged engines): above
        :attr:`JointAutoscalerConfig.page_hot_util` the decode tier is
        memory-pressured — hot regardless of latency, and never cold.

        ``refresh_active`` is the adapter lifecycle's rollout signal: a
        basis refresh is walking the decode replicas one at a time
        (``AdapterLifecycle``, docs/lifecycle.md).  It vetoes the cold
        classification — replicas take turns stalled on base swaps, so a
        comfortable window percentile is the rollout hiding load, and
        retiring a replica mid-rollout would churn the replica set the
        rollout is walking.

        ``retire_prefill_units`` / ``retire_decode_units`` (typed pools):
        the cost units the tier's scale-down victim actually occupies —
        what a trade would free.  None falls back to the per-role
        footprint (exact for untyped pools, the cheapest-type floor for
        typed ones)."""
        cfg = self.cfg
        ttft_p95 = self._p95(ttfts)
        tpot_p95 = self._p95(tpots)
        pre_p95 = self._p95(prefill_lags)
        dwait_p95 = self._p95(decode_waits)

        pre_slo = cfg.prefill_share * self.slo.ttft_p95
        dec_slo = (1.0 - cfg.prefill_share) * self.slo.ttft_p95
        pre_hot = (pre_p95 > pre_slo
                   or prefill_backlog > cfg.backlog_per_replica * n_prefill)
        pre_cold = (not pre_hot
                    and pre_p95 < cfg.down_fraction * pre_slo
                    and prefill_backlog <= n_prefill)
        starved = not ttfts and decode_backlog > 0
        dec_hot = (starved or tpot_p95 > self.slo.tpot_p95
                   or dwait_p95 > dec_slo
                   or decode_backlog > cfg.backlog_per_replica * n_decode
                   or kv_page_util > cfg.page_hot_util)
        dec_cold = (not dec_hot and bool(ttfts)
                    and dwait_p95 < cfg.down_fraction * dec_slo
                    and tpot_p95 <= cfg.down_fraction * min(self.slo.tpot_p95,
                                                            1e12)
                    and decode_backlog <= n_decode
                    and decompress_util < cfg.decompress_cold_util
                    and not refresh_active)

        d_pre = d_dec = d_comp = 0
        if self._cooldown > 0:
            self._cooldown -= 1
        elif pre_hot and dec_hot:
            # both pressured: spend free budget on the proportionally worse
            # tier (no trade — robbing a hot tier makes things worse)
            pre_sev = pre_p95 / max(pre_slo, 1e-12)
            dec_sev = dwait_p95 / max(dec_slo, 1e-12)
            if starved or tpot_p95 > self.slo.tpot_p95:
                dec_sev = max(dec_sev, 2.0 * pre_sev + 1.0)
            order = (["decode", "prefill"] if dec_sev >= pre_sev
                     else ["prefill", "decode"])
            for role in order:
                if self.budget.can_allocate(role):
                    if role == "prefill":
                        d_pre = 1
                    else:
                        d_dec = 1
                    break
            else:
                # nothing allocatable and no tier may be robbed; shrinking
                # wire bytes is the one lever that helps both tiers
                if self._escalate(fabric_lag_s):
                    d_comp = 1
        elif pre_hot:
            if self.budget.can_allocate("prefill"):
                d_pre = 1
            elif self._escalate(fabric_lag_s):
                # the pool is exhausted and the wire is the pressure:
                # spend quantization error before robbing the other tier
                d_comp = 1
            elif (dec_cold and n_decode > cfg.min_decode
                  and self._trade_frees_enough("decode", "prefill",
                                               retire_decode_units)):
                d_pre, d_dec = 1, -1             # trade: decode funds prefill
        elif dec_hot:
            if self.budget.can_allocate("decode"):
                d_dec = 1
            elif (pre_cold and n_prefill > cfg.min_prefill
                  and self._trade_frees_enough("prefill", "decode",
                                               retire_prefill_units)):
                d_pre, d_dec = -1, 1             # trade: prefill funds decode
        elif (decompress_util >= cfg.decompress_cold_util
              and self._prev_decompress_util >= cfg.decompress_cold_util
              and self.comp_policy is not None
              and self.comp_policy.ceiling > self._comp_floor
              and self.comp_policy.lower_ceiling()):
            # sustained decode-side dequant pressure (a full window above
            # the cold threshold on both sides of this decision): the
            # compression that saved wire bytes is now taxing decode
            # compute every window — relax the ceiling one level even
            # though the wire isn't quiet.  Without this branch the high
            # decompress_util itself vetoes dec_cold, so nothing on the
            # decode axis ever moved and the tax was permanent.
            d_comp = -1
        elif pre_cold and n_prefill > cfg.min_prefill:
            d_pre = -1                           # release to the pool
        elif dec_cold and n_decode > cfg.min_decode:
            d_dec = -1
        elif (self.comp_policy is not None
              and fabric_lag_s < cfg.comp_relax_lag_s
              and self.comp_policy.ceiling > self._comp_floor
              and self.comp_policy.lower_ceiling()):
            d_comp = -1                          # quiet window: ship raw again
        if d_pre or d_dec or d_comp:
            self._cooldown = cfg.cooldown_intervals
        self._prev_decompress_util = decompress_util
        pre_slice = dec_slice = None
        if self.budget.cfg.typed:
            if d_pre > 0:
                freed = (retire_decode_units
                         or self.budget.cfg.cost("decode")) if d_dec < 0 else 0
                pre_slice = self.pick_slice("prefill", extra_units=freed)
            if d_dec > 0:
                freed = (retire_prefill_units
                         or self.budget.cfg.cost("prefill")) if d_pre < 0 else 0
                dec_slice = self.pick_slice("decode", extra_units=freed)
        self.history.append(JointScaleDecision(
            t=now, n_prefill=n_prefill, n_decode=n_decode,
            free_accels=self.budget.available, ttft_p95=ttft_p95,
            tpot_p95=tpot_p95, prefill_lag_p95=pre_p95,
            decode_wait_p95=dwait_p95, prefill_backlog=prefill_backlog,
            decode_backlog=decode_backlog, d_prefill=d_pre, d_decode=d_dec,
            decompress_util=decompress_util, d_comp=d_comp,
            comp_ceiling=(self.comp_policy.ceiling_mode
                          if self.comp_policy is not None else None),
            fabric_lag_s=fabric_lag_s, kv_page_util=kv_page_util,
            refresh_active=refresh_active,
            prefill_slice=pre_slice.name if pre_slice else None,
            decode_slice=dec_slice.name if dec_slice else None))
        return d_pre, d_dec


def run_joint_autoscaled(fleet: Fleet, requests: Sequence[Request],
                         autoscaler: JointAutoscaler,
                         decode_factory: Callable[[], ServingEngine],
                         prefill_factory: Callable[[], PrefillWorker],
                         max_steps: int = 10_000_000) -> FleetStats:
    """Drive a *disaggregated* fleet through `requests`, scaling both tiers
    under the autoscaler's :class:`~repro.serving.resources.HardwareBudget`.

    Per window: route the window's arrivals (the prefill tier runs eagerly
    and stamps decode-readiness), advance every decode replica to the
    window end, observe the tiers' latency components, then apply the
    joint decision.  Membership changes are symmetric: retired decode
    replicas and prefill workers drain what they hold but receive no new
    work, and their accelerators return to the pool at retire time (the
    drain tail is the hand-over cost).  JD clusters re-home on decode
    membership changes.

    Thin wrapper over the unified window loop
    (:func:`repro.serving.simulator.run_study`), kept for its established
    signature; proven bit-exact against the committed joint baselines.
    """
    from .simulator import run_study     # local: simulator imports us
    return run_study(fleet, requests, autoscaler=autoscaler,
                     decode_factory=decode_factory,
                     prefill_factory=prefill_factory,
                     max_steps=max_steps).stats


def run_autoscaled(fleet: Fleet, requests: Sequence[Request],
                   autoscaler: Autoscaler,
                   engine_factory: Callable[[], ServingEngine],
                   max_steps: int = 10_000_000) -> FleetStats:
    """Drive `fleet` through `requests` in decision windows.

    Per window: route the window's arrivals (prefill-tier-first when the
    fleet is disaggregated), advance every replica to the window end,
    observe TTFT/TPOT of requests that finished inside the window, then
    apply the autoscaler's decision — ``engine_factory()`` builds a decode
    replica that joins at the window boundary; scale-down retires the most
    recently added active replica (drains, no new work).  Membership
    changes re-home JD clusters.  After the last arrival the fleet runs to
    completion and merged stats are returned.

    Thin wrapper over the unified window loop
    (:func:`repro.serving.simulator.run_study`), kept for its established
    signature; proven bit-exact against the committed elastic baselines.
    """
    from .simulator import run_study     # local: simulator imports us
    return run_study(fleet, requests, autoscaler=autoscaler,
                     decode_factory=engine_factory,
                     max_steps=max_steps).stats
