"""Workload generation + the paper's throughput study driver (Figs. 1 & 4).

Replicates §6.4's setup in v5e terms: N unique rank-16 LoRAs, asynchronous
request arrivals, inputs assigned to adapters uniformly at random, ten
generated tokens per request; memory-matched baseline (Appendix F): the
uncompressed engine gets an adapter budget equal to what the compressed
configuration consumes (shared bases + all Sigmas).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

from repro.serving.engine import (CostModelExecutor, EngineConfig,
                                  ModelFootprint, ServingEngine,
                                  ServingHardware)
from repro.serving.request import Request
from repro.serving.scheduler import SchedulerConfig


@dataclasses.dataclass
class WorkloadConfig:
    n_requests: int = 1000
    n_adapters: int = 64
    prompt_len_mean: int = 128       # sonnet-ish prompts
    prompt_len_std: int = 32
    new_tokens: int = 10             # paper: ten tokens per request
    arrival_rate: float = 0.0        # req/s Poisson; 0 = all at t=0
    seed: int = 0


def make_workload(cfg: WorkloadConfig) -> List[Request]:
    rng = np.random.default_rng(cfg.seed)
    t = 0.0
    out = []
    for i in range(cfg.n_requests):
        if cfg.arrival_rate > 0:
            t += rng.exponential(1.0 / cfg.arrival_rate)
        plen = int(np.clip(rng.normal(cfg.prompt_len_mean, cfg.prompt_len_std),
                           16, 4 * cfg.prompt_len_mean))
        out.append(Request(rid=i,
                           adapter_id=int(rng.integers(cfg.n_adapters)),
                           prompt_len=plen, max_new_tokens=cfg.new_tokens,
                           arrival_time=t))
    return out


# paper Appendix F: compression setting per collection size
PAPER_SETTINGS = {
    4: dict(rank=16, clusters=1), 8: dict(rank=16, clusters=1),
    16: dict(rank=32, clusters=1), 32: dict(rank=64, clusters=1),
    64: dict(rank=64, clusters=1), 128: dict(rank=16, clusters=7),
    256: dict(rank=16, clusters=10), 512: dict(rank=16, clusters=25),
    1024: dict(rank=16, clusters=25),
}


def compression_setting(n_adapters: int) -> Dict:
    keys = sorted(PAPER_SETTINGS)
    for k in keys:
        if n_adapters <= k:
            return PAPER_SETTINGS[k]
    return PAPER_SETTINGS[keys[-1]]


def run_throughput_study(model_cfg, n_adapters_list: List[int],
                         workload: Optional[WorkloadConfig] = None,
                         hw: Optional[ServingHardware] = None,
                         max_batch: int = 32,
                         cluster_assign_seed: int = 0) -> List[Dict]:
    """Compressed vs uncompressed vs single-LoRA throughput across N."""
    hw = hw or ServingHardware()
    rows = []
    for n in n_adapters_list:
        wl = dataclasses.replace(workload or WorkloadConfig(), n_adapters=n)
        setting = compression_setting(n)
        rng = np.random.default_rng(cluster_assign_seed)
        cluster_of = {a: int(rng.integers(setting["clusters"]))
                      for a in range(n)}

        fp_jd = ModelFootprint.from_config(model_cfg, jd_rank=setting["rank"],
                                           n_clusters=setting["clusters"])
        fp_lora = ModelFootprint.from_config(model_cfg)

        # memory matching (App F): baseline budget = compressed footprint
        jd_total = (fp_jd.jd_shared_bytes_per_cluster * setting["clusters"]
                    + n * fp_jd.jd_sigma_bytes_per_adapter)
        budget = max(jd_total, 2 * fp_lora.lora_bytes_per_adapter)

        results = {}
        for mode, fp in (("jd", fp_jd), ("lora", fp_lora)):
            ex = CostModelExecutor(hw, fp, mode, cluster_of)
            eng = ServingEngine(
                EngineConfig(scheduler=SchedulerConfig(max_batch=max_batch),
                             adapter_budget_bytes=budget, mode=mode),
                ex, cluster_of)
            eng.submit(make_workload(wl))
            stats = eng.run()
            results[mode] = stats.to_dict()

        # single-LoRA reference (merged into base: no adapter overhead)
        fp_single = ModelFootprint.from_config(model_cfg)
        fp_single = dataclasses.replace(fp_single, lora_bytes_per_adapter=0)
        ex1 = CostModelExecutor(hw, fp_single, "lora", {})
        wl1 = dataclasses.replace(wl, n_adapters=1)
        eng1 = ServingEngine(
            EngineConfig(scheduler=SchedulerConfig(max_batch=max_batch),
                         adapter_budget_bytes=budget, mode="lora"), ex1, {})
        eng1.submit(make_workload(wl1))
        results["single"] = eng1.run().to_dict()

        rows.append({
            "n_adapters": n, "setting": setting,
            "budget_bytes": budget,
            "jd": results["jd"], "lora": results["lora"],
            "single": results["single"],
            "throughput_ratio_jd_vs_lora":
                results["jd"]["throughput_rps"]
                / max(results["lora"]["throughput_rps"], 1e-9),
            "jd_frac_of_single":
                results["jd"]["throughput_rps"]
                / max(results["single"]["throughput_rps"], 1e-9),
        })
    return rows
