"""The paper's throughput study driver (Figs. 1 & 4), fleet-capable.

Replicates §6.4's setup in v5e terms: N unique rank-16 LoRAs, asynchronous
request arrivals, inputs assigned to adapters uniformly at random, ten
generated tokens per request; memory-matched baseline (Appendix F): the
uncompressed engine gets an adapter budget equal to what the compressed
configuration consumes (shared bases + all Sigmas).

The study now drives a :class:`repro.serving.router.Fleet` through the
workload generator in :mod:`repro.serving.workload`.  The default
configuration — one replica, uniform popularity, round-robin routing — is
the special case that reproduces the original single-replica numbers
bit-exactly; `FleetConfig(n_replicas=..., policy=...)` plus a skewed
`WorkloadSpec` opens the production scenarios (Zipf popularity, bursty
arrivals, affinity routing).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

from repro.serving.autoscaler import (Autoscaler, AutoscalerConfig,
                                      JointAutoscaler, JointAutoscalerConfig,
                                      SLOConfig, run_autoscaled,
                                      run_joint_autoscaled)
from repro.serving.engine import (CostModelExecutor, EngineConfig,
                                  ModelFootprint, ServingEngine,
                                  ServingHardware)
from repro.serving.prefill import PrefillConfig, PrefillTier, PrefillWorker
from repro.serving.request import Request
from repro.serving.resources import BudgetConfig, HardwareBudget
from repro.serving.router import Fleet, FleetConfig, FleetStats
from repro.serving.scheduler import SchedulerConfig
from repro.serving.workload import WorkloadSpec, make_workload

# Backwards-compatible names: the workload generator used to live here.
WorkloadConfig = WorkloadSpec


# paper Appendix F: compression setting per collection size
PAPER_SETTINGS = {
    4: dict(rank=16, clusters=1), 8: dict(rank=16, clusters=1),
    16: dict(rank=32, clusters=1), 32: dict(rank=64, clusters=1),
    64: dict(rank=64, clusters=1), 128: dict(rank=16, clusters=7),
    256: dict(rank=16, clusters=10), 512: dict(rank=16, clusters=25),
    1024: dict(rank=16, clusters=25),
}


def compression_setting(n_adapters: int) -> Dict:
    keys = sorted(PAPER_SETTINGS)
    for k in keys:
        if n_adapters <= k:
            return PAPER_SETTINGS[k]
    return PAPER_SETTINGS[keys[-1]]


def memory_matched_setup(model_cfg, n_adapters: int,
                         cluster_assign_seed: int = 0):
    """Appendix-F memory matching for a collection size.

    Returns (setting, cluster_of, budget): the paper's compression setting,
    a seeded random cluster assignment, and the per-replica adapter budget —
    the uncompressed baseline gets exactly what the compressed configuration
    consumes (shared bases + all Sigmas), floored at two resident LoRAs."""
    setting = compression_setting(n_adapters)
    rng = np.random.default_rng(cluster_assign_seed)
    cluster_of = {a: int(rng.integers(setting["clusters"]))
                  for a in range(n_adapters)}
    fp_jd = ModelFootprint.from_config(model_cfg, jd_rank=setting["rank"],
                                       n_clusters=setting["clusters"])
    fp_lora = ModelFootprint.from_config(model_cfg)
    jd_total = (fp_jd.jd_shared_bytes_per_cluster * setting["clusters"]
                + n_adapters * fp_jd.jd_sigma_bytes_per_adapter)
    budget = max(jd_total, 2 * fp_lora.lora_bytes_per_adapter)
    return setting, cluster_of, budget


def serving_footprint(model_cfg, mode: str, n_adapters: int,
                      setting: Dict) -> ModelFootprint:
    """The cost-model footprint build_fleet has always used for `mode`."""
    if mode == "jd":
        return ModelFootprint.from_config(model_cfg, jd_rank=setting["rank"],
                                          n_clusters=setting["clusters"])
    fp = ModelFootprint.from_config(model_cfg)
    if n_adapters <= 1:                # merged single-LoRA reference
        fp = dataclasses.replace(fp, lora_bytes_per_adapter=0)
    return fp


def build_engine(model_cfg, mode: str, n_adapters: int, budget: float,
                 hw: ServingHardware, cluster_of: Dict[int, int],
                 setting: Dict, max_batch: int = 32,
                 prefetch: bool = False,
                 pool_bytes: Optional[float] = None,
                 pool_adapter_share: Optional[float] = None) -> ServingEngine:
    """One cost-model decode replica (also the autoscaler's engine factory).

    With `pool_bytes` the replica runs unified paging: adapter weights and
    KV blocks share one paged HBM region of that many bytes
    (`pool_adapter_share` carves the static-split baseline out of the same
    machinery); `budget` is then ignored.  Without it, the legacy
    byte-budget adapter cache is used, bit-exact with the pre-paging
    engine."""
    fp = serving_footprint(model_cfg, mode, n_adapters, setting)
    ex = CostModelExecutor(hw, fp, mode, cluster_of)
    pool = (None if pool_bytes is None else
            fp.pool_config(pool_bytes, adapter_share=pool_adapter_share))
    return ServingEngine(
        EngineConfig(scheduler=SchedulerConfig(max_batch=max_batch),
                     adapter_budget_bytes=budget, mode=mode,
                     prefetch=prefetch, pool=pool),
        ex, cluster_of)


def build_prefill_worker(model_cfg, mode: str, n_adapters: int, budget: float,
                         prefill_cfg: PrefillConfig, hw: ServingHardware,
                         cluster_of: Dict[int, int],
                         setting: Dict) -> PrefillWorker:
    """One prefill worker (also the joint autoscaler's prefill factory)."""
    fp = serving_footprint(model_cfg, mode, n_adapters, setting)
    cfg = dataclasses.replace(prefill_cfg, mode=mode,
                              adapter_budget_bytes=budget)
    return PrefillWorker(cfg, CostModelExecutor(hw, fp, mode, cluster_of),
                         cluster_of)


def build_prefill_tier(model_cfg, mode: str, n_adapters: int, budget: float,
                       prefill_cfg: PrefillConfig, hw: ServingHardware,
                       cluster_of: Dict[int, int],
                       setting: Dict) -> PrefillTier:
    """Prefill workers with the same footprint/cost model and per-worker
    adapter budget as the decode tier (adapters must be resident on the
    prefill device too); all workers share the tier's KV fabric."""
    cfg = dataclasses.replace(prefill_cfg, mode=mode,
                              adapter_budget_bytes=budget)
    workers = [build_prefill_worker(model_cfg, mode, n_adapters, budget,
                                    prefill_cfg, hw, cluster_of, setting)
               for _ in range(cfg.n_workers)]
    return PrefillTier(cfg, workers)


def build_fleet(model_cfg, mode: str, n_adapters: int, budget: float,
                fleet_cfg: FleetConfig, hw: ServingHardware,
                cluster_of: Dict[int, int], setting: Dict,
                max_batch: int = 32, prefetch: bool = False,
                prefill_cfg: Optional[PrefillConfig] = None,
                pool_bytes: Optional[float] = None,
                pool_adapter_share: Optional[float] = None) -> Fleet:
    """N identical replicas of the cost-model engine for `mode`.

    Budget is per replica (each replica owns an HBM adapter region).  With
    `prefill_cfg` the fleet is disaggregated: a prefill tier (own workers,
    caches, and KV transfer link) feeds the decode replicas.  With
    `pool_bytes` each decode replica runs unified paging (see
    :func:`build_engine`)."""
    engines = [build_engine(model_cfg, mode, n_adapters, budget, hw,
                            cluster_of, setting, max_batch, prefetch,
                            pool_bytes=pool_bytes,
                            pool_adapter_share=pool_adapter_share)
               for _ in range(fleet_cfg.n_replicas)]
    tier = None
    if prefill_cfg is not None:
        fleet_cfg = dataclasses.replace(fleet_cfg, disaggregated=True)
        tier = build_prefill_tier(model_cfg, mode, n_adapters, budget,
                                  prefill_cfg, hw, cluster_of, setting)
    return Fleet(fleet_cfg, engines, cluster_of, prefill_tier=tier)


def run_elastic_study(model_cfg, mode: str, n_adapters: int,
                      requests: List[Request],
                      fleet_cfg: FleetConfig,
                      hw: Optional[ServingHardware] = None,
                      max_batch: int = 32,
                      cluster_assign_seed: int = 0,
                      prefill_cfg: Optional[PrefillConfig] = None,
                      autoscaler_cfg: Optional[AutoscalerConfig] = None,
                      slo: Optional[SLOConfig] = None,
                      budget_cfg: Optional[BudgetConfig] = None,
                      joint_cfg: Optional[JointAutoscalerConfig] = None,
                      pool_bytes: Optional[float] = None,
                      pool_adapter_share: Optional[float] = None
                      ) -> FleetStats:
    """One serving cell, optionally disaggregated and/or autoscaled.

    With `autoscaler_cfg` the fleet starts at ``fleet_cfg.n_replicas``
    decode replicas and elastically scales between the autoscaler's
    min/max against `slo`; otherwise the replica set is fixed.  With
    `budget_cfg` (requires `prefill_cfg`) the run is *jointly* autoscaled:
    both tiers start at their configured sizes and the
    :class:`~repro.serving.autoscaler.JointAutoscaler` trades prefill
    workers against decode replicas under the fixed accelerator pool.
    KV wire compression is configured on the fabric —
    ``prefill_cfg=PrefillConfig(fabric=FabricConfig(...,
    compression=KVCompressionConfig(...)))`` — and threads through the
    whole cell: workers compress, chunks ship small, decode replicas pay
    dequantization, and the joint autoscaler sees that load.  With
    ``FabricConfig(..., adaptive=AdaptiveCompressionConfig(...))`` the
    mode is instead picked per transfer from live channel backlog, and a
    jointly autoscaled run additionally drives the policy's mode ceiling
    (raised under budget-exhausted wire pressure before any replica
    trade, relaxed in quiet windows — see ``JointScaleDecision.d_comp``).
    With `pool_bytes` every decode replica (including ones the autoscaler
    adds) runs unified paging over a pool of that size;
    `pool_adapter_share` selects the static-split baseline.
    Returns merged :class:`FleetStats` (``stats.autoscaler`` holds the
    decision history when autoscaled; the prefill dict carries per-mode
    wire-byte totals)."""
    hw = hw or ServingHardware()
    setting, cluster_of, budget = memory_matched_setup(
        model_cfg, n_adapters, cluster_assign_seed)
    fleet = build_fleet(model_cfg, mode, n_adapters, budget, fleet_cfg, hw,
                        cluster_of, setting, max_batch,
                        prefill_cfg=prefill_cfg, pool_bytes=pool_bytes,
                        pool_adapter_share=pool_adapter_share)

    def decode_factory() -> ServingEngine:
        return build_engine(model_cfg, mode, n_adapters, budget, hw,
                            cluster_of, setting, max_batch,
                            pool_bytes=pool_bytes,
                            pool_adapter_share=pool_adapter_share)

    if budget_cfg is not None:
        if prefill_cfg is None:
            raise ValueError("joint autoscaling needs prefill_cfg "
                             "(disaggregated fleet)")
        scaler = JointAutoscaler(joint_cfg or JointAutoscalerConfig(),
                                 slo or SLOConfig(),
                                 HardwareBudget(budget_cfg))

        def prefill_factory() -> PrefillWorker:
            return build_prefill_worker(model_cfg, mode, n_adapters, budget,
                                        prefill_cfg, hw, cluster_of, setting)

        stats = run_joint_autoscaled(fleet, requests, scaler,
                                     decode_factory, prefill_factory)
        stats.autoscaler = scaler.history
        return stats
    if autoscaler_cfg is None:
        fleet.submit(requests)
        return fleet.run()
    scaler = Autoscaler(autoscaler_cfg, slo or SLOConfig())
    stats = run_autoscaled(fleet, requests, scaler, decode_factory)
    stats.autoscaler = scaler.history
    return stats


def run_throughput_study(model_cfg, n_adapters_list: List[int],
                         workload: Optional[WorkloadSpec] = None,
                         hw: Optional[ServingHardware] = None,
                         max_batch: int = 32,
                         cluster_assign_seed: int = 0,
                         fleet: Optional[FleetConfig] = None,
                         prefetch: bool = False) -> List[Dict]:
    """Compressed vs uncompressed vs single-LoRA throughput across N."""
    hw = hw or ServingHardware()
    fleet_cfg = fleet or FleetConfig()
    rows = []
    for n in n_adapters_list:
        wl = dataclasses.replace(workload or WorkloadSpec(), n_adapters=n)
        setting, cluster_of, budget = memory_matched_setup(
            model_cfg, n, cluster_assign_seed)

        results = {}
        for mode in ("jd", "lora"):
            fl = build_fleet(model_cfg, mode, n, budget, fleet_cfg, hw,
                             cluster_of, setting, max_batch, prefetch)
            fl.submit(make_workload(wl))
            results[mode] = fl.run().to_dict()

        # single-LoRA reference (merged into base: no adapter overhead)
        fl1 = build_fleet(model_cfg, "lora", 1, budget, fleet_cfg, hw, {},
                          setting, max_batch, prefetch)
        fl1.submit(make_workload(dataclasses.replace(wl, n_adapters=1)))
        results["single"] = fl1.run().to_dict()

        rows.append({
            "n_adapters": n, "setting": setting,
            "budget_bytes": budget,
            "n_replicas": fleet_cfg.n_replicas, "policy": fleet_cfg.policy,
            "jd": results["jd"], "lora": results["lora"],
            "single": results["single"],
            "throughput_ratio_jd_vs_lora":
                results["jd"]["throughput_rps"]
                / max(results["lora"]["throughput_rps"], 1e-9),
            "jd_frac_of_single":
                results["jd"]["throughput_rps"]
                / max(results["single"]["throughput_rps"], 1e-9),
        })
    return rows
