"""The paper's throughput study driver (Figs. 1 & 4), fleet-capable.

Replicates §6.4's setup in v5e terms: N unique rank-16 LoRAs, asynchronous
request arrivals, inputs assigned to adapters uniformly at random, ten
generated tokens per request; memory-matched baseline (Appendix F): the
uncompressed engine gets an adapter budget equal to what the compressed
configuration consumes (shared bases + all Sigmas).

The study now drives a :class:`repro.serving.router.Fleet` through the
workload generator in :mod:`repro.serving.workload`.  The default
configuration — one replica, uniform popularity, round-robin routing — is
the special case that reproduces the original single-replica numbers
bit-exactly; `FleetConfig(n_replicas=..., policy=...)` plus a skewed
`WorkloadSpec` opens the production scenarios (Zipf popularity, bursty
arrivals, affinity routing).

**The unified study driver (PR 9).**  Every serving study is one of two
shapes: submit-everything-and-drain (a fixed fleet), or a *window loop*
(arrivals and control-plane events interleaved in causal time order,
data plane advanced to each window edge, then control-plane decisions —
autoscaling, lifecycle rollouts, migrations).  :func:`run_study` is that
loop, once; ``run_autoscaled`` / ``run_joint_autoscaled``
(autoscaler.py), ``run_churn_study`` (lifecycle.py) and the entry points
here are thin wrappers over it, proven bit-exact against the committed
``BENCH_*.json`` baselines.  Scripted :class:`StudyEvent` hooks and a
:class:`~repro.serving.migration.MigrationPolicy` plug into the same
loop instead of forking a sixth driver copy; results come back as one
:class:`StudyReport`.
"""
from __future__ import annotations

import dataclasses
import inspect
from typing import Callable, Dict, List, Optional, Sequence, Union

import numpy as np

from repro.serving.autoscaler import (Autoscaler, AutoscalerConfig,
                                      JointAutoscaler, JointAutoscalerConfig,
                                      SLOConfig)
from repro.serving.engine import (CostModelExecutor, EngineConfig,
                                  ModelFootprint, ServingEngine,
                                  ServingHardware)
from repro.serving.lifecycle import (AdapterLifecycle, LifecycleEvent,
                                     apply_event)
from repro.serving.migration import MigrationPolicy
from repro.serving.prefill import PrefillConfig, PrefillTier, PrefillWorker
from repro.serving.request import Request
from repro.serving.resources import (PAGE_TOKENS, BudgetConfig,
                                     HardwareBudget, SliceType,
                                     merge_mode_dict)
from repro.serving.router import Fleet, FleetConfig, FleetStats
from repro.serving.scheduler import SchedulerConfig
from repro.serving.workload import WorkloadSpec, make_workload

# Backwards-compatible names: the workload generator used to live here.
WorkloadConfig = WorkloadSpec


# paper Appendix F: compression setting per collection size
PAPER_SETTINGS = {
    4: dict(rank=16, clusters=1), 8: dict(rank=16, clusters=1),
    16: dict(rank=32, clusters=1), 32: dict(rank=64, clusters=1),
    64: dict(rank=64, clusters=1), 128: dict(rank=16, clusters=7),
    256: dict(rank=16, clusters=10), 512: dict(rank=16, clusters=25),
    1024: dict(rank=16, clusters=25),
}


def compression_setting(n_adapters: int) -> Dict:
    keys = sorted(PAPER_SETTINGS)
    for k in keys:
        if n_adapters <= k:
            return PAPER_SETTINGS[k]
    return PAPER_SETTINGS[keys[-1]]


def memory_matched_setup(model_cfg, n_adapters: int,
                         cluster_assign_seed: int = 0):
    """Appendix-F memory matching for a collection size.

    Returns (setting, cluster_of, budget): the paper's compression setting,
    a seeded random cluster assignment, and the per-replica adapter budget —
    the uncompressed baseline gets exactly what the compressed configuration
    consumes (shared bases + all Sigmas), floored at two resident LoRAs."""
    setting = compression_setting(n_adapters)
    rng = np.random.default_rng(cluster_assign_seed)
    cluster_of = {a: int(rng.integers(setting["clusters"]))
                  for a in range(n_adapters)}
    fp_jd = ModelFootprint.from_config(model_cfg, jd_rank=setting["rank"],
                                       n_clusters=setting["clusters"])
    fp_lora = ModelFootprint.from_config(model_cfg)
    jd_total = (fp_jd.jd_shared_bytes_per_cluster * setting["clusters"]
                + n_adapters * fp_jd.jd_sigma_bytes_per_adapter)
    budget = max(jd_total, 2 * fp_lora.lora_bytes_per_adapter)
    return setting, cluster_of, budget


def serving_footprint(model_cfg, mode: str, n_adapters: int,
                      setting: Dict) -> ModelFootprint:
    """The cost-model footprint build_fleet has always used for `mode`."""
    if mode == "jd":
        return ModelFootprint.from_config(model_cfg, jd_rank=setting["rank"],
                                          n_clusters=setting["clusters"])
    fp = ModelFootprint.from_config(model_cfg)
    if n_adapters <= 1:                # merged single-LoRA reference
        fp = dataclasses.replace(fp, lora_bytes_per_adapter=0)
    return fp


def slice_pool_bytes(fp: ModelFootprint, hw: ServingHardware) -> float:
    """The unified-pool region a replica on (slice-scaled) hardware `hw`
    actually has: the serving cap of its HBM minus the resident base
    weights, floored at one page so a tiny slice still constructs."""
    page = fp.kv_bytes_per_token * PAGE_TOKENS
    return max(hw.hbm_bytes * hw.mem_cap_frac - fp.weight_bytes, page)


def build_engine(model_cfg, mode: str, n_adapters: int, budget: float,
                 hw: ServingHardware, cluster_of: Dict[int, int],
                 setting: Dict, max_batch: int = 32,
                 prefetch: bool = False,
                 pool_bytes: Optional[float] = None,
                 pool_adapter_share: Optional[float] = None,
                 slice_type: Optional[SliceType] = None,
                 rank_of: Optional[Dict[int, int]] = None) -> ServingEngine:
    """One cost-model decode replica (also the autoscaler's engine factory).

    With `pool_bytes` the replica runs unified paging: adapter weights and
    KV blocks share one paged HBM region of that many bytes
    (`pool_adapter_share` carves the static-split baseline out of the same
    machinery); `budget` is then ignored.  Without it, the legacy
    byte-budget adapter cache is used, bit-exact with the pre-paging
    engine.

    With `slice_type` the replica is typed: its hardware is scaled by the
    slice's speed factors and HBM (``ServingHardware.for_slice``), the
    executor prices per-rank SGMV padding against the slice's tile, and
    ``pool_bytes="slice"`` sizes the paged pool from the slice's own HBM
    (:func:`slice_pool_bytes`) instead of a caller-fixed region."""
    fp = serving_footprint(model_cfg, mode, n_adapters, setting)
    hw = hw.for_slice(slice_type)
    if pool_bytes == "slice":
        pool_bytes = slice_pool_bytes(fp, hw)
    ex = CostModelExecutor(hw, fp, mode, cluster_of, rank_of=rank_of,
                           slice_type=slice_type)
    pool = (None if pool_bytes is None else
            fp.pool_config(pool_bytes, adapter_share=pool_adapter_share))
    return ServingEngine(
        EngineConfig(scheduler=SchedulerConfig(max_batch=max_batch),
                     adapter_budget_bytes=budget, mode=mode,
                     prefetch=prefetch, pool=pool),
        ex, cluster_of, slice_type=slice_type)


def build_prefill_worker(model_cfg, mode: str, n_adapters: int, budget: float,
                         prefill_cfg: PrefillConfig, hw: ServingHardware,
                         cluster_of: Dict[int, int], setting: Dict,
                         slice_type: Optional[SliceType] = None
                         ) -> PrefillWorker:
    """One prefill worker (also the joint autoscaler's prefill factory).
    With `slice_type` the worker's compute roofline is scaled by the
    slice's ``prefill_speed``."""
    fp = serving_footprint(model_cfg, mode, n_adapters, setting)
    hw = hw.for_slice(slice_type)
    cfg = dataclasses.replace(prefill_cfg, mode=mode,
                              adapter_budget_bytes=budget)
    return PrefillWorker(cfg, CostModelExecutor(hw, fp, mode, cluster_of),
                         cluster_of, slice_type=slice_type)


def build_prefill_tier(model_cfg, mode: str, n_adapters: int, budget: float,
                       prefill_cfg: PrefillConfig, hw: ServingHardware,
                       cluster_of: Dict[int, int], setting: Dict,
                       slice_type: Optional[SliceType] = None) -> PrefillTier:
    """Prefill workers with the same footprint/cost model and per-worker
    adapter budget as the decode tier (adapters must be resident on the
    prefill device too); all workers share the tier's KV fabric."""
    cfg = dataclasses.replace(prefill_cfg, mode=mode,
                              adapter_budget_bytes=budget)
    workers = [build_prefill_worker(model_cfg, mode, n_adapters, budget,
                                    prefill_cfg, hw, cluster_of, setting,
                                    slice_type=slice_type)
               for _ in range(cfg.n_workers)]
    return PrefillTier(cfg, workers)


def build_fleet(model_cfg, mode: str, n_adapters: int, budget: float,
                fleet_cfg: FleetConfig, hw: ServingHardware,
                cluster_of: Dict[int, int], setting: Dict,
                max_batch: int = 32, prefetch: bool = False,
                prefill_cfg: Optional[PrefillConfig] = None,
                pool_bytes: Optional[float] = None,
                pool_adapter_share: Optional[float] = None,
                decode_slice_types: Optional[Sequence[SliceType]] = None,
                prefill_slice_type: Optional[SliceType] = None,
                rank_of: Optional[Dict[int, int]] = None) -> Fleet:
    """N replicas of the cost-model engine for `mode`.

    Budget is per replica (each replica owns an HBM adapter region).  With
    `prefill_cfg` the fleet is disaggregated: a prefill tier (own workers,
    caches, and KV transfer link) feeds the decode replicas.  With
    `pool_bytes` each decode replica runs unified paging (see
    :func:`build_engine`).

    Heterogeneous fleets: `decode_slice_types` names each replica's slice
    class (one entry per replica — replicas need no longer be identical),
    `prefill_slice_type` types the whole prefill tier, and `rank_of`
    (adapter id -> LoRA rank) feeds both the executors' per-rank byte
    model and the router's rank-aware placement
    (``FleetConfig.rank_aware``)."""
    if (decode_slice_types is not None
            and len(decode_slice_types) != fleet_cfg.n_replicas):
        raise ValueError(f"decode_slice_types names "
                         f"{len(decode_slice_types)} replicas, fleet has "
                         f"{fleet_cfg.n_replicas}")
    engines = [build_engine(model_cfg, mode, n_adapters, budget, hw,
                            cluster_of, setting, max_batch, prefetch,
                            pool_bytes=pool_bytes,
                            pool_adapter_share=pool_adapter_share,
                            slice_type=(decode_slice_types[k]
                                        if decode_slice_types else None),
                            rank_of=rank_of)
               for k in range(fleet_cfg.n_replicas)]
    tier = None
    if prefill_cfg is not None:
        fleet_cfg = dataclasses.replace(fleet_cfg, disaggregated=True)
        tier = build_prefill_tier(model_cfg, mode, n_adapters, budget,
                                  prefill_cfg, hw, cluster_of, setting,
                                  slice_type=prefill_slice_type)
    return Fleet(fleet_cfg, engines, cluster_of, prefill_tier=tier,
                 rank_of=rank_of)


# ---------------------------------------------------------------------------
# the unified study driver (PR 9)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class StudyEvent:
    """A scripted control-plane action in a study's event stream.

    Fires once, in causal order against the arrival stream (an event at
    `t` is applied before any request arriving after `t` is routed).
    `fn` receives the live :class:`StudyState` — retire a replica, add a
    prefill worker, flip a config knob.  Lifecycle actions use
    :class:`~repro.serving.lifecycle.LifecycleEvent` in the same stream."""
    t: float
    fn: Callable[["StudyState"], None]
    label: str = ""


@dataclasses.dataclass
class StudyState:
    """Live handles a :class:`StudyEvent` (or migration hook) can act on
    mid-study."""
    fleet: Fleet
    t: float = 0.0
    autoscaler: Optional[object] = None
    lifecycle: Optional[AdapterLifecycle] = None
    migration: Optional[MigrationPolicy] = None
    budget: Optional[HardwareBudget] = None
    decode_factory: Optional[Callable[[], ServingEngine]] = None
    prefill_factory: Optional[Callable[[], PrefillWorker]] = None
    _finished: Optional[List[Request]] = None

    def attach_engine(self, eng: ServingEngine) -> int:
        """Join a replica built outside the loop at time ``self.t``, wired
        into the study's callbacks (finish observation, lifecycle,
        migration) exactly like an autoscaler-added one."""
        if self._finished is not None:
            _chain_finish(eng, self._finished.append)
        if self.lifecycle is not None:
            self.lifecycle.attach_engine(eng)
        idx = self.fleet.add_replica(eng, now=self.t)
        if self.migration is not None:
            self.migration.wire(eng)
        return idx

    def retire_decode(self, i: Optional[int] = None,
                      migrate: Optional[bool] = None) -> None:
        """Retire replica `i` (default: the most recently added active
        one).  `migrate` defaults to instant scale-down when a migration
        policy with ``migrate_on_retire`` is attached, drain otherwise."""
        if i is None:
            i = self.fleet._active_idxs()[-1]
        if migrate is None:
            migrate = (self.migration is not None
                       and self.migration.cfg.migrate_on_retire)
        self.fleet.retire_replica(i, migrate=migrate, now=self.t)


@dataclasses.dataclass
class StudyReport:
    """The unified study result: merged fleet stats, the control plane's
    decision history, and per-mode wire accounting — plus the JSON /
    derived-cell helpers every benchmark previously hand-rolled."""
    stats: FleetStats
    decisions: Optional[List] = None     # autoscaler history, if any
    wire_by_mode: Optional[Dict] = None  # fabric wire bytes by mode
    migration: Optional[Dict] = None     # MigrationStats.to_dict()
    lifecycle: Optional[Dict] = None     # LifecycleStats.to_dict()
    budget: Optional[Dict] = None        # HardwareBudget.to_dict()

    @property
    def rps(self) -> float:
        return self.stats.total.throughput_rps

    def to_dict(self) -> Dict:
        d = self.stats.to_dict()
        if self.wire_by_mode:
            d["wire_bytes_by_mode"] = dict(self.wire_by_mode)
        return d

    def metrics(self, **extra) -> Dict[str, float]:
        """The perf-gate metric dict (`check_regression` compares names
        ending in rps/speedup/ratio); pass extras as keywords."""
        m = {"rps": self.rps}
        m.update(extra)
        return m

    def derived(self, slo_ttft: Optional[float] = None) -> str:
        """The benchmark CSV `derived` cell: headline latency percentiles
        plus whichever control-plane facts this study produced."""
        tot = self.stats.total
        s = (f"rps={tot.throughput_rps:.2f};"
             f"ttft_p95={tot.ttft_pct(95) * 1e3:.1f}ms;"
             f"tpot_p95={tot.tpot_pct(95) * 1e3:.2f}ms")
        if slo_ttft is not None:
            s += f";met_slo={tot.ttft_pct(95) <= slo_ttft}"
        if self.stats.n_prefill_final is not None:
            s += (f";split={self.stats.n_prefill_final}P:"
                  f"{self.stats.n_replicas_final}D"
                  f";scale_events={self.stats.scale_events}")
        if self.migration is not None:
            s += f";migrations={self.migration['n_migrations']}"
        return s


def _chain_finish(eng: ServingEngine, cb: Callable[[Request], None]) -> None:
    """Add `cb` to an engine's on_finish without clobbering an existing
    hook (the lifecycle chains its drain bookkeeping the same way)."""
    prev = eng.on_finish
    if prev is None:
        eng.on_finish = cb
    else:
        def chained(r, _prev=prev, _cb=cb):
            _prev(r)
            _cb(r)
        eng.on_finish = chained


def _call_factory(factory, slice_type: Optional[SliceType]):
    """Build a unit from an autoscaler factory, forwarding the chosen
    slice type only when the factory can take one — legacy zero-arg
    factories (and untyped budgets, where `slice_type` is None) keep
    working unchanged."""
    if slice_type is None:
        return factory()
    try:
        params = inspect.signature(factory).parameters
    except (TypeError, ValueError):   # builtins / odd callables
        return factory()
    return factory(slice_type) if params else factory()


def _apply_study_event(ev, state: StudyState) -> None:
    if isinstance(ev, LifecycleEvent):
        if state.lifecycle is None:
            raise ValueError(f"lifecycle event {ev} in a study with no "
                             f"lifecycle")
        apply_event(state.lifecycle, ev)
    else:
        ev.fn(state)


def run_study(fleet: Fleet,
              workload: Union[Sequence[Request], WorkloadSpec],
              *,
              autoscaler: Optional[object] = None,
              lifecycle: Optional[AdapterLifecycle] = None,
              events: Optional[Sequence] = None,
              migration: Optional[MigrationPolicy] = None,
              decode_factory: Optional[Callable[[], ServingEngine]] = None,
              prefill_factory: Optional[Callable[[], PrefillWorker]] = None,
              window: Optional[float] = None,
              max_steps: int = 10_000_000) -> StudyReport:
    """Drive `fleet` through a workload under any combination of control
    planes — THE window loop every legacy entry point now wraps.

    Two shapes, one function:

    * **One-shot** — no autoscaler, no lifecycle, no events, no migration
      policy, no explicit `window`: submit everything, drain, report.
      Bit-exact with the pre-unification fixed-fleet path (the shared
      fabric resolves all transfers in one batch, which windowed
      resolution deliberately does not reproduce).
    * **Window loop** — per window: (1) interleave scripted events
      (:class:`StudyEvent` / :class:`LifecycleEvent
      <repro.serving.lifecycle.LifecycleEvent>`) and request arrivals in
      causal time order, stamping and routing arrivals as they come;
      (2) advance every replica to the window edge; (3) control plane —
      lifecycle rollout pacing, the migration policy's window hook
      (priority preemption + affinity defrag), then the autoscaler's
      decision (decode-only `Autoscaler` or two-tier `JointAutoscaler`,
      reproducing their original observation windows verbatim).  An
      autoscaler scale-down retires with live migration when the
      attached :class:`~repro.serving.migration.MigrationPolicy` asks
      for instant scale-down.

    `window` defaults to the autoscaler's decision interval, else 0.25 s.
    `workload` may be a :class:`~repro.serving.workload.WorkloadSpec`
    (generated here) or an explicit request list."""
    if isinstance(workload, WorkloadSpec):
        workload = make_workload(workload)
    reqs = list(workload)
    evs = sorted(events or [], key=lambda e: e.t)
    joint = isinstance(autoscaler, JointAutoscaler)
    if autoscaler is not None and decode_factory is None:
        raise ValueError("an autoscaled study needs decode_factory")
    if joint and (prefill_factory is None or fleet.prefill_tier is None):
        raise ValueError("joint autoscaling needs a disaggregated fleet "
                         "(prefill_tier) and prefill_factory")
    if migration is not None:
        migration.attach(fleet)

    one_shot = (autoscaler is None and lifecycle is None and not evs
                and migration is None and window is None)
    if one_shot:
        # submit in caller order (bit-exact with the legacy fixed path)
        fleet.submit(reqs)
        return _report(fleet, fleet.run(max_steps), None, None)
    reqs.sort(key=lambda r: r.arrival_time)

    tier = fleet.prefill_tier
    budget = autoscaler.budget if joint else None
    if joint:
        n_dec0 = len(fleet._active_idxs())
        # each live unit is charged for its *own* slice type (None on a
        # legacy unit resolves to the budget's default slice, so the
        # untyped path is arithmetically unchanged)
        pre_types = [getattr(tier.workers[k], "slice_type", None)
                     for k in tier._active_idxs()]
        dec_types = [getattr(fleet.engines[k], "slice_type", None)
                     for k in fleet._active_idxs()]
        need = (sum(budget.cfg.cost("prefill", s) for s in pre_types)
                + sum(budget.cfg.cost("decode", s) for s in dec_types))
        if need > budget.available:
            # fail at construction time with a clear message instead of
            # dying mid-run inside HardwareBudget.allocate
            raise ValueError(
                f"budget too small for the initial split: {tier.n_active} "
                f"prefill x {budget.cfg.cost('prefill')} accels + {n_dec0} "
                f"decode x {budget.cfg.cost('decode')} accels needs {need}, "
                f"{budget.available} free of {budget.cfg.total_units}")
        for s in pre_types:
            budget.allocate("prefill", s)
        for s in dec_types:
            budget.allocate("decode", s)
        if autoscaler.comp_policy is None and tier.fabric.policy is not None:
            autoscaler.bind_compression(tier.fabric.policy)

    finished: List[Request] = []
    if autoscaler is not None:
        for eng in fleet.engines:
            _chain_finish(eng, finished.append)
    state = StudyState(fleet=fleet, autoscaler=autoscaler,
                       lifecycle=lifecycle, migration=migration,
                       budget=budget, decode_factory=decode_factory,
                       prefill_factory=prefill_factory, _finished=finished)
    mig_retire = (migration is not None and migration.cfg.migrate_on_retire)

    dt = window if window is not None else (
        autoscaler.cfg.decision_interval if autoscaler is not None else 0.25)
    t = dt
    i = j = 0
    recent: List[Request] = []       # arrivals still possibly in prefill
    pending_decomp: List[Request] = []   # compressed, dequant not yet billed
    while True:
        # (1) interleave scripted events and arrivals inside this window
        # by time: an event is visible to the requests behind it
        win_arrivals: List[Request] = []
        while i < len(reqs) or j < len(evs):
            r_t = reqs[i].arrival_time if i < len(reqs) else float("inf")
            e_t = evs[j].t if j < len(evs) else float("inf")
            if min(r_t, e_t) >= t:
                break
            if e_t <= r_t:
                state.t = e_t
                _apply_study_event(evs[j], state)
                j += 1
            else:
                k = i                # batch arrivals up to the next event
                until = min(t, e_t)
                while k < len(reqs) and reqs[k].arrival_time < until:
                    k += 1
                batch = reqs[i:k]
                if lifecycle is not None:
                    lifecycle.stamp(batch)
                fleet.submit(batch)
                win_arrivals.extend(batch)
                i = k
        if joint:
            recent.extend(win_arrivals)
            pending_decomp.extend(r for r in win_arrivals
                                  if r.kv_decompress_cost > 0)
        # (2) advance the data plane through the window BEFORE the control
        # plane acts at its edge: a basis swap (or a migration) moves
        # clocks forward, and acting first would let it cut in line ahead
        # of arrivals queued within the window
        fleet.advance_to(t)
        state.t = t
        if lifecycle is not None:
            lifecycle.tick(t)
        if migration is not None:
            migration.on_window(fleet, t)
        # (3) observations + the autoscaler's decision
        ttfts = [r.ttft for r in finished if r.ttft is not None]
        tpots = [r.tpot for r in finished if r.tpot is not None]
        dwaits = [r.decode_wait for r in finished
                  if r.decode_wait is not None]
        if joint:
            # bill dequantization to the window it actually ran in
            # (admission stamps decompress_done_time), not the window the
            # request finishes
            decomp_total = sum(r.kv_decompress_cost for r in pending_decomp
                               if r.decompress_done_time is not None
                               and r.decompress_done_time <= t)
            pending_decomp = [r for r in pending_decomp
                              if r.decompress_done_time is None
                              or r.decompress_done_time > t]
        finished.clear()
        outstanding = sum(len(eng.running) + len(eng.waiting)
                          for eng in fleet.engines)
        if i >= len(reqs) and j >= len(evs) and outstanding == 0:
            break
        # drain phase (arrivals over): further decisions could only
        # inflate scale_events with idle capacity
        if autoscaler is not None and i < len(reqs):
            if joint:
                # the prefill tier simulates eagerly, so "queued at t" is
                # virtual: arrived but not yet prefill-complete by the
                # window end
                recent = [r for r in recent if r.prefill_done_time is None
                          or r.prefill_done_time > t]
                prefill_backlog = sum(1 for r in recent
                                      if r.arrival_time <= t)
                pre_lags = [r.prefill_lag for r in win_arrivals
                            if r.prefill_lag is not None]
                decode_backlog = sum(
                    len(eng.running)
                    + sum(1 for r in eng.waiting if r.ready_time <= t)
                    for eng in fleet.engines)
                n_dec_active = len(fleet._active_idxs())
                # unified paging: the worst active replica's page pressure
                # (0 for non-paged engines) — admissions block on pages,
                # so this sees a memory bottleneck percentiles can miss
                kv_page_util = max(
                    (1.0 - fleet.engines[k].pool.free_pages
                     / fleet.engines[k].pool.total_pages
                     for k in fleet._active_idxs()
                     if fleet.engines[k].pool is not None), default=0.0)
                # retirement always takes the newest unit, so tell the
                # autoscaler how many cost units *that* unit would free —
                # on a typed pool a trade must be priced in the donor's
                # actual slice, not the config-wide minimum
                retire_pre_units = retire_dec_units = None
                if budget.cfg.typed:
                    pact, dact = tier._active_idxs(), fleet._active_idxs()
                    if pact:
                        retire_pre_units = budget.cfg.cost(
                            "prefill",
                            getattr(tier.workers[pact[-1]],
                                    "slice_type", None))
                    if dact:
                        retire_dec_units = budget.cfg.cost(
                            "decode",
                            getattr(fleet.engines[dact[-1]],
                                    "slice_type", None))
                d_pre, d_dec = autoscaler.decide(
                    t, ttfts, tpots, dwaits, pre_lags, tier.n_active,
                    n_dec_active, prefill_backlog, decode_backlog,
                    decompress_util=decomp_total / (dt * max(n_dec_active,
                                                             1)),
                    fabric_lag_s=max(0.0, tier.fabric.free_at - t),
                    kv_page_util=kv_page_util,
                    retire_prefill_units=retire_pre_units,
                    retire_decode_units=retire_dec_units)
                if d_dec < 0:
                    victim = fleet._active_idxs()[-1]
                    vst = getattr(fleet.engines[victim], "slice_type", None)
                    fleet.retire_replica(victim, migrate=mig_retire, now=t)
                    budget.release("decode", vst)
                if d_pre < 0:
                    pv = tier._active_idxs()[-1]
                    vst = getattr(tier.workers[pv], "slice_type", None)
                    tier.retire_worker(pv)
                    budget.release("prefill", vst)
                if d_pre > 0:
                    st = autoscaler.pick_slice("prefill")
                    budget.allocate("prefill", st)
                    tier.add_worker(_call_factory(prefill_factory, st),
                                    now=t)
                if d_dec > 0:
                    st = autoscaler.pick_slice("decode")
                    budget.allocate("decode", st)
                    state.attach_engine(_call_factory(decode_factory, st))
            else:
                # decisions see only decode-actionable work: requests
                # whose KV is still in prefill/transfer (ready_time > t)
                # cannot be helped by another decode replica
                backlog = sum(
                    len(eng.running)
                    + sum(1 for r in eng.waiting if r.ready_time <= t)
                    for eng in fleet.engines)
                active = fleet._active_idxs()
                delta = autoscaler.decide(t, ttfts, tpots, len(active),
                                          backlog)
                if delta > 0:
                    for _ in range(delta):
                        state.attach_engine(decode_factory())
                elif delta < 0:
                    for _ in range(-delta):
                        fleet.retire_replica(fleet._active_idxs()[-1],
                                             migrate=mig_retire, now=t)
        t += dt
    stats = fleet.run(max_steps)
    if lifecycle is not None:
        # let a rollout that was mid-flight at drain finish against the
        # final fleet clock so its bookkeeping (versions, shrink) settles
        lifecycle.tick(stats.total.wall_time + lifecycle.cfg.refresh_interval)
        stats.lifecycle = lifecycle.stats.to_dict()
    if joint:
        stats.n_prefill_final = tier.n_active
        stats.scale_events += tier.scale_events
        stats.budget = budget.to_dict()
    return _report(fleet, stats, autoscaler, lifecycle)


def _report(fleet: Fleet, stats: FleetStats, autoscaler, lifecycle
            ) -> StudyReport:
    wire: Dict[str, int] = {}
    if fleet.prefill_tier is not None:
        merge_mode_dict(wire,
                        fleet.prefill_tier.fabric.stats.wire_bytes_by_mode)
    if fleet._mig_fabric is not None:
        merge_mode_dict(wire, fleet._mig_fabric.stats.wire_bytes_by_mode)
    if not fleet.migration.empty:
        stats.migration = fleet.migration.to_dict()
    if autoscaler is not None:
        stats.autoscaler = autoscaler.history
    return StudyReport(
        stats=stats,
        decisions=autoscaler.history if autoscaler is not None else None,
        wire_by_mode=wire or None,
        migration=stats.migration,
        lifecycle=stats.lifecycle,
        budget=stats.budget)


def run_elastic_study(model_cfg, mode: str, n_adapters: int,
                      requests: List[Request],
                      fleet_cfg: FleetConfig,
                      hw: Optional[ServingHardware] = None,
                      max_batch: int = 32,
                      cluster_assign_seed: int = 0,
                      prefill_cfg: Optional[PrefillConfig] = None,
                      autoscaler_cfg: Optional[AutoscalerConfig] = None,
                      slo: Optional[SLOConfig] = None,
                      budget_cfg: Optional[BudgetConfig] = None,
                      joint_cfg: Optional[JointAutoscalerConfig] = None,
                      pool_bytes: Optional[float] = None,
                      pool_adapter_share: Optional[float] = None,
                      migration: Optional[MigrationPolicy] = None,
                      events: Optional[Sequence] = None,
                      report: bool = False,
                      decode_slice_types: Optional[Sequence[SliceType]] = None,
                      prefill_slice_type: Optional[SliceType] = None,
                      rank_of: Optional[Dict[int, int]] = None
                      ) -> Union[FleetStats, StudyReport]:
    """One serving cell, optionally disaggregated and/or autoscaled.

    With `autoscaler_cfg` the fleet starts at ``fleet_cfg.n_replicas``
    decode replicas and elastically scales between the autoscaler's
    min/max against `slo`; otherwise the replica set is fixed.  With
    `budget_cfg` (requires `prefill_cfg`) the run is *jointly* autoscaled:
    both tiers start at their configured sizes and the
    :class:`~repro.serving.autoscaler.JointAutoscaler` trades prefill
    workers against decode replicas under the fixed accelerator pool.
    KV wire compression is configured on the fabric —
    ``prefill_cfg=PrefillConfig(fabric=FabricConfig(...,
    compression=KVCompressionConfig(...)))`` — and threads through the
    whole cell: workers compress, chunks ship small, decode replicas pay
    dequantization, and the joint autoscaler sees that load.  With
    ``FabricConfig(..., adaptive=AdaptiveCompressionConfig(...))`` the
    mode is instead picked per transfer from live channel backlog, and a
    jointly autoscaled run additionally drives the policy's mode ceiling
    (raised under budget-exhausted wire pressure before any replica
    trade, relaxed in quiet windows — see ``JointScaleDecision.d_comp``).
    With `pool_bytes` every decode replica (including ones the autoscaler
    adds) runs unified paging over a pool of that size;
    `pool_adapter_share` selects the static-split baseline.
    Heterogeneous cells: `decode_slice_types` / `prefill_slice_type` type
    the starting fleet (see :func:`build_fleet`), `rank_of` feeds the
    per-rank byte model and rank-aware routing, and a typed `budget_cfg`
    lets the joint autoscaler pick *which* slice class each scale-up adds
    (the factories here accept the chosen type).
    Returns merged :class:`FleetStats` (``stats.autoscaler`` holds the
    decision history when autoscaled; the prefill dict carries per-mode
    wire-byte totals), or the full :class:`StudyReport` with
    ``report=True``."""
    hw = hw or ServingHardware()
    setting, cluster_of, budget = memory_matched_setup(
        model_cfg, n_adapters, cluster_assign_seed)
    fleet = build_fleet(model_cfg, mode, n_adapters, budget, fleet_cfg, hw,
                        cluster_of, setting, max_batch,
                        prefill_cfg=prefill_cfg, pool_bytes=pool_bytes,
                        pool_adapter_share=pool_adapter_share,
                        decode_slice_types=decode_slice_types,
                        prefill_slice_type=prefill_slice_type,
                        rank_of=rank_of)

    def decode_factory(slice_type: Optional[SliceType] = None
                       ) -> ServingEngine:
        return build_engine(model_cfg, mode, n_adapters, budget, hw,
                            cluster_of, setting, max_batch,
                            pool_bytes=pool_bytes,
                            pool_adapter_share=pool_adapter_share,
                            slice_type=slice_type, rank_of=rank_of)

    if budget_cfg is not None:
        if prefill_cfg is None:
            raise ValueError("joint autoscaling needs prefill_cfg "
                             "(disaggregated fleet)")
        scaler = JointAutoscaler(joint_cfg or JointAutoscalerConfig(),
                                 slo or SLOConfig(),
                                 HardwareBudget(budget_cfg))

        def prefill_factory(slice_type: Optional[SliceType] = None
                            ) -> PrefillWorker:
            return build_prefill_worker(model_cfg, mode, n_adapters, budget,
                                        prefill_cfg, hw, cluster_of, setting,
                                        slice_type=slice_type)

        rep = run_study(fleet, requests, autoscaler=scaler,
                        decode_factory=decode_factory,
                        prefill_factory=prefill_factory,
                        migration=migration, events=events)
        return rep if report else rep.stats
    if autoscaler_cfg is None:
        rep = run_study(fleet, requests, migration=migration, events=events)
        return rep if report else rep.stats
    scaler = Autoscaler(autoscaler_cfg, slo or SLOConfig())
    rep = run_study(fleet, requests, autoscaler=scaler,
                    decode_factory=decode_factory,
                    migration=migration, events=events)
    return rep if report else rep.stats


def run_throughput_study(model_cfg, n_adapters_list: List[int],
                         workload: Optional[WorkloadSpec] = None,
                         hw: Optional[ServingHardware] = None,
                         max_batch: int = 32,
                         cluster_assign_seed: int = 0,
                         fleet: Optional[FleetConfig] = None,
                         prefetch: bool = False) -> List[Dict]:
    """Compressed vs uncompressed vs single-LoRA throughput across N."""
    hw = hw or ServingHardware()
    fleet_cfg = fleet or FleetConfig()
    rows = []
    for n in n_adapters_list:
        wl = dataclasses.replace(workload or WorkloadSpec(), n_adapters=n)
        setting, cluster_of, budget = memory_matched_setup(
            model_cfg, n, cluster_assign_seed)

        results = {}
        for mode in ("jd", "lora"):
            fl = build_fleet(model_cfg, mode, n, budget, fleet_cfg, hw,
                             cluster_of, setting, max_batch, prefetch)
            results[mode] = run_study(fl, make_workload(wl)).stats.to_dict()

        # single-LoRA reference (merged into base: no adapter overhead)
        fl1 = build_fleet(model_cfg, "lora", 1, budget, fleet_cfg, hw, {},
                          setting, max_batch, prefetch)
        results["single"] = run_study(
            fl1, make_workload(dataclasses.replace(wl, n_adapters=1))
        ).stats.to_dict()

        rows.append({
            "n_adapters": n, "setting": setting,
            "budget_bytes": budget,
            "n_replicas": fleet_cfg.n_replicas, "policy": fleet_cfg.policy,
            "jd": results["jd"], "lora": results["lora"],
            "single": results["single"],
            "throughput_ratio_jd_vs_lora":
                results["jd"]["throughput_rps"]
                / max(results["lora"]["throughput_rps"], 1e-9),
            "jd_frac_of_single":
                results["jd"]["throughput_rps"]
                / max(results["single"]["throughput_rps"], 1e-9),
        })
    return rows
