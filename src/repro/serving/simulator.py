"""The paper's throughput study driver (Figs. 1 & 4), fleet-capable.

Replicates §6.4's setup in v5e terms: N unique rank-16 LoRAs, asynchronous
request arrivals, inputs assigned to adapters uniformly at random, ten
generated tokens per request; memory-matched baseline (Appendix F): the
uncompressed engine gets an adapter budget equal to what the compressed
configuration consumes (shared bases + all Sigmas).

The study now drives a :class:`repro.serving.router.Fleet` through the
workload generator in :mod:`repro.serving.workload`.  The default
configuration — one replica, uniform popularity, round-robin routing — is
the special case that reproduces the original single-replica numbers
bit-exactly; `FleetConfig(n_replicas=..., policy=...)` plus a skewed
`WorkloadSpec` opens the production scenarios (Zipf popularity, bursty
arrivals, affinity routing).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

from repro.serving.engine import (CostModelExecutor, EngineConfig,
                                  ModelFootprint, ServingEngine,
                                  ServingHardware)
from repro.serving.request import Request
from repro.serving.router import Fleet, FleetConfig
from repro.serving.scheduler import SchedulerConfig
from repro.serving.workload import WorkloadSpec, make_workload

# Backwards-compatible names: the workload generator used to live here.
WorkloadConfig = WorkloadSpec


# paper Appendix F: compression setting per collection size
PAPER_SETTINGS = {
    4: dict(rank=16, clusters=1), 8: dict(rank=16, clusters=1),
    16: dict(rank=32, clusters=1), 32: dict(rank=64, clusters=1),
    64: dict(rank=64, clusters=1), 128: dict(rank=16, clusters=7),
    256: dict(rank=16, clusters=10), 512: dict(rank=16, clusters=25),
    1024: dict(rank=16, clusters=25),
}


def compression_setting(n_adapters: int) -> Dict:
    keys = sorted(PAPER_SETTINGS)
    for k in keys:
        if n_adapters <= k:
            return PAPER_SETTINGS[k]
    return PAPER_SETTINGS[keys[-1]]


def memory_matched_setup(model_cfg, n_adapters: int,
                         cluster_assign_seed: int = 0):
    """Appendix-F memory matching for a collection size.

    Returns (setting, cluster_of, budget): the paper's compression setting,
    a seeded random cluster assignment, and the per-replica adapter budget —
    the uncompressed baseline gets exactly what the compressed configuration
    consumes (shared bases + all Sigmas), floored at two resident LoRAs."""
    setting = compression_setting(n_adapters)
    rng = np.random.default_rng(cluster_assign_seed)
    cluster_of = {a: int(rng.integers(setting["clusters"]))
                  for a in range(n_adapters)}
    fp_jd = ModelFootprint.from_config(model_cfg, jd_rank=setting["rank"],
                                       n_clusters=setting["clusters"])
    fp_lora = ModelFootprint.from_config(model_cfg)
    jd_total = (fp_jd.jd_shared_bytes_per_cluster * setting["clusters"]
                + n_adapters * fp_jd.jd_sigma_bytes_per_adapter)
    budget = max(jd_total, 2 * fp_lora.lora_bytes_per_adapter)
    return setting, cluster_of, budget


def build_fleet(model_cfg, mode: str, n_adapters: int, budget: float,
                fleet_cfg: FleetConfig, hw: ServingHardware,
                cluster_of: Dict[int, int], setting: Dict,
                max_batch: int = 32, prefetch: bool = False) -> Fleet:
    """N identical replicas of the cost-model engine for `mode`.

    Budget is per replica (each replica owns an HBM adapter region)."""
    if mode == "jd":
        fp = ModelFootprint.from_config(model_cfg, jd_rank=setting["rank"],
                                        n_clusters=setting["clusters"])
    else:
        fp = ModelFootprint.from_config(model_cfg)
        if n_adapters <= 1:            # merged single-LoRA reference
            fp = dataclasses.replace(fp, lora_bytes_per_adapter=0)
    engines = []
    for _ in range(fleet_cfg.n_replicas):
        ex = CostModelExecutor(hw, fp, mode, cluster_of)
        engines.append(ServingEngine(
            EngineConfig(scheduler=SchedulerConfig(max_batch=max_batch),
                         adapter_budget_bytes=budget, mode=mode,
                         prefetch=prefetch),
            ex, cluster_of))
    return Fleet(fleet_cfg, engines, cluster_of)


def run_throughput_study(model_cfg, n_adapters_list: List[int],
                         workload: Optional[WorkloadSpec] = None,
                         hw: Optional[ServingHardware] = None,
                         max_batch: int = 32,
                         cluster_assign_seed: int = 0,
                         fleet: Optional[FleetConfig] = None,
                         prefetch: bool = False) -> List[Dict]:
    """Compressed vs uncompressed vs single-LoRA throughput across N."""
    hw = hw or ServingHardware()
    fleet_cfg = fleet or FleetConfig()
    rows = []
    for n in n_adapters_list:
        wl = dataclasses.replace(workload or WorkloadSpec(), n_adapters=n)
        setting, cluster_of, budget = memory_matched_setup(
            model_cfg, n, cluster_assign_seed)

        results = {}
        for mode in ("jd", "lora"):
            fl = build_fleet(model_cfg, mode, n, budget, fleet_cfg, hw,
                             cluster_of, setting, max_batch, prefetch)
            fl.submit(make_workload(wl))
            results[mode] = fl.run().to_dict()

        # single-LoRA reference (merged into base: no adapter overhead)
        fl1 = build_fleet(model_cfg, "lora", 1, budget, fleet_cfg, hw, {},
                          setting, max_batch, prefetch)
        fl1.submit(make_workload(dataclasses.replace(wl, n_adapters=1)))
        results["single"] = fl1.run().to_dict()

        rows.append({
            "n_adapters": n, "setting": setting,
            "budget_bytes": budget,
            "n_replicas": fleet_cfg.n_replicas, "policy": fleet_cfg.policy,
            "jd": results["jd"], "lora": results["lora"],
            "single": results["single"],
            "throughput_ratio_jd_vs_lora":
                results["jd"]["throughput_rps"]
                / max(results["lora"]["throughput_rps"], 1e-9),
            "jd_frac_of_single":
                results["jd"]["throughput_rps"]
                / max(results["single"]["throughput_rps"], 1e-9),
        })
    return rows
