"""Multi-LoRA serving engine: continuous batching + adapter cache + executor.

Two executors share one engine loop:

- :class:`CostModelExecutor` — roofline-calibrated analytic step times for
  the production target (v5e serving slice); used for the paper-scale
  throughput studies (Figs. 1 & 4) where 1000s of adapters are simulated.
- :class:`RealModelExecutor` — actually runs prefill/decode of a (reduced)
  model on the host with batched LoRA application; used by the end-to-end
  example and tests (real logits, real adapter math, wall-clock timing).

Serving modes:
  "lora"  — uncompressed multi-LoRA baseline (vLLM-style swap on miss)
  "jd"    — compressed: shared bases pinned, Sigmas resident (tiny), no swap
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence


from .adapter_cache import AdapterCache, CacheConfig
from .request import Request, ServeStats, weight_key
from .resources import (PAGE_TOKENS, PagedPool, PagedPoolConfig,
                        merge_mode_dict)
from .scheduler import Scheduler, SchedulerConfig


# ---------------------------------------------------------------------------
# cost-model executor (production target)
# ---------------------------------------------------------------------------


# fused-path cost constants re-derived from wall-clock measurement: the
# affine decode-step fit (t = step_overhead + per_slot * batch) from
# benchmarks/real_decode.py's `derived` cell, committed in
# benchmarks/baselines/BENCH_real.json.  tests/test_hetero.py fails if
# these and the committed JSON drift apart.  They parameterize
# ServingHardware.real_calibrated(), NOT the roofline defaults below —
# changing the live defaults would move every committed baseline.
REAL_DECODE_STEP_OVERHEAD_S = 0.0012266824722044262
REAL_DECODE_PER_SLOT_S = 0.0002450057222126311


@dataclasses.dataclass
class ServingHardware:
    """One serving replica (e.g. a 4-chip v5e slice)."""
    peak_flops: float = 4 * 197e12
    hbm_bw: float = 4 * 819e9
    hbm_bytes: float = 4 * 16e9
    mem_cap_frac: float = 0.4        # paper: cap at 40% of device memory
    mfu_prefill: float = 0.45
    step_overhead: float = 3e-4      # host/dispatch per decode step

    def for_slice(self, slice_type) -> "ServingHardware":
        """This hardware scaled by a :class:`SliceType
        <repro.serving.resources.SliceType>`'s factors: ``prefill_speed``
        scales peak compute (the prefill roofline), ``decode_speed``
        scales HBM bandwidth (the weight-streaming decode roofline), and
        the slice's ``hbm_bytes`` replaces the replica's HBM when set.
        The default slice (all factors 1.0, no HBM override) returns
        bit-identical figures — ``x * 1.0`` is exact in IEEE 754."""
        if slice_type is None:
            return self
        return dataclasses.replace(
            self,
            peak_flops=self.peak_flops * slice_type.prefill_speed,
            hbm_bw=self.hbm_bw * slice_type.decode_speed,
            hbm_bytes=(slice_type.hbm_bytes
                       if slice_type.hbm_bytes is not None
                       else self.hbm_bytes))

    @classmethod
    def real_calibrated(cls, **overrides) -> "ServingHardware":
        """A replica whose per-step dispatch overhead comes from the
        committed wall-clock fit of the fused decode path
        (:data:`REAL_DECODE_STEP_OVERHEAD_S`) instead of the roofline
        guess.  The per-slot slope of the same fit is exported as
        :data:`REAL_DECODE_PER_SLOT_S` for studies that want the full
        affine model."""
        overrides.setdefault("step_overhead", REAL_DECODE_STEP_OVERHEAD_S)
        return cls(**overrides)


@dataclasses.dataclass
class ModelFootprint:
    """Serving-relevant sizes (derived from a ModelConfig)."""
    n_active_params: int
    weight_bytes: int                # resident base weights (bf16)
    lora_bytes_per_adapter: int      # uncompressed A+B across modules
    jd_shared_bytes_per_cluster: int  # U_j+V_j across modules
    jd_sigma_bytes_per_adapter: int
    n_clusters: int = 1
    kv_bytes_per_token: int = 0      # bf16 K+V across layers (disagg handoff)
    lora_rank: int = 16              # the rank lora_bytes_per_adapter prices

    @staticmethod
    def from_config(cfg, rank: int = 16, jd_rank: int = 16,
                    n_clusters: int = 1, diag: bool = False,
                    adapter_bits: int = 16) -> "ModelFootprint":
        """``adapter_bits=16`` prices bf16 resident adapters (the default,
        bit-exact with every committed baseline); ``adapter_bits=8`` prices
        the int8 per-output-channel packing of `kernels/adapter_quant.py`
        (1 byte per value + one f32 scale per output channel), the
        residency the ``fused_q8`` decode path actually keeps in the
        `PagedPool` — roughly a 2x page cut vs bf16 and ~4x vs the float32
        training-output banks `RealModelExecutor` holds."""
        d = cfg.d_model
        hd = cfg.resolved_head_dim
        dims = {"q": (d, cfg.num_heads * hd), "k": (d, cfg.num_kv_heads * hd),
                "v": (d, cfg.num_kv_heads * hd)}
        if adapter_bits == 16:
            lora_b = sum(2 * rank * (di + do) for di, do in dims.values())
            shared_b = sum(2 * jd_rank * (di + do)
                           for di, do in dims.values())
            sig_b = 2 * (jd_rank if diag else jd_rank * jd_rank) * len(dims)
        elif adapter_bits == 8:
            # int8 values + one f32 scale per output channel:
            # A (r, di): r scales; B (do, r): do scales — per module.
            lora_b = sum(rank * (di + do) + 4 * (rank + do)
                         for di, do in dims.values())
            # shared basis: U (do, jd_rank) do scales; V (di, jd_rank)
            # jd_rank scales (per-column, the rank axis is the output)
            shared_b = sum(jd_rank * (di + do) + 4 * (do + jd_rank)
                           for di, do in dims.values())
            # diag Sigma stays fp (tiny); full Sigma packs per row
            sig_b = ((2 * jd_rank if diag
                      else jd_rank * jd_rank + 4 * jd_rank) * len(dims))
        else:
            raise ValueError(f"adapter_bits must be 16 or 8, got "
                             f"{adapter_bits}")
        return ModelFootprint(
            n_active_params=cfg.active_param_count(),
            weight_bytes=2 * cfg.param_count(),
            lora_bytes_per_adapter=lora_b * cfg.num_layers,
            jd_shared_bytes_per_cluster=shared_b * cfg.num_layers,
            jd_sigma_bytes_per_adapter=sig_b * cfg.num_layers,
            n_clusters=n_clusters,
            kv_bytes_per_token=2 * 2 * cfg.num_layers * cfg.num_kv_heads * hd,
            lora_rank=rank)

    def pool_config(self, total_bytes: float,
                    adapter_share: Optional[float] = None) -> PagedPoolConfig:
        """The unified paged pool sized for this model: one page is one
        :data:`PAGE_TOKENS`-token KV block across all layers/heads.
        `total_bytes` is the HBM region shared by KV blocks and adapter
        weights (e.g. ``hw.hbm_bytes * hw.mem_cap_frac`` minus the base
        weights); `adapter_share` carves the pre-paging static split out
        of the same machinery (see :class:`PagedPoolConfig
        <repro.serving.resources.PagedPoolConfig>`)."""
        if self.kv_bytes_per_token <= 0:
            raise ValueError("pool_config needs kv_bytes_per_token > 0")
        return PagedPoolConfig(
            total_bytes=total_bytes,
            page_bytes=self.kv_bytes_per_token * PAGE_TOKENS,
            adapter_share=adapter_share)


class CostModelExecutor:
    """Roofline step-time model; decode is weight-streaming bound.

    Supports a **raw overlay** for the online lifecycle: adapters in
    ``raw_ids`` are served through the uncompressed SGMV path even in
    "jd" mode (a hot-registered adapter decodes from its full A/B weights
    — :func:`repro.core.collection.export_uncompressed` — until a basis
    refresh absorbs it into a cluster, invariant L1).  A jd decode step
    with mixed raw/compressed slots streams each raw adapter's LoRA
    weights plus the compressed slots' bases and Sigmas.  With
    ``raw_ids`` empty the model is bit-exact with the pre-lifecycle
    executor.

    Heterogeneous adapters (PR 10): with ``rank_of`` (adapter id ->
    LoRA rank) the SGMV-path byte model is per-rank — a rank-r adapter
    streams ``lora_bytes_per_adapter * padded(r) / lora_rank`` bytes,
    where ``padded(r)`` rounds r up to the replica slice's native SGMV
    contraction tile (``slice_type.sgmv_tile_rank``; see
    :func:`repro.kernels.sgmv.sgmv_tile_cost`).  The padding is what
    makes placement matter: a rank-4 adapter on a tile-32 slice streams
    8x its useful bytes.  ``rank_of=None`` keeps the homogeneous
    per-adapter constant, bit-exact with every committed baseline."""

    def __init__(self, hw: ServingHardware, fp: ModelFootprint, mode: str,
                 cluster_of: Optional[Dict[int, int]] = None,
                 rank_of: Optional[Dict[int, int]] = None,
                 slice_type=None):
        self.hw, self.fp, self.mode = hw, fp, mode
        self.cluster_of = cluster_of or {}
        self.rank_of = rank_of
        self.slice_type = slice_type
        self.raw_ids: set = set()

    def mark_raw(self, aid: int) -> None:
        """Serve `aid` through the uncompressed SGMV path (hot register)."""
        self.raw_ids.add(aid)

    def unmark_raw(self, aid: int) -> None:
        """`aid`'s cluster basis now serves it (refresh rollout complete)."""
        self.raw_ids.discard(aid)

    def lora_adapter_bytes(self, aid: int) -> int:
        """Bytes one SGMV (uncompressed) adapter streams per decode step.

        Homogeneous (``rank_of=None``): the footprint's per-adapter
        constant, unchanged.  Heterogeneous: scale it to `aid`'s rank
        padded up to the slice's native SGMV contraction tile — the
        per-rank cost :func:`repro.kernels.sgmv.sgmv_tile_cost` prices
        (a tile of 1 means no padding)."""
        if self.rank_of is None:
            return self.fp.lora_bytes_per_adapter
        r = self.rank_of.get(aid, self.fp.lora_rank)
        tile = self.slice_type.sgmv_tile_rank if self.slice_type else 1
        padded = tile * -(-r // tile)
        return (self.fp.lora_bytes_per_adapter * padded) // self.fp.lora_rank

    def adapter_bytes(self, aid: int) -> int:
        if self.mode == "jd" and aid not in self.raw_ids:
            return self.fp.jd_sigma_bytes_per_adapter
        return self.lora_adapter_bytes(aid)

    def shared_bytes(self) -> int:
        if self.mode == "jd":
            return self.fp.jd_shared_bytes_per_cluster * self.fp.n_clusters
        return 0

    def decode_step_time(self, batch: Sequence[Request]) -> float:
        B = len(batch)
        if B == 0:
            return 0.0
        uniq = {r.adapter_id for r in batch}
        t_w = self.fp.weight_bytes / self.hw.hbm_bw
        t_f = 2.0 * self.fp.n_active_params * B / self.hw.peak_flops
        if self.mode == "jd":
            raw = uniq & self.raw_ids
            n_raw_slots = sum(1 for r in batch if r.adapter_id in raw)
            ucl = {self.cluster_of.get(a, 0) for a in uniq - raw}
            extra = (len(ucl) * self.fp.jd_shared_bytes_per_cluster
                     + (B - n_raw_slots) * self.fp.jd_sigma_bytes_per_adapter
                     + sum(self.lora_adapter_bytes(a) for a in raw)
                     ) / self.hw.hbm_bw
        else:
            extra = (sum(self.lora_adapter_bytes(a) for a in uniq)
                     + 0) / self.hw.hbm_bw
        return max(t_w + extra, t_f) + self.hw.step_overhead

    def prefill_time(self, req: Request) -> float:
        fl = 2.0 * self.fp.n_active_params * req.prompt_len
        return fl / (self.hw.peak_flops * self.hw.mfu_prefill)

    def kv_bytes(self, req: Request) -> int:
        """KV-cache bytes produced by prefill (shipped on disagg handoff)."""
        return self.fp.kv_bytes_per_token * req.prompt_len


# ---------------------------------------------------------------------------
# engine
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class EngineConfig:
    scheduler: SchedulerConfig = dataclasses.field(default_factory=SchedulerConfig)
    adapter_budget_bytes: float = 2e9
    mode: str = "lora"               # lora | jd
    prefetch: bool = False           # opportunistic warm-up of queued adapters
    # waiting-queue lookahead for prefetch; None = adaptive — follow the
    # router-fed queue depth (every request already known to the engine),
    # so bursts warm proportionally more adapters ahead of admission
    prefetch_depth: Optional[int] = None
    # unified paging (PR 6): when set, adapter weights and KV blocks share
    # ONE paged HBM pool — KV pages are reserved (worst case) at admission
    # and adapter eviction funds decode pages and vice versa;
    # ``adapter_budget_bytes`` is ignored.  None = legacy static split,
    # bit-exact with the pre-paging engine.
    pool: Optional[PagedPoolConfig] = None
    # KV page reservation policy (paged engines only).  "worst_case"
    # reserves prompt + max_new_tokens pages at admission — decode never
    # fails mid-request (bit-exact with every committed baseline).
    # "on_demand" reserves only the prompt (+1 token) and grows the
    # reservation page by page as decode crosses 128-token boundaries, so
    # long-max_new_tokens tails stop holding idle pages; when the pool is
    # exhausted mid-growth the engine preempts a running victim through
    # the live-migration machinery (ServingEngine.preempt).
    kv_reserve: str = "worst_case"
    # eviction-fairness cap consulted by the growth path: a request
    # already bounced this many times is not picked as a victim while an
    # uncapped candidate exists (Scheduler.pick_victim, invariant M5)
    max_preemptions: int = 3
    # real-executor decode path (PR 8): "unfused" keeps the generic
    # transformer decode step (bit-exact with every committed baseline);
    # "fused" runs the one-pass flash-decode + adapter-delta kernel
    # (kernels/fused_decode.py) with a donated in-place KV cache;
    # "fused_q8" additionally serves adapters from int8 per-channel banks
    # (kernels/adapter_quant.py).  Ignored by CostModelExecutor; a
    # RealModelExecutor must be constructed with the matching path.
    decode_path: str = "unfused"


class ServingEngine:
    """Simulated-clock continuous-batching engine."""

    def __init__(self, cfg: EngineConfig, executor,
                 cluster_of: Optional[Dict[int, int]] = None,
                 slice_type=None):
        self.cfg = cfg
        self.executor = executor
        # the hardware slice class this replica occupies (None: the legacy
        # interchangeable accelerator); the Fleet's rank-aware routing
        # reads decode_speed and sgmv_tile_rank off it
        self.slice_type = slice_type
        ex_path = getattr(executor, "decode_path", None)
        if ex_path is not None and ex_path != cfg.decode_path:
            raise ValueError(f"engine decode_path={cfg.decode_path!r} but "
                             f"the executor was built with {ex_path!r}")
        if cfg.kv_reserve not in ("worst_case", "on_demand"):
            raise ValueError(f"kv_reserve must be 'worst_case' or "
                             f"'on_demand', got {cfg.kv_reserve!r}")
        if cfg.kv_reserve == "on_demand" and cfg.pool is None:
            raise ValueError("kv_reserve='on_demand' requires a paged pool")
        self.scheduler = Scheduler(cfg.scheduler, cluster_of)
        self.pool: Optional[PagedPool] = None
        if cfg.pool is not None:
            fp = getattr(executor, "fp", None)
            if fp is None or fp.kv_bytes_per_token <= 0:
                raise ValueError("a paged engine needs an executor with a "
                                 "ModelFootprint (kv_bytes_per_token > 0)")
            self.pool = PagedPool(cfg.pool)
            self.pool.set_reclaimer(
                lambda n: self.cache.reclaim(n, self._protected()))
        self.cache = AdapterCache(CacheConfig(cfg.adapter_budget_bytes),
                                  pool=self.pool)
        if cfg.mode == "jd":
            self.cache.pin_shared(executor.shared_bytes())
        self.clock = 0.0
        self.stats = ServeStats()
        self.running: List[Request] = []
        self.waiting: List[Request] = []
        self.on_finish = None        # optional callback(req) on completion
        # optional callback(req) -> bool when the growth path must evict a
        # running request: return True if the victim was live-migrated to
        # another replica (MigrationPolicy wires Fleet.migrate here); False
        # (or no handler) falls back to a local host swap (see preempt)
        self.on_preempt = None
        self._kv_held: Dict[int, int] = {}   # rid -> reserved KV pages
        self._admitting: Optional[int] = None  # adapter id mid-reservation
        self._page_blocked = False   # last _admit deferred a ready request

    # -- unified paging helpers ---------------------------------------------
    def _protected(self) -> set:
        """Weight keys a reclaim must not evict: the running batch's, plus
        the adapter of the request being admitted right now."""
        prot = {weight_key(r) for r in self.running}
        if self._admitting is not None:
            prot.add(self._admitting)
        return prot

    def _kv_pages(self, req: Request) -> int:
        """KV pages to reserve for `req` at admission: the full worst case
        (``prompt + max_new_tokens``) so decode never fails mid-request, or
        just the blocks its KV occupies *now* plus the next token under
        ``kv_reserve="on_demand"`` (grown per step by `_grow_kv`; see
        docs/architecture.md)."""
        if self.cfg.kv_reserve == "on_demand":
            tokens = req.prompt_len + req.generated + 1
        else:
            tokens = req.prompt_len + req.max_new_tokens
        return self.pool.pages_for(tokens * self.executor.fp.kv_bytes_per_token)

    def _reserve(self, req: Request, pending_adapter_pages: int
                 ) -> Optional[int]:
        """Try to fund `req`'s admission from the pool: its KV reservation
        (`_kv_pages`; reclaiming cold adapters if needed) AND, if its adapter is
        not resident, the adapter's pages.  `pending_adapter_pages` counts
        adapters of requests admitted earlier in the same round whose load
        has not been issued yet, so one round cannot overcommit.  Returns
        the adapter pages this request will add (0 if resident), or None
        when it cannot fit even after evicting every unprotected adapter
        (the request stays waiting)."""
        kv_need = self._kv_pages(req)
        a_need = (0 if self.cache.is_resident(weight_key(req)) else
                  self.pool.pages_for(
                      self.executor.adapter_bytes(req.adapter_id)))
        self._admitting = weight_key(req)
        try:
            if not self.pool.feasible(
                    kv_need, a_need + pending_adapter_pages,
                    self.cache.evictable_pages(self._protected())):
                return None
            if not self.pool.alloc_with_reclaim("kv", kv_need):
                return None          # unreachable given feasible(); belt
            self._kv_held[req.rid] = kv_need
            return a_need
        finally:
            self._admitting = None

    def submit(self, reqs: Sequence[Request]) -> None:
        self.waiting.extend(reqs)
        self.waiting.sort(key=lambda r: r.ready_time)

    def refresh_shared(self, nbytes: int, now: float) -> float:
        """Swap this replica's pinned shared bases for a refreshed set of
        `nbytes` (one step of a basis-refresh rollout, or its rollback).

        The replica decodes nothing while its bases are in flight — the
        DMA stalls this clock (charged as swap time), which is exactly why
        the lifecycle rolls replicas one at a time (invariant L2): the
        rest of the fleet keeps serving.  Returns the completion time."""
        self.clock = max(self.clock, now)
        t_done = self.cache.repin_shared(nbytes, self.clock)
        self.stats.swap_time += t_done - self.clock
        self.clock = t_done
        return t_done

    def _admit(self) -> None:
        admitted = self.scheduler.admit(self.running, self.waiting,
                                        self.cache.resident_ids, self.clock)
        pending_adapter_pages = 0
        self._page_blocked = False
        for r in admitted:
            if self.pool is not None:
                a_need = self._reserve(r, pending_adapter_pages)
                if a_need is None:
                    # stays waiting; retried when pages free up (a finished
                    # decode or an adapter eviction)
                    self.stats.n_page_blocked += 1
                    self._page_blocked = True
                    continue
                if r.prefilled:
                    # disagg: the adapter load is issued in step(); account
                    # for it so this round cannot overcommit the pool
                    pending_adapter_pages += a_need
            self.waiting.remove(r)
            if r.start_time is None:     # disagg requests keep prefill start
                r.start_time = self.clock
            if not r.prefilled:
                # colocated serving: prefill runs inline at admission.
                # adapter must be resident before prefill
                t_ready = self.cache.ensure(
                    weight_key(r),
                    self.executor.adapter_bytes(r.adapter_id),
                    self.clock,
                    protected=self._protected() | {weight_key(r)})
                stall = max(0.0, t_ready - self.clock)
                t_pre = self.executor.prefill_time(r)
                self.clock += stall + t_pre
                self.stats.swap_time += stall
                self.stats.compute_time += t_pre
                r.prefilled = True
            else:
                if (r.kv_decompress_cost > 0
                        and r.decompress_done_time is None):
                    # compressed disagg handoff: the KV arrives quantized
                    # and is dequantized on THIS replica, charging the
                    # compute to the decode tier.  Dequant streams per
                    # landed chunk and overlaps the transfer tail
                    # (mirroring the first-chunk admission model), so the
                    # WHOLE cost is charged once here —
                    # decompress_done_time marks when the replica paid it,
                    # which can precede kv_landed_time
                    self.clock += r.kv_decompress_cost
                    self.stats.decompress_time += r.kv_decompress_cost
                    merge_mode_dict(self.stats.decompress_by_mode,
                                    {r.wire_mode: r.kv_decompress_cost})
                    r.decompress_done_time = self.clock
                if r.kv_restore_cost > 0:
                    # migrated-in checkpoint (wire dequant) or a locally
                    # preempted request returning from host (swap round
                    # trip): the admitting replica pays the pending
                    # restore exactly once, then the request resumes at
                    # the same `generated` position it was stopped at
                    self.clock += r.kv_restore_cost
                    self.stats.restore_time += r.kv_restore_cost
                    r.kv_restore_cost = 0.0
            self.running.append(r)

    # -- live migration / preemption (PR 9) ---------------------------------
    def checkpoint(self, req: Request) -> int:
        """Detach `req` from this engine for migration or preemption.

        Removes it from its decode slot (or the waiting queue) and frees
        its KV page reservation IMMEDIATELY — the pages are back in the
        source pool at checkpoint time, not when the checkpoint lands on
        its target (invariant M3) — and returns the raw KV bytes that
        must move: the prompt's blocks plus every generated token's (the
        full decoded prefix; token-exact resume needs all of it).  A
        request with no KV on this replica yet (colocated, still
        waiting) checkpoints at zero bytes.  The caller owns what
        happens next: `Fleet.migrate` ships the bytes over the fabric,
        :meth:`preempt`'s local fallback swaps them to host."""
        if req in self.running:
            self.running.remove(req)
        elif req in self.waiting:
            self.waiting.remove(req)
        else:
            raise ValueError(f"request {req.rid} is not on this engine")
        if self.pool is not None:
            self.pool.free("kv", self._kv_held.pop(req.rid, 0))
        if not req.prefilled and req.generated == 0:
            return 0
        fp = getattr(self.executor, "fp", None)
        if fp is None:
            return 0
        return (req.prompt_len + req.generated) * fp.kv_bytes_per_token

    def preempt(self, victim: Request) -> None:
        """Evict `victim` from its decode slot (page pressure, or a
        higher-priority tenant via serving/migration.py).

        The preferred path is live migration: `on_preempt` checkpoints
        the victim and rehomes it on another replica over the fabric.
        Without a handler — or when it declines (single-replica fleet) —
        the checkpoint swaps to HOST memory instead: pages free now, and
        the swap-out + swap-in DMA round trip is charged when the victim
        is re-admitted (`Request.kv_restore_cost`, counted as
        restore_time).  Either way the victim keeps its `generated`
        position: preemption delays a request, never restarts it."""
        victim.preemptions += 1
        self.stats.n_preempted += 1
        if self.on_preempt is not None and self.on_preempt(victim):
            return
        nbytes = self.checkpoint(victim)
        if nbytes > 0:
            dma = self.cache.cfg.dma
            victim.kv_restore_cost += 2 * (dma.latency
                                           + nbytes / dma.bandwidth)
        self.submit([victim])

    def _grow_kv(self) -> None:
        """Mid-decode reservation growth (``kv_reserve="on_demand"``):
        before the step writes each running request's next token, extend
        its reservation to cover ``prompt + generated + 1`` tokens.
        Growth that cannot be funded even after reclaiming cold adapters
        preempts a victim (lowest priority, then smallest KV — never the
        grower itself) and retries."""
        bpt = self.executor.fp.kv_bytes_per_token
        for r in list(self.running):
            if r not in self.running:    # preempted by an earlier grower
                continue
            need = self.pool.pages_for((r.prompt_len + r.generated + 1) * bpt)
            while need > self._kv_held.get(r.rid, 0):
                held = self._kv_held.get(r.rid, 0)
                if self.pool.alloc_with_reclaim("kv", need - held):
                    self._kv_held[r.rid] = need
                    break
                victim = (self.scheduler.pick_victim(
                              self.running, protect=(r.rid,),
                              max_moves=self.cfg.max_preemptions)
                          # all candidates at the fairness cap: progress
                          # beats fairness when the alternative is aborting
                          or self.scheduler.pick_victim(self.running,
                                                        protect=(r.rid,)))
                if victim is None:
                    raise MemoryError(
                        f"cannot grow the KV reservation of request "
                        f"{r.rid} and no running request is preemptible: "
                        f"{self.pool.to_dict()}")
                self.preempt(victim)

    def _prefetch_waiting(self) -> None:
        """Opportunistically warm adapters of queued requests.  Low priority:
        never stalls this step and never delays a later demand load (see
        AdapterCache.prefetch).  With ``prefetch_depth=None`` the lookahead
        is adaptive: it tracks the routed queue itself rather than a static
        depth, so a deep backlog warms more adapters ahead."""
        if not self.cfg.prefetch:
            return
        depth = self.cfg.prefetch_depth
        if depth is None:
            depth = len(self.waiting)
        for r in self.waiting[:depth]:
            if r.ready_time > self.clock:       # not yet known to the engine
                break
            self.cache.prefetch(weight_key(r),
                                self.executor.adapter_bytes(r.adapter_id),
                                self.clock)

    def step(self) -> bool:
        """One engine iteration; returns False when fully drained."""
        if not self.running and not self.waiting:
            return False
        if not self.running and self.waiting:
            # jump to next arrival (KV-ready time for disaggregated requests)
            self.clock = max(self.clock, self.waiting[0].ready_time)
        self._admit()
        if not self.running:
            if self.pool is not None and self._page_blocked:
                # an empty engine has every KV page free and every adapter
                # evictable — if the head request STILL cannot be funded it
                # never will be, and retrying would spin the clock forever
                raise MemoryError(
                    f"paged pool cannot fit a single request: "
                    f"{self.pool.to_dict()}")
            return True
        if self.pool is not None and self.cfg.kv_reserve == "on_demand":
            self._grow_kv()
            if not self.running:     # the whole batch was preempted away
                return True
        # ensure all batch adapters resident (overlapped DMA; stall on max)
        batch_ids = {weight_key(r) for r in self.running}
        t_ready = self.clock
        for r in self.running:
            t_ready = max(t_ready, self.cache.ensure(
                weight_key(r), self.executor.adapter_bytes(r.adapter_id),
                self.clock, protected=batch_ids))
        stall = max(0.0, t_ready - self.clock)
        self._prefetch_waiting()
        self.stats.peak_batch = max(self.stats.peak_batch, len(self.running))
        self.stats.peak_resident_adapters = max(
            self.stats.peak_resident_adapters, len(self.cache.resident_ids))
        t_step = self.executor.decode_step_time(self.running)
        self.clock += stall + t_step
        self.stats.swap_time += stall
        self.stats.compute_time += t_step
        self.stats.n_tokens += len(self.running)
        for r in self.running:
            r.generated += 1
            if r.generated == 1:
                r.first_token_time = self.clock
            if r.done:
                r.finish_time = self.clock
                if self.pool is not None:   # release the KV reservation
                    self.pool.free("kv", self._kv_held.pop(r.rid, 0))
                self.stats.record_finish(r)
                if self.on_finish is not None:
                    self.on_finish(r)
        self.running = [r for r in self.running if not r.done]
        return True

    def run(self, max_steps: int = 10_000_000) -> ServeStats:
        steps = 0
        while self.step() and steps < max_steps:
            steps += 1
        self.stats.wall_time = self.clock
        self.stats.n_swaps = self.cache.n_swaps
        if self.pool is not None:
            self.stats.peak_kv_pages = self.pool.peak["kv"]
            self.stats.peak_adapter_pages = self.pool.peak["adapter"]
            self.stats.n_page_reclaims = self.pool.n_reclaims
            self.stats.pages_reclaimed = self.pool.pages_reclaimed
        return self.stats
