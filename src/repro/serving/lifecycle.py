"""Online adapter lifecycle: hot register / update / retire, no redeploys.

The compressed-basis clusters (``core/cluster.py``) are built offline over
a fixed adapter collection, but production traffic is tenants and A/B
variants arriving and retiring all day (the SageMaker/vLLM runtime-LoRA
pattern).  :class:`AdapterLifecycle` is the control plane that closes the
gap over a live :class:`~repro.serving.router.Fleet`:

- **register** — the adapter is servable *immediately*: every executor
  gets a raw overlay (``CostModelExecutor.mark_raw``) so it decodes
  through the uncompressed SGMV path
  (:func:`repro.core.collection.export_uncompressed`) with no compression
  in its critical path (invariant L1).  Its nearest cluster basis is
  assigned incrementally at register time
  (:func:`repro.core.cluster.assign_adapter` over the existing bases —
  no re-solve), which routing and scheduling use right away; the basis
  only *serves* it after a refresh ships fleet-wide.
- **background basis refresh** — on a cadence, a rollout walks the fleet
  one replica at a time (invariant L2): each replica hot-swaps its pinned
  bases (:meth:`ServingEngine.refresh_shared
  <repro.serving.engine.ServingEngine.refresh_shared>` — the DMA stalls
  only that replica) after a quality gate checks the candidate on it.
  The gate reuses the kernel-vs-oracle agreement / reconstruction-error
  machinery (``tests/test_kvcomp``-style agreement plus
  :func:`repro.core.cluster.refresh_gate`); a failure rolls every
  already-swapped replica back to the prior basis and aborts the rollout
  — the absorbed adapters keep serving raw (invariant L3).
- **update** — retire+register under the same id with a bumped weight
  *epoch*: requests are stamped with the epoch they were routed against
  and finish on it (:func:`~repro.serving.request.weight_key` keys caches
  per epoch), so an update never swaps weights under an in-flight request
  (invariant L4).
- **retire** — routing affinity drains immediately
  (:meth:`Fleet.drop_home <repro.serving.router.Fleet.drop_home>`), the
  adapter's cache/:class:`~repro.serving.resources.PagedPool` pages are
  released once its last in-flight request finishes, and its Sigma row is
  dropped lazily at the next basis refresh (invariant L5).

The full state machine and the L1-L5 invariants are specified in
``docs/lifecycle.md`` and asserted by ``tests/test_lifecycle.py``.  The
control plane is simulation-side (jax-free): the grounded assignment /
gate computations plug in through ``assign_fn`` / ``gate_fn``.

:func:`make_churn_workload` and :func:`run_churn_study` drive churn
scenarios — Poisson adapter arrival/retirement streams over a Zipf base
load — measured by ``benchmarks/adapter_churn.py``.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from .request import Request, weight_key
from .router import Fleet, FleetStats
from .workload import WorkloadSpec, make_workload

# adapter states (docs/lifecycle.md §1)
REGISTERED = "registered"        # accepted, raw overlay being installed
RAW_SERVING = "raw-serving"      # served via uncompressed SGMV path
REFRESHING = "refreshing"        # a rollout is absorbing it (still raw)
CLUSTER_ASSIGNED = "cluster-assigned"  # its cluster basis serves it
RETIRED = "retired"              # no new routing; draining / drained

LIFECYCLE_STATES = (REGISTERED, RAW_SERVING, REFRESHING, CLUSTER_ASSIGNED,
                    RETIRED)


@dataclasses.dataclass
class LifecycleConfig:
    """Control-plane knobs (defaults are the churn benchmark's)."""
    # seconds between basis-refresh rollouts (a rollout starts only when
    # raw adapters or drained retirements are pending)
    refresh_interval: float = 2.0
    # minimum spacing between consecutive per-replica base swaps inside
    # one rollout — the "one replica at a time" pacing (invariant L2)
    rollout_step_interval: float = 0.05
    # gate thresholds: a candidate basis ships to a replica only if the
    # gate's reconstruction error and kernel-vs-oracle agreement clear
    # these (otherwise: rollback, invariant L3)
    gate_max_rel_err: float = 0.5
    gate_min_agreement: float = 0.99


@dataclasses.dataclass
class GateResult:
    """Outcome of one per-replica gate check during a rollout.

    ``rel_err`` is the candidate's worst newly-absorbed relative
    reconstruction error (``refresh_gate``'s ``new_worst_rel_err``);
    ``agreement`` the kernel-vs-oracle match fraction on the replica
    (1.0 = bit-exact, the ``tests/test_kvcomp`` machinery).  ``ok``
    carries any additional gate-internal verdict (e.g. ``refresh_gate``'s
    no-regression check)."""
    ok: bool = True
    rel_err: float = 0.0
    agreement: float = 1.0
    reason: str = ""


@dataclasses.dataclass
class AdapterState:
    """One adapter's lifecycle record (state machine in docs/lifecycle.md)."""
    aid: int
    state: str
    epoch: int = 0
    cluster: Optional[int] = None
    registered_at: float = 0.0
    retired_at: Optional[float] = None
    # epoch -> requests routed but not yet finished.  An update bumps
    # `epoch`; stale epochs drain here and release their weights when
    # their count hits zero (invariant L4).
    inflight: Dict[int, int] = dataclasses.field(default_factory=dict)

    @property
    def live(self) -> bool:
        return self.state != RETIRED


@dataclasses.dataclass
class BasisRollout:
    """One in-flight replica-by-replica basis refresh (at most one
    fleet-wide, invariant L2)."""
    version: int                     # basis version this rollout ships
    adapters: Tuple[Tuple[int, int], ...]   # (aid, epoch) being absorbed
    shrinks: Tuple[int, ...]         # drained retirees whose Sigma row drops
    targets: Tuple[Tuple[str, int], ...]    # ("decode"|"prefill", index)
    started_at: float
    next_at: float                   # earliest time of the next swap
    next_idx: int = 0                # first not-yet-swapped target


@dataclasses.dataclass
class LifecycleStats:
    n_registered: int = 0
    n_updated: int = 0
    n_retired: int = 0
    n_refreshes: int = 0             # rollouts completed fleet-wide
    n_rollbacks: int = 0             # rollouts aborted by a failed gate
    n_gate_checks: int = 0
    n_gate_failures: int = 0
    n_shrunk: int = 0                # Sigma rows dropped at refreshes
    raw_requests: int = 0            # stamped while raw-serving/refreshing
    assigned_requests: int = 0       # stamped while cluster-assigned
    bytes_released: int = 0          # cache/pool bytes freed by drains

    def to_dict(self) -> Dict:
        return dataclasses.asdict(self)


def _default_gate(rollout: BasisRollout,
                  target: Tuple[str, int]) -> GateResult:
    """Stand-in gate for pure-simulation runs: always ships.  Grounded
    runs plug :func:`repro.core.cluster.refresh_gate` + a kernel agreement
    check in through ``AdapterLifecycle(gate_fn=...)``."""
    return GateResult(ok=True)


class AdapterLifecycle:
    """Control plane for online adapter register / update / retire.

    Owns the per-adapter state machine, request epoch stamping, and the
    background basis-refresh rollouts over a live
    :class:`~repro.serving.router.Fleet`.  Construction hooks every
    decode replica's ``on_finish`` (chaining any existing callback) so
    drains are observed; replicas added later must be attached with
    :meth:`attach_engine`.

    ``assign_fn(aid) -> cluster`` supplies the incremental
    nearest-cluster assignment (grounded:
    :func:`repro.core.cluster.assign_adapter` over the real bank; the
    default hashes over the footprint's cluster count).
    ``gate_fn(rollout, target) -> GateResult`` supplies the per-replica
    refresh gate (grounded: :func:`repro.core.cluster.refresh_gate` plus
    a kernel-vs-oracle agreement check; the default always passes).
    """

    def __init__(self, fleet: Fleet, cfg: Optional[LifecycleConfig] = None,
                 assign_fn: Optional[Callable[[int], int]] = None,
                 gate_fn: Optional[
                     Callable[[BasisRollout, Tuple[str, int]],
                              GateResult]] = None):
        self.fleet = fleet
        self.cfg = cfg or LifecycleConfig()
        self.assign_fn = assign_fn or self._hash_assign
        self.gate_fn = gate_fn or _default_gate
        self.adapters: Dict[int, AdapterState] = {}
        self.basis_version = 0
        self.rollout: Optional[BasisRollout] = None
        self.stats = LifecycleStats()
        self._last_refresh = 0.0
        self._shrink_pending: set = set()
        self._mode = fleet.engines[0].cfg.mode
        for eng in fleet.engines:
            self.attach_engine(eng)

    # -- fleet plumbing -----------------------------------------------------
    def attach_engine(self, eng) -> None:
        """Chain this lifecycle onto a replica's completion callback."""
        prev = eng.on_finish

        def hook(r: Request, _prev=prev) -> None:
            self.note_finish(r)
            if _prev is not None:
                _prev(r)

        eng.on_finish = hook

    def _hash_assign(self, aid: int) -> int:
        fp = getattr(self.fleet.engines[0].executor, "fp", None)
        k = max(1, getattr(fp, "n_clusters", 1))
        return aid % k

    def _executors(self):
        for eng in self.fleet.engines:
            yield eng.executor
        if self.fleet.prefill_tier is not None:
            for w in self.fleet.prefill_tier.workers:
                yield w.executor

    def _caches(self):
        for eng in self.fleet.engines:
            yield eng.cache
        if self.fleet.prefill_tier is not None:
            for w in self.fleet.prefill_tier.workers:
                yield w.cache

    def _mark_raw(self, aid: int, raw: bool) -> None:
        for ex in self._executors():
            fn = getattr(ex, "mark_raw" if raw else "unmark_raw", None)
            if fn is not None:
                fn(aid)

    def _discard_weights(self, aid: int, epoch: int) -> None:
        key = aid if epoch == 0 else (aid, epoch)
        for cache in self._caches():
            self.stats.bytes_released += cache.discard(key)

    @property
    def refresh_active(self) -> bool:
        """True while a basis rollout walks the fleet — the signal wired
        into :meth:`JointAutoscaler.decide
        <repro.serving.autoscaler.JointAutoscaler.decide>` as
        ``refresh_active`` (a mid-rollout fleet must not shed replicas)."""
        return self.rollout is not None

    def state_of(self, aid: int) -> Optional[str]:
        st = self.adapters.get(aid)
        return None if st is None else st.state

    # -- register / update / retire ------------------------------------------
    def register(self, aid: int, now: float = 0.0) -> AdapterState:
        """Hot-register `aid`: raw-servable immediately (invariant L1).

        The adapter enters ``registered`` and transitions to
        ``raw-serving`` in the same control-plane action: every executor
        (decode replicas and prefill workers) gets the raw SGMV overlay,
        and the nearest cluster basis is assigned incrementally — routing
        affinity uses the cluster at once, while decode stays raw until a
        refresh rollout completes fleet-wide."""
        st = self.adapters.get(aid)
        if st is not None and st.live:
            raise ValueError(f"adapter {aid} is already live ({st.state})")
        epoch = st.epoch + 1 if st is not None else 0
        st = AdapterState(aid=aid, state=REGISTERED, epoch=epoch,
                          registered_at=now)
        self.adapters[aid] = st
        self._shrink_pending.discard(aid)     # re-registered before shrink
        self._mark_raw(aid, True)
        st.cluster = self.assign_fn(aid)
        self.fleet.cluster_of[aid] = st.cluster
        st.state = RAW_SERVING
        self.stats.n_registered += 1
        return st

    def update(self, aid: int, now: float = 0.0) -> AdapterState:
        """Replace `aid`'s weights: retire+register under a bumped epoch.

        In-flight requests keep decoding against the epoch they were
        stamped with — the two weight versions are distinct cache entries
        (:func:`~repro.serving.request.weight_key`) — and the stale
        epoch's weights are released when its last request drains
        (invariant L4).  The new weights serve raw until a refresh
        absorbs them (their old Sigma no longer matches)."""
        st = self.adapters.get(aid)
        if st is None or not st.live:
            raise ValueError(f"cannot update unknown/retired adapter {aid}")
        old_epoch = st.epoch
        self._unabsorb(aid)
        st.epoch += 1
        st.state = RAW_SERVING
        st.registered_at = now
        self._mark_raw(aid, True)
        st.cluster = self.assign_fn(aid)
        self.fleet.cluster_of[aid] = st.cluster
        if old_epoch not in st.inflight:
            self._discard_weights(aid, old_epoch)
        self.stats.n_updated += 1
        return st

    def retire(self, aid: int, now: float = 0.0) -> AdapterState:
        """Retire `aid`: no new routing, drain what is in flight.

        Routing affinity is dropped immediately; cache/pool pages are
        released when the last in-flight request finishes; the Sigma row
        is dropped at the next basis refresh (lazy shrink) — invariant
        L5."""
        st = self.adapters.get(aid)
        if st is None or not st.live:
            raise ValueError(f"cannot retire unknown/retired adapter {aid}")
        self._unabsorb(aid)
        st.state = RETIRED
        st.retired_at = now
        self._drop_affinity(aid)
        self.stats.n_retired += 1
        if not st.inflight:
            self._finish_retirement(st)
        return st

    def _unabsorb(self, aid: int) -> None:
        """Pull `aid` out of an in-flight rollout's absorption set (its
        weights changed or it retired; the candidate basis no longer
        describes it)."""
        if self.rollout is not None:
            self.rollout.adapters = tuple(
                (a, e) for a, e in self.rollout.adapters if a != aid)

    def _drop_affinity(self, aid: int) -> None:
        self.fleet.drop_home(aid)
        if self.fleet.cfg.policy == "cluster_affinity":
            ckey = self.fleet.cluster_of.get(aid)
            if ckey is not None and not any(
                    k != aid and v == ckey
                    for k, v in self.fleet.cluster_of.items()):
                self.fleet.drop_home(ckey)

    def _finish_retirement(self, st: AdapterState) -> None:
        """The last in-flight request drained: release every replica's
        pages for every epoch and queue the lazy basis shrink."""
        self._discard_weights(st.aid, st.epoch)
        self._mark_raw(st.aid, False)
        self.fleet.cluster_of.pop(st.aid, None)
        self._shrink_pending.add(st.aid)

    # -- request flow --------------------------------------------------------
    def stamp(self, reqs: Sequence[Request]) -> None:
        """Stamp each request with its adapter's current weight epoch and
        count it in flight.  Call before ``fleet.submit`` — retired
        adapters are not routable and raise.  Requests for adapters this
        lifecycle does not manage (the pre-existing offline collection)
        pass through untouched at epoch 0."""
        for r in reqs:
            st = self.adapters.get(r.adapter_id)
            if st is None:
                continue
            if not st.live:
                raise ValueError(
                    f"request {r.rid} targets retired adapter {r.adapter_id}")
            r.adapter_epoch = st.epoch
            st.inflight[st.epoch] = st.inflight.get(st.epoch, 0) + 1
            if st.state == CLUSTER_ASSIGNED:
                self.stats.assigned_requests += 1
            else:
                self.stats.raw_requests += 1

    def note_finish(self, r: Request) -> None:
        """Observe a completion (wired into each engine's ``on_finish``):
        decrement the epoch's in-flight count and run any drain-deferred
        release — a stale epoch's weights after an update, or the full
        page/affinity release after a retire."""
        st = self.adapters.get(r.adapter_id)
        if st is None:
            return
        n = st.inflight.get(r.adapter_epoch, 0) - 1
        if n > 0:
            st.inflight[r.adapter_epoch] = n
        else:
            st.inflight.pop(r.adapter_epoch, None)
            if r.adapter_epoch != st.epoch:
                self._discard_weights(r.adapter_id, r.adapter_epoch)
            elif not st.live:
                self._finish_retirement(st)

    # -- background basis refresh --------------------------------------------
    def tick(self, now: float) -> None:
        """Advance the control plane to simulated time `now`: step an
        in-flight rollout (one replica per ``rollout_step_interval``) or
        start one when the refresh cadence has elapsed and work is
        pending.  Drivers call this once per window."""
        if self.rollout is not None:
            self._advance_rollout(now)
        if (self.rollout is None and self._mode == "jd"
                and now - self._last_refresh >= self.cfg.refresh_interval):
            pending = [(st.aid, st.epoch) for st in self.adapters.values()
                       if st.state == RAW_SERVING]
            if pending or self._shrink_pending:
                self._start_rollout(now, pending)
                self._advance_rollout(now)

    def _rollout_targets(self) -> Tuple[Tuple[str, int], ...]:
        targets = [("decode", i) for i in self.fleet._active_idxs()]
        tier = self.fleet.prefill_tier
        if tier is not None:
            targets += [("prefill", i) for i in tier._active_idxs()]
        return tuple(targets)

    def _start_rollout(self, now: float,
                       pending: List[Tuple[int, int]]) -> None:
        self.rollout = BasisRollout(
            version=self.basis_version + 1,
            adapters=tuple(sorted(pending)),
            shrinks=tuple(sorted(self._shrink_pending)),
            targets=self._rollout_targets(),
            started_at=now, next_at=now)
        self._last_refresh = now
        for aid, epoch in self.rollout.adapters:
            st = self.adapters[aid]
            if st.epoch == epoch and st.state == RAW_SERVING:
                st.state = REFRESHING
        self.stats.n_shrunk += len(self.rollout.shrinks)

    def _target_obj(self, target: Tuple[str, int]):
        kind, i = target
        if kind == "decode":
            return self.fleet.engines[i]
        return self.fleet.prefill_tier.workers[i]

    def _swap(self, target: Tuple[str, int], now: float) -> None:
        obj = self._target_obj(target)
        obj.refresh_shared(obj.executor.shared_bytes(), now)

    def _advance_rollout(self, now: float) -> None:
        ro = self.rollout
        while ro is not None and ro.next_idx < len(ro.targets) \
                and ro.next_at <= now:
            target = ro.targets[ro.next_idx]
            self._swap(target, ro.next_at)       # load candidate bases
            self.stats.n_gate_checks += 1
            gate = self.gate_fn(ro, target)      # kernel-vs-oracle check
            if (not gate.ok
                    or gate.agreement < self.cfg.gate_min_agreement
                    or gate.rel_err > self.cfg.gate_max_rel_err):
                self.stats.n_gate_failures += 1
                self._rollback(ro, now)
                return
            ro.next_idx += 1
            ro.next_at += self.cfg.rollout_step_interval
        if ro is not None and ro.next_idx >= len(ro.targets):
            self._complete_rollout(now)

    def _rollback(self, ro: BasisRollout, now: float) -> None:
        """A gate failed on a replica: every replica that holds the
        candidate basis (including the failed one) re-pins the prior
        basis, the rollout aborts, and the absorbed adapters keep serving
        raw (invariant L3).  The next cadence retries with a fresh
        candidate."""
        for target in ro.targets[:ro.next_idx + 1]:
            self._swap(target, now)              # re-pin the prior basis
        for aid, epoch in ro.adapters:
            st = self.adapters.get(aid)
            if st is not None and st.epoch == epoch \
                    and st.state == REFRESHING:
                st.state = RAW_SERVING
        self.stats.n_rollbacks += 1
        self.rollout = None
        self._last_refresh = now

    def _complete_rollout(self, now: float) -> None:
        """Every replica holds the new basis: absorbed adapters flip to
        cluster-assigned (their raw weights are released — the basis
        serves them; Sigma demand-loads), shrinks land, the version
        bumps."""
        ro = self.rollout
        self.basis_version = ro.version
        for aid, epoch in ro.adapters:
            st = self.adapters.get(aid)
            if st is None or st.epoch != epoch or st.state != REFRESHING:
                continue                          # updated/retired mid-roll
            st.state = CLUSTER_ASSIGNED
            self._mark_raw(aid, False)
            self._discard_weights(aid, epoch)
        for aid in ro.shrinks:
            self._shrink_pending.discard(aid)
        self.stats.n_refreshes += 1
        self.rollout = None
        self._last_refresh = now


# ---------------------------------------------------------------------------
# churn workloads + study driver
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class LifecycleEvent:
    """One control-plane action in a churn stream."""
    t: float
    action: str                      # register | update | retire
    adapter_id: int


@dataclasses.dataclass
class ChurnSpec:
    """Poisson adapter arrival/retirement over a Zipf(ish) base load.

    `base` describes the steady-state request stream over the offline
    collection (adapter ids ``0..n_adapters-1``).  Churn adapters take
    ids from ``n_adapters`` upward: they register at Poisson rate
    `churn_rate`, live an exponential `lifetime`, emit their own Poisson
    `request_rate` stream while live, may see one mid-life weight update
    (`update_prob`), and retire at end of life.  Deterministic per
    `seed`."""
    base: WorkloadSpec
    churn_rate: float = 1.0          # registrations per second
    lifetime: float = 3.0            # mean seconds live before retirement
    request_rate: float = 20.0       # req/s per live churn adapter
    update_prob: float = 0.25        # chance of one mid-life update
    seed: int = 0


def make_churn_workload(spec: ChurnSpec
                        ) -> Tuple[List[Request], List[LifecycleEvent]]:
    """Generate (requests, events) for a churn study.

    Guarantees the driver relies on: every churn adapter's requests
    arrive strictly inside its [register, retire) window, and event/
    request interleaving is consistent under time-ordered replay."""
    base = make_workload(spec.base)
    horizon = base[-1].arrival_time if base else 1.0
    rng = np.random.default_rng(spec.seed + 0xC0FFEE)
    events: List[LifecycleEvent] = []
    churn_reqs: List[Request] = []
    rid = len(base)
    aid = spec.base.n_adapters
    t = 0.0
    while spec.churn_rate > 0:
        t += rng.exponential(1.0 / spec.churn_rate)
        if t >= horizon:
            break
        life = max(rng.exponential(spec.lifetime), 0.05)
        events.append(LifecycleEvent(t=t, action="register", adapter_id=aid))
        if rng.random() < spec.update_prob:
            events.append(LifecycleEvent(
                t=t + rng.uniform(0.3, 1.0) * life, action="update",
                adapter_id=aid))
        events.append(LifecycleEvent(t=t + life, action="retire",
                                     adapter_id=aid))
        tt = t
        while True:
            tt += rng.exponential(1.0 / spec.request_rate)
            if tt >= t + life:
                break
            plen = int(np.clip(rng.normal(spec.base.prompt_len_mean,
                                          spec.base.prompt_len_std),
                               16, 4 * spec.base.prompt_len_mean))
            churn_reqs.append(Request(
                rid=rid, adapter_id=aid, prompt_len=plen,
                max_new_tokens=spec.base.new_tokens, arrival_time=tt))
            rid += 1
        aid += 1
    events.sort(key=lambda e: e.t)
    reqs = sorted(base + churn_reqs, key=lambda r: r.arrival_time)
    return reqs, events


def apply_event(lc: AdapterLifecycle, ev: LifecycleEvent) -> None:
    if ev.action == "register":
        lc.register(ev.adapter_id, now=ev.t)
    elif ev.action == "update":
        lc.update(ev.adapter_id, now=ev.t)
    elif ev.action == "retire":
        lc.retire(ev.adapter_id, now=ev.t)
    else:
        raise ValueError(f"unknown lifecycle action {ev.action!r}")


def run_churn_study(fleet: Fleet, lifecycle: AdapterLifecycle,
                    requests: Sequence[Request],
                    events: Sequence[LifecycleEvent],
                    window: float = 0.25,
                    max_steps: int = 10_000_000) -> FleetStats:
    """Drive a fleet through a request stream *and* a lifecycle event
    stream in causal time order.

    Per window: interleave arrivals and control-plane events by time (a
    register is visible to the requests behind it; a retire rejects
    nothing retroactively — in-flight requests drain per invariant L4/L5),
    advance the lifecycle (rollout pacing) and every replica to the window
    end.  Returns merged :class:`~repro.serving.router.FleetStats` with
    ``stats.lifecycle`` filled in.

    Thin wrapper over the unified window loop
    (:func:`repro.serving.simulator.run_study`), kept for its established
    signature; proven bit-exact against the committed churn baseline."""
    from .simulator import run_study     # local: simulator imports us
    return run_study(fleet, requests, lifecycle=lifecycle, events=events,
                     window=window, max_steps=max_steps).stats
