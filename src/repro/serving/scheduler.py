"""Continuous-batching scheduler with adapter-aware and cluster-aware
admission (§6.4 + the paper's "clustering offers opportunities for efficient
scheduling" direction in §7).

Policy:
  1. running requests keep their decode slot unless explicitly preempted
     (:meth:`Scheduler.pick_victim` — mid-decode page exhaustion or a
     higher-priority tenant via the live-migration machinery,
     serving/migration.py; the default priority-0 stream never preempts);
  2. free slots admit waiting requests, highest `Request.priority` first,
     then preferring (a) adapters already resident, (b) adapters whose
     *cluster* basis is resident (compressed mode), (c) FIFO otherwise;
  3. per-batch distinct-adapter cap models the SGMV tile-efficiency limit.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

from .request import Request


@dataclasses.dataclass
class SchedulerConfig:
    max_batch: int = 32              # decode slots
    max_adapters_per_batch: int = 32
    cluster_aware: bool = True


class Scheduler:
    def __init__(self, cfg: SchedulerConfig,
                 cluster_of: Optional[Dict[int, int]] = None):
        self.cfg = cfg
        self.cluster_of = cluster_of or {}

    def admit(self, running: List[Request], waiting: List[Request],
              resident: set, now: float) -> List[Request]:
        """Pick waiting requests to admit into free slots."""
        free = self.cfg.max_batch - len(running)
        if free <= 0 or not waiting:
            return []
        active_adapters = {r.adapter_id for r in running}
        active_clusters = {self.cluster_of.get(a) for a in active_adapters}

        def score(req: Request):
            resident_hit = req.adapter_id in resident
            same_adapter = req.adapter_id in active_adapters
            same_cluster = (self.cfg.cluster_aware and
                            self.cluster_of.get(req.adapter_id)
                            in active_clusters)
            # lower = better; priority dominates (all-zero priorities —
            # every pre-migration workload — leave the order unchanged),
            # then FIFO tiebreak by decode-readiness (equals the arrival
            # time for colocated serving)
            return (-req.priority, not same_adapter, not resident_hit,
                    not same_cluster, req.ready_time)

        ready = [r for r in waiting if r.ready_time <= now]
        ready.sort(key=score)
        admitted: List[Request] = []
        adapters = set(active_adapters)
        for r in ready:
            if len(admitted) >= free:
                break
            if r.adapter_id not in adapters and \
                    len(adapters) >= self.cfg.max_adapters_per_batch:
                continue
            adapters.add(r.adapter_id)
            admitted.append(r)
        return admitted

    @staticmethod
    def pick_victim(running: Sequence[Request],
                    below_priority: Optional[int] = None,
                    protect: Sequence[int] = (),
                    max_moves: Optional[int] = None) -> Optional[Request]:
        """Choose which running request to preempt, or None if nobody may
        be.  Eligibility: rid not in `protect`, priority strictly below
        `below_priority` (None = any), and fewer than `max_moves` prior
        evictions — the cap is the starvation guard (invariant M5): a
        request bounced `max_moves` times keeps its slot for good, so
        every victim eventually runs to completion.  Among the eligible,
        the victim is the lowest-priority request, ties broken by the
        smallest KV footprint (cheapest checkpoint to ship), then rid."""
        safe = set(protect)
        cands = [r for r in running
                 if r.rid not in safe
                 and (below_priority is None or r.priority < below_priority)
                 and (max_moves is None
                      or r.migrations + r.preemptions < max_moves)]
        if not cands:
            return None
        return min(cands, key=lambda r: (r.priority,
                                         r.prompt_len + r.generated, r.rid))

    @staticmethod
    def group_by_adapter(batch: Sequence[Request]) -> Dict[int, List[Request]]:
        groups: Dict[int, List[Request]] = {}
        for r in batch:
            groups.setdefault(r.adapter_id, []).append(r)
        return groups
