"""Real-model executor: actually runs prefill/decode with batched LoRA
application on the host (reduced configs).  Wall-clock timed, real logits.

Slot model: a fixed decode batch of ``max_batch`` KV-cache slots; admitted
requests prefill into a free slot (batch-1 prefill, cache splice); each
engine decode step advances every occupied slot by one token with per-slot
adapter ids (mode "lora": stacked A/B banks; mode "jd": U/V/Sigma bundles).

Decode paths (``decode_path``, surfaced as `EngineConfig.decode_path`):

* ``"unfused"`` (default) — the generic `transformer.decode_step`
  (functional cache, separate attention + adapter passes).  Bit-exact
  with every committed baseline.
* ``"fused"`` — a purpose-built decode step: the per-layer loop is
  unrolled, rope tables are built once, the KV cache is DONATED to the
  jit so the single-token write is in-place instead of a full functional
  cache copy per layer, and attention + the o-projection adapter delta
  run as ONE fused pass (`kernels/fused_decode.py` via
  `kernels/ops.py::fused_lora_decode` / `fused_jd_decode`).
* ``"fused_q8"`` — ``"fused"`` plus int8 per-output-channel adapter
  residency (`kernels/adapter_quant.py`): banks are packed at
  construction, `adapter_bytes` shrinks ~4x (threading straight through
  `PagedPool` page accounting), and the o-target bank is dequantized
  inside the fused kernel epilogue; q/k/v banks are dequantized in-jit.

`benchmarks/real_decode.py` measures all three and re-derives the
simulator's cost-model constants from the fused measurements
(:func:`derive_cost_constants`)."""
from __future__ import annotations

import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.kernels import ops as kops
from repro.kernels import ref as kref
from repro.kernels.adapter_quant import adapter_quantize
from repro.models import layers
from repro.models import transformer as tf
from repro.models.lora import LoRAContext
from repro.serving.request import Request

Array = jax.Array

DECODE_PATHS = ("unfused", "fused", "fused_q8")


class RealModelExecutor:
    def __init__(self, cfg: ModelConfig, params, bundles: Dict[str, Dict],
                 mode: str, max_batch: int, s_max: int,
                 cluster_of: Optional[np.ndarray] = None,
                 adapter_bytes_override: Optional[int] = None,
                 decode_path: str = "unfused"):
        """bundles: layer-structured arrays for the adapters:
        mode 'lora': {"layers": {target: {"A": (L,n,r,d), "B": (L,n,d,r)}}}
        mode 'jd':   {"layers": {target: {"U","V","sigma","cluster_of"}}}"""
        if decode_path not in DECODE_PATHS:
            raise ValueError(f"decode_path must be one of {DECODE_PATHS}, "
                             f"got {decode_path!r}")
        self.cfg, self.mode = cfg, mode
        self.decode_path = decode_path
        self.params = params
        self.max_batch = max_batch
        self.s_max = s_max
        self.cluster_of = cluster_of
        self.cache = tf.init_cache(cfg, max_batch, s_max)
        self.slot_req: List[Optional[int]] = [None] * max_batch
        self.slot_adapter = np.zeros(max_batch, np.int32)
        self.slot_tokens = np.zeros(max_batch, np.int32)
        self.slot_len = np.zeros(max_batch, np.int32)
        # host mirror of the cache's scalar index: lets the fused paths pick
        # a static KV bucket without a device sync
        self._host_len = 0
        if decode_path == "unfused":
            self.bundles = bundles
            self._decode = jax.jit(self._decode_fn)
        else:
            self._check_fusable()
            if decode_path == "fused_q8":
                bundles = _quantize_bundles(bundles, mode)
            self.bundles = bundles
            # donate the cache: the per-step single-token KV write happens
            # in place instead of copying every layer's full cache slice
            self._decode = jax.jit(self._fused_decode_fn, donate_argnums=(3,),
                                   static_argnames=("bucket",))
        self._prefill = jax.jit(self._prefill_fn)
        nbytes = sum(x.size * x.dtype.itemsize
                     for x in jax.tree.leaves(self.bundles)) or 1
        n_adapters = self._n_adapters()
        self._adapter_bytes = adapter_bytes_override or max(
            nbytes // max(n_adapters, 1), 1)

    def _check_fusable(self) -> None:
        if self.cfg.family not in ("dense", "vlm"):
            raise ValueError("fused decode paths support dense-attention "
                             f"families only, not {self.cfg.family!r}")
        if self.cfg.sliding_window:
            raise ValueError("fused decode paths assume full attention "
                             "(sliding_window=0)")
        if self.mode not in ("lora", "jd"):
            raise ValueError(f"unknown adapter mode {self.mode!r}")

    def _n_adapters(self) -> int:
        for leaf in jax.tree.leaves(self.bundles):
            return leaf.shape[1] if leaf.ndim > 1 else 1
        return 1

    def _ctx(self, ids: Array) -> LoRAContext:
        return LoRAContext(mode="batched" if self.mode == "lora" else "jd",
                           params=None, ids=ids, scaling=1.0)

    def _decode_fn(self, params, bundles, tokens, cache, ids):
        proto = self._ctx(ids)
        return tf.decode_step(params, tokens, self.cfg, cache,
                              lora_params=bundles, lora_ctx_proto=proto)

    def _prefill_fn(self, params, bundles, tokens, cache, ids):
        if self.decode_path == "fused_q8":
            bundles = _dequantize_bundles(bundles)
        proto = self._ctx(ids)
        return tf.prefill(params, {"tokens": tokens}, self.cfg, cache,
                          lora_params=bundles, lora_ctx_proto=proto)

    # -- fused decode step --------------------------------------------------
    def _bucket(self) -> int:
        """Static KV window for the fused step: the occupied prefix of the
        cache rounded up to 128 tokens (the page/quant-block granule).

        The generic unfused step attends over all ``s_max`` slots every
        step (masked, but computed); the executor knows the occupied
        length on the host, so the fused step only ever touches
        ``ceil(len/128)`` blocks — one retrace per 128 tokens of growth,
        O(active) attention instead of O(s_max)."""
        need = self._host_len + 1
        return min(self.s_max, 128 * -(-need // 128))

    def _fused_decode_fn(self, params, bundles, tokens, cache, ids, *,
                         bucket):
        """Unrolled single-token decode with the o-projection adapter delta
        fused into the attention kernel.  Matches `transformer.decode_step`
        semantics (scalar cache index, decode at max occupied length);
        ``bucket`` (static) truncates attention to the occupied KV prefix
        — masked tail blocks contribute exactly zero, so logits are
        unchanged."""
        cfg = self.cfg
        quant = self.decode_path == "fused_q8"
        banks = bundles["layers"]
        if quant:
            qkv_banks = {t: _dequantize_target(tp)
                         for t, tp in banks.items() if t != "o"}
        else:
            qkv_banks = {t: tp for t, tp in banks.items() if t != "o"}
        o_bank = banks.get("o")
        proto = self._ctx(ids)

        x = layers.embed_tokens(params["embed"], tokens)
        Bt, S, _ = x.shape                       # S == 1
        idx = cache["index"]
        positions = idx + jnp.arange(S, dtype=jnp.int32)
        cos, sin = layers.rope_tables(positions, cfg.resolved_head_dim,
                                      cfg.rope_theta)
        ck, cv = cache["k"], cache["v"]
        kv_len = jnp.broadcast_to(idx + S, (Bt,)).astype(jnp.int32)
        for li in range(cfg.num_layers):
            p_l = jax.tree.map(lambda a: a[li], params["layers"])
            lora_l = {t: jax.tree.map(lambda a: a[li], tp)
                      for t, tp in qkv_banks.items()} or None
            ctx = (LoRAContext(mode=proto.mode, params=lora_l, ids=ids,
                               scaling=proto.scaling)
                   if lora_l is not None else None)
            xin = layers.rms_norm(x, p_l["ln1"], cfg.norm_eps)
            qh, kh, vh = layers._qkv(p_l["attn"], xin, cfg, ctx)
            qh = layers.apply_rope(qh, cos, sin)
            kh = layers.apply_rope(kh, cos, sin)
            ck = jax.lax.dynamic_update_slice(
                ck, kh.astype(ck.dtype)[None], (li, 0, idx, 0, 0))
            cv = jax.lax.dynamic_update_slice(
                cv, vh.astype(cv.dtype)[None], (li, 0, idx, 0, 0))
            attn, delta = self._fused_attn(qh[:, 0], ck[li, :, :bucket],
                                           cv[li, :, :bucket], kv_len,
                                           ids, o_bank, li)
            y = jnp.einsum("bhk,hkd->bd", attn, p_l["attn"]["wo"])
            if delta is not None:
                y = y + (proto.scaling * delta).astype(y.dtype)
            x = x + y[:, None]
            x = x + layers.mlp_fwd(
                p_l["mlp"], layers.rms_norm(x, p_l["ln2"], cfg.norm_eps))
        logits = layers.logits_fwd(params["embed"], x, cfg)
        new_cache = dict(cache)
        new_cache.update(k=ck, v=cv, index=idx + S)
        return logits, new_cache

    def _fused_attn(self, q1, k_l, v_l, kv_len, ids, o_bank, li):
        """One layer's decode attention (+ fused o-delta when the bundles
        carry an "o" target)."""
        if o_bank is None:
            return kops.decode_attention(q1, k_l, v_l, kv_len), None
        if self.mode == "lora":
            if self.decode_path == "fused_q8":
                return kops.fused_lora_decode(
                    q1, k_l, v_l, kv_len, ids,
                    o_bank["A_q"][li], o_bank["B_q"][li],
                    a_scale=o_bank["A_s"][li], b_scale=o_bank["B_s"][li])
            return kops.fused_lora_decode(q1, k_l, v_l, kv_len, ids,
                                          o_bank["A"][li], o_bank["B"][li])
        if self.decode_path == "fused_q8":
            sigma = (o_bank["sigma"][li] if "sigma" in o_bank else
                     kref.adapter_dequant_ref(o_bank["sigma_q"][li],
                                              o_bank["sigma_s"][li]))
            return kops.fused_jd_decode(
                q1, k_l, v_l, kv_len, ids, o_bank["U_q"][li],
                o_bank["V_q"][li], sigma, o_bank["cluster_of"][li],
                u_scale=o_bank["U_s"][li], v_scale=o_bank["V_s"][li])
        return kops.fused_jd_decode(
            q1, k_l, v_l, kv_len, ids, o_bank["U"][li], o_bank["V"][li],
            o_bank["sigma"][li], o_bank["cluster_of"][li])

    # -- engine interface ---------------------------------------------------
    def adapter_bytes(self, aid: int) -> int:
        return self._adapter_bytes

    def shared_bytes(self) -> int:
        return 0

    def prefill_request(self, req: Request, prompt: np.ndarray) -> None:
        slot = self.slot_req.index(None)
        c1 = tf.init_cache(self.cfg, 1, self.s_max)
        logits, c1 = self._prefill(
            self.params, self.bundles, jnp.asarray(prompt[None]), c1,
            jnp.asarray([req.adapter_id], jnp.int32))
        # splice the single-request cache into the slot batch
        def splice(dst, src):
            if dst.ndim == 0:
                return dst
            bdim = _batch_dim(dst)
            idx = [slice(None)] * dst.ndim
            idx[bdim] = slice(slot, slot + 1)
            return dst.at[tuple(idx)].set(src)
        self.cache = jax.tree.map(splice, self.cache, c1)
        # advance the shared scalar index to the deepest prefilled slot so
        # decode continues AFTER the prompt instead of overwriting it (the
        # splice alone keeps dst's scalar leaves, i.e. a stale index)
        self.cache["index"] = jnp.maximum(
            self.cache["index"], jnp.asarray(req.prompt_len, jnp.int32))
        self._host_len = max(self._host_len, int(req.prompt_len))
        self.slot_req[slot] = req.rid
        self.slot_adapter[slot] = req.adapter_id
        self.slot_tokens[slot] = int(jnp.argmax(logits[0, -1]))
        self.slot_len[slot] = req.prompt_len

    def decode_step_real(self) -> Dict[int, int]:
        """One decode step for all occupied slots; returns {rid: token}."""
        tokens = jnp.asarray(self.slot_tokens[:, None])
        ids = jnp.asarray(self.slot_adapter)
        # index must be per-slot; our cache uses a scalar index — decode at
        # max occupied length (padding slots attend junk but are ignored)
        if self.decode_path == "unfused":
            logits, self.cache = self._decode(self.params, self.bundles,
                                              tokens, self.cache, ids)
        else:
            logits, self.cache = self._decode(self.params, self.bundles,
                                              tokens, self.cache, ids,
                                              bucket=self._bucket())
        self._host_len += 1
        out = {}
        nxt = np.asarray(jnp.argmax(logits[:, -1], axis=-1))
        for slot, rid in enumerate(self.slot_req):
            if rid is not None:
                self.slot_tokens[slot] = nxt[slot]
                self.slot_len[slot] += 1
                out[rid] = int(nxt[slot])
        return out

    def release(self, rid: int) -> None:
        slot = self.slot_req.index(rid)
        self.slot_req[slot] = None

    # -- live migration (PR 9) ----------------------------------------------
    def export_slot(self, rid: int) -> Dict:
        """Checkpoint a request's decode state for live migration: its KV
        slice (every batched cache leaf at the request's slot), the last
        sampled token, and the filled depth.  The slot is NOT released —
        the engine frees it via :meth:`release` once the checkpoint is on
        the wire (invariant M3)."""
        slot = self.slot_req.index(rid)

        def take(x):
            if x.ndim == 0:
                return x
            bdim = _batch_dim(x)
            idx = [slice(None)] * x.ndim
            idx[bdim] = slice(slot, slot + 1)
            return x[tuple(idx)]

        return {"kv": jax.tree.map(take, self.cache),
                "adapter": int(self.slot_adapter[slot]),
                "token": int(self.slot_tokens[slot]),
                "len": int(self.slot_len[slot]),
                "index": int(self._host_len)}

    def import_slot(self, req: Request, state: Dict) -> None:
        """Re-admit a migrated request from :meth:`export_slot` state.

        Splices the shipped KV slice into a free slot and resumes decode
        from the checkpointed token — token-exact with the source
        (invariant M1).  The cache's scalar index is shared across slots,
        so exactness requires the target's filled depth not to exceed the
        source's (e.g. a fresh replica); deeper targets decode correctly
        but attend padding for the shallower slot, like any mixed-depth
        batch under the scalar-index cache model."""
        slot = self.slot_req.index(None)

        def splice(dst, src):
            if dst.ndim == 0:
                return dst
            bdim = _batch_dim(dst)
            idx = [slice(None)] * dst.ndim
            idx[bdim] = slice(slot, slot + 1)
            return dst.at[tuple(idx)].set(src)

        self.cache = jax.tree.map(splice, self.cache, state["kv"])
        self.cache["index"] = jnp.maximum(
            self.cache["index"], jnp.asarray(state["index"], jnp.int32))
        self._host_len = max(self._host_len, int(state["index"]))
        self.slot_req[slot] = req.rid
        self.slot_adapter[slot] = state["adapter"]
        self.slot_tokens[slot] = state["token"]
        self.slot_len[slot] = state["len"]

    # cost hooks (engine uses wall-clock when run_real is used instead)
    def decode_step_time(self, batch) -> float:
        t0 = time.perf_counter()
        self.decode_step_real()
        return time.perf_counter() - t0

    def prefill_time(self, req: Request) -> float:
        t0 = time.perf_counter()
        prompt = np.random.randint(0, self.cfg.vocab_size,
                                   size=req.prompt_len).astype(np.int32)
        self.prefill_request(req, prompt)
        return time.perf_counter() - t0


def _quantize_bundles(bundles: Dict, mode: str) -> Dict:
    """Pack fp adapter banks into int8 values + per-output-channel f32
    scales (`kernels/adapter_quant.py`).  Diag Sigma (already tiny) stays
    fp; `cluster_of` passes through."""
    def one_target(tp):
        if "A" in tp:                              # raw LoRA
            aq, a_s = adapter_quantize(tp["A"])
            bq, b_s = adapter_quantize(tp["B"])
            return {"A_q": aq, "A_s": a_s, "B_q": bq, "B_s": b_s}
        uq, u_s = adapter_quantize(tp["U"])
        vq, v_s = adapter_quantize(tp["V"], axis=-2)
        out = {"U_q": uq, "U_s": u_s, "V_q": vq, "V_s": v_s,
               "cluster_of": tp["cluster_of"]}
        sigma = tp["sigma"]
        if sigma.ndim >= 4:                        # (L, n, r, r) full
            sq, s_s = adapter_quantize(sigma)
            out["sigma_q"], out["sigma_s"] = sq, s_s
        else:                                      # (L, n, r) diag
            out["sigma"] = sigma
        return out
    return {"layers": {t: one_target(tp)
                       for t, tp in bundles["layers"].items()}}


def _dequantize_target(tp: Dict) -> Dict:
    """fp32 view of one (possibly packed) target bank, traceable in-jit."""
    if "A_q" in tp:
        return {"A": kref.adapter_dequant_ref(tp["A_q"], tp["A_s"]),
                "B": kref.adapter_dequant_ref(tp["B_q"], tp["B_s"])}
    if "U_q" in tp:
        out = {"U": kref.adapter_dequant_ref(tp["U_q"], tp["U_s"]),
               "V": kref.adapter_dequant_ref(tp["V_q"], tp["V_s"]),
               "cluster_of": tp["cluster_of"]}
        out["sigma"] = (tp["sigma"] if "sigma" in tp else
                        kref.adapter_dequant_ref(tp["sigma_q"],
                                                 tp["sigma_s"]))
        return out
    return tp


def _dequantize_bundles(bundles: Dict) -> Dict:
    return {"layers": {t: _dequantize_target(tp)
                       for t, tp in bundles["layers"].items()}}


def derive_cost_constants(samples) -> Dict[str, float]:
    """Fit the simulator's decode cost model t(B) ~= c0 + c1 * B to real
    measured (batch, seconds) pairs from `benchmarks/real_decode.py`.

    The fit keeps `CostModelExecutor`'s constants (`ServingHardware`'s
    ``step_overhead`` and the per-token roofline term) auditable against
    the fused executor's wall clock: the benchmark embeds this dict in its
    ``--json`` output, so when the kernels speed up, the drift between the
    simulated and real cost model is a number in the report instead of a
    silent divergence."""
    b = np.asarray([s[0] for s in samples], np.float64)
    t = np.asarray([s[1] for s in samples], np.float64)
    if b.size < 2 or np.all(b == b[0]):
        raise ValueError("need samples at >= 2 distinct batch sizes")
    M = np.stack([np.ones_like(b), b], axis=1)
    coef, *_ = np.linalg.lstsq(M, t, rcond=None)
    pred = M @ coef
    denom = float(np.sum((t - t.mean()) ** 2)) or 1.0
    return {"step_overhead_s": float(max(coef[0], 0.0)),
            "per_slot_s": float(max(coef[1], 0.0)),
            "r2": 1.0 - float(np.sum((t - pred) ** 2)) / denom,
            "n_samples": int(b.size)}


def _batch_dim(x) -> int:
    # caches: kv (L,B,S,Kv,hd) -> 1; hybrid (G,P,B,...) -> 2 for conv/state,
    # (G,B,S,..) -> 1 for kv; audio cross (L,B,S,..) -> 1
    return {5: 1, 6: 2, 4: 1, 3: 1, 2: 0}.get(x.ndim, 1)
