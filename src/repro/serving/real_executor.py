"""Real-model executor: actually runs prefill/decode with batched LoRA
application on the host (reduced configs).  Wall-clock timed, real logits.

Slot model: a fixed decode batch of ``max_batch`` KV-cache slots; admitted
requests prefill into a free slot (batch-1 prefill, cache splice); each
engine decode step advances every occupied slot by one token with per-slot
adapter ids (mode "lora": stacked A/B banks; mode "jd": U/V/Sigma bundles).
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import transformer as tf
from repro.models.lora import LoRAContext
from repro.serving.request import Request

Array = jax.Array


class RealModelExecutor:
    def __init__(self, cfg: ModelConfig, params, bundles: Dict[str, Dict],
                 mode: str, max_batch: int, s_max: int,
                 cluster_of: Optional[np.ndarray] = None,
                 adapter_bytes_override: Optional[int] = None):
        """bundles: layer-structured arrays for the adapters:
        mode 'lora': {"layers": {target: {"A": (L,n,r,d), "B": (L,n,d,r)}}}
        mode 'jd':   {"layers": {target: {"U","V","sigma","cluster_of"}}}"""
        self.cfg, self.mode = cfg, mode
        self.params = params
        self.bundles = bundles
        self.max_batch = max_batch
        self.s_max = s_max
        self.cluster_of = cluster_of
        self.cache = tf.init_cache(cfg, max_batch, s_max)
        self.slot_req: List[Optional[int]] = [None] * max_batch
        self.slot_adapter = np.zeros(max_batch, np.int32)
        self.slot_tokens = np.zeros(max_batch, np.int32)
        self.slot_len = np.zeros(max_batch, np.int32)
        self._decode = jax.jit(self._decode_fn)
        self._prefill = jax.jit(self._prefill_fn)
        nbytes = sum(x.size * x.dtype.itemsize
                     for x in jax.tree.leaves(bundles)) or 1
        n_adapters = self._n_adapters()
        self._adapter_bytes = adapter_bytes_override or max(
            nbytes // max(n_adapters, 1), 1)

    def _n_adapters(self) -> int:
        for leaf in jax.tree.leaves(self.bundles):
            return leaf.shape[1] if leaf.ndim > 1 else 1
        return 1

    def _ctx(self, ids: Array) -> LoRAContext:
        return LoRAContext(mode="batched" if self.mode == "lora" else "jd",
                           params=None, ids=ids, scaling=1.0)

    def _decode_fn(self, params, bundles, tokens, cache, ids):
        proto = self._ctx(ids)
        return tf.decode_step(params, tokens, self.cfg, cache,
                              lora_params=bundles, lora_ctx_proto=proto)

    def _prefill_fn(self, params, bundles, tokens, cache, ids):
        proto = self._ctx(ids)
        return tf.prefill(params, {"tokens": tokens}, self.cfg, cache,
                          lora_params=bundles, lora_ctx_proto=proto)

    # -- engine interface ---------------------------------------------------
    def adapter_bytes(self, aid: int) -> int:
        return self._adapter_bytes

    def shared_bytes(self) -> int:
        return 0

    def prefill_request(self, req: Request, prompt: np.ndarray) -> None:
        slot = self.slot_req.index(None)
        c1 = tf.init_cache(self.cfg, 1, self.s_max)
        logits, c1 = self._prefill(
            self.params, self.bundles, jnp.asarray(prompt[None]), c1,
            jnp.asarray([req.adapter_id], jnp.int32))
        # splice the single-request cache into the slot batch
        def splice(dst, src):
            if dst.ndim == 0:
                return dst
            bdim = _batch_dim(dst)
            idx = [slice(None)] * dst.ndim
            idx[bdim] = slice(slot, slot + 1)
            return dst.at[tuple(idx)].set(src)
        self.cache = jax.tree.map(splice, self.cache, c1)
        self.slot_req[slot] = req.rid
        self.slot_adapter[slot] = req.adapter_id
        self.slot_tokens[slot] = int(jnp.argmax(logits[0, -1]))
        self.slot_len[slot] = req.prompt_len

    def decode_step_real(self) -> Dict[int, int]:
        """One decode step for all occupied slots; returns {rid: token}."""
        tokens = jnp.asarray(self.slot_tokens[:, None])
        ids = jnp.asarray(self.slot_adapter)
        # index must be per-slot; our cache uses a scalar index — decode at
        # max occupied length (padding slots attend junk but are ignored)
        logits, self.cache = self._decode(self.params, self.bundles, tokens,
                                          self.cache, ids)
        out = {}
        nxt = np.asarray(jnp.argmax(logits[:, -1], axis=-1))
        for slot, rid in enumerate(self.slot_req):
            if rid is not None:
                self.slot_tokens[slot] = nxt[slot]
                self.slot_len[slot] += 1
                out[rid] = int(nxt[slot])
        return out

    def release(self, rid: int) -> None:
        slot = self.slot_req.index(rid)
        self.slot_req[slot] = None

    # cost hooks (engine uses wall-clock when run_real is used instead)
    def decode_step_time(self, batch) -> float:
        t0 = time.perf_counter()
        self.decode_step_real()
        return time.perf_counter() - t0

    def prefill_time(self, req: Request) -> float:
        t0 = time.perf_counter()
        prompt = np.random.randint(0, self.cfg.vocab_size,
                                   size=req.prompt_len).astype(np.int32)
        self.prefill_request(req, prompt)
        return time.perf_counter() - t0


def _batch_dim(x) -> int:
    # caches: kv (L,B,S,Kv,hd) -> 1; hybrid (G,P,B,...) -> 2 for conv/state,
    # (G,B,S,..) -> 1 for kv; audio cross (L,B,S,..) -> 1
    return {5: 1, 6: 2, 4: 1, 3: 1, 2: 0}.get(x.ndim, 1)
