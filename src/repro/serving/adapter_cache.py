"""Accelerator-memory adapter cache: HBM budget, LRU eviction, DMA cost model.

Models the paper's core serving bottleneck: with many adapters, the working
set exceeds the device budget and adapters are continuously loaded/offloaded
(host DRAM -> HBM over PCIe on TPU hosts).  Compressed collections pin the
shared bases (U_j, V_j) once and stream only tiny Sigma_i on miss — usually
the whole Sigma set fits, eliminating swaps entirely.

Transfers are modeled non-blocking (vLLM-style): a single copy engine whose
busy-until time overlaps compute; a step stalls only if it needs an adapter
whose transfer hasn't completed.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Dict, Iterable, Set


@dataclasses.dataclass
class DMAModel:
    bandwidth: float = 16e9          # bytes/s host->device (PCIe gen4-ish)
    latency: float = 50e-6           # per-transfer fixed cost


@dataclasses.dataclass
class CacheConfig:
    capacity_bytes: float            # HBM budget for adapter weights
    dma: DMAModel = dataclasses.field(default_factory=DMAModel)


class AdapterCache:
    """LRU over adapter entries + pinned shared entries."""

    def __init__(self, cfg: CacheConfig):
        self.cfg = cfg
        self._resident: "OrderedDict[int, int]" = OrderedDict()  # id -> bytes
        self._inflight_prefetch: Dict[int, float] = {}  # id -> ready_at
        self._pinned_bytes = 0
        self._used = 0
        self.copy_engine_free_at = 0.0
        self.n_swaps = 0
        self.n_prefetches = 0
        self.bytes_swapped = 0

    # -- sizing ------------------------------------------------------------
    @property
    def used_bytes(self) -> int:
        return self._used + self._pinned_bytes

    @property
    def capacity(self) -> float:
        return self.cfg.capacity_bytes

    def fits(self, n_more: int) -> bool:
        return self.used_bytes + n_more <= self.capacity

    # -- pinned shared state (compressed bases) ----------------------------
    def pin_shared(self, nbytes: int) -> None:
        if self._pinned_bytes + self._used + nbytes > self.capacity:
            raise MemoryError(
                f"shared bases ({nbytes/1e6:.1f} MB) exceed adapter budget "
                f"({self.capacity/1e6:.1f} MB)")
        self._pinned_bytes += nbytes

    # -- lookup / load ------------------------------------------------------
    def is_resident(self, aid: int) -> bool:
        return aid in self._resident

    def touch(self, aid: int) -> None:
        if aid in self._resident:
            self._resident.move_to_end(aid)

    def ensure(self, aid: int, nbytes: int, now: float) -> float:
        """Make `aid` resident; returns the time the adapter is usable.

        Eviction is free (drop); transfer is queued on the copy engine and
        overlaps compute — the caller stalls only until the returned time."""
        if aid in self._resident:
            self._resident.move_to_end(aid)
            # promoted prefetch: usable once its background transfer lands —
            # unless a fresh demand transfer would land sooner (the prefetch
            # sits behind other background loads), in which case the demand
            # path re-issues it on the copy engine: a promotion never waits
            # longer than a cold demand load would have
            ready = self._inflight_prefetch.pop(aid, now)
            if ready > now:
                nbytes = self._resident[aid]
                cold = (max(now, self.copy_engine_free_at)
                        + self.cfg.dma.latency + nbytes / self.cfg.dma.bandwidth)
                if cold < ready:
                    self.copy_engine_free_at = cold
                    self.n_swaps += 1
                    self.bytes_swapped += nbytes
                    ready = cold
            return max(now, ready)
        # evict LRU until it fits
        while self._used + self._pinned_bytes + nbytes > self.capacity \
                and self._resident:
            evicted, b = self._resident.popitem(last=False)
            self._inflight_prefetch.pop(evicted, None)
            self._used -= b
        if self._used + self._pinned_bytes + nbytes > self.capacity:
            raise MemoryError("adapter larger than total budget")
        start = max(now, self.copy_engine_free_at)
        t_done = start + self.cfg.dma.latency + nbytes / self.cfg.dma.bandwidth
        self.copy_engine_free_at = t_done
        self._resident[aid] = nbytes
        self._used += nbytes
        self.n_swaps += 1
        self.bytes_swapped += nbytes
        return t_done

    def ensure_many(self, pairs: Iterable[tuple], now: float) -> float:
        t = now
        for aid, nbytes in pairs:
            t = max(t, self.ensure(aid, nbytes, now))
        return t

    def prefetch(self, aid: int, nbytes: int, now: float) -> None:
        """Opportunistic background load at LOW priority.

        Unlike :meth:`ensure`, a prefetch must never get in the way of the
        demand path, so it

        - does NOT advance ``copy_engine_free_at`` — a demand miss issued
          right after a prefetch preempts it rather than queueing behind it;
        - does NOT evict anything — it only fills otherwise-idle capacity;
        - counts as ``n_prefetches``, not ``n_swaps``.

        The loaded adapter becomes usable at its background completion time;
        an :meth:`ensure` that arrives earlier stalls only until then
        (promotion), never longer than a cold demand load would have.
        """
        if self.is_resident(aid):
            return
        if self._used + self._pinned_bytes + nbytes > self.capacity:
            return                    # would need eviction: not worth it
        start = max(now, self.copy_engine_free_at,
                    max(self._inflight_prefetch.values(), default=0.0))
        t_done = start + self.cfg.dma.latency + nbytes / self.cfg.dma.bandwidth
        self._resident[aid] = nbytes
        self._resident.move_to_end(aid, last=False)  # LRU: coldest entry
        self._used += nbytes
        self._inflight_prefetch[aid] = t_done
        self.n_prefetches += 1

    @property
    def resident_ids(self) -> Set[int]:
        return set(self._resident)
