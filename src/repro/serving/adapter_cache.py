"""Accelerator-memory adapter cache: HBM budget, LRU eviction, DMA cost model.

Models the paper's core serving bottleneck: with many adapters, the working
set exceeds the device budget and adapters are continuously loaded/offloaded
(host DRAM -> HBM over PCIe on TPU hosts).  Compressed collections pin the
shared bases (U_j, V_j) once and stream only tiny Sigma_i on miss — usually
the whole Sigma set fits, eliminating swaps entirely.

Transfers are modeled non-blocking (vLLM-style): a single copy engine whose
busy-until time overlaps compute; a step stalls only if it needs an adapter
whose transfer hasn't completed.

Two capacity regimes (PR 6, unified paging — spec in ``docs/architecture.md``):

  * ``pool=None`` (legacy): a private byte budget, ``cfg.capacity_bytes``.
    Bit-exact with the pre-paging cache — all regression-locked benchmark
    numbers run this path.
  * ``pool=PagedPool``: adapter weights occupy whole pages of the replica's
    shared HBM pool (S-LoRA's unified paging), competing with KV blocks.
    Capacity checks go through the pool; ``cfg.capacity_bytes`` is ignored.
    The engine registers :meth:`reclaim` as the pool's pressure valve, so a
    KV reservation that does not fit evicts cold adapters — and an adapter
    miss can, symmetrically, use pages freed by finished decodes.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Dict, Iterable, Optional, Set

from .resources import PagedPool


@dataclasses.dataclass
class DMAModel:
    bandwidth: float = 16e9          # bytes/s host->device (PCIe gen4-ish)
    latency: float = 50e-6           # per-transfer fixed cost


@dataclasses.dataclass
class CacheConfig:
    capacity_bytes: float            # HBM budget (bytes); unused when pooled
    dma: DMAModel = dataclasses.field(default_factory=DMAModel)


class AdapterCache:
    """LRU over adapter entries + pinned shared entries.

    Entries are keyed by adapter id — or, for adapters updated through the
    online lifecycle, by an ``(adapter_id, epoch)`` tuple
    (:func:`~repro.serving.request.weight_key`), so two weight versions of
    one adapter can be resident while the old epoch's in-flight requests
    drain.  The demand path is :meth:`ensure`; :meth:`prefetch` fills idle
    capacity in the background; :meth:`reclaim` is the pool's pressure
    valve; :meth:`discard` and :meth:`repin_shared` are the lifecycle
    control plane's release/refresh hooks (docs/lifecycle.md)."""

    def __init__(self, cfg: CacheConfig, pool: Optional[PagedPool] = None):
        self.cfg = cfg
        self.pool = pool
        self._resident: "OrderedDict[int, int]" = OrderedDict()  # id -> bytes
        self._inflight_prefetch: Dict[int, float] = {}  # id -> ready_at
        self._pinned_bytes = 0
        self._used = 0
        self.copy_engine_free_at = 0.0
        self.n_swaps = 0
        self.n_prefetches = 0
        self.bytes_swapped = 0

    # -- sizing ------------------------------------------------------------
    @property
    def used_bytes(self) -> int:
        return self._used + self._pinned_bytes

    @property
    def capacity(self) -> float:
        if self.pool is not None:
            return self.pool.total_pages * self.pool.cfg.page_bytes
        return self.cfg.capacity_bytes

    def fits(self, n_more: int) -> bool:
        if self.pool is not None:
            return self.pool.can_alloc("adapter", self.pool.pages_for(n_more))
        return self.used_bytes + n_more <= self.capacity

    def _pages(self, nbytes: int) -> int:
        return 0 if self.pool is None else self.pool.pages_for(nbytes)

    def _evict(self, aid: int) -> None:
        """Drop a resident entry and release its bytes/pages."""
        b = self._resident.pop(aid)
        self._inflight_prefetch.pop(aid, None)
        self._used -= b
        if self.pool is not None:
            self.pool.free("adapter", self._pages(b))

    # -- pinned shared state (compressed bases) ----------------------------
    def pin_shared(self, nbytes: int) -> None:
        if self.pool is not None:
            self.pool.alloc("pinned", self._pages(nbytes))  # raises if full
            self._pinned_bytes += nbytes
            return
        if self._pinned_bytes + self._used + nbytes > self.capacity:
            raise MemoryError(
                f"shared bases ({nbytes/1e6:.1f} MB) exceed adapter budget "
                f"({self.capacity/1e6:.1f} MB)")
        self._pinned_bytes += nbytes

    def repin_shared(self, nbytes: int, now: float) -> float:
        """Hot-swap the pinned shared-base region (basis refresh/rollback).

        Frees the currently pinned pages/bytes, pins a region of `nbytes`
        (evicting cold adapters when the new bases need more room than the
        old ones freed), and queues the transfer on the copy engine exactly
        like a demand load.  Returns the completion time — the replica
        must not decode against the new bases before it."""
        if self.pool is not None:
            self.pool.free("pinned", self._pages(self._pinned_bytes))
            self._pinned_bytes = 0
            need = self._pages(nbytes)
            while not self.pool.can_alloc("pinned", need) and self._resident:
                self._evict(next(iter(self._resident)))
            self.pool.alloc("pinned", need)      # raises if genuinely too big
        else:
            self._pinned_bytes = 0
            while self._used + nbytes > self.capacity and self._resident:
                evicted, b = self._resident.popitem(last=False)
                self._inflight_prefetch.pop(evicted, None)
                self._used -= b
            if self._used + nbytes > self.capacity:
                raise MemoryError(
                    f"refreshed shared bases ({nbytes/1e6:.1f} MB) exceed "
                    f"adapter budget ({self.capacity/1e6:.1f} MB)")
        self._pinned_bytes = nbytes
        start = max(now, self.copy_engine_free_at)
        t_done = start + self.cfg.dma.latency + nbytes / self.cfg.dma.bandwidth
        self.copy_engine_free_at = t_done
        self.n_swaps += 1
        self.bytes_swapped += nbytes
        return t_done

    # -- lookup / load ------------------------------------------------------
    def is_resident(self, aid: int) -> bool:
        return aid in self._resident

    def touch(self, aid: int) -> None:
        if aid in self._resident:
            self._resident.move_to_end(aid)

    def ensure(self, aid: int, nbytes: int, now: float,
               protected: Optional[Set[int]] = None) -> float:
        """Make `aid` resident; returns the time the adapter is usable.

        Eviction is free (drop); transfer is queued on the copy engine and
        overlaps compute — the caller stalls only until the returned time.
        In pooled mode, adapters in `protected` (the engine's running
        batch) are never chosen as eviction victims."""
        if aid in self._resident:
            self._resident.move_to_end(aid)
            # promoted prefetch: usable once its background transfer lands —
            # unless a fresh demand transfer would land sooner (the prefetch
            # sits behind other background loads), in which case the demand
            # path re-issues it on the copy engine: a promotion never waits
            # longer than a cold demand load would have
            ready = self._inflight_prefetch.pop(aid, now)
            if ready > now:
                nbytes = self._resident[aid]
                cold = (max(now, self.copy_engine_free_at)
                        + self.cfg.dma.latency + nbytes / self.cfg.dma.bandwidth)
                if cold < ready:
                    self.copy_engine_free_at = cold
                    self.n_swaps += 1
                    self.bytes_swapped += nbytes
                    ready = cold
            return max(now, ready)
        # evict LRU until it fits
        if self.pool is not None:
            need = self._pages(nbytes)
            safe = protected or ()
            while not self.pool.can_alloc("adapter", need):
                victim = next((a for a in self._resident if a not in safe),
                              None)
                if victim is None:
                    break
                self._evict(victim)
            if not self.pool.try_alloc("adapter", need):
                raise MemoryError(
                    f"adapter ({need} pages) larger than the pool's adapter "
                    f"capacity ({self.pool.adapter_cap} pages, "
                    f"{self.pool.used['pinned']} pinned, "
                    f"{self.pool.used['kv']} held by KV)")
        else:
            while self._used + self._pinned_bytes + nbytes > self.capacity \
                    and self._resident:
                evicted, b = self._resident.popitem(last=False)
                self._inflight_prefetch.pop(evicted, None)
                self._used -= b
            if self._used + self._pinned_bytes + nbytes > self.capacity:
                raise MemoryError("adapter larger than total budget")
        start = max(now, self.copy_engine_free_at)
        t_done = start + self.cfg.dma.latency + nbytes / self.cfg.dma.bandwidth
        self.copy_engine_free_at = t_done
        self._resident[aid] = nbytes
        self._used += nbytes
        self.n_swaps += 1
        self.bytes_swapped += nbytes
        return t_done

    def ensure_many(self, pairs: Iterable[tuple], now: float) -> float:
        t = now
        for aid, nbytes in pairs:
            t = max(t, self.ensure(aid, nbytes, now))
        return t

    def prefetch(self, aid: int, nbytes: int, now: float) -> None:
        """Opportunistic background load at LOW priority.

        Unlike :meth:`ensure`, a prefetch must never get in the way of the
        demand path, so it

        - does NOT advance ``copy_engine_free_at`` — a demand miss issued
          right after a prefetch preempts it rather than queueing behind it;
        - does NOT evict anything — it only fills otherwise-idle capacity;
        - counts as ``n_prefetches``, not ``n_swaps``.

        The loaded adapter becomes usable at its background completion time;
        an :meth:`ensure` that arrives earlier stalls only until then
        (promotion), never longer than a cold demand load would have.
        """
        if self.is_resident(aid):
            return
        if self.pool is not None:
            if not self.pool.try_alloc("adapter", self._pages(nbytes)):
                return                # would need eviction: not worth it
        elif self._used + self._pinned_bytes + nbytes > self.capacity:
            return                    # would need eviction: not worth it
        start = max(now, self.copy_engine_free_at,
                    max(self._inflight_prefetch.values(), default=0.0))
        t_done = start + self.cfg.dma.latency + nbytes / self.cfg.dma.bandwidth
        self._resident[aid] = nbytes
        self._resident.move_to_end(aid, last=False)  # LRU: coldest entry
        self._used += nbytes
        self._inflight_prefetch[aid] = t_done
        self.n_prefetches += 1

    def discard(self, key) -> int:
        """Release a resident entry's bytes/pages outright (retire/update).

        The lifecycle control plane calls this once an adapter (or a stale
        weight epoch of one) has no in-flight requests left — invariant L5:
        a retired adapter holds no pool pages after its drain.  Callers are
        responsible for that drain; the cache does not know the running
        batch.  Returns the bytes freed (0 if the key was not resident)."""
        if key not in self._resident:
            return 0
        freed = self._resident[key]
        self._evict(key)
        return freed

    @property
    def resident_ids(self) -> Set[int]:
        return set(self._resident)

    # -- page-granular pressure (pooled mode only) --------------------------
    def evictable_pages(self, protected: Set[int]) -> int:
        """Pages that :meth:`reclaim` could free without touching adapters
        in `protected` (the running batch + the one being admitted)."""
        if self.pool is None:
            return 0
        return sum(self._pages(b) for aid, b in self._resident.items()
                   if aid not in protected)

    def reclaim(self, n_pages: int, protected: Set[int]) -> int:
        """Evict cold adapters to free up to `n_pages` of pool pages.

        Registered with the pool (:meth:`PagedPool.set_reclaimer
        <repro.serving.resources.PagedPool.set_reclaimer>`) by the engine:
        this is how a KV reservation pushes adapters out.  Eviction order —
        prefetched-but-never-used entries first (speculative bytes are the
        cheapest to drop), then true LRU; `protected` ids are never evicted.
        Returns the pages actually freed (may be < `n_pages`)."""
        if self.pool is None:
            return 0
        freed = 0
        # two passes over a snapshot: OrderedDict order IS coldest-first,
        # and prefetched-but-unused entries sit at the cold end by
        # construction, but a promoted prefetch leaves the map, so walk
        # the inflight set explicitly first.
        victims = [aid for aid in self._resident
                   if aid in self._inflight_prefetch and aid not in protected]
        victims += [aid for aid in self._resident
                    if aid not in self._inflight_prefetch
                    and aid not in protected]
        for aid in victims:
            if freed >= n_pages:
                break
            freed += self._pages(self._resident[aid])
            self._evict(aid)
        return freed
