"""Unified serving resources: hardware budget, shared KV fabric, and KV
wire compression.

Abstractions the rest of the serving stack draws from instead of owning
capacity itself:

  - :class:`HardwareBudget` — N accelerators total, with per-role footprints
    (accelerators per prefill worker / per decode replica).  Both tiers
    allocate from the same pool, so the joint autoscaler can only grow one
    tier by leaving room in — or actively shrinking — the other.  This is
    the Splitwise/InfiniLoRA framing: phase-splitting pays off only when the
    *split itself* is sized under the real fixed budget, not when each tier
    can grow unboundedly.

  - :class:`KVFabric` — the prefill->decode KV interconnect as one shared,
    contended resource.  PR 2 gave every prefill worker a private
    :class:`~repro.serving.prefill.TransferLink`, which overstates achievable
    throughput exactly where disaggregated systems pay: N workers bursting
    KV simultaneously do not each see full bandwidth.  The fabric serializes
    *chunks* onto a single shared channel (aggregate bandwidth, per-chunk
    fixed latency) with deterministic fair interleaving across in-flight
    transfers (fewest-chunks-sent first), and supports chunked/streamed
    handoff: the first landed chunk unblocks decode admission
    (``decode_ready_time``), while the tail of the transfer overlaps decode
    (``kv_landed_time``).

  - :class:`KVCompressionConfig` — compress-then-serve applied to the
    handoff itself: prefill workers quantize (int8/int4, per-channel, the
    Pallas kernels in :mod:`repro.kernels.kv_quant`) or low-rank-project
    each KV cache before it ships, every raw-token-range chunk crosses
    the wire at its compressed size, and the decode replica pays a
    modeled dequantization cost at admission.

Degenerate configurations are exact by construction:

  * one worker, ``chunk_bytes == 0`` (whole-KV serial handoff) reproduces
    the PR-2 ``TransferLink`` times bit-exactly — ``start = max(free_at,
    prefill_done)``, ``done = start + latency + nbytes / bandwidth``;
  * ``chunk_bytes >= nbytes`` is a single chunk, i.e. the serial path.

The fabric is resolved lazily: prefill workers *record* transfers as their
simulated prefill completes (handoff never blocks the worker's next
prefill), and :meth:`KVFabric.resolve` then schedules all recorded chunks
on the shared channel and stamps the requests.  Resolution happens per
drain (window-by-window under the autoscaler), so channel backlog carries
across windows through ``free_at``.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional


# ---------------------------------------------------------------------------
# hardware budget
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class BudgetConfig:
    """A fixed pool of accelerators shared by both serving tiers."""

    total_accelerators: int = 8
    prefill_accels_per_worker: int = 1
    decode_accels_per_replica: int = 1

    def cost(self, role: str) -> int:
        if role == "prefill":
            return self.prefill_accels_per_worker
        if role == "decode":
            return self.decode_accels_per_replica
        raise ValueError(f"unknown role {role!r}; one of ('prefill', 'decode')")


class HardwareBudget:
    """Allocation ledger over a :class:`BudgetConfig`.

    The budget owns capacity; tiers merely hold allocations.  ``allocate``
    raises when the pool is exhausted — callers must check
    :meth:`can_allocate` (or free capacity by retiring from the other role)
    first, which is exactly the trade the joint autoscaler implements.
    """

    def __init__(self, cfg: BudgetConfig):
        if cfg.total_accelerators < 1:
            raise ValueError("budget needs at least one accelerator")
        self.cfg = cfg
        self.allocated: Dict[str, int] = {"prefill": 0, "decode": 0}

    @property
    def in_use(self) -> int:
        return sum(self.allocated[role] * self.cfg.cost(role)
                   for role in self.allocated)

    @property
    def available(self) -> int:
        return self.cfg.total_accelerators - self.in_use

    def count(self, role: str) -> int:
        return self.allocated[role]

    def can_allocate(self, role: str) -> bool:
        return self.cfg.cost(role) <= self.available

    def allocate(self, role: str) -> None:
        if not self.can_allocate(role):
            raise MemoryError(
                f"hardware budget exhausted: {role} needs "
                f"{self.cfg.cost(role)} accelerators, {self.available} free "
                f"of {self.cfg.total_accelerators}")
        self.allocated[role] += 1

    def release(self, role: str) -> None:
        if self.allocated[role] < 1:
            raise ValueError(f"no {role} allocation to release")
        self.allocated[role] -= 1

    def to_dict(self) -> Dict:
        return {
            "total_accelerators": self.cfg.total_accelerators,
            "prefill_workers": self.allocated["prefill"],
            "decode_replicas": self.allocated["decode"],
            "accelerators_free": self.available,
        }


# ---------------------------------------------------------------------------
# KV wire compression
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class KVCompressionConfig:
    """Compress-then-serve applied to the KV handoff itself.

    The transfer-bound regime PR 3 exposed (slow fabric, long prompts) is
    exactly where shrinking ``kv_bytes`` on the wire pays: prefill workers
    quantize (or project) each produced KV cache before enqueueing it on
    the fabric, and the decode replica dequantizes after the first chunk
    lands — compute traded for wire bytes, the paper's thesis applied to
    the interconnect.

    Modes:

      ``int8`` / ``int4`` — per-channel symmetric quantization.  The wire
        ratios and worst-case error bounds are NOT free parameters: they
        are measured off the packed artifacts of the Pallas kernels in
        :mod:`repro.kernels.kv_quant` (values + one f32 scale per channel
        per 128-token block), and tests/test_kvcomp.py asserts this module
        and the kernel module agree.
      ``lowrank`` — keep ``lowrank_ratio`` of the KV channels through a
        learned projection (the same U/V machinery the compressed adapters
        use); wire ratio equals the kept fraction, error depends on the
        trained projection so no static bound is exported.

    Cost model: quantize/dequantize stream the block once (read one side,
    write the other), so both are HBM-bandwidth bound —
    ``overhead + (raw + wire) / mem_bw`` — with ``mem_bw`` defaulting to
    the v5e serving slice's aggregate HBM bandwidth
    (:class:`~repro.serving.engine.ServingHardware`).  Compression is
    charged to the *prefill* worker's clock before the handoff is
    recorded; decompression to the *decode* replica at admission
    (``Request.decompress_done_time``), which is how the joint autoscaler
    sees it when trading tiers under a budget.
    """

    mode: str = "int8"               # int8 | int4 | lowrank
    lowrank_ratio: float = 0.25      # kept channel fraction (lowrank only)
    # (de)quant streaming bandwidth; mirrors ServingHardware.hbm_bw (the
    # v5e slice), kept in sync by tests/test_kvcomp.py
    mem_bw: float = 4 * 819e9
    kernel_overhead: float = 20e-6   # per-handoff kernel launch cost, s

    MODES = ("int8", "int4", "lowrank")
    # mirrors repro.kernels.kv_quant.{WIRE_RATIO, ERROR_BOUND} at the
    # canonical 128-token block, duplicated so the simulator stays
    # jax-free; tests/test_kvcomp.py asserts the two stay in sync
    WIRE_RATIO = {"int8": 33 / 64, "int4": 17 / 64}
    ERROR_BOUND = {"int8": 1 / 254, "int4": 1 / 14}

    def __post_init__(self):
        if self.mode not in self.MODES:
            raise ValueError(f"unknown compression mode {self.mode!r}; "
                             f"one of {self.MODES}")
        if not 0.0 < self.lowrank_ratio <= 1.0:
            raise ValueError("lowrank_ratio must be in (0, 1]")
        if self.mem_bw <= 0:
            raise ValueError("mem_bw must be > 0")

    @property
    def wire_ratio(self) -> float:
        if self.mode == "lowrank":
            return self.lowrank_ratio
        return self.WIRE_RATIO[self.mode]

    @property
    def error_bound(self) -> Optional[float]:
        """Worst-case per-channel relative error (None for lowrank)."""
        return self.ERROR_BOUND.get(self.mode)

    def wire_bytes(self, raw_bytes: int) -> int:
        if raw_bytes <= 0:
            return 0
        return max(1, math.ceil(raw_bytes * self.wire_ratio))

    def compress_time(self, raw_bytes: int) -> float:
        """Prefill-side quantize/project cost for one KV cache."""
        if raw_bytes <= 0:
            return 0.0
        return (self.kernel_overhead
                + (raw_bytes + self.wire_bytes(raw_bytes)) / self.mem_bw)

    def decompress_time(self, raw_bytes: int) -> float:
        """Decode-side dequantize cost (same streaming roofline)."""
        return self.compress_time(raw_bytes)


# ---------------------------------------------------------------------------
# shared KV fabric
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class FabricConfig:
    """Shared prefill->decode interconnect.

    ``bandwidth`` is the *aggregate* fabric bandwidth all prefill workers
    contend for (PR 2's per-worker private links were ``n_workers`` times
    this).  ``latency`` is paid per chunk — small chunks stream the first
    bytes to decode sooner but occupy the channel longer in total, which is
    the real chunking trade-off.  ``chunk_bytes == 0`` ships each KV cache
    as one chunk (the serial PR-2 path).
    """

    bandwidth: float = 50e9          # aggregate bytes/s prefill -> decode
    latency: float = 200e-6          # per-chunk fixed cost
    chunk_bytes: int = 0             # 0 = whole-KV serial handoff
    # wire compression; None ships raw KV (bit-exact with the PR-3 fabric)
    compression: Optional[KVCompressionConfig] = None

    def __post_init__(self):
        if self.bandwidth <= 0:
            raise ValueError("fabric bandwidth must be > 0")
        if self.chunk_bytes < 0:
            raise ValueError("chunk_bytes must be >= 0 (0 = serial)")

    def n_chunks(self, nbytes: int) -> int:
        if self.chunk_bytes <= 0 or nbytes <= self.chunk_bytes:
            return 1
        return math.ceil(nbytes / self.chunk_bytes)


@dataclasses.dataclass
class FabricStats:
    n_transfers: int = 0
    n_chunks: int = 0
    transfer_time: float = 0.0       # sum of per-request ready->landed spans
    kv_bytes_moved: int = 0          # bytes on the wire (post-compression)
    kv_raw_bytes: int = 0            # bytes produced by prefill
    busy_time: float = 0.0           # channel occupancy (latency + wire time)


class _Transfer:
    """One in-flight KV handoff (all chunks available at ``ready_at``).

    Chunking is over the RAW KV (token ranges — the same 128-token blocks
    the quantization kernel works on); ``wire_chunks`` holds each chunk's
    on-the-wire size after compression.  With compression off the wire
    chunks equal the raw chunk sizes, reproducing the PR-3 arithmetic
    bit-exactly."""

    __slots__ = ("req", "ready_at", "nbytes", "raw_bytes", "wire_chunks",
                 "n_chunks", "chunks_sent")

    def __init__(self, req, ready_at: float, raw_bytes: int,
                 wire_chunks: List[int]):
        self.req = req
        self.ready_at = ready_at
        self.raw_bytes = raw_bytes
        self.wire_chunks = wire_chunks
        self.nbytes = sum(wire_chunks)
        self.n_chunks = len(wire_chunks)
        self.chunks_sent = 0

    def next_chunk_bytes(self) -> int:
        return self.wire_chunks[self.chunks_sent]


class KVFabric:
    """Deterministic chunk scheduler over one shared serialized channel.

    Transfers are recorded with :meth:`request` as prefill completes and
    scheduled by :meth:`resolve`: chunks are non-preemptive; among in-flight
    transfers the next chunk goes to the one with the fewest chunks already
    sent (ties: earlier ``ready_at``, then lower rid) — a fair round-robin
    that bounds head-of-line blocking by one chunk, so a short handoff slips
    between a long transfer's chunks instead of waiting out the whole thing.
    """

    def __init__(self, cfg: FabricConfig):
        self.cfg = cfg
        self.free_at = 0.0
        self.stats = FabricStats()
        self._pending: List[_Transfer] = []

    @classmethod
    def from_link(cls, link) -> "KVFabric":
        """A fabric equivalent to one PR-2 ``TransferLink`` (serial chunks)."""
        return cls(FabricConfig(bandwidth=link.bandwidth,
                                latency=link.latency, chunk_bytes=0))

    def _wire_chunks(self, nbytes: int) -> List[int]:
        """Per-chunk wire sizes for a raw KV of `nbytes`.  Chunk boundaries
        are raw token ranges (compression quantizes each block
        independently, so a compressed chunk is a *smaller* wire unit —
        the first chunk lands sooner and every slot in the fair interleave
        shortens); compression=None ships the raw spans unchanged."""
        n = self.cfg.n_chunks(nbytes)
        if n == 1:
            raw_spans = [nbytes]
        else:
            cb = self.cfg.chunk_bytes
            raw_spans = [cb] * (n - 1) + [nbytes - cb * (n - 1)]
        comp = self.cfg.compression
        if comp is None:
            return raw_spans
        return [comp.wire_bytes(s) for s in raw_spans]

    def request(self, req, ready_at: float, nbytes: int) -> None:
        """Record a KV handoff; scheduled at the next :meth:`resolve`.

        `nbytes` is the RAW KV size prefill produced; with wire
        compression configured each raw chunk ships at its compressed
        size and the request is stamped with its decode-side
        decompression cost (charged by the decode engine at admission)."""
        comp = self.cfg.compression
        wire_chunks = self._wire_chunks(nbytes)
        req.kv_raw_bytes = nbytes
        req.kv_wire_bytes = sum(wire_chunks)
        if comp is not None:
            req.kv_compression = comp.mode
            req.kv_decompress_cost = comp.decompress_time(nbytes)
        self._pending.append(_Transfer(req, ready_at, nbytes, wire_chunks))

    def resolve(self) -> None:
        """Schedule all recorded transfers' chunks and stamp the requests:
        ``decode_ready_time`` at the first chunk's landing,
        ``kv_landed_time`` (and ``transfer_time``) at the last."""
        if not self._pending:
            return
        pending = sorted(self._pending,
                         key=lambda tr: (tr.ready_at, tr.req.rid))
        self._pending = []
        active: List[_Transfer] = []
        i = 0
        t = self.free_at
        while i < len(pending) or active:
            if not active:
                t = max(t, pending[i].ready_at)
            while i < len(pending) and pending[i].ready_at <= t:
                active.append(pending[i])
                i += 1
            tr = min(active, key=lambda x: (x.chunks_sent, x.ready_at,
                                            x.req.rid))
            size = tr.next_chunk_bytes()
            start = max(t, tr.ready_at)
            done = start + self.cfg.latency + size / self.cfg.bandwidth
            self.stats.busy_time += done - start
            self.stats.n_chunks += 1
            t = done
            tr.chunks_sent += 1
            if tr.chunks_sent == 1:
                tr.req.decode_ready_time = done
            if tr.chunks_sent == tr.n_chunks:
                tr.req.kv_landed_time = done
                tr.req.transfer_time = done - tr.ready_at
                self.stats.n_transfers += 1
                self.stats.transfer_time += tr.req.transfer_time
                self.stats.kv_bytes_moved += tr.nbytes
                self.stats.kv_raw_bytes += tr.raw_bytes
                active.remove(tr)
        self.free_at = t
