"""Unified serving resources: hardware budget, paged HBM pool, shared KV
fabric, and KV wire compression.

Abstractions the rest of the serving stack draws from instead of owning
capacity itself:

  - :class:`HardwareBudget` — N accelerators total, with per-role footprints
    (accelerators per prefill worker / per decode replica).  Both tiers
    allocate from the same pool, so the joint autoscaler can only grow one
    tier by leaving room in — or actively shrinking — the other.  This is
    the Splitwise/InfiniLoRA framing: phase-splitting pays off only when the
    *split itself* is sized under the real fixed budget, not when each tier
    can grow unboundedly.

  - :class:`PagedPool` — ONE paged HBM region per replica shared by KV
    blocks and adapter weights (S-LoRA's unified paging).  A page is one
    :data:`PAGE_TOKENS`-token KV block (the same granularity the
    quantization kernels in :mod:`repro.kernels.kv_quant` work on), and
    adapter weights occupy whole pages of the same pool, so a skew shift
    can trade cache-resident adapters for decode slots and back.  The full
    memory-architecture spec (page lifecycle, eviction ordering, the
    invariants ``tests/test_paged.py`` asserts) lives in
    ``docs/architecture.md``.

  - :class:`KVFabric` — the prefill->decode KV interconnect as one shared,
    contended resource.  PR 2 gave every prefill worker a private
    :class:`~repro.serving.prefill.TransferLink`, which overstates achievable
    throughput exactly where disaggregated systems pay: N workers bursting
    KV simultaneously do not each see full bandwidth.  The fabric serializes
    *chunks* onto a single shared channel (aggregate bandwidth, per-chunk
    fixed latency) with deterministic fair interleaving across in-flight
    transfers (fewest-chunks-sent first), and supports chunked/streamed
    handoff: the first landed chunk unblocks decode admission
    (``decode_ready_time``), while the tail of the transfer overlaps decode
    (``kv_landed_time``).

  - :class:`KVCompressionConfig` — compress-then-serve applied to the
    handoff itself: prefill workers quantize (int8/int4, per-channel, the
    Pallas kernels in :mod:`repro.kernels.kv_quant`) or low-rank-project
    each KV cache before it ships, every raw-token-range chunk crosses
    the wire at its compressed size, and the decode replica pays a
    modeled dequantization cost at admission.

  - :class:`AdaptiveCompressionPolicy` — the compute-for-bytes trade made
    load-adaptive.  A static per-fabric mode pays quantization error and
    (de)quant compute on an idle fabric and cannot reach for int4 under
    saturation; the adaptive policy picks the mode *per transfer* from the
    live channel backlog (outstanding wire bytes plus the ``free_at``
    horizon vs the transfer's ``ready_at``), climbing a raw -> int8 ->
    int4 ladder with hysteresis, under a mode *ceiling* the joint
    autoscaler can raise before robbing a cold tier and relax in quiet
    windows.  A ceiling (or ladder) locked at raw reproduces the
    ``compression=None`` fabric bit-exactly.

Degenerate configurations are exact by construction:

  * one worker, ``chunk_bytes == 0`` (whole-KV serial handoff) reproduces
    the PR-2 ``TransferLink`` times bit-exactly — ``start = max(free_at,
    prefill_done)``, ``done = start + latency + nbytes / bandwidth``;
  * ``chunk_bytes >= nbytes`` is a single chunk, i.e. the serial path.

The fabric is resolved lazily: prefill workers *record* transfers as their
simulated prefill completes (handoff never blocks the worker's next
prefill), and :meth:`KVFabric.resolve` then schedules all recorded chunks
on the shared channel and stamps the requests.  Resolution happens per
drain (window-by-window under the autoscaler), so channel backlog carries
across windows through ``free_at``.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, List, Optional, Tuple


# ---------------------------------------------------------------------------
# hardware budget (typed slice pool)
# ---------------------------------------------------------------------------


_ROLES = ("prefill", "decode")


@dataclasses.dataclass(frozen=True)
class SliceType:
    """One accelerator slice class in a heterogeneous pool.

    A slice type prices and scales everything placement needs to know
    about one hardware class:

      - ``cost_units`` — what one slice of this type costs against the
        pool's fixed budget; equal-cost comparisons across types happen
        in these units, not replica counts.
      - ``prefill_slices`` / ``decode_slices`` — per-role footprint
        multipliers: slices of this type one prefill worker / decode
        replica occupies (the typed generalization of the legacy
        ``prefill_accels_per_worker`` / ``decode_accels_per_replica``).
      - ``hbm_bytes`` — the slice's HBM; a replica's :class:`PagedPool`
        is sized from it.  ``None`` inherits the base
        :class:`~repro.serving.engine.ServingHardware` figure.
      - ``fabric_bw`` — interconnect bandwidth (bytes/s) for sizing a
        :class:`FabricConfig` fed by workers of this type.
      - ``prefill_speed`` / ``decode_speed`` — factors on the base
        hardware's prefill compute / HBM streaming rooflines (see
        :meth:`ServingHardware.for_slice
        <repro.serving.engine.ServingHardware.for_slice>`).
      - ``sgmv_tile_rank`` — native contraction-tile width (ranks) of
        the slice's SGMV pipeline: a rank-r adapter's tiles pad to the
        next multiple of this, so skinny ranks waste a wide slice and
        the router should bias them toward narrow ones.  The pure cost
        model is :func:`repro.kernels.sgmv.sgmv_rank_efficiency`.

    The defaults describe the legacy interchangeable accelerator — unit
    cost, unit footprints, unit speed factors — so a pool of only this
    type is arithmetically identical to the pre-typed budget.
    """

    name: str
    cost_units: int = 1
    prefill_slices: int = 1          # per-role footprint multipliers
    decode_slices: int = 1
    hbm_bytes: Optional[float] = None    # None: inherit base hardware
    fabric_bw: Optional[float] = None    # bytes/s; None: fabric default
    prefill_speed: float = 1.0       # scales peak compute (prefill roofline)
    decode_speed: float = 1.0        # scales HBM bandwidth (decode roofline)
    sgmv_tile_rank: int = 8          # native SGMV contraction tile (ranks)

    def footprint(self, role: str) -> int:
        if role == "prefill":
            return self.prefill_slices
        if role == "decode":
            return self.decode_slices
        raise ValueError(f"unknown role {role!r}; one of {_ROLES}")

    def cost(self, role: str) -> int:
        """Cost units one `role` allocation on this slice type consumes."""
        return self.cost_units * self.footprint(role)


@dataclasses.dataclass
class BudgetConfig:
    """A fixed pool of accelerator capacity shared by both serving tiers.

    Two shapes, one config:

    * **Legacy single-type** (the default): the three count fields are
      whole **accelerator counts** — ``total_accelerators`` is the pool
      size, ``prefill_accels_per_worker`` / ``decode_accels_per_replica``
      the per-role footprints one allocation consumes.  This path stays
      bit-exact with every committed baseline.
    * **Typed** (``slice_types`` set): the pool is ``total_cost_units``
      cost units (defaulting to ``total_accelerators``) that allocations
      spend through a :class:`SliceType`'s ``cost(role)``.  A mixed-slice
      fleet at the same ``total_cost_units`` is *equal cost* to any
      homogeneous one — the comparison ``benchmarks/hetero_placement.py``
      makes.
    """

    total_accelerators: int = 8
    prefill_accels_per_worker: int = 1
    decode_accels_per_replica: int = 1
    # typed pool: the slice classes allocations may draw from, and the
    # fixed cost-unit budget they share; None keeps the legacy pool
    slice_types: Optional[Tuple[SliceType, ...]] = None
    total_cost_units: Optional[int] = None

    @property
    def typed(self) -> bool:
        return bool(self.slice_types)

    @property
    def total_units(self) -> int:
        """Pool size in cost units (== accelerators when untyped)."""
        if self.total_cost_units is not None:
            return self.total_cost_units
        return self.total_accelerators

    def default_slice(self) -> SliceType:
        """The single slice class a legacy config describes."""
        return SliceType(name="accel",
                         prefill_slices=self.prefill_accels_per_worker,
                         decode_slices=self.decode_accels_per_replica)

    def types(self) -> Tuple[SliceType, ...]:
        if self.slice_types:
            return tuple(self.slice_types)
        return (self.default_slice(),)

    def type_named(self, name: str) -> SliceType:
        for st in self.types():
            if st.name == name:
                return st
        raise ValueError(f"unknown slice type {name!r}; one of "
                         f"{[s.name for s in self.types()]}")

    def cost(self, role: str, slice_type: Optional[SliceType] = None) -> int:
        """Cost units one `role` allocation consumes on `slice_type`.

        With ``slice_type=None``: the legacy per-role footprint for an
        untyped pool (arithmetic identical to the pre-typed budget), or
        the *cheapest* type's cost for a typed one — the floor that
        feasibility checks compare against ``available``."""
        if slice_type is not None:
            return slice_type.cost(role)
        if not self.typed:
            return self.default_slice().cost(role)
        return min(st.cost(role) for st in self.types())


class HardwareBudget:
    """Allocation ledger over a :class:`BudgetConfig`.

    The budget owns capacity; tiers merely hold allocations.  ``allocate``
    raises when the pool is exhausted — callers must check
    :meth:`can_allocate` (or free capacity by retiring from the other role)
    first, which is exactly the trade the joint autoscaler implements.
    All quantities are **cost units** (plain accelerator counts for a
    legacy single-type config — see :class:`BudgetConfig`); per-replica
    HBM is accounted separately, in pages, by each replica's
    :class:`PagedPool`.

    Conservation invariants, asserted per slice type by
    ``tests/test_hetero.py``: ``in_use + available == cfg.total_units``
    after every operation (H1); an allocation whose cost exceeds
    ``available`` raises instead of overcommitting, and releasing a
    (role, type) pair with no live allocation raises (H2).

    Usage::

        budget = HardwareBudget(BudgetConfig(total_accelerators=6))
        budget.allocate("prefill")           # 1 worker  (5 accels free)
        budget.allocate("decode")            # 1 replica (4 accels free)
        if budget.can_allocate("decode"):
            budget.allocate("decode")
        budget.release("prefill")            # retire a worker -> pool

    Typed pools name the slice class per allocation::

        big, small = SliceType("big", cost_units=4), SliceType("small")
        budget = HardwareBudget(BudgetConfig(
            slice_types=(big, small), total_cost_units=8))
        budget.allocate("prefill", big)      # 4 units (4 free)
        budget.allocate("decode", small)     # 1 unit  (3 free)
        budget.release("prefill", big)
    """

    def __init__(self, cfg: BudgetConfig):
        if cfg.total_units < 1:
            raise ValueError("budget needs at least one accelerator")
        if cfg.typed:
            names = [st.name for st in cfg.types()]
            if len(set(names)) != len(names):
                raise ValueError(f"duplicate slice type names: {names}")
        self.cfg = cfg
        # role -> slice type name -> live allocation count
        self._alloc: Dict[str, Dict[str, int]] = {r: {} for r in _ROLES}

    def _resolve(self, role: str,
                 slice_type: Optional[SliceType]) -> SliceType:
        if role not in _ROLES:
            raise ValueError(f"unknown role {role!r}; one of {_ROLES}")
        if slice_type is None:
            if self.cfg.typed:
                raise ValueError(
                    f"typed budget needs an explicit slice type; one of "
                    f"{[s.name for s in self.cfg.types()]}")
            return self.cfg.default_slice()
        return self.cfg.type_named(slice_type.name)

    @property
    def allocated(self) -> Dict[str, int]:
        """Legacy view: role -> total allocation count over all types."""
        return {role: sum(d.values()) for role, d in self._alloc.items()}

    @property
    def in_use(self) -> int:
        return sum(n * self.cfg.type_named(t).cost(role)
                   for role, d in self._alloc.items()
                   for t, n in d.items())

    @property
    def available(self) -> int:
        return self.cfg.total_units - self.in_use

    def count(self, role: str,
              slice_type: Optional[SliceType] = None) -> int:
        if slice_type is not None:
            return self._alloc[role].get(slice_type.name, 0)
        return sum(self._alloc[role].values())

    def can_allocate(self, role: str,
                     slice_type: Optional[SliceType] = None) -> bool:
        """Whether one `role` allocation fits: on `slice_type` when named,
        else on the legacy type (untyped pool) / the cheapest type."""
        return self.cfg.cost(role, slice_type) <= self.available

    def allocate(self, role: str,
                 slice_type: Optional[SliceType] = None) -> SliceType:
        """Spend one `role` allocation; returns the slice type it landed
        on.  An untyped pool resolves ``slice_type=None`` to the legacy
        accelerator; a typed pool requires the caller to name the type
        (the autoscaler's ``pick_slice`` choice)."""
        st = self._resolve(role, slice_type)
        if st.cost(role) > self.available:
            raise MemoryError(
                f"hardware budget exhausted: {role} needs "
                f"{st.cost(role)} accelerators, {self.available} free "
                f"of {self.cfg.total_units}")
        d = self._alloc[role]
        d[st.name] = d.get(st.name, 0) + 1
        return st

    def release(self, role: str,
                slice_type: Optional[SliceType] = None) -> None:
        if slice_type is None and self.cfg.typed:
            held = [t for t, n in self._alloc[role].items() if n > 0]
            if len(held) == 1:       # unambiguous: only one type held
                slice_type = self.cfg.type_named(held[0])
        st = self._resolve(role, slice_type)
        if self._alloc[role].get(st.name, 0) < 1:
            raise ValueError(f"no {role} allocation to release")
        self._alloc[role][st.name] -= 1

    def to_dict(self) -> Dict:
        d = {
            "total_accelerators": self.cfg.total_units,
            "prefill_workers": self.count("prefill"),
            "decode_replicas": self.count("decode"),
            "accelerators_free": self.available,
        }
        if self.cfg.typed:
            d["slices"] = {role: {t: n for t, n in alloc.items() if n}
                           for role, alloc in self._alloc.items()}
        return d


# ---------------------------------------------------------------------------
# unified paged HBM pool (KV blocks + adapter weights)
# ---------------------------------------------------------------------------


# tokens per KV page — one page is one 128-token KV block, the same
# granularity the wire-quantization kernels use (kv_quant.BLOCK_T; the sim
# stays jax-free so the constant is duplicated and tests/test_paged.py
# asserts the two agree)
PAGE_TOKENS = 128


@dataclasses.dataclass
class PagedPoolConfig:
    """One paged HBM region per replica, shared by KV blocks and adapter
    weights (S-LoRA's unified paging).

    Units: ``total_bytes`` is the HBM region in **bytes**; ``page_bytes``
    is the size of one page in **bytes** — one :data:`PAGE_TOKENS`-token
    KV block across all layers/heads, i.e.
    ``ModelFootprint.kv_bytes_per_token * PAGE_TOKENS`` (see
    :meth:`ModelFootprint.pool_config
    <repro.serving.engine.ModelFootprint.pool_config>`).  Everything the
    pool hands out is counted in whole **pages**.

    ``adapter_share`` reproduces the pre-unified STATIC SPLIT as a
    degenerate configuration: when set, adapter + pinned pages are capped
    at ``floor(adapter_share * total_pages)`` and KV pages at the
    remainder, so neither side can borrow the other's headroom.  ``None``
    (the default) is the unified pool — the only caps are the pool itself.
    ``benchmarks/paged_pool.py`` measures the two against each other.
    """

    total_bytes: float               # bytes: the pool's HBM region
    page_bytes: int                  # bytes: one PAGE_TOKENS-token KV block
    adapter_share: Optional[float] = None    # static-split baseline knob

    def __post_init__(self):
        if self.total_bytes <= 0:
            raise ValueError("pool total_bytes must be > 0")
        if self.page_bytes < 1:
            raise ValueError("page_bytes must be >= 1")
        if self.adapter_share is not None \
                and not 0.0 < self.adapter_share < 1.0:
            raise ValueError("adapter_share must be in (0, 1) or None")
        if self.total_pages < 1:
            raise ValueError(
                f"pool smaller than one page: {self.total_bytes:.0f} B total "
                f"vs {self.page_bytes} B/page")

    @property
    def total_pages(self) -> int:
        return int(self.total_bytes // self.page_bytes)


class PagedPool:
    """Page-granular allocation ledger over one HBM region.

    Pages are fungible (no placement, so no fragmentation — the gathered-
    page decode kernel reads them through a page table) and every page is
    in exactly one state at a time:

      ``free`` — available to either side;
      ``kv`` — holds a decode request's KV block (reserved at admission,
        freed when the request finishes; never evicted mid-request);
      ``adapter`` — holds adapter weights, owned by an
        :class:`~repro.serving.adapter_cache.AdapterCache` entry, the ONLY
        evictable state;
      ``pinned`` — compressed shared bases (U/V), never evicted.

    Allocation invariants (asserted by ``tests/test_paged.py`` and
    documented in ``docs/architecture.md``):

      I1 — conservation: ``free_pages + sum(used.values())`` equals
           ``total_pages`` after every operation;
      I2 — no negative balances: ``free(kind, n)`` with ``n`` larger than
           the kind's balance raises instead of underflowing;
      I3 — no overcommit: an allocation never succeeds beyond capacity
           (``free_pages`` >= 0 always; with ``adapter_share`` set, also
           never beyond the side's static cap);
      I4 — reclaim only evicts ``adapter`` pages: ``kv`` and ``pinned``
           pages are never taken by :meth:`alloc_with_reclaim`;
      I5 — no fragmentation: any request for ``n <= free_pages`` (within
           caps) succeeds, regardless of prior alloc/free churn.

    Usage::

        pool = PagedPool(PagedPoolConfig(total_bytes=1e9, page_bytes=2**20))
        pool.alloc("adapter", 4)
        pool.set_reclaimer(lambda n: cache.reclaim(n, protected=set()))
        pool.alloc_with_reclaim("kv", pool.free_pages + 2)  # evicts adapters
        pool.free("kv", 2)
    """

    KINDS = ("kv", "adapter", "pinned")

    def __init__(self, cfg: PagedPoolConfig):
        self.cfg = cfg
        self.used: Dict[str, int] = {k: 0 for k in self.KINDS}
        self.peak: Dict[str, int] = {k: 0 for k in self.KINDS}
        self.n_reclaims = 0              # alloc_with_reclaim eviction rounds
        self.pages_reclaimed = 0         # adapter pages evicted to fund KV
        self._reclaimer: Optional[Callable[[int], int]] = None

    # -- sizing ------------------------------------------------------------
    @property
    def total_pages(self) -> int:
        return self.cfg.total_pages

    @property
    def free_pages(self) -> int:
        return self.total_pages - sum(self.used.values())

    @property
    def adapter_cap(self) -> int:
        """Page cap on adapter + pinned pages (the static split's adapter
        side); the whole pool when ``adapter_share`` is None."""
        if self.cfg.adapter_share is None:
            return self.total_pages
        return int(self.cfg.adapter_share * self.total_pages)

    @property
    def kv_cap(self) -> int:
        """Page cap on KV pages; the whole pool when unified."""
        if self.cfg.adapter_share is None:
            return self.total_pages
        return self.total_pages - self.adapter_cap

    def pages_for(self, nbytes: float) -> int:
        """Whole pages covering `nbytes` (0 for empty)."""
        if nbytes <= 0:
            return 0
        return int(math.ceil(nbytes / self.cfg.page_bytes))

    # -- allocation --------------------------------------------------------
    def _check_kind(self, kind: str) -> None:
        if kind not in self.KINDS:
            raise ValueError(f"unknown page kind {kind!r}; "
                             f"one of {self.KINDS}")

    def can_alloc(self, kind: str, n_pages: int) -> bool:
        self._check_kind(kind)
        if n_pages <= 0:
            return True
        if n_pages > self.free_pages:
            return False
        if kind == "kv":
            return self.used["kv"] + n_pages <= self.kv_cap
        return (self.used["adapter"] + self.used["pinned"] + n_pages
                <= self.adapter_cap)

    def try_alloc(self, kind: str, n_pages: int) -> bool:
        if not self.can_alloc(kind, n_pages):
            return False
        self.used[kind] += n_pages
        self.peak[kind] = max(self.peak[kind], self.used[kind])
        return True

    def alloc(self, kind: str, n_pages: int) -> None:
        if not self.try_alloc(kind, n_pages):
            raise MemoryError(
                f"paged pool exhausted: {kind} needs {n_pages} pages, "
                f"{self.free_pages} free of {self.total_pages} "
                f"(kv={self.used['kv']}, adapter={self.used['adapter']}, "
                f"pinned={self.used['pinned']})")

    def free(self, kind: str, n_pages: int) -> None:
        self._check_kind(kind)
        if n_pages < 0 or n_pages > self.used[kind]:
            raise ValueError(f"cannot free {n_pages} {kind} pages; "
                             f"{self.used[kind]} held")
        self.used[kind] -= n_pages

    # -- adapter-for-KV pressure -------------------------------------------
    def set_reclaimer(self, fn: Callable[[int], int]) -> None:
        """Register the adapter side's eviction hook: ``fn(n_pages)`` frees
        up to `n_pages` of ``adapter`` pages (prefetched-but-unused first,
        then LRU — see :meth:`AdapterCache.reclaim
        <repro.serving.adapter_cache.AdapterCache.reclaim>`) and returns
        how many it actually freed."""
        self._reclaimer = fn

    def alloc_with_reclaim(self, kind: str, n_pages: int) -> bool:
        """Allocate, evicting adapter pages to cover a shortfall.

        This is the page-granular pressure of the unified pool: a KV
        reservation that does not fit asks the adapter cache to release
        cold pages (invariant I4 — only ``adapter`` pages move).  Returns
        False if the allocation still cannot fit (caps, pinned pages, or
        nothing evictable)."""
        if self.try_alloc(kind, n_pages):
            return True
        if kind == "kv" and self._reclaimer is not None:
            shortfall = n_pages - self.free_pages
            if 0 < shortfall <= self.used["adapter"]:
                freed = self._reclaimer(shortfall)
                if freed > 0:
                    self.n_reclaims += 1
                    self.pages_reclaimed += freed
        return self.try_alloc(kind, n_pages)

    def feasible(self, kv_more: int, adapter_more: int,
                 evictable_adapter_pages: int) -> bool:
        """Would `kv_more` KV pages AND `adapter_more` adapter pages fit if
        up to `evictable_adapter_pages` of the current adapter pages were
        evicted first?  The engine's admission check: a request is admitted
        only when both its KV reservation and its (possibly non-resident)
        adapter can be funded without touching protected pages."""
        evictable = min(evictable_adapter_pages, self.used["adapter"])
        if kv_more + adapter_more > self.free_pages + evictable:
            return False
        if self.used["kv"] + kv_more > self.kv_cap:
            return False
        return (self.used["adapter"] - evictable + adapter_more
                + self.used["pinned"] <= self.adapter_cap)

    def to_dict(self) -> Dict:
        return {
            "total_pages": self.total_pages,
            "page_bytes": self.cfg.page_bytes,
            "kv_pages": self.used["kv"],
            "adapter_pages": self.used["adapter"],
            "pinned_pages": self.used["pinned"],
            "free_pages": self.free_pages,
            "peak_kv_pages": self.peak["kv"],
            "peak_adapter_pages": self.peak["adapter"],
            "n_reclaims": self.n_reclaims,
            "pages_reclaimed": self.pages_reclaimed,
        }


# ---------------------------------------------------------------------------
# KV wire compression
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class KVCompressionConfig:
    """Compress-then-serve applied to the KV handoff itself.

    The transfer-bound regime PR 3 exposed (slow fabric, long prompts) is
    exactly where shrinking ``kv_bytes`` on the wire pays: prefill workers
    quantize (or project) each produced KV cache before enqueueing it on
    the fabric, and the decode replica dequantizes after the first chunk
    lands — compute traded for wire bytes, the paper's thesis applied to
    the interconnect.

    Modes:

      ``int8`` / ``int4`` — per-channel symmetric quantization.  The wire
        ratios and worst-case error bounds are NOT free parameters: they
        are measured off the packed artifacts of the Pallas kernels in
        :mod:`repro.kernels.kv_quant` (values + one f32 scale per channel
        per 128-token block), and tests/test_kvcomp.py asserts this module
        and the kernel module agree.
      ``lowrank`` — keep ``lowrank_ratio`` of the KV channels through a
        learned projection (the same U/V machinery the compressed adapters
        use); wire ratio equals the kept fraction, error depends on the
        trained projection so no static bound is exported.

    Cost model: quantize/dequantize stream the block once (read one side,
    write the other), so both are HBM-bandwidth bound —
    ``overhead + (raw + wire) / mem_bw`` — with ``mem_bw`` defaulting to
    the v5e serving slice's aggregate HBM bandwidth
    (:class:`~repro.serving.engine.ServingHardware`).  Compression is
    charged to the *prefill* worker's clock before the handoff is
    recorded; decompression to the *decode* replica at admission
    (``Request.decompress_done_time``), which is how the joint autoscaler
    sees it when trading tiers under a budget.
    """

    mode: str = "int8"               # int8 | int4 | lowrank
    lowrank_ratio: float = 0.25      # kept channel fraction (lowrank only)
    # (de)quant streaming bandwidth; mirrors ServingHardware.hbm_bw (the
    # v5e slice), kept in sync by tests/test_kvcomp.py
    mem_bw: float = 4 * 819e9
    kernel_overhead: float = 20e-6   # per-handoff kernel launch cost, s

    MODES = ("int8", "int4", "lowrank")
    # mirrors repro.kernels.kv_quant.{WIRE_RATIO, ERROR_BOUND} at the
    # canonical 128-token block, duplicated so the simulator stays
    # jax-free; tests/test_kvcomp.py asserts the two stay in sync
    WIRE_RATIO = {"int8": 33 / 64, "int4": 17 / 64}
    ERROR_BOUND = {"int8": 1 / 254, "int4": 1 / 14}
    # packed-artifact structure, per channel: quantized values (1/2 or 1/4
    # of the raw bf16 bytes) plus one f32 scale per BLOCK_TOKENS tokens —
    # a tail block smaller than BLOCK_TOKENS carries a full scale, so its
    # wire ratio is strictly worse than the full-block aggregate above
    VALUE_RATIO = {"int8": 1 / 2, "int4": 1 / 4}
    BLOCK_TOKENS = 128               # kv_quant.BLOCK_T
    BLOCK_RAW_BYTES = 256            # one channel-block of bf16 tokens
    SCALE_BYTES = 4                  # one f32 scale per channel per block

    def __post_init__(self):
        if self.mode not in self.MODES:
            raise ValueError(f"unknown compression mode {self.mode!r}; "
                             f"one of {self.MODES}")
        if not 0.0 < self.lowrank_ratio <= 1.0:
            raise ValueError("lowrank_ratio must be in (0, 1]")
        if self.mem_bw <= 0:
            raise ValueError("mem_bw must be > 0")

    @property
    def wire_ratio(self) -> float:
        if self.mode == "lowrank":
            return self.lowrank_ratio
        return self.WIRE_RATIO[self.mode]

    @property
    def error_bound(self) -> Optional[float]:
        """Worst-case per-channel relative error (None for lowrank)."""
        return self.ERROR_BOUND.get(self.mode)

    def wire_bytes(self, raw_bytes: int,
                   bytes_per_token: Optional[int] = None) -> int:
        """On-the-wire size of one raw KV span, block-granularly.

        Scales are per channel per ``BLOCK_TOKENS``-token block, so a
        partial tail block pays a full scale: with ``bytes_per_token``
        known (the real handoff path — see :func:`kv_bytes_per_token`) the
        scale count is exact, ``ceil(tokens / 128) * channels``; without
        it the span is modeled as per-channel 256-raw-byte blocks, one
        scale per full-or-partial block.  Both reduce to the aggregate
        ``WIRE_RATIO`` on block-aligned spans."""
        if raw_bytes <= 0:
            return 0
        if self.mode == "lowrank":
            return max(1, math.ceil(raw_bytes * self.lowrank_ratio))
        value_bytes = math.ceil(raw_bytes * self.VALUE_RATIO[self.mode])
        if (bytes_per_token is not None and bytes_per_token >= 2
                and bytes_per_token % 2 == 0):
            n_channels = bytes_per_token // 2
            n_blocks = math.ceil(
                raw_bytes / (bytes_per_token * self.BLOCK_TOKENS))
            return value_bytes + self.SCALE_BYTES * n_blocks * n_channels
        return (value_bytes
                + self.SCALE_BYTES * math.ceil(raw_bytes
                                               / self.BLOCK_RAW_BYTES))

    def compress_time(self, raw_bytes: int,
                      bytes_per_token: Optional[int] = None) -> float:
        """Prefill-side quantize/project cost for one KV cache."""
        if raw_bytes <= 0:
            return 0.0
        wire = self.wire_bytes(raw_bytes, bytes_per_token)
        return self.kernel_overhead + (raw_bytes + wire) / self.mem_bw

    def decompress_time(self, raw_bytes: int,
                        bytes_per_token: Optional[int] = None) -> float:
        """Decode-side dequantize cost (same streaming roofline)."""
        return self.compress_time(raw_bytes, bytes_per_token)


def merge_mode_dict(into: Dict, other: Dict) -> None:
    """Accumulate per-mode counters (shared by the fabric / prefill /
    decode per-mode stats dicts)."""
    for k, v in other.items():
        into[k] = into.get(k, 0) + v


def kv_bytes_per_token(nbytes: int, prompt_len: int) -> Optional[int]:
    """Recover the bf16 KV bytes/token of a handoff from its request, or
    None when `nbytes` does not decompose into whole per-token channels
    (hand-built executors with synthetic KV sizes fall back to the
    byte-granular block model)."""
    if prompt_len > 0 and nbytes > 0 and nbytes % prompt_len == 0:
        bpt = nbytes // prompt_len
        if bpt % 2 == 0:
            return bpt
    return None


# ---------------------------------------------------------------------------
# adaptive per-transfer compression policy
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class AdaptiveCompressionConfig:
    """Per-transfer wire-mode selection from live channel backlog.

    ``modes`` is the escalation ladder, level 0 first; the floor must be
    ``"raw"`` so an idle fabric pays neither quantization error nor
    (de)quant compute.  A transfer recorded while the channel's estimated
    backlog (see :meth:`KVFabric.backlog_seconds`) exceeds
    ``escalate_backlog_s[i - 1]`` ships at ladder level ``i`` (highest
    threshold crossed wins — a spike jumps straight to int4).  Hysteresis
    is asymmetric: escalation is immediate (latency protection), relaxing
    drops one level at a time and only after ``min_dwell`` transfers at
    the current level AND the backlog has fallen below ``relax_fraction``
    of that level's threshold — so a backlog oscillating inside the band
    does not thrash the mode.

    ``initial_ceiling`` caps the ladder (None = top).  The joint
    autoscaler owns the ceiling at runtime: it starts it low, raises it
    under budget-exhausted prefill pressure *before* trading a replica
    away from a cold tier, and relaxes it in quiet windows.

    ``modes=("raw",)`` (or a ceiling pinned at 0) is the raw-locked
    policy: bit-exact with a ``compression=None`` fabric.
    """

    modes: Tuple[str, ...] = ("raw", "int8", "int4")
    escalate_backlog_s: Tuple[float, ...] = (0.02, 0.04)
    relax_fraction: float = 0.25     # relax below this fraction of the band
    min_dwell: int = 8               # transfers at a level before relaxing
    initial_ceiling: Optional[int] = None    # None = top of the ladder
    # per-mode cost knobs, forwarded to each level's KVCompressionConfig
    lowrank_ratio: float = 0.25
    mem_bw: float = 4 * 819e9
    kernel_overhead: float = 20e-6

    def __post_init__(self):
        known = ("raw",) + KVCompressionConfig.MODES
        if not self.modes or self.modes[0] != "raw":
            raise ValueError("the ladder floor must be 'raw' (level 0)")
        if len(set(self.modes)) != len(self.modes):
            raise ValueError("duplicate ladder modes")
        for m in self.modes:
            if m not in known:
                raise ValueError(f"unknown ladder mode {m!r}; one of {known}")
        if len(self.escalate_backlog_s) < len(self.modes) - 1:
            raise ValueError("need one escalate threshold per non-raw level")
        steps = self.escalate_backlog_s[:len(self.modes) - 1]
        if any(t <= 0 for t in steps) or list(steps) != sorted(set(steps)):
            raise ValueError("escalate thresholds must be positive and "
                             "strictly increasing")
        if not 0.0 < self.relax_fraction < 1.0:
            raise ValueError("relax_fraction must be in (0, 1)")
        if self.min_dwell < 1:
            raise ValueError("min_dwell must be >= 1")
        if (self.initial_ceiling is not None
                and not 0 <= self.initial_ceiling < len(self.modes)):
            raise ValueError("initial_ceiling outside the ladder")


class AdaptiveCompressionPolicy:
    """Stateful ladder walker over an :class:`AdaptiveCompressionConfig`.

    :meth:`decide` is called once per recorded transfer with the channel's
    backlog estimate **in seconds** and returns the transfer's
    :class:`KVCompressionConfig` (None for raw).  ``ceiling`` is the
    autoscaler-owned cap; ``n_switches`` counts level changes (the
    hysteresis tests bound it).

    Usage::

        policy = AdaptiveCompressionPolicy(AdaptiveCompressionConfig(
            modes=("raw", "int8", "int4"),
            escalate_backlog_s=(0.02, 0.04), initial_ceiling=1))
        cfg = policy.decide(backlog_s=0.03)  # climbs raw -> int8
        policy.raise_ceiling()               # autoscaler grants int4
        policy.lower_ceiling()               # quiet window: clamp back

    Normally :class:`KVFabric` drives it — workers just call
    ``fabric.plan(...)``.
    """

    def __init__(self, cfg: AdaptiveCompressionConfig):
        self.cfg = cfg
        self.level = 0
        self.ceiling = (self.top if cfg.initial_ceiling is None
                        else cfg.initial_ceiling)
        self.n_switches = 0
        self.n_decisions = 0
        self._dwell = 0
        self._configs = {
            m: KVCompressionConfig(mode=m, lowrank_ratio=cfg.lowrank_ratio,
                                   mem_bw=cfg.mem_bw,
                                   kernel_overhead=cfg.kernel_overhead)
            for m in cfg.modes if m != "raw"}

    @property
    def top(self) -> int:
        return len(self.cfg.modes) - 1

    @property
    def mode(self) -> str:
        return self.cfg.modes[self.level]

    @property
    def ceiling_mode(self) -> str:
        return self.cfg.modes[self.ceiling]

    def _move(self, level: int) -> None:
        self.level = level
        self._dwell = 0
        self.n_switches += 1

    def decide(self, backlog_s: float) -> Optional[KVCompressionConfig]:
        """Mode for the next transfer given the channel backlog estimate."""
        cfg = self.cfg
        self.n_decisions += 1
        self._dwell += 1
        target = 0
        for i in range(1, len(cfg.modes)):
            if backlog_s > cfg.escalate_backlog_s[i - 1]:
                target = i
        target = min(target, self.ceiling)
        if target > self.level:
            self._move(target)               # escalate immediately
        elif (target < self.level and self._dwell >= cfg.min_dwell
              and backlog_s < (cfg.relax_fraction
                               * cfg.escalate_backlog_s[self.level - 1])):
            self._move(self.level - 1)       # relax one step, out of band
        return self._configs.get(self.mode)

    # -- autoscaler-owned ceiling ------------------------------------------
    def raise_ceiling(self) -> bool:
        """One ladder level more headroom; False when already at the top."""
        if self.ceiling >= self.top:
            return False
        self.ceiling += 1
        return True

    def lower_ceiling(self) -> bool:
        """One level less; clamps the live level down with it."""
        if self.ceiling <= 0:
            return False
        self.ceiling -= 1
        if self.level > self.ceiling:
            self._move(self.ceiling)
        return True


# ---------------------------------------------------------------------------
# shared KV fabric
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class FabricConfig:
    """Shared prefill->decode interconnect.

    ``bandwidth`` is the *aggregate* fabric bandwidth all prefill workers
    contend for (PR 2's per-worker private links were ``n_workers`` times
    this).  ``latency`` is paid per chunk — small chunks stream the first
    bytes to decode sooner but occupy the channel longer in total, which is
    the real chunking trade-off.  ``chunk_bytes == 0`` ships each KV cache
    as one chunk (the serial PR-2 path).
    """

    bandwidth: float = 50e9          # aggregate bytes/s prefill -> decode
    latency: float = 200e-6          # per-chunk fixed cost
    chunk_bytes: int = 0             # 0 = whole-KV serial handoff
    # wire compression; None ships raw KV (bit-exact with the PR-3 fabric)
    compression: Optional[KVCompressionConfig] = None
    # per-transfer adaptive mode selection (mutually exclusive with the
    # static `compression` mode); see AdaptiveCompressionPolicy
    adaptive: Optional[AdaptiveCompressionConfig] = None

    def __post_init__(self):
        if self.bandwidth <= 0:
            raise ValueError("fabric bandwidth must be > 0")
        if self.latency < 0:
            raise ValueError("fabric latency must be >= 0")
        if self.chunk_bytes < 0:
            raise ValueError("chunk_bytes must be >= 0 (0 = serial)")
        if self.compression is not None and self.adaptive is not None:
            raise ValueError("configure either a static compression mode or "
                             "an adaptive policy, not both")

    def n_chunks(self, nbytes: int) -> int:
        if self.chunk_bytes <= 0 or nbytes <= self.chunk_bytes:
            return 1
        return math.ceil(nbytes / self.chunk_bytes)


@dataclasses.dataclass
class FabricStats:
    n_transfers: int = 0
    n_chunks: int = 0
    transfer_time: float = 0.0       # sum of per-request ready->landed spans
    kv_bytes_moved: int = 0          # bytes on the wire (post-compression)
    kv_raw_bytes: int = 0            # bytes produced by prefill
    busy_time: float = 0.0           # channel occupancy (latency + wire time)
    # per-wire-mode accounting ("raw" / "int8" / "int4" / "lowrank"): how
    # many transfers each mode carried and the wire/raw bytes it covered
    n_transfers_by_mode: Dict[str, int] = dataclasses.field(
        default_factory=dict)
    wire_bytes_by_mode: Dict[str, int] = dataclasses.field(
        default_factory=dict)
    raw_bytes_by_mode: Dict[str, int] = dataclasses.field(
        default_factory=dict)
    n_mode_switches: int = 0         # adaptive-policy level changes

    def _bump_mode(self, mode: str, wire: int, raw: int) -> None:
        merge_mode_dict(self.n_transfers_by_mode, {mode: 1})
        merge_mode_dict(self.wire_bytes_by_mode, {mode: wire})
        merge_mode_dict(self.raw_bytes_by_mode, {mode: raw})


class _Transfer:
    """One in-flight KV handoff (all chunks available at ``ready_at``).

    Chunking is over the RAW KV (token ranges — the same 128-token blocks
    the quantization kernel works on); ``wire_chunks`` holds each chunk's
    on-the-wire size after compression.  With compression off the wire
    chunks equal the raw chunk sizes, reproducing the PR-3 arithmetic
    bit-exactly."""

    __slots__ = ("req", "ready_at", "nbytes", "raw_bytes", "wire_chunks",
                 "n_chunks", "chunks_sent", "mode")

    def __init__(self, req, ready_at: float, raw_bytes: int,
                 wire_chunks: List[int], mode: str = "raw"):
        self.req = req
        self.ready_at = ready_at
        self.raw_bytes = raw_bytes
        self.wire_chunks = wire_chunks
        self.nbytes = sum(wire_chunks)
        self.n_chunks = len(wire_chunks)
        self.chunks_sent = 0
        self.mode = mode

    def next_chunk_bytes(self) -> int:
        return self.wire_chunks[self.chunks_sent]


class KVFabric:
    """Deterministic chunk scheduler over one shared serialized channel.

    Transfers are recorded with :meth:`request` as prefill completes and
    scheduled by :meth:`resolve`: chunks are non-preemptive; among in-flight
    transfers the next chunk goes to the one with the fewest chunks already
    sent (ties: earlier ``ready_at``, then lower rid) — a fair round-robin
    that bounds head-of-line blocking by one chunk, so a short handoff slips
    between a long transfer's chunks instead of waiting out the whole thing.

    Units: ``bandwidth`` bytes/s, ``latency`` seconds/chunk, ``chunk_bytes``
    bytes (0 = whole-KV serial handoff); all times are absolute simulated
    seconds.

    Usage::

        fabric = KVFabric(FabricConfig(bandwidth=64e9, latency=5e-6,
                                       chunk_bytes=1 << 20))
        comp = fabric.plan(req, at=done, nbytes=kv_bytes)   # pick wire mode
        fabric.request(req, ready_at=done + compress_time,
                       nbytes=kv_bytes, comp=comp)
        fabric.resolve()    # schedule chunks; stamps req.decode_ready_time
    """

    _PLAN = object()                 # sentinel: request() plans its own mode

    def __init__(self, cfg: FabricConfig):
        self.cfg = cfg
        self.free_at = 0.0
        self.stats = FabricStats()
        self._pending: List[_Transfer] = []
        self.policy = (AdaptiveCompressionPolicy(cfg.adaptive)
                       if cfg.adaptive is not None else None)

    @classmethod
    def from_link(cls, link) -> "KVFabric":
        """A fabric equivalent to one PR-2 ``TransferLink`` (serial chunks)."""
        return cls(FabricConfig(bandwidth=link.bandwidth,
                                latency=link.latency, chunk_bytes=0))

    def backlog_seconds(self, at: float) -> float:
        """Estimated channel time committed ahead of a transfer becoming
        ready at `at`: the resolved horizon (``free_at``) beyond `at`,
        plus the wire time and per-chunk latencies of every
        recorded-but-unresolved transfer that is *already ready* at `at`.

        Causality: the tier simulates workers eagerly and sequentially, so
        when one worker plans a transfer, other workers' *future* handoffs
        (``ready_at > at``) can already sit in ``_pending``.  A live
        controller could not see those, so they are excluded — the
        estimate only reads traffic that exists at `at`.  A policy (or
        ladder) locked at raw ignores this signal entirely, so the raw
        path is unaffected (``tests/test_adaptive.py`` locks it bit-exact
        against the ``compression=None`` baseline)."""
        pending = sum(tr.nbytes / self.cfg.bandwidth
                      + tr.n_chunks * self.cfg.latency
                      for tr in self._pending if tr.ready_at <= at)
        return max(0.0, self.free_at - at) + pending

    def plan(self, req, at: float, nbytes: int) -> \
            Optional[KVCompressionConfig]:
        """Pick this transfer's wire mode: the static per-fabric mode, or
        the adaptive policy's per-transfer backlog decision (None = raw).
        Prefill workers call this BEFORE charging compression to their
        clock, then pass the result to :meth:`request`."""
        if nbytes <= 0:
            return None
        if self.policy is not None:
            return self.policy.decide(self.backlog_seconds(at))
        return self.cfg.compression

    def _wire_chunks(self, nbytes: int,
                     comp: Optional[KVCompressionConfig],
                     bytes_per_token: Optional[int]) -> List[int]:
        """Per-chunk wire sizes for a raw KV of `nbytes`.  Chunk boundaries
        are raw token ranges (compression quantizes each block
        independently, so a compressed chunk is a *smaller* wire unit —
        the first chunk lands sooner and every slot in the fair interleave
        shortens); an uncompressed transfer ships the raw spans
        unchanged.  Wire sizes are block-granular: a tail chunk smaller
        than a 128-token block pays its full per-channel scales."""
        n = self.cfg.n_chunks(nbytes)
        if n == 1:
            raw_spans = [nbytes]
        else:
            cb = self.cfg.chunk_bytes
            raw_spans = [cb] * (n - 1) + [nbytes - cb * (n - 1)]
        if comp is None:
            return raw_spans
        return [comp.wire_bytes(s, bytes_per_token) for s in raw_spans]

    def request(self, req, ready_at: float, nbytes: int,
                comp=_PLAN) -> None:
        """Record a KV handoff; scheduled at the next :meth:`resolve`.

        `nbytes` is the RAW KV size prefill produced; with wire
        compression in play each raw chunk ships at its compressed size
        and the request is stamped with its mode and decode-side
        decompression cost (charged by the decode engine at admission).
        `comp` is the planned mode for this transfer (see :meth:`plan`);
        left unset, the fabric plans it here.

        An empty KV (``nbytes <= 0``) has nothing to ship: it lands at
        ``ready_at`` with no chunk, no per-chunk latency, and no channel
        occupancy or stats traffic."""
        if comp is self._PLAN:
            comp = self.plan(req, ready_at, nbytes)
        if nbytes <= 0:
            req.kv_raw_bytes = max(0, nbytes)
            req.kv_wire_bytes = 0
            req.decode_ready_time = ready_at
            req.kv_landed_time = ready_at
            req.transfer_time = 0.0
            return
        bpt = kv_bytes_per_token(nbytes, req.prompt_len)
        wire_chunks = self._wire_chunks(nbytes, comp, bpt)
        req.kv_raw_bytes = nbytes
        req.kv_wire_bytes = sum(wire_chunks)
        mode = "raw"
        if comp is not None:
            mode = comp.mode
            req.kv_compression = comp.mode
            req.kv_decompress_cost = comp.decompress_time(nbytes, bpt)
        self._pending.append(_Transfer(req, ready_at, nbytes, wire_chunks,
                                       mode))

    def resolve(self) -> None:
        """Schedule all recorded transfers' chunks and stamp the requests:
        ``decode_ready_time`` at the first chunk's landing,
        ``kv_landed_time`` (and ``transfer_time``) at the last."""
        if self.policy is not None:
            # sync even with nothing pending: ceiling clamps between
            # windows also count as level switches
            self.stats.n_mode_switches = self.policy.n_switches
        if not self._pending:
            return
        pending = sorted(self._pending,
                         key=lambda tr: (tr.ready_at, tr.req.rid))
        self._pending = []
        active: List[_Transfer] = []
        i = 0
        t = self.free_at
        while i < len(pending) or active:
            if not active:
                t = max(t, pending[i].ready_at)
            while i < len(pending) and pending[i].ready_at <= t:
                active.append(pending[i])
                i += 1
            tr = min(active, key=lambda x: (x.chunks_sent, x.ready_at,
                                            x.req.rid))
            size = tr.next_chunk_bytes()
            start = max(t, tr.ready_at)
            done = start + self.cfg.latency + size / self.cfg.bandwidth
            self.stats.busy_time += done - start
            self.stats.n_chunks += 1
            t = done
            tr.chunks_sent += 1
            if tr.chunks_sent == 1:
                tr.req.decode_ready_time = done
            if tr.chunks_sent == tr.n_chunks:
                tr.req.kv_landed_time = done
                tr.req.transfer_time = done - tr.ready_at
                self.stats.n_transfers += 1
                self.stats.transfer_time += tr.req.transfer_time
                self.stats.kv_bytes_moved += tr.nbytes
                self.stats.kv_raw_bytes += tr.raw_bytes
                self.stats._bump_mode(tr.mode, tr.nbytes, tr.raw_bytes)
                active.remove(tr)
        self.free_at = t


@dataclasses.dataclass
class MigrationTicket:
    """Fabric proxy for a decode→decode KV move (live request migration).

    :meth:`KVFabric.request` stamps whatever object it is given with the
    transfer's wire accounting and landing times.  A *migration* must not
    clobber the request's original prefill-handoff fields — those already
    hold the first hop's bytes and the paid (or pending) decompression
    charge — so ``Fleet.migrate`` ships a ticket instead and folds the
    stamped values into the request's cumulative ``mig_*`` counters
    afterwards.  Every wire byte is therefore charged exactly once, on
    the hop that moved it (invariant M2, ``tests/test_migration.py``).

    ``prompt_len`` is the number of KV *tokens* checkpointed (the prompt
    plus every token generated so far), not the request's original prompt
    length: ``kv_bytes_per_token`` must recover the per-token stride from
    ``nbytes / prompt_len`` for block-granular wire sizing, and a
    mid-stream checkpoint carries the whole decoded prefix."""

    rid: int
    prompt_len: int                  # KV tokens on the move (prompt + generated)
    # stamped by KVFabric.request / KVFabric.resolve
    kv_raw_bytes: int = 0
    kv_wire_bytes: int = 0
    kv_compression: Optional[str] = None
    kv_decompress_cost: float = 0.0
    decode_ready_time: Optional[float] = None
    kv_landed_time: Optional[float] = None
    transfer_time: float = 0.0

    @property
    def wire_mode(self) -> str:
        return self.kv_compression or "raw"
