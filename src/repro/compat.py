"""jax version compatibility shims.

The codebase targets current jax APIs; older releases (e.g. 0.4.x) keep the
same functionality under different names.  Centralized here so call sites
stay clean and a jax upgrade deletes this file.
"""
from __future__ import annotations

import jax

if hasattr(jax, "shard_map"):
    shard_map = jax.shard_map
else:                                    # jax < 0.5: experimental namespace
    from jax.experimental.shard_map import shard_map  # noqa: F401

if hasattr(jax.lax, "axis_size"):
    axis_size = jax.lax.axis_size
else:                                    # jax < 0.6: psum of ones
    def axis_size(axis_name):
        return jax.lax.psum(1, axis_name)
