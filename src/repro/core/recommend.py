"""§6.5 hyperparameter recommendation procedure.

  - <=100 LoRAs: JD-Full without clustering, rank ~ n/2 + 7.
  - >100 LoRAs: rank 16 JD-Full + clustering; pick a mid-network module,
    sweep an exponentially growing number of clusters, and choose the minimal
    k whose reconstruction loss drops below 0.6.  Reconstruction loss is a
    cheap CPU-only validation metric (no LLM eval needed).
"""
from __future__ import annotations

import dataclasses
from typing import Mapping, Optional, Sequence

import jax

from .cluster import cluster_jd, clustered_reconstruction_errors
from .collection import CompressionConfig, LoRABank
from .jd import jd_full_eig, normalize_bank, reconstruction_errors


@dataclasses.dataclass
class Recommendation:
    rank: int
    n_clusters: int
    probe_module: Optional[str]
    probe_losses: dict            # k -> reconstruction loss on the probe module
    threshold: float


def recommend_rank(n_loras: int) -> int:
    """Rank rule of thumb for the unclustered regime."""
    return max(4, int(n_loras / 2 + 7))


def pick_probe_module(names: Sequence[str]) -> str:
    """'Select a LoRA module from the middle of the network' (§6.5)."""
    names = sorted(names)
    return names[len(names) // 2]


def recommend(banks: Mapping[str, LoRABank],
              rank: int = 16,
              threshold: float = 0.6,
              max_clusters: int = 64,
              iters: int = 10,
              seed: int = 0) -> Recommendation:
    names = list(banks)
    n = banks[names[0]].n
    if n <= 100:
        r = recommend_rank(n)
        return Recommendation(rank=r, n_clusters=1, probe_module=None,
                              probe_losses={}, threshold=threshold)

    probe = pick_probe_module(names)
    bank = banks[probe]
    A, B, _ = normalize_bank(bank.A.astype("float32"), bank.B.astype("float32"))
    key = jax.random.PRNGKey(seed)

    losses = {}
    k = 1
    best_k = max_clusters
    while k <= max_clusters:
        if k == 1:
            res = jd_full_eig(A, B, rank=rank, iters=iters, key=key)
            loss = float(reconstruction_errors(A, B, res)["loss"])
        else:
            res = cluster_jd(A, B, rank=rank, n_clusters=k, jd_iters=iters,
                             key=key)
            loss = float(clustered_reconstruction_errors(A, B, res)["loss"])
        losses[k] = loss
        if loss < threshold:
            best_k = k
            break
        k *= 2
    return Recommendation(rank=rank, n_clusters=best_k, probe_module=probe,
                          probe_losses=losses, threshold=threshold)


def to_config(rec: Recommendation, method: str = "jd_full_eig") -> CompressionConfig:
    return CompressionConfig(method=method, rank=rec.rank,
                             n_clusters=rec.n_clusters)
