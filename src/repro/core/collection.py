"""Containers for LoRA collections and their compressed forms.

A *collection* maps target-module names (e.g. ``"layers.0.attn.q_proj"``) to
stacked adapter banks.  This is the interface between:

- training (which produces per-task ``{module: (A_i, B_i)}`` pytrees),
- compression (:mod:`repro.core.jd` / :mod:`repro.core.cluster`), and
- serving (which wants per-module ``U/V/Sigma`` plus per-request indices).

Heterogeneous ranks are zero-padded to the collection max (padding rows of A /
columns of B with zeros leaves every product ``B_i A_i`` unchanged).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Mapping, Optional, Sequence

import jax
import jax.numpy as jnp

from .cluster import ClusteredJD, cluster_jd, clustered_reconstruction_errors
from .jd import (JDResult, jd_diag, jd_full, jd_full_eig, normalize_bank,
                 reconstruction_errors, svd_per_lora, svd_reconstruction_errors,
                 ties_merge)

Array = jax.Array


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class LoRABank:
    """All adapters targeting one linear module."""

    A: Array      # (n, r_pad, d_in)
    B: Array      # (n, d_out, r_pad)
    ranks: Array  # (n,) original ranks (before padding)

    @property
    def n(self) -> int:
        return self.A.shape[0]

    @property
    def d_in(self) -> int:
        return self.A.shape[-1]

    @property
    def d_out(self) -> int:
        return self.B.shape[1]

    def delta(self, i: int) -> Array:
        return self.B[i] @ self.A[i]


def stack_bank(pairs: Sequence[tuple], pad_to: Optional[int] = None) -> LoRABank:
    """Stack [(A_1, B_1), ...] of possibly different ranks into a LoRABank."""
    ranks = [a.shape[0] for a, _ in pairs]
    r_pad = pad_to or max(ranks)
    As, Bs = [], []
    for a, b in pairs:
        r = a.shape[0]
        As.append(jnp.pad(a, ((0, r_pad - r), (0, 0))))
        Bs.append(jnp.pad(b, ((0, 0), (0, r_pad - r))))
    return LoRABank(A=jnp.stack(As), B=jnp.stack(Bs),
                    ranks=jnp.asarray(ranks, dtype=jnp.int32))


@dataclasses.dataclass
class CompressionConfig:
    method: str = "jd_full"       # jd_full | jd_full_eig | jd_diag | svd | ties
    rank: int = 16
    n_clusters: int = 1
    iters: int = 10
    normalize: bool = True        # §6.1 unit-Frobenius normalization
    outer_iters: int = 5          # clustering alternations
    seed: int = 0


@dataclasses.dataclass
class CompressedModule:
    """One module's compressed bank + bookkeeping."""

    result: object                # JDResult or ClusteredJD
    norms: Optional[Array]        # de-normalization scales (None if not normalized)
    metrics: Dict[str, float]
    method: str

    @property
    def clustered(self) -> bool:
        return isinstance(self.result, ClusteredJD)


def compress_bank(bank: LoRABank, cfg: CompressionConfig) -> CompressedModule:
    """Compress one module bank according to ``cfg`` (renormalization folded
    back into sigma so the stored compressed adapters reconstruct the ORIGINAL
    products)."""
    A, B = bank.A.astype(jnp.float32), bank.B.astype(jnp.float32)
    norms = None
    if cfg.normalize:
        A, B, norms = normalize_bank(A, B)
    key = jax.random.PRNGKey(cfg.seed)

    if cfg.n_clusters > 1:
        res = cluster_jd(A, B, rank=cfg.rank, n_clusters=cfg.n_clusters,
                         outer_iters=cfg.outer_iters, jd_iters=cfg.iters,
                         solver="eig" if cfg.method == "jd_full_eig" else "eigh",
                         key=key)
        errs = clustered_reconstruction_errors(A, B, res)
    elif cfg.method in ("jd_full", "jd_full_eig", "jd_diag"):
        fn = {"jd_full": jd_full, "jd_full_eig": jd_full_eig,
              "jd_diag": jd_diag}[cfg.method]
        res = fn(A, B, rank=cfg.rank, iters=cfg.iters, key=key)
        errs = reconstruction_errors(A, B, res)
    elif cfg.method == "svd":
        res = svd_per_lora(A, B, rank=cfg.rank)
        errs = svd_reconstruction_errors(A, B, res)
    elif cfg.method == "ties":
        res = ties_merge(A, B, rank=cfg.rank)
        errs = reconstruction_errors(
            A, B, JDResult(U=res.U, V=res.V, sigma=res.sigma, diag=True))
    else:
        raise ValueError(f"unknown method {cfg.method}")

    if norms is not None:
        res = res.scale_sigma(norms)

    metrics = {k: float(v) for k, v in errs.items() if jnp.ndim(v) == 0}
    return CompressedModule(result=res, norms=norms, metrics=metrics,
                            method=cfg.method)


def compress_collection(banks: Mapping[str, LoRABank], cfg: CompressionConfig,
                        progress: Optional[Callable[[str, dict], None]] = None,
                        ) -> Dict[str, CompressedModule]:
    """Compress every module bank (the per-module independence of eq. 1)."""
    out = {}
    for name in sorted(banks):
        out[name] = compress_bank(banks[name], cfg)
        if progress is not None:
            progress(name, out[name].metrics)
    return out


def collection_loss(comp: Mapping[str, CompressedModule]) -> float:
    """Energy-weighted reconstruction loss across modules (§6.5 validation)."""
    num = sum(m.metrics["loss"] * 1.0 for m in comp.values())
    return num / max(len(comp), 1)


# ---------------------------------------------------------------------------
# serving export
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ServingAdapterBundle:
    """Device-ready arrays for the serving engine, one module.

    Uncompressed:  A (n, r, d_in), B (n, d_out, r)
    Compressed:    U (k, d_out, r), V (k, d_in, r), sigma (n, r[, r]),
                   cluster_of (n,)
    """

    kind: str                     # "lora" | "jd"
    arrays: Dict[str, Array]
    param_bytes_shared: int       # resident once (U, V)
    param_bytes_per_adapter: int  # per adapter (sigma / A+B)


def export_for_serving(module: CompressedModule) -> ServingAdapterBundle:
    res = module.result
    if isinstance(res, ClusteredJD):
        arrays = dict(U=res.U, V=res.V, sigma=res.sigma, cluster_of=res.assign)
        shared = res.U.size + res.V.size
        per = res.sigma[0].size + 1
    else:
        assert isinstance(res, JDResult)
        if res.U.ndim == 3:   # svd baseline: per-adapter bases => nothing shared
            arrays = dict(U=res.U, V=res.V, sigma=res.sigma,
                          cluster_of=jnp.arange(res.n, dtype=jnp.int32))
            shared = 0
            per = res.U[0].size + res.V[0].size + res.sigma[0].size
        else:
            arrays = dict(U=res.U[None], V=res.V[None], sigma=res.sigma,
                          cluster_of=jnp.zeros(res.n, dtype=jnp.int32))
            shared = res.U.size + res.V.size
            per = res.sigma[0].size
    itemsize = 4
    return ServingAdapterBundle(kind="jd", arrays=arrays,
                                param_bytes_shared=shared * itemsize,
                                param_bytes_per_adapter=per * itemsize)


def export_uncompressed(bank: LoRABank) -> ServingAdapterBundle:
    arrays = dict(A=bank.A, B=bank.B)
    per = bank.A[0].size + bank.B[0].size
    return ServingAdapterBundle(kind="lora", arrays=arrays,
                                param_bytes_shared=0,
                                param_bytes_per_adapter=per * 4)
