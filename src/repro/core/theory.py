"""Numeric checks of the paper's §4 theory (Prop. 1, Thm. 1, Cor. 1).

These are used by property tests and by ``benchmarks/recon_random_vs_trained``
to show where real LoRA collections sit between the merged-model lower bound
and the spectral upper bound.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .jd import JDResult

Array = jax.Array


def tilde_r(A: Array, B: Array, tol: float = 1e-6) -> int:
    """Prop. 1 threshold: max(rank([A_1;...]), rank([B_1,...]))."""
    n, r_pad, d_in = A.shape
    d_out = B.shape[1]
    A_cat = A.reshape(n * r_pad, d_in)
    B_cat = jnp.transpose(B, (1, 0, 2)).reshape(d_out, n * r_pad)
    ra = jnp.linalg.matrix_rank(A_cat, tol=tol)
    rb = jnp.linalg.matrix_rank(B_cat, tol=tol)
    return int(jnp.maximum(ra, rb))


def theorem1_bounds(A: Array, B: Array, rank: int) -> dict:
    """Thm. 1: sum_j<=r sigbar_j^2 <= sum_i ||Sigma_i||^2 <= sum_j<=min(r^2,n) sig_j^2.

    sig_j  = singular values of L = [vec(B_1A_1) ... vec(B_nA_n)]
    sigbar = singular values of sum_i B_i A_i.
    Materializes the products — use on test-scale dims only.
    """
    n = A.shape[0]
    deltas = jnp.einsum("nor,nri->noi", B, A)
    L = deltas.reshape(n, -1).T                     # (d_out*d_in, n)
    sig = jnp.linalg.svd(L, compute_uv=False)       # length min(d^2, n)
    merged = jnp.sum(deltas, axis=0)
    sigbar = jnp.linalg.svd(merged, compute_uv=False)
    lower = jnp.sum(sigbar[:rank] ** 2)
    upper = jnp.sum(sig[: min(rank * rank, n)] ** 2)
    total = jnp.sum(sig ** 2)                       # = sum_i ||B_iA_i||^2
    # NOTE (reproduction finding): the paper's proof of the lower bound
    # applies Jensen as  sum_i ||x_i||^2 >= ||sum_i x_i||^2, which misses the
    # 1/n factor (counterexample: x_i identical).  The corrected bound is
    # sum_i ||Sigma_i||^2 >= (1/n) * sum_{j<=r} sigbar_j^2; we verify that.
    return dict(lower=float(lower), lower_corrected=float(lower / n),
                upper=float(upper), total=float(total),
                sig=sig, sigbar=sigbar)


def retained_energy(res: JDResult) -> float:
    """sum_i ||Sigma_i||_F^2 (the quantity Thm. 1 bounds; requires orthogonal
    U, V, i.e. JD-Full)."""
    return float(jnp.sum(res.sigma_full() ** 2))


def check_theorem1(A: Array, B: Array, res: JDResult, atol: float = 1e-3) -> dict:
    b = theorem1_bounds(A, B, res.rank)
    kept = retained_energy(res)
    return dict(
        lower=b["lower"], lower_corrected=b["lower_corrected"], kept=kept,
        upper=b["upper"], total=b["total"],
        lower_ok=bool(kept >= b["lower_corrected"] - atol * max(b["total"], 1.0)),
        lower_literal_ok=bool(kept >= b["lower"] - atol * max(b["total"], 1.0)),
        upper_ok=bool(kept <= b["upper"] + atol * max(b["total"], 1.0)),
        error_lb=float(1.0 - b["upper"] / max(b["total"], 1e-30)),
    )


def corollary1_regime(A: Array, B: Array) -> dict:
    """Cor. 1 preconditions: unit Frobenius norms + pairwise orthogonality."""
    n = A.shape[0]
    deltas = jnp.einsum("nor,nri->noi", B, A)
    flat = deltas.reshape(n, -1)
    gram = flat @ flat.T
    norms = jnp.sqrt(jnp.diagonal(gram))
    off = gram - jnp.diag(jnp.diagonal(gram))
    return dict(norms=norms, max_off_diag=float(jnp.max(jnp.abs(off))))
