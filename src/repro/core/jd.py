"""Joint-diagonalization compression of LoRA collections.

Implements the paper's §3.1 / Appendix A algorithms on *stacked* adapter
banks.  A bank holds every adapter targeting one linear module:

    A: (n, r_pad, d_in)   B: (n, d_out, r_pad)

so that ``delta_i = B[i] @ A[i]``.  Adapters of heterogeneous rank are
zero-padded to ``r_pad`` (padding does not change the product).

Algorithms
----------
- :func:`jd_full`           eq. (2), alternating eigendecomposition (App. A.1 case 1)
- :func:`jd_full_eig`       App. A.2 QR eigenvalue-iteration variant (accelerator friendly)
- :func:`jd_diag`           eq. (3), triple-least-squares coordinate descent (App. A.1 case 2)
- :func:`svd_per_lora`      eq. (4), the k = n degenerate case (r-SVD baseline)
- :func:`ties_merge`        TIES-merging baseline (App. H.3)

All routines accept an optional per-adapter ``weights`` vector (0/1 mask or
soft weights); the clustering driver in :mod:`repro.core.cluster` reuses them
with membership masks so every cluster solve is a fixed-shape jittable call.

Everything here is pure JAX and runs in float32.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp

Array = jax.Array


# ---------------------------------------------------------------------------
# containers
# ---------------------------------------------------------------------------


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class JDResult:
    """Compressed representation of one bank: ``B_i A_i ~= U @ Sigma_i @ V^T``.

    ``sigma`` is (n, r, r) when ``diag`` is False, else (n, r).
    """

    U: Array  # (d_out, r)
    V: Array  # (d_in, r)
    sigma: Array
    diag: bool = dataclasses.field(metadata=dict(static=True), default=False)

    @property
    def rank(self) -> int:
        return self.U.shape[-1]

    @property
    def n(self) -> int:
        return self.sigma.shape[0]

    def sigma_full(self) -> Array:
        """Sigma as (n, r, r) regardless of parameterization."""
        if self.diag:
            r = self.sigma.shape[-1]
            return self.sigma[..., None] * jnp.eye(r, dtype=self.sigma.dtype)
        return self.sigma

    def reconstruct(self, i: Optional[int] = None) -> Array:
        """Materialize reconstructed delta(s). (n, d_out, d_in) or (d_out, d_in)."""
        sig = self.sigma_full()
        if i is not None:
            sig = sig[i]
            return self.U @ sig @ self.V.T
        return jnp.einsum("or,nrs,is->noi", self.U, sig, self.V)

    def scale_sigma(self, scales: Array) -> "JDResult":
        shape = (-1,) + (1,) * (self.sigma.ndim - 1)
        return dataclasses.replace(self, sigma=self.sigma * scales.reshape(shape))


# ---------------------------------------------------------------------------
# bank helpers (work on stacked A/B without forming n x d_out x d_in products)
# ---------------------------------------------------------------------------


def product_frob_norms(A: Array, B: Array) -> Array:
    """||B_i A_i||_F for each adapter, without forming the products.

    tr(A^T B^T B A) = sum((B^T B) * (A A^T))  elementwise with transpose pairing.
    """
    BtB = jnp.einsum("nor,nos->nrs", B, B)  # (n, r, r)
    AAt = jnp.einsum("nri,nsi->nrs", A, A)  # (n, r, r)
    sq = jnp.sum(BtB * AAt, axis=(-2, -1))
    return jnp.sqrt(jnp.maximum(sq, 0.0))


def normalize_bank(A: Array, B: Array, eps: float = 1e-12):
    """Frobenius-normalize each product to 1 (§6.1) by scaling A.

    Returns (A_hat, B, norms); de-normalize by ``result.scale_sigma(norms)``.
    """
    norms = product_frob_norms(A, B)
    A_hat = A / jnp.maximum(norms, eps)[:, None, None]
    return A_hat, B, norms


def reconstruction_errors(A: Array, B: Array, res: JDResult,
                          weights: Optional[Array] = None) -> dict:
    """Per-adapter squared errors + relative metrics, product-free.

    ||BA - U S V^T||^2 = ||BA||^2 - 2 tr(A^T B^T U S V^T) + tr(S^T U^T U S V^T V)
    """
    n = A.shape[0]
    sig = res.sigma_full()
    norms_sq = product_frob_norms(A, B) ** 2  # (n,)
    BtU = jnp.einsum("nor,ok->nrk", B, res.U)  # (n, r_pad, r)
    AV = jnp.einsum("nri,ik->nrk", A, res.V)  # (n, r_pad, r)
    # tr(A^T B^T U S V^T) = sum over (B^T U)^T S-weighted (A V)
    cross = jnp.einsum("nrk,nkl,nrl->n", BtU, sig, AV)
    UtU = res.U.T @ res.U
    VtV = res.V.T @ res.V
    gram = jnp.einsum("nkl,km,nmp,lp->n", sig, UtU, sig, VtV)
    err_sq = jnp.maximum(norms_sq - 2.0 * cross + gram, 0.0)
    rel = jnp.sqrt(err_sq / jnp.maximum(norms_sq, 1e-30))
    w = jnp.ones(n) if weights is None else weights
    wsum = jnp.maximum(jnp.sum(w), 1e-30)
    return dict(
        err_sq=err_sq,
        norms_sq=norms_sq,
        rel_err=rel,
        mean_rel_err=jnp.sum(rel * w) / wsum,
        # the paper's "reconstruction loss" (<= 0.6 rule in §6.5): energy ratio
        loss=jnp.sum(err_sq * w) / jnp.maximum(jnp.sum(norms_sq * w), 1e-30),
    )


def _weighted(x: Array, weights: Optional[Array]) -> Array:
    if weights is None:
        return x
    return x * weights.reshape((-1,) + (1,) * (x.ndim - 1))


def _orthonormalize(M: Array) -> Array:
    """Column-orthonormalize via reduced QR (the paper's `orthogonalize`)."""
    q, r = jnp.linalg.qr(M)
    # fix sign for determinism: make diag(r) nonnegative
    s = jnp.sign(jnp.diagonal(r))
    s = jnp.where(s == 0, 1.0, s)
    return q * s[None, :]


def _top_r_eigvecs(M: Array, r: int) -> Array:
    """Top-r eigenvectors of a PSD matrix (ascending eigh -> take tail)."""
    _, vecs = jnp.linalg.eigh(M)
    return vecs[:, -r:][:, ::-1]


def _sigma_full_from(U: Array, V: Array, A: Array, B: Array) -> Array:
    """Sigma_i = U^T B_i A_i V  (eq. 6), computed as (U^T B_i)(A_i V)."""
    return jnp.einsum("nor,ok,nri,il->nkl", B, U, A, V)


# ---------------------------------------------------------------------------
# JD-Full: alternating eigendecomposition (App. A.1 case 1)
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("rank", "iters"))
def jd_full(A: Array, B: Array, rank: int, iters: int = 10,
            weights: Optional[Array] = None,
            key: Optional[Array] = None) -> JDResult:
    """JD-Full via alternating top-r eigendecompositions.

    U-iter: M = sum_i w_i G_i G_i^T with G_i = B_i (A_i V)   -> U = eigvecs_r(M)
    V-iter: N = sum_i w_i K_i K_i^T with K_i = A_i^T (B_i^T U) -> V = eigvecs_r(N)
    """
    n, _, d_in = A.shape
    if key is None:
        key = jax.random.PRNGKey(0)
    V = _orthonormalize(jax.random.normal(key, (d_in, rank), dtype=A.dtype))

    def body(carry, _):
        V = carry
        G = jnp.einsum("nor,nri,ik->nok", B, A, V)  # (n, d_out, r)
        G = _weighted(G, None if weights is None else jnp.sqrt(weights))
        M = jnp.einsum("nok,npk->op", G, G)
        U = _top_r_eigvecs(M, rank)
        K = jnp.einsum("nri,nor,ok->nik", A, B, U)  # (n, d_in, r)
        K = _weighted(K, None if weights is None else jnp.sqrt(weights))
        N = jnp.einsum("nik,njk->ij", K, K)
        V = _top_r_eigvecs(N, rank)
        return V, None

    V, _ = jax.lax.scan(body, V, None, length=iters)
    # final U for the converged V, then sigma
    G = jnp.einsum("nor,nri,ik->nok", B, A, V)
    G = _weighted(G, None if weights is None else jnp.sqrt(weights))
    M = jnp.einsum("nok,npk->op", G, G)
    U = _top_r_eigvecs(M, rank)
    sigma = _sigma_full_from(U, V, A, B)
    return JDResult(U=U, V=V, sigma=sigma, diag=False)


# ---------------------------------------------------------------------------
# JD-Full: QR eigenvalue iteration (App. A.2) — accelerator friendly
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("rank", "iters"))
def jd_full_eig(A: Array, B: Array, rank: int, iters: int = 30,
                weights: Optional[Array] = None,
                key: Optional[Array] = None) -> JDResult:
    """JD-Full via the paper's QR-orthogonalized power iteration.

    U0 <- sum_i B_i (A_i V)((A_i V)^T (B_i^T U));  U <- qr(U0)
    V0 <- sum_i A_i^T (B_i^T U)((B_i^T U)^T (A_i V));  V <- qr(V0)

    Only r-width matmuls + one QR per update: no d x d eigendecompositions.
    """
    n, _, d_in = A.shape
    d_out = B.shape[1]
    if key is None:
        key = jax.random.PRNGKey(0)
    ku, kv = jax.random.split(key)
    U = _orthonormalize(jax.random.normal(ku, (d_out, rank), dtype=A.dtype))
    V = _orthonormalize(jax.random.normal(kv, (d_in, rank), dtype=A.dtype))

    def body(carry, _):
        U, V = carry
        AV = jnp.einsum("nri,ik->nrk", A, V)       # (n, r_pad, r)
        BtU = jnp.einsum("nor,ok->nrk", B, U)      # (n, r_pad, r)
        AV_w = _weighted(AV, weights)
        # U0 = sum_i B_i [ AV_i (AV_i^T BtU_i) ]
        inner_u = jnp.einsum("nrk,nrl->nkl", AV, BtU)   # (n, r, r)
        U0 = jnp.einsum("nor,nrk,nkl->ol", B, AV_w, inner_u)
        U_new = _orthonormalize(U0)
        BtU2 = jnp.einsum("nor,ok->nrk", B, U_new)
        inner_v = jnp.einsum("nrk,nrl->nkl", BtU2, AV)  # (n, r, r)
        BtU2_w = _weighted(BtU2, weights)
        V0 = jnp.einsum("nri,nrk,nkl->il", A, BtU2_w, inner_v)
        V_new = _orthonormalize(V0)
        return (U_new, V_new), None

    (U, V), _ = jax.lax.scan(body, (U, V), None, length=iters)
    sigma = _sigma_full_from(U, V, A, B)
    return JDResult(U=U, V=V, sigma=sigma, diag=False)


def jd_convergence_gap(U_prev: Array, U_next: Array) -> Array:
    """App. H.12 convergence criterion term: ||U+ - U U^T U+||_F / ||U+||_F."""
    resid = U_next - U_prev @ (U_prev.T @ U_next)
    return jnp.linalg.norm(resid) / jnp.maximum(jnp.linalg.norm(U_next), 1e-30)


# ---------------------------------------------------------------------------
# JD-Diag: triple least squares (App. A.1 case 2)
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("rank", "iters"))
def jd_diag(A: Array, B: Array, rank: int, iters: int = 10,
            weights: Optional[Array] = None,
            key: Optional[Array] = None) -> JDResult:
    """JD-Diag coordinate descent: solve U, V, then diag(Sigma_i) in cycle."""
    n, _, d_in = A.shape
    d_out = B.shape[1]
    if key is None:
        key = jax.random.PRNGKey(0)
    ku, kv = jax.random.split(key)
    # warm-start from orthonormal random; s_i = 1
    U = _orthonormalize(jax.random.normal(ku, (d_out, rank), dtype=A.dtype))
    V = _orthonormalize(jax.random.normal(kv, (d_in, rank), dtype=A.dtype))
    s = jnp.ones((n, rank), dtype=A.dtype)
    w = jnp.ones((n,), dtype=A.dtype) if weights is None else weights

    def ridge_solve(M, rhs):
        # (r, r) solve with a tiny Tikhonov floor for rank-deficient cases
        r = M.shape[0]
        return jnp.linalg.solve(M + 1e-8 * jnp.trace(M) / r * jnp.eye(r, dtype=M.dtype), rhs)

    def body(carry, _):
        U, V, s = carry
        AV = jnp.einsum("nri,ik->nrk", A, V)            # (n, r_pad, r)
        G = jnp.einsum("nor,nrk->nok", B, AV)           # (n, d_out, r) = B_i A_i V
        # U = (sum_i w G_i diag(s_i)) (sum_i w diag(s_i) V^T V diag(s_i))^{-1}
        t1 = jnp.einsum("n,nok,nk->ok", w, G, s)
        VtV = V.T @ V
        t2 = VtV * jnp.einsum("n,nk,nl->kl", w, s, s)
        U = ridge_solve(t2.T, t1.T).T
        # V update
        BtU = jnp.einsum("nor,ok->nrk", B, U)           # (n, r_pad, r)
        H = jnp.einsum("nri,nrk->nik", A, BtU)          # (n, d_in, r) = A_i^T B_i^T U
        t1v = jnp.einsum("n,nik,nk->ik", w, H, s)
        UtU = U.T @ U
        t2v = UtU * jnp.einsum("n,nk,nl->kl", w, s, s)
        V = ridge_solve(t2v.T, t1v.T).T
        # sigma update: s_i = (U^T U o V^T V)^{-1} (U^T B_i o V^T A_i^T) 1
        AV = jnp.einsum("nri,ik->nrk", A, V)
        BtU = jnp.einsum("nor,ok->nrk", B, U)
        q = jnp.einsum("nrk,nrk->nk", BtU, AV)          # (n, r)
        M_uv = (U.T @ U) * (V.T @ V)
        s = ridge_solve(M_uv, q.T).T
        return (U, V, s), None

    (U, V, s), _ = jax.lax.scan(body, (U, V, s), None, length=iters)
    return JDResult(U=U, V=V, sigma=s, diag=True)


# ---------------------------------------------------------------------------
# baselines
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("rank",))
def svd_per_lora(A: Array, B: Array, rank: int) -> JDResult:
    """r-SVD baseline (eq. 4): per-adapter truncated SVD, batched.

    Economical: QR-factor B_i and A_i^T, SVD the small (r_pad x r_pad) core.
    Returned as a JDResult-per-adapter bank stacked on axis 0 with
    U: (n, d_out, r), V: (n, d_in, r), sigma: (n, r).
    """

    def one(a, b):
        qb, rb = jnp.linalg.qr(b)           # (d_out, r_pad), (r_pad, r_pad)
        qa, ra = jnp.linalg.qr(a.T)         # (d_in, r_pad)
        core = rb @ ra.T                    # (r_pad, r_pad)
        u, s, vt = jnp.linalg.svd(core)
        u_r, s_r, v_r = u[:, :rank], s[:rank], vt[:rank, :].T
        return qb @ u_r, qa @ v_r, s_r

    U, V, s = jax.vmap(one)(A, B)
    return JDResult(U=U, V=V, sigma=s, diag=True)


def svd_reconstruction_errors(A: Array, B: Array, res: JDResult) -> dict:
    """Reconstruction metrics for the per-adapter SVD baseline."""
    norms_sq = product_frob_norms(A, B) ** 2
    kept = jnp.sum(res.sigma ** 2, axis=-1)
    err_sq = jnp.maximum(norms_sq - kept, 0.0)
    rel = jnp.sqrt(err_sq / jnp.maximum(norms_sq, 1e-30))
    return dict(err_sq=err_sq, norms_sq=norms_sq, rel_err=rel,
                mean_rel_err=jnp.mean(rel),
                loss=jnp.sum(err_sq) / jnp.maximum(jnp.sum(norms_sq), 1e-30))


@functools.partial(jax.jit, static_argnames=("rank", "trim_frac"))
def ties_merge(A: Array, B: Array, rank: int, trim_frac: float = 0.2) -> JDResult:
    """TIES-merging baseline: trim -> elect sign -> disjoint mean -> rank-r SVD.

    Consolidates every adapter into ONE rank-r LoRA (Table 7's Ties row).
    Materializes the (d_out, d_in) merged task matrix (fine at LoRA scale).
    """
    deltas = jnp.einsum("nor,nri->noi", B, A)  # (n, d_out, d_in)
    mag = jnp.abs(deltas)
    kth = jnp.quantile(mag.reshape(mag.shape[0], -1), 1.0 - trim_frac, axis=1)
    trimmed = jnp.where(mag >= kth[:, None, None], deltas, 0.0)
    sign = jnp.sign(jnp.sum(trimmed, axis=0))
    agree = jnp.where(jnp.sign(trimmed) == sign[None], trimmed, 0.0)
    cnt = jnp.sum(jnp.abs(jnp.sign(agree)), axis=0)
    merged = jnp.sum(agree, axis=0) / jnp.maximum(cnt, 1.0)
    u, s, vt = jnp.linalg.svd(merged, full_matrices=False)
    U, sig, V = u[:, :rank], s[:rank], vt[:rank, :].T
    n = A.shape[0]
    return JDResult(U=U, V=V, sigma=jnp.tile(sig[None], (n, 1)), diag=True)


# ---------------------------------------------------------------------------
# objective (for tests / convergence monitoring)
# ---------------------------------------------------------------------------


def jd_objective(A: Array, B: Array, res: JDResult,
                 weights: Optional[Array] = None) -> Array:
    """sum_i w_i ||B_i A_i - U Sigma_i V^T||_F^2 (eq. 1)."""
    errs = reconstruction_errors(A, B, res, weights)
    w = jnp.ones(A.shape[0]) if weights is None else weights
    return jnp.sum(errs["err_sq"] * w)
