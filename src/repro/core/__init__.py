"""Paper core: joint diagonalization LoRA compression (+clustering, theory)."""
from .jd import (JDResult, jd_full, jd_full_eig, jd_diag, svd_per_lora,
                 ties_merge, normalize_bank, product_frob_norms,
                 reconstruction_errors, svd_reconstruction_errors,
                 jd_objective, jd_convergence_gap)
from .cluster import (ClusteredJD, cluster_jd, clustered_reconstruction_errors,
                      parameter_counts)
from .collection import (LoRABank, stack_bank, CompressionConfig,
                         CompressedModule, compress_bank, compress_collection,
                         export_for_serving, export_uncompressed,
                         ServingAdapterBundle)
from .recommend import Recommendation, recommend, recommend_rank, to_config
from . import theory

__all__ = [
    "JDResult", "jd_full", "jd_full_eig", "jd_diag", "svd_per_lora",
    "ties_merge", "normalize_bank", "product_frob_norms",
    "reconstruction_errors", "svd_reconstruction_errors", "jd_objective",
    "jd_convergence_gap", "ClusteredJD", "cluster_jd",
    "clustered_reconstruction_errors", "parameter_counts", "LoRABank",
    "stack_bank", "CompressionConfig", "CompressedModule", "compress_bank",
    "compress_collection", "export_for_serving", "export_uncompressed",
    "ServingAdapterBundle", "Recommendation", "recommend", "recommend_rank",
    "to_config", "theory",
]
