"""Clustered joint compression (§3.2, Appendix A.3).

Alternates between (1) per-cluster JD-Full solves and (2) reassigning each
adapter to the cluster whose shared basis reconstructs it best.  Every
per-cluster solve runs over the *full* bank with a 0/1 membership mask so all
shapes are static; k solves are vmapped.

Initialization follows App. A.3: one global JD, then k-means on vec(Sigma_i).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from .jd import JDResult, jd_full, jd_full_eig, product_frob_norms

Array = jax.Array


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class ClusteredJD:
    """k per-cluster bases + per-adapter sigma and assignment."""

    U: Array        # (k, d_out, r)
    V: Array        # (k, d_in, r)
    sigma: Array    # (n, r, r)
    assign: Array   # (n,) int32
    diag: bool = dataclasses.field(metadata=dict(static=True), default=False)

    @property
    def n_clusters(self) -> int:
        return self.U.shape[0]

    @property
    def rank(self) -> int:
        return self.U.shape[-1]

    def cluster_result(self, j: int) -> JDResult:
        return JDResult(U=self.U[j], V=self.V[j], sigma=self.sigma, diag=self.diag)

    def reconstruct(self, i: int) -> Array:
        j = self.assign[i]
        return self.U[j] @ self.sigma[i] @ self.V[j].T

    def scale_sigma(self, scales: Array) -> "ClusteredJD":
        shape = (-1,) + (1,) * (self.sigma.ndim - 1)
        return dataclasses.replace(self, sigma=self.sigma * scales.reshape(shape))


# ---------------------------------------------------------------------------
# small fixed-iteration k-means on vec(sigma) for initialization
# ---------------------------------------------------------------------------


def _kmeans(x: Array, k: int, iters: int, key: Array) -> Array:
    """Plain k-means; returns assignments (n,). x: (n, d)."""
    n = x.shape[0]
    # k-means++-ish init: random distinct points
    idx = jax.random.choice(key, n, shape=(k,), replace=False)
    cent = x[idx]

    def body(cent, _):
        d2 = jnp.sum((x[:, None, :] - cent[None]) ** 2, axis=-1)  # (n, k)
        a = jnp.argmin(d2, axis=1)
        onehot = jax.nn.one_hot(a, k, dtype=x.dtype)              # (n, k)
        counts = jnp.maximum(onehot.sum(0), 1.0)
        cent_new = (onehot.T @ x) / counts[:, None]
        # keep old centroid for empty clusters
        cent_new = jnp.where((onehot.sum(0) > 0)[:, None], cent_new, cent)
        return cent_new, a

    cent, assigns = jax.lax.scan(body, cent, None, length=iters)
    return assigns[-1]


# ---------------------------------------------------------------------------
# assignment step: best cluster per adapter under orthogonal-U,V JD-Full
# ---------------------------------------------------------------------------


def _assignment_scores(A: Array, B: Array, U: Array, V: Array) -> Array:
    """Retained energy ||U_j^T B_i A_i V_j||_F^2 for every (i, j).

    With orthogonal U_j, V_j the reconstruction error of adapter i in cluster
    j is ||B_iA_i||^2 - retained_ij, so argmax retained == argmin error.
    Returns (n, k).
    """
    # (n,k,r_pad,r): A_i V_j and B_i^T U_j
    AV = jnp.einsum("nri,kic->nkrc", A, V)
    BtU = jnp.einsum("nor,koc->nkrc", B, U)
    # Sigma_ij = U_j^T B_i A_i V_j = BtU^T @ AV  -> (n,k,r,r)
    sig = jnp.einsum("nkrc,nkrd->nkcd", BtU, AV)
    return jnp.sum(sig ** 2, axis=(-2, -1))


def cluster_jd(A: Array, B: Array, rank: int, n_clusters: int,
               outer_iters: int = 5, jd_iters: int = 10,
               kmeans_iters: int = 10,
               solver: str = "eig",
               key: Optional[Array] = None) -> ClusteredJD:
    """Full clustering driver (App. A.3).

    solver: "eig" (App. A.2 iteration; default, accelerator friendly) or
            "eigh" (App. A.1 exact alternating eigendecomposition).
    """
    if key is None:
        key = jax.random.PRNGKey(0)
    n = A.shape[0]
    k = n_clusters
    solve = {"eig": jd_full_eig, "eigh": jd_full}[solver]

    # ---- init: global JD + k-means on vec(sigma) -------------------------
    k_init, k_km, k_solve = jax.random.split(key, 3)
    glob = solve(A, B, rank=rank, iters=jd_iters, key=k_init)
    assign = _kmeans(glob.sigma.reshape(n, -1), k, kmeans_iters, k_km)

    def solve_cluster(mask, kk):
        return solve(A, B, rank=rank, iters=jd_iters, weights=mask, key=kk)

    keys = jax.random.split(k_solve, k)

    prev_assign = None
    res = None
    for _ in range(outer_iters):
        masks = jax.nn.one_hot(assign, k, dtype=A.dtype).T  # (k, n)
        res = jax.vmap(solve_cluster)(masks, keys)          # stacked JDResult
        scores = _assignment_scores(A, B, res.U, res.V)     # (n, k)
        assign = jnp.argmax(scores, axis=1).astype(jnp.int32)
        if prev_assign is not None and bool(jnp.all(assign == prev_assign)):
            break
        prev_assign = assign

    # final per-adapter sigma against its own cluster basis
    U_i = res.U[assign]  # (n, d_out, r)
    V_i = res.V[assign]  # (n, d_in, r)
    sigma = jnp.einsum("nor,nok,nri,nil->nkl", B, U_i, A, V_i)
    return ClusteredJD(U=res.U, V=res.V, sigma=sigma,
                       assign=assign, diag=False)


def clustered_reconstruction_errors(A: Array, B: Array, c: ClusteredJD) -> dict:
    """Reconstruction metrics where each adapter uses its assigned cluster."""
    norms_sq = product_frob_norms(A, B) ** 2
    U_i, V_i = c.U[c.assign], c.V[c.assign]
    BtU = jnp.einsum("nor,nok->nrk", B, U_i)
    AV = jnp.einsum("nri,nik->nrk", A, V_i)
    cross = jnp.einsum("nrk,nkl,nrl->n", BtU, c.sigma, AV)
    # U_j, V_j orthonormal => gram = ||sigma_i||^2
    gram = jnp.sum(c.sigma ** 2, axis=(-2, -1))
    err_sq = jnp.maximum(norms_sq - 2.0 * cross + gram, 0.0)
    rel = jnp.sqrt(err_sq / jnp.maximum(norms_sq, 1e-30))
    return dict(err_sq=err_sq, norms_sq=norms_sq, rel_err=rel,
                mean_rel_err=jnp.mean(rel),
                loss=jnp.sum(err_sq) / jnp.maximum(jnp.sum(norms_sq), 1e-30))


# ---------------------------------------------------------------------------
# online lifecycle: incremental assignment, lazy shrink, refresh gate
# ---------------------------------------------------------------------------


def assign_adapter(A_i: Array, B_i: Array, c: ClusteredJD):
    """Incrementally place ONE new adapter on its nearest existing basis.

    The online-registration half of the assignment step: score every
    cluster with :func:`_assignment_scores` on a singleton bank (retained
    energy under the orthogonal bases — argmax retained == argmin
    reconstruction error) and compute the adapter's Sigma against the
    winner.  Nothing is re-solved, so this is cheap enough to run at
    register time; the basis only *serves* the adapter after the next
    refresh ships it fleet-wide (see ``serving/lifecycle.py``).

    A_i: (r_lora, d_in), B_i: (d_out, r_lora).  Returns
    ``(cluster, sigma, rel_err)`` — the nearest cluster index, the (r, r)
    Sigma against that cluster's basis, and the adapter's relative
    reconstruction error under it."""
    A, B = A_i[None], B_i[None]
    scores = _assignment_scores(A, B, c.U, c.V)[0]            # (k,)
    j = int(jnp.argmax(scores))
    sigma = jnp.einsum("or,ok,ri,il->kl", B_i, c.U[j], A_i, c.V[j])
    norm_sq = product_frob_norms(A, B)[0] ** 2
    err_sq = jnp.maximum(norm_sq - scores[j], 0.0)
    rel = float(jnp.sqrt(err_sq / jnp.maximum(norm_sq, 1e-30)))
    return j, sigma, rel


def add_adapter(c: ClusteredJD, A_i: Array, B_i: Array):
    """Hot-register: append one adapter to the collection without a
    re-solve (its Sigma rides the nearest existing basis; the next basis
    refresh re-solves with it as a full member).  Returns
    ``(new ClusteredJD, cluster, rel_err)``."""
    j, sigma, rel = assign_adapter(A_i, B_i, c)
    new = dataclasses.replace(
        c, sigma=jnp.concatenate([c.sigma, sigma[None]]),
        assign=jnp.concatenate(
            [c.assign, jnp.asarray([j], dtype=c.assign.dtype)]))
    return new, j, rel


def drop_adapter(c: ClusteredJD, i: int) -> ClusteredJD:
    """Retire: drop adapter `i`'s Sigma row and assignment.  The shared
    bases are left untouched — lazy shrink: they still reconstruct every
    remaining adapter exactly as before, and the next refresh re-solves
    over the smaller membership."""
    keep = jnp.arange(c.sigma.shape[0]) != i
    return dataclasses.replace(c, sigma=c.sigma[keep], assign=c.assign[keep])


def refresh_gate(A: Array, B: Array, serving: ClusteredJD,
                 candidate: ClusteredJD, max_regression: float = 0.0,
                 abs_slack: float = 1e-6,
                 max_new_rel_err: float = 1.0) -> dict:
    """Quality gate for a basis-refresh rollout (invariant L3).

    `A`/`B` is the bank the *candidate* covers; its first
    ``serving.sigma.shape[0]`` adapters (same order) are the ones the
    serving basis covers, any tail rows are newly absorbed raw adapters.
    The candidate passes only if

    - the adapters already served compressed do not regress: candidate
      mean relative reconstruction error <= serving mean * (1 +
      `max_regression`) + `abs_slack` (they were being served at the old
      error; a refresh must never make them worse), and
    - every newly absorbed adapter lands under `max_new_rel_err` (it was
      being served RAW, i.e. exactly — absorbing it may not cost more
      than the configured quality floor).

    Returns ``dict(ok, serving_err, candidate_err, new_worst_rel_err)``
    — plain floats, consumable by the jax-free control plane."""
    n_old = serving.sigma.shape[0]
    old_m = clustered_reconstruction_errors(A[:n_old], B[:n_old], serving)
    cand = clustered_reconstruction_errors(A, B, candidate)
    old_err = float(old_m["mean_rel_err"])
    new_err = float(jnp.mean(cand["rel_err"][:n_old]))
    new_worst = (float(jnp.max(cand["rel_err"][n_old:]))
                 if A.shape[0] > n_old else 0.0)
    ok = (new_err <= old_err * (1.0 + max_regression) + abs_slack
          and new_worst <= max_new_rel_err)
    return dict(ok=bool(ok), serving_err=old_err, candidate_err=new_err,
                new_worst_rel_err=new_worst)


def parameter_counts(d_out: int, d_in: int, n: int, rank: int,
                     n_clusters: int = 1, diag: bool = False,
                     lora_rank: int = 16) -> dict:
    """§F parameter accounting: compressed vs uncompressed counts."""
    base = n * lora_rank * (d_out + d_in)
    shared = n_clusters * rank * (d_out + d_in)
    per = n * (rank if diag else rank * rank) + (n if n_clusters > 1 else 0)
    comp = shared + per
    return dict(uncompressed=base, compressed=comp,
                saved_ratio=1.0 - comp / base)
