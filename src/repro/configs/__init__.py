from .base import (EncDecConfig, HybridConfig, LoRAConfig, ModelConfig,
                   MoEConfig, SHAPES, SSMConfig, ShapeConfig, VLMConfig,
                   smoke_shape)
from .registry import ASSIGNED, get_config, list_archs, smoke_config

__all__ = [
    "EncDecConfig", "HybridConfig", "LoRAConfig", "ModelConfig", "MoEConfig",
    "SHAPES", "SSMConfig", "ShapeConfig", "VLMConfig", "smoke_shape",
    "ASSIGNED", "get_config", "list_archs", "smoke_config",
]
