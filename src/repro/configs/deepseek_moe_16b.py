"""deepseek-moe-16b [arXiv:2401.06066]: 28L d=2048 16H (kv=16) vocab=102400,
MoE: 64 routed top-6 + 2 shared, d_ff_expert=1408, first layer dense 10944."""
from .base import LoRAConfig, ModelConfig, MoEConfig
from .registry import register


@register("deepseek-moe-16b")
def full() -> ModelConfig:
    return ModelConfig(
        name="deepseek-moe-16b", family="moe",
        num_layers=28, d_model=2048, num_heads=16, num_kv_heads=16,
        d_ff=10944, vocab_size=102400, rope_theta=1e4,
        moe=MoEConfig(num_experts=64, top_k=6, num_shared=2,
                      d_ff_expert=1408, first_k_dense=1, d_ff_dense=10944),
        lora=LoRAConfig(rank=16, targets=("q", "k", "v")),
        logits_chunk_vocab=12800,
    )
