"""pixtral-12b [hf:mistralai/Pixtral-12B-2409]: mistral-nemo backbone
40L d=5120 32H (kv=8) d_ff=14336 vocab=131072 + pixtral-ViT frontend (STUB:
input_specs supplies precomputed patch embeddings)."""
from .base import LoRAConfig, ModelConfig, VLMConfig
from .registry import register


@register("pixtral-12b")
def full() -> ModelConfig:
    return ModelConfig(
        name="pixtral-12b", family="vlm",
        num_layers=40, d_model=5120, num_heads=32, num_kv_heads=8,
        head_dim=128, d_ff=14336, vocab_size=131072,
        vlm=VLMConfig(num_patches=1024),
        lora=LoRAConfig(rank=16, targets=("q", "k", "v")),
        logits_chunk_vocab=8192,
    )
