"""whisper-small [arXiv:2212.04356]: 12L enc + 12L dec, d=768 12H d_ff=3072
vocab=51865; conv frontend STUB (input_specs supplies frame embeddings)."""
from .base import EncDecConfig, LoRAConfig, ModelConfig
from .registry import register


@register("whisper-small")
def full() -> ModelConfig:
    return ModelConfig(
        name="whisper-small", family="audio",
        num_layers=12, d_model=768, num_heads=12, num_kv_heads=12,
        head_dim=64, d_ff=3072, vocab_size=51865,
        encdec=EncDecConfig(encoder_layers=12),
        lora=LoRAConfig(rank=16, targets=("q", "k", "v")),
        logits_chunk_vocab=0,
    )
