"""granite-moe-3b-a800m [hf:ibm-granite]: 32L d=1536 24H (kv=8) vocab=49155,
MoE: 40 experts top-8, d_ff_expert=512."""
from .base import LoRAConfig, ModelConfig, MoEConfig
from .registry import register


@register("granite-moe-3b-a800m")
def full() -> ModelConfig:
    return ModelConfig(
        name="granite-moe-3b-a800m", family="moe",
        num_layers=32, d_model=1536, num_heads=24, num_kv_heads=8,
        head_dim=64, d_ff=512, vocab_size=49155, rope_theta=1e4,
        moe=MoEConfig(num_experts=40, top_k=8, num_shared=0, d_ff_expert=512),
        lora=LoRAConfig(rank=16, targets=("q", "k", "v")),
        logits_chunk_vocab=0,
    )
