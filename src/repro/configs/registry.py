"""Registry of assigned architectures (exact public configs) + smoke variants.

Every entry: ``full()`` returns the exact published config; ``smoke()`` a
reduced same-family config for CPU tests (small widths/layers/experts/vocab).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict

from .base import (EncDecConfig, HybridConfig, ModelConfig,
                   MoEConfig, SSMConfig, VLMConfig)

_REGISTRY: Dict[str, Callable[[], ModelConfig]] = {}


def register(name):
    def deco(fn):
        _REGISTRY[name] = fn
        return fn
    return deco


def get_config(name: str) -> ModelConfig:
    from . import (deepseek_moe_16b, granite_moe_3b_a800m, mamba2_2p7b,  # noqa
                   mistral_7b, mistral_large_123b, pixtral_12b, qwen1p5_110b,
                   qwen3_1p7b, qwen3_32b, whisper_small, zamba2_2p7b)
    return _REGISTRY[name]()


def list_archs():
    from . import (deepseek_moe_16b, granite_moe_3b_a800m, mamba2_2p7b,  # noqa
                   mistral_7b, mistral_large_123b, pixtral_12b, qwen1p5_110b,
                   qwen3_1p7b, qwen3_32b, whisper_small, zamba2_2p7b)
    return sorted(_REGISTRY)


ASSIGNED = [
    "deepseek-moe-16b", "granite-moe-3b-a800m", "qwen3-32b", "qwen3-1.7b",
    "mistral-large-123b", "qwen1.5-110b", "zamba2-2.7b", "pixtral-12b",
    "mamba2-2.7b", "whisper-small",
]


def smoke_config(name: str) -> ModelConfig:
    """Reduced same-family config: small widths, few layers/experts, tiny
    vocab.  Keeps every structural feature (GQA ratio, qk-norm, bias, shared
    experts, hybrid period, enc-dec, ...) of the full config."""
    cfg = get_config(name)
    kw = dict(
        name=cfg.name + "-smoke",
        num_layers=min(cfg.num_layers, 4),
        d_model=128,
        num_heads=4,
        num_kv_heads=max(1, 4 * cfg.num_kv_heads // max(cfg.num_heads, 1)),
        head_dim=32,
        d_ff=256,
        vocab_size=512,
        attn_chunk_q=32,
        attn_chunk_kv=32,
        logits_chunk_vocab=0,
        scan_layers=True,
    )
    if cfg.moe is not None:
        kw["moe"] = MoEConfig(
            num_experts=8, top_k=min(cfg.moe.top_k, 2),
            num_shared=min(cfg.moe.num_shared, 1),
            d_ff_expert=64,
            first_k_dense=1 if cfg.moe.first_k_dense else 0,
            d_ff_dense=256 if cfg.moe.first_k_dense else 0)
    if cfg.ssm is not None:
        kw["ssm"] = SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=32,
                              n_groups=cfg.ssm.n_groups, chunk=16)
        kw["num_heads"] = 4      # unused by ssm but keep consistent
    if cfg.hybrid is not None:
        kw["hybrid"] = HybridConfig(period=2)
        kw["num_layers"] = 4
    if cfg.encdec is not None:
        kw["encdec"] = EncDecConfig(encoder_layers=2)
        kw["num_layers"] = 2
    if cfg.vlm is not None:
        kw["vlm"] = VLMConfig(num_patches=8)
    return dataclasses.replace(cfg, **kw)
