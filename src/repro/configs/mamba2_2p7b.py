"""mamba2-2.7b [arXiv:2405.21060]: 64L d=2560 attention-free SSD,
ssm_state=128, vocab=50280."""
from .base import LoRAConfig, ModelConfig, SSMConfig
from .registry import register


@register("mamba2-2.7b")
def full() -> ModelConfig:
    return ModelConfig(
        name="mamba2-2.7b", family="ssm",
        num_layers=64, d_model=2560, num_heads=0, num_kv_heads=0,
        head_dim=64, d_ff=0, vocab_size=50280,
        ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64, chunk=256),
        lora=LoRAConfig(rank=16, targets=("ssm_in", "ssm_out")),
        logits_chunk_vocab=0,
    )
