"""mistral-large-123b [hf:mistralai/Mistral-Large-Instruct-2407]:
88L d=12288 96H (kv=8) d_ff=28672 vocab=32768, head_dim=128."""
from .base import LoRAConfig, ModelConfig
from .registry import register


@register("mistral-large-123b")
def full() -> ModelConfig:
    return ModelConfig(
        name="mistral-large-123b", family="dense",
        num_layers=88, d_model=12288, num_heads=96, num_kv_heads=8,
        head_dim=128, d_ff=28672, vocab_size=32768,
        lora=LoRAConfig(rank=16, targets=("q", "k", "v")),
        logits_chunk_vocab=8192,
    )
