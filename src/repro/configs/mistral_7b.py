"""mistral-7b (the paper's base model, for the compression experiments):
32L d=4096 32H (kv=8) d_ff=14336 vocab=32000."""
from .base import LoRAConfig, ModelConfig
from .registry import register


@register("mistral-7b")
def full() -> ModelConfig:
    return ModelConfig(
        name="mistral-7b", family="dense",
        num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8,
        head_dim=128, d_ff=14336, vocab_size=32000,
        lora=LoRAConfig(rank=16, targets=("q", "k", "v")),
        logits_chunk_vocab=8000,
    )
