"""qwen3-1.7b: 28L d=2048 16H (kv=8) d_ff=6144 vocab=151936, qk-norm."""
from .base import LoRAConfig, ModelConfig
from .registry import register


@register("qwen3-1.7b")
def full() -> ModelConfig:
    return ModelConfig(
        name="qwen3-1.7b", family="dense",
        num_layers=28, d_model=2048, num_heads=16, num_kv_heads=8,
        head_dim=128, d_ff=6144, vocab_size=151936, qk_norm=True,
        lora=LoRAConfig(rank=16, targets=("q", "k", "v")),
        logits_chunk_vocab=9496 * 2,
    )
