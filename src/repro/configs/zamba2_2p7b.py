"""zamba2-2.7b [arXiv:2411.15242]: 54 Mamba2 layers d=2560 + weight-shared
attention block (32H, kv=32, d_ff=10240) every 6 layers; ssm_state=64."""
from .base import HybridConfig, LoRAConfig, ModelConfig, SSMConfig
from .registry import register


@register("zamba2-2.7b")
def full() -> ModelConfig:
    return ModelConfig(
        name="zamba2-2.7b", family="hybrid",
        num_layers=54, d_model=2560, num_heads=32, num_kv_heads=32,
        head_dim=80, d_ff=10240, vocab_size=32000,
        ssm=SSMConfig(d_state=64, d_conv=4, expand=2, head_dim=64, chunk=256),
        hybrid=HybridConfig(period=6),
        lora=LoRAConfig(rank=16, targets=("q", "k", "v", "ssm_in", "ssm_out")),
        logits_chunk_vocab=0,
    )
