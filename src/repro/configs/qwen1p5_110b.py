"""qwen1.5-110b [hf:Qwen/Qwen1.5 family]: 80L d=8192 64H (kv=8)
d_ff=49152 vocab=152064, QKV bias."""
from .base import LoRAConfig, ModelConfig
from .registry import register


@register("qwen1.5-110b")
def full() -> ModelConfig:
    return ModelConfig(
        name="qwen1.5-110b", family="dense",
        num_layers=80, d_model=8192, num_heads=64, num_kv_heads=8,
        head_dim=128, d_ff=49152, vocab_size=152064, qkv_bias=True,
        lora=LoRAConfig(rank=16, targets=("q", "k", "v")),
        logits_chunk_vocab=9504 * 2,
    )
