"""Architecture / run configuration dataclasses.

One ``ModelConfig`` describes any of the 10 assigned architectures; family-
specific blocks (MoE / SSM / enc-dec / hybrid) are optional sub-configs.
``smoke()`` produces a reduced same-family config for CPU tests.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


def _pad_to(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    num_shared: int = 0
    d_ff_expert: int = 0            # per-expert hidden dim
    first_k_dense: int = 0          # leading dense layers (deepseek-moe)
    d_ff_dense: int = 0             # their hidden dim
    capacity_factor: float = 1.25
    router_jitter: float = 0.0


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 1
    chunk: int = 256

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclasses.dataclass(frozen=True)
class HybridConfig:
    """zamba2-style: shared attention+MLP block every `period` SSM layers."""
    period: int = 6


@dataclasses.dataclass(frozen=True)
class EncDecConfig:
    """whisper-style encoder-decoder; frontend is a stub (precomputed frames)."""
    encoder_layers: int = 12
    cross_attention: bool = True


@dataclasses.dataclass(frozen=True)
class VLMConfig:
    """pixtral-style: patch embeddings (stub ViT) prepended to token stream."""
    num_patches: int = 1024


@dataclasses.dataclass(frozen=True)
class LoRAConfig:
    rank: int = 16
    alpha: float = 32.0
    targets: Tuple[str, ...] = ("q", "k", "v")   # or ("ssm_in","ssm_out")


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0               # 0 -> d_model // num_heads
    qk_norm: bool = False
    qkv_bias: bool = False
    rope_theta: float = 1e6
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    sliding_window: int = 0         # 0 = full attention
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    hybrid: Optional[HybridConfig] = None
    encdec: Optional[EncDecConfig] = None
    vlm: Optional[VLMConfig] = None
    lora: Optional[LoRAConfig] = LoRAConfig()
    # implementation knobs
    attn_chunk_q: int = 1024        # 0 = naive attention
    attn_chunk_kv: int = 2048
    remat: bool = True
    scan_layers: bool = True
    logits_chunk_vocab: int = 0     # >0: chunked cross-entropy over vocab
    # perf-iteration knobs (baseline values; see EXPERIMENTS.md §Perf)
    decode_attn: str = "gather"     # gather | seq_shard (flash-decode merge)
    attn_cp_fallback: bool = False  # context-parallel attn when heads % tp != 0
    grad_cast_bf16: bool = False    # cast layer-boundary cotangents to bf16

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.num_heads)

    @property
    def padded_vocab(self) -> int:
        return _pad_to(self.vocab_size, 256)

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    def param_count(self) -> int:
        """Analytic parameter count N (embedding included once)."""
        d, hd = self.d_model, self.resolved_head_dim
        attn = d * self.num_heads * hd + 2 * d * self.num_kv_heads * hd \
            + self.num_heads * hd * d
        if self.qkv_bias:
            attn += (self.num_heads + 2 * self.num_kv_heads) * hd
        mlp_dense = 3 * d * self.d_ff
        emb = self.padded_vocab * d * (1 if self.tie_embeddings else 2)
        n = emb
        L = self.num_layers
        if self.family == "moe":
            m = self.moe
            per_moe = attn + 3 * d * m.d_ff_expert * (m.num_experts + m.num_shared) \
                + d * m.num_experts
            n += (L - m.first_k_dense) * per_moe
            n += m.first_k_dense * (attn + 3 * d * m.d_ff_dense)
        elif self.family == "ssm":
            n += L * self._ssm_params()
        elif self.family == "hybrid":
            n_shared_sites = L // self.hybrid.period
            n += L * self._ssm_params()
            n += attn + mlp_dense          # ONE shared block (weight-tied)
            del n_shared_sites
        elif self.family == "audio":
            enc_l = self.encdec.encoder_layers
            n += enc_l * (attn + mlp_dense)              # encoder
            n += L * (attn + attn + mlp_dense)           # decoder (self+cross)
        else:  # dense / vlm
            n += L * (attn + mlp_dense)
        n += L * 2 * d  # norms (approx)
        return int(n)

    def active_param_count(self) -> int:
        """Active params per token (MoE: shared + top_k experts only)."""
        if self.family != "moe":
            return self.param_count()
        d = self.d_model
        m = self.moe
        hd = self.resolved_head_dim
        attn = d * self.num_heads * hd + 2 * d * self.num_kv_heads * hd \
            + self.num_heads * hd * d
        per_moe_active = attn + 3 * d * m.d_ff_expert * (m.top_k + m.num_shared)
        L = self.num_layers
        n = self.padded_vocab * d * 2
        n += (L - m.first_k_dense) * per_moe_active
        n += m.first_k_dense * (attn + 3 * d * m.d_ff_dense)
        return int(n)

    def _ssm_params(self) -> int:
        d = self.d_model
        s = self.ssm
        di = s.d_inner(d)
        nh = s.n_heads(d)
        in_proj = d * (2 * di + 2 * s.n_groups * s.d_state + nh)
        conv = (di + 2 * s.n_groups * s.d_state) * s.d_conv
        out_proj = di * d
        return in_proj + conv + out_proj + 3 * nh  # A_log, D, dt_bias


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                       # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def smoke_shape(kind: str = "train") -> ShapeConfig:
    return {
        "train": ShapeConfig("smoke_train", 64, 2, "train"),
        "prefill": ShapeConfig("smoke_prefill", 64, 2, "prefill"),
        "decode": ShapeConfig("smoke_decode", 64, 2, "decode"),
    }[kind]
