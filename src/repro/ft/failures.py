"""Fault tolerance: failure injection, restart policy, straggler mitigation,
elastic re-meshing.

The production story (documented in DESIGN.md):
  - every K steps an async sharded checkpoint is written;
  - a node failure surfaces as a collective error / missed heartbeat -> the
    launcher tears the job down and restarts on the surviving hosts;
  - restart rebuilds the best mesh for the surviving device count
    (``launch.mesh.make_mesh_for``) and restores the latest checkpoint under
    the new shardings (elastic restore);
  - stragglers are handled by a per-step deadline: a step that exceeds
    ``straggler_factor x`` the EWMA step time raises StragglerDetected so the
    runner can exclude the slow host on the next restart (on CPU we inject
    synthetic delays to test the policy).

This module is exercised by tests/test_ft.py with real failure injection.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional


class NodeFailure(RuntimeError):
    pass


class StragglerDetected(RuntimeError):
    def __init__(self, step: int, t: float, ewma: float):
        super().__init__(f"step {step} took {t:.3f}s vs ewma {ewma:.3f}s")
        self.step = step


@dataclasses.dataclass
class FailurePlan:
    """Deterministic failure schedule for tests/drills."""
    fail_at_steps: tuple = ()
    straggle_at_steps: tuple = ()
    straggle_seconds: float = 0.5
    kill_nodes: int = 1


@dataclasses.dataclass
class FTConfig:
    ckpt_every: int = 10
    max_restarts: int = 10
    straggler_factor: float = 3.0
    ewma_alpha: float = 0.2
    min_steps_for_ewma: int = 3


@dataclasses.dataclass
class RunState:
    step: int = 0
    restarts: int = 0
    ewma_step_time: float = 0.0
    excluded_nodes: int = 0
    history: List[Dict] = dataclasses.field(default_factory=list)


class FaultTolerantRunner:
    """Drives step_fn with checkpoint/restart + straggler detection.

    step_fn(state_dict, step) -> state_dict   (pure training step closure)
    save_fn(step, state_dict), restore_fn() -> (step, state_dict) | None
    """

    def __init__(self, cfg: FTConfig, step_fn, save_fn, restore_fn,
                 plan: Optional[FailurePlan] = None,
                 on_restart: Optional[Callable[[RunState], None]] = None):
        self.cfg = cfg
        self.step_fn = step_fn
        self.save_fn = save_fn
        self.restore_fn = restore_fn
        self.plan = plan or FailurePlan()
        self.on_restart = on_restart
        self.state = RunState()

    def _maybe_inject(self, step: int):
        if step in self.plan.fail_at_steps:
            # only fail once per scheduled step
            self.plan = dataclasses.replace(
                self.plan, fail_at_steps=tuple(
                    s for s in self.plan.fail_at_steps if s != step))
            raise NodeFailure(f"injected node failure at step {step}")
        if step in self.plan.straggle_at_steps:
            self.plan = dataclasses.replace(
                self.plan, straggle_at_steps=tuple(
                    s for s in self.plan.straggle_at_steps if s != step))
            time.sleep(self.plan.straggle_seconds)

    def run(self, init_state, n_steps: int):
        rs = self.state
        train_state = init_state
        while rs.step < n_steps:
            try:
                self._run_segment(train_state, n_steps)
                return self._final
            except NodeFailure as e:
                rs.restarts += 1
                rs.history.append({"step": rs.step, "event": str(e)})
                if rs.restarts > self.cfg.max_restarts:
                    raise
                restored = self.restore_fn()
                if restored is None:
                    rs.step = 0
                    train_state = init_state
                else:
                    rs.step, train_state = restored
                if self.on_restart:
                    self.on_restart(rs)
            except StragglerDetected as e:
                rs.history.append({"step": e.step, "event": str(e)})
                rs.excluded_nodes += 1
                # continue without restart: the slow host is flagged for the
                # next scheduling decision; the step already completed.
        return self._final

    def _run_segment(self, train_state, n_steps: int):
        rs = self.state
        while rs.step < n_steps:
            self._maybe_inject(rs.step)
            t0 = time.perf_counter()
            train_state = self.step_fn(train_state, rs.step)
            dt = time.perf_counter() - t0
            rs.step += 1
            if rs.step % self.cfg.ckpt_every == 0:
                self.save_fn(rs.step, train_state)
            self._final = train_state
            # straggler detection on EWMA
            if rs.ewma_step_time == 0.0:
                rs.ewma_step_time = dt
            slow = (rs.step > self.cfg.min_steps_for_ewma and
                    dt > self.cfg.straggler_factor * rs.ewma_step_time)
            rs.ewma_step_time = ((1 - self.cfg.ewma_alpha) * rs.ewma_step_time
                                 + self.cfg.ewma_alpha * dt)
            if slow:
                raise StragglerDetected(rs.step - 1, dt, rs.ewma_step_time)
        return train_state
