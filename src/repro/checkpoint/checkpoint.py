"""Sharded checkpointing with async save and mesh-elastic restore.

Layout: one directory per step containing flat ``.npy`` leaves (path-encoded
pytree keys) + a manifest.  Arrays are written from the addressable shards'
assembled host value (on a real multi-host fleet each host writes its own
shard files; the manifest layout already carries the spec, so the restore
path is host-local — documented in DESIGN.md).

Restore is *elastic*: arrays are re-placed under whatever mesh/sharding the
caller provides (possibly a different topology than the save-time mesh),
which is what repro.ft uses after a failure shrinks the fleet.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import ml_dtypes
import numpy as np


_SEP = "::"
_ASYNC_STATE: dict = {}

# numpy .npy cannot roundtrip ml_dtypes (bfloat16 etc.); store a raw view and
# record the true dtype in the manifest.
_RAW_VIEW = {"bfloat16": np.uint16, "float8_e4m3fn": np.uint8,
             "float8_e5m2": np.uint8}


def _flatten(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(_path_str(p) for p in path)
        flat[key] = leaf
    return flat


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return f"#{p.idx}"
    return str(p)


def save_checkpoint(ckpt_dir: str, step: int, tree, *,
                    keep: int = 3, blocking: bool = True,
                    _async_state: dict = _ASYNC_STATE) -> str:
    """Write `tree` under ckpt_dir/step_N (atomic rename)."""
    # join any in-flight async save BEFORE touching tmp dirs: a previous
    # save of the same step (e.g. re-reached after a crash/restart) may
    # still be writing into .tmp_step_N, and deleting it mid-write races
    # the writer thread (rmtree fails with "Directory not empty")
    prev: Optional[threading.Thread] = _async_state.get("thread")
    if prev is not None and prev.is_alive():
        prev.join()
    base = Path(ckpt_dir)
    base.mkdir(parents=True, exist_ok=True)
    tmp = base / f".tmp_step_{step}"
    final = base / f"step_{step}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir()

    flat = _flatten(tree)
    host = {k: np.asarray(jax.device_get(v)) for k, v in flat.items()}

    def write():
        manifest = {}
        for k, v in host.items():
            fn = f"{abs(hash(k)) % 10**12}.npy"
            true_dtype = str(v.dtype)
            raw = _RAW_VIEW.get(true_dtype)
            np.save(tmp / fn, v.view(raw) if raw is not None else v)
            manifest[k] = {"file": fn, "shape": list(v.shape),
                           "dtype": true_dtype}
        (tmp / "manifest.json").write_text(json.dumps(
            {"step": step, "arrays": manifest, "time": time.time()}))
        if final.exists():
            shutil.rmtree(final)
        os.rename(tmp, final)
        _gc(base, keep)

    if blocking:
        write()
    else:
        t = threading.Thread(target=write, daemon=True)
        t.start()
        _async_state["thread"] = t
    return str(final)


def wait_for_async_saves(_async_state: dict = _ASYNC_STATE):
    t = _async_state.get("thread")
    if t is not None:
        t.join()


def _gc(base: Path, keep: int):
    steps = sorted((int(p.name.split("_")[1]), p)
                   for p in base.glob("step_*"))
    for _, p in steps[:-keep]:
        shutil.rmtree(p, ignore_errors=True)


def latest_step(ckpt_dir: str) -> Optional[int]:
    base = Path(ckpt_dir)
    if not base.exists():
        return None
    steps = [int(p.name.split("_")[1]) for p in base.glob("step_*")
             if (p / "manifest.json").exists()]
    return max(steps) if steps else None


def restore_checkpoint(ckpt_dir: str, step: int, tree_like, *,
                       shardings=None):
    """Restore into the structure of `tree_like`; if `shardings` (a matching
    pytree of NamedSharding) is given, arrays are placed under it — possibly
    a different mesh than at save time (elastic restore)."""
    base = Path(ckpt_dir) / f"step_{step}"
    manifest = json.loads((base / "manifest.json").read_text())["arrays"]
    flat_like = _flatten(tree_like)
    flat_sh = _flatten(shardings) if shardings is not None else {}
    out = {}
    for k, like in flat_like.items():
        meta = manifest[k]
        arr = np.load(base / meta["file"])
        if meta["dtype"] in _RAW_VIEW:
            arr = arr.view(getattr(ml_dtypes, meta["dtype"]))
        sh = flat_sh.get(k)
        if sh is not None:
            out[k] = jax.device_put(arr, sh)
        else:
            out[k] = jnp.asarray(arr)
    # unflatten along tree_like's structure
    leaves_paths = jax.tree_util.tree_flatten_with_path(tree_like)
    keys = [_SEP.join(_path_str(q) for q in path)
            for path, _ in leaves_paths[0]]
    return jax.tree_util.tree_unflatten(leaves_paths[1],
                                        [out[k] for k in keys])
