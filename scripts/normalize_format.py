"""Mechanical ruff-format-style normalization, AST-verified.

CI runs the real ``ruff format --check`` (pinned 0.8.4); this script exists
because dev containers without network access cannot install ruff.  It
applies the formatter's *mechanically safe* rules —

  - prefer double quotes for strings (skipped when the content contains a
    double quote),
  - strip trailing whitespace and normalize the EOF newline,
  - exactly two blank lines between top-level definitions,
  - at most two consecutive blank lines at module level and at most one
    inside any indented block (runs inside brackets or strings are left
    alone),

— and verifies after every transformation that the file's AST is unchanged
(``ast.dump`` equality), dropping any transformation that is not provably
behavior-preserving for that file.  Line-wrapping style is left to the real
formatter.

Usage:  python scripts/normalize_format.py [--check] [paths...]
"""
from __future__ import annotations

import argparse
import ast
import io
import sys
import tokenize
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent


def _requote(tok_str: str) -> str:
    """Convert a single-quoted string token to double quotes when safe."""
    i = 0
    while i < len(tok_str) and tok_str[i] not in "\"'":
        i += 1
    prefix, body = tok_str[:i], tok_str[i:]
    if body.startswith('"'):
        return tok_str
    if body.startswith("'''"):
        inner, new_quote = body[3:-3], '"""'
    elif body.startswith("'"):
        inner, new_quote = body[1:-1], '"'
    else:
        return tok_str
    if '"' in inner:
        return tok_str                   # would need escaping: not safe
    return prefix + new_quote + inner + new_quote


def requote(text: str) -> str:
    """Rewrite every plain STRING token's quotes (f-string parts and
    anything tokenize splits further are left alone)."""
    try:
        toks = list(tokenize.generate_tokens(io.StringIO(text).readline))
    except (tokenize.TokenError, IndentationError):
        return text
    lines = text.splitlines(keepends=True)
    # apply replacements right-to-left so positions stay valid
    for tok in reversed(toks):
        if tok.type != tokenize.STRING or "'" not in tok.string:
            continue
        new = _requote(tok.string)
        if new == tok.string:
            continue
        (sr, sc), (er, ec) = tok.start, tok.end
        if sr != er:                     # multiline string: single splice
            joined = "".join(lines[sr - 1:er])
            replaced = joined[:sc] + new + joined[len(joined)
                                                  - (len(lines[er - 1])
                                                     - ec):]
            lines[sr - 1:er] = [replaced]
        else:
            ln = lines[sr - 1]
            lines[sr - 1] = ln[:sc] + new + ln[ec:]
    return "".join(lines)


def strip_trailing_ws(text: str) -> str:
    lines = [ln.rstrip() for ln in text.splitlines()]
    while lines and lines[-1] == "":
        lines.pop()
    return "\n".join(lines) + "\n"


def _toplevel_items(text):
    """(start_row, end_row, kind) per top-level logical line, via tokenize
    (so lines inside strings or bracket continuations are never mistaken
    for definitions).  kind: 'decorator' | 'def' | 'other'."""
    toks = list(tokenize.generate_tokens(io.StringIO(text).readline))
    items = []
    start = kind = None
    for tok in toks:
        if tok.type in (tokenize.NL, tokenize.COMMENT, tokenize.INDENT,
                        tokenize.DEDENT, tokenize.ENDMARKER):
            continue
        if tok.type == tokenize.NEWLINE:
            if start is not None:
                items.append((start, tok.start[0], kind))
            start = None
            continue
        if start is None:
            start = tok.start[0]
            if tok.start[1] != 0:
                kind = "other"
            elif tok.type == tokenize.OP and tok.string == "@":
                kind = "decorator"
            elif tok.type == tokenize.NAME and tok.string in ("def", "class"):
                kind = "def"
            else:
                kind = "other"
    return items


def blank_lines(text: str) -> str:
    """Exactly two blank lines before top-level def/class/decorator groups;
    none between a decorator and what it decorates."""
    try:
        items = _toplevel_items(text)
    except (tokenize.TokenError, IndentationError):
        return text
    def_rows = {r for r, _, k in items if k in ("def", "decorator")}
    attach_rows = set()                  # def rows glued to a decorator above
    for (_, end, kind), (start2, _, kind2) in zip(items, items[1:]):
        if kind == "decorator" and kind2 in ("def", "decorator"):
            attach_rows.add(start2)
    first_code = min((r for r, _, _ in items), default=None)
    lines = text.splitlines()
    out: list[tuple[int, str]] = []      # (original_row, line)
    for i, ln in enumerate(lines):
        row = i + 1
        if row in def_rows and first_code is not None and row > first_code:
            j = len(out) - 1
            while j >= 0 and out[j][1] == "":
                j -= 1
            prev = out[j][1] if j >= 0 else ""
            if row in attach_rows:       # decorator group stays attached
                del out[j + 1:]
            elif not prev.lstrip().startswith("#"):
                del out[j + 1:]
                out.extend([(0, ""), (0, "")])
        out.append((row, ln))
    while out and out[-1][1] == "":
        out.pop()
    return "\n".join(ln for _, ln in out) + "\n"


def collapse_blank_runs(text: str) -> str:
    """Ruff-format's empty-line cap: at most two consecutive blank lines at
    module level, at most one inside an indented block.  Only lines the
    tokenizer sees as blank NL lines are touched — blank lines inside
    strings never produce NL tokens, and runs inside brackets (implicit
    continuations) are skipped as not mechanically safe."""
    try:
        toks = list(tokenize.generate_tokens(io.StringIO(text).readline))
    except (tokenize.TokenError, IndentationError):
        return text
    blank_rows = set()
    depth = 0
    for tok in toks:
        if tok.type == tokenize.OP:
            if tok.string in "([{":
                depth += 1
            elif tok.string in ")]}":
                depth -= 1
        elif (tok.type == tokenize.NL and depth == 0
              and tok.line.strip() == ""):
            blank_rows.add(tok.start[0])
    lines = text.splitlines()
    out = []
    i = 0
    while i < len(lines):
        if (i + 1) in blank_rows:
            j = i
            while j + 1 < len(lines) and (j + 2) in blank_rows:
                j += 1
            # depth comes from the next CODE line: comment lines (which may
            # sit at column 0 inside a block) are skipped, so a blank run
            # above a commented statement still caps at the block's 1
            nxt = j + 1
            while nxt < len(lines) and (lines[nxt].strip() == ""
                                        or lines[nxt].lstrip()
                                        .startswith("#")):
                nxt += 1
            indented = (nxt < len(lines)
                        and len(lines[nxt]) > len(lines[nxt].lstrip()))
            out.extend([""] * min(j - i + 1, 1 if indented else 2))
            i = j + 1
        else:
            out.append(lines[i])
            i += 1
    return "\n".join(out) + ("\n" if text.endswith("\n") else "")


def process(path: Path, check: bool) -> bool:
    """Returns True when the file was (or would be) changed."""
    src = path.read_text()
    try:
        want = ast.dump(ast.parse(src))
    except SyntaxError:
        return False
    cur = src
    for step in (requote, strip_trailing_ws, blank_lines,
                 collapse_blank_runs):
        cand = step(cur)
        if cand == cur:
            continue
        try:
            ok = ast.dump(ast.parse(cand)) == want
        except SyntaxError:
            ok = False
        if ok:
            cur = cand
        else:
            print(f"note: dropped unsafe {step.__name__} for {path}",
                  file=sys.stderr)
    if cur == src:
        return False
    if not check:
        path.write_text(cur)
    return True


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--check", action="store_true",
                    help="report files that would change; do not write")
    ap.add_argument("paths", nargs="*", default=None)
    args = ap.parse_args()
    roots = ([Path(p) for p in args.paths] if args.paths
             else [REPO / "src", REPO / "tests", REPO / "benchmarks",
                   REPO / "examples", REPO / "scripts"])
    changed = 0
    for root in roots:
        files = [root] if root.is_file() else sorted(root.rglob("*.py"))
        for f in files:
            if process(f, args.check):
                changed += 1
                print(("would reformat: " if args.check else "reformatted: ")
                      + str(f))
    print(f"{changed} file(s) {'would be ' if args.check else ''}changed")
    return 1 if (args.check and changed) else 0


if __name__ == "__main__":
    sys.exit(main())
