"""Generate EXPERIMENTS.md from results/dryrun + results/perf + benchmark runs."""
import json
import subprocess
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
DRY = ROOT / "results" / "dryrun"
PERF = ROOT / "results" / "perf"


def fmt_cell(d):
    r = d["roofline"]
    return (f"| {d['arch']} | {d['shape']} | {d['mesh']} | "
            f"{r['t_compute_s']:.4f} | {r['t_memory_s']:.4f} | "
            f"{r['t_collective_s']:.4f} | {r['bottleneck']} | "
            f"{r['useful_flops_ratio']:.3f} | {r['roofline_fraction']:.4f} | "
            f"{d['memory']['temp_bytes']/1e9:.1f} |")


def main():
    recs = sorted((json.loads(p.read_text()) for p in DRY.glob("*.json")),
                  key=lambda d: (d["arch"], d["shape"], d["mesh"]))
    ok = [d for d in recs if d.get("ok") and not d.get("skipped")]
    skips = [d for d in recs if d.get("skipped")]
    fails = [d for d in recs if not d.get("ok")]

    perf = sorted((json.loads(p.read_text()) for p in PERF.glob("*.json")),
                  key=lambda d: (d["arch"], d["shape"], d.get("variant", "")))

    # benchmark CSV (quick mode)
    subprocess.run(
        [sys.executable, "-m", "benchmarks.compression_quality"],
        capture_output=True, text=True, cwd=ROOT,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"})

    out = []
    w = out.append
    w("# EXPERIMENTS — dry-run, roofline, and perf iterations\n")
    w("Hardware model: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, 50 GB/s/link "
      "ICI. Meshes: 16x16 (data, model) single pod; 2x16x16 (pod, data, "
      "model) = 512 chips multi-pod.\n")

    # ----- §Dry-run -----
    w("## §Dry-run\n")
    w(f"**{len(ok)} cells compiled OK**, {len(skips)} documented skips, "
      f"{len(fails)} failures, across every (architecture x shape x mesh) "
      "combination. Each cell lowers + compiles the full step "
      "(train: fwd+bwd+AdamW w/ FSDP+TP+SP sharding and microbatching; "
      "prefill/decode: serve step with sharded KV caches), then records "
      "`memory_analysis()`, loop-aware HLO cost terms, and the collective "
      "schedule.\n")
    w("Methodology notes (see DESIGN.md §9): XLA `cost_analysis()` counts "
      "while-loop bodies once, so FLOPs/bytes/collectives are re-derived "
      "from the post-SPMD HLO with trip-count multiplication "
      "(`launch/hlo_cost.py`, validated <5% vs analytic on scanned matmuls); "
      "in-place `dynamic-update-slice` writes are billed at update-slice "
      "size; XLA:CPU's f32 loop-carry round-trips (absent on TPU) are not "
      "billed.\n")
    if skips:
        w("Skipped cells (all `long_500k` on pure full-attention archs, per "
          "DESIGN.md §4):\n")
        for d in skips:
            w(f"- {d['arch']} {d['shape']} {d['mesh']}")
        w("")
    if fails:
        w("FAILED cells:\n")
        for d in fails:
            w(f"- {d['arch']} {d['shape']} {d['mesh']}: {d.get('error')}")
        w("")

    # ----- §Roofline -----
    w("## §Roofline (single-pod 16x16 baselines; multi-pod rows included "
      "for dry-run completeness)\n")
    w("| arch | shape | mesh | t_compute (s) | t_memory (s) | t_collective "
      "(s) | bottleneck | MODEL_FLOPS/HLO_FLOPs | roofline fraction | "
      "temp GB/dev |")
    w("|---|---|---|---|---|---|---|---|---|---|")
    for d in ok:
        w(fmt_cell(d))
    w("")
    w("Reading the table: `useful` = MODEL_FLOPS / (HLO dot-FLOPs x chips) "
      "— 6·N·D train / 2·N·D prefill / 2·N_active·B + KV-read decode; values "
      "~0.74 on train cells reflect full-remat recompute (8/6 overhead) "
      "plus causal-mask waste in chunked attention. `roofline fraction` = "
      "ideal-compute time / max(three terms): decode cells are inherently "
      "weight/KV-streaming bound, so their fraction is small by "
      "construction — compare t_memory against the ideal stream time "
      "instead (perf section). `temp GB/dev` > 16 GB flags cells that need "
      "the §Perf variants to fit HBM.\n")

    # ----- §Perf -----
    w("## §Perf — hillclimb log (hypothesis -> change -> before -> after)\n")
    w("Three cells selected per the brief: **worst roofline fraction** "
      "(granite-moe prefill_32k), **most collective-bound** "
      "(mistral-large-123b train_4k), **most representative of the paper** "
      "(decode/serving cells, qwen3-32b decode_32k + the 100B-class decode "
      "cells). Variant artifacts in `results/perf/`.\n")
    w("| cell | variant | t_compute | t_memory | t_collective | frac | "
      "temp GB |")
    w("|---|---|---|---|---|---|---|")
    base_by_key = {(d["arch"], d["shape"]): d for d in ok
                   if d["mesh"] == "16x16"}
    seen = set()
    for d in perf:
        key = (d["arch"], d["shape"])
        if key in base_by_key and key not in seen:
            b = base_by_key[key]
            r = b["roofline"]
            w(f"| {key[0]} {key[1]} | **baseline** | {r['t_compute_s']:.4f} "
              f"| {r['t_memory_s']:.4f} | {r['t_collective_s']:.4f} | "
              f"{r['roofline_fraction']:.4f} | "
              f"{b['memory']['temp_bytes']/1e9:.1f} |")
            seen.add(key)
        if not d.get("ok"):
            w(f"| {key[0]} {key[1]} | {d.get('variant')} | FAIL | | | | |")
            continue
        r = d["roofline"]
        w(f"| {key[0]} {key[1]} | {d.get('variant')} | {r['t_compute_s']:.4f} "
          f"| {r['t_memory_s']:.4f} | {r['t_collective_s']:.4f} | "
          f"{r['roofline_fraction']:.4f} | "
          f"{d['memory']['temp_bytes']/1e9:.1f} |")
    w("")
    return "\n".join(out)


if __name__ == "__main__":
    print(main())
