#!/usr/bin/env python
"""Docs reference checker (CI docs lane).

Every module path (`serving/resources.py`, `tests/test_paged.py::Name`),
dotted module (`repro.kernels.kv_quant`), `ClassName`, `Class.attr`, and
`SCREAMING_CASE` constant mentioned in inline code spans of the checked
markdown files must exist in the tree.  Dangling references fail the run
— docs rot is a CI failure, not a review nit.

Checked files: everything under docs/ plus README.md.  Fenced code
blocks are skipped (they hold diagrams and shell transcripts); only
inline `backtick` spans are parsed.  Tokens that do not look like code
references (flags, shell fragments, JSON keys, snake_case words) are
ignored rather than guessed at.

Two spec-sync lanes ride along:

* **Invariant IDs.**  A spec doc (under `docs/`) that names invariants
  (`I1`/`L4`-style IDs) must agree with the test files it references:
  every documented ID must be asserted (an `# I1` trailing comment or
  `Invariant I1:` docstring in a referenced `tests/test_*.py`), and
  every asserted ID in those files must be documented.  Spec drift fails
  in both directions.  README may cite invariants in passing without
  owning the full set, so the lane skips it.
* **Serving coverage.**  Every public class defined under
  `src/repro/serving/` must be mentioned (inline span) in at least one
  checked doc — a public serving API that no doc names is a failure.

Usage:  python scripts/check_docs_refs.py  (exit 1 on any dangling ref)
"""
from __future__ import annotations

import builtins
import pathlib
import re
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent
SRC_DIRS = ("src", "tests", "benchmarks", "scripts", "examples")

RE_FENCE = re.compile(r"^(```|~~~)", re.M)
RE_SPAN = re.compile(r"`([^`\n]+)`")
RE_CALL = re.compile(r"^([A-Za-z_][\w.]*)\(.*\)$")
RE_DOTTED = re.compile(r"^(repro|tests|benchmarks|scripts)(\.[A-Za-z_]\w*)+$")
RE_CLASS_ATTR = re.compile(r"^([A-Z][A-Za-z0-9]*)\.([a-z_]\w*)$")
RE_CLASS = re.compile(r"^[A-Z][A-Za-z0-9]*$")
RE_CONST = re.compile(r"^[A-Z][A-Z0-9_]{2,}$")
RE_DEF = re.compile(r"^(?:class|def)\s+(\w+)", re.M)
RE_CLASS_DEF = re.compile(r"^class\s+(\w+)", re.M)
# invariant IDs: loose in prose ("I1", "L4", "M2", "H3"), marker-form in
# tests (trailing "# L4" comment or "Invariant L4" docstring opener) so
# test code mentioning e.g. an L2 norm can't inject phantom invariants
RE_DOC_INV = re.compile(r"\b([HILM]\d+)\b")
RE_TEST_INV = re.compile(r"(?:#\s*|Invariant\s+)([HILM]\d+)\b")
RE_TEST_REF = re.compile(r"\btests/test_\w+\.py")

BUILTINS = set(dir(builtins))


def _iter_source_files():
    for d in SRC_DIRS:
        base = ROOT / d
        if base.is_dir():
            yield from sorted(base.rglob("*.py"))


def build_index():
    """Map every top-level class name to its defining files, and collect
    all class/def names plus the full source text (for constants and
    dynamically-stamped attributes)."""
    class_files: dict[str, list[pathlib.Path]] = {}
    defined: set[str] = set()
    all_text: list[str] = []
    for py in _iter_source_files():
        text = py.read_text()
        all_text.append(text)
        defined.update(RE_DEF.findall(text))
        for name in RE_CLASS_DEF.findall(text):
            class_files.setdefault(name, []).append(py)
    return class_files, defined, "\n".join(all_text)


def resolve_path(ref: str) -> pathlib.Path | None:
    for base in ("", "src", "src/repro"):
        p = ROOT / base / ref
        if p.is_file():
            return p
    return None


def strip_fences(md: str) -> str:
    out, keep = [], True
    for line in md.splitlines():
        if RE_FENCE.match(line):
            keep = not keep
            continue
        if keep:
            out.append(line)
    return "\n".join(out)


def check_file(md_path, class_files, defined, source_text):
    errors = []
    text = strip_fences(md_path.read_text())
    for span in RE_SPAN.findall(text):
        tok = span.strip()
        call = RE_CALL.match(tok)
        if call and " " not in call.group(1):
            tok = call.group(1)
        if " " in tok or "=" in tok:
            continue

        ext = re.search(r"\.(md|py|json|txt|csv|yml|yaml|toml)(::|$)", tok)
        if ext and ext.group(1) != "py" and ext.group(1) != "md":
            continue                       # data files: not docs-gated
        if ext:
            ref, _, member = tok.partition("::")
            path = resolve_path(ref)
            if path is None:
                errors.append(f"{md_path.name}: dangling file `{tok}`")
            elif member and not re.search(
                    rf"\b{re.escape(member)}\b", path.read_text()):
                errors.append(
                    f"{md_path.name}: `{member}` not found in `{ref}`")
            continue

        if RE_DOTTED.match(tok):
            parts = tok.split(".")
            # the last component may be a module, a package, or a name
            # defined inside the parent module
            for cut in (len(parts), len(parts) - 1):
                ref = "/".join(parts[:cut])
                if resolve_path(ref + ".py") or resolve_path(
                        ref + "/__init__.py"):
                    break
            else:
                errors.append(f"{md_path.name}: dangling module `{tok}`")
                continue
            tail = parts[cut:]
            if tail and tail[0] not in defined:
                errors.append(f"{md_path.name}: dangling name `{tok}`")
            continue

        m = RE_CLASS_ATTR.match(tok)
        if m:
            cls, attr = m.groups()
            files = class_files.get(cls)
            if not files:
                errors.append(f"{md_path.name}: dangling class `{tok}`")
            elif not any(re.search(rf"\b{re.escape(attr)}\b",
                                   f.read_text()) for f in files) \
                    and not re.search(rf"\b{re.escape(attr)}\b", source_text):
                errors.append(f"{md_path.name}: dangling attr `{tok}`")
            continue

        if RE_CLASS.match(tok) and any(c.islower() for c in tok):
            if tok not in class_files and tok not in BUILTINS:
                errors.append(f"{md_path.name}: dangling class `{tok}`")
            continue

        if RE_CONST.match(tok):
            if not re.search(rf"\b{re.escape(tok)}\b", source_text):
                errors.append(f"{md_path.name}: dangling constant `{tok}`")
            continue
    errors.extend(check_invariants(md_path, text))
    return errors


def check_invariants(md_path, text):
    """Cross-check invariant IDs between a spec doc and the test files it
    references (both directions: documented-but-unasserted and
    asserted-but-undocumented are failures).  Only docs/ files own a
    spec; README cites invariants in passing and is skipped."""
    if (ROOT / "docs") not in md_path.parents:
        return []
    doc_ids = set(RE_DOC_INV.findall(text))
    if not doc_ids:
        return []
    test_ids: set[str] = set()
    refs = sorted(set(RE_TEST_REF.findall(text)))
    for ref in refs:
        path = resolve_path(ref)
        if path is not None:
            test_ids.update(RE_TEST_INV.findall(path.read_text()))
    if not test_ids:
        return [f"{md_path.name}: names invariants {sorted(doc_ids)} but "
                f"references no test file asserting any"]
    errors = []
    for i in sorted(doc_ids - test_ids):
        errors.append(f"{md_path.name}: invariant `{i}` documented but "
                      f"asserted in none of {refs}")
    for i in sorted(test_ids - doc_ids):
        errors.append(f"{md_path.name}: invariant `{i}` asserted in "
                      f"{refs} but missing from the doc")
    return errors


def check_serving_coverage(docs):
    """Every public class under src/repro/serving/ must be named in at
    least one checked doc's inline spans."""
    spans = []
    for md in docs:
        spans.extend(RE_SPAN.findall(strip_fences(md.read_text())))
    span_text = "\n".join(spans)
    errors = []
    for py in sorted((ROOT / "src" / "repro" / "serving").glob("*.py")):
        for cls in RE_CLASS_DEF.findall(py.read_text()):
            if cls.startswith("_"):
                continue
            if not re.search(rf"\b{re.escape(cls)}\b", span_text):
                errors.append(f"public serving class `{cls}` "
                              f"({py.relative_to(ROOT)}) appears in no "
                              f"checked doc")
    return errors


def main() -> int:
    docs = sorted((ROOT / "docs").glob("**/*.md"))
    readme = ROOT / "README.md"
    if readme.is_file():
        docs.append(readme)
    if not docs:
        print("check_docs_refs: no markdown files found", file=sys.stderr)
        return 1
    class_files, defined, source_text = build_index()
    errors = []
    n_spans = 0
    for md in docs:
        n_spans += len(RE_SPAN.findall(strip_fences(md.read_text())))
        errors.extend(check_file(md, class_files, defined, source_text))
    errors.extend(check_serving_coverage(docs))
    for e in errors:
        print(f"[fail] {e}")
    print(f"check_docs_refs: {len(docs)} files, {n_spans} code spans, "
          f"{len(errors)} dangling")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
