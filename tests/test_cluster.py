"""Clustered compression (§3.2 / App. A.3) tests."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (CompressionConfig, LoRABank, cluster_jd,
                        clustered_reconstruction_errors, compress_bank,
                        jd_full_eig, parameter_counts, reconstruction_errors)


def two_group_bank(key, per=6, r_l=2, d=24, noise=0.02):
    """Two well-separated low-rank families."""
    k1, k2, k3, k4, kn = jax.random.split(key, 5)
    A1 = jax.random.normal(k1, (1, r_l, d))
    B1 = jax.random.normal(k2, (1, d, r_l))
    A2 = jax.random.normal(k3, (1, r_l, d))
    B2 = jax.random.normal(k4, (1, d, r_l))
    A = jnp.concatenate([jnp.tile(A1, (per, 1, 1)), jnp.tile(A2, (per, 1, 1))])
    B = jnp.concatenate([jnp.tile(B1, (per, 1, 1)), jnp.tile(B2, (per, 1, 1))])
    A = A + noise * jax.random.normal(kn, A.shape)
    return A, B


def test_separable_clusters_recovered():
    A, B = two_group_bank(jax.random.PRNGKey(0))
    c = cluster_jd(A, B, rank=4, n_clusters=2, jd_iters=25, outer_iters=6)
    assign = np.asarray(c.assign)
    # both groups internally consistent
    assert len(set(assign[:6])) == 1 and len(set(assign[6:])) == 1
    assert assign[0] != assign[6]
    errs = clustered_reconstruction_errors(A, B, c)
    assert float(errs["loss"]) < 0.05


def test_clustering_beats_single_basis_at_same_rank():
    A, B = two_group_bank(jax.random.PRNGKey(1), noise=0.05)
    single = jd_full_eig(A, B, rank=3, iters=30)
    l1 = float(reconstruction_errors(A, B, single)["loss"])
    c = cluster_jd(A, B, rank=3, n_clusters=2, jd_iters=20)
    l2 = float(clustered_reconstruction_errors(A, B, c)["loss"])
    assert l2 < l1


def test_parameter_counts_formulas():
    """§3.2 / App. F accounting: clustered O(dkr + nr^2)."""
    pc = parameter_counts(d_out=4096, d_in=4096, n=1000, rank=16,
                          n_clusters=25, lora_rank=16)
    expected_comp = 25 * 16 * (4096 + 4096) + 1000 * (16 * 16) + 1000
    assert pc["compressed"] == expected_comp
    assert pc["uncompressed"] == 1000 * 16 * 8192
    assert 0.9 < pc["saved_ratio"] < 1.0


def test_compress_bank_clustered_path():
    A, B = two_group_bank(jax.random.PRNGKey(2))
    bank = LoRABank(A=A, B=B, ranks=jnp.full((12,), 2, jnp.int32))
    cm = compress_bank(bank, CompressionConfig(method="jd_full_eig", rank=4,
                                               n_clusters=2, iters=20))
    assert cm.clustered
    assert cm.metrics["loss"] < 0.1
