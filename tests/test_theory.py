"""Numeric verification of §4 (Prop. 1, Thm. 1 incl. the corrected lower
bound — see DESIGN.md §8 / theory.py for the Jensen-factor finding)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (see requirements.txt)")
from hypothesis import given, settings, strategies as st

from repro.core.jd import jd_full, normalize_bank, reconstruction_errors
from repro.core.theory import check_theorem1, corollary1_regime, tilde_r


def random_bank(seed, n=6, r_l=3, d=24):
    k = jax.random.PRNGKey(seed)
    ka, kb = jax.random.split(k)
    return (jax.random.normal(ka, (n, r_l, d)) * 0.3,
            jax.random.normal(kb, (n, d, r_l)) * 0.3)


def test_prop1_threshold():
    A, B = random_bank(0, n=3, r_l=2, d=20)
    tr = tilde_r(A, B)
    assert 2 <= tr <= 6
    res = jd_full(A, B, rank=tr, iters=40)
    assert float(reconstruction_errors(A, B, res)["loss"]) < 1e-5
    res_small = jd_full(A, B, rank=tr - 1, iters=40)
    assert float(reconstruction_errors(A, B, res_small)["loss"]) > 1e-6


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000), n=st.integers(2, 8),
       rank=st.integers(1, 8))
def test_thm1_bounds_hold(seed, n, rank):
    A, B = random_bank(seed, n=n)
    # the lower bound holds at the OPTIMUM; run the solver long enough and
    # allow a small optimization-gap tolerance (alternating descent can sit
    # fractionally below the optimum at low rank)
    res = jd_full(A, B, rank=min(rank, 20), iters=60)
    chk = check_theorem1(A, B, res, atol=2e-2)
    assert chk["upper_ok"], chk
    assert chk["lower_ok"], chk      # corrected (1/n) lower bound


def test_thm1_literal_lower_bound_fails_on_duplicates():
    """Reproduction finding: the paper's as-stated lower bound misapplies
    Jensen; identical adapters give a counterexample."""
    A, B = random_bank(1, n=1)
    A = jnp.tile(A, (6, 1, 1))
    B = jnp.tile(B, (6, 1, 1))
    res = jd_full(A, B, rank=2, iters=25)
    chk = check_theorem1(A, B, res)
    assert chk["upper_ok"] and chk["lower_ok"]
    assert not chk["lower_literal_ok"], chk


def test_cor1_orthogonal_unit_norm_regime():
    """Orthogonal unit-norm LoRAs: kept energy in [1, min(r^2, n)]."""
    d, n = 24, 6
    # construct exactly orthogonal rank-1 deltas via disjoint rows
    As, Bs = [], []
    for i in range(n):
        a = jnp.zeros((1, d)).at[0, i].set(1.0)
        b = jnp.zeros((d, 1)).at[i + n, 0].set(1.0)
        As.append(a)
        Bs.append(b)
    A, B = jnp.stack(As), jnp.stack(Bs)
    reg = corollary1_regime(A, B)
    assert reg["max_off_diag"] < 1e-6
    np.testing.assert_allclose(reg["norms"], 1.0, rtol=1e-5)
    r = 2
    res = jd_full(A, B, rank=r, iters=30)
    kept = float(jnp.sum(res.sigma_full() ** 2))
    assert 1.0 - 1e-3 <= kept <= min(r * r, n) + 1e-3


def test_random_vs_structured_reconstruction():
    """App. H.11: collections with shared structure compress better than
    random ones at the same rank."""
    key = jax.random.PRNGKey(4)
    ka, kb, kc = jax.random.split(key, 3)
    n, r_l, d = 10, 3, 30
    A_rand = jax.random.normal(ka, (n, r_l, d))
    B_rand = jax.random.normal(kb, (n, d, r_l))
    # structured: all share a common subspace + small noise
    A0 = jax.random.normal(kc, (r_l, d))
    A_str = A0[None] + 0.1 * jax.random.normal(ka, (n, r_l, d))
    B_str = B_rand
    A_rand, B_rand, _ = normalize_bank(A_rand, B_rand)
    A_str, B_str, _ = normalize_bank(A_str, B_str)
    l_rand = float(reconstruction_errors(
        A_rand, B_rand, jd_full(A_rand, B_rand, 6, iters=12))["loss"])
    l_str = float(reconstruction_errors(
        A_str, B_str, jd_full(A_str, B_str, 6, iters=12))["loss"])
    assert l_str < l_rand
