"""Unified paging (PR 6): PagedPool invariants, pooled engine behavior, the
Zipf skew-shift acceptance comparison, the autoscaler page signal, and the
gathered-page Pallas decode kernel vs the contiguous oracle.

The allocator invariants asserted here (I1-I5) are the ones documented in
docs/architecture.md — keep the two in sync.
"""
import numpy as np
import pytest

from repro.serving.adapter_cache import AdapterCache, CacheConfig
from repro.serving.autoscaler import JointAutoscaler, JointAutoscalerConfig, SLOConfig
from repro.serving.engine import (
    CostModelExecutor,
    EngineConfig,
    ModelFootprint,
    ServingEngine,
    ServingHardware,
)
from repro.serving.request import Request
from repro.serving.resources import (
    PAGE_TOKENS,
    BudgetConfig,
    HardwareBudget,
    PagedPool,
    PagedPoolConfig,
)
from repro.serving.scheduler import SchedulerConfig


def make_pool(total_pages=16, page_bytes=100, adapter_share=None):
    return PagedPool(PagedPoolConfig(total_bytes=float(total_pages * page_bytes),
                                     page_bytes=page_bytes,
                                     adapter_share=adapter_share))


def conserved(pool):
    """Invariant I1: free + sum(used) == total after every operation."""
    return pool.free_pages + sum(pool.used.values()) == pool.total_pages


# ---------------------------------------------------------------------------
# allocator invariants
# ---------------------------------------------------------------------------


class TestPagedPool:
    def test_conservation_through_alloc_free(self):            # I1
        pool = make_pool(16)
        pool.alloc("kv", 5)
        assert conserved(pool)
        pool.alloc("adapter", 4)
        pool.alloc("pinned", 2)
        assert conserved(pool) and pool.free_pages == 5
        pool.free("kv", 3)
        pool.free("adapter", 4)
        assert conserved(pool) and pool.free_pages == 12

    def test_free_underflow_raises(self):                      # I2
        pool = make_pool(8)
        pool.alloc("kv", 2)
        with pytest.raises(ValueError):
            pool.free("kv", 3)
        with pytest.raises(ValueError):
            pool.free("adapter", 1)
        assert conserved(pool)

    def test_no_overcommit(self):                              # I3
        pool = make_pool(8)
        pool.alloc("kv", 8)
        assert not pool.can_alloc("adapter", 1)
        assert not pool.try_alloc("kv", 1)
        with pytest.raises(MemoryError):
            pool.alloc("adapter", 1)
        assert pool.free_pages == 0 and conserved(pool)

    def test_unknown_kind_rejected(self):
        pool = make_pool(8)
        with pytest.raises(ValueError):
            pool.alloc("weights", 1)

    def test_pages_for_rounds_up(self):
        pool = make_pool(8, page_bytes=100)
        assert pool.pages_for(0) == 0
        assert pool.pages_for(1) == 1
        assert pool.pages_for(100) == 1
        assert pool.pages_for(101) == 2

    def test_reclaim_takes_only_adapter_pages(self):           # I4
        pool = make_pool(10)
        pool.alloc("kv", 3)
        pool.alloc("pinned", 3)
        pool.alloc("adapter", 4)
        calls = []

        def reclaimer(n):
            calls.append(n)
            pool.free("adapter", n)
            return n

        pool.set_reclaimer(reclaimer)
        assert pool.alloc_with_reclaim("kv", 2)
        assert calls == [2]
        assert pool.used["pinned"] == 3 and pool.used["kv"] == 5
        assert pool.n_reclaims == 1 and pool.pages_reclaimed == 2
        assert conserved(pool)
        # adapter shortfall never triggers the reclaimer (it IS the evictor)
        assert not pool.alloc_with_reclaim("adapter", 10)
        assert len(calls) == 1

    def test_reclaim_shortfall_larger_than_adapters_fails_clean(self):
        pool = make_pool(10)
        pool.alloc("kv", 7)
        pool.alloc("adapter", 1)
        pool.set_reclaimer(lambda n: (pool.free("adapter", 1), 1)[1])
        # needs 4, free 2, only 1 adapter page exists -> infeasible, no call
        assert not pool.alloc_with_reclaim("kv", 4)
        assert pool.used["adapter"] == 1 and conserved(pool)

    def test_no_fragmentation_after_churn(self):               # I5
        rng = np.random.default_rng(7)
        pool = make_pool(64)
        held = {"kv": [], "adapter": []}
        for _ in range(500):
            kind = ("kv", "adapter")[rng.integers(2)]
            if rng.random() < 0.55:
                n = int(rng.integers(1, 9))
                if pool.try_alloc(kind, n):
                    held[kind].append(n)
            elif held[kind]:
                pool.free(kind, held[kind].pop(rng.integers(len(held[kind]))))
            assert conserved(pool)
        # pages are fungible: ANY request within the free count succeeds
        if pool.free_pages > 0:
            assert pool.try_alloc("kv", pool.free_pages)
        assert pool.free_pages == 0 and conserved(pool)

    def test_static_split_caps_both_sides(self):
        pool = make_pool(20, adapter_share=0.4)
        assert pool.adapter_cap == 8 and pool.kv_cap == 12
        assert not pool.can_alloc("adapter", 9)
        pool.alloc("adapter", 8)
        assert not pool.can_alloc("adapter", 1)
        pool.alloc("kv", 12)
        # free pages exist on neither side's ledger: the split wastes them
        assert pool.free_pages == 0
        # unified has no such caps
        uni = make_pool(20)
        assert uni.adapter_cap == uni.kv_cap == 20
        uni.alloc("adapter", 15)
        assert uni.can_alloc("kv", 5)

    def test_feasible_accounts_eviction_and_caps(self):
        pool = make_pool(10)
        pool.alloc("kv", 4)
        pool.alloc("adapter", 4)
        assert pool.feasible(2, 0, 0)
        assert not pool.feasible(3, 0, 0)
        assert pool.feasible(3, 0, 1)          # evicting 1 adapter page funds it
        assert pool.feasible(6, 0, 4)
        assert not pool.feasible(7, 0, 4)
        split = make_pool(10, adapter_share=0.5)
        split.alloc("kv", 5)
        # kv side capped: free pages exist but belong to the adapter side
        assert not split.feasible(1, 0, 0)
        assert split.feasible(0, 5, 0)

    def test_config_validation(self):
        with pytest.raises(ValueError):
            PagedPoolConfig(total_bytes=0, page_bytes=10)
        with pytest.raises(ValueError):
            PagedPoolConfig(total_bytes=100.0, page_bytes=10, adapter_share=1.0)
        with pytest.raises(ValueError):
            PagedPoolConfig(total_bytes=5.0, page_bytes=10)  # < one page


# ---------------------------------------------------------------------------
# pooled adapter cache
# ---------------------------------------------------------------------------


def make_cache(pool, dma_bw=1e12):
    cfg = CacheConfig(capacity_bytes=0.0)      # ignored in pooled mode
    cfg.dma.bandwidth = dma_bw
    return AdapterCache(cfg, pool=pool)


class TestPooledAdapterCache:
    def test_ensure_allocates_and_evicts_pages(self):
        pool = make_pool(4, page_bytes=100)
        cache = make_cache(pool)
        cache.ensure(1, 200, 0.0)              # 2 pages
        cache.ensure(2, 200, 0.0)              # 2 pages: pool full
        assert pool.used["adapter"] == 4
        cache.ensure(3, 200, 0.0)              # evicts LRU (adapter 1)
        assert pool.used["adapter"] == 4 and conserved(pool)
        assert cache.resident_ids == {2, 3}

    def test_ensure_never_evicts_protected(self):
        pool = make_pool(4, page_bytes=100)
        cache = make_cache(pool)
        cache.ensure(1, 200, 0.0)
        cache.ensure(2, 200, 0.0)
        with pytest.raises(MemoryError):
            cache.ensure(3, 200, 0.0, protected={1, 2, 3})
        assert cache.resident_ids == {1, 2}

    def test_pin_shared_takes_pinned_pages(self):
        pool = make_pool(4, page_bytes=100)
        cache = make_cache(pool)
        cache.pin_shared(250)                  # 3 pages
        assert pool.used["pinned"] == 3
        with pytest.raises(MemoryError):
            cache.pin_shared(200)

    def test_prefetch_only_fills_free_pages(self):
        pool = make_pool(4, page_bytes=100)
        cache = make_cache(pool)
        cache.ensure(1, 300, 0.0)              # 3 pages
        cache.prefetch(2, 200, 0.0)            # needs 2, 1 free: dropped
        assert not cache.is_resident(2) and pool.used["adapter"] == 3
        cache.prefetch(3, 100, 0.0)
        assert cache.is_resident(3) and pool.used["adapter"] == 4

    def test_reclaim_prefers_prefetched_unused_then_lru(self):
        pool = make_pool(8, page_bytes=100)
        cache = make_cache(pool)
        cache.ensure(1, 200, 0.0)              # LRU-coldest demand entry
        cache.ensure(2, 200, 0.0)
        cache.prefetch(3, 200, 1.0)            # speculative, never used
        assert pool.used["adapter"] == 6
        freed = cache.reclaim(2, protected=set())
        # the prefetched-but-unused adapter goes first, NOT the LRU demand one
        assert freed == 2
        assert not cache.is_resident(3)
        assert cache.resident_ids == {1, 2}
        # next round falls back to true LRU
        assert cache.reclaim(2, protected=set()) == 2
        assert cache.resident_ids == {2}

    def test_reclaim_respects_protected(self):
        pool = make_pool(8, page_bytes=100)
        cache = make_cache(pool)
        cache.ensure(1, 200, 0.0)
        cache.ensure(2, 200, 0.0)
        assert cache.evictable_pages(protected={1}) == 2
        assert cache.reclaim(8, protected={1}) == 2
        assert cache.resident_ids == {1}


# ---------------------------------------------------------------------------
# pooled engine
# ---------------------------------------------------------------------------


def make_fp(kv_bytes_per_token=1024, adapter_bytes=None):
    page = kv_bytes_per_token * PAGE_TOKENS
    return ModelFootprint(
        n_active_params=int(1e8), weight_bytes=int(1e9),
        lora_bytes_per_adapter=(2 * page if adapter_bytes is None
                                else adapter_bytes),
        jd_shared_bytes_per_cluster=page, jd_sigma_bytes_per_adapter=64,
        kv_bytes_per_token=kv_bytes_per_token)


def make_engine(fp, total_pages, max_batch=8, adapter_share=None,
                n_adapters=32, prefetch=False):
    page_bytes = fp.kv_bytes_per_token * PAGE_TOKENS
    pool_cfg = fp.pool_config(float(total_pages * page_bytes),
                              adapter_share=adapter_share)
    ex = CostModelExecutor(ServingHardware(), fp, "lora")
    return ServingEngine(
        EngineConfig(scheduler=SchedulerConfig(max_batch=max_batch),
                     prefetch=prefetch, pool=pool_cfg), ex)


def make_requests(adapter_seq, prompt_len=PAGE_TOKENS,
                  max_new_tokens=PAGE_TOKENS, dt=1e-3):
    return [Request(rid=i, adapter_id=a, prompt_len=prompt_len,
                    max_new_tokens=max_new_tokens, arrival_time=i * dt)
            for i, a in enumerate(adapter_seq)]


class TestPooledEngine:
    def test_pool_config_page_size(self):
        fp = make_fp(kv_bytes_per_token=512)
        cfg = fp.pool_config(1e9)
        assert cfg.page_bytes == 512 * PAGE_TOKENS

    def test_pool_requires_kv_footprint(self):
        fp = make_fp()
        bad = ModelFootprint(n_active_params=1, weight_bytes=1,
                             lora_bytes_per_adapter=1,
                             jd_shared_bytes_per_cluster=1,
                             jd_sigma_bytes_per_adapter=1)
        with pytest.raises(ValueError):
            bad.pool_config(1e9)
        ex = CostModelExecutor(ServingHardware(), bad, "lora")
        with pytest.raises(ValueError):
            ServingEngine(EngineConfig(pool=fp.pool_config(1e9)), ex)

    def test_all_kv_pages_released_at_drain(self):
        fp = make_fp()
        eng = make_engine(fp, total_pages=40)
        eng.submit(make_requests([i % 5 for i in range(30)]))
        stats = eng.run()
        assert stats.n_requests == 30
        assert eng.pool.used["kv"] == 0
        assert conserved(eng.pool)
        assert stats.peak_kv_pages > 0

    def test_exhaustion_under_mixed_pressure_serializes(self):
        # pool fits ONE request's worst-case KV (2 pages) + its adapter
        # (2 pages): admissions must serialize instead of deadlocking
        fp = make_fp()
        eng = make_engine(fp, total_pages=4, max_batch=8)
        eng.submit(make_requests([0, 1, 2, 3]))
        stats = eng.run()
        assert stats.n_requests == 4
        assert stats.peak_batch == 1           # pages, not slots, bound it
        assert stats.n_page_blocked > 0
        assert eng.pool.used["kv"] == 0 and conserved(eng.pool)

    def test_too_small_pool_raises_not_livelocks(self):
        fp = make_fp()
        eng = make_engine(fp, total_pages=2)   # KV alone needs 2, adapter 2
        eng.submit(make_requests([0]))
        with pytest.raises(MemoryError):
            eng.run()

    def test_kv_pressure_evicts_prefetched_unused_adapter(self):
        # one running adapter + a prefetched-but-unused one; the next
        # admission's KV reservation must evict the speculative bytes
        fp = make_fp()
        eng = make_engine(fp, total_pages=10, max_batch=2, prefetch=True)
        # 2 kv + 2 adapter per request; adapter 9's prefetch fills 2 more
        eng.submit(make_requests([0, 9, 0, 0], dt=1e-4))
        stats = eng.run()
        assert stats.n_requests == 4
        assert stats.pages_reclaimed > 0 or stats.n_page_blocked == 0

    def test_adapter_eviction_funds_decode_pages(self):
        # phase 1 warms six adapters with tiny (1-KV-page) requests so 12 of
        # 16 pages hold adapter weights; phase 2 is KV-heavy on ONE adapter —
        # its reservations must reclaim the cold adapters' pages
        fp = make_fp()
        eng = make_engine(fp, total_pages=16, max_batch=4)
        warm = make_requests([0, 1, 2, 3, 4, 5], prompt_len=32,
                             max_new_tokens=32)
        heavy = [Request(rid=100 + i, adapter_id=0,
                         prompt_len=2 * PAGE_TOKENS,
                         max_new_tokens=2 * PAGE_TOKENS,
                         arrival_time=1.0 + i * 1e-4) for i in range(8)]
        eng.submit(warm + heavy)
        stats = eng.run()
        assert stats.n_requests == len(warm) + len(heavy)
        assert stats.peak_resident_adapters == 6    # warm set all resident
        assert stats.n_page_reclaims > 0            # KV pressure evicted it
        assert stats.pages_reclaimed > 0
        assert eng.pool.used["kv"] == 0 and conserved(eng.pool)

    def test_static_split_is_degenerate_configuration(self):
        fp = make_fp()
        eng = make_engine(fp, total_pages=16, adapter_share=0.5)
        eng.submit(make_requests([i % 8 for i in range(24)]))
        stats = eng.run()
        assert stats.n_requests == 24
        # the adapter side can never exceed its carve-out
        assert stats.peak_adapter_pages <= eng.pool.adapter_cap


# ---------------------------------------------------------------------------
# acceptance: Zipf(1.0) skew shift — unified beats the static split
# ---------------------------------------------------------------------------


def zipf_requests(n_requests, n_adapters, seed, rank_perm=None, t0=0.0,
                  alpha=1.0, dt=2e-4):
    """Zipf(alpha)-popular adapter draws; `rank_perm` remaps which adapter
    holds which popularity rank (the skew SHIFT between phases)."""
    rng = np.random.default_rng(seed)
    p = 1.0 / np.arange(1, n_adapters + 1) ** alpha
    p /= p.sum()
    ranks = rng.choice(n_adapters, size=n_requests, p=p)
    perm = np.arange(n_adapters) if rank_perm is None else rank_perm
    return [Request(rid=i, adapter_id=int(perm[r]), prompt_len=PAGE_TOKENS,
                    max_new_tokens=PAGE_TOKENS, arrival_time=t0 + i * dt)
            for i, r in enumerate(ranks)]


class TestSkewShiftAcceptance:
    def run_cell(self, adapter_share):
        fp = make_fp()
        eng = make_engine(fp, total_pages=64, max_batch=8,
                          adapter_share=adapter_share, n_adapters=32)
        n, n_adapters = 150, 32
        phase1 = zipf_requests(n, n_adapters, seed=0)
        perm = np.random.default_rng(1).permutation(n_adapters)
        phase2 = zipf_requests(n, n_adapters, seed=2, rank_perm=perm,
                               t0=phase1[-1].arrival_time + 1e-3)
        for i, r in enumerate(phase2):
            r.rid = n + i
        eng.submit(phase1 + phase2)
        return eng.run()

    def test_unified_serves_more_resident_adapters_at_equal_slots(self):
        unified = self.run_cell(adapter_share=None)
        split = self.run_cell(adapter_share=0.25)
        # same fixed HBM budget, same decode-slot count actually used...
        assert unified.n_requests == split.n_requests == 300
        assert unified.peak_batch >= split.peak_batch
        # ...and the unified pool kept STRICTLY more adapters cache-resident
        # (the static split's adapter carve-out caps its working set)
        assert unified.peak_resident_adapters > split.peak_resident_adapters
        # because idle decode headroom was lent to the adapter side
        assert unified.peak_adapter_pages > split.peak_adapter_pages
        # and it never pays MORE adapter reloads than the split
        assert unified.n_swaps <= split.n_swaps


# ---------------------------------------------------------------------------
# autoscaler page signal
# ---------------------------------------------------------------------------


class TestAutoscalerPageSignal:
    def make_scaler(self, total=8):
        return JointAutoscaler(
            JointAutoscalerConfig(cooldown_intervals=0),
            SLOConfig(ttft_p95=0.5),
            HardwareBudget(BudgetConfig(total_accelerators=total)))

    def comfortable(self):
        """Latency samples far below every SLO share."""
        return dict(ttfts=[0.01] * 8, tpots=[0.001] * 8,
                    decode_waits=[0.01] * 8, prefill_lags=[0.01] * 8,
                    prefill_backlog=0, decode_backlog=0)

    def test_page_saturation_scales_decode_up(self):
        sc = self.make_scaler()
        d_pre, d_dec = sc.decide(1.0, n_prefill=1, n_decode=1,
                                 kv_page_util=0.95, **self.comfortable())
        assert (d_pre, d_dec) == (0, 1)
        assert sc.history[-1].kv_page_util == 0.95

    def test_page_saturation_vetoes_decode_cold(self):
        sc = self.make_scaler()
        d_pre, d_dec = sc.decide(1.0, n_prefill=1, n_decode=3,
                                 kv_page_util=0.95, **self.comfortable())
        assert d_dec >= 0                      # never retires a full pool

    def test_low_page_util_keeps_legacy_behavior(self):
        sc = self.make_scaler()
        d_pre, d_dec = sc.decide(1.0, n_prefill=1, n_decode=3,
                                 kv_page_util=0.2, **self.comfortable())
        assert d_dec == -1                     # comfortable tier still shrinks


# ---------------------------------------------------------------------------
# gathered-page kernel vs contiguous oracle
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed,B,Kv,G,hd,n_blocks", [
    (0, 2, 2, 2, 64, 2),
    (1, 3, 4, 2, 64, 4),
    (2, 1, 1, 8, 128, 3),
])
def test_paged_decode_bit_exact_with_contiguous(seed, B, Kv, G, hd, n_blocks):
    import jax.numpy as jnp

    from repro.kernels.flash_decode import flash_decode, flash_decode_paged
    from repro.kernels.ref import flash_decode_paged_ref, gather_pages_ref

    page_t = 128
    rng = np.random.default_rng(seed)
    P = B * n_blocks + 3                       # pool larger than needed
    H = Kv * G
    q = jnp.asarray(rng.normal(size=(B, H, hd)), jnp.float32)
    kp = jnp.asarray(rng.normal(size=(P, page_t, Kv, hd)), jnp.float32)
    vp = jnp.asarray(rng.normal(size=(P, page_t, Kv, hd)), jnp.float32)
    # permuted page table: physically scattered, logically contiguous
    pt = jnp.asarray(rng.permutation(P)[:B * n_blocks].reshape(B, n_blocks),
                     jnp.int32)
    kv_len = jnp.asarray(
        rng.integers(page_t, n_blocks * page_t, size=(B,)), jnp.int32)

    out_p, l_p, m_p = flash_decode_paged(q, kp, vp, pt, kv_len)
    k = gather_pages_ref(kp, pt)
    v = gather_pages_ref(vp, pt)
    out_c, l_c, m_c = flash_decode(q, k, v, kv_len, block_s=page_t)
    # bit-exact: the paged path runs the SAME kernel body over the same
    # logical blocks — only the BlockSpec addressing differs
    assert np.array_equal(np.asarray(out_p), np.asarray(out_c))
    assert np.array_equal(np.asarray(l_p), np.asarray(l_c))
    assert np.array_equal(np.asarray(m_p), np.asarray(m_c))
    ref = flash_decode_paged_ref(q, kp, vp, pt, kv_len)
    np.testing.assert_allclose(np.asarray(out_p), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_page_tokens_matches_quant_block():
    """The sim's page granularity IS the quant kernels' block granularity
    (one page = one wire block); the constant is duplicated because the
    serving sim must import without jax."""
    from repro.kernels import kv_quant

    assert PAGE_TOKENS == kv_quant.BLOCK_T
