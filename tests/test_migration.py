"""Mid-stream live migration (PR 9): checkpoint/restore, wire accounting,
preemption policies, and the unified study driver.

The migration invariants asserted here (M1-M5) are the ones documented in
docs/architecture.md — keep the two in sync:

  M1 — token-exact resume (cost model AND real executor);
  M2 — no double-charged wire bytes (handoff and migration accounted
       separately, each exactly once);
  M3 — KV pages freed on the source at checkpoint time;
  M4 — prefetch hints deduped on the target;
  M5 — preemption never starves the victim (move cap + all finish).
"""
import dataclasses as dc

import numpy as np
import pytest

from repro.serving.adapter_cache import AdapterCache, CacheConfig, DMAModel
from repro.serving.engine import (CostModelExecutor, EngineConfig,
                                  ModelFootprint, ServingEngine,
                                  ServingHardware)
from repro.serving.lifecycle import LifecycleEvent
from repro.serving.migration import MigrationConfig, MigrationPolicy
from repro.serving.request import Request
from repro.serving.resources import PAGE_TOKENS, FabricConfig
from repro.serving.router import Fleet, FleetConfig
from repro.serving.scheduler import Scheduler, SchedulerConfig
from repro.serving.simulator import StudyEvent, run_study


def make_fp(kv_bytes_per_token=1024):
    page = kv_bytes_per_token * PAGE_TOKENS
    return ModelFootprint(
        n_active_params=int(1e8), weight_bytes=int(1e9),
        lora_bytes_per_adapter=2 * page,
        jd_shared_bytes_per_cluster=page, jd_sigma_bytes_per_adapter=64,
        kv_bytes_per_token=kv_bytes_per_token)


def _engine(fp=None, max_batch=8, total_pages=None, kv_reserve="worst_case",
            max_preemptions=3):
    fp = fp or make_fp()
    ex = CostModelExecutor(ServingHardware(), fp, "lora")
    pool = None
    if total_pages is not None:
        page_bytes = fp.kv_bytes_per_token * PAGE_TOKENS
        pool = fp.pool_config(float(total_pages * page_bytes))
    eng = ServingEngine(
        EngineConfig(scheduler=SchedulerConfig(max_batch=max_batch),
                     adapter_budget_bytes=1e9, pool=pool,
                     kv_reserve=kv_reserve, max_preemptions=max_preemptions),
        ex)
    return eng


def _fleet(n=2, policy="round_robin", fabric=None, **eng_kw):
    cfg = FleetConfig(n_replicas=n, policy=policy, migration_fabric=fabric)
    return Fleet(cfg, [_engine(**eng_kw) for _ in range(n)])


def _req(rid=0, adapter=0, prompt=PAGE_TOKENS, new_tokens=8, t=0.0,
         priority=0):
    return Request(rid=rid, adapter_id=adapter, prompt_len=prompt,
                   max_new_tokens=new_tokens, arrival_time=t,
                   priority=priority)


# ---------------------------------------------------------------------------
# scheduler: priority admission and victim selection
# ---------------------------------------------------------------------------


def test_priority_admission_first():
    sched = Scheduler(SchedulerConfig(max_batch=1))
    lo = _req(rid=0, t=0.0, priority=0)
    hi = _req(rid=1, t=0.0, priority=5)
    admitted = sched.admit([], [lo, hi], set(), now=0.0)
    assert admitted == [hi]


def test_pick_victim_lowest_priority_smallest_kv():
    a = _req(rid=0, priority=0, prompt=256)
    b = _req(rid=1, priority=0, prompt=128)
    c = _req(rid=2, priority=3, prompt=64)
    assert Scheduler.pick_victim([a, b, c]) is b      # low prio, small KV
    assert Scheduler.pick_victim([a, b, c], protect=(1,)) is a
    assert Scheduler.pick_victim([a, b, c], below_priority=1) is b
    assert Scheduler.pick_victim([c], below_priority=3) is None


def test_pick_victim_move_cap():                                   # M5
    """A request at the move cap is no longer an eligible victim."""
    bounced = _req(rid=0, priority=0)
    bounced.migrations, bounced.preemptions = 2, 1
    fresh = _req(rid=1, priority=0, prompt=4 * PAGE_TOKENS)
    assert Scheduler.pick_victim([bounced, fresh], max_moves=3) is fresh
    assert Scheduler.pick_victim([bounced], max_moves=3) is None


# ---------------------------------------------------------------------------
# checkpoint / restore
# ---------------------------------------------------------------------------


def test_checkpoint_frees_source_pages_immediately():              # M3
    """Pages are back in the source pool at checkpoint time, before the
    checkpoint lands anywhere."""
    eng = _engine(total_pages=64)
    req = _req(new_tokens=4)
    eng.submit([req])
    eng.step()
    assert req in eng.running and eng.pool.used["kv"] > 0
    held = eng._kv_held[req.rid]
    free_before = eng.pool.free_pages
    nbytes = eng.checkpoint(req)
    assert req not in eng.running
    assert req.rid not in eng._kv_held
    assert eng.pool.free_pages == free_before + held
    assert eng.pool.free_pages + sum(eng.pool.used.values()) \
        == eng.pool.total_pages
    # the full decoded prefix must move: prompt plus generated tokens
    fp = eng.executor.fp
    assert nbytes == (req.prompt_len + req.generated) * fp.kv_bytes_per_token


def test_checkpoint_unrouted_request_raises():
    eng = _engine()
    with pytest.raises(ValueError):
        eng.checkpoint(_req())


def test_zero_kv_checkpoint_for_unprefilled_waiting():
    eng = _engine(max_batch=1)
    first, queued = _req(rid=0), _req(rid=1)
    eng.submit([first, queued])
    eng.step()
    assert queued in eng.waiting
    assert eng.checkpoint(queued) == 0


# ---------------------------------------------------------------------------
# fleet.migrate: token-exact resume + wire accounting
# ---------------------------------------------------------------------------


def _run_until_generated(fleet, req, g):
    eng = fleet.engines[fleet.assignments[req.rid]]
    while req.generated < g:
        assert eng.step()
    return eng


def test_migrate_token_exact_resume():                             # M1
    """A migrated request resumes at the same `generated` position and
    finishes with exactly the same number of output tokens as an
    unmigrated control run."""
    control = _fleet(n=2)
    creq = _req(new_tokens=8)
    control.submit([creq])
    control.run()

    fleet = _fleet(n=2)
    req = _req(new_tokens=8)
    fleet.submit([req])
    eng = _run_until_generated(fleet, req, 3)
    g0 = req.generated
    resume = fleet.migrate(req, 1, now=eng.clock)
    assert req.generated == g0                  # never reset by the move
    assert req.replica == 1 and req.migrated_from == 0
    assert req.migrations == 1
    assert resume >= eng.clock                  # wire time is not free
    fleet.run()
    assert req.generated == req.max_new_tokens == creq.generated
    assert fleet.engines[1].stats.n_requests == 1
    assert fleet.engines[0].stats.n_migrated_out == 1
    assert fleet.engines[1].stats.n_migrated_in == 1


def test_migrate_wire_bytes_charged_once():                        # M2
    """Migration wire traffic is accounted on the migration ticket and
    the request's cumulative `mig_*` counters — the prefill-handoff
    fields stay untouched, and fabric totals equal the sum of the two
    accounting streams (each byte charged exactly once)."""
    fleet = _fleet(n=2, fabric=FabricConfig())
    req = _req(new_tokens=8)
    fleet.submit([req])
    eng = _run_until_generated(fleet, req, 3)
    fleet.migrate(req, 1, now=eng.clock)
    fp = fleet.engines[0].executor.fp
    expect = (req.prompt_len + req.generated) * fp.kv_bytes_per_token
    assert req.mig_raw_bytes == expect
    assert req.mig_wire_bytes > 0
    # handoff fields unclobbered: this was a colocated request, so the
    # prefill-handoff stream carried nothing
    assert req.kv_raw_bytes == 0 and req.kv_wire_bytes == 0
    m = fleet.migration
    assert m.n_migrations == 1
    assert m.kv_raw_bytes == req.mig_raw_bytes
    assert m.kv_wire_bytes == req.mig_wire_bytes
    fab = fleet.migration_fabric()
    assert sum(fab.stats.wire_bytes_by_mode.values()) == m.kv_wire_bytes
    fleet.run()
    # a second migration accumulates rather than overwrites
    raw1 = req.mig_raw_bytes
    req2 = _req(rid=1, new_tokens=8)
    fleet.submit([req2])
    assert req.mig_raw_bytes == raw1


def test_migrate_rejects_bad_targets():
    fleet = _fleet(n=3)
    req = _req(new_tokens=4)
    fleet.submit([req])
    src = fleet.assignments[req.rid]
    with pytest.raises(ValueError):
        fleet.migrate(req, src, now=0.0)
    fleet.retire_replica(2)
    if src != 2:
        with pytest.raises(ValueError):
            fleet.migrate(req, 2, now=0.0)
    with pytest.raises(ValueError):
        fleet.migrate(_req(rid=99), 1 - src, now=0.0)


def test_migration_prefetch_dedupe():                              # M4
    """The target's adapter-cache hint never double-loads: resident or
    in-flight adapters absorb the hint."""
    fleet = _fleet(n=2)
    r0, r1 = _req(rid=0, adapter=7, new_tokens=8), \
        _req(rid=1, adapter=7, new_tokens=8, t=1e-4)
    fleet.submit([r0, r1])      # round robin: r0 -> replica 0, r1 -> 1
    eng0 = _run_until_generated(fleet, r0, 2)
    dst = fleet.engines[1]
    # adapter 7 already resident on the target (r1 decoded there):
    # the migration hint must be a no-op
    _run_until_generated(fleet, r1, 1)
    assert dst.cache.is_resident(7)
    n0 = dst.cache.n_prefetches
    fleet.migrate(r0, 1, now=max(eng0.clock, dst.clock))
    assert dst.cache.n_prefetches == n0
    fleet.run()


def test_migration_prefetch_issued_when_cold():                    # M4
    fleet = _fleet(n=2)
    r0 = _req(rid=0, adapter=7, new_tokens=8)
    fleet.submit([r0])
    eng0 = _run_until_generated(fleet, r0, 2)
    dst = fleet.engines[1]
    assert not dst.cache.is_resident(7)
    fleet.migrate(r0, 1, now=eng0.clock)
    assert dst.cache.n_prefetches == 1
    fleet.run()
    assert r0.generated == r0.max_new_tokens


# ---------------------------------------------------------------------------
# instant scale-down
# ---------------------------------------------------------------------------


def test_retire_migrate_empties_source_immediately():
    """Instant scale-down: the retired replica holds nothing the moment
    retire returns — its budget slice is free now, not after a drain."""
    fleet = _fleet(n=2)
    reqs = [_req(rid=i, new_tokens=8) for i in range(4)]
    fleet.submit(reqs)
    src = fleet.engines[0]
    while not src.running:
        src.step()
    t = max(e.clock for e in fleet.engines)
    n_on_src = len(src.running) + len(src.waiting)
    assert n_on_src > 0
    fleet.retire_replica(0, migrate=True, now=t)
    assert not src.running and not src.waiting
    assert fleet.migration.n_retire_migrations == n_on_src
    fleet.run()
    assert all(r.generated == r.max_new_tokens for r in reqs)


def test_retire_drain_keeps_source_busy():
    """Control for the above: drain-based retirement leaves the queue on
    the retired replica (the legacy, bit-exact default)."""
    fleet = _fleet(n=2)
    reqs = [_req(rid=i, new_tokens=8) for i in range(4)]
    fleet.submit(reqs)
    src = fleet.engines[0]
    while not src.running:
        src.step()
    held = len(src.running) + len(src.waiting)
    fleet.retire_replica(0)
    assert len(src.running) + len(src.waiting) == held
    assert fleet.migration.empty
    fleet.run()


# ---------------------------------------------------------------------------
# on-demand KV growth + preemption
# ---------------------------------------------------------------------------


def test_on_demand_reserves_fewer_pages_at_admission():
    """The mid-decode-growth bugfix: admission reserves pages for the
    prompt plus ONE token instead of the worst-case max_new_tokens."""
    worst = _engine(total_pages=64)
    grow = _engine(total_pages=64, kv_reserve="on_demand")
    for eng in (worst, grow):
        eng.submit([_req(new_tokens=4 * PAGE_TOKENS)])
        eng.step()
    assert grow._kv_held[0] < worst._kv_held[0]
    fp = grow.executor.fp
    bpt = fp.kv_bytes_per_token
    assert worst._kv_held[0] == worst.pool.pages_for(
        (PAGE_TOKENS + 4 * PAGE_TOKENS) * bpt)
    # prompt + generated + 1 grows with the decode
    g = grow.pool.pages_for((PAGE_TOKENS + 1) * bpt)
    assert grow._kv_held[0] >= g


def test_on_demand_growth_completes_all_requests():                # M5
    """Page pressure forces preemption (host-swap fallback on a lone
    replica), but every victim is re-queued and finishes — preemption
    delays requests, never starves them."""
    eng = _engine(total_pages=8, kv_reserve="on_demand", max_batch=4)
    reqs = [_req(rid=i, adapter=i, new_tokens=2 * PAGE_TOKENS)
            for i in range(4)]
    eng.submit(reqs)
    stats = eng.run()
    assert stats.n_requests == len(reqs)
    assert all(r.generated == r.max_new_tokens for r in reqs)
    assert stats.n_preempted > 0
    assert stats.restore_time > 0        # the swap round trip was paid
    assert eng.pool.used["kv"] == 0      # everything released at the end


def test_on_demand_infeasible_single_request_raises():
    """A single request that outgrows the whole pool has no victim to
    preempt: growth must fail loudly, not loop."""
    eng = _engine(total_pages=2, kv_reserve="on_demand")
    eng.submit([_req(new_tokens=4 * PAGE_TOKENS)])
    with pytest.raises(MemoryError):
        eng.run()


def test_on_demand_requires_pool():
    fp = make_fp()
    ex = CostModelExecutor(ServingHardware(), fp, "lora")
    with pytest.raises(ValueError):
        ServingEngine(EngineConfig(kv_reserve="on_demand"), ex)
    with pytest.raises(ValueError):
        ServingEngine(EngineConfig(kv_reserve="bogus"), ex)


def test_preempt_migrates_across_fleet_when_wired():               # M5
    """With a MigrationPolicy attached, page-pressure preemption rehomes
    the victim on another replica instead of host-swapping, and the
    move cap keeps any one request from bouncing forever."""
    fleet = _fleet(n=2, total_pages=8, kv_reserve="on_demand", max_batch=4)
    policy = MigrationPolicy(MigrationConfig(max_moves_per_request=2))
    policy.attach(fleet)
    reqs = [_req(rid=i, adapter=i, new_tokens=2 * PAGE_TOKENS,
                 t=i * 1e-4) for i in range(6)]
    fleet.submit(reqs)
    stats = fleet.run()
    assert stats.total.n_requests == len(reqs)
    assert all(r.generated == r.max_new_tokens for r in reqs)
    cap = policy.cfg.max_moves_per_request
    assert all(r.migrations + r.preemptions <= cap + 1 for r in reqs)


# ---------------------------------------------------------------------------
# priority preemption policy
# ---------------------------------------------------------------------------


def test_priority_tenant_preempts_and_victim_finishes():           # M5
    """A full batch with a waiting priority tenant evicts the cheapest
    low-priority victim to another replica; the tenant gets the slot,
    the victim still completes."""
    fleet = _fleet(n=2, max_batch=2)
    policy = MigrationPolicy()
    policy.attach(fleet)
    base = [_req(rid=i, adapter=i, new_tokens=64) for i in range(2)]
    # both low-priority requests onto replica 0, decoded into the batch
    # BEFORE the priority tenant shows up
    eng = fleet.engines[0]
    eng.submit(base)
    for r in base:
        fleet.assignments[r.rid] = 0
        r.replica = 0
    while len(eng.running) < 2:
        eng.step()
    vip = _req(rid=10, adapter=9, new_tokens=4, t=eng.clock, priority=5)
    eng.submit([vip])
    fleet.assignments[vip.rid] = 0
    vip.replica = 0
    assert vip in eng.waiting            # batch full with low-priority work
    policy.on_window(fleet, t=eng.clock)
    assert fleet.migration.n_preempt_migrations == 1
    fleet.run()
    assert vip.generated == vip.max_new_tokens
    assert all(r.generated == r.max_new_tokens for r in base)
    moved = [r for r in base if r.migrations > 0]
    assert len(moved) == 1


# ---------------------------------------------------------------------------
# the unified study driver
# ---------------------------------------------------------------------------


def test_run_study_one_shot_matches_fleet_run():
    """No control plane, no window: run_study is the legacy
    submit-and-drain path, bit-exact."""
    reqs_a = [_req(rid=i, adapter=i % 3, new_tokens=4, t=i * 1e-3)
              for i in range(8)]
    reqs_b = [dc.replace(r) for r in reqs_a]
    fa, fb = _fleet(n=2), _fleet(n=2)
    fa.submit(reqs_a)
    legacy = fa.run().to_dict()
    report = run_study(fb, reqs_b)
    assert report.stats.to_dict() == legacy
    assert report.migration is None and report.decisions is None


def test_run_study_event_retire_with_migration():
    """A scripted retire event under a MigrationPolicy does instant
    scale-down: the report carries the migration accounting."""
    reqs = [_req(rid=i, adapter=i % 3, new_tokens=256, t=i * 1e-3)
            for i in range(12)]
    report = run_study(
        _fleet(n=2), reqs,
        migration=MigrationPolicy(),
        events=[StudyEvent(t=2e-3, fn=lambda s: s.retire_decode(0),
                           label="retire replica 0")],
        window=2e-3)
    assert report.stats.total.n_requests == len(reqs)
    assert report.migration is not None
    assert report.migration["n_retire_migrations"] > 0
    assert report.migration["n_migrations"] \
        >= report.migration["n_retire_migrations"]
    assert "rps" in report.metrics()
    assert "migrations=" in report.derived()


def test_run_study_lifecycle_event_requires_lifecycle():
    with pytest.raises(ValueError):
        run_study(_fleet(n=2), [_req()],
                  events=[LifecycleEvent(t=0.1, action="register",
                                         adapter_id=5)],
                  window=0.1)


def test_study_report_wire_accounting():
    """Per-mode wire accounting surfaces migration traffic."""
    reqs = [_req(rid=i, adapter=i % 3, new_tokens=256, t=i * 1e-3)
            for i in range(8)]
    report = run_study(
        _fleet(n=2, fabric=FabricConfig()), reqs,
        migration=MigrationPolicy(),
        events=[StudyEvent(t=2e-3, fn=lambda s: s.retire_decode(0))],
        window=2e-3)
    assert report.migration is not None
    assert report.migration["kv_wire_bytes"] > 0
    assert report.wire_by_mode is not None
    assert sum(report.wire_by_mode.values()) \
        >= report.migration["kv_wire_bytes"]
    d = report.to_dict()
    assert d["wire_bytes_by_mode"] == report.wire_by_mode


# ---------------------------------------------------------------------------
# real executor: checkpoint/restore is token-exact
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def real_setup():
    import jax
    import jax.numpy as jnp
    from repro.configs import smoke_config
    from repro.models import transformer as tf
    from repro.models.param import init_params

    cfg = dc.replace(smoke_config("mistral-7b"), num_layers=2, d_model=64,
                     num_heads=2, num_kv_heads=1, d_ff=128, vocab_size=64)
    params = init_params(tf.model_defs(cfg), jax.random.PRNGKey(0))
    L, n, r = cfg.num_layers, 4, 8
    d, hd = cfg.d_model, cfg.resolved_head_dim
    dims = {"q": (d, cfg.num_heads * hd), "k": (d, cfg.num_kv_heads * hd),
            "v": (d, cfg.num_kv_heads * hd), "o": (cfg.num_heads * hd, d)}
    ks = jax.random.split(jax.random.PRNGKey(7), 2 * len(dims))
    bundles = {"layers": {}}
    for i, (tgt, (di, do)) in enumerate(dims.items()):
        bundles["layers"][tgt] = {
            "A": 0.05 * jax.random.normal(ks[2 * i], (L, n, r, di),
                                          jnp.float32),
            "B": 0.05 * jax.random.normal(ks[2 * i + 1], (L, n, do, r),
                                          jnp.float32)}
    return cfg, params, bundles, n


def test_real_executor_migration_token_exact(real_setup):          # M1
    """Export a mid-decode slot from one executor and import it into a
    fresh one: the continued token stream equals the unmigrated control
    stream exactly."""
    from repro.serving.real_executor import RealModelExecutor

    cfg, params, bundles, n = real_setup

    def executor():
        return RealModelExecutor(cfg, params, bundles, "lora", max_batch=4,
                                 s_max=64, decode_path="unfused")

    rng = np.random.default_rng(3)
    prompt = rng.integers(0, 36, size=7).astype(np.int32)
    req = Request(rid=0, adapter_id=1, prompt_len=len(prompt),
                  max_new_tokens=10)

    control = executor()
    control.prefill_request(req, prompt)
    want = [int(control.slot_tokens[0])]
    for _ in range(8):
        want.append(control.decode_step_real()[0])

    src = executor()
    src.prefill_request(Request(rid=0, adapter_id=1,
                                prompt_len=len(prompt), max_new_tokens=10),
                        prompt)
    got = [int(src.slot_tokens[0])]
    for _ in range(4):
        got.append(src.decode_step_real()[0])
    state = src.export_slot(0)
    src.release(0)

    dst = executor()
    dst.import_slot(Request(rid=0, adapter_id=1, prompt_len=len(prompt),
                            max_new_tokens=10), state)
    for _ in range(4):
        got.append(dst.decode_step_real()[0])
    assert got == want
