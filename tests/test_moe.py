"""MoE dispatch/combine correctness + dense-oracle equivalence."""
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import smoke_config
from repro.models.moe import _combine, _dispatch, _moe_dense, _route, moe_defs
from repro.models.param import init_params


def test_dispatch_combine_roundtrip():
    """dispatch->identity-expert->combine == weighted passthrough."""
    key = jax.random.PRNGKey(0)
    T, d, E, k, C = 32, 16, 4, 2, 24
    x = jax.random.normal(key, (T, d))
    topi = jax.random.randint(key, (T, k), 0, E)
    topw = jnp.ones((T, k)) / k
    buf, eid, slot, valid = _dispatch(x, topi, C, E)
    y = _combine(buf, eid, slot, valid, topw)
    # capacity is ample => every choice kept => y == x (sum_k w_k x = x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(x), rtol=1e-5,
                               atol=1e-5)


def test_dispatch_respects_capacity():
    T, d, E, k = 64, 8, 2, 1
    x = jnp.ones((T, d))
    topi = jnp.zeros((T, k), jnp.int32)       # all to expert 0
    cap = 16
    buf, eid, slot, valid = _dispatch(x, topi, cap, E)
    assert int(valid.sum()) == cap
    assert float(buf[0].sum()) == cap * d


def test_dispatch_offset_window():
    """Only experts inside [offset, offset+n_local) are bucketed."""
    T, d, E = 16, 4, 8
    x = jnp.ones((T, d))
    topi = jnp.tile(jnp.arange(8, dtype=jnp.int32)[:, None], (2, 1))
    buf, eid, slot, valid = _dispatch(x, topi, 4, 2, bucket_offset=4)
    assert int(valid.sum()) == 4            # experts 4 and 5, two each
    assert float(buf.sum()) == 4 * d


def test_moe_dense_matches_manual():
    cfg = smoke_config("deepseek-moe-16b")
    defs = moe_defs(cfg)
    p = init_params(defs, jax.random.PRNGKey(0), dtype_override=jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (12, cfg.d_model))
    topw, topi, aux = _route(p, x, cfg)
    y = _moe_dense(p, x, topw, topi, cfg)
    # manual: per token loop
    y_ref = np.zeros_like(np.asarray(y))
    for t in range(12):
        acc = np.zeros(cfg.d_model, np.float32)
        for j in range(cfg.moe.top_k):
            e = int(topi[t, j])
            g = np.asarray(x[t] @ p["w_gate"][e])
            u = np.asarray(x[t] @ p["w_up"][e])
            h = g / (1 + np.exp(-g)) * u
            acc += float(topw[t, j]) * (h @ np.asarray(p["w_down"][e]))
        y_ref[t] = acc
    np.testing.assert_allclose(np.asarray(y), y_ref, rtol=2e-4, atol=2e-4)
    assert jnp.isfinite(aux)


EP_EQUIV_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses
import jax, jax.numpy as jnp, numpy as np
from repro.configs import smoke_config
from repro.models.moe import moe_defs, moe_fwd
from repro.models.param import init_params
from repro.distributed.sharding import use_mesh
cfg = smoke_config("deepseek-moe-16b")
# ample capacity: EP must match the (no-drop) dense oracle exactly
cfg = dataclasses.replace(cfg, moe=dataclasses.replace(cfg.moe,
                                                       capacity_factor=16.0))
defs = moe_defs(cfg)
p = init_params(defs, jax.random.PRNGKey(0), dtype_override=jnp.float32)
x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model))
y_dense, aux_d = moe_fwd(p, x, cfg)              # no mesh -> dense oracle
from repro.launch.mesh import make_mesh_compat
mesh = make_mesh_compat((2, 4), ("data", "model"))
with use_mesh(mesh):
    y_ep, aux_e = jax.jit(lambda p, x: moe_fwd(p, x, cfg))(p, x)
err = float(jnp.max(jnp.abs(y_ep - y_dense)))
rel = err / float(jnp.max(jnp.abs(y_dense)))
assert rel < 1e-4, (err, rel)
print("EP-vs-dense rel err:", rel)
"""


def test_moe_ep_matches_dense_subprocess():
    """shard_map expert-parallel path == dense oracle (8 fake devices)."""
    r = subprocess.run([sys.executable, "-c", EP_EQUIV_SCRIPT],
                       capture_output=True, text=True,
                       env={**__import__("os").environ,
                            "PYTHONPATH": "src"}, cwd=".", timeout=600)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "EP-vs-dense rel err" in r.stdout
