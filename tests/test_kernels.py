"""Per-kernel shape/dtype sweeps: Pallas (interpret mode) vs jnp oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref as R
from repro.kernels.flash_decode import flash_decode
from repro.kernels.jd_apply import jd_apply
from repro.kernels.sgmv import sgmv_expand, sgmv_shrink, sigma_bmm

TOL = dict(rtol=2e-2, atol=3e-2)


def grouped_inputs(seed, T, d_in, n, tile, dtype):
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 3)
    ids = jax.random.randint(ks[0], (T,), 0, n)
    x = (jax.random.normal(ks[1], (T, d_in), jnp.float32)).astype(dtype)
    perm, tile_ids, valid = R.group_tokens_by_adapter(ids, n, tile)
    return x[perm], ids[perm], tile_ids, valid


@pytest.mark.parametrize("dtype", [jnp.bfloat16, jnp.float32])
@pytest.mark.parametrize("T,d_in,d_out,n,r,tile", [
    (32, 128, 64, 3, 8, 8),
    (64, 256, 192, 5, 16, 8),
    (128, 512, 256, 2, 32, 16),
    (16, 64, 128, 7, 4, 8),
])
def test_sgmv_sweep(T, d_in, d_out, n, r, tile, dtype):
    xg, idg, tile_ids, _ = grouped_inputs(0, T, d_in, n, tile, dtype)
    key = jax.random.PRNGKey(1)
    A = (jax.random.normal(key, (n, r, d_in)) / 8).astype(dtype)
    B = (jax.random.normal(key, (n, d_out, r)) / 4).astype(dtype)
    t = sgmv_shrink(xg, A, tile_ids, block_t=tile, block_d=64)
    t_ref = R.sgmv_shrink_ref(xg, A, idg).astype(jnp.float32)
    np.testing.assert_allclose(np.asarray(t), np.asarray(t_ref), **TOL)
    y = sgmv_expand(t.astype(dtype), B, tile_ids, block_t=tile, block_d=64)
    y_ref = R.sgmv_expand_ref(t.astype(dtype), B, idg)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(y_ref, np.float32), **TOL)


@pytest.mark.parametrize("r", [4, 16])
def test_sigma_bmm(r):
    T, n, tile = 48, 4, 8
    xg, idg, tile_ids, _ = grouped_inputs(2, T, r, n, tile, jnp.float32)
    sig = jax.random.normal(jax.random.PRNGKey(3), (n, r, r)) / 4
    out = sigma_bmm(xg, sig, tile_ids, block_t=tile)
    ref = R.sigma_bmm_ref(xg, sig, idg)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), **TOL)


@pytest.mark.parametrize("diag", [True, False])
@pytest.mark.parametrize("k_clusters", [1, 3])
def test_jd_apply_sweep(diag, k_clusters):
    T, d_in, d_out, n, r, tile = 64, 192, 128, 6, 8, 8
    xg, idg, tile_ids, _ = grouped_inputs(4, T, d_in, n, tile, jnp.bfloat16)
    key = jax.random.PRNGKey(5)
    ks = jax.random.split(key, 3)
    U = (jax.random.normal(ks[0], (k_clusters, d_out, r)) / 4).astype(jnp.bfloat16)
    V = (jax.random.normal(ks[1], (k_clusters, d_in, r)) / 8).astype(jnp.bfloat16)
    cluster_of = jnp.arange(n, dtype=jnp.int32) % k_clusters
    sig = (jnp.abs(jax.random.normal(ks[2], (n, r))) if diag
           else jax.random.normal(ks[2], (n, r, r)) / 4)
    tile_cids = cluster_of[tile_ids]
    out = jd_apply(xg, U, V, sig, cluster_of, idg, tile_cids, tile_ids)
    ref = R.jd_apply_ref(xg, U, V, sig, cluster_of, idg)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=5e-2, atol=5e-2)


@pytest.mark.parametrize("dtype", [jnp.bfloat16, jnp.float32])
@pytest.mark.parametrize("B,H,Kv,hd,S,bs", [
    (2, 4, 2, 32, 128, 32),
    (3, 8, 4, 64, 256, 64),
    (1, 2, 1, 16, 64, 64),     # single block
])
def test_flash_decode_sweep(B, H, Kv, hd, S, bs, dtype):
    key = jax.random.PRNGKey(6)
    ks = jax.random.split(key, 4)
    q = jax.random.normal(ks[0], (B, H, hd)).astype(dtype)
    k = jax.random.normal(ks[1], (B, S, Kv, hd)).astype(dtype)
    v = jax.random.normal(ks[2], (B, S, Kv, hd)).astype(dtype)
    kv_len = jax.random.randint(ks[3], (B,), 1, S + 1)
    out, l, m = flash_decode(q, k, v, kv_len, block_s=bs)
    ref = R.flash_decode_ref(q, k, v, kv_len)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=2e-2, atol=2e-2)


def test_flash_decode_merge_stats():
    """(m, l) stats support sequence-sharded softmax merging: two half-KV
    kernel calls merged == full-KV call (the long-context decode path)."""
    key = jax.random.PRNGKey(7)
    ks = jax.random.split(key, 3)
    B, H, Kv, hd, S = 2, 4, 2, 32, 128
    q = jax.random.normal(ks[0], (B, H, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, Kv, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, Kv, hd), jnp.float32)
    kv_len = jnp.full((B,), S, jnp.int32)
    full, _, _ = flash_decode(q, k, v, kv_len, block_s=32)
    h = S // 2
    o1, l1, m1 = flash_decode(q, k[:, :h], v[:, :h],
                              jnp.full((B,), h, jnp.int32), block_s=32)
    o2, l2, m2 = flash_decode(q, k[:, h:], v[:, h:],
                              jnp.full((B,), h, jnp.int32), block_s=32)
    G = H // Kv
    m = jnp.maximum(m1, m2)
    w1 = jnp.exp(m1 - m) * l1
    w2 = jnp.exp(m2 - m) * l2
    o1g = o1.reshape(B, Kv, G, hd)
    o2g = o2.reshape(B, Kv, G, hd)
    merged = (o1g * w1 + o2g * w2) / (w1 + w2)
    np.testing.assert_allclose(np.asarray(merged.reshape(B, H, hd)),
                               np.asarray(full), rtol=2e-4, atol=2e-4)


def test_ops_dispatch_matches_ref():
    from repro.kernels import ops
    T, d_in, d_out, n, r = 40, 96, 64, 4, 8
    key = jax.random.PRNGKey(8)
    ks = jax.random.split(key, 4)
    x = jax.random.normal(ks[0], (T, d_in), jnp.float32)
    A = jax.random.normal(ks[1], (n, r, d_in)) / 8
    B = jax.random.normal(ks[2], (n, d_out, r)) / 4
    ids = jax.random.randint(ks[3], (T,), 0, n)
    y_k = ops.lora_apply(x, A, B, ids, tile=8, use_pallas="interpret")
    y_r = ops.lora_apply(x, A, B, ids, use_pallas="ref")
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r),
                               rtol=1e-3, atol=1e-3)
