"""Per-kernel shape/dtype sweeps: Pallas (interpret mode) vs jnp oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref as R
from repro.kernels.flash_decode import flash_decode
from repro.kernels.jd_apply import jd_apply
from repro.kernels.sgmv import sgmv_expand, sgmv_shrink, sigma_bmm

TOL = dict(rtol=2e-2, atol=3e-2)


def grouped_inputs(seed, T, d_in, n, tile, dtype):
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 3)
    ids = jax.random.randint(ks[0], (T,), 0, n)
    x = (jax.random.normal(ks[1], (T, d_in), jnp.float32)).astype(dtype)
    perm, tile_ids, valid = R.group_tokens_by_adapter(ids, n, tile)
    return x[perm], ids[perm], tile_ids, valid


@pytest.mark.parametrize("dtype", [jnp.bfloat16, jnp.float32])
@pytest.mark.parametrize("T,d_in,d_out,n,r,tile", [
    (32, 128, 64, 3, 8, 8),
    (64, 256, 192, 5, 16, 8),
    (128, 512, 256, 2, 32, 16),
    (16, 64, 128, 7, 4, 8),
])
def test_sgmv_sweep(T, d_in, d_out, n, r, tile, dtype):
    xg, idg, tile_ids, _ = grouped_inputs(0, T, d_in, n, tile, dtype)
    key = jax.random.PRNGKey(1)
    A = (jax.random.normal(key, (n, r, d_in)) / 8).astype(dtype)
    B = (jax.random.normal(key, (n, d_out, r)) / 4).astype(dtype)
    t = sgmv_shrink(xg, A, tile_ids, block_t=tile, block_d=64)
    t_ref = R.sgmv_shrink_ref(xg, A, idg).astype(jnp.float32)
    np.testing.assert_allclose(np.asarray(t), np.asarray(t_ref), **TOL)
    y = sgmv_expand(t.astype(dtype), B, tile_ids, block_t=tile, block_d=64)
    y_ref = R.sgmv_expand_ref(t.astype(dtype), B, idg)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(y_ref, np.float32), **TOL)


@pytest.mark.parametrize("r", [4, 16])
def test_sigma_bmm(r):
    T, n, tile = 48, 4, 8
    xg, idg, tile_ids, _ = grouped_inputs(2, T, r, n, tile, jnp.float32)
    sig = jax.random.normal(jax.random.PRNGKey(3), (n, r, r)) / 4
    out = sigma_bmm(xg, sig, tile_ids, block_t=tile)
    ref = R.sigma_bmm_ref(xg, sig, idg)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), **TOL)


@pytest.mark.parametrize("diag", [True, False])
@pytest.mark.parametrize("k_clusters", [1, 3])
def test_jd_apply_sweep(diag, k_clusters):
    T, d_in, d_out, n, r, tile = 64, 192, 128, 6, 8, 8
    xg, idg, tile_ids, _ = grouped_inputs(4, T, d_in, n, tile, jnp.bfloat16)
    key = jax.random.PRNGKey(5)
    ks = jax.random.split(key, 3)
    U = (jax.random.normal(ks[0], (k_clusters, d_out, r)) / 4).astype(jnp.bfloat16)
    V = (jax.random.normal(ks[1], (k_clusters, d_in, r)) / 8).astype(jnp.bfloat16)
    cluster_of = jnp.arange(n, dtype=jnp.int32) % k_clusters
    sig = (jnp.abs(jax.random.normal(ks[2], (n, r))) if diag
           else jax.random.normal(ks[2], (n, r, r)) / 4)
    tile_cids = cluster_of[tile_ids]
    out = jd_apply(xg, U, V, sig, cluster_of, idg, tile_cids, tile_ids)
    ref = R.jd_apply_ref(xg, U, V, sig, cluster_of, idg)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=5e-2, atol=5e-2)


@pytest.mark.parametrize("dtype", [jnp.bfloat16, jnp.float32])
@pytest.mark.parametrize("B,H,Kv,hd,S,bs", [
    (2, 4, 2, 32, 128, 32),
    (3, 8, 4, 64, 256, 64),
    (1, 2, 1, 16, 64, 64),     # single block
])
def test_flash_decode_sweep(B, H, Kv, hd, S, bs, dtype):
    key = jax.random.PRNGKey(6)
    ks = jax.random.split(key, 4)
    q = jax.random.normal(ks[0], (B, H, hd)).astype(dtype)
    k = jax.random.normal(ks[1], (B, S, Kv, hd)).astype(dtype)
    v = jax.random.normal(ks[2], (B, S, Kv, hd)).astype(dtype)
    kv_len = jax.random.randint(ks[3], (B,), 1, S + 1)
    out, l, m = flash_decode(q, k, v, kv_len, block_s=bs)
    ref = R.flash_decode_ref(q, k, v, kv_len)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=2e-2, atol=2e-2)


def test_flash_decode_merge_stats():
    """(m, l) stats support sequence-sharded softmax merging: two half-KV
    kernel calls merged == full-KV call (the long-context decode path)."""
    key = jax.random.PRNGKey(7)
    ks = jax.random.split(key, 3)
    B, H, Kv, hd, S = 2, 4, 2, 32, 128
    q = jax.random.normal(ks[0], (B, H, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, Kv, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, Kv, hd), jnp.float32)
    kv_len = jnp.full((B,), S, jnp.int32)
    full, _, _ = flash_decode(q, k, v, kv_len, block_s=32)
    h = S // 2
    o1, l1, m1 = flash_decode(q, k[:, :h], v[:, :h],
                              jnp.full((B,), h, jnp.int32), block_s=32)
    o2, l2, m2 = flash_decode(q, k[:, h:], v[:, h:],
                              jnp.full((B,), h, jnp.int32), block_s=32)
    G = H // Kv
    m = jnp.maximum(m1, m2)
    w1 = jnp.exp(m1 - m) * l1
    w2 = jnp.exp(m2 - m) * l2
    o1g = o1.reshape(B, Kv, G, hd)
    o2g = o2.reshape(B, Kv, G, hd)
    merged = (o1g * w1 + o2g * w2) / (w1 + w2)
    np.testing.assert_allclose(np.asarray(merged.reshape(B, H, hd)),
                               np.asarray(full), rtol=2e-4, atol=2e-4)


def test_ops_dispatch_matches_ref():
    from repro.kernels import ops
    T, d_in, d_out, n, r = 40, 96, 64, 4, 8
    key = jax.random.PRNGKey(8)
    ks = jax.random.split(key, 4)
    x = jax.random.normal(ks[0], (T, d_in), jnp.float32)
    A = jax.random.normal(ks[1], (n, r, d_in)) / 8
    B = jax.random.normal(ks[2], (n, d_out, r)) / 4
    ids = jax.random.randint(ks[3], (T,), 0, n)
    y_k = ops.lora_apply(x, A, B, ids, tile=8, use_pallas="interpret")
    y_r = ops.lora_apply(x, A, B, ids, use_pallas="ref")
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r),
                               rtol=1e-3, atol=1e-3)


# ---------------------------------------------------------------------------
# fused decode + adapter delta (PR 8): one pass == composed unfused passes
# ---------------------------------------------------------------------------

from repro.kernels.adapter_quant import (adapter_dequantize, adapter_quantize,
                                         int8_error_bound, quantized_nbytes)
from repro.kernels.flash_decode import flash_decode_paged
from repro.kernels.fused_decode import (fused_decode_jd,
                                        fused_decode_jd_paged,
                                        fused_decode_lora,
                                        fused_decode_lora_paged)

FUSED_TOL = dict(rtol=2e-5, atol=2e-5)


def _attn_inputs(seed, B, H, Kv, hd, S, n):
    ks = jax.random.split(jax.random.PRNGKey(seed), 6)
    q = jax.random.normal(ks[0], (B, H, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, Kv, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, Kv, hd), jnp.float32)
    kv_len = jax.random.randint(ks[3], (B,), 1, S + 1)
    ids = jax.random.randint(ks[4], (B,), 0, n)
    return q, k, v, kv_len, ids, ks[5]


def _paged(k, v, page_t, seed=0):
    """Scatter contiguous (B,S,Kv,hd) KV into a permuted physical pool."""
    B, S, Kv, hd = k.shape
    nb = S // page_t
    perm = np.random.default_rng(seed).permutation(B * nb).astype(np.int32)
    page_table = jnp.asarray(perm.reshape(B, nb))
    kp = jnp.zeros((B * nb, page_t, Kv, hd), k.dtype)
    vp = jnp.zeros_like(kp)
    for b in range(B):
        for s in range(nb):
            kp = kp.at[perm[b * nb + s]].set(k[b, s * page_t:(s + 1) * page_t])
            vp = vp.at[perm[b * nb + s]].set(v[b, s * page_t:(s + 1) * page_t])
    return kp, vp, page_table


def _scatter_tiles(vals, perm, valid, B):
    """Undo group_tokens_by_adapter: grouped rows back to batch order."""
    out = np.zeros((B,) + vals.shape[1:], np.float32)
    p, m = np.asarray(perm), np.asarray(valid).astype(bool)
    out[p[m]] = np.asarray(vals, np.float32)[m]
    return out


@pytest.mark.parametrize("B,r,n", [(4, 8, 3), (8, 16, 5), (16, 4, 2)])
def test_fused_lora_matches_composed_and_oracle(B, r, n):
    """Fused kernel == flash_decode (bit-exact attention) + sgmv shrink/
    expand (delta to f32 tolerance) == ref oracle, across batch x rank x
    adapter-count."""
    H, Kv, hd, S, d_out = 4, 2, 32, 128, 64
    q, k, v, kv_len, ids, kw = _attn_inputs(10 + B + r, B, H, Kv, hd, S, n)
    ka, kb = jax.random.split(kw)
    A = jax.random.normal(ka, (n, r, H * hd), jnp.float32) / 8
    Bm = jax.random.normal(kb, (n, d_out, r), jnp.float32) / 4
    out, delta = fused_decode_lora(q, k, v, kv_len, ids, A, Bm, block_s=32)
    # attention half: bit-exact with the standalone kernel
    f_out, _, _ = flash_decode(q, k, v, kv_len, block_s=32)
    assert np.array_equal(np.asarray(out), np.asarray(f_out))
    # delta half: composed unfused path (grouped SGMV over the attn out)
    of = f_out.reshape(B, -1)
    perm, tile_ids, valid = R.group_tokens_by_adapter(ids, n, tile=4)
    t = sgmv_shrink(of[perm], A, tile_ids, block_t=4)
    d = sgmv_expand(t, Bm, tile_ids, block_t=4)
    composed = _scatter_tiles(d, perm, valid, B)
    np.testing.assert_allclose(np.asarray(delta), composed, **FUSED_TOL)
    # and the oracle
    o_ref, d_ref = R.fused_decode_lora_ref(q, k, v, kv_len, ids, A, Bm)
    np.testing.assert_allclose(np.asarray(delta), np.asarray(d_ref),
                               **FUSED_TOL)
    np.testing.assert_allclose(np.asarray(out), np.asarray(o_ref),
                               rtol=2e-2, atol=2e-2)


@pytest.mark.parametrize("diag", [True, False])
@pytest.mark.parametrize("k_clusters", [1, 3])
def test_fused_jd_matches_composed_and_oracle(diag, k_clusters):
    """Fused compressed-basis variant == flash_decode + jd_apply on the
    grouped attention output, for diag and full Sigma and >1 cluster."""
    B, H, Kv, hd, S, n, r, d_out = 8, 4, 2, 32, 128, 6, 8, 64
    q, k, v, kv_len, ids, kw = _attn_inputs(3 if diag else 4,
                                            B, H, Kv, hd, S, n)
    ku, kv_, ksig = jax.random.split(kw, 3)
    U = jax.random.normal(ku, (k_clusters, d_out, r), jnp.float32) / 4
    V = jax.random.normal(kv_, (k_clusters, H * hd, r), jnp.float32) / 8
    cluster_of = jnp.arange(n, dtype=jnp.int32) % k_clusters
    sig = (jnp.abs(jax.random.normal(ksig, (n, r))) if diag
           else jax.random.normal(ksig, (n, r, r)) / 4)
    out, delta = fused_decode_jd(q, k, v, kv_len, ids, U, V, sig,
                                 cluster_of, block_s=32)
    f_out, _, _ = flash_decode(q, k, v, kv_len, block_s=32)
    assert np.array_equal(np.asarray(out), np.asarray(f_out))
    of = f_out.reshape(B, -1)
    perm, tile_ids, valid = R.group_tokens_by_adapter(ids, n, tile=4)
    tile_cids = cluster_of[tile_ids]
    d = jd_apply(of[perm], U, V, sig, cluster_of, ids[perm], tile_cids,
                 tile_ids, block_t=4)
    composed = _scatter_tiles(d, perm, valid, B)
    np.testing.assert_allclose(np.asarray(delta), composed, **FUSED_TOL)
    _, d_ref = R.fused_decode_jd_ref(q, k, v, kv_len, ids, U, V, sig,
                                     cluster_of)
    np.testing.assert_allclose(np.asarray(delta), np.asarray(d_ref),
                               **FUSED_TOL)


@pytest.mark.parametrize("mode", ["lora", "jd"])
def test_fused_paged_bit_exact_with_contiguous(mode):
    """Paged fused variant over a permuted page table == contiguous fused
    (out AND delta), and == flash_decode_paged on the attention half."""
    B, H, Kv, hd, S, n, r, d_out, page_t = 4, 4, 2, 32, 128, 3, 8, 64, 16
    q, k, v, kv_len, ids, kw = _attn_inputs(20, B, H, Kv, hd, S, n)
    kp, vp, page_table = _paged(k, v, page_t, seed=1)
    if mode == "lora":
        ka, kb = jax.random.split(kw)
        A = jax.random.normal(ka, (n, r, H * hd), jnp.float32) / 8
        Bm = jax.random.normal(kb, (n, d_out, r), jnp.float32) / 4
        out_c, d_c = fused_decode_lora(q, k, v, kv_len, ids, A, Bm,
                                       block_s=page_t)
        out_p, d_p = fused_decode_lora_paged(q, kp, vp, page_table, kv_len,
                                             ids, A, Bm)
    else:
        ku, kv_, ksig = jax.random.split(kw, 3)
        U = jax.random.normal(ku, (2, d_out, r), jnp.float32) / 4
        V = jax.random.normal(kv_, (2, H * hd, r), jnp.float32) / 8
        cluster_of = jnp.arange(n, dtype=jnp.int32) % 2
        sig = jax.random.normal(ksig, (n, r, r), jnp.float32) / 4
        out_c, d_c = fused_decode_jd(q, k, v, kv_len, ids, U, V, sig,
                                     cluster_of, block_s=page_t)
        out_p, d_p = fused_decode_jd_paged(q, kp, vp, page_table, kv_len,
                                           ids, U, V, sig, cluster_of)
    assert np.array_equal(np.asarray(out_p), np.asarray(out_c))
    assert np.array_equal(np.asarray(d_p), np.asarray(d_c))
    f_out, _, _ = flash_decode_paged(q, kp, vp, page_table, kv_len)
    assert np.array_equal(np.asarray(out_p), np.asarray(f_out))


def test_fused_lora_q8_matches_q8_oracle_and_fp_within_bound():
    """int8 banks: fused dequant epilogue == quantized oracle exactly (to
    f32 tolerance), and the fp gap stays within the analytic bound."""
    B, H, Kv, hd, S, n, r, d_out = 8, 4, 2, 32, 128, 4, 8, 64
    q, k, v, kv_len, ids, kw = _attn_inputs(30, B, H, Kv, hd, S, n)
    ka, kb = jax.random.split(kw)
    A = jax.random.normal(ka, (n, r, H * hd), jnp.float32) / 8
    Bm = jax.random.normal(kb, (n, d_out, r), jnp.float32) / 4
    aq, a_s = adapter_quantize(A)
    bq, b_s = adapter_quantize(Bm)
    out, delta = fused_decode_lora(q, k, v, kv_len, ids, aq, bq,
                                   a_scale=a_s, b_scale=b_s, block_s=32)
    _, d_ref = R.fused_decode_lora_ref(q, k, v, kv_len, ids, aq, bq,
                                       a_scale=a_s, b_scale=b_s)
    np.testing.assert_allclose(np.asarray(delta), np.asarray(d_ref),
                               **FUSED_TOL)
    _, d_fp = R.fused_decode_lora_ref(q, k, v, kv_len, ids, A, Bm)
    err = float(np.max(np.abs(np.asarray(delta) - np.asarray(d_fp))))
    assert err < 0.05, err                     # quant noise, not a bug


def test_adapter_quant_kernel_matches_oracle_and_bound():
    """Pallas quantizer == ref oracle bit-exact; roundtrip error bounded by
    `int8_error_bound`; packed bytes ~4x smaller than f32."""
    key = jax.random.PRNGKey(9)
    for shape, axis in (((3, 16, 64), -1), ((2, 5, 64, 8), -2)):
        key, k1 = jax.random.split(key)
        w = jax.random.normal(k1, shape, jnp.float32)
        q, s = adapter_quantize(w, axis=axis)
        q_ref, s_ref = R.adapter_quant_ref(w, axis=axis)
        assert np.array_equal(np.asarray(q), np.asarray(q_ref))
        np.testing.assert_allclose(np.asarray(s), np.asarray(s_ref),
                                   rtol=1e-6, atol=0)
        back = adapter_dequantize(q, s)
        bound = np.asarray(int8_error_bound(w, axis=axis))
        assert np.all(np.abs(np.asarray(back) - np.asarray(w))
                      <= bound + 1e-7)
        fp32 = int(np.prod(shape)) * 4
        assert fp32 / quantized_nbytes(shape, axis=axis) > 3.0


def test_ops_fused_dispatch_matches_ref():
    from repro.kernels import ops
    B, H, Kv, hd, S, n, r, d_out = 4, 4, 2, 32, 64, 3, 8, 64
    q, k, v, kv_len, ids, kw = _attn_inputs(40, B, H, Kv, hd, S, n)
    ka, kb = jax.random.split(kw)
    A = jax.random.normal(ka, (n, r, H * hd), jnp.float32) / 8
    Bm = jax.random.normal(kb, (n, d_out, r), jnp.float32) / 4
    o_k, d_k = ops.fused_lora_decode(q, k, v, kv_len, ids, A, Bm,
                                     use_pallas="interpret")
    o_r, d_r = ops.fused_lora_decode(q, k, v, kv_len, ids, A, Bm,
                                     use_pallas="ref")
    np.testing.assert_allclose(np.asarray(o_k), np.asarray(o_r),
                               rtol=2e-2, atol=2e-2)
    np.testing.assert_allclose(np.asarray(d_k), np.asarray(d_r), **FUSED_TOL)
