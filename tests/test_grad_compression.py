"""int8 compressed psum == exact psum within quantization tolerance."""
import os
import subprocess
import sys

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.distributed.grad_compression import compressed_psum
from repro.launch.mesh import make_mesh_compat
mesh = make_mesh_compat((8,), ("data",))
x = jax.random.normal(jax.random.PRNGKey(0), (8, 4, 37))

def body(xs):
    exact = jax.lax.psum(xs, "data")
    comp = compressed_psum(xs, "data")
    return exact, comp

from repro.compat import shard_map
exact, comp = jax.jit(shard_map(body, mesh=mesh,
                                    in_specs=P("data"),
                                    out_specs=P("data")))(x)
rel = float(jnp.max(jnp.abs(exact - comp)) / jnp.max(jnp.abs(exact)))
assert rel < 0.05, rel
print("compressed psum rel err:", rel)
"""


def test_compressed_psum_subprocess():
    r = subprocess.run([sys.executable, "-c", SCRIPT],
                       capture_output=True, text=True,
                       env={**os.environ, "PYTHONPATH": "src"},
                       cwd=".", timeout=300)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "compressed psum rel err" in r.stdout
