"""Logical-axis sharding rules: divisibility fallback + per-cell specs."""
import os
import subprocess
import sys


SPEC_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
from jax.sharding import PartitionSpec as P
from repro.distributed.sharding import spec_for, use_mesh
from repro.launch import shardings as sh
from repro.configs import get_config
from repro.models import transformer as tf
from repro.models.param import param_specs

from repro.launch.mesh import make_mesh_compat
mesh = make_mesh_compat((2, 4), ("data", "model"))

# divisible -> sharded
assert spec_for((16, 64), ("batch", "d_ff"), mesh) == P("data", "model")
# non-divisible head count -> replicate (granite's 24 heads scenario)
assert spec_for((6,), ("heads",), mesh) == P(None)
# one mesh axis never used twice
s = spec_for((8, 8), ("heads", "d_ff"), mesh)
assert s == P("model", None)
# experts take precedence, expert_ff falls back (deepseek vs granite)
assert spec_for((8, 16, 32), ("experts", "d_model", "expert_ff"), mesh) \
    == P("model", None, None)
assert spec_for((6, 16, 32), ("experts", "d_model", "expert_ff"), mesh) \
    == P(None, None, "model")

# param specs: FSDP only in train rules
cfg = get_config("qwen3-1.7b")
defs = tf.model_defs(cfg)
tr = sh.params_shardings(defs, mesh, "train")
se = sh.params_shardings(defs, mesh, "serve")
wq_tr = tr["layers"]["attn"]["wq"].spec
wq_se = se["layers"]["attn"]["wq"].spec
assert wq_tr == P(None, "data", "model", None), wq_tr  # (L,d,H,hd) FSDP+TP
assert wq_se == P(None, None, "model", None), wq_se    # TP only
print("sharding specs ok")
"""


def test_spec_rules_subprocess():
    r = subprocess.run([sys.executable, "-c", SPEC_SCRIPT],
                       capture_output=True, text=True,
                       env={**os.environ, "PYTHONPATH": "src"},
                       cwd=".", timeout=300)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "sharding specs ok" in r.stdout


def test_constrain_noop_without_mesh():
    import jax.numpy as jnp
    from repro.distributed.sharding import constrain
    x = jnp.ones((4, 4))
    y = constrain(x, "batch", "d_model")
    assert (y == x).all()
