"""Fault tolerance: checkpoint/restart bitwise-resume, straggler detection,
elastic restore."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpoint import (latest_step, restore_checkpoint,
                                         save_checkpoint,
                                         wait_for_async_saves)
from repro.ft.failures import FailurePlan, FaultTolerantRunner, FTConfig


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(12.0).reshape(3, 4),
            "nested": {"b": jnp.ones((2, 2), jnp.bfloat16),
                       "c": jnp.asarray(3, jnp.int32)}}
    save_checkpoint(str(tmp_path), 5, tree)
    assert latest_step(str(tmp_path)) == 5
    back = restore_checkpoint(str(tmp_path), 5, tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert a.dtype == b.dtype


def test_checkpoint_gc_keeps_latest(tmp_path):
    tree = {"x": jnp.zeros(3)}
    for s in (1, 2, 3, 4, 5):
        save_checkpoint(str(tmp_path), s, tree, keep=2)
    steps = sorted(int(p.name.split("_")[1])
                   for p in tmp_path.glob("step_*"))
    assert steps == [4, 5]


def test_async_save(tmp_path):
    tree = {"x": jnp.arange(1000.0)}
    save_checkpoint(str(tmp_path), 1, tree, blocking=False)
    wait_for_async_saves()
    assert latest_step(str(tmp_path)) == 1


def _make_counter_runner(tmp_path, plan, ckpt_every=2):
    """Deterministic integer 'training': state = prod of per-step factors."""
    saves = {}

    def step_fn(state, i):
        return {"v": state["v"] * (i + 2) % 1_000_003}

    def save_fn(step, state):
        saves[step] = dict(state)

    def restore_fn():
        if not saves:
            return None
        s = max(saves)
        return s, dict(saves[s])

    return FaultTolerantRunner(FTConfig(ckpt_every=ckpt_every), step_fn,
                               save_fn, restore_fn, plan=plan), saves


@pytest.mark.slow
def test_restart_resumes_and_matches_no_failure_run(tmp_path):
    clean, _ = _make_counter_runner(tmp_path, FailurePlan())
    ref = clean.run({"v": 1}, 9)
    faulty, _ = _make_counter_runner(
        tmp_path, FailurePlan(fail_at_steps=(3, 7)))
    out = faulty.run({"v": 1}, 9)
    assert out == ref
    assert faulty.state.restarts == 2


def test_straggler_detection():
    runner, _ = _make_counter_runner(
        None, FailurePlan(straggle_at_steps=(6,), straggle_seconds=0.3))
    runner.cfg = FTConfig(ckpt_every=100, straggler_factor=5.0)
    runner.run({"v": 1}, 10)
    assert runner.state.excluded_nodes == 1
    assert any("step" in h["event"] for h in runner.state.history)


ELASTIC_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.checkpoint.checkpoint import save_checkpoint, restore_checkpoint
from repro.launch.mesh import make_mesh_for
import tempfile
d = tempfile.mkdtemp()
mesh8 = make_mesh_for(8, model_parallel=4)       # (2, 4) data x model
x = jax.device_put(jnp.arange(64.0).reshape(8, 8),
                   NamedSharding(mesh8, P("data", "model")))
tree = {"w": x}
save_checkpoint(d, 1, tree)
# 'failure': restart on fewer devices -> different mesh
mesh4 = make_mesh_for(4, model_parallel=2)       # (2, 2)
sh = {"w": NamedSharding(mesh4, P("data", "model"))}
back = restore_checkpoint(d, 1, tree, shardings=sh)
np.testing.assert_array_equal(np.asarray(back["w"]), np.asarray(x))
assert back["w"].sharding.mesh.size == 4
print("elastic restore ok")
"""


@pytest.mark.slow
def test_elastic_restore_different_mesh():
    r = subprocess.run([sys.executable, "-c", ELASTIC_SCRIPT],
                       capture_output=True, text=True,
                       env={**os.environ, "PYTHONPATH": "src"},
                       cwd=".", timeout=300)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "elastic restore ok" in r.stdout


@pytest.mark.fast
def test_train_resume_bitwise(tmp_path):
    """Full train loop: crash at step 7, resume from step-5 ckpt, final
    params identical to an uninterrupted run (deterministic data pipeline)."""
    from repro.configs import smoke_config
    from repro.launch.train import train_full

    cfg = smoke_config("qwen3-1.7b")
    ref = train_full(cfg, steps=8, batch=2, seq=32,
                     ckpt_dir=str(tmp_path / "ref"), ckpt_every=5)

    # interrupted run: wrap step to fail once at step 6
    def train_with_failure():
        import repro.launch.train as T
        import repro.ft.failures as FF

        class Plan(FF.FailurePlan):
            pass

        # monkeypatch FTConfig runner construction inside train_full by
        # injecting failure thru a global plan
        orig_runner = FF.FaultTolerantRunner

        class R(orig_runner):
            def __init__(self, cfg, step_fn, save_fn, restore_fn, plan=None,
                         on_restart=None):
                super().__init__(cfg, step_fn, save_fn, restore_fn,
                                 plan=FF.FailurePlan(fail_at_steps=(6,)),
                                 on_restart=on_restart)

        FF.FaultTolerantRunner = R
        T.FaultTolerantRunner = R
        try:
            return T.train_full(cfg, steps=8, batch=2, seq=32,
                                ckpt_dir=str(tmp_path / "faulty"),
                                ckpt_every=5)
        finally:
            FF.FaultTolerantRunner = orig_runner
            T.FaultTolerantRunner = orig_runner

    out = train_with_failure()
    for a, b in zip(jax.tree.leaves(ref["params"]),
                    jax.tree.leaves(out["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
