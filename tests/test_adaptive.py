"""Adaptive per-transfer KV wire compression: ladder policy + hysteresis,
raw-locked parity with the static compression=None fabric, per-mode
accounting, the joint autoscaler's compression axis, and the fabric
edge-case bugfixes (zero-byte handoffs, startup validation)."""
import dataclasses

import pytest

from repro.serving.adapter_cache import AdapterCache, CacheConfig, DMAModel
from repro.serving.autoscaler import (JointAutoscaler, JointAutoscalerConfig,
                                      SLOConfig)
from repro.serving.prefill import PrefillConfig, PrefillTier, PrefillWorker
from repro.serving.request import Request
from repro.serving.resources import (AdaptiveCompressionConfig,
                                     AdaptiveCompressionPolicy, BudgetConfig,
                                     FabricConfig, HardwareBudget,
                                     KVCompressionConfig, KVFabric,
                                     kv_bytes_per_token)


class FixedCostExecutor:
    """Hand-computable executor: prefill 1s, decode step 0.5s, KV 1000 B."""

    def __init__(self, prefill=1.0, decode=0.5, kv=1000):
        self._prefill, self._decode, self._kv = prefill, decode, kv

    def adapter_bytes(self, aid):
        return 1

    def shared_bytes(self):
        return 0

    def decode_step_time(self, batch):
        return self._decode if batch else 0.0

    def prefill_time(self, req):
        return self._prefill

    def kv_bytes(self, req):
        return self._kv


def _free_cache():
    return AdapterCache(CacheConfig(1e9, DMAModel(bandwidth=1e30,
                                                  latency=0.0)))


def _worker(cfg, kv=1000):
    w = PrefillWorker(cfg, FixedCostExecutor(kv=kv))
    w.cache = _free_cache()
    return w


def _reqs(n, arrivals=None, new_tokens=2):
    arrivals = arrivals or [0.0] * n
    return [Request(rid=i, adapter_id=i, prompt_len=8,
                    max_new_tokens=new_tokens, arrival_time=t)
            for i, t in enumerate(arrivals)]


# ---------------------------------------------------------------------------
# ladder config + policy hysteresis
# ---------------------------------------------------------------------------


def test_adaptive_config_validation():
    with pytest.raises(ValueError):
        AdaptiveCompressionConfig(modes=())
    with pytest.raises(ValueError):
        AdaptiveCompressionConfig(modes=("int8", "raw"))  # floor must be raw
    with pytest.raises(ValueError):
        AdaptiveCompressionConfig(modes=("raw", "fp8"))
    with pytest.raises(ValueError):
        AdaptiveCompressionConfig(modes=("raw", "int8", "int8"))
    with pytest.raises(ValueError):
        AdaptiveCompressionConfig(escalate_backlog_s=(0.05,))  # too few
    with pytest.raises(ValueError):
        AdaptiveCompressionConfig(escalate_backlog_s=(0.05, 0.02))
    with pytest.raises(ValueError):
        AdaptiveCompressionConfig(relax_fraction=1.0)
    with pytest.raises(ValueError):
        AdaptiveCompressionConfig(min_dwell=0)
    with pytest.raises(ValueError):
        AdaptiveCompressionConfig(initial_ceiling=3)
    # raw-locked ladder needs no thresholds at all
    AdaptiveCompressionConfig(modes=("raw",), escalate_backlog_s=())


def test_policy_escalates_immediately_and_jumps_levels():
    p = AdaptiveCompressionPolicy(AdaptiveCompressionConfig(
        escalate_backlog_s=(1.0, 2.0), min_dwell=8))
    assert p.decide(0.0) is None and p.mode == "raw"
    # a spike past the top threshold jumps straight to int4, dwell or not
    assert p.decide(5.0).mode == "int4"
    assert p.n_switches == 1


def test_policy_hysteresis_does_not_thrash_on_oscillating_backlog():
    """Backlog oscillating inside the hysteresis band (above relax_fraction
    of the level's threshold, below the next escalation) changes the mode
    exactly once, not per transfer."""
    p = AdaptiveCompressionPolicy(AdaptiveCompressionConfig(
        escalate_backlog_s=(1.0, 2.0), relax_fraction=0.5, min_dwell=4))
    modes = [p.decide(b) and p.mode
             for b in [1.1, 0.6, 1.1, 0.6, 1.1, 0.6, 1.1, 0.6, 1.1, 0.6]]
    assert p.n_switches == 1
    assert modes[0] == "int8" and all(m == "int8" for m in modes if m)
    # dropping out of the band still waits out min_dwell before relaxing,
    # and then steps down one level at a time
    p2 = AdaptiveCompressionPolicy(AdaptiveCompressionConfig(
        escalate_backlog_s=(1.0, 2.0), relax_fraction=0.5, min_dwell=3))
    p2.decide(5.0)                       # -> int4
    relaxed = [p2.decide(0.0) for _ in range(3)]
    assert p2.mode == "int8"             # one step down after 3 dwelled
    assert relaxed[-1].mode == "int8"
    for _ in range(3):
        p2.decide(0.0)
    assert p2.mode == "raw"
    assert p2.n_switches == 3


def test_policy_ceiling_caps_and_clamps():
    p = AdaptiveCompressionPolicy(AdaptiveCompressionConfig(
        escalate_backlog_s=(1.0, 2.0), initial_ceiling=0))
    assert p.decide(100.0) is None       # ceiling-locked at raw
    assert p.raise_ceiling() and p.ceiling_mode == "int8"
    assert p.decide(100.0).mode == "int8"    # capped below int4
    assert p.raise_ceiling() and not p.raise_ceiling()   # top is int4
    assert p.decide(100.0).mode == "int4"
    assert p.lower_ceiling() and p.mode == "int8"    # level clamps down
    p.lower_ceiling()
    assert p.mode == "raw" and not p.lower_ceiling()


# ---------------------------------------------------------------------------
# raw-locked parity + per-mode accounting
# ---------------------------------------------------------------------------


def test_raw_locked_policy_bit_exact_with_compression_none():
    """modes=("raw",) (and a ceiling pinned at 0) reproduces the PR-4
    compression=None fabric bit-exactly: same request stamps, same fabric
    stats, no compress time charged."""
    locked = FabricConfig(bandwidth=100.0, latency=0.1, chunk_bytes=300,
                          adaptive=AdaptiveCompressionConfig(modes=("raw",)))
    ceiling0 = FabricConfig(bandwidth=100.0, latency=0.1, chunk_bytes=300,
                            adaptive=AdaptiveCompressionConfig(
                                initial_ceiling=0))
    plain = FabricConfig(bandwidth=100.0, latency=0.1, chunk_bytes=300)
    outs = []
    for fab in (plain, locked, ceiling0):
        w = _worker(PrefillConfig(n_workers=1, fabric=fab))
        reqs = _reqs(3, arrivals=[0.0, 0.0, 5.0])
        w.submit(reqs)
        w.drain()
        outs.append((
            [(r.prefill_done_time, r.decode_ready_time, r.kv_landed_time,
              r.transfer_time, r.kv_raw_bytes, r.kv_wire_bytes,
              r.kv_compression, r.kv_decompress_cost) for r in reqs],
            w.stats.compress_time, w.fabric.stats))
    assert outs[0] == outs[1] == outs[2]
    assert outs[0][1] == 0.0
    assert outs[0][2].wire_bytes_by_mode == {"raw": 3000}


def test_backlog_estimate_is_causal():
    """`backlog_seconds(at)` counts only pending transfers already ready at
    `at`.  The tier simulates workers eagerly, so *future* handoffs
    (``ready_at > at``) can sit in ``_pending`` when a transfer is planned
    — a live controller could not see those, and the estimate must not."""
    fab = KVFabric(FabricConfig(bandwidth=100.0, latency=0.1, chunk_bytes=0))
    now, future = _reqs(2)
    fab.request(now, 0.0, 1000, comp=None)       # 10 s wire + 0.1 s latency
    fab.request(future, 50.0, 1000, comp=None)   # does not exist yet at t=1
    assert fab.backlog_seconds(1.0) == pytest.approx(10.1)
    assert fab.backlog_seconds(50.0) == pytest.approx(20.2)
    # after resolve the horizon carries through free_at, not _pending
    fab.resolve()
    assert fab._pending == []
    assert fab.backlog_seconds(fab.free_at - 1.0) == pytest.approx(1.0)
    assert fab.backlog_seconds(fab.free_at + 1.0) == 0.0


def test_adaptive_decision_ignores_future_transfers():
    """A transfer planned at t=0 must ship raw on an idle channel even if a
    future handoff was recorded first (the pre-fix estimate peeked at it
    and escalated off traffic that did not exist yet)."""
    fab = KVFabric(FabricConfig(
        bandwidth=100.0, latency=0.0,
        adaptive=AdaptiveCompressionConfig(escalate_backlog_s=(5.0, 15.0),
                                           min_dwell=1)))
    r_future, r_now = _reqs(2)
    fab.request(r_future, 100.0, 1000)   # 10 s of wire, but only at t=100
    fab.request(r_now, 0.0, 1000)        # causal backlog at t=0 is zero
    fab.resolve()
    assert r_now.wire_mode == "raw"
    assert r_now.kv_compression is None


def test_raw_locked_tier_bit_exact_with_future_transfers_pending():
    """Regression for the causal-backlog fix at tier scope: two eager
    workers record handoffs out of order (future ``ready_at`` visible in
    ``_pending``), and a raw-locked ladder must still reproduce the
    ``compression=None`` fabric bit-exactly — the raw path never consults
    the backlog estimate."""
    def run(fab_cfg):
        cfg = PrefillConfig(n_workers=2, fabric=fab_cfg)
        tier = PrefillTier(cfg, [_worker(cfg), _worker(cfg)])
        reqs = _reqs(6, arrivals=[0.0, 0.0, 2.0, 2.0, 9.0, 9.0])
        tier.submit(reqs)
        tier.drain()
        return ([(r.prefill_done_time, r.decode_ready_time,
                  r.kv_landed_time, r.transfer_time, r.kv_raw_bytes,
                  r.kv_wire_bytes, r.kv_compression, r.kv_decompress_cost)
                 for r in reqs], tier.fabric.stats)
    plain = run(FabricConfig(bandwidth=100.0, latency=0.1, chunk_bytes=300))
    locked = run(FabricConfig(bandwidth=100.0, latency=0.1, chunk_bytes=300,
                              adaptive=AdaptiveCompressionConfig(
                                  modes=("raw",))))
    assert plain == locked
    assert plain[1].wire_bytes_by_mode == {"raw": 6000}


def test_per_request_mode_stamps_match_per_mode_stats():
    """Every request's stamped wire mode groups its kv_wire_bytes into
    exactly the fabric's per-mode totals."""
    fab = KVFabric(FabricConfig(
        bandwidth=100.0, latency=0.0,
        adaptive=AdaptiveCompressionConfig(escalate_backlog_s=(5.0, 15.0),
                                           min_dwell=1)))
    reqs = _reqs(6)
    # serialized 1000-B transfers at 100 B/s: backlog grows ~10s per
    # recorded transfer, walking the ladder raw -> int8 -> int4
    for i, r in enumerate(reqs):
        fab.request(r, float(i), 1000)
    fab.resolve()
    modes = [r.wire_mode for r in reqs]
    assert modes[0] == "raw" and modes[-1] == "int4"
    assert set(modes) == {"raw", "int8", "int4"}
    by_mode = {}
    for r in reqs:
        by_mode[r.wire_mode] = by_mode.get(r.wire_mode, 0) + r.kv_wire_bytes
    assert by_mode == fab.stats.wire_bytes_by_mode
    assert sum(by_mode.values()) == fab.stats.kv_bytes_moved
    assert fab.stats.raw_bytes_by_mode == {
        m: 1000 * modes.count(m) for m in set(modes)}
    assert fab.stats.n_transfers_by_mode == {
        m: modes.count(m) for m in set(modes)}
    assert fab.stats.n_mode_switches == 2
    # compressed requests carry their decode-side dequant cost, raw none
    for r in reqs:
        assert (r.kv_decompress_cost > 0) == (r.kv_compression is not None)


def test_adaptive_worker_charges_compress_only_when_quantizing():
    """The worker's clock pays the quantize kernel only for transfers the
    policy actually compressed; an idle fabric ships raw for free."""
    fab = FabricConfig(
        bandwidth=10.0, latency=0.0,
        adaptive=AdaptiveCompressionConfig(
            escalate_backlog_s=(50.0, 1e9), min_dwell=1,
            mem_bw=1000.0, kernel_overhead=0.1))
    w = _worker(PrefillConfig(n_workers=1, fabric=fab))
    # both at t=0: first transfer sees an empty channel (raw), the second
    # sees the first's 100s wire backlog and quantizes
    reqs = _reqs(2)
    w.submit(reqs)
    w.drain()
    assert reqs[0].wire_mode == "raw"
    assert reqs[1].wire_mode == "int8"
    comp = KVCompressionConfig(mode="int8", mem_bw=1000.0,
                               kernel_overhead=0.1)
    assert w.stats.compress_time == pytest.approx(comp.compress_time(1000))
    assert reqs[1].kv_wire_bytes == comp.wire_bytes(1000)


# ---------------------------------------------------------------------------
# joint autoscaler: the compression axis
# ---------------------------------------------------------------------------


def _hot_prefill_args():
    """prefill blowing its SLO share, decode comfortable, pool exhausted."""
    return dict(n_prefill=1, n_decode=3, prefill_backlog=9, decode_backlog=1)


def _exhausted_joint(policy=None):
    budget = HardwareBudget(BudgetConfig(total_accelerators=4))
    budget.allocate("prefill")
    for _ in range(3):
        budget.allocate("decode")
    return JointAutoscaler(JointAutoscalerConfig(cooldown_intervals=0),
                           SLOConfig(ttft_p95=1.0), budget,
                           comp_policy=policy)


def test_mode_escalation_fires_before_replica_trade():
    """Budget exhausted + prefill hot + wire pressured: with ceiling
    headroom the autoscaler raises the compression ceiling and does NOT
    trade; only once the ladder is exhausted does the trade fire."""
    policy = AdaptiveCompressionPolicy(AdaptiveCompressionConfig(
        initial_ceiling=0))
    a = _exhausted_joint(policy)
    args = _hot_prefill_args()
    for step, ceiling in ((1, "int8"), (2, "int4")):
        assert a.decide(float(step), [0.6] * 20, [], [0.05] * 20,
                        [0.9] * 20, fabric_lag_s=1.0, **args) == (0, 0)
        h = a.history[-1]
        assert h.d_comp == 1 and h.comp_ceiling == ceiling
        assert h.fabric_lag_s == 1.0
    # ladder exhausted: now the replica trade happens
    assert a.decide(3.0, [0.6] * 20, [], [0.05] * 20, [0.9] * 20,
                    fabric_lag_s=1.0, **args) == (1, -1)
    assert a.history[-1].d_comp == 0


def test_no_escalation_when_wire_is_not_the_pressure():
    """Prefill hot but the fabric horizon is clear (compute-bound): adding
    quantization would only add prefill compute, so the policy is left
    alone and the trade fires directly."""
    policy = AdaptiveCompressionPolicy(AdaptiveCompressionConfig(
        initial_ceiling=0))
    a = _exhausted_joint(policy)
    assert a.decide(1.0, [0.6] * 20, [], [0.05] * 20, [0.9] * 20,
                    fabric_lag_s=0.0, **_hot_prefill_args()) == (1, -1)
    assert policy.ceiling == 0 and a.history[-1].d_comp == 0


def test_both_tiers_hot_and_exhausted_escalates_instead_of_stalling():
    """Both tiers hot, pool full, wire pressured: no tier may be robbed,
    but shrinking wire bytes helps both — the ceiling is raised where the
    policy-less autoscaler could only do nothing."""
    policy = AdaptiveCompressionPolicy(AdaptiveCompressionConfig(
        initial_ceiling=0))
    both_hot = dict(n_prefill=1, n_decode=3, prefill_backlog=9,
                    decode_backlog=99)
    a = _exhausted_joint(policy)
    assert a.decide(1.0, [2.0] * 20, [], [0.8] * 20, [0.9] * 20,
                    fabric_lag_s=1.0, **both_hot) == (0, 0)
    assert a.history[-1].d_comp == 1 and policy.ceiling_mode == "int8"
    # without wire pressure (compute-bound) the window still stalls
    a2 = _exhausted_joint(AdaptiveCompressionPolicy(
        AdaptiveCompressionConfig(initial_ceiling=0)))
    assert a2.decide(1.0, [2.0] * 20, [], [0.8] * 20, [0.9] * 20,
                     fabric_lag_s=0.0, **both_hot) == (0, 0)
    assert a2.history[-1].d_comp == 0


def test_ceiling_relaxes_in_quiet_windows_down_to_its_bind_floor():
    """Quiet windows hand back the headroom the autoscaler granted — one
    level per window, stopping at the ceiling the policy was bound with."""
    policy = AdaptiveCompressionPolicy(AdaptiveCompressionConfig(
        initial_ceiling=0))
    a = _exhausted_joint(policy)
    a.decide(1.0, [0.6] * 20, [], [0.05] * 20, [0.9] * 20,
             fabric_lag_s=1.0, **_hot_prefill_args())   # ceiling -> int8
    assert policy.ceiling_mode == "int8"
    policy.decide(100.0)                 # live at int8
    quiet = dict(n_prefill=1, n_decode=1, prefill_backlog=2,
                 decode_backlog=2, fabric_lag_s=0.0)
    assert a.decide(2.0, [0.4] * 20, [0.001] * 20, [0.3] * 20, [0.3] * 20,
                    **quiet) == (0, 0)
    h = a.history[-1]
    assert h.d_comp == -1 and h.comp_ceiling == "raw"
    assert policy.mode == "raw"          # live level clamped with it
    # at the bind floor: a further quiet window takes nothing more
    assert a.decide(3.0, [0.4] * 20, [0.001] * 20, [0.3] * 20, [0.3] * 20,
                    **quiet) == (0, 0)
    assert a.history[-1].d_comp == 0


def test_relax_never_lowers_a_ceiling_it_did_not_raise():
    """A fabric that owns its full ladder (initial_ceiling=None) is not
    quietly ratcheted down to raw by idle warm-up windows."""
    policy = AdaptiveCompressionPolicy(AdaptiveCompressionConfig())
    a = _exhausted_joint(policy)
    for step in range(1, 4):
        a.decide(float(step), [0.4] * 20, [0.001] * 20, [0.3] * 20,
                 [0.3] * 20, n_prefill=1, n_decode=1, prefill_backlog=2,
                 decode_backlog=2, fabric_lag_s=0.0)
        assert a.history[-1].d_comp == 0
    assert policy.ceiling == policy.top


def _decompress_pressure_args(util):
    """Neither tier hot or cold-releasable, wire busy (quiet-relax gated
    off), decode paying `util` of its window to KV dequant."""
    return dict(ttfts=[0.4] * 20, tpots=[0.001] * 20,
                decode_waits=[0.3] * 20, prefill_lags=[0.3] * 20,
                n_prefill=1, n_decode=1, prefill_backlog=2,
                decode_backlog=2, fabric_lag_s=1.0, decompress_util=util)


def test_sustained_decompress_pressure_relaxes_ceiling_one_level():
    """ROADMAP carry-over bugfix: decompress_util above the cold threshold
    vetoes dec_cold, and a busy wire vetoes the quiet-relax branch — so a
    raised ceiling used to stay raised forever while decode burned a
    quarter of every window dequantizing.  Two consecutive pressured
    windows must now relax one level (and stop at the bind floor)."""
    policy = AdaptiveCompressionPolicy(AdaptiveCompressionConfig(
        initial_ceiling=0))
    a = _exhausted_joint(policy)
    a.decide(1.0, [0.6] * 20, [], [0.05] * 20, [0.9] * 20,
             fabric_lag_s=1.0, **_hot_prefill_args())   # ceiling -> int8
    assert policy.ceiling_mode == "int8"
    # first pressured window: not yet "sustained" — no relax
    assert a.decide(2.0, **_decompress_pressure_args(0.3)) == (0, 0)
    assert a.history[-1].d_comp == 0 and policy.ceiling_mode == "int8"
    # second consecutive window above threshold: relax one level
    assert a.decide(3.0, **_decompress_pressure_args(0.3)) == (0, 0)
    h = a.history[-1]
    assert h.d_comp == -1 and h.comp_ceiling == "raw"
    # at the bind floor: continued pressure takes nothing more
    assert a.decide(4.0, **_decompress_pressure_args(0.3)) == (0, 0)
    assert a.history[-1].d_comp == 0 and policy.ceiling == 0


def test_decompress_spike_alone_does_not_relax():
    """A single pressured window (spike) resets when the next window is
    clean — only *sustained* pressure moves the ceiling."""
    policy = AdaptiveCompressionPolicy(AdaptiveCompressionConfig(
        initial_ceiling=0))
    a = _exhausted_joint(policy)
    a.decide(1.0, [0.6] * 20, [], [0.05] * 20, [0.9] * 20,
             fabric_lag_s=1.0, **_hot_prefill_args())   # ceiling -> int8
    for t, util in ((2.0, 0.3), (3.0, 0.0), (4.0, 0.3)):
        a.decide(t, **_decompress_pressure_args(util))
        assert a.history[-1].d_comp == 0
    assert policy.ceiling_mode == "int8"


# ---------------------------------------------------------------------------
# fabric edge-case bugfixes
# ---------------------------------------------------------------------------


def test_zero_byte_handoff_lands_at_ready_with_no_channel_traffic():
    """An empty KV has nothing to ship: it lands at ready_at (no wire
    round-trip), emits no chunk, pays no per-chunk latency, and leaves
    the channel free."""
    fab = KVFabric(FabricConfig(bandwidth=100.0, latency=0.5))
    r0, r1 = _reqs(2)
    fab.request(r0, 3.0, 0)
    fab.resolve()
    assert r0.decode_ready_time == 3.0 and r0.kv_landed_time == 3.0
    assert r0.transfer_time == 0.0
    assert r0.kv_raw_bytes == 0 and r0.kv_wire_bytes == 0
    assert fab.stats.n_chunks == 0 and fab.stats.n_transfers == 0
    assert fab.stats.busy_time == 0.0 and fab.free_at == 0.0
    # a real transfer afterwards is not queued behind phantom chunks
    fab.request(r1, 0.0, 100)
    fab.resolve()
    assert r1.decode_ready_time == pytest.approx(1.5)
    # and through a worker: decode-ready == prefill-done, no compression
    w = _worker(PrefillConfig(n_workers=1, fabric=FabricConfig(
        bandwidth=100.0, latency=0.5,
        adaptive=AdaptiveCompressionConfig())), kv=0)
    reqs = _reqs(1)
    w.submit(reqs)
    w.drain()
    assert reqs[0].decode_ready_time == reqs[0].prefill_done_time == 1.0
    assert w.stats.compress_time == 0.0


def test_fabric_config_validation_latency_and_exclusivity():
    with pytest.raises(ValueError):
        FabricConfig(latency=-1e-6)
    with pytest.raises(ValueError):
        FabricConfig(compression=KVCompressionConfig(mode="int8"),
                     adaptive=AdaptiveCompressionConfig())
    FabricConfig(latency=0.0)            # zero is a valid ideal channel


def test_joint_autoscaler_rejects_budget_below_tier_floors():
    budget = HardwareBudget(BudgetConfig(total_accelerators=3))
    with pytest.raises(ValueError, match="tier floors"):
        JointAutoscaler(JointAutoscalerConfig(min_prefill=2, min_decode=2),
                        SLOConfig(), budget)
    big = HardwareBudget(BudgetConfig(total_accelerators=2,
                                      prefill_accels_per_worker=2))
    with pytest.raises(ValueError, match="tier floors"):
        JointAutoscaler(JointAutoscalerConfig(), SLOConfig(), big)


def test_run_joint_autoscaled_rejects_oversized_initial_split():
    """A fleet whose starting split does not fit the pool fails fast with
    a clear ValueError instead of a mid-run MemoryError."""
    from repro.configs import get_config
    from repro.serving.router import FleetConfig
    from repro.serving.simulator import run_elastic_study
    from repro.serving.workload import WorkloadSpec, make_workload

    cfg = get_config("mistral-7b")
    reqs = make_workload(WorkloadSpec(n_requests=4, n_adapters=4))
    with pytest.raises(ValueError, match="initial split"):
        run_elastic_study(
            cfg, "jd", 4, reqs,
            FleetConfig(n_replicas=3, policy="cluster_affinity"),
            prefill_cfg=PrefillConfig(n_workers=3),
            budget_cfg=BudgetConfig(total_accelerators=4))


def test_kv_bytes_per_token_helper():
    assert kv_bytes_per_token(1024, 8) == 128
    assert kv_bytes_per_token(1000, 8) is None     # 125 B/token is odd
    assert kv_bytes_per_token(1000, 3) is None     # does not divide
    assert kv_bytes_per_token(0, 8) is None
    assert kv_bytes_per_token(1024, 0) is None


# ---------------------------------------------------------------------------
# acceptance: the 2 GB/s bursty sweep
# ---------------------------------------------------------------------------


def test_adaptive_beats_every_static_mode_on_bursty_2g_sweep():
    """On the 2 GB/s bursty cells the adaptive policy's p95 TTFT is <=
    every static mode's and strictly below raw, while its quantized wire
    volume stays strictly below always-int4's."""
    from benchmarks.adaptive_compression import (adaptive_cell,
                                                 adaptive_workload,
                                                 quantized_wire_bytes)
    from repro.configs import get_config

    cfg = get_config("mistral-7b")
    reqs = adaptive_workload(burst_cv=4.0)
    static = {
        name: adaptive_cell(cfg, reqs, 2e9, compression=comp)
        for name, comp in (("raw", None),
                           ("int8", KVCompressionConfig(mode="int8")),
                           ("int4", KVCompressionConfig(mode="int4")))}
    adaptive = adaptive_cell(cfg, reqs, 2e9,
                             adaptive=AdaptiveCompressionConfig())
    p95 = {k: v.total.ttft_pct(95) for k, v in static.items()}
    ap95 = adaptive.total.ttft_pct(95)
    assert all(ap95 <= v for v in p95.values()), (ap95, p95)
    assert ap95 < p95["raw"]
    q_adaptive = quantized_wire_bytes(adaptive.to_dict())
    q_int4 = quantized_wire_bytes(static["int4"].to_dict())
    assert 0 < q_adaptive < q_int4
    # the ladder was actually walked: some transfers shipped raw
    by_mode = adaptive.to_dict()["kv_wire_bytes_by_mode"]
    assert by_mode.get("raw", 0) > 0 and by_mode.get("int4", 0) > 0


def test_raw_locked_sweep_cell_bit_exact_with_pr4_baseline():
    """The raw-locked adaptive cell reproduces PR 4's kvcomp raw chunked
    cell (committed BENCH_kvcomp baseline) bit-exactly."""
    import json
    import pathlib
    from benchmarks.adaptive_compression import (adaptive_cell,
                                                 adaptive_workload)
    from repro.configs import get_config

    cfg = get_config("mistral-7b")
    reqs = adaptive_workload(burst_cv=4.0)
    locked = adaptive_cell(cfg, reqs, 2e9,
                           adaptive=AdaptiveCompressionConfig(
                               modes=("raw",)))
    baseline_path = (pathlib.Path(__file__).parent.parent
                     / "benchmarks" / "baselines" / "BENCH_kvcomp.json")
    with open(baseline_path) as f:
        baseline = json.load(f)
    assert locked.total.throughput_rps == pytest.approx(
        baseline["kvcomp_zipf1.0_bw2g_raw"]["rps"], rel=1e-12)


def test_joint_compression_axis_beats_raw_locked_budget_cell():
    """On the budget-6 joint cell the compression axis (ceiling raised
    under wire pressure before replica trades) strictly beats the same
    cell raw-locked, and the escalations are on the record."""
    from benchmarks.adaptive_compression import (adaptive_workload,
                                                 joint_axis_cell)
    from repro.configs import get_config

    cfg = get_config("mistral-7b")
    reqs = adaptive_workload(burst_cv=4.0)
    axis = joint_axis_cell(cfg, reqs, 2e9)
    locked = joint_axis_cell(cfg, reqs, 2e9, raw_locked=True)
    assert axis.total.ttft_pct(95) < locked.total.ttft_pct(95)
    assert axis.total.throughput_rps > locked.total.throughput_rps
    raises = [h for h in axis.autoscaler if h.d_comp > 0]
    assert len(raises) == 2              # raw -> int8 -> int4
    assert [h.comp_ceiling for h in raises] == ["int8", "int4"]
    # escalations happened while the pool was exhausted, i.e. they were
    # taken INSTEAD of a same-window trade
    assert all(h.free_accels == 0 and h.d_prefill == 0 and h.d_decode == 0
               for h in raises)
    assert not any(h.d_comp for h in locked.autoscaler)


def _req(rid=0, arrival=0.0):
    return Request(rid=rid, adapter_id=0, prompt_len=8, max_new_tokens=2,
                   arrival_time=arrival)


def test_dataclass_replace_keeps_wire_fields_off():
    """Workload copies used across cells must not leak per-cell stamps."""
    r = dataclasses.replace(_req())
    assert r.kv_compression is None and r.kv_wire_bytes == 0
    assert r.wire_mode == "raw"
