"""Serving engine invariants + throughput-study sanity."""

import pytest

from repro.configs import get_config
from repro.serving.adapter_cache import AdapterCache, CacheConfig
from repro.serving.engine import (CostModelExecutor, EngineConfig,
                                  ModelFootprint, ServingEngine,
                                  ServingHardware)
from repro.serving.request import Request
from repro.serving.scheduler import Scheduler, SchedulerConfig
from repro.serving.simulator import (WorkloadConfig, compression_setting,
                                     make_workload, run_throughput_study)


def _engine(mode="lora", n_adapters=16, budget=None, max_batch=8):
    cfg = get_config("mistral-7b")
    fp = ModelFootprint.from_config(cfg)
    ex = CostModelExecutor(ServingHardware(), fp, mode,
                           {a: 0 for a in range(n_adapters)})
    budget = budget or 4 * fp.lora_bytes_per_adapter
    eng = ServingEngine(EngineConfig(
        scheduler=SchedulerConfig(max_batch=max_batch),
        adapter_budget_bytes=budget, mode=mode), ex)
    return eng, fp


def test_all_requests_served_exactly_once():
    eng, _ = _engine()
    reqs = make_workload(WorkloadConfig(n_requests=100, n_adapters=16))
    eng.submit(reqs)
    stats = eng.run()
    assert stats.n_requests == 100
    assert all(r.done and r.finish_time is not None for r in reqs)
    assert stats.n_tokens == sum(r.max_new_tokens for r in reqs)


def test_cache_capacity_never_exceeded():
    cfg = CacheConfig(capacity_bytes=1000)
    c = AdapterCache(cfg)
    for i in range(50):
        c.ensure(i % 7, 300, now=float(i))
        assert c.used_bytes <= 1000
    with pytest.raises(MemoryError):
        c.ensure(99, 2000, now=0.0)


def test_pinned_shared_counts_against_budget():
    c = AdapterCache(CacheConfig(capacity_bytes=1000))
    c.pin_shared(800)
    c.ensure(0, 150, now=0.0)
    assert c.used_bytes == 950
    with pytest.raises(MemoryError):
        c.pin_shared(300)


def test_swap_count_grows_with_adapter_pressure():
    eng_small, fp = _engine(budget=2 * 1)  # tiny budget => swaps every time
    eng_small.cache.cfg = CacheConfig(2 * fp.lora_bytes_per_adapter)
    eng_small.cache.cfg = CacheConfig(2 * fp.lora_bytes_per_adapter)
    eng_big, _ = _engine(budget=64 * fp.lora_bytes_per_adapter)
    wl = WorkloadConfig(n_requests=200, n_adapters=32)
    eng2, _ = _engine(budget=2 * fp.lora_bytes_per_adapter)
    eng2.submit(make_workload(wl))
    s_small = eng2.run()
    eng_big.submit(make_workload(wl))
    s_big = eng_big.run()
    assert s_small.n_swaps > s_big.n_swaps
    assert s_small.throughput_rps < s_big.throughput_rps


def test_scheduler_prefers_resident_and_cluster():
    sched = Scheduler(SchedulerConfig(max_batch=2, cluster_aware=True),
                      cluster_of={0: 0, 1: 0, 2: 1})
    running = [Request(rid=0, adapter_id=0, prompt_len=8, max_new_tokens=4)]
    waiting = [Request(rid=1, adapter_id=2, prompt_len=8, max_new_tokens=4,
                       arrival_time=0.0),
               Request(rid=2, adapter_id=1, prompt_len=8, max_new_tokens=4,
                       arrival_time=1.0)]
    picked = sched.admit(running, waiting, resident=set(), now=2.0)
    # adapter 1 shares cluster 0 with the running adapter 0 => preferred
    assert picked[0].adapter_id == 1


def test_jd_mode_no_swaps_at_scale():
    cfg = get_config("mistral-7b")
    setting = compression_setting(1024)
    fp = ModelFootprint.from_config(cfg, jd_rank=setting["rank"],
                                    n_clusters=setting["clusters"])
    cluster_of = {a: a % setting["clusters"] for a in range(1024)}
    ex = CostModelExecutor(ServingHardware(), fp, "jd", cluster_of)
    budget = (fp.jd_shared_bytes_per_cluster * setting["clusters"]
              + 1024 * fp.jd_sigma_bytes_per_adapter) * 1.05
    eng = ServingEngine(EngineConfig(
        scheduler=SchedulerConfig(max_batch=16),
        adapter_budget_bytes=budget, mode="jd"), ex, cluster_of)
    eng.submit(make_workload(WorkloadConfig(n_requests=300,
                                            n_adapters=1024)))
    stats = eng.run()
    # all sigmas fit: after warm-up there is no further swapping
    assert stats.n_swaps <= 1024
    assert stats.swap_time < 0.05 * stats.wall_time


def test_throughput_ratio_grows_with_n():
    cfg = get_config("mistral-7b")
    rows = run_throughput_study(
        cfg, [4, 256], WorkloadConfig(n_requests=150, new_tokens=10))
    r4, r256 = rows[0], rows[1]
    assert r256["throughput_ratio_jd_vs_lora"] > r4["throughput_ratio_jd_vs_lora"]
    assert r256["jd_frac_of_single"] > 0.8     # paper: >= 80% of single-LoRA


def test_single_replica_uniform_reproduces_seed_numbers():
    """The fleet refactor keeps the original single-replica uniform study as
    a special case: these values were captured from the pre-fleet seed code
    and must keep reproducing (tolerance covers float noise only)."""
    cfg = get_config("mistral-7b")
    rows = run_throughput_study(
        cfg, [4, 64, 256], WorkloadConfig(n_requests=150, new_tokens=10))
    seed = {4: (146.11467216655996, 111.18997706172227),
            64: (145.1476526239968, 56.26989433898296),
            256: (144.9412976690654, 50.259192942710385)}
    for row in rows:
        jd_rps, lora_rps = seed[row["n_adapters"]]
        assert row["jd"]["throughput_rps"] == pytest.approx(jd_rps, rel=1e-9)
        assert row["lora"]["throughput_rps"] == pytest.approx(lora_rps,
                                                              rel=1e-9)
        assert row["single"]["throughput_rps"] == pytest.approx(
            145.66018734248797, rel=1e-9)
