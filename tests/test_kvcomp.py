"""Compressed-KV wire transfer: Pallas quant kernels vs JAX oracles,
fabric wire accounting, decode-side decompression, autoscaler coupling,
cross-tier prefetch, and the transfer-bound acceptance sweep."""
import pytest

from repro.serving.adapter_cache import AdapterCache, CacheConfig, DMAModel
from repro.serving.autoscaler import (JointAutoscaler, JointAutoscalerConfig,
                                      SLOConfig)
from repro.serving.engine import EngineConfig, ServingEngine
from repro.serving.prefill import PrefillConfig, PrefillTier, PrefillWorker
from repro.serving.request import Request
from repro.serving.resources import (BudgetConfig, FabricConfig,
                                     HardwareBudget, KVCompressionConfig)
from repro.serving.router import Fleet, FleetConfig
from repro.serving.scheduler import SchedulerConfig

jax = pytest.importorskip("jax")
import jax.numpy as jnp                                       # noqa: E402
import numpy as np                                            # noqa: E402

from repro.kernels import kv_quant as KQ                      # noqa: E402
from repro.kernels.ref import kv_dequant_ref, kv_quant_ref    # noqa: E402


# ---------------------------------------------------------------------------
# kernel vs oracle + round-trip properties
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("bits", [8, 4])
@pytest.mark.parametrize("T,C", [(128, 256), (64, 128), (32, 384)])
def test_kv_quant_kernel_matches_ref(bits, T, C):
    x = jax.random.normal(jax.random.PRNGKey(0), (T, C), jnp.float32)
    packed, scales = KQ.kv_quantize(x, bits=bits)
    q_ref, s_ref = kv_quant_ref(x, bits)
    np.testing.assert_allclose(np.asarray(scales), np.asarray(s_ref),
                               rtol=1e-6)
    out = KQ.kv_dequantize(packed, scales, bits=bits)
    ref = kv_dequant_ref(q_ref, s_ref)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-6)


@pytest.mark.parametrize("bits", [8, 4])
def test_roundtrip_error_bound(bits):
    """|dequant(quant(x)) - x| <= error_bound * per-channel absmax — the
    bound the serving config exports."""
    x = jax.random.normal(jax.random.PRNGKey(1), (128, 256), jnp.float32)
    packed, scales = KQ.kv_quantize(x, bits=bits)
    out = KQ.kv_dequantize(packed, scales, bits=bits)
    absmax = jnp.max(jnp.abs(x), axis=0, keepdims=True)
    err = jnp.max(jnp.abs(out - x) / absmax)
    assert float(err) <= KQ.ERROR_BOUND[bits] * (1 + 1e-5)


def test_int4_monotonically_worse_than_int8():
    x = jax.random.normal(jax.random.PRNGKey(2), (128, 256), jnp.float32)
    errs = {}
    for bits in (8, 4):
        packed, scales = KQ.kv_quantize(x, bits=bits)
        out = KQ.kv_dequantize(packed, scales, bits=bits)
        errs[bits] = (float(jnp.max(jnp.abs(out - x))),
                      float(jnp.mean((out - x) ** 2)))
    assert errs[4][0] > errs[8][0]       # max error strictly worse
    assert errs[4][1] > errs[8][1]       # and mean-squared error too


@pytest.mark.parametrize("bits", [8, 4])
def test_roundtrip_exact_on_already_quantized_grid(bits):
    """x on an exactly representable quantization grid (power-of-two scale)
    round-trips bit-exactly: dequant(quant(x)) == x."""
    qmax = KQ.QMAX[bits]
    rng = np.random.default_rng(3)
    k = rng.integers(-qmax, qmax + 1, size=(128, 128)).astype(np.float32)
    k[0, :] = qmax                       # pin the absmax so scale = 1/32
    x = jnp.asarray(k / 32.0)
    packed, scales = KQ.kv_quantize(x, bits=bits)
    out = KQ.kv_dequantize(packed, scales, bits=bits)
    assert jnp.array_equal(out, x)


def test_quant_validation():
    x = jnp.zeros((31, 128), jnp.float32)
    with pytest.raises(ValueError):
        KQ.kv_quantize(x, bits=4)        # odd token count cannot pack
    with pytest.raises(ValueError):
        KQ.kv_quantize(x, bits=2)
    # all-zero channels quantize to zero with a finite scale
    packed, scales = KQ.kv_quantize(jnp.zeros((32, 128)), bits=8)
    assert float(jnp.max(jnp.abs(KQ.kv_dequantize(packed, scales)))) == 0.0


def test_sim_constants_match_measured_kernel_artifacts():
    """The serving simulator's wire ratios / error bounds ARE the kernel's:
    measured off the packed artifacts, not tuned by hand."""
    for bits, mode in ((8, "int8"), (4, "int4")):
        assert KQ.measured_wire_ratio(bits) == \
            KVCompressionConfig.WIRE_RATIO[mode]
        assert KQ.WIRE_RATIO[bits] == KVCompressionConfig.WIRE_RATIO[mode]
        assert KQ.ERROR_BOUND[bits] == KVCompressionConfig.ERROR_BOUND[mode]


@pytest.mark.parametrize("bits,mode", [(8, "int8"), (4, "int4")])
@pytest.mark.parametrize("T", [128, 64, 32])
def test_block_granular_wire_bytes_match_packed_artifacts(bits, mode, T):
    """Token-aware sim wire bytes == the packed kernel artifact's bytes,
    including tail blocks smaller than the canonical 128 tokens (where the
    per-channel scale makes the ratio strictly worse than the full-block
    aggregate)."""
    C = 256
    x = jax.random.normal(jax.random.PRNGKey(0), (T, C), jnp.float32)
    packed, scales = KQ.kv_quantize(x, bits=bits)
    raw = 2 * T * C                      # bf16 on the wire without quant
    cfg = KVCompressionConfig(mode=mode)
    wire = cfg.wire_bytes(raw, bytes_per_token=2 * C)
    assert wire == packed.nbytes + scales.nbytes
    assert wire / raw == KQ.measured_wire_ratio(bits, n_tokens=T,
                                                n_channels=C)
    if T < KQ.BLOCK_T:                   # tail block: strictly worse ratio
        assert wire / raw > KVCompressionConfig.WIRE_RATIO[mode]


def test_default_mem_bw_matches_serving_hardware():
    """The (de)quant streaming bandwidth defaults to the same v5e slice
    HBM bandwidth the decode cost model uses — retuning one without the
    other would silently skew the compression trade."""
    from repro.serving.engine import ServingHardware

    assert KVCompressionConfig().mem_bw == ServingHardware().hbm_bw


# ---------------------------------------------------------------------------
# compression config + fabric wire accounting
# ---------------------------------------------------------------------------


def test_compression_config_validation():
    with pytest.raises(ValueError):
        KVCompressionConfig(mode="fp8")
    with pytest.raises(ValueError):
        KVCompressionConfig(mode="lowrank", lowrank_ratio=0.0)
    with pytest.raises(ValueError):
        KVCompressionConfig(mem_bw=0.0)
    c = KVCompressionConfig(mode="lowrank", lowrank_ratio=0.5)
    assert c.wire_ratio == 0.5 and c.error_bound is None


def test_compression_cost_arithmetic():
    c = KVCompressionConfig(mode="int8", mem_bw=1000.0, kernel_overhead=0.1)
    assert c.wire_bytes(1000) == 516     # ceil(1000 * 33/64)
    assert c.compress_time(1000) == pytest.approx(0.1 + 1516 / 1000.0)
    assert c.decompress_time(1000) == c.compress_time(1000)
    assert c.wire_bytes(0) == 0 and c.compress_time(0) == 0.0


class FixedCostExecutor:
    """Hand-computable executor: prefill 1s, decode step 0.5s, KV 1000 B."""

    def __init__(self, prefill=1.0, decode=0.5, kv=1000):
        self._prefill, self._decode, self._kv = prefill, decode, kv

    def adapter_bytes(self, aid):
        return 1

    def shared_bytes(self):
        return 0

    def decode_step_time(self, batch):
        return self._decode if batch else 0.0

    def prefill_time(self, req):
        return self._prefill

    def kv_bytes(self, req):
        return self._kv


def _free_cache():
    return AdapterCache(CacheConfig(1e9, DMAModel(bandwidth=1e30,
                                                  latency=0.0)))


def _worker(cfg, kv=1000):
    w = PrefillWorker(cfg, FixedCostExecutor(kv=kv))
    w.cache = _free_cache()
    return w


def _reqs(adapters, arrivals=None, new_tokens=2):
    arrivals = arrivals or [0.0] * len(adapters)
    return [Request(rid=i, adapter_id=a, prompt_len=8,
                    max_new_tokens=new_tokens, arrival_time=t)
            for i, (a, t) in enumerate(zip(adapters, arrivals))]


def test_compressed_handoff_shrinks_wire_and_charges_prefill():
    """1000-B KV, int8 (mem_bw=1000, overhead=0.1): compress takes
    0.1 + 1516/1000 = 1.616s on the worker clock, 516 wire bytes ship in
    5.16s at 100 B/s -> decode-ready at 1 + 1.616 + 5.16 = 7.776."""
    comp = KVCompressionConfig(mode="int8", mem_bw=1000.0,
                               kernel_overhead=0.1)
    fab = FabricConfig(bandwidth=100.0, latency=0.0, chunk_bytes=0,
                       compression=comp)
    w = _worker(PrefillConfig(n_workers=1, fabric=fab))
    reqs = _reqs([0])
    w.submit(reqs)
    w.drain()
    r = reqs[0]
    assert r.prefill_done_time == pytest.approx(2.616)
    assert r.kv_raw_bytes == 1000 and r.kv_wire_bytes == 516
    assert r.kv_compression == "int8"
    assert r.kv_decompress_cost == pytest.approx(1.616)
    assert r.decode_ready_time == pytest.approx(2.616 + 5.16)
    assert w.stats.compress_time == pytest.approx(1.616)
    assert w.stats.kv_bytes_moved == 516
    assert w.stats.kv_raw_bytes == 1000


def test_compressed_chunks_land_first_chunk_sooner():
    """Chunking is over raw token ranges: a 1000-B KV in 400-B raw chunks
    ships 208/208/104-wire-byte chunks under int8 — the first chunk (and
    every fair-interleave slot) shrinks by the wire ratio.  Wire sizes are
    block-granular: 400 raw bytes span two 256-byte channel-blocks, so the
    chunk pays two scales (200 values + 8) — strictly worse than the
    aggregate 33/64 ratio's ceil(400*33/64)=207."""
    comp = KVCompressionConfig(mode="int8", mem_bw=1e30, kernel_overhead=0.0)
    fab_c = FabricConfig(bandwidth=100.0, latency=0.0, chunk_bytes=400,
                         compression=comp)
    fab_r = FabricConfig(bandwidth=100.0, latency=0.0, chunk_bytes=400)
    out = {}
    for name, fab in (("int8", fab_c), ("raw", fab_r)):
        w = _worker(PrefillConfig(n_workers=1, fabric=fab))
        reqs = _reqs([0])
        w.submit(reqs)
        w.drain()
        out[name] = reqs[0]
    # raw: chunks 400/400/200 -> first at 1+4.0; int8 per-chunk wire:
    # 200+2*4=208 (x2), 100+4=104 (200 raw bytes fit one block: one scale)
    assert out["raw"].decode_ready_time == pytest.approx(5.0)
    assert out["int8"].decode_ready_time == pytest.approx(1.0 + 2.08)
    assert out["int8"].kv_wire_bytes == 208 + 208 + 104
    assert out["int8"].kv_landed_time < out["raw"].kv_landed_time


def test_compression_none_reproduces_pr3_chunk_timings_bit_exactly():
    """The PR-3 chunked-streaming arithmetic is untouched when compression
    is off: 100 B in 30-B chunks over 100 B/s with 0.1s per-chunk latency
    -> first chunk at 1.4, last at 2.4 (same numbers as PR 3's test)."""
    for fab in (FabricConfig(bandwidth=100.0, latency=0.1, chunk_bytes=30),
                FabricConfig(bandwidth=100.0, latency=0.1, chunk_bytes=30,
                             compression=None)):
        w = _worker(PrefillConfig(n_workers=1, fabric=fab), kv=100)
        reqs = _reqs([0])
        w.submit(reqs)
        w.drain()
        r = reqs[0]
        assert r.prefill_done_time == 1.0
        assert r.decode_ready_time == pytest.approx(1.0 + 0.1 + 0.3)
        assert r.kv_landed_time == pytest.approx(1.0 + 4 * 0.1 + 1.0)
        assert r.transfer_time == pytest.approx(1.4)
        assert r.kv_raw_bytes == r.kv_wire_bytes == 100
        assert r.kv_decompress_cost == 0.0 and r.kv_compression is None
        assert w.stats.n_chunks == 4
        assert w.stats.compress_time == 0.0


# ---------------------------------------------------------------------------
# decode-side decompression + autoscaler coupling
# ---------------------------------------------------------------------------


def test_decode_engine_charges_decompression_at_admission():
    eng = ServingEngine(EngineConfig(scheduler=SchedulerConfig(max_batch=4),
                                     adapter_budget_bytes=1e9),
                        FixedCostExecutor())
    eng.cache = _free_cache()
    r = Request(rid=0, adapter_id=0, prompt_len=8, max_new_tokens=2,
                arrival_time=0.0)
    r.prefilled = True
    r.decode_ready_time = 1.0
    r.kv_decompress_cost = 0.5
    eng.submit([r])
    stats = eng.run()
    # clock jumps to KV-ready (1.0), dequant charges 0.5, then two 0.5s
    # decode steps: first token at 2.0, finish at 2.5
    assert r.decompress_done_time == pytest.approx(1.5)
    assert r.first_token_time == pytest.approx(2.0)
    assert stats.decompress_time == pytest.approx(0.5)
    # raw requests pay nothing
    r2 = Request(rid=1, adapter_id=0, prompt_len=8, max_new_tokens=1)
    r2.prefilled = True
    r2.decode_ready_time = 10.0
    eng.submit([r2])
    eng.run()
    assert r2.decompress_done_time is None
    assert stats.decompress_time == pytest.approx(0.5)


def test_joint_autoscaler_decompress_util_vetoes_decode_cold():
    """A decode tier spending real time dequantizing compressed KV is never
    classified cold — the prefill-hot trade that would rob it must not
    fire, but it does once decompression load is off."""
    def fresh():
        budget = HardwareBudget(BudgetConfig(total_accelerators=4))
        budget.allocate("prefill")
        for _ in range(3):
            budget.allocate("decode")
        return JointAutoscaler(JointAutoscalerConfig(cooldown_intervals=0),
                               SLOConfig(ttft_p95=1.0), budget)

    args = dict(n_prefill=1, n_decode=3, prefill_backlog=9, decode_backlog=1)
    a = fresh()
    assert a.decide(1.0, [0.6] * 20, [], [0.05] * 20, [0.9] * 20,
                    decompress_util=0.5, **args) == (0, 0)
    assert a.history[-1].decompress_util == pytest.approx(0.5)
    a2 = fresh()
    assert a2.decide(1.0, [0.6] * 20, [], [0.05] * 20, [0.9] * 20,
                     decompress_util=0.0, **args) == (1, -1)


# ---------------------------------------------------------------------------
# cross-tier adapter prefetch
# ---------------------------------------------------------------------------


def _disagg_fleet(cross_tier_prefetch, budget_bytes=3.0):
    """2-decode-replica disagg fleet; decode caches fit `budget_bytes`
    1-byte adapters each."""
    pcfg = PrefillConfig(n_workers=1)
    tier = PrefillTier(pcfg, [_worker(pcfg)])
    engines = []
    for _ in range(2):
        eng = ServingEngine(
            EngineConfig(scheduler=SchedulerConfig(max_batch=4),
                         adapter_budget_bytes=budget_bytes),
            FixedCostExecutor())
        eng.cache = AdapterCache(CacheConfig(budget_bytes,
                                             DMAModel(bandwidth=1.0,
                                                      latency=0.0)))
        engines.append(eng)
    cfg = FleetConfig(n_replicas=2, policy="round_robin", disaggregated=True,
                      cross_tier_prefetch=cross_tier_prefetch)
    return Fleet(cfg, engines, prefill_tier=tier), engines


def test_cross_tier_prefetch_hints_decode_caches():
    """Hinted runs warm the decode replica's cache from prefill-admission
    knowledge: n_prefetches rises and the hinted adapter is resident (and
    still usable) by the time its KV lands."""
    fleet_off, eng_off = _disagg_fleet(False)
    fleet_on, eng_on = _disagg_fleet(True)
    reqs_a = _reqs([0, 1, 2, 3])
    reqs_b = _reqs([0, 1, 2, 3])
    fleet_off.submit(reqs_a)
    fleet_on.submit(reqs_b)
    assert sum(e.cache.n_prefetches for e in eng_off) == 0
    assert sum(e.cache.n_prefetches for e in eng_on) > 0
    # the hint is placed at prefill admission, a full prefill + transfer
    # ahead of the KV landing
    for r in reqs_b:
        assert eng_on[r.replica].cache.is_resident(r.adapter_id)
    fleet_off.run()
    fleet_on.run()
    # the warm cache turns the admission-time demand DMA stall into a
    # background load that completed during prefill+transfer: first tokens
    # come strictly sooner, never later
    on = [r.first_token_time for r in reqs_b]
    off = [r.first_token_time for r in reqs_a]
    assert all(a <= b for a, b in zip(on, off))
    assert sum(on) < sum(off)


def test_cross_tier_prefetch_never_evicts_demand_entries():
    fleet, engines = _disagg_fleet(True, budget_bytes=2.0)
    eng = engines[0]
    # two demand adapters fill the cache
    eng.cache.ensure(100, 1, 0.0)
    eng.cache.ensure(101, 1, 0.0)
    before = set(eng.cache.resident_ids)
    fleet.submit(_reqs([7, 8]))          # hints would need eviction: refused
    assert eng.cache.resident_ids == before
    assert eng.cache.n_prefetches == 0


# ---------------------------------------------------------------------------
# acceptance: the transfer-bound sweep
# ---------------------------------------------------------------------------


def test_compressed_streaming_lowers_p95_ttft_when_transfer_bound():
    """On the 2 GB/s fabric sweep, every quantized mode strictly lowers p95
    TTFT vs raw chunked streaming (and raw serial), while moving the
    kernel-measured fraction of the bytes."""
    from benchmarks.kv_compression import (CHUNK, compression_cell,
                                           transfer_bound_workload)
    from repro.configs import get_config

    cfg = get_config("mistral-7b")
    reqs = transfer_bound_workload(alpha=1.0)
    serial = compression_cell(cfg, reqs, 2e9, None, chunk_bytes=0)
    raw = compression_cell(cfg, reqs, 2e9, None)
    int8 = compression_cell(cfg, reqs, 2e9, KVCompressionConfig(mode="int8"))
    int4 = compression_cell(cfg, reqs, 2e9, KVCompressionConfig(mode="int4"))
    p95 = {name: s.total.ttft_pct(95)
           for name, s in [("serial", serial), ("raw", raw),
                           ("int8", int8), ("int4", int4)]}
    assert p95["int8"] < p95["raw"] < p95["serial"], p95
    assert p95["int4"] < p95["int8"], p95
    # wire accounting: same raw bytes produced, kernel-measured fraction
    # moved.  Block-granular scales make the aggregate ratio sit strictly
    # ABOVE the full-block 33/64 (prompts are not 128-token multiples, so
    # tail blocks pay full per-channel scales) but within the sub-1% scale
    # overhead a >=129-token prompt can add
    d_raw, d8 = raw.to_dict(), int8.to_dict()
    assert d8["kv_raw_bytes"] == d_raw["kv_raw_bytes"]
    assert d_raw["kv_bytes_moved"] == d_raw["kv_raw_bytes"]
    ratio = d8["kv_bytes_moved"] / d8["kv_raw_bytes"]
    assert KVCompressionConfig.WIRE_RATIO["int8"] < ratio
    assert ratio < KVCompressionConfig.WIRE_RATIO["int8"] * 1.02
    assert CHUNK == 1 << 24
    # decode replicas actually paid for dequantization
    assert d8["decompress_time_s"] > 0.0


def test_parity_cell_bit_exact_with_pr3_joint_baseline():
    """compression=None reproduces PR 3's BENCH_joint static3x3 cell."""
    import json
    import pathlib
    from benchmarks.kv_compression import parity_cell
    from repro.configs import get_config

    stats = parity_cell(get_config("mistral-7b"))
    baseline_path = (pathlib.Path(__file__).parent.parent
                     / "benchmarks" / "baselines" / "BENCH_joint.json")
    with open(baseline_path) as f:
        baseline = json.load(f)
    assert stats.total.throughput_rps == pytest.approx(
        baseline["joint_zipf1.0_b6_fab50g_static3x3"]["rps"], rel=1e-12)
