"""Dry-run pipeline smoke (reduced device count via subprocess) + results
integrity of the full 512-device sweep if present."""
import json
import os
import subprocess
import sys
from pathlib import Path

import pytest

RESULTS = Path(__file__).resolve().parents[1] / "results" / "dryrun"


def test_dryrun_cell_subprocess():
    env = {**os.environ, "PYTHONPATH": "src", "REPRO_DRYRUN_DEVICES": "256"}
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", "qwen3-1.7b",
         "--shape", "decode_32k", "--force", "--out", "/tmp/dryrun_test"],
        capture_output=True, text=True, env=env, cwd=".", timeout=580)
    assert r.returncode == 0, (r.stdout[-2000:], r.stderr[-2000:])
    rec = json.loads(Path(
        "/tmp/dryrun_test/qwen3-1.7b__decode_32k__pod16x16.json").read_text())
    assert rec["ok"]
    assert rec["roofline"]["flops_per_dev"] > 0
    assert rec["roofline"]["bottleneck"] in ("compute", "memory", "collective")


@pytest.mark.skipif(not RESULTS.exists(), reason="full sweep not run")
def test_full_sweep_complete_and_ok():
    recs = [json.loads(p.read_text()) for p in RESULTS.glob("*.json")]
    assert len(recs) >= 80
    bad = [r for r in recs if not r.get("ok")]
    assert not bad, [(r["arch"], r["shape"], r.get("error")) for r in bad]
    skips = [r for r in recs if r.get("skipped")]
    # exactly the documented long_500k skips (8 archs x 2 meshes)
    assert all(r["shape"] == "long_500k" for r in skips)
    assert len(skips) == 16


def test_hlo_cost_parser_on_reference():
    """Loop-aware parser exactly recovers flops of a known scanned matmul."""
    env = {**os.environ, "PYTHONPATH": "src"}
    script = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.launch.hlo_cost import analyze_hlo
from repro.launch.mesh import make_mesh_compat
mesh = make_mesh_compat((2, 4), ("data", "model"))
L, M, K, N = 8, 64, 128, 256
def f(x, w):
    def body(c, wl):
        return jnp.tanh(c @ wl), None
    y, _ = jax.lax.scan(body, x, w)
    return y.sum()
co = jax.jit(f, in_shardings=(NamedSharding(mesh, P("data", None)),
                              NamedSharding(mesh, P(None, None, "model")))
             ).lower(jax.ShapeDtypeStruct((M, K), jnp.float32),
                     jax.ShapeDtypeStruct((L, K, K), jnp.float32)).compile()
res = analyze_hlo(co.as_text())
expected = 2 * L * M * K * (K / 4) / 2   # per-device
assert abs(res["dot_flops_per_dev"] - expected) / expected < 0.05, res
print("parser ok", res["dot_flops_per_dev"], expected)
"""
    r = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, env=env, cwd=".", timeout=300)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "parser ok" in r.stdout
