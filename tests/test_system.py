"""End-to-end behaviour test of the paper's pipeline at reproducible scale:

  pretrain base on a task *family*  ->  train per-task LoRAs on NEW family
  members  ->  jointly compress (JD)  ->  compressed adapters preserve task
  performance (+high agreement)  ->  serving path equals offline logits.

This is the ICML paper's §5-§6 story on a reduced base model: real training,
real eval, real serving — no mocks.  Task family = sequence rotations: the
base learns the rotation *concept*, each LoRA learns a new rotation amount
(an attention-shift, exactly what q/k adapters express).
"""
import dataclasses as dc

import jax
import jax.numpy as jnp
import numpy as np
import pytest

# Fast-lane since the cheap fixture (ROADMAP "slow-lane promotion"): a
# 2-layer d64 model with a 64-token vocab trains the same rotation family
# in ~40s total, so the file no longer needs the slow tag.

from repro.configs import smoke_config
from repro.core import CompressionConfig, compress_bank, stack_bank
from repro.data import tasks as T
from repro.data.pipeline import mixture_loader
from repro.launch.train import train_lora_collection
from repro.models import transformer as tf
from repro.models.layers import logits_fwd
from repro.models.lora import LoRAContext
from repro.models.param import init_params
from repro.training.optimizer import AdamWConfig, init_opt_state
from repro.training.step import make_train_step

N_TASKS = 3
SEQ = 24


def rot_task(tid, want_k, in_len=8):
    for s in range(2000):
        spec = T.TaskSpec(task_id=tid, kind="rotate", seed=s, vocab=32,
                          in_len=in_len, instr_len=2)
        rng = np.random.default_rng(spec.seed)
        if int(rng.integers(1, in_len - 1)) == want_k:
            return spec
    raise AssertionError("no seed found")


EVAL_SPECS = None  # filled in fixture


@pytest.fixture(scope="module")
def trained(tmp_path_factory):
    # cheap fast-lane fixture: a d64/2-head/2-layer model with the vocab
    # cut to 64 (task tokens only reach id 36) learns the rotation family
    # in 150 pretrain + 120 LoRA steps — margins below were re-derived
    # from deterministic runs of THIS fixture
    out = tmp_path_factory.mktemp("loras")
    cfg = dc.replace(smoke_config("mistral-7b"), num_layers=2, d_model=64,
                     num_heads=2, num_kv_heads=1, d_ff=128, vocab_size=64)
    defs = tf.model_defs(cfg)
    base = init_params(defs, jax.random.PRNGKey(0))
    opt = init_opt_state(base)
    pre_specs = [rot_task(100 + i, k) for i, k in enumerate([1, 2, 4])]
    eval_specs = [rot_task(i, k) for i, k in enumerate([3, 5, 6])]
    gen = mixture_loader(pre_specs, 32, SEQ, base_seed=5)(0)
    step = jax.jit(make_train_step(cfg, AdamWConfig(lr=3e-3, warmup_steps=30,
                                                    total_steps=600)))
    for i in range(150):
        b = next(gen)
        base, opt, _ = step(base, opt, {k: jnp.asarray(v)
                                        for k, v in b.items()})
    train_lora_collection(cfg, N_TASKS, 120, batch=32, seq=SEQ,
                          out_dir=str(out), base_params=base,
                          specs=eval_specs, lr=1e-2, log_every=10_000)
    loras = []
    for t in range(N_TASKS):
        z = np.load(out / f"lora_task{t}.npz")
        tree = {"layers": {}}
        for k in z.files:
            parts = k.split("/")
            tree["layers"].setdefault(parts[1], {})[parts[2]] = jnp.asarray(z[k])
        loras.append(tree)
    return cfg, base, loras, eval_specs


def _predict_fn(cfg, base, lora_params, proto):
    def predict(tokens):
        h, _, _ = tf.forward(base, cfg, tokens=jnp.asarray(tokens),
                             mode="train", lora_params=lora_params,
                             lora_ctx_proto=proto)
        return np.asarray(jnp.argmax(logits_fwd(base["embed"], h, cfg), -1))
    return predict


def _task_loss(cfg, base, lora, proto, spec):
    b = {k: jnp.asarray(v) for k, v in T.batch_of(spec, 16, SEQ, 999).items()}
    return float(tf.lm_loss(base, b, cfg, lora_params=lora,
                            lora_ctx_proto=proto))


def _proto(cfg):
    return LoRAContext(mode="single", params=None,
                       scaling=cfg.lora.alpha / cfg.lora.rank)


def test_lora_training_learns_tasks(trained):
    cfg, base, loras, specs = trained
    for t in (0, 1):
        l_base = _task_loss(cfg, base, None, None, specs[t])
        l_lora = _task_loss(cfg, base, loras[t], _proto(cfg), specs[t])
        # margin re-derived on the cheap fixture: improvements are ~0.36
        # (t=0) / ~0.20 (t=1) on these seeds; 0.1 keeps 2x headroom while
        # still requiring a real training effect
        assert l_lora < l_base - 0.1, (t, l_base, l_lora)
    a_base = T.eval_token_accuracy(specs[0], _predict_fn(cfg, base, None, None),
                                   n=16, seq_len=SEQ)
    a_lora = T.eval_token_accuracy(
        specs[0], _predict_fn(cfg, base, loras[0], _proto(cfg)),
        n=16, seq_len=SEQ)
    # deterministic cheap fixture gives 0.069 -> 0.201; assert a real (not
    # float-noise) gain without re-tuning every RNG-stream change
    assert a_lora > a_base + 0.05, (a_base, a_lora)


def _compress(cfg, loras, method="jd_full", rank=None, diag_iters=25):
    """Joint compression of the collection, re-exported as per-task rank-r
    (a, b) pairs: a = Sigma_i V^T, b = U."""
    scale = cfg.lora.alpha / cfg.lora.rank
    rank = rank or 3 * cfg.lora.rank
    comp = [dict(layers={}) for _ in range(N_TASKS)]
    losses = []
    for tgt in loras[0]["layers"]:
        L = loras[0]["layers"][tgt]["a"].shape[0]
        for layer in range(L):
            pairs = [(loras[t]["layers"][tgt]["a"][layer],
                      loras[t]["layers"][tgt]["b"][layer] * scale)
                     for t in range(N_TASKS)]
            bank = stack_bank(pairs)
            cm = compress_bank(bank, CompressionConfig(
                method=method, rank=rank, iters=diag_iters))
            losses.append(cm.metrics["loss"])
            res = cm.result
            sig = res.sigma_full() if hasattr(res, "sigma_full") else res.sigma
            for t in range(N_TASKS):
                tr = comp[t]["layers"].setdefault(tgt, {"a": [], "b": []})
                tr["a"].append(sig[t] @ res.V.T)
                tr["b"].append(res.U)
    for t in range(N_TASKS):
        for tgt in comp[t]["layers"]:
            tr = comp[t]["layers"][tgt]
            comp[t]["layers"][tgt] = {
                "a": jnp.stack([jnp.asarray(x) for x in tr["a"]]),
                "b": jnp.stack([jnp.asarray(x) for x in tr["b"]])}
    return comp, float(np.mean(losses))


def test_compression_preserves_performance(trained):
    """Fig. 2/3 analogue: near-lossless joint rank keeps task metrics."""
    cfg, base, loras, specs = trained
    comp, recon = _compress(cfg, loras)
    assert recon < 0.05, recon       # n*r joint rank ~= lossless
    unit = LoRAContext(mode="single", params=None, scaling=1.0)
    for t in (0, 1):                 # two tasks keep the fast lane fast
        l_unc = _task_loss(cfg, base, loras[t], _proto(cfg), specs[t])
        l_comp = _task_loss(cfg, base, comp[t], unit, specs[t])
        assert l_comp <= l_unc + 0.1, (t, l_unc, l_comp)
        # agreement (§H.9): greedy generations match between compressed and
        # uncompressed adapters
        b = T.batch_of(specs[t], 16, SEQ, seed=424)
        p_unc = _predict_fn(cfg, base, loras[t], _proto(cfg))(b["tokens"])
        p_comp = _predict_fn(cfg, base, comp[t], unit)(b["tokens"])
        mask = b["targets"] >= 0
        agree = float((p_unc == p_comp)[mask].mean())
        assert agree > 0.9, (t, agree)


def test_aggressive_compression_degrades_gracefully(trained):
    """Rank sweep: reconstruction error grows as rank shrinks (Fig. 6)."""
    cfg, base, loras, specs = trained
    _, r_full = _compress(cfg, loras, rank=3 * cfg.lora.rank)
    _, r_half = _compress(cfg, loras, rank=cfg.lora.rank)
    _, r_tiny = _compress(cfg, loras, rank=4)
    assert r_full < r_half < r_tiny, (r_full, r_half, r_tiny)


def test_served_collection_matches_offline_logits(trained):
    """Batched multi-LoRA serving == offline single-adapter forward."""
    cfg, base, loras, specs = trained
    scale = cfg.lora.alpha / cfg.lora.rank
    n = N_TASKS
    bundles = {"layers": {}}
    for tgt in loras[0]["layers"]:
        A = jnp.stack([loras[t]["layers"][tgt]["a"] for t in range(n)], axis=1)
        B = jnp.stack([loras[t]["layers"][tgt]["b"] * scale
                       for t in range(n)], axis=1)
        bundles["layers"][tgt] = {"A": A, "B": B}
    key = jax.random.PRNGKey(9)
    toks = jax.random.randint(key, (1, 12), 0, cfg.vocab_size)
    cache = tf.init_cache(cfg, 1, 32)
    lg1, _ = tf.prefill(base, {"tokens": toks}, cfg, cache,
                        lora_params=loras[1], lora_ctx_proto=_proto(cfg))
    proto_b = LoRAContext(mode="batched", params=None,
                          ids=jnp.asarray([1], jnp.int32), scaling=1.0)
    cache2 = tf.init_cache(cfg, 1, 32)
    lg2, _ = tf.prefill(base, {"tokens": toks}, cfg, cache2,
                        lora_params=bundles, lora_ctx_proto=proto_b)
    np.testing.assert_allclose(np.asarray(lg1, np.float32),
                               np.asarray(lg2, np.float32),
                               rtol=0.05, atol=0.1)
