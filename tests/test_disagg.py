"""Disaggregated prefill tier, KV transfer link, elastic fleet membership,
and SLO-driven autoscaling."""
import numpy as np
import pytest

from repro.serving.adapter_cache import AdapterCache, CacheConfig, DMAModel
from repro.serving.autoscaler import (Autoscaler, AutoscalerConfig, SLOConfig,
                                      run_autoscaled)
from repro.serving.engine import EngineConfig, ServingEngine
from repro.serving.prefill import (PrefillConfig, PrefillTier, PrefillWorker,
                                   TransferLink)
from repro.serving.request import Request
from repro.serving.router import Fleet, FleetConfig
from repro.serving.scheduler import SchedulerConfig


class FixedCostExecutor:
    """Hand-computable executor: prefill 1s, decode step 0.5s, KV 100 B."""

    def __init__(self, prefill=1.0, decode=0.5, kv=100):
        self._prefill, self._decode, self._kv = prefill, decode, kv

    def adapter_bytes(self, aid):
        return 1

    def shared_bytes(self):
        return 0

    def decode_step_time(self, batch):
        return self._decode if batch else 0.0

    def prefill_time(self, req):
        return self._prefill

    def kv_bytes(self, req):
        return self._kv


def _free_cache():
    # zero-cost DMA so latency arithmetic is exact
    return AdapterCache(CacheConfig(1e9, DMAModel(bandwidth=1e30,
                                                  latency=0.0)))


def _worker(link=None, max_batch=8):
    cfg = PrefillConfig(n_workers=1, max_batch=max_batch,
                        adapter_budget_bytes=1e9,
                        link=link or TransferLink(bandwidth=100.0,
                                                  latency=0.0))
    w = PrefillWorker(cfg, FixedCostExecutor())
    w.cache = _free_cache()
    return w


def _engine(max_batch=8):
    eng = ServingEngine(
        EngineConfig(scheduler=SchedulerConfig(max_batch=max_batch),
                     adapter_budget_bytes=1e9),
        FixedCostExecutor())
    eng.cache = _free_cache()
    return eng


def _reqs(adapters, arrivals=None, new_tokens=2):
    arrivals = arrivals or [0.0] * len(adapters)
    return [Request(rid=i, adapter_id=a, prompt_len=8,
                    max_new_tokens=new_tokens, arrival_time=t)
            for i, (a, t) in enumerate(zip(adapters, arrivals))]


# ---------------------------------------------------------------------------
# transfer link + prefill worker semantics
# ---------------------------------------------------------------------------


def test_transfer_link_cost():
    link = TransferLink(bandwidth=1000.0, latency=0.1)
    assert link.time_for(500) == pytest.approx(0.1 + 0.5)


def test_prefill_worker_serializes_compute_and_link():
    """2 requests at t=0: prefill 1s each (serialized); 100-byte KV over a
    100 B/s link (serialized per worker) -> ready at 2.0 and 3.0."""
    w = _worker()
    reqs = _reqs([0, 1])
    w.submit(reqs)
    w.drain()
    assert [r.prefill_done_time for r in reqs] == [1.0, 2.0]
    assert [r.decode_ready_time for r in reqs] == [2.0, 3.0]
    assert all(r.prefilled for r in reqs)
    assert w.stats.n_prefills == 2
    assert w.stats.kv_bytes_moved == 200
    assert w.stats.transfer_time == pytest.approx(2.0)


def test_prefill_worker_jumps_to_arrival():
    w = _worker()
    reqs = _reqs([0], arrivals=[5.0])
    w.submit(reqs)
    w.drain()
    assert reqs[0].prefill_done_time == 6.0
    assert reqs[0].decode_ready_time == 7.0


def test_prefilled_request_skips_engine_prefill():
    """A KV-shipped request enters decode without paying prefill again and
    is admitted no earlier than its KV-ready time."""
    eng = _engine()
    r = Request(rid=0, adapter_id=0, prompt_len=8, max_new_tokens=1,
                arrival_time=0.0, prefilled=True, decode_ready_time=2.0)
    eng.submit([r])
    eng.run()
    # admitted at 2.0 (ready time), first decode step ends 2.5: no 1s prefill
    assert r.first_token_time == pytest.approx(2.5)
    assert r.ttft == pytest.approx(2.5)       # vs original arrival


def test_ready_time_defaults_to_arrival():
    r = Request(rid=0, adapter_id=0, prompt_len=8, max_new_tokens=1,
                arrival_time=1.5)
    assert r.ready_time == 1.5
    r.decode_ready_time = 4.0
    assert r.ready_time == 4.0


def test_prefill_tier_routes_least_outstanding():
    cfg = PrefillConfig(n_workers=2, link=TransferLink(bandwidth=1e30,
                                                       latency=0.0))
    workers = [PrefillWorker(cfg, FixedCostExecutor()) for _ in range(2)]
    for w in workers:
        w.cache = _free_cache()
    tier = PrefillTier(cfg, workers)
    reqs = _reqs([0, 1, 2, 3])
    tier.process(reqs)
    assert {r.prefill_replica for r in reqs} == {0, 1}
    assert tier.stats.n_prefills == 4


# ---------------------------------------------------------------------------
# disaggregated fleet routing
# ---------------------------------------------------------------------------


def _disagg_fleet(n_decode, policy="round_robin", n_prefill=1):
    pcfg = PrefillConfig(n_workers=n_prefill,
                         link=TransferLink(bandwidth=100.0, latency=0.0))
    workers = [PrefillWorker(pcfg, FixedCostExecutor())
               for _ in range(n_prefill)]
    for w in workers:
        w.cache = _free_cache()
    tier = PrefillTier(pcfg, workers)
    fcfg = FleetConfig(n_replicas=n_decode, policy=policy,
                       disaggregated=True)
    return Fleet(fcfg, [_engine() for _ in range(n_decode)],
                 prefill_tier=tier)


def test_disagg_fleet_serves_all_exactly_once():
    f = _disagg_fleet(2)
    reqs = _reqs([0, 1, 2, 3], new_tokens=3)
    f.submit(reqs)
    stats = f.run()
    assert stats.total.n_requests == 4
    assert all(r.done and r.prefilled for r in reqs)
    # prefill tier stats surface in the merged dict
    d = stats.to_dict()
    assert d["n_prefills"] == 4 and d["kv_bytes_moved"] == 400
    # decode TTFT can never beat the KV arrival
    assert all(r.first_token_time > r.decode_ready_time for r in reqs)


def test_disagg_fleet_requires_tier():
    with pytest.raises(ValueError):
        Fleet(FleetConfig(n_replicas=1, disaggregated=True), [_engine()])


# ---------------------------------------------------------------------------
# elastic membership
# ---------------------------------------------------------------------------


def test_add_replica_receives_new_work():
    f = Fleet(FleetConfig(n_replicas=1, policy="round_robin"), [_engine()])
    f.submit(_reqs([0, 1]))
    f.add_replica(_engine(), now=0.0)
    late = _reqs([2, 3])
    late[0].rid, late[1].rid = 10, 11
    f.submit(late)
    assert {f.assignments[10], f.assignments[11]} == {0, 1}


def test_retired_replica_drains_but_gets_no_new_work():
    f = Fleet(FleetConfig(n_replicas=2, policy="round_robin"),
              [_engine(), _engine()])
    f.submit(_reqs([0, 1], new_tokens=2))
    queued = len(f.engines[1].waiting) + len(f.engines[1].running)
    f.retire_replica(1)
    late = _reqs([2, 3])
    late[0].rid, late[1].rid = 10, 11
    f.submit(late)
    assert f.assignments[10] == 0 and f.assignments[11] == 0
    stats = f.run()
    # the retired replica still finished what it had
    assert stats.per_replica[1].n_requests == queued
    assert stats.total.n_requests == 4


def test_membership_change_rehomes_clusters():
    cluster_of = {0: 100, 1: 100}        # one cluster, two adapters
    f = Fleet(FleetConfig(n_replicas=2, policy="cluster_affinity",
                          spill_requests=1e9), [_engine(), _engine()],
              cluster_of)
    f.submit(_reqs([0, 1]))
    home = f.assignments[0]
    assert f.assignments[1] == home      # sticky
    f.retire_replica(home)
    assert f._home == {}                 # re-homed on membership change
    late = _reqs([0])
    late[0].rid = 10
    f.submit(late)
    assert f.assignments[10] != home     # re-placed on the surviving replica


# ---------------------------------------------------------------------------
# autoscaler policy
# ---------------------------------------------------------------------------


def _scaler(**kw):
    cfg = AutoscalerConfig(min_replicas=1, max_replicas=4,
                           cooldown_intervals=1, **kw)
    return Autoscaler(cfg, SLOConfig(ttft_p95=1.0))


def test_autoscaler_scales_up_on_slo_violation():
    a = _scaler()
    assert a.decide(1.0, [2.0] * 20, [], n_active=2, backlog=10) == 1


def test_autoscaler_respects_max_and_cooldown():
    a = _scaler()
    assert a.decide(1.0, [2.0] * 20, [], n_active=4, backlog=10) == 0  # at max
    a2 = _scaler()
    assert a2.decide(1.0, [2.0] * 20, [], 2, 10) == 1
    # cooldown window: no change even though still violating
    assert a2.decide(2.0, [2.0] * 20, [], 3, 10) == 0
    assert a2.decide(3.0, [2.0] * 20, [], 3, 10) == 1


def test_autoscaler_scales_up_when_starved():
    a = _scaler()
    # no finishes at all but a backlog: the fleet is drowning
    assert a.decide(1.0, [], [], n_active=2, backlog=50) == 1


def test_autoscaler_scales_down_with_hysteresis():
    a = _scaler()
    # well under SLO (p95 = 0.1 < 0.4 * 1.0) and tiny backlog
    assert a.decide(1.0, [0.1] * 20, [], n_active=3, backlog=2) == -1
    # under SLO but above the down_fraction band: hold
    a2 = _scaler()
    assert a2.decide(1.0, [0.8] * 20, [], n_active=3, backlog=2) == 0
    # not below min
    a3 = _scaler()
    assert a3.decide(1.0, [0.1] * 20, [], n_active=1, backlog=0) == 0


def test_autoscaler_history_records_decisions():
    a = _scaler()
    a.decide(1.0, [2.0] * 20, [], 2, 10)
    a.decide(2.0, [0.5] * 20, [], 3, 1)
    assert [h.delta for h in a.history] == [1, 0]
    assert a.history[0].ttft_p95 == pytest.approx(2.0)


def test_run_autoscaled_adds_replicas_under_load():
    """Deterministic micro-scenario: 1 slow replica, a flood of arrivals;
    the driver must add replicas (SLO 0.1s, decode 0.5s => violation) and
    still serve everything exactly once."""
    f = Fleet(FleetConfig(n_replicas=1, policy="round_robin"), [_engine(1)])
    reqs = _reqs(list(range(12)), arrivals=[0.1 * i for i in range(12)],
                 new_tokens=1)
    scaler = Autoscaler(AutoscalerConfig(min_replicas=1, max_replicas=3,
                                         decision_interval=0.5,
                                         cooldown_intervals=0),
                        SLOConfig(ttft_p95=0.1))
    stats = run_autoscaled(f, reqs, scaler, lambda: _engine(1))
    assert stats.total.n_requests == 12
    assert len(f.engines) > 1                  # scaled up
    assert stats.scale_events > 0
    assert stats.n_replicas_final >= 1


# ---------------------------------------------------------------------------
# acceptance: autoscaled disaggregated jd fleet vs fixed 4-replica fleet
# ---------------------------------------------------------------------------


def test_autoscaled_disagg_meets_slo_fixed_fleet_misses():
    """Zipf(1.0) bursty (Gamma CV=4) arrivals at 256 adapters, decode-bound
    generations: the fixed 4-replica colocated jd fleet blows the 350 ms
    p95 TTFT SLO; the autoscaled disaggregated jd fleet meets it."""
    from benchmarks.disagg_throughput import (autoscaled_cell, bursty_workload,
                                              fixed_cell)
    from repro.configs import get_config
    from repro.serving.workload import make_workload

    cfg = get_config("mistral-7b")
    slo = 0.35
    wl = bursty_workload(n_requests=1200, alpha=1.0, seed=0)

    fixed = fixed_cell(cfg, wl, n_prefill=0, n_decode=4)
    auto = autoscaled_cell(cfg, wl, n_prefill=4, slo_ttft=slo)

    fixed_p95 = fixed.total.ttft_pct(95)
    auto_p95 = auto.total.ttft_pct(95)
    assert fixed_p95 > slo, fixed_p95          # fixed fleet misses ...
    assert auto_p95 <= slo, auto_p95           # ... autoscaled meets
    # and it's a genuine elastic run: replicas were added along the way
    assert auto.scale_events > 0
    assert auto.n_replicas_final > 2
    # same demand was served
    assert auto.total.n_requests == fixed.total.n_requests == 1200


def test_disagg_removes_prefill_head_of_line_blocking():
    """With matched prefill capacity, moving prefill off the decode
    replicas improves p95 TPOT (decode steps no longer wait for other
    requests' admission prefills)."""
    from benchmarks.disagg_throughput import bursty_workload, fixed_cell
    from repro.configs import get_config

    cfg = get_config("mistral-7b")
    wl = bursty_workload(n_requests=400, alpha=1.0, seed=0)
    colocated = fixed_cell(cfg, wl, n_prefill=0, n_decode=4)
    disagg = fixed_cell(cfg, wl, n_prefill=4, n_decode=4)
    assert disagg.total.tpot_pct(95) < colocated.total.tpot_pct(95)
