"""Unified resource manager: hardware budget, shared KV fabric with chunked
streaming handoff, symmetric tier elasticity, and joint autoscaling."""
import pytest

from repro.serving.adapter_cache import AdapterCache, CacheConfig, DMAModel
from repro.serving.autoscaler import (JointAutoscaler, JointAutoscalerConfig,
                                      SLOConfig)
from repro.serving.prefill import (PrefillConfig, PrefillTier, PrefillWorker,
                                   TransferLink)
from repro.serving.request import Request
from repro.serving.resources import (BudgetConfig, FabricConfig,
                                     HardwareBudget, KVFabric)


class FixedCostExecutor:
    """Hand-computable executor: prefill 1s, decode step 0.5s, KV 100 B."""

    def __init__(self, prefill=1.0, decode=0.5, kv=100):
        self._prefill, self._decode, self._kv = prefill, decode, kv

    def adapter_bytes(self, aid):
        return 1

    def shared_bytes(self):
        return 0

    def decode_step_time(self, batch):
        return self._decode if batch else 0.0

    def prefill_time(self, req):
        return self._prefill

    def kv_bytes(self, req):
        return self._kv


def _free_cache():
    # zero-cost DMA so latency arithmetic is exact
    return AdapterCache(CacheConfig(1e9, DMAModel(bandwidth=1e30,
                                                  latency=0.0)))


def _worker(cfg=None, fabric=None, kv=100):
    cfg = cfg or PrefillConfig(n_workers=1,
                               link=TransferLink(bandwidth=100.0,
                                                 latency=0.0))
    w = PrefillWorker(cfg, FixedCostExecutor(kv=kv), fabric=fabric)
    w.cache = _free_cache()
    return w


def _reqs(adapters, arrivals=None, new_tokens=2):
    arrivals = arrivals or [0.0] * len(adapters)
    return [Request(rid=i, adapter_id=a, prompt_len=8,
                    max_new_tokens=new_tokens, arrival_time=t)
            for i, (a, t) in enumerate(zip(adapters, arrivals))]


# ---------------------------------------------------------------------------
# hardware budget
# ---------------------------------------------------------------------------


def test_budget_allocate_release_ledger():
    b = HardwareBudget(BudgetConfig(total_accelerators=4))
    b.allocate("prefill")
    b.allocate("decode")
    b.allocate("decode")
    assert b.in_use == 3 and b.available == 1
    assert b.count("decode") == 2
    b.release("decode")
    assert b.available == 2


def test_budget_exhaustion_raises():
    b = HardwareBudget(BudgetConfig(total_accelerators=2))
    b.allocate("prefill")
    b.allocate("decode")
    assert not b.can_allocate("decode")
    with pytest.raises(MemoryError):
        b.allocate("decode")
    with pytest.raises(ValueError):
        HardwareBudget(BudgetConfig(total_accelerators=2)).release("prefill")


def test_budget_role_footprints():
    b = HardwareBudget(BudgetConfig(total_accelerators=6,
                                    prefill_accels_per_worker=2,
                                    decode_accels_per_replica=1))
    b.allocate("prefill")
    b.allocate("prefill")
    assert b.available == 2
    assert b.can_allocate("prefill")     # exactly one 2-accel worker fits
    b.allocate("decode")
    assert not b.can_allocate("prefill")  # 1 accel left < 2-accel footprint
    b.allocate("decode")
    assert not b.can_allocate("decode")


def test_joint_trade_respects_role_footprints():
    """A trade must not fire when retiring the donor frees fewer
    accelerators than the receiver's footprint needs (it would crash the
    driver's allocate)."""
    budget = HardwareBudget(BudgetConfig(total_accelerators=5,
                                         prefill_accels_per_worker=2,
                                         decode_accels_per_replica=1))
    budget.allocate("prefill")
    for _ in range(3):
        budget.allocate("decode")
    a = JointAutoscaler(JointAutoscalerConfig(cooldown_intervals=0),
                        SLOConfig(ttft_p95=1.0), budget)
    # prefill hot, decode cold: the 1-accel decode retire cannot fund a
    # 2-accel prefill worker -> no trade, no crash
    assert a.decide(1.0, [0.6] * 20, [], [0.05] * 20, [0.9] * 20,
                    n_prefill=1, n_decode=3,
                    prefill_backlog=9, decode_backlog=1) == (0, 0)
    # with one accel already free, retiring a decode replica is enough
    budget.release("decode")
    assert a.decide(2.0, [0.6] * 20, [], [0.05] * 20, [0.9] * 20,
                    n_prefill=1, n_decode=2,
                    prefill_backlog=9, decode_backlog=1) == (1, -1)


# ---------------------------------------------------------------------------
# fabric degenerate paths: PR-2 TransferLink equivalence
# ---------------------------------------------------------------------------


def test_single_worker_fabric_bit_exact_vs_pr2_link():
    """One worker on the fabric reproduces PR-2 TransferLink times exactly:
    2 requests at t=0, prefill 1s each (serialized), 100-byte KV over a
    100 B/s channel -> decode-ready at 2.0 and 3.0 (same arithmetic as the
    PR-2 per-worker serialized link)."""
    w = _worker()
    reqs = _reqs([0, 1])
    w.submit(reqs)
    w.drain()
    link = TransferLink(bandwidth=100.0, latency=0.0)
    assert [r.prefill_done_time for r in reqs] == [1.0, 2.0]
    assert reqs[0].decode_ready_time == 1.0 + link.time_for(100)
    assert reqs[1].decode_ready_time == 2.0 + link.time_for(100)
    assert [r.decode_ready_time for r in reqs] == [2.0, 3.0]
    assert [r.kv_landed_time for r in reqs] == [2.0, 3.0]
    assert w.stats.transfer_time == pytest.approx(2.0)
    assert w.stats.kv_bytes_moved == 200
    assert w.stats.n_chunks == 2          # serial: one chunk per handoff


def test_single_worker_fabric_bit_exact_with_latency():
    link = TransferLink(bandwidth=1000.0, latency=0.1)
    cfg = PrefillConfig(n_workers=1, link=link)
    w = _worker(cfg, kv=500)
    reqs = _reqs([0], arrivals=[5.0])
    w.submit(reqs)
    w.drain()
    assert reqs[0].prefill_done_time == 6.0
    assert reqs[0].decode_ready_time == pytest.approx(6.0 + link.time_for(500))
    assert reqs[0].decode_ready_time == pytest.approx(6.6)


def test_zero_chunk_and_one_chunk_degrade_to_serial():
    """chunk_bytes=0 (whole-KV handoff) and chunk_bytes >= nbytes (a single
    chunk) both produce the serial-path times."""
    results = []
    for chunk in (0, 100, 10_000):
        fab = FabricConfig(bandwidth=100.0, latency=0.05, chunk_bytes=chunk)
        w = _worker(PrefillConfig(n_workers=1, fabric=fab))
        reqs = _reqs([0, 1])
        w.submit(reqs)
        w.drain()
        results.append([(r.decode_ready_time, r.kv_landed_time)
                        for r in reqs])
    assert results[0] == results[1] == results[2]
    ready0, landed0 = results[0][0]
    assert ready0 == landed0 == pytest.approx(1.0 + 0.05 + 1.0)


def test_chunked_handoff_unblocks_decode_at_first_chunk():
    """100 bytes in 30-byte chunks over 100 B/s with 0.1s per-chunk latency:
    first chunk lands at 1.4 (decode-ready), the tail streams until 2.4 —
    vs 2.1 for the serial path (earlier start, more total channel time)."""
    fab = FabricConfig(bandwidth=100.0, latency=0.1, chunk_bytes=30)
    w = _worker(PrefillConfig(n_workers=1, fabric=fab))
    reqs = _reqs([0])
    w.submit(reqs)
    w.drain()
    r = reqs[0]
    assert r.prefill_done_time == 1.0
    assert r.decode_ready_time == pytest.approx(1.0 + 0.1 + 0.3)
    # chunks: 30/30/30/10 -> 4 latencies + 1s wire time
    assert r.kv_landed_time == pytest.approx(1.0 + 4 * 0.1 + 1.0)
    assert r.transfer_time == pytest.approx(1.4)
    assert w.stats.n_chunks == 4


def test_fabric_contention_across_workers():
    """Two workers finishing prefill simultaneously contend on the shared
    fabric: the second transfer queues behind the first (PR-2 private links
    would ship both in parallel)."""
    cfg = PrefillConfig(n_workers=2, link=TransferLink(bandwidth=100.0,
                                                       latency=0.0))
    workers = [_worker(cfg), _worker(cfg)]
    tier = PrefillTier(cfg, workers)
    reqs = _reqs([0, 1])             # one request per worker, both prefill 0->1
    tier.process(reqs)
    ready = sorted(r.decode_ready_time for r in reqs)
    assert ready == [2.0, 3.0]       # serialized: private links would give 2.0/2.0
    assert tier.stats.kv_bytes_moved == 200


def test_fabric_fair_interleave_bounds_hol_blocking():
    """A short handoff slips between a long transfer's chunks instead of
    waiting out the whole thing."""
    fab = FabricConfig(bandwidth=100.0, latency=0.0, chunk_bytes=50)
    fabric = KVFabric(fab)
    long_req = Request(rid=0, adapter_id=0, prompt_len=8, max_new_tokens=1)
    short_req = Request(rid=1, adapter_id=1, prompt_len=8, max_new_tokens=1)
    fabric.request(long_req, 0.0, 100)      # chunks at 0.5, 1.0
    fabric.request(short_req, 0.1, 10)      # ready mid-first-chunk
    fabric.resolve()
    assert long_req.decode_ready_time == pytest.approx(0.5)
    # short transfer goes next (fewest chunks sent), before the long tail
    assert short_req.decode_ready_time == pytest.approx(0.6)
    assert long_req.kv_landed_time == pytest.approx(1.1)


def test_fabric_backlog_carries_across_resolves():
    fab = KVFabric(FabricConfig(bandwidth=100.0, latency=0.0))
    r1, r2 = _reqs([0, 1])
    fab.request(r1, 0.0, 100)
    fab.resolve()
    fab.request(r2, 0.5, 100)        # channel busy until 1.0
    fab.resolve()
    assert r1.kv_landed_time == pytest.approx(1.0)
    assert r2.decode_ready_time == pytest.approx(2.0)


def test_fabric_config_validation():
    with pytest.raises(ValueError):
        FabricConfig(bandwidth=0.0)
    with pytest.raises(ValueError):
        FabricConfig(chunk_bytes=-1)


# ---------------------------------------------------------------------------
# symmetric prefill-tier elasticity
# ---------------------------------------------------------------------------


def test_prefill_tier_add_worker_mid_stream():
    cfg = PrefillConfig(n_workers=1, link=TransferLink(bandwidth=1e30,
                                                       latency=0.0))
    tier = PrefillTier(cfg, [_worker(cfg)])
    tier.process(_reqs([0, 1]))
    i = tier.add_worker(_worker(cfg), now=5.0)
    assert tier.workers[i].clock == 5.0
    late = _reqs([2, 3], arrivals=[5.0, 5.0])
    late[0].rid, late[1].rid = 10, 11
    tier.process(late)
    # least-outstanding routing spreads across both active workers
    assert {r.prefill_replica for r in late} == {0, 1}
    assert tier.scale_events == 1


def test_prefill_tier_retired_worker_drains_but_gets_no_new_work():
    cfg = PrefillConfig(n_workers=2, link=TransferLink(bandwidth=1e30,
                                                       latency=0.0))
    tier = PrefillTier(cfg, [_worker(cfg), _worker(cfg)])
    reqs = _reqs([0, 1])
    tier.submit(reqs)                # one per worker
    tier.retire_worker(1)
    late = _reqs([2, 3])
    late[0].rid, late[1].rid = 10, 11
    tier.submit(late)
    assert all(r.prefill_replica == 0 for r in late)
    tier.drain()                     # retired worker still finishes its one
    assert all(r.prefilled for r in reqs + late)
    assert tier.n_active == 1


def test_prefill_tier_cannot_retire_last_worker():
    cfg = PrefillConfig(n_workers=1)
    tier = PrefillTier(cfg, [_worker(cfg)])
    with pytest.raises(ValueError):
        tier.retire_worker(0)


# ---------------------------------------------------------------------------
# joint autoscaler policy
# ---------------------------------------------------------------------------


def _joint(total=4, **kw):
    budget = HardwareBudget(BudgetConfig(total_accelerators=total))
    cfg = JointAutoscalerConfig(cooldown_intervals=0, **kw)
    return JointAutoscaler(cfg, SLOConfig(ttft_p95=1.0), budget), budget


def test_joint_grows_pressured_tier_from_free_pool():
    a, b = _joint(total=4)
    b.allocate("prefill")
    b.allocate("decode")
    # prefill lag blowing its SLO share, decode fine, pool has room
    assert a.decide(1.0, [0.6] * 20, [], [0.05] * 20, [0.9] * 20,
                    n_prefill=1, n_decode=1,
                    prefill_backlog=0, decode_backlog=0) == (1, 0)
    # decode wait blowing its share, prefill fine
    a2, b2 = _joint(total=4)
    b2.allocate("prefill")
    b2.allocate("decode")
    assert a2.decide(1.0, [0.8] * 20, [], [0.7] * 20, [0.05] * 20,
                     n_prefill=1, n_decode=1,
                     prefill_backlog=0, decode_backlog=0) == (0, 1)


def test_joint_trades_when_budget_exhausted():
    # pool full: 1 prefill + 3 decode on 4 accels; prefill drowning,
    # decode comfortable -> decode funds prefill
    a, b = _joint(total=4)
    b.allocate("prefill")
    for _ in range(3):
        b.allocate("decode")
    assert a.decide(1.0, [0.6] * 20, [], [0.05] * 20, [0.9] * 20,
                    n_prefill=1, n_decode=3,
                    prefill_backlog=9, decode_backlog=1) == (1, -1)
    # and the symmetric trade
    a2, b2 = _joint(total=4)
    for _ in range(3):
        b2.allocate("prefill")
    b2.allocate("decode")
    assert a2.decide(1.0, [0.8] * 20, [], [0.7] * 20, [0.01] * 20,
                     n_prefill=3, n_decode=1,
                     prefill_backlog=1, decode_backlog=9) == (-1, 1)


def test_joint_never_robs_a_hot_tier():
    # both tiers hot, pool full: no trade, no change
    a, b = _joint(total=2)
    b.allocate("prefill")
    b.allocate("decode")
    assert a.decide(1.0, [2.0] * 20, [], [0.8] * 20, [0.9] * 20,
                    n_prefill=1, n_decode=1,
                    prefill_backlog=9, decode_backlog=9) == (0, 0)


def test_joint_releases_cold_capacity():
    a, b = _joint(total=6)
    for _ in range(3):
        b.allocate("prefill")
        b.allocate("decode")
    d = a.decide(1.0, [0.05] * 20, [0.001] * 20, [0.04] * 20, [0.01] * 20,
                 n_prefill=3, n_decode=3,
                 prefill_backlog=0, decode_backlog=0)
    assert d in ((-1, 0), (0, -1))
    assert sum(d) == -1


def test_joint_respects_min_and_cooldown():
    a, b = _joint(total=4)
    a.cfg.cooldown_intervals = 1
    b.allocate("prefill")
    b.allocate("decode")
    assert a.decide(1.0, [0.6] * 20, [], [0.05] * 20, [0.9] * 20,
                    1, 1, 0, 0) == (1, 0)
    # cooldown swallows the next decision
    assert a.decide(2.0, [0.6] * 20, [], [0.05] * 20, [0.9] * 20,
                    2, 1, 0, 0) == (0, 0)
    # min_prefill/min_decode floor the trades
    a2, b2 = _joint(total=2)
    b2.allocate("prefill")
    b2.allocate("decode")
    assert a2.decide(1.0, [0.6] * 20, [], [0.05] * 20, [0.9] * 20,
                     n_prefill=1, n_decode=1,
                     prefill_backlog=9, decode_backlog=0) == (0, 0)


def test_joint_history_records_decisions():
    a, b = _joint(total=4)
    b.allocate("prefill")
    b.allocate("decode")
    a.decide(1.0, [0.6] * 20, [], [0.05] * 20, [0.9] * 20, 1, 1, 0, 0)
    assert len(a.history) == 1
    h = a.history[0]
    assert h.d_prefill == 1 and h.d_decode == 0
    assert h.prefill_lag_p95 == pytest.approx(0.9)


# ---------------------------------------------------------------------------
# acceptance: joint autoscaling beats every static split of a fixed budget,
# and chunked streaming beats serial handoff when transfer-bound
# ---------------------------------------------------------------------------


TOTAL_ACCELS = 6
SLO_TTFT = 0.4


def test_joint_autoscaler_meets_slo_every_static_split_misses():
    """Fixed 6-accelerator budget, Zipf(1.0) gamma-burst arrivals over 256
    adapters with a phase shift (prompt-heavy then decode-heavy): every
    static prefill:decode split of the budget blows the 400 ms p95 TTFT
    SLO, the joint autoscaler meets it by re-splitting on the fly."""
    from benchmarks.joint_budget import (joint_cell, phase_shift_workload,
                                         static_split_cell)
    from repro.configs import get_config

    cfg = get_config("mistral-7b")
    reqs = phase_shift_workload(alpha=1.0, seed=0)

    static_p95 = {}
    for n_prefill in range(1, TOTAL_ACCELS):
        stats = static_split_cell(cfg, reqs, n_prefill,
                                  TOTAL_ACCELS - n_prefill)
        static_p95[n_prefill] = stats.total.ttft_pct(95)
    joint = joint_cell(cfg, reqs, TOTAL_ACCELS, slo_ttft=SLO_TTFT)
    joint_p95 = joint.total.ttft_pct(95)

    assert all(p95 > SLO_TTFT for p95 in static_p95.values()), static_p95
    assert joint_p95 <= SLO_TTFT, (joint_p95, static_p95)
    # it reallocated for real: membership changed in both tiers and the
    # budget was never exceeded
    assert joint.scale_events > 2
    assert joint.budget["prefill_workers"] + joint.budget["decode_replicas"] \
        <= TOTAL_ACCELS
    assert joint.total.n_requests == len(reqs)


def test_chunked_streaming_beats_serial_on_transfer_bound_fabric():
    """On a 2 GB/s fabric (transfer-bound for 256-token-prompt KV), chunked
    streaming handoff strictly lowers p95 TTFT vs serial whole-KV transfer:
    decode admission unblocks at the first landed chunk."""
    from benchmarks.joint_budget import static_split_cell
    from repro.configs import get_config
    from repro.serving.workload import WorkloadSpec, make_workload

    cfg = get_config("mistral-7b")
    wl = WorkloadSpec(n_requests=300, n_adapters=256, popularity="zipf",
                      zipf_alpha=1.0, arrival="gamma", arrival_rate=150.0,
                      burst_cv=4.0, new_tokens=32, prompt_len_mean=256,
                      prompt_len_std=32, seed=0)
    reqs = make_workload(wl)
    serial = static_split_cell(
        cfg, reqs, 3, 3,
        fabric=FabricConfig(bandwidth=2e9, chunk_bytes=0))
    chunked = static_split_cell(
        cfg, reqs, 3, 3,
        fabric=FabricConfig(bandwidth=2e9, chunk_bytes=1 << 20))
    assert chunked.total.ttft_pct(95) < serial.total.ttft_pct(95)
    # same bytes moved either way, just streamed
    assert (chunked.to_dict()["kv_bytes_moved"]
            == serial.to_dict()["kv_bytes_moved"])


def test_pr1_single_replica_uniform_numbers_bit_exact():
    """The budget/fabric refactor keeps the original single-replica uniform
    study reproducing the seed numbers (colocated path: no fabric at all)."""
    from repro.configs import get_config
    from repro.serving.simulator import run_throughput_study
    from repro.serving.workload import WorkloadSpec

    cfg = get_config("mistral-7b")
    rows = run_throughput_study(
        cfg, [4], WorkloadSpec(n_requests=150, new_tokens=10))
    assert rows[0]["jd"]["throughput_rps"] == pytest.approx(
        146.11467216655996, rel=1e-9)
    assert rows[0]["lora"]["throughput_rps"] == pytest.approx(
        111.18997706172227, rel=1e-9)


def test_pr2_single_link_disagg_numbers_bit_exact():
    """A 1-worker disaggregated cell (the PR-2 single-link shape) produces
    the same request stamps whether the handoff is the tier's shared fabric
    or a literal per-worker TransferLink replay."""
    link = TransferLink(bandwidth=1000.0, latency=0.01)
    cfg = PrefillConfig(n_workers=1, link=link)
    w = _worker(cfg, kv=500)
    reqs = _reqs([0, 1, 2], arrivals=[0.0, 0.1, 4.0])
    w.submit(reqs)
    w.drain()
    # replay PR-2 arithmetic: serialized per-link, start at prefill-done
    free = 0.0
    for r in sorted(reqs, key=lambda r: r.prefill_done_time):
        start = max(r.prefill_done_time, free)
        done = start + link.time_for(500)
        free = done
        assert r.decode_ready_time == pytest.approx(done, rel=1e-12)
        assert r.kv_landed_time == r.decode_ready_time
