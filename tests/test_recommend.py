"""§6.5 hyperparameter recommendation procedure."""
import jax
import jax.numpy as jnp

from repro.core import LoRABank, recommend, recommend_rank
from repro.core.recommend import pick_probe_module


def test_rank_rule():
    assert recommend_rank(64) == 64 // 2 + 7
    assert recommend_rank(2) >= 4


def test_probe_module_is_middle():
    names = [f"layers.{i}.q" for i in range(9)]
    assert pick_probe_module(names) == sorted(names)[4]


def test_small_collection_no_clustering():
    key = jax.random.PRNGKey(0)
    banks = {}
    for m in ("l0.q", "l1.q"):
        ka, kb = jax.random.split(jax.random.fold_in(key, hash(m) % 100))
        banks[m] = LoRABank(A=jax.random.normal(ka, (10, 2, 24)),
                            B=jax.random.normal(kb, (10, 24, 2)),
                            ranks=jnp.full((10,), 2, jnp.int32))
    rec = recommend(banks)
    assert rec.n_clusters == 1
    assert rec.rank == recommend_rank(10)


def test_large_collection_picks_clusters():
    key = jax.random.PRNGKey(1)
    n = 120
    banks = {}
    ka, kb = jax.random.split(key)
    # two strong families => clustering should hit the 0.6 threshold fast
    A1 = jnp.tile(jax.random.normal(ka, (1, 2, 24)), (n // 2, 1, 1))
    A2 = jnp.tile(jax.random.normal(kb, (1, 2, 24)), (n // 2, 1, 1))
    A = jnp.concatenate([A1, A2]) + 0.05 * jax.random.normal(ka, (n, 2, 24))
    B = jnp.tile(jax.random.normal(kb, (1, 24, 2)), (n, 1, 1))
    banks["mid.q"] = LoRABank(A=A, B=B, ranks=jnp.full((n,), 2, jnp.int32))
    rec = recommend(banks, rank=4, max_clusters=8, iters=8)
    assert rec.n_clusters <= 8
    assert rec.probe_module == "mid.q"
    assert min(rec.probe_losses.values()) < 0.6
