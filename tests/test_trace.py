"""Multi-tenant trace replay through the disaggregated serving stack.

The committed fixture is a downsampled Azure/Splitwise-style trace: two
tenant classes (chat = short prompts / long generations, summarization =
long prompts / short generations), Zipf-ish tenant popularity, bursty
arrivals, and a few out-of-order timestamps from concurrent frontends —
the shapes the ROADMAP's trace-dataset item calls for."""
import os

import pytest

from repro.configs import get_config
from repro.serving.prefill import PrefillConfig
from repro.serving.router import FleetConfig
from repro.serving.simulator import run_elastic_study
from repro.serving.workload import load_trace

TRACE = os.path.join(os.path.dirname(__file__), "data",
                     "splitwise_multitenant_sample.csv")


def _load():
    # the fixture deliberately contains out-of-order frontend timestamps;
    # the loader sorts (with a warning) and renumbers rids
    with pytest.warns(UserWarning, match="out-of-order"):
        return load_trace(TRACE)


def test_fixture_shape():
    reqs = _load()
    assert len(reqs) == 160
    assert [r.rid for r in reqs] == list(range(160))
    assert all(a.arrival_time <= b.arrival_time
               for a, b in zip(reqs, reqs[1:]))
    tenants = {r.adapter_id for r in reqs}
    assert 8 <= len(tenants) <= 12
    # both tenant classes present: long-prompt/short-gen and the reverse
    assert any(r.prompt_len >= 256 and r.max_new_tokens <= 48 for r in reqs)
    assert any(r.prompt_len <= 256 and r.max_new_tokens >= 64 for r in reqs)


def test_trace_replays_through_disaggregated_fleet():
    cfg = get_config("mistral-7b")
    reqs = _load()
    n_tenants = max(r.adapter_id for r in reqs) + 1
    stats = run_elastic_study(
        cfg, "jd", n_tenants, reqs,
        FleetConfig(n_replicas=2, policy="cluster_affinity"),
        prefill_cfg=PrefillConfig(n_workers=2))
    assert stats.total.n_requests == len(reqs)
    assert all(r.done and r.prefilled for r in reqs)
    assert all(r.first_token_time > r.decode_ready_time for r in reqs)
    d = stats.to_dict()
    assert d["n_prefills"] == len(reqs)
    assert d["kv_bytes_moved"] > 0


def test_trace_replay_is_deterministic():
    cfg = get_config("mistral-7b")
    runs = []
    for _ in range(2):
        reqs = _load()
        stats = run_elastic_study(
            cfg, "jd", max(r.adapter_id for r in reqs) + 1, reqs,
            FleetConfig(n_replicas=2, policy="cluster_affinity"),
            prefill_cfg=PrefillConfig(n_workers=2))
        runs.append(stats.total.throughput_rps)
    assert runs[0] == runs[1]
