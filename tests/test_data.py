"""Data pipeline: determinism, masking, task diversity."""
import numpy as np

from repro.data.pipeline import TaskDataLoader
from repro.data.tasks import (batch_of, eval_token_accuracy, make_task,
                              sample_example)


def test_batches_deterministic():
    spec = make_task(3)
    b1 = batch_of(spec, 4, 32, seed=42)
    b2 = batch_of(spec, 4, 32, seed=42)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    np.testing.assert_array_equal(b1["targets"], b2["targets"])


def test_loader_resumable():
    spec = make_task(1)
    l = TaskDataLoader(spec, 2, 32, base_seed=7)
    direct = [l.batch_at(i) for i in range(5)]
    it = l.iterate(3)
    got = next(it)
    np.testing.assert_array_equal(got["tokens"], direct[3]["tokens"])


def test_loss_mask_only_on_output():
    spec = make_task(2)
    rng = np.random.default_rng(0)
    toks, tgts = sample_example(spec, rng)
    n_masked = (tgts == -1).sum()
    assert n_masked == 1 + spec.instr_len + spec.in_len
    assert (tgts[n_masked:] >= 0).all()


def test_tasks_differ():
    outs = []
    for t in range(7):
        spec = make_task(t)
        rng2 = np.random.default_rng(123)
        toks, tgts = sample_example(spec, rng2)
        outs.append(tgts[tgts >= 0])
    distinct = {tuple(o.tolist()) for o in outs}
    assert len(distinct) >= 6   # the 7 kinds give >= 6 distinct outputs


def test_oracle_predictor_scores_one():
    """Predicting the ground-truth targets scores accuracy 1."""
    spec = make_task(4)

    def oracle(tokens):
        b = batch_of(spec, tokens.shape[0], tokens.shape[1], seed=999)
        return b["targets"]

    assert eval_token_accuracy(spec, oracle, n=8, seed=999) == 1.0
