"""Unit + property tests for the JD compression core (paper §3.1 / App. A)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (see requirements.txt)")
from hypothesis import given, settings, strategies as st

from repro.core import (CompressionConfig, LoRABank, compress_bank,
                        jd_convergence_gap, jd_diag, jd_full, jd_full_eig,
                        jd_objective, normalize_bank, product_frob_norms,
                        reconstruction_errors, stack_bank, svd_per_lora,
                        svd_reconstruction_errors, ties_merge)

jax.config.update("jax_platform_name", "cpu")


def random_bank(key, n=8, r_l=4, d_in=48, d_out=32, scale=0.25):
    ka, kb = jax.random.split(key)
    A = jax.random.normal(ka, (n, r_l, d_in)) * scale
    B = jax.random.normal(kb, (n, d_out, r_l)) * scale
    return A, B


def test_product_norms_match_materialized():
    A, B = random_bank(jax.random.PRNGKey(0))
    deltas = jnp.einsum("nor,nri->noi", B, A)
    ref = jnp.sqrt(jnp.sum(deltas ** 2, axis=(1, 2)))
    np.testing.assert_allclose(product_frob_norms(A, B), ref, rtol=1e-5)


def test_error_formula_matches_materialized():
    A, B = random_bank(jax.random.PRNGKey(1))
    res = jd_full(A, B, rank=6, iters=8)
    deltas = jnp.einsum("nor,nri->noi", B, A)
    err_mat = jnp.sum((deltas - res.reconstruct()) ** 2)
    errs = reconstruction_errors(A, B, res)
    np.testing.assert_allclose(err_mat, jnp.sum(errs["err_sq"]), rtol=1e-3)


def test_jd_full_lossless_at_tilde_r():
    from repro.core.theory import tilde_r
    A, B = random_bank(jax.random.PRNGKey(2), n=4, r_l=3, d_in=32, d_out=24)
    tr = tilde_r(A, B)
    res = jd_full(A, B, rank=tr, iters=30)
    assert float(reconstruction_errors(A, B, res)["loss"]) < 1e-5


def test_jd_full_monotone_in_rank():
    A, B = random_bank(jax.random.PRNGKey(3))
    losses = [float(reconstruction_errors(
        A, B, jd_full(A, B, rank=r, iters=12))["loss"]) for r in (2, 4, 8, 16)]
    assert all(l1 >= l2 - 1e-4 for l1, l2 in zip(losses, losses[1:])), losses


def test_objective_decreases_with_iters():
    A, B = random_bank(jax.random.PRNGKey(4))
    o1 = float(jd_objective(A, B, jd_full(A, B, rank=6, iters=1)))
    o10 = float(jd_objective(A, B, jd_full(A, B, rank=6, iters=10)))
    assert o10 <= o1 + 1e-5


def test_eig_iteration_matches_eigh():
    A, B = random_bank(jax.random.PRNGKey(5))
    l_eigh = float(reconstruction_errors(A, B, jd_full(A, B, 8, iters=15))["loss"])
    l_eig = float(reconstruction_errors(A, B, jd_full_eig(A, B, 8, iters=60))["loss"])
    assert abs(l_eig - l_eigh) < 0.02, (l_eig, l_eigh)


def test_eig_iteration_convergence():
    """App. H.12 convergence criterion reaches small gap."""
    A, B = random_bank(jax.random.PRNGKey(6))
    res1 = jd_full_eig(A, B, rank=6, iters=40)
    res2 = jd_full_eig(A, B, rank=6, iters=41)
    gap = float(jd_convergence_gap(res1.U, res2.U))
    assert gap < 0.05


def test_jd_diag_no_better_than_full():
    """Same r: diag constrains Sigma, so error >= full (paper §4)."""
    A, B = random_bank(jax.random.PRNGKey(7))
    lf = float(reconstruction_errors(A, B, jd_full(A, B, 8, iters=15))["loss"])
    ld = float(reconstruction_errors(A, B, jd_diag(A, B, 8, iters=40))["loss"])
    assert ld >= lf - 0.02


def test_svd_lossless_at_full_rank():
    A, B = random_bank(jax.random.PRNGKey(8), r_l=4)
    res = svd_per_lora(A, B, rank=4)
    assert float(svd_reconstruction_errors(A, B, res)["loss"]) < 1e-5


def test_normalization_roundtrip():
    A, B = random_bank(jax.random.PRNGKey(9), n=3, r_l=2, d_in=16, d_out=12)
    bank = LoRABank(A=A, B=B, ranks=jnp.full((3,), 2, jnp.int32))
    from repro.core.theory import tilde_r
    tr = tilde_r(A, B)
    cm = compress_bank(bank, CompressionConfig(method="jd_full", rank=tr,
                                               iters=40, normalize=True))
    # denormalized sigma must reconstruct the ORIGINAL (unnormalized) deltas
    rec = cm.result.reconstruct(1)
    np.testing.assert_allclose(np.asarray(rec), np.asarray(B[1] @ A[1]),
                               atol=2e-3)


def test_stack_bank_pads_heterogeneous_ranks():
    key = jax.random.PRNGKey(10)
    pairs = []
    for r in (2, 4, 3):
        ka, kb = jax.random.split(jax.random.fold_in(key, r))
        pairs.append((jax.random.normal(ka, (r, 20)),
                      jax.random.normal(kb, (16, r))))
    bank = stack_bank(pairs)
    assert bank.A.shape == (3, 4, 20)
    for i, (a, b) in enumerate(pairs):
        np.testing.assert_allclose(np.asarray(bank.delta(i)),
                                   np.asarray(b @ a), rtol=2e-5, atol=1e-5)


def test_ties_merge_single_basis():
    A, B = random_bank(jax.random.PRNGKey(11))
    res = ties_merge(A, B, rank=8)
    assert res.U.shape[-1] == 8 and res.sigma.shape[0] == A.shape[0]


@settings(max_examples=12, deadline=None)
@given(n=st.integers(2, 10), r_l=st.integers(1, 5),
       d_in=st.integers(8, 40), d_out=st.integers(8, 40),
       rank=st.integers(1, 12), seed=st.integers(0, 2 ** 16))
def test_property_error_nonneg_and_bounded(n, r_l, d_in, d_out, rank, seed):
    """0 <= loss <= 1 after normalization, any shape/rank."""
    A, B = random_bank(jax.random.PRNGKey(seed), n=n, r_l=r_l,
                       d_in=d_in, d_out=d_out)
    A, B, _ = normalize_bank(A, B)
    res = jd_full(A, B, rank=min(rank, d_in, d_out), iters=6)
    loss = float(reconstruction_errors(A, B, res)["loss"])
    assert -1e-4 <= loss <= 1.0 + 1e-4
